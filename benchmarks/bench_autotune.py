"""Paper §Abstract claims: predictor-guided tile selection gives up to 3.2x
speedup and 22% power reduction vs baseline configurations — reproduced with
the autotuner over a grid of GEMM shapes, for both objectives.

Also times the two prediction paths (numpy vs jitted forest) — the jitted
path is what lets the tuner rank candidates inside compiled search loops."""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import (default_chip, dump, get_dataset, paper_split,
                               row, timeit)
from repro.core.autotuner import GemmAutotuner
from repro.core.features import NUMERIC_FEATURES
from repro.core.hwsim import TpuGemmSimulator
from repro.core.predictor import PerfPredictor


SHAPES = [
    (512, 512, 512), (1024, 1024, 1024), (2048, 2048, 2048),
    (4096, 4096, 4096), (8192, 8192, 8192),
    (4096, 4096, 1024), (16, 4096, 4096), (8192, 1024, 8192),
    (32768, 4096, 4096),
]


def run() -> list[dict]:
    table = get_dataset()
    tr, _ = paper_split(table, train_n=4000)
    pred = PerfPredictor(model="rf", residual=True, fast=True,
                         chip=default_chip()).fit(tr)
    tuner = GemmAutotuner(pred, TpuGemmSimulator(chip=default_chip(), seed=7))

    reports_rt = [tuner.tune_report(*s) for s in SHAPES]
    reports_en = [tuner.tune_report(*s, objective="energy") for s in SHAPES]
    reports_pw = [tuner.tune_report(*s, objective="power") for s in SHAPES]
    best_speedup = max(r["speedup"] for r in reports_rt)
    mean_speedup = float(np.mean([r["speedup"] for r in reports_rt]))
    best_power = max(r["power_reduction_pct"] for r in reports_pw)
    best_energy = max(r["energy_reduction_pct"] for r in reports_en)

    us_tune = timeit(lambda: tuner.tune_report(4096, 4096, 4096), n=3)

    # prediction-path latency: numpy vs jitted forest (batch of 64 configs)
    cfgs = tuner.candidate_configs(4096, 4096, 4096)[:64]
    from repro.core.features import features_matrix

    X = features_matrix(cfgs)
    Xj = jnp.asarray(X, jnp.float32)
    jfn = pred.jax_predictor()
    jfn(Xj)  # compile
    us_np = timeit(lambda: pred.predict_matrix(
        {k: X[:, i] for i, k in enumerate(NUMERIC_FEATURES)}), n=10)
    us_jax = timeit(lambda: jfn(Xj).block_until_ready(), n=10)

    dump("autotune", {
        "runtime_reports": reports_rt,
        "energy_reports": reports_en,
        "power_reports": reports_pw,
        "best_speedup": best_speedup,
        "mean_speedup": mean_speedup,
        "best_power_reduction_pct": best_power,
        "best_energy_reduction_pct": best_energy,
        "paper_claims": {"speedup": 3.2, "power_reduction_pct": 22.0},
        "predict_us_numpy_64cfgs": us_np,
        "predict_us_jax_64cfgs": us_jax,
    })
    return [
        row("autotune.runtime_objective", us_tune,
            f"best_speedup={best_speedup:.2f}x(paper:3.2x);"
            f"mean={mean_speedup:.2f}x"),
        row("autotune.energy_objective", us_tune,
            f"power_red={best_power:.1f}%(paper:22%);"
            f"energy_red={best_energy:.1f}%"),
        row("autotune.predict_numpy", us_np, "64 configs/call"),
        row("autotune.predict_jitted", us_jax, "64 configs/call (in-jit)"),
    ]
