"""Paper §Abstract claims: predictor-guided tile selection gives up to 3.2x
speedup and 22% power reduction vs baseline configurations — reproduced with
the autotuner over a grid of GEMM shapes, for both objectives.

Also times the serving hot path: `rank` over a 512-candidate grid through
the batched scorer (stacked-descent / jit) vs the pre-refactor NumPy
per-tree loop, plus the batched `tune_many` fleet API vs per-shape tuning.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import (default_chip, dump, get_dataset, paper_split,
                               row, timeit)
from repro.core.autotuner import GemmAutotuner
from repro.core.features import features_matrix, table_from_configs
from repro.core.hwsim import TpuGemmSimulator
from repro.core.predictor import PerfPredictor
from repro.core.profiler import sweep_configs


SHAPES = [
    (512, 512, 512), (1024, 1024, 1024), (2048, 2048, 2048),
    (4096, 4096, 4096), (8192, 8192, 8192),
    (4096, 4096, 1024), (16, 4096, 4096), (8192, 1024, 8192),
    (32768, 4096, 4096),
]


def run() -> list[dict]:
    table = get_dataset()
    tr, _ = paper_split(table, train_n=4000)
    pred = PerfPredictor(model="rf", residual=True, fast=True,
                         chip=default_chip()).fit(tr)
    tuner = GemmAutotuner(pred, TpuGemmSimulator(chip=default_chip(), seed=7))

    reports_rt = [tuner.tune_report(*s) for s in SHAPES]
    reports_en = [tuner.tune_report(*s, objective="energy") for s in SHAPES]
    reports_pw = [tuner.tune_report(*s, objective="power") for s in SHAPES]
    best_speedup = max(r["speedup"] for r in reports_rt)
    mean_speedup = float(np.mean([r["speedup"] for r in reports_rt]))
    best_power = max(r["power_reduction_pct"] for r in reports_pw)
    best_energy = max(r["energy_reduction_pct"] for r in reports_en)

    us_tune = timeit(lambda: tuner.tune_report(4096, 4096, 4096), n=3)

    # batched fleet tuning (fresh tuner so nothing is cached)
    fleet_tuner = GemmAutotuner(
        pred, TpuGemmSimulator(chip=default_chip(), seed=7))
    us_fleet = timeit(lambda: fleet_tuner.tune_many(SHAPES), n=1, warmup=0)

    # rank-latency: 512-candidate grid, batched scorer vs the pre-refactor
    # NumPy per-tree loop (both rankings must agree)
    cfgs = sweep_configs(n_configs=512, seed=1)
    X = features_matrix(cfgs, chip=tuner.chip)
    tuner.rank(cfgs, features=X)  # warm the compiled scorer

    def rank_reference():
        t = table_from_configs(cfgs, chip=tuner.chip)
        return np.argsort(pred.predict_matrix_reference(t)[:, 0])

    us_rank = timeit(lambda: tuner.rank(cfgs, features=X), n=10)
    us_rank_ref = timeit(rank_reference, n=10)

    # fully in-graph ranking: feature grid + compiled predictor + top-k in
    # one jit call, 4 shapes x 160-block static grid = 640 candidates
    graph_shapes = SHAPES[:4]
    tuner.rank_in_graph(graph_shapes)  # warm the compiled ranker
    us_rank_graph = timeit(lambda: tuner.rank_in_graph(graph_shapes), n=10)
    # parity gate: batched scores within 1e-4 relative of the loop path
    # (exact order equality only holds on the bit-exact numpy scorer; the
    # jit path on accelerators is ~1e-9 and can flip near-ties)
    ref_scores = pred.predict_matrix_reference(
        table_from_configs(cfgs, chip=tuner.chip))
    new_scores = tuner._predict_features(X)
    rel = np.abs(new_scores - ref_scores) / np.maximum(
        np.abs(ref_scores), 1e-12)
    assert rel.max() < 1e-4, f"scorer parity violated: {rel.max():.2e}"

    dump("autotune", {
        "runtime_reports": reports_rt,
        "energy_reports": reports_en,
        "power_reports": reports_pw,
        "best_speedup": best_speedup,
        "mean_speedup": mean_speedup,
        "best_power_reduction_pct": best_power,
        "best_energy_reduction_pct": best_energy,
        "paper_claims": {"speedup": 3.2, "power_reduction_pct": 22.0},
        "artifact_fingerprint": tuner.artifact_fingerprint,
        "tune_many_us_9shapes": us_fleet,
        "rank512_us_batched": us_rank,
        "rank512_us_reference_loop": us_rank_ref,
        "rank512_speedup": us_rank_ref / us_rank,
        "rank_in_graph_us_640cand": us_rank_graph,
    })
    return [
        row("autotune.runtime_objective", us_tune,
            f"best_speedup={best_speedup:.2f}x(paper:3.2x);"
            f"mean={mean_speedup:.2f}x"),
        row("autotune.energy_objective", us_tune,
            f"power_red={best_power:.1f}%(paper:22%);"
            f"energy_red={best_energy:.1f}%"),
        row("autotune.tune_many", us_fleet, f"{len(SHAPES)} shapes/call"),
        row("autotune.rank512_batched", us_rank, "512 candidates/call"),
        row("autotune.rank512_reference", us_rank_ref,
            f"numpy per-tree loop; batched is "
            f"{us_rank_ref / us_rank:.1f}x faster"),
        row("autotune.rank_in_graph", us_rank_graph,
            "4 shapes x 160-block grid, one jit call (scoped x64)"),
    ]
