"""Paper Table V / Fig 6: correlations between dimension products
(MxN, MxK, NxK, MxNxK) and runtime/power/energy/TFLOPS."""

from __future__ import annotations

import numpy as np

from benchmarks.common import dump, get_dataset, row, timeit
from repro.core.mlperf.metrics import correlation_matrix


def run() -> list[dict]:
    table = get_dataset()
    dims = ["mxn", "mxk", "nxk", "mxnxk"]
    mets = ["runtime_ms", "power_w", "energy_j", "tflops"]
    mat_all = correlation_matrix(table, dims, mets)
    # The paper sweeps tuned CUTLASS kernels — no pathological tiles. Our
    # sweep includes sub-MXU blocks whose overhead-bound runtimes decouple
    # from mxnxk; the comparable population is the production-block subset.
    sel = np.asarray(table["block_m"]) >= 64
    sub = {k: np.asarray(v)[sel] for k, v in table.items()
           if k in dims + mets}
    mat = correlation_matrix(sub, dims, mets)
    us = timeit(lambda: correlation_matrix(sub, dims, mets), n=3)
    paper = {
        "mxn": [0.85, 0.80, 0.77, -0.39],
        "mxk": [0.89, 0.59, 0.81, -0.23],
        "nxk": [0.69, 0.38, 0.65, -0.09],
        "mxnxk": [0.98, 0.70, 0.91, -0.41],
    }
    dump("correlations", {
        "dims": dims, "metrics": mets,
        "ours_production_blocks": {
            d: [float(x) for x in mat[i]] for i, d in enumerate(dims)},
        "ours_all_configs": {
            d: [float(x) for x in mat_all[i]] for i, d in enumerate(dims)},
        "paper": paper,
    })
    i = dims.index("mxnxk")
    return [row(
        "table5.correlations", us,
        f"corr(mxnxk,rt)={mat[i][0]:.2f}(paper:0.98);"
        f"corr(mxn,pw)={mat[0][1]:.2f}(paper:0.80);"
        f"corr(mxnxk,tflops)={mat[i][3]:.2f}(paper:-0.41)")]
