"""Paper §IV-C: the 16,128-operation CUTLASS-analogue profiling sweep."""

from __future__ import annotations

import time

from benchmarks.common import dump, get_dataset, row


def run() -> list[dict]:
    t0 = time.perf_counter()
    table = get_dataset()
    dt = time.perf_counter() - t0
    n = len(table["runtime_ms"])
    bounds = {}
    for b in table["bound"]:
        bounds[str(b)] = bounds.get(str(b), 0) + 1
    dump("dataset_sweep", {
        "rows": n,
        "collect_or_load_s": dt,
        "bound_distribution": bounds,
        "runtime_ms_range": [float(table["runtime_ms"].min()),
                             float(table["runtime_ms"].max())],
        "power_w_range": [float(table["power_w"].min()),
                          float(table["power_w"].max())],
    })
    return [row("dataset.profile_sweep", dt / max(n, 1) * 1e6,
                f"rows={n};bounds={bounds}")]
