"""Paper §IV-C: the 16,128-operation CUTLASS-analogue profiling sweep,
collected through the vectorized measure_batch substrate."""

from __future__ import annotations

import time

from benchmarks.common import default_chip, dump, get_dataset, row
from repro.core.profiler import sweep_configs
from repro.core.hwsim import TpuGemmSimulator


def run() -> list[dict]:
    t0 = time.perf_counter()
    table = get_dataset()
    dt = time.perf_counter() - t0
    n = len(table["runtime_ms"])
    bounds = {}
    for b in table["bound"]:
        bounds[str(b)] = bounds.get(str(b), 0) + 1

    # batch-vs-scalar substrate throughput on the same 1k-config slice
    cfgs = sweep_configs(n_configs=1000, seed=3)
    sim_b = TpuGemmSimulator(chip=default_chip(), seed=3)
    t0 = time.perf_counter()
    sim_b.measure_batch(cfgs)
    batch_s = time.perf_counter() - t0
    sim = TpuGemmSimulator(chip=default_chip(), seed=3)
    t0 = time.perf_counter()
    for cfg in cfgs:
        sim.measure(cfg)
    scalar_s = time.perf_counter() - t0

    dump("dataset_sweep", {
        "chip": default_chip(),
        "rows": n,
        "collect_or_load_s": dt,
        "bound_distribution": bounds,
        "batch_sweep_s_per_1k": batch_s,
        "scalar_sweep_s_per_1k": scalar_s,
        "batch_speedup": scalar_s / max(batch_s, 1e-9),
        "runtime_ms_range": [float(table["runtime_ms"].min()),
                             float(table["runtime_ms"].max())],
        "power_w_range": [float(table["power_w"].min()),
                          float(table["power_w"].max())],
    })
    return [
        row("dataset.profile_sweep", dt / max(n, 1) * 1e6,
            f"rows={n};bounds={bounds}"),
        row("dataset.batch_vs_scalar", batch_s / 1000 * 1e6,
            f"batch_speedup={scalar_s / max(batch_s, 1e-9):.1f}x"),
    ]
