"""Kernel-level microbench: Pallas tiled GEMM (interpret mode, CPU container)
vs the jnp oracle — correctness tracking plus call latency. On real TPU
hardware this module is where wall-clock kernel timing would plug in."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import dump, row, timeit
from repro.kernels.ref import matmul_ref
from repro.kernels.tiled_matmul import BlockConfig, tiled_matmul


def run() -> list[dict]:
    rng = np.random.default_rng(0)
    m, n, k = 256, 256, 256
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    cfg = BlockConfig(64, 128, 128)

    out_p = tiled_matmul(a, b, config=cfg, interpret=True)
    out_r = matmul_ref(a, b)
    err = float(jnp.max(jnp.abs(out_p - out_r)))

    us_pallas = timeit(
        lambda: tiled_matmul(a, b, config=cfg,
                             interpret=True).block_until_ready(), n=3)
    us_ref = timeit(lambda: matmul_ref(a, b).block_until_ready(), n=10)
    dump("kernel_micro", {
        "shape": [m, n, k],
        "block": cfg.as_tuple(),
        "max_abs_err": err,
        "us_pallas_interpret": us_pallas,
        "us_xla_ref": us_ref,
    })
    return [
        row("kernel.pallas_interpret_256", us_pallas,
            f"max_err={err:.2e} (interpret=CPU correctness mode)"),
        row("kernel.xla_ref_256", us_ref, "oracle"),
    ]
