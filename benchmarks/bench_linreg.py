"""Paper Tables II/III: linear-regression coefficients for runtime and power
on the tiled-matmul study (m, n, k, tile size), with R^2 — reproducing the
paper's observation that runtime is poorly linear (R^2 0.13) while power is
much more linear (R^2 0.82)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import dump, row, timeit
from repro.core.hwsim import GemmConfig, TpuGemmSimulator
from repro.core.mlperf import LinearRegression, r2_score


def _tiled_dataset(n_runtime: int = 142, n_power: int = 196):
    """Mimic the paper's two small hand-collected datasets."""
    sim = TpuGemmSimulator(seed=1)
    rng = np.random.default_rng(1)
    sizes = [256, 512, 1024, 2048, 4096, 6144, 8192]
    tiles = [8, 64, 128, 256, 512, 1024]
    rows = []
    while len(rows) < max(n_runtime, n_power):
        m, n, k = rng.choice(sizes, 3)
        t = int(rng.choice(tiles))
        tel = sim.measure(GemmConfig(int(m), int(n), int(k), t, t,
                                     min(t, 512)))
        if tel.valid:
            rows.append((m, n, k, t, tel.runtime_ms, tel.power_w))
    arr = np.array(rows)
    X = arr[:, :4]
    return X[:n_runtime], arr[:n_runtime, 4], X[:n_power], arr[:n_power, 5]


def run() -> list[dict]:
    Xr, y_rt, Xp, y_pw = _tiled_dataset()
    lr_rt = LinearRegression().fit(Xr, y_rt)
    lr_pw = LinearRegression().fit(Xp, y_pw)
    r2_rt = r2_score(y_rt, lr_rt.predict(Xr))
    r2_pw = r2_score(y_pw, lr_pw.predict(Xp))
    us = timeit(lambda: LinearRegression().fit(Xr, y_rt), n=10)
    dump("linreg_tables", {
        "runtime_coefficients": dict(zip(["m", "n", "k", "tile"],
                                         map(float, lr_rt.coef_))),
        "power_coefficients": dict(zip(["m", "n", "k", "tile"],
                                       map(float, lr_pw.coef_))),
        "runtime_r2": r2_rt, "power_r2": r2_pw,
        "paper_runtime_r2": 0.1344, "paper_power_r2": 0.8209,
        "tile_coef_signs": {
            "runtime": float(np.sign(lr_rt.coef_[3])),
            "power": float(np.sign(lr_pw.coef_[3])),
        },
    })
    return [
        row("linreg.runtime", us,
            f"r2={r2_rt:.3f};tile_coef={lr_rt.coef_[3]:.3g}(paper:-2588)"),
        row("linreg.power", us,
            f"r2={r2_pw:.3f};tile_coef={lr_pw.coef_[3]:.3g}(paper:-0.769)"),
    ]
