"""Paper Table VI: model-architecture comparison — stacking ensemble vs
random forest vs GBDT(XGBoost stand-in) vs linear regression."""

from __future__ import annotations

import time

from benchmarks.common import dump, get_dataset, paper_split, row
from repro.core.predictor import PerfPredictor


def run() -> list[dict]:
    table = get_dataset()
    tr, te = paper_split(table)
    results = {}
    rows = []
    for name in ["stacking", "rf", "gbdt", "linreg"]:
        t0 = time.perf_counter()
        pred = PerfPredictor(model=name, residual=True, fast=True).fit(tr)
        fit_s = time.perf_counter() - t0
        rep = pred.evaluate(te)
        results[name] = {
            "fit_s": fit_s,
            "runtime_r2": rep["runtime_ms"]["r2"],
            "power_r2": rep["power_w"]["r2"],
            "energy_r2": rep["energy_j"]["r2"],
        }
        rows.append(row(
            f"table6.{name}", fit_s * 1e6,
            f"rt_r2={rep['runtime_ms']['r2']:.4f};"
            f"pw_r2={rep['power_w']['r2']:.3f};"
            f"en_r2={rep['energy_j']['r2']:.3f}"))
    results["paper_reference"] = {
        "stacking": [0.9808, 0.7783, 0.8572],
        "rf": [0.9456, 0.7234, 0.8123],
        "xgboost": [0.9623, 0.7456, 0.8345],
        "linreg": [0.8234, 0.6123, 0.7234],
    }
    dump("model_comparison", results)
    return rows
