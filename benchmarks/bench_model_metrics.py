"""Paper Table IV: multi-target model metrics (R2/MSE/MAE/Med%/Mean%) for
runtime, power, energy, TFLOPS.

Two rows per target: the paper-faithful configuration (RF 100x6, direct
regression, paper-size 2076/519 split) and the beyond-paper residual-anchor
model (EXPERIMENTS.md §Perf-pred)."""

from __future__ import annotations

import time

from benchmarks.common import dump, get_dataset, paper_split, row
from repro.core.predictor import PerfPredictor


def run() -> list[dict]:
    table = get_dataset()
    tr, te = paper_split(table)

    out = {}
    rows = []
    for tag, kwargs in [
        ("paper_faithful", dict(model="rf", residual=False,
                                log_targets=False)),
        ("residual_anchor", dict(model="rf", residual=True)),
    ]:
        t0 = time.perf_counter()
        pred = PerfPredictor(**kwargs).fit(tr)
        fit_s = time.perf_counter() - t0
        rep = pred.evaluate(te)
        out[tag] = {"fit_seconds": fit_s, "report": rep}
        rt = rep["runtime_ms"]
        rows.append(row(
            f"table4.{tag}", fit_s * 1e6,
            f"rt_r2={rt['r2']:.4f};rt_med%={rt['median_pct_err']:.1f};"
            f"pw_r2={rep['power_w']['r2']:.3f};"
            f"en_r2={rep['energy_j']['r2']:.3f};"
            f"tf_r2={rep['tflops']['r2']:.3f}"))
    out["paper_reference"] = {
        "runtime": {"r2": 0.9808, "med_pct": 11.41, "mean_pct": 15.57},
        "power": {"r2": 0.7783, "med_pct": 5.42, "mean_pct": 22.16},
        "energy": {"r2": 0.8572, "med_pct": 22.01, "mean_pct": 43.02},
        "tflops": {"r2": 0.8637, "med_pct": 6.39, "mean_pct": 10.85},
        "train_convergence_s": 6.25,
    }
    dump("model_metrics", out)
    return rows
