"""f32 in-graph ranking: quantify winner drift vs scoped-x64 (ROADMAP
follow-up from PR 3).

`GemmAutotuner.rank_in_graph` defaults to scoped float64, which is
bit-identical to the trace-time `rank()` path. The f32 mode embeds in
fp32 jitted programs (no x64 scope) and is faster to lower — but only
serves if it picks the *same winners*. This bench ranks the serving GEMM
fleet (decode + batched prefill + the chunked-admission width x bucket
grid) through each shipped golden artifact (`tests/fixtures/`) in both
precisions and counts top-1 / top-3 winner mismatches, plus wall time.

Measured result (recorded in README): zero winner drift across every
family — tree-ensemble scores are coarse and linreg margins wide, so f32
rounding never crosses an argmin boundary on these artifacts. x64 stays
the default (it carries the bit-parity guarantee); f32 is a safe opt-in
where an x64 scope is unavailable.

Run:  PYTHONPATH=src python benchmarks/bench_rank_f32.py
"""

from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import dump, row  # noqa: E402

FIXTURE_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "tests", "fixtures")
FAMILIES = ("rf", "gbdt", "linreg", "stacking")
TOP_K = 3


def _fleet():
    from repro.kernels import ops
    from repro.models.config import ModelConfig

    cfg = ModelConfig(name="drift-bench", kind="dense", n_layers=2,
                      d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024,
                      vocab=4096)
    return ops.serving_gemm_fleet(cfg, max_batch=8, max_len=512,
                                  chunk_tokens=64, lane_width=16)


def _keys(cfgs):
    return [(c.block_m, c.block_n, c.block_k) for c in cfgs]


def run(smoke: bool | None = None) -> list[dict]:
    from repro.core.autotuner import GemmAutotuner
    from repro.core.hwsim import TpuGemmSimulator
    from repro.core.predictor import PerfPredictor

    shapes = _fleet()
    rows = []
    payload = {"n_shapes": len(shapes), "top_k": TOP_K, "families": {}}
    for fam in FAMILIES:
        pred = PerfPredictor.load(
            os.path.join(FIXTURE_DIR, f"golden_{fam}.npz"))
        tuner = GemmAutotuner(pred, TpuGemmSimulator(seed=0), scorer="jit")
        t0 = time.perf_counter()
        tops64, _ = tuner.rank_in_graph(shapes, top_k=TOP_K, x64=True)
        t64 = time.perf_counter() - t0
        t0 = time.perf_counter()
        tops32, _ = tuner.rank_in_graph(shapes, top_k=TOP_K, x64=False)
        t32 = time.perf_counter() - t0
        top1 = sum(1 for a, b in zip(tops64, tops32)
                   if _keys(a[:1]) != _keys(b[:1]))
        topk = sum(1 for a, b in zip(tops64, tops32)
                   if _keys(a) != _keys(b))
        payload["families"][fam] = {
            "top1_mismatches": top1, "topk_mismatches": topk,
            "x64_s": t64, "f32_s": t32,
        }
        rows.append(row(
            f"rank_f32_drift_{fam}", t32 * 1e6,
            f"top1 drift {top1}/{len(shapes)}, top{TOP_K} {topk}/"
            f"{len(shapes)}; x64 {t64 * 1e3:.0f}ms vs f32 "
            f"{t32 * 1e3:.0f}ms"))
    dump("rank_f32_drift", payload)
    return rows


def main(argv: list[str]) -> int:
    for r in run():
        print(f"{r['name']}: {r['derived']}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
