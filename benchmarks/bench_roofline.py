"""Paper Fig 1: roofline model — ridge points and bound classification for
the target chip (TPU v5e) vs the paper's RTX 4070; plus the per-cell
roofline table derived from the dry-run artifacts (§Roofline deliverable)."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import ART, dump, row
from repro.core.chips import RTX_4070, TPU_V5E
from repro.core.energy import energy_report
from repro.core.roofline import RooflineReport, format_report_table


def reports_from_artifacts(mesh: str = "pod16x16") -> list[RooflineReport]:
    out = []
    for path in sorted(glob.glob(os.path.join(ART, "dryrun", mesh,
                                              "*.json"))):
        with open(path) as f:
            d = json.load(f)
        variant = d.get("variant")
        label = f"{d['arch']}/{d['shape']}" + (f"+{variant}" if variant
                                               else "")
        out.append(RooflineReport(
            name=label,
            n_chips=d["n_chips"],
            dtype="bf16",
            hlo_flops=d["flops_per_chip"] * d["n_chips"],
            hlo_bytes=d["bytes_per_chip"] * d["n_chips"],
            collective_wire_bytes=(d["collective_wire_bytes_per_chip"]
                                   * d["n_chips"]),
            compute_s=d["flops_per_chip"] / TPU_V5E.peak("bf16"),
            memory_s=d["bytes_per_chip"] / TPU_V5E.hbm_bw,
            collective_s=(d["collective_wire_bytes_per_chip"]
                          / TPU_V5E.ici_link_bw),
            model_flops=d["model_flops"],
            bytes_per_device=d["memory_analysis"]["argument_size_in_bytes"],
        ))
    return out


def run() -> list[dict]:
    ridge_v5e = TPU_V5E.ridge_point("bf16")
    ridge_4070 = RTX_4070.ridge_point("f32")
    rows = [row("roofline.ridge_points", 0.0,
                f"v5e={ridge_v5e:.0f}FLOPs/B;rtx4070={ridge_4070:.0f}"
                f"(paper:59)")]
    reports = reports_from_artifacts()
    if reports:
        table = format_report_table(reports)
        energies = [energy_report(
            r, tokens_per_step=1.0).as_row() for r in reports]
        dump("cell_roofline", {
            "table": table,
            "rows": [r.as_row() for r in reports],
            "energy": energies,
        })
        dominated = {}
        for r in reports:
            dominated[r.dominant] = dominated.get(r.dominant, 0) + 1
        fracs = sorted((r.roofline_fraction, r.name) for r in reports)
        rows.append(row("roofline.cells", 0.0,
                        f"cells={len(reports)};dominant={dominated};"
                        f"worst={fracs[0][1]}@{100*fracs[0][0]:.1f}%"))
        rows.append(row("roofline.best_cell", 0.0,
                        f"{fracs[-1][1]}@{100*fracs[-1][0]:.1f}%"))
    else:
        rows.append(row("roofline.cells", 0.0,
                        "no dryrun artifacts (run repro.launch.dryrun)"))
    return rows
