"""Serving-engine benchmark: continuous batching vs the legacy wave loop.

Serves one mixed-budget workload (max_new_tokens drawn from {4, 8, 64} —
the Racing-to-Idle shape) through both engine modes over the same tiny
dense LM and reports tokens/s, attributed J/token, slot occupancy, and the
executed decode-step*slot totals. The JSON artifact
(artifacts/bench/serving.json) is the regression surface CI uploads.

Run:  PYTHONPATH=src python benchmarks/bench_serving.py [--smoke]
"""

from __future__ import annotations

import os
import sys
import time

import numpy as np

# allow `python benchmarks/bench_serving.py` from anywhere (run.py inserts
# the repo root itself)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import dump, row  # noqa: E402

BUDGETS = (4, 8, 64)


def _build(smoke: bool):
    import jax

    from repro.models.config import ModelConfig
    from repro.models.registry import get_model

    cfg = ModelConfig(
        name="serve-bench", kind="dense",
        n_layers=2 if smoke else 4,
        d_model=64 if smoke else 256,
        n_heads=4 if smoke else 8, n_kv_heads=2 if smoke else 4,
        d_ff=128 if smoke else 1024, vocab=256 if smoke else 4096,
        param_dtype="float32", activation_dtype="float32", remat=False,
    )
    model = get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    return cfg, model, params


PROMPT_LEN = 16   # fixed so one wave prefill trace serves every wave and
                  # the warm-up pass can cover both modes' jit shapes


def _workload(cfg, n_requests: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [
        (uid, rng.integers(0, cfg.vocab, PROMPT_LEN).astype(np.int32),
         int(rng.choice(BUDGETS)))
        for uid in range(n_requests)
    ]


def _serve(cfg, model, params, reqs, mode: str, max_batch: int):
    from repro.serving.engine import Request, ServingEngine

    eng = ServingEngine(model, params, cfg, max_batch=max_batch,
                        max_len=128, mode=mode)
    # warm-up pass covering every jit shape the timed region traces —
    # a full wave of PROMPT_LEN prompts (wave prefill (B, S) + decode
    # (B,)) which in continuous mode also compiles the slot-prefill
    # bucket and the insert fn — then reset counters so the tok/s
    # comparison charges compilation to neither mode
    for i in range(max_batch):
        eng.submit(Request(uid=10_000 + i,
                           prompt=np.arange(1, PROMPT_LEN + 1,
                                            dtype=np.int32),
                           max_new_tokens=2))
    eng.run_until_empty()
    eng.reset_stats()
    for uid, prompt, mnt in reqs:
        eng.submit(Request(uid=uid, prompt=prompt.copy(),
                           max_new_tokens=mnt))
    t0 = time.perf_counter()
    results = eng.run_until_empty()
    wall = time.perf_counter() - t0
    rep = eng.report()
    rep["mode"] = mode
    rep["wall_s"] = wall
    rep["tokens_per_s"] = (rep["generated_tokens"] / wall if wall > 0
                           else 0.0)
    return results, rep


def run(smoke: bool | None = None) -> list[dict]:
    if smoke is None:
        # mirror benchmarks.common.default_n_configs: unset env = full scale
        smoke = int(os.environ.get("BENCH_N_CONFIGS", "16128")) <= 256
    cfg, model, params = _build(smoke)
    n_requests = 12 if smoke else 24
    max_batch = 4
    reqs = _workload(cfg, n_requests)

    res_c, rep_c = _serve(cfg, model, params, reqs, "continuous", max_batch)
    res_w, rep_w = _serve(cfg, model, params, reqs, "wave", max_batch)

    # identical greedy streams is a hard invariant, not a benchmark stat
    by_uid = {r.uid: r for r in res_w}
    for r in res_c:
        if not np.array_equal(r.tokens, by_uid[r.uid].tokens):
            raise AssertionError(f"stream mismatch for request {r.uid}")

    payload = {
        "n_requests": n_requests,
        "max_batch": max_batch,
        "budgets": list(BUDGETS),
        "continuous": rep_c,
        "wave": rep_w,
        "slot_step_reduction": (
            1.0 - rep_c["slot_steps"] / rep_w["slot_steps"]
            if rep_w["slot_steps"] else 0.0),
        "j_per_token_reduction": (
            1.0 - rep_c["j_per_token"] / rep_w["j_per_token"]
            if rep_w["j_per_token"] else 0.0),
    }
    dump("serving", payload)

    def derived(rep):
        return (f"tok/s={rep['tokens_per_s']:.0f} "
                f"J/tok={rep['j_per_token']:.2e} "
                f"occ={rep['slot_occupancy']:.2f} "
                f"slot_steps={rep['slot_steps']:.0f}")

    return [
        row("serve_continuous", rep_c["wall_s"] * 1e6, derived(rep_c)),
        row("serve_wave", rep_w["wall_s"] * 1e6, derived(rep_w)),
        row("serve_slot_step_reduction", 0.0,
            f"{100 * payload['slot_step_reduction']:.1f}% fewer "
            f"decode-step*slots; J/tok "
            f"-{100 * payload['j_per_token_reduction']:.1f}%"),
    ]


def main(argv: list[str]) -> int:
    smoke = "--smoke" in argv
    rows = run(smoke=smoke or None)
    for r in rows:
        print(f"{r['name']}: {r['derived']}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
