"""Serving-engine benchmark: chunked admission vs serial slot prefill vs
the legacy wave loop.

Serves one adversarial mixed workload — long prompts queued *ahead of* a
burst of short ones (the shape that makes serialized slot prefill stall
TTFT hardest) with mixed decode budgets (the Racing-to-Idle shape) —
through three engine configurations over the same tiny dense LM:

  * ``wave``: legacy batch-of-waves loop;
  * ``serial``: continuous batching, PR 4 single-shot slot prefill;
  * ``chunked``: continuous batching, chunked admission fused into the
    decode loop (this PR's tentpole).

Reports tokens/s, J/token, slot occupancy, executed step totals, and
TTFT / queue-time **percentiles** (mean, p50, p95) per mode. The JSON
artifact (artifacts/bench/serving.json) is the regression surface CI
uploads; with ``--smoke`` the run exits non-zero if chunked-admission
mean TTFT regresses past the pinned threshold vs serial admission
(``SMOKE_TTFT_RATIO_MAX``). The prefill-once admit families (encdec,
vlm) run the same chunked-vs-serial comparison — admission extras,
stream-parity assert, and the shared TTFT gate — landing in the
``admit_families`` block of the JSON payload.

A second comparison serves a **shared-prefix workload** (every request
starts with one of a few long system prompts) through the dense and the
paged KV layouts *at the same KV HBM byte budget*: the dense engine
spends a full ``max_len`` row per in-flight request, the paged engine
spends pages proportional to actual length and maps shared prefixes
copy-on-write, so the same bytes sustain strictly more concurrent
requests. Smoke gates: paged concurrency > dense, paged mean TTFT
(model clock) below ``PAGED_TTFT_RATIO_MAX`` x dense, and paged J/token
within ``PAGED_JTOK_RATIO_MAX`` x dense.

``--fleet`` serves a seeded adversarial mix (long best-effort prompts
ahead of a burst of short SLO-bound ones) through a two-chip
`FleetScheduler` and through each member as a forced single-engine
baseline at equal streams; smoke gates pin interactive SLO attainment
(``FLEET_SLO_ATTAIN_MIN``) and the fleet-vs-best-baseline J/token ratio
(``FLEET_JTOK_RATIO_MAX``), dumping artifacts/bench/serving_fleet.json.
A second scenario routes an encdec fleet (admission extras through the
scheduler) and asserts placement never changes tokens.

``--chaos`` replays the fleet mix under a deterministic fault plan
(one stall caught by the straggler detector, one state-preserved crash
whose in-flight requests migrate, plus a predictor-artifact-corruption
scenario that degrades tuning to BASELINE configs); smoke gates pin
interactive attainment under faults (``CHAOS_SLO_ATTAIN_MIN``), zero
lost requests, the faulted-vs-healthy J/token ratio
(``CHAOS_JTOK_RATIO_MAX``), and bit-identical streams, dumping
artifacts/bench/serving_chaos.json (the plan and seed included).

``--seed N`` re-seeds every workload generator and is recorded in each
JSON payload, so an artifact diff across seeds is a one-flag experiment.

Run:  PYTHONPATH=src python benchmarks/bench_serving.py [--smoke]
      [--seed N] [--fleet | --chaos | --tp N | --grain]
"""

from __future__ import annotations

import os
import sys
import time

import numpy as np

# allow `python benchmarks/bench_serving.py` from anywhere (run.py inserts
# the repo root itself)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import dump, row  # noqa: E402

BUDGETS = (4, 8, 32)
MAX_BATCH = 4
MAX_LEN = 512
CHUNK_TOKENS = 64
SHORT_LEN = (8, 16)       # short-prompt length range (inclusive)
LONG_LEN = 384            # adversarial long prompt (6 chunk calls)

# CI gate: chunked-admission mean TTFT must stay at or below this
# fraction of serial-admission mean TTFT on the smoke mix (the tentpole
# acceptance is >= 2x lower, i.e. ratio <= 0.5)
SMOKE_TTFT_RATIO_MAX = float(os.environ.get("SMOKE_TTFT_RATIO_MAX", "0.5"))

# ---- paged-vs-dense shared-prefix comparison (fixed KV HBM budget) ----
PAGE_SIZE = 32
PREFIX_LEN = 64           # shared system-prompt length (2 full pages)
TAIL_LEN = (16, 32)       # per-request unique suffix range (inclusive)
PAGED_BUDGETS = (4, 8, 16)
# paged mean model-clock TTFT must beat dense by this factor, and J/token
# must stay within this factor of dense, on the shared-prefix mix
PAGED_TTFT_RATIO_MAX = float(os.environ.get("PAGED_TTFT_RATIO_MAX", "0.75"))
PAGED_JTOK_RATIO_MAX = float(os.environ.get("PAGED_JTOK_RATIO_MAX", "1.0"))


def _build(smoke: bool):
    import jax

    from repro.models.config import ModelConfig
    from repro.models.registry import get_model

    # the smoke model must make a long-prompt prefill *compute-bound*
    # (a stall worth killing), not dispatch-bound, while staying small
    # enough for CPU CI
    cfg = ModelConfig(
        name="serve-bench", kind="dense",
        n_layers=3 if smoke else 4,
        d_model=128 if smoke else 256,
        n_heads=4 if smoke else 8, n_kv_heads=2 if smoke else 4,
        d_ff=256 if smoke else 1024, vocab=512 if smoke else 4096,
        param_dtype="float32", activation_dtype="float32", remat=False,
    )
    model = get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    return cfg, model, params


def _workload(cfg, n_long: int, n_short: int, seed: int = 0):
    """Long prompts first — the adversarial ordering for serialized
    admission — then a burst of short prompts with mixed budgets."""
    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(n_long):
        reqs.append((uid, rng.integers(0, cfg.vocab, LONG_LEN)
                     .astype(np.int32), int(rng.choice(BUDGETS))))
    for uid in range(n_long, n_long + n_short):
        n = int(rng.integers(SHORT_LEN[0], SHORT_LEN[1] + 1))
        reqs.append((uid, rng.integers(0, cfg.vocab, n).astype(np.int32),
                     int(rng.choice(BUDGETS))))
    return reqs


def _percentiles(values) -> dict:
    v = np.asarray(sorted(values), np.float64)
    if len(v) == 0:
        return {"mean": 0.0, "p50": 0.0, "p95": 0.0}
    return {"mean": float(v.mean()),
            "p50": float(np.percentile(v, 50)),
            "p95": float(np.percentile(v, 95))}


def _serve(cfg, model, params, reqs, label: str):
    from repro.serving.engine import Request, ServingEngine

    mode, admission = {
        "wave": ("wave", "serial"),
        "serial": ("continuous", "serial"),
        "chunked": ("continuous", "chunked"),
    }[label]
    eng = ServingEngine(model, params, cfg, max_batch=MAX_BATCH,
                        max_len=MAX_LEN, mode=mode, admission=admission,
                        chunk_tokens=CHUNK_TOKENS)
    # warm-up pass over the identical workload so every jit shape the
    # timed region traces (wave prefill, slot/chunk buckets, admission
    # widths, splices, decode) is compiled — then reset counters so the
    # comparison charges compilation to no mode
    for uid, prompt, mnt in reqs:
        eng.submit(Request(uid=100_000 + uid, prompt=prompt.copy(),
                           max_new_tokens=mnt))
    eng.run_until_empty()
    eng.reset_stats()
    for uid, prompt, mnt in reqs:
        eng.submit(Request(uid=uid, prompt=prompt.copy(),
                           max_new_tokens=mnt))
    t0 = time.perf_counter()
    results = eng.run_until_empty()
    wall = time.perf_counter() - t0
    rep = eng.report()
    rep["mode"] = label
    rep["wall_s"] = wall
    rep["tokens_per_s"] = (rep["generated_tokens"] / wall if wall > 0
                           else 0.0)
    rep["ttft_s"] = _percentiles([r.ttft_s for r in results])
    rep["ttft_model_s"] = _percentiles([r.ttft_model_s for r in results])
    rep["queue_s"] = _percentiles([r.queue_s for r in results])
    return results, rep


def _prefix_workload(cfg, n_reqs: int, n_prefixes: int, seed: int = 1):
    """Every request opens with one of ``n_prefixes`` shared system
    prompts (round-robin) followed by a unique tail — the workload shape
    shared-prefix page reuse exists for."""
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, cfg.vocab, PREFIX_LEN).astype(np.int32)
                for _ in range(n_prefixes)]
    reqs = []
    for uid in range(n_reqs):
        tail = rng.integers(0, cfg.vocab,
                            int(rng.integers(TAIL_LEN[0], TAIL_LEN[1] + 1)))
        reqs.append((uid,
                     np.concatenate([prefixes[uid % n_prefixes],
                                     tail]).astype(np.int32),
                     int(rng.choice(PAGED_BUDGETS))))
    return reqs


def _serve_layout(cfg, model, params, reqs, *, max_batch: int,
                  max_len: int, label: str, **engine_kw):
    """One warmed + timed pass of the shared-prefix workload through a
    continuous chunked-admission engine in the given KV layout."""
    from repro.serving.engine import Request, ServingEngine

    eng = ServingEngine(model, params, cfg, max_batch=max_batch,
                        max_len=max_len, mode="continuous",
                        admission="chunked", chunk_tokens=CHUNK_TOKENS,
                        **engine_kw)
    for uid, prompt, mnt in reqs:
        eng.submit(Request(uid=100_000 + uid, prompt=prompt.copy(),
                           max_new_tokens=mnt))
    eng.run_until_empty()
    eng.reset_stats()
    for uid, prompt, mnt in reqs:
        eng.submit(Request(uid=uid, prompt=prompt.copy(),
                           max_new_tokens=mnt))
    t0 = time.perf_counter()
    results = eng.run_until_empty()
    wall = time.perf_counter() - t0
    rep = eng.report()
    rep["mode"] = label
    rep["wall_s"] = wall
    rep["tokens_per_s"] = (rep["generated_tokens"] / wall if wall > 0
                           else 0.0)
    rep["ttft_s"] = _percentiles([r.ttft_s for r in results])
    rep["ttft_model_s"] = _percentiles([r.ttft_model_s for r in results])
    rep["concurrency"] = max_batch + eng.lane_width
    return results, rep


def run_paged(smoke: bool, cfg, model, params,
              seed: int = 0) -> tuple[list[dict], dict]:
    """Paged vs dense KV layout on the shared-prefix mix at one fixed KV
    HBM byte budget: the dense engine's budget is (max_batch + lane) full
    ``max_len`` rows; the paged engine gets exactly those bytes as pages
    and spends them on twice the decode slots + lane width."""
    from repro.models.config import kv_cache_bytes

    n_reqs, n_prefixes = (16, 2) if smoke else (32, 4)
    dense_batch = 2 if smoke else 4
    dense_rows = 3 * dense_batch             # max_batch + 2x admission lane
    hbm_budget = kv_cache_bytes(cfg, dense_rows * MAX_LEN)
    num_pages = dense_rows * MAX_LEN // PAGE_SIZE   # same bytes, in pages
    reqs = _prefix_workload(cfg, n_reqs, n_prefixes, seed=seed + 1)

    dense_out, rd = _serve_layout(cfg, model, params, reqs,
                                  max_batch=dense_batch, max_len=MAX_LEN,
                                  label="dense")
    paged_out, rp = _serve_layout(cfg, model, params, reqs,
                                  max_batch=2 * dense_batch,
                                  max_len=MAX_LEN, label="paged",
                                  kv_layout="paged", page_size=PAGE_SIZE,
                                  num_pages=num_pages + 1)

    # layout parity is a hard invariant: same greedy streams, per request
    by_uid = {r.uid: r for r in dense_out}
    for r in paged_out:
        if not np.array_equal(r.tokens, by_uid[r.uid].tokens):
            raise AssertionError(
                f"paged stream mismatch for request {r.uid}")

    paged_hbm = (rp["paging"]["peak_in_use"]
                 * kv_cache_bytes(cfg, PAGE_SIZE))
    ttft_ratio = (rp["ttft_model_s"]["mean"] / rd["ttft_model_s"]["mean"]
                  if rd["ttft_model_s"]["mean"] > 0 else 0.0)
    jtok_ratio = (rp["j_per_token"] / rd["j_per_token"]
                  if rd["j_per_token"] else 0.0)
    payload = {
        "seed": seed,
        "n_requests": n_reqs,
        "n_prefixes": n_prefixes,
        "prefix_len": PREFIX_LEN,
        "page_size": PAGE_SIZE,
        "max_len": MAX_LEN,
        "kv_hbm_budget_bytes": float(hbm_budget),
        "paged_peak_hbm_bytes": float(paged_hbm),
        "dense": rd,
        "paged": rp,
        "concurrency_dense": rd["concurrency"],
        "concurrency_paged": rp["concurrency"],
        "ttft_ratio_paged_vs_dense": ttft_ratio,
        "jtok_ratio_paged_vs_dense": jtok_ratio,
        "paged_ttft_gate_max_ratio": PAGED_TTFT_RATIO_MAX,
        "paged_jtok_gate_max_ratio": PAGED_JTOK_RATIO_MAX,
    }
    dump("serving_paged", payload)
    rows = [
        row("serve_paged", rp["wall_s"] * 1e6,
            f"tok/s={rp['tokens_per_s']:.0f} "
            f"J/tok={rp['j_per_token']:.2e} "
            f"conc={rp['concurrency']} "
            f"model-ttft={rp['ttft_model_s']['mean'] * 1e3:.2f}ms "
            f"prefix-hits={rp['paging']['prefix_hits']} "
            f"hit-tokens={rp['paging']['prefix_hit_tokens']}"),
        row("serve_paged_vs_dense", 0.0,
            f"fixed KV budget={hbm_budget / 1e6:.2f}MB: concurrency "
            f"{rd['concurrency']} -> {rp['concurrency']}, paged/dense "
            f"mean TTFT ratio={ttft_ratio:.3f} (model clock, gate <= "
            f"{PAGED_TTFT_RATIO_MAX}), J/tok ratio={jtok_ratio:.3f} "
            f"(gate <= {PAGED_JTOK_RATIO_MAX}), paged peak HBM "
            f"{paged_hbm / 1e6:.2f}MB"),
    ]
    return rows, payload


# ---- admit families (encdec, vlm): chunked vs serial admission ----
# the prefill-once admission pass (encoder + cross-KV projection for
# encdec, image-patch prefix for vlm) is paid identically by both
# admissions and priced into model-clock TTFT, so the chunked/serial
# ratio shares the dense gate (SMOKE_TTFT_RATIO_MAX)
ADMIT_FAMILY_KW = {
    "encdec": dict(d_ff=256, n_encoder_layers=2, gated_mlp=False),
    "vlm": dict(d_ff=256, qkv_bias=True, mrope=True,
                mrope_sections=(8, 4, 4)),
}


def _build_admit(kind: str, smoke: bool):
    import jax

    from repro.models.config import ModelConfig
    from repro.models.registry import get_model

    cfg = ModelConfig(
        name=f"serve-{kind}", kind=kind,
        n_layers=2 if smoke else 3,
        d_model=128, n_heads=4, n_kv_heads=2, vocab=512,
        param_dtype="float32", activation_dtype="float32", remat=False,
        **ADMIT_FAMILY_KW[kind])
    model = get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    return cfg, model, params


def _admit_extras(cfg, uid: int, seed: int):
    """Deterministic per-request modality input: an encoder source for
    encdec (required), an image-patch grid for vlm (every third request
    is text-only and serves like a dense LM)."""
    rng = np.random.default_rng(seed * 1000 + uid)
    if cfg.kind == "encdec":
        t = 8 + 4 * (uid % 3)
        return {"src_embeds": rng.standard_normal(
            (t, cfg.d_model)).astype(np.float32)}
    grid = [(4, 4), (2, 3), None][uid % 3]
    if grid is None:
        return None
    gh, gw = grid
    return {"patch_embeds": rng.standard_normal(
        (gh * gw, cfg.d_model)).astype(np.float32), "grid_hw": grid}


def _serve_admit(cfg, model, params, reqs, label: str, seed: int):
    from repro.serving.engine import Request, ServingEngine

    eng = ServingEngine(model, params, cfg, max_batch=MAX_BATCH,
                        max_len=MAX_LEN, mode="continuous",
                        admission=label, chunk_tokens=CHUNK_TOKENS)
    for pass_uid0 in (100_000, 0):      # warm-up, then the timed pass
        for uid, prompt, mnt in reqs:
            eng.submit(Request(uid=pass_uid0 + uid, prompt=prompt.copy(),
                               max_new_tokens=mnt,
                               extras=_admit_extras(cfg, uid, seed)))
        if pass_uid0:
            eng.run_until_empty()
            eng.reset_stats()
    t0 = time.perf_counter()
    results = eng.run_until_empty()
    wall = time.perf_counter() - t0
    rep = eng.report()
    rep["mode"] = label
    rep["wall_s"] = wall
    rep["tokens_per_s"] = (rep["generated_tokens"] / wall if wall > 0
                           else 0.0)
    rep["ttft_s"] = _percentiles([r.ttft_s for r in results])
    rep["ttft_model_s"] = _percentiles([r.ttft_model_s for r in results])
    return results, rep


def run_admit(smoke: bool, cfg_kinds=("encdec", "vlm"),
              seed: int = 0) -> tuple[list[dict], dict]:
    """encdec and vlm on the adversarial long-ahead-of-shorts mix:
    chunked and serial admission must produce bit-identical greedy
    streams (admission is one-shot either way), and chunked mean TTFT
    on the model clock must clear the same gate as the dense smoke."""
    n_long, n_short = (1, 6) if smoke else (2, 12)
    families = {}
    rows = []
    for kind in cfg_kinds:
        cfg, model, params = _build_admit(kind, smoke)
        reqs = _workload(cfg, n_long, n_short, seed=seed + 3)
        out, reps = {}, {}
        for label in ("chunked", "serial"):
            out[label], reps[label] = _serve_admit(cfg, model, params,
                                                   reqs, label, seed)
        # stream parity across admissions is the hard invariant
        by_uid = {r.uid: r for r in out["serial"]}
        for r in out["chunked"]:
            if not np.array_equal(r.tokens, by_uid[r.uid].tokens):
                raise AssertionError(
                    f"{kind}: chunked/serial stream mismatch for "
                    f"request {r.uid}")
        rc, rs = reps["chunked"], reps["serial"]
        ratio = (rc["ttft_model_s"]["mean"] / rs["ttft_model_s"]["mean"]
                 if rs["ttft_model_s"]["mean"] > 0 else 0.0)
        families[kind] = {
            "chunked": rc,
            "serial": rs,
            "ttft_ratio_chunked_vs_serial": ratio,
        }
        rows.append(row(
            f"serve_{kind}", rc["wall_s"] * 1e6,
            f"tok/s={rc['tokens_per_s']:.0f} "
            f"J/tok={rc['j_per_token']:.2e} "
            f"model-ttft={rc['ttft_model_s']['mean'] * 1e3:.2f}ms "
            f"chunked/serial ratio={ratio:.3f} "
            f"(gate <= {SMOKE_TTFT_RATIO_MAX})"))
    payload = {
        "seed": seed,
        "n_requests": n_long + n_short,
        "ttft_gate_max_ratio": SMOKE_TTFT_RATIO_MAX,
        "families": families,
    }
    dump("serving_admit", payload)
    return rows, payload


# ---- sharded (tensor-parallel) serving smoke: --tp N ----
# fleet J/token at tp=N must stay within this factor of tp=1 (the fleet
# spends n_chips x a shorter step; the gate pins the regression surface)
TP_JTOK_RATIO_MAX = float(os.environ.get("TP_JTOK_RATIO_MAX", "3.0"))
TP_MAX_BATCH = 4
TP_MAX_LEN = 256
TP_LONG_LEN = 160


def _ensure_devices(n: int) -> None:
    """Re-exec under host-platform device emulation when the backend
    exposes fewer than `n` devices (CI and laptops run the sharded smoke
    on emulated CPU devices; a real mesh passes through untouched)."""
    import jax

    if jax.device_count() >= n or os.environ.get("_BENCH_TP_REEXEC"):
        return
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n} "
        + os.environ.get("XLA_FLAGS", ""))
    os.environ["_BENCH_TP_REEXEC"] = "1"
    os.execv(sys.executable, [sys.executable] + sys.argv)


def _build_tp():
    import jax

    from repro.models.config import ModelConfig
    from repro.models.registry import get_model

    # the sharded gate needs per-step time dominated by weight-streaming
    # GEMMs (1/tp of the weights per chip) rather than per-kernel launch
    # overhead (which does not shrink with tp) — so the tp bench model is
    # deliberately larger than the admission-bench one, and every sharded
    # dim (heads, kv heads, d_ff, vocab) divides tp=4
    cfg = ModelConfig(
        name="serve-tp", kind="dense", n_layers=4, d_model=512,
        n_heads=8, n_kv_heads=4, d_ff=2048, vocab=4096,
        param_dtype="float32", activation_dtype="float32", remat=False)
    model = get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    return cfg, model, params


def _serve_once(model, params, cfg, reqs, **engine_kw):
    """One warmed + timed chunked-admission pass; returns (results,
    report-with-measured-wall)."""
    from repro.serving.engine import Request, ServingEngine

    eng = ServingEngine(model, params, cfg, mode="continuous",
                        admission="chunked", **engine_kw)
    for uid, prompt, mnt in reqs:
        eng.submit(Request(uid=100_000 + uid, prompt=prompt.copy(),
                           max_new_tokens=mnt))
    eng.run_until_empty()
    eng.reset_stats()
    for uid, prompt, mnt in reqs:
        eng.submit(Request(uid=uid, prompt=prompt.copy(),
                           max_new_tokens=mnt))
    t0 = time.perf_counter()
    results = eng.run_until_empty()
    rep = eng.report()
    rep["wall_s"] = time.perf_counter() - t0
    return results, rep


def run_tp(tp: int, smoke: bool) -> tuple[list[dict], dict]:
    """Sharded vs single-chip serving of the same workload: greedy
    streams must be bit-identical, model-clock tokens/s strictly higher
    at tp, fleet J/token within the pinned ratio, and the collective
    overlap factor lands in the JSON artifact."""
    cfg, model, params = _build_tp()
    n_long, n_short = (1, 4) if smoke else (2, 8)
    rng = np.random.default_rng(7)
    reqs = []
    for uid in range(n_long):
        reqs.append((uid, rng.integers(0, cfg.vocab, TP_LONG_LEN)
                     .astype(np.int32), 4))
    for uid in range(n_long, n_long + n_short):
        n = int(rng.integers(SHORT_LEN[0], SHORT_LEN[1] + 1))
        reqs.append((uid, rng.integers(0, cfg.vocab, n).astype(np.int32),
                     int(rng.choice((4, 8)))))

    outs, reps = {}, {}
    for t in (1, tp):
        outs[t], reps[t] = _serve_once(
            model, params, cfg, reqs, max_batch=TP_MAX_BATCH,
            max_len=TP_MAX_LEN, chunk_tokens=CHUNK_TOKENS, tp=t)

    # bit parity is the hard sharding contract, not a benchmark stat
    by_uid = {r.uid: r for r in outs[1]}
    for r in outs[tp]:
        if not np.array_equal(r.tokens, by_uid[r.uid].tokens):
            raise AssertionError(
                f"sharded stream mismatch for request {r.uid} (tp={tp})")

    r1, rt = reps[1], reps[tp]
    speedup = (rt["model_tokens_per_s"] / r1["model_tokens_per_s"]
               if r1["model_tokens_per_s"] > 0 else 0.0)
    jtok_ratio = (rt["j_per_token"] / r1["j_per_token"]
                  if r1["j_per_token"] else 0.0)
    payload = {
        "tp": tp,
        "n_requests": len(reqs),
        "max_batch": TP_MAX_BATCH,
        "max_len": TP_MAX_LEN,
        "chunk_tokens": CHUNK_TOKENS,
        "tp1": r1,
        "tpN": rt,
        "model_speedup_tp_vs_1": speedup,
        "overlap_factor": rt["overlap_factor"],
        "collective_wire_s": rt["collective_wire_s"],
        "jtok_ratio_tp_vs_1": jtok_ratio,
        "tp_jtok_gate_max_ratio": TP_JTOK_RATIO_MAX,
    }
    dump("serving_tp", payload)
    rows = [row(
        "serve_tp", rt["wall_s"] * 1e6,
        f"tp={tp} model-tok/s={rt['model_tokens_per_s']:.0f} "
        f"(tp1={r1['model_tokens_per_s']:.0f}, x{speedup:.2f}) "
        f"fleet J/tok={rt['j_per_token']:.2e} (x{jtok_ratio:.2f} vs tp1, "
        f"gate <= {TP_JTOK_RATIO_MAX}) "
        f"overlap={rt['overlap_factor']:.3f}")]
    return rows, payload


# ---- predictor-driven fleet scheduling: --fleet ----
# two heterogeneous members: the scheduler must beat the best *single*
# engine (same ledger: served energy + idle-floor over the makespan for
# every member) while holding the interactive TTFT SLO
FLEET_CHIPS = {"v5e": "tpu_v5e", "ada": "rtx4070"}
FLEET_MAX_BATCH = 2
FLEET_MAX_LEN = 256
FLEET_LONG_LEN = 160
FLEET_CHUNK = 32
# interactive-class TTFT bound on the fleet model clock (submit -> first
# token, scheduler queue wait included)
FLEET_TTFT_SLO_S = float(os.environ.get("FLEET_TTFT_SLO_S", "0.05"))
# smoke gates: interactive SLO attainment, and fleet J/token vs the best
# single-engine baseline at equal streams
FLEET_SLO_ATTAIN_MIN = float(os.environ.get("FLEET_SLO_ATTAIN_MIN", "0.95"))
FLEET_JTOK_RATIO_MAX = float(os.environ.get("FLEET_JTOK_RATIO_MAX", "1.0"))


def _fleet_workload(cfg, n_long: int, n_short: int, seed: int):
    """Adversarial fleet mix: long best-effort ("batch") prompts queued
    ahead of a burst of short SLO-bound ("interactive") ones, mixed
    decode budgets — regenerated from `seed` for every scenario so the
    fleet and each single-engine baseline serve equal streams."""
    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(n_long):
        reqs.append((uid, rng.integers(0, cfg.vocab, FLEET_LONG_LEN)
                     .astype(np.int32), int(rng.choice(BUDGETS)), "batch"))
    for uid in range(n_long, n_long + n_short):
        n = int(rng.integers(SHORT_LEN[0], SHORT_LEN[1] + 1))
        reqs.append((uid, rng.integers(0, cfg.vocab, n).astype(np.int32),
                     int(rng.choice(BUDGETS)), "interactive"))
    return reqs


def _serve_fleet(cfg, model, params, seed: int, n_long: int, n_short: int,
                 route_to: str | None = None, extras_fn=None):
    """One warmed + timed pass of the fleet mix through the scheduler;
    `route_to` forces the single-engine baseline (others parked, same
    ledger); `extras_fn(uid)` supplies per-request modality input for
    admit-family members (encdec source embeddings)."""
    from repro.serving.engine import Request, ServingEngine
    from repro.serving.scheduler import FleetScheduler, SLAClass

    engines = {
        name: ServingEngine(model, params, cfg, max_batch=FLEET_MAX_BATCH,
                            max_len=FLEET_MAX_LEN, mode="continuous",
                            admission="chunked", chunk_tokens=FLEET_CHUNK,
                            chip=chip)
        for name, chip in FLEET_CHIPS.items()}
    sched = FleetScheduler(
        engines,
        sla={"interactive": SLAClass("interactive", FLEET_TTFT_SLO_S),
             "batch": SLAClass("batch", None)},
        route_to=route_to)
    for pass_uid0 in (100_000, 0):      # warm-up, then the timed pass
        for uid, prompt, mnt, sla in _fleet_workload(cfg, n_long,
                                                     n_short, seed):
            sched.submit(Request(uid=pass_uid0 + uid, prompt=prompt,
                                 max_new_tokens=mnt,
                                 extras=(extras_fn(uid) if extras_fn
                                         else None)), sla=sla)
        if pass_uid0:
            sched.run_until_empty()
            sched.reset_stats()
    t0 = time.perf_counter()
    results = sched.run_until_empty()
    rep = sched.report()
    rep["wall_s"] = time.perf_counter() - t0
    rep["label"] = route_to or "fleet"
    return results, rep


def run_fleet(smoke: bool, seed: int) -> tuple[list[dict], dict]:
    """Fleet scheduler vs every single-engine baseline on the same
    seeded adversarial mix: greedy streams must be bit-identical across
    scenarios (routing invariance), interactive SLO attainment and the
    fleet-vs-best-baseline J/token ratio land in the JSON artifact for
    the smoke gates."""
    cfg, model, params = _build(smoke)
    n_long, n_short = (2, 8) if smoke else (4, 16)

    fleet_out, fleet_rep = _serve_fleet(cfg, model, params, seed,
                                        n_long, n_short)
    by_uid = {r.uid: r for r in fleet_out}
    baselines = {}
    for name in FLEET_CHIPS:
        out, rep = _serve_fleet(cfg, model, params, seed, n_long, n_short,
                                route_to=name)
        # placement must never change tokens — only latency and energy
        for r in out:
            if not np.array_equal(r.tokens, by_uid[r.uid].tokens):
                raise AssertionError(
                    f"fleet stream mismatch for request {r.uid} "
                    f"(baseline {name})")
        baselines[name] = rep

    # one admit-family member scenario: an encdec fleet routes requests
    # whose admission (encoder + cross-KV projection) runs through the
    # scheduler's deferral/pricing machinery — placement must still
    # never change tokens
    ecfg, emodel, eparams = _build_admit("encdec", True)

    def _esrc(uid):
        rng = np.random.default_rng(4000 + uid)
        t = 8 + 2 * (uid % 3)
        return {"src_embeds": rng.standard_normal(
            (t, ecfg.d_model)).astype(np.float32)}

    e_long, e_short = (1, 3) if smoke else (2, 6)
    e_out, e_rep = _serve_fleet(ecfg, emodel, eparams, seed + 5,
                                e_long, e_short, extras_fn=_esrc)
    e_by = {r.uid: r for r in e_out}
    eb_out, eb_rep = _serve_fleet(ecfg, emodel, eparams, seed + 5,
                                  e_long, e_short, route_to="v5e",
                                  extras_fn=_esrc)
    for r in eb_out:
        if not np.array_equal(r.tokens, e_by[r.uid].tokens):
            raise AssertionError(
                f"fleet stream mismatch for encdec request {r.uid}")

    best_name = min(baselines,
                    key=lambda n: baselines[n]["fleet_j_per_token"])
    best_jtok = baselines[best_name]["fleet_j_per_token"]
    jtok_ratio = (fleet_rep["fleet_j_per_token"] / best_jtok
                  if best_jtok > 0 else 0.0)
    payload = {
        "seed": seed,
        "n_requests": n_long + n_short,
        "n_long": n_long,
        "max_batch": FLEET_MAX_BATCH,
        "max_len": FLEET_MAX_LEN,
        "chunk_tokens": FLEET_CHUNK,
        "chips": dict(FLEET_CHIPS),
        "ttft_slo_model_s": FLEET_TTFT_SLO_S,
        "fleet": fleet_rep,
        "baselines": baselines,
        "best_baseline": best_name,
        "attainment": fleet_rep["attainment"],
        "jtok_ratio_fleet_vs_best_baseline": jtok_ratio,
        "fleet_attain_gate_min": FLEET_SLO_ATTAIN_MIN,
        "fleet_jtok_gate_max_ratio": FLEET_JTOK_RATIO_MAX,
        "encdec_member": {
            "n_requests": e_long + e_short,
            "fleet": e_rep,
            "baseline_v5e": eb_rep,
        },
    }
    dump("serving_fleet", payload)
    cls = fleet_rep["sla"]["interactive"]
    rows = [
        row("serve_fleet", fleet_rep["wall_s"] * 1e6,
            f"J/tok={fleet_rep['fleet_j_per_token']:.2e} "
            f"(x{jtok_ratio:.3f} vs best single engine "
            f"[{best_name}], gate <= {FLEET_JTOK_RATIO_MAX}) "
            f"attainment={fleet_rep['attainment']:.3f} "
            f"(gate >= {FLEET_SLO_ATTAIN_MIN}) "
            f"interactive ttft p95={cls['ttft_fleet_p95_model_s'] * 1e3:.2f}"
            f"ms (slo={FLEET_TTFT_SLO_S * 1e3:.0f}ms) "
            f"parks={fleet_rep['parks']} drains={fleet_rep['drains']}"),
    ]
    return rows, payload


# ---- chaos smoke: --chaos ----
# deterministic fault schedule on the fleet model clock: one stall (the
# detector-and-evict path) and one state-preserved crash (the migration
# path), both pinned to fractions of the measured no-fault makespan.
# gates: interactive SLO attainment under faults, zero requests lost,
# fleet J/token within a bounded factor of the no-fault run, and token
# streams bit-identical to the no-fault run (migration preserves state;
# replay re-derives the same greedy stream).
CHAOS_SLO_ATTAIN_MIN = float(os.environ.get("CHAOS_SLO_ATTAIN_MIN", "0.90"))
CHAOS_JTOK_RATIO_MAX = float(os.environ.get("CHAOS_JTOK_RATIO_MAX", "1.3"))
CHAOS_STALL_FACTOR = float(os.environ.get("CHAOS_STALL_FACTOR", "8.0"))


def _serve_chaos(cfg, model, params, seed: int, n_long: int, n_short: int,
                 plan=None):
    """One warmed + timed fleet pass with an optional `FaultPlan` armed
    *after* the warm-up reset, so event times land on the measured run's
    clock. Interactive requests use a defer (not shed) overload policy:
    faults may stretch latency but must never drop work — the zero-lost
    gate depends on it."""
    from repro.serving.engine import Request, ServingEngine
    from repro.serving.scheduler import FleetScheduler, SLAClass

    engines = {
        name: ServingEngine(model, params, cfg, max_batch=FLEET_MAX_BATCH,
                            max_len=FLEET_MAX_LEN, mode="continuous",
                            admission="chunked", chunk_tokens=FLEET_CHUNK,
                            chip=chip)
        for name, chip in FLEET_CHIPS.items()}
    sched = FleetScheduler(
        engines,
        sla={"interactive": SLAClass("interactive", FLEET_TTFT_SLO_S,
                                     policy="defer", defer_s=0.01,
                                     max_defers=2),
             "batch": SLAClass("batch", None)})
    for pass_uid0 in (100_000, 0):      # warm-up, then the timed pass
        for uid, prompt, mnt, sla in _fleet_workload(cfg, n_long,
                                                     n_short, seed):
            sched.submit(Request(uid=pass_uid0 + uid, prompt=prompt,
                                 max_new_tokens=mnt), sla=sla)
        if pass_uid0:
            sched.run_until_empty()
            sched.reset_stats()
    sched.arm_faults(plan)
    t0 = time.perf_counter()
    results = sched.run_until_empty()
    rep = sched.report()
    rep["wall_s"] = time.perf_counter() - t0
    return results, rep, sched


def run_chaos(smoke: bool, seed: int) -> tuple[list[dict], dict]:
    """Chaos smoke: the fleet mix served healthy, then under a seeded
    1-stall + 1-crash plan, then under mid-run predictor-artifact
    corruption. Faults may move work and stretch latency but must never
    lose a request or change a token."""
    from repro.serving.faults import FaultEvent, FaultPlan

    cfg, model, params = _build(smoke)
    n_long, n_short = (2, 8) if smoke else (4, 16)
    n_reqs = n_long + n_short

    base_out, base_rep, _ = _serve_chaos(cfg, model, params, seed,
                                         n_long, n_short)
    horizon = base_rep["makespan_model_s"]
    ref = {r.uid: np.asarray(r.tokens) for r in base_out}

    def _check(results, rep, label):
        if len(results) != n_reqs:
            raise AssertionError(
                f"{label}: {n_reqs - len(results)} request(s) lost "
                f"({len(results)}/{n_reqs} completed)")
        for r in results:
            if not np.array_equal(np.asarray(r.tokens), ref[r.uid]):
                raise AssertionError(
                    f"{label}: stream mismatch for request {r.uid} — "
                    f"faults changed tokens")
        assert rep["requests"] == n_reqs

    # stall early (straggler-detector eviction path), then crash the
    # other member with device state intact (migration path) once the
    # stalled one is back to absorb its in-flight work
    plan = FaultPlan([
        FaultEvent(0.15 * horizon, "stall", "ada",
                   factor=CHAOS_STALL_FACTOR, duration_s=0.25 * horizon),
        FaultEvent(0.55 * horizon, "crash", "v5e", state_lost=False),
    ], seed=seed)
    chaos_out, chaos_rep, _ = _serve_chaos(cfg, model, params, seed,
                                           n_long, n_short, plan=plan)
    _check(chaos_out, chaos_rep, "chaos")
    if chaos_rep["faults"]["crashes"] != 1:
        raise AssertionError("chaos plan's crash event did not fire")

    # separate scenario: predictor-artifact corruption mid-run must
    # degrade tuning to BASELINE configs and keep serving — flagged,
    # streams untouched
    corrupt = FaultPlan([
        FaultEvent(0.3 * horizon, "artifact_corruption", "v5e"),
    ], seed=seed)
    deg_out, deg_rep, _ = _serve_chaos(cfg, model, params, seed,
                                       n_long, n_short, plan=corrupt)
    _check(deg_out, deg_rep, "degraded")
    if deg_rep["faults"]["degraded_members"] != ["v5e"]:
        raise AssertionError(
            "artifact corruption did not flag the member as degraded: "
            f"{deg_rep['faults']['degraded_members']}")

    base_jtok = base_rep["fleet_j_per_token"]
    jtok_ratio = (chaos_rep["fleet_j_per_token"] / base_jtok
                  if base_jtok > 0 else 0.0)
    f = chaos_rep["faults"]
    payload = {
        "seed": seed,
        "n_requests": n_reqs,
        "n_long": n_long,
        "chips": dict(FLEET_CHIPS),
        "ttft_slo_model_s": FLEET_TTFT_SLO_S,
        "stall_factor": CHAOS_STALL_FACTOR,
        "plan": f["plan"],
        "no_fault": base_rep,
        "chaos": chaos_rep,
        "degraded": deg_rep,
        "attainment": chaos_rep["attainment"],
        "jtok_ratio_chaos_vs_no_fault": jtok_ratio,
        "requests_lost": n_reqs - len(chaos_out),
        "chaos_attain_gate_min": CHAOS_SLO_ATTAIN_MIN,
        "chaos_jtok_gate_max_ratio": CHAOS_JTOK_RATIO_MAX,
    }
    dump("serving_chaos", payload)
    rows = [
        row("serve_chaos", chaos_rep["wall_s"] * 1e6,
            f"crashes={f['crashes']} evictions={f['evictions']} "
            f"stalls={f['stalls']} migrations={f['migrations']} "
            f"replays={f['replays']} "
            f"lost_J={f['lost_energy_j']:.2e} "
            f"attainment={chaos_rep['attainment']:.3f} "
            f"(gate >= {CHAOS_SLO_ATTAIN_MIN}) "
            f"J/tok=x{jtok_ratio:.3f} vs no-fault "
            f"(gate <= {CHAOS_JTOK_RATIO_MAX})"),
        row("serve_chaos_degraded", deg_rep["wall_s"] * 1e6,
            f"degraded={deg_rep['faults']['degraded_members']} "
            f"streams bit-identical to healthy run"),
    ]
    return rows, payload


# ---- SSM serve-grain sweep: --grain ----
GRAINS = (8, 32, 64)
GRAIN_PROMPT_LEN = 448
GRAIN_MAX_LEN = 512
GRAIN_CHUNK = 128          # a multiple of every grain in the sweep


def _build_grain():
    import jax

    from repro.models.config import ModelConfig
    from repro.models.registry import get_model

    cfg = ModelConfig(
        name="serve-grain", kind="mamba2", n_layers=2, d_model=256,
        n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
        expand=2, ssm_state=16, ssm_headdim=64,
        param_dtype="float32", activation_dtype="float32", remat=False)
    model = get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    return cfg, model, params


def run_grain(smoke: bool) -> tuple[list[dict], dict]:
    """Long-prompt mamba2 prefill throughput vs the SSM serve-scan grain:
    the default 8-token block scans a 448-token prompt in 56 sequential
    `lax.scan` steps; grain 32/64 recovers throughput with 4x/8x fewer
    steps. Streams are asserted bit-identical between chunked admission
    and single-shot prefill *within* each grain (the serving parity
    contract — grain is part of the numerics, so streams are only
    comparable at equal grain)."""
    from repro.serving.engine import Request, ServingEngine

    cfg, model, params = _build_grain()
    n_reqs = 2 if smoke else 4
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab, GRAIN_PROMPT_LEN)
               .astype(np.int32) for _ in range(n_reqs)]
    total_prompt = n_reqs * GRAIN_PROMPT_LEN

    per_grain = {}
    for g in GRAINS:
        streams = {}
        rep = None
        for adm in ("serial", "chunked"):
            eng = ServingEngine(model, params, cfg, max_batch=2,
                                max_len=GRAIN_MAX_LEN, mode="continuous",
                                admission=adm, chunk_tokens=GRAIN_CHUNK,
                                ssm_serve_grain=g)
            for uid, p in enumerate(prompts):   # warm-up (jit traces)
                eng.submit(Request(uid=100 + uid, prompt=p.copy(),
                                   max_new_tokens=1))
            eng.run_until_empty()
            eng.reset_stats()
            # budget 1: requests finish on their first sampled token, so
            # the timed pass is pure prefill — the surface grain targets
            for uid, p in enumerate(prompts):
                eng.submit(Request(uid=uid, prompt=p.copy(),
                                   max_new_tokens=1))
            t0 = time.perf_counter()
            res = eng.run_until_empty()
            wall = time.perf_counter() - t0
            streams[adm] = {r.uid: r.tokens.tolist() for r in res}
            if adm == "chunked":
                rep = eng.report()
                rep["wall_s"] = wall
        if streams["serial"] != streams["chunked"]:
            raise AssertionError(
                f"grain={g}: chunked/single-shot stream mismatch")
        per_grain[str(g)] = {
            "prefill_tokens_per_s_wall": (total_prompt / rep["wall_s"]
                                          if rep["wall_s"] > 0 else 0.0),
            "wall_s": rep["wall_s"],
            "model_s": rep["model_s"],
            "chunk_steps": rep["chunk_steps"],
        }
    base = per_grain[str(GRAINS[0])]["prefill_tokens_per_s_wall"]
    payload = {
        "n_requests": n_reqs,
        "prompt_len": GRAIN_PROMPT_LEN,
        "chunk_tokens": GRAIN_CHUNK,
        "grains": list(GRAINS),
        "per_grain": per_grain,
        "recovery_vs_grain8": {
            k: (v["prefill_tokens_per_s_wall"] / base if base > 0 else 0.0)
            for k, v in per_grain.items()},
    }
    dump("serving_ssm_grain", payload)
    rows = [row(
        f"serve_ssm_grain{g}",
        per_grain[str(g)]["wall_s"] * 1e6,
        f"prefill tok/s={per_grain[str(g)]['prefill_tokens_per_s_wall']:.0f}"
        f" (x{payload['recovery_vs_grain8'][str(g)]:.2f} vs grain 8, "
        f"{per_grain[str(g)]['chunk_steps']} chunk calls)")
        for g in GRAINS]
    return rows, payload


def run(smoke: bool | None = None, seed: int = 0) -> list[dict]:
    if smoke is None:
        # mirror benchmarks.common.default_n_configs: unset env = full scale
        smoke = int(os.environ.get("BENCH_N_CONFIGS", "16128")) <= 256
    cfg, model, params = _build(smoke)
    n_long, n_short = (2, 10) if smoke else (4, 20)
    reqs = _workload(cfg, n_long, n_short, seed=seed)

    out = {}
    reports = {}
    for label in ("chunked", "serial", "wave"):
        out[label], reports[label] = _serve(cfg, model, params, reqs, label)

    # identical greedy streams is a hard invariant, not a benchmark stat
    by_uid = {r.uid: r for r in out["wave"]}
    for label in ("chunked", "serial"):
        for r in out[label]:
            if not np.array_equal(r.tokens, by_uid[r.uid].tokens):
                raise AssertionError(
                    f"stream mismatch for request {r.uid} ({label})")

    rc, rs, rw = reports["chunked"], reports["serial"], reports["wave"]
    # the gated ratio uses the *model clock* (predicted step_s of every
    # dispatched call — deterministic, CI-machine independent); wall-clock
    # TTFT percentiles are reported alongside for the curious
    ttft_ratio = (rc["ttft_model_s"]["mean"] / rs["ttft_model_s"]["mean"]
                  if rs["ttft_model_s"]["mean"] > 0 else 0.0)
    ttft_wall_ratio = (rc["ttft_s"]["mean"] / rs["ttft_s"]["mean"]
                       if rs["ttft_s"]["mean"] > 0 else 0.0)
    payload = {
        "seed": seed,
        "n_requests": len(reqs),
        "n_long": n_long,
        "max_batch": MAX_BATCH,
        "max_len": MAX_LEN,
        "chunk_tokens": CHUNK_TOKENS,
        "budgets": list(BUDGETS),
        "chunked": rc,
        "serial": rs,
        "wave": rw,
        "ttft_ratio_chunked_vs_serial": ttft_ratio,
        "ttft_wall_ratio_chunked_vs_serial": ttft_wall_ratio,
        "ttft_gate_max_ratio": SMOKE_TTFT_RATIO_MAX,
        "slot_step_reduction": (
            1.0 - rc["slot_steps"] / rw["slot_steps"]
            if rw["slot_steps"] else 0.0),
        "j_per_token_reduction": (
            1.0 - rc["j_per_token"] / rw["j_per_token"]
            if rw["j_per_token"] else 0.0),
    }
    admit_rows, admit_payload = run_admit(smoke, seed=seed)
    run.last_admit_payload = admit_payload
    payload["admit_families"] = admit_payload["families"]
    dump("serving", payload)
    run.last_payload = payload
    # the chunked-mode report is also dumped standalone so CI artifact
    # diffs of the fused-admission path stay one file
    dump("serving_chunked", {"workload": payload["n_requests"],
                             "report": rc})
    dump("serving_wave", {"workload": payload["n_requests"],
                          "report": rw})

    def derived(rep):
        return (f"tok/s={rep['tokens_per_s']:.0f} "
                f"J/tok={rep['j_per_token']:.2e} "
                f"occ={rep['slot_occupancy']:.2f} "
                f"ttft(mean/p50/p95)="
                f"{rep['ttft_s']['mean'] * 1e3:.1f}/"
                f"{rep['ttft_s']['p50'] * 1e3:.1f}/"
                f"{rep['ttft_s']['p95'] * 1e3:.1f}ms "
                f"model-ttft={rep['ttft_model_s']['mean'] * 1e3:.2f}ms")

    paged_rows, paged_payload = run_paged(smoke, cfg, model, params,
                                          seed=seed)
    run.last_paged_payload = paged_payload

    return [
        row("serve_chunked", rc["wall_s"] * 1e6, derived(rc)),
        row("serve_serial", rs["wall_s"] * 1e6, derived(rs)),
        row("serve_wave", rw["wall_s"] * 1e6, derived(rw)),
        row("serve_ttft_ratio", 0.0,
            f"chunked/serial mean TTFT ratio={ttft_ratio:.3f} "
            f"(model clock; wall={ttft_wall_ratio:.3f}; "
            f"gate <= {SMOKE_TTFT_RATIO_MAX}); "
            f"{100 * payload['slot_step_reduction']:.1f}% fewer "
            f"decode-step*slots vs wave; J/tok "
            f"-{100 * payload['j_per_token_reduction']:.1f}%"),
    ] + admit_rows + paged_rows


def main(argv: list[str]) -> int:
    smoke = "--smoke" in argv
    seed = (int(argv[argv.index("--seed") + 1]) if "--seed" in argv
            else 0)
    special = False
    if "--fleet" in argv:
        special = True
        f_rows, fp = run_fleet(smoke, seed)
        for r in f_rows:
            print(f"{r['name']}: {r['derived']}")
        best = fp["baselines"][fp["best_baseline"]]
        if best["fleet_j_per_token"] <= 0.0:
            print("FLEET GATE FAILED: best single-engine baseline "
                  "J/token is 0 (energy model unavailable?) — gate "
                  "cannot assess")
            return 1
        if fp["attainment"] < FLEET_SLO_ATTAIN_MIN:
            print(f"FLEET GATE FAILED: interactive SLO attainment "
                  f"{fp['attainment']:.3f} < {FLEET_SLO_ATTAIN_MIN} "
                  f"(ttft slo {FLEET_TTFT_SLO_S * 1e3:.0f}ms on the "
                  f"fleet model clock)")
            return 1
        jr = fp["jtok_ratio_fleet_vs_best_baseline"]
        if jr > FLEET_JTOK_RATIO_MAX:
            print(f"FLEET GATE FAILED: fleet J/token is x{jr:.3f} of "
                  f"the best single engine ({fp['best_baseline']}) > "
                  f"{FLEET_JTOK_RATIO_MAX} at equal streams")
            return 1
        print(f"fleet gates ok: streams bit-identical across scenarios, "
              f"attainment {fp['attainment']:.3f} >= "
              f"{FLEET_SLO_ATTAIN_MIN}, J/tok x{jr:.3f} vs best single "
              f"engine [{fp['best_baseline']}] <= {FLEET_JTOK_RATIO_MAX}")
    if "--chaos" in argv:
        special = True
        c_rows, cp = run_chaos(smoke, seed)
        for r in c_rows:
            print(f"{r['name']}: {r['derived']}")
        if cp["no_fault"]["fleet_j_per_token"] <= 0.0:
            print("CHAOS GATE FAILED: no-fault fleet J/token is 0 "
                  "(energy model unavailable?) — gate cannot assess")
            return 1
        if cp["requests_lost"] != 0:
            print(f"CHAOS GATE FAILED: {cp['requests_lost']} request(s) "
                  f"lost under the fault plan")
            return 1
        if cp["attainment"] < CHAOS_SLO_ATTAIN_MIN:
            print(f"CHAOS GATE FAILED: interactive SLO attainment "
                  f"{cp['attainment']:.3f} < {CHAOS_SLO_ATTAIN_MIN} "
                  f"under 1 crash + 1 stall")
            return 1
        jr = cp["jtok_ratio_chaos_vs_no_fault"]
        if jr > CHAOS_JTOK_RATIO_MAX:
            print(f"CHAOS GATE FAILED: fleet J/token under faults is "
                  f"x{jr:.3f} of the no-fault run > "
                  f"{CHAOS_JTOK_RATIO_MAX}")
            return 1
        if cp["degraded"]["faults"]["degraded_members"] != ["v5e"]:
            print("CHAOS GATE FAILED: artifact corruption did not flag "
                  "the degraded member")
            return 1
        print(f"chaos gates ok (seed {cp['seed']}): streams "
              f"bit-identical to the no-fault run, 0 requests lost, "
              f"attainment {cp['attainment']:.3f} >= "
              f"{CHAOS_SLO_ATTAIN_MIN}, J/tok x{jr:.3f} <= "
              f"{CHAOS_JTOK_RATIO_MAX}, BASELINE downgrade flagged")
    if "--tp" in argv:
        tp = int(argv[argv.index("--tp") + 1])
        _ensure_devices(tp)
        special = True
        tp_rows, tp_payload = run_tp(tp, smoke)
        for r in tp_rows:
            print(f"{r['name']}: {r['derived']}")
        if tp_payload["tp1"]["model_tokens_per_s"] <= 0.0:
            print("TP GATE FAILED: tp=1 model-clock tokens/s is 0 "
                  "(energy model unavailable?) — gate cannot assess")
            return 1
        if tp_payload["model_speedup_tp_vs_1"] <= 1.0:
            print(f"TP GATE FAILED: model-clock tokens/s at tp={tp} is "
                  f"x{tp_payload['model_speedup_tp_vs_1']:.3f} of tp=1 — "
                  f"not strictly higher at equal streams")
            return 1
        if not tp_payload["overlap_factor"] > 0.0:
            print("TP GATE FAILED: collective overlap factor is 0 — "
                  "row-parallel all-gathers are not being pipelined")
            return 1
        jr = tp_payload["jtok_ratio_tp_vs_1"]
        if jr > TP_JTOK_RATIO_MAX:
            print(f"TP GATE FAILED: fleet J/token at tp={tp} is "
                  f"x{jr:.3f} of tp=1 > {TP_JTOK_RATIO_MAX}")
            return 1
        print(f"tp gates ok: streams bit-identical, model tokens/s "
              f"x{tp_payload['model_speedup_tp_vs_1']:.2f}, J/tok "
              f"x{jr:.2f} <= {TP_JTOK_RATIO_MAX}, overlap "
              f"{tp_payload['overlap_factor']:.3f}")
    if "--grain" in argv:
        special = True
        g_rows, g_payload = run_grain(smoke)
        for r in g_rows:
            print(f"{r['name']}: {r['derived']}")
        top = max(g_payload["recovery_vs_grain8"].values())
        print(f"grain sweep ok: streams bit-identical per grain, best "
              f"long-prompt prefill recovery x{top:.2f} vs grain 8")
    if special:
        return 0
    rows = run(smoke=smoke or None, seed=seed)
    for r in rows:
        print(f"{r['name']}: {r['derived']}")
    if smoke:
        payload = run.last_payload
        ratio = payload["ttft_ratio_chunked_vs_serial"]
        if payload["serial"]["ttft_model_s"]["mean"] <= 0.0:
            # a broken/unavailable energy model zeroes the model clock —
            # that must fail the gate loudly, not pass it vacuously
            print("TTFT GATE FAILED: serial model-clock TTFT is 0 "
                  "(energy model unavailable?) — gate cannot assess")
            return 1
        if ratio > SMOKE_TTFT_RATIO_MAX:
            print(f"TTFT GATE FAILED: chunked/serial mean TTFT ratio "
                  f"{ratio:.3f} > {SMOKE_TTFT_RATIO_MAX} — chunked "
                  f"admission has regressed on the prefill-stall mix")
            return 1
        print(f"TTFT gate ok: ratio {ratio:.3f} <= "
              f"{SMOKE_TTFT_RATIO_MAX}")
        pp = run.last_paged_payload
        if pp["concurrency_paged"] <= pp["concurrency_dense"]:
            print("PAGED GATE FAILED: paged concurrency "
                  f"{pp['concurrency_paged']} not above dense "
                  f"{pp['concurrency_dense']} at the fixed KV budget")
            return 1
        if pp["paged_peak_hbm_bytes"] > pp["kv_hbm_budget_bytes"]:
            print("PAGED GATE FAILED: paged peak HBM "
                  f"{pp['paged_peak_hbm_bytes']:.0f}B exceeds the dense "
                  f"budget {pp['kv_hbm_budget_bytes']:.0f}B")
            return 1
        if pp["dense"]["ttft_model_s"]["mean"] <= 0.0:
            print("PAGED GATE FAILED: dense model-clock TTFT is 0 "
                  "(energy model unavailable?) — gate cannot assess")
            return 1
        pr = pp["ttft_ratio_paged_vs_dense"]
        if pr > PAGED_TTFT_RATIO_MAX:
            print(f"PAGED GATE FAILED: paged/dense mean TTFT ratio "
                  f"{pr:.3f} > {PAGED_TTFT_RATIO_MAX} on the "
                  f"shared-prefix mix")
            return 1
        jr = pp["jtok_ratio_paged_vs_dense"]
        if jr > PAGED_JTOK_RATIO_MAX:
            print(f"PAGED GATE FAILED: paged/dense J/token ratio "
                  f"{jr:.3f} > {PAGED_JTOK_RATIO_MAX}")
            return 1
        print(f"paged gates ok: concurrency {pp['concurrency_dense']} -> "
              f"{pp['concurrency_paged']}, TTFT ratio {pr:.3f} <= "
              f"{PAGED_TTFT_RATIO_MAX}, J/tok ratio {jr:.3f} <= "
              f"{PAGED_JTOK_RATIO_MAX}")
        ap = run.last_admit_payload
        for kind, fam in ap["families"].items():
            if fam["serial"]["ttft_model_s"]["mean"] <= 0.0:
                print(f"ADMIT GATE FAILED: {kind} serial model-clock "
                      f"TTFT is 0 (energy model unavailable?) — gate "
                      f"cannot assess")
                return 1
            fr = fam["ttft_ratio_chunked_vs_serial"]
            if fr > SMOKE_TTFT_RATIO_MAX:
                print(f"ADMIT GATE FAILED: {kind} chunked/serial mean "
                      f"TTFT ratio {fr:.3f} > {SMOKE_TTFT_RATIO_MAX} — "
                      f"chunked admission has regressed for the "
                      f"prefill-once family")
                return 1
        ratios = ", ".join(
            f"{k}={v['ttft_ratio_chunked_vs_serial']:.3f}"
            for k, v in ap["families"].items())
        print(f"admit gates ok: streams bit-identical across admissions, "
              f"TTFT ratios {ratios} <= {SMOKE_TTFT_RATIO_MAX}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
