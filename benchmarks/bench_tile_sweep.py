"""Paper Figs 2-5 + Table I: tiled-matmul runtime/power vs matrix size per
tile size, and the occupancy (VMEM buffer) cliff. The whole tile x size grid
is evaluated in one `analyze_batch` call."""

from __future__ import annotations

import numpy as np

from benchmarks.common import default_chip, dump, row, timeit
from repro.core.hwsim import GemmConfig, TpuGemmSimulator

# TPU tile analogues of the paper's CUDA tiles 1..32 (square blocks; the
# "tile=8" point is the sub-MXU pathological one like the paper's tile=1)
TILES = (8, 64, 128, 256, 512, 1024, 2048)
SIZES = (256, 512, 1024, 2048, 4096, 8192)


def run() -> list[dict]:
    sim = TpuGemmSimulator(chip=default_chip(), seed=0)
    grid = [GemmConfig(m=s, n=s, k=s, block_m=t, block_n=t,
                       block_k=min(t, 512))
            for t in TILES for s in SIZES]
    tel = sim.analyze_batch(grid)
    rt = np.where(tel["valid"], tel["runtime_ms"], np.nan)
    pw = np.where(tel["valid"], tel["power_w"], np.nan)
    runtime = {t: list(rt[i * len(SIZES):(i + 1) * len(SIZES)])
               for i, t in enumerate(TILES)}
    power = {t: list(pw[i * len(SIZES):(i + 1) * len(SIZES)])
             for i, t in enumerate(TILES)}

    occupancy = sim.occupancy_report(list(TILES))

    # best tile at the paper's reference size (4096)
    i4096 = SIZES.index(4096)
    valid = {t: runtime[t][i4096] for t in TILES
             if np.isfinite(runtime[t][i4096])}
    best_tile = min(valid, key=valid.get)
    worst_tile = max(valid, key=valid.get)
    speedup = valid[worst_tile] / valid[best_tile]

    us = timeit(lambda: sim.analyze_batch(grid), n=20)
    dump("tile_sweep", {
        "chip": sim.chip.name,
        "sizes": list(SIZES),
        "runtime_ms": {str(k): v for k, v in runtime.items()},
        "power_w": {str(k): v for k, v in power.items()},
        "occupancy": {str(k): v for k, v in occupancy.items()},
        "best_tile_4096": best_tile,
        "speedup_best_vs_worst": speedup,
    })
    return [
        row("tile_sweep.analyze_batch", us,
            f"{len(grid)}cfgs/call;best_tile@4096={best_tile};"
            f"speedup_vs_worst={speedup:.1f}x"),
        row("tile_sweep.occupancy_cliff", us,
            "occupancy=" + ",".join(f"{t}:{occupancy[t]}" for t in TILES)),
    ]
