"""Shared benchmark utilities: timing, dataset cache, CSV row protocol.

Every bench module exposes `run() -> list[dict]` with keys
{name, us_per_call, derived}; `benchmarks.run` aggregates to CSV and dumps
detailed JSON to artifacts/bench/.

`BENCH_N_CONFIGS` (env var, also settable via `benchmarks/run.py
--n-configs`) shrinks the profiled dataset for smoke runs — CI sweeps 64
configs instead of the paper's 16,128. `BENCH_CHIP` selects the measurement
substrate (default tpu_v5e); datasets are cached per chip.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")
BENCH_ART = os.path.join(ART, "bench")

os.makedirs(BENCH_ART, exist_ok=True)


def default_n_configs() -> int:
    return int(os.environ.get("BENCH_N_CONFIGS", 16128))


def default_chip() -> str:
    return os.environ.get("BENCH_CHIP", "tpu_v5e")


def dataset_path(chip: str | None = None) -> str:
    from repro.core.chips import get_chip

    chip = get_chip(chip or default_chip()).name  # canonicalize aliases
    suffix = "" if chip == "tpu_v5e" else f"_{chip}"  # legacy cache name
    return os.path.join(ART, f"gemm_dataset{suffix}.npz")


def timeit(fn, *args, n: int = 5, warmup: int = 1) -> float:
    """Mean wall-clock microseconds per call."""
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(n):
        fn(*args)
    return (time.perf_counter() - t0) / n * 1e6


def get_dataset(n_configs: int | None = None, seed: int = 0,
                chip: str | None = None):
    """The paper-scale profiled dataset, cached on disk (per chip)."""
    from repro.core.profiler import collect_dataset, load_dataset, save_dataset

    n_configs = n_configs or default_n_configs()
    chip = chip or default_chip()
    path = dataset_path(chip)
    if os.path.exists(path):
        table = load_dataset(path)
        if len(table["runtime_ms"]) >= n_configs * 0.9:
            return table
    table = collect_dataset(n_configs=n_configs, seed=seed, chip=chip)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    save_dataset(table, path)
    return table


def paper_split(table, train_n: int = 2076, test_n: int = 519, seed: int = 0):
    """The paper's split: 2,076 train / 519 test rows of the 16,128.

    Smoke-size tables (fewer rows than train_n + test_n) fall back to a
    proportional 80/20 split so tiny CI sweeps still exercise every bench.
    """
    n = len(table["runtime_ms"])
    if n < train_n + test_n:
        train_n = max(1, int(n * 0.8))
        test_n = max(1, n - train_n)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    tr_idx, te_idx = perm[:train_n], perm[train_n:train_n + test_n]
    tr = {k: np.asarray(v)[tr_idx] for k, v in table.items()}
    te = {k: np.asarray(v)[te_idx] for k, v in table.items()}
    return tr, te


def dump(name: str, payload) -> None:
    with open(os.path.join(BENCH_ART, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def row(name: str, us: float, derived: str) -> dict:
    return {"name": name, "us_per_call": us, "derived": derived}


# retained for callers that imported the old constant
DATASET_PATH = dataset_path("tpu_v5e")
