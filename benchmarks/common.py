"""Shared benchmark utilities: timing, dataset cache, CSV row protocol.

Every bench module exposes `run() -> list[dict]` with keys
{name, us_per_call, derived}; `benchmarks.run` aggregates to CSV and dumps
detailed JSON to artifacts/bench/.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")
BENCH_ART = os.path.join(ART, "bench")
DATASET_PATH = os.path.join(ART, "gemm_dataset.npz")

os.makedirs(BENCH_ART, exist_ok=True)


def timeit(fn, *args, n: int = 5, warmup: int = 1) -> float:
    """Mean wall-clock microseconds per call."""
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(n):
        fn(*args)
    return (time.perf_counter() - t0) / n * 1e6


def get_dataset(n_configs: int = 16128, seed: int = 0):
    """The paper-scale profiled dataset, cached on disk."""
    from repro.core.profiler import collect_dataset, load_dataset, save_dataset

    if os.path.exists(DATASET_PATH):
        table = load_dataset(DATASET_PATH)
        if len(table["runtime_ms"]) >= n_configs * 0.9:
            return table
    table = collect_dataset(n_configs=n_configs, seed=seed)
    os.makedirs(os.path.dirname(DATASET_PATH), exist_ok=True)
    save_dataset(table, DATASET_PATH)
    return table


def paper_split(table, train_n: int = 2076, test_n: int = 519, seed: int = 0):
    """The paper's split: 2,076 train / 519 test rows of the 16,128."""
    n = len(table["runtime_ms"])
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    tr_idx, te_idx = perm[:train_n], perm[train_n:train_n + test_n]
    tr = {k: np.asarray(v)[tr_idx] for k, v in table.items()}
    te = {k: np.asarray(v)[te_idx] for k, v in table.items()}
    return tr, te


def dump(name: str, payload) -> None:
    with open(os.path.join(BENCH_ART, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def row(name: str, us: float, derived: str) -> dict:
    return {"name": name, "us_per_call": us, "derived": derived}
