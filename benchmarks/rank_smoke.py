"""CI smoke: the autotuner's batched rank path must beat the pre-refactor
NumPy per-tree loop on a 512-candidate grid, with identical ranking.

Trains a small forest on a small sweep (fast), then times both paths in
steady state (features precomputed for the batched path, per-call table
build + per-tree loop for the reference — i.e. exactly what the old
`GemmAutotuner.rank` did). Exits non-zero if the batched path is not
faster or the rankings disagree.

Run:  PYTHONPATH=src python benchmarks/rank_smoke.py
"""

from __future__ import annotations

import sys
import time

import numpy as np

from repro.core.autotuner import GemmAutotuner
from repro.core.features import features_matrix, table_from_configs
from repro.core.hwsim import TpuGemmSimulator
from repro.core.predictor import PerfPredictor
from repro.core.profiler import collect_dataset, sweep_configs

N_CANDIDATES = 512


def median_ms(fn, n: int = 20) -> float:
    fn(), fn()  # warm
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e3


def main() -> int:
    table = collect_dataset(n_configs=1500, seed=0)
    pred = PerfPredictor(model="rf", residual=True, fast=True,
                         chip="tpu_v5e").fit(table)
    tuner = GemmAutotuner(pred, TpuGemmSimulator(seed=3))
    cfgs = sweep_configs(n_configs=N_CANDIDATES, seed=1)
    X = features_matrix(cfgs, chip=tuner.chip)

    def rank_reference():
        t = table_from_configs(cfgs, chip=tuner.chip)
        return np.argsort(pred.predict_matrix_reference(t)[:, 0])

    t_new = median_ms(lambda: tuner.rank(cfgs, features=X))
    t_ref = median_ms(rank_reference)
    # parity: batched scores within 1e-4 relative of the loop path (order
    # equality only holds when both paths are the bit-exact numpy scorer)
    ref_scores = pred.predict_matrix_reference(
        table_from_configs(cfgs, chip=tuner.chip))
    rel = np.abs(tuner._predict_features(X) - ref_scores) / np.maximum(
        np.abs(ref_scores), 1e-12)
    speedup = t_ref / t_new
    print(f"rank {N_CANDIDATES} candidates: batched {t_new:.2f} ms vs "
          f"numpy per-tree loop {t_ref:.2f} ms -> {speedup:.1f}x; "
          f"max score deviation {rel.max():.2e}")
    if rel.max() >= 1e-4:
        print("FAIL: batched and reference predictions diverge",
              file=sys.stderr)
        return 1
    if speedup <= 1.0:
        print("FAIL: batched rank is not faster than the per-tree loop",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
