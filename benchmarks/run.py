"""Benchmark aggregator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV; detailed JSON lands in
artifacts/bench/.

Usage:
  PYTHONPATH=src python benchmarks/run.py                    # full scale
  PYTHONPATH=src python benchmarks/run.py --n-configs 64     # CI smoke
  PYTHONPATH=src python benchmarks/run.py --chip rtx4070     # paper's chip
"""

from __future__ import annotations

import argparse
import os
import sys
import traceback

# allow `python benchmarks/run.py` from anywhere (repo root on sys.path)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

BENCHES = [
    "bench_roofline",        # Fig 1 + §Roofline cell table
    "bench_tile_sweep",      # Figs 2-5 + Table I
    "bench_linreg",          # Tables II & III
    "bench_dataset",         # §IV-C 16,128-op sweep
    "bench_model_metrics",   # Table IV
    "bench_correlation",     # Table V / Fig 6
    "bench_model_comparison",# Table VI
    "bench_autotune",        # §Abstract 3.2x / 22% claims
    "bench_kernel",          # Pallas kernel micro
    "bench_rank_f32",        # f32 vs x64 in-graph ranking winner drift
    "bench_serving",         # chunked/serial/wave serving (TTFT, J/token)
]


def main(argv: list[str] | None = None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--n-configs", type=int, default=None,
                        help="profiled sweep size (default 16128; use a "
                             "small value like 64 for a smoke run)")
    parser.add_argument("--chip", type=str, default=None,
                        help="measurement substrate (tpu_v5e, rtx4070)")
    parser.add_argument("--only", type=str, default=None,
                        help="comma-separated bench module subset")
    parser.add_argument("--exclude", type=str, default=None,
                        help="comma-separated bench modules to skip "
                             "(applied to the default list or --only)")
    args = parser.parse_args(argv)
    # bench modules pick these up through benchmarks.common defaults
    if args.n_configs is not None:
        os.environ["BENCH_N_CONFIGS"] = str(args.n_configs)
    if args.chip is not None:
        os.environ["BENCH_CHIP"] = args.chip

    import importlib

    benches = args.only.split(",") if args.only else BENCHES
    if args.exclude:
        skip = set(args.exclude.split(","))
        benches = [b for b in benches if b not in skip]
    print("name,us_per_call,derived")
    failed = []
    for name in benches:
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            for r in mod.run():
                derived = str(r["derived"]).replace(",", ";")
                print(f"{r['name']},{r['us_per_call']:.1f},{derived}",
                      flush=True)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
