"""Benchmark aggregator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV; detailed JSON lands in
artifacts/bench/.
"""

from __future__ import annotations

import sys
import traceback


BENCHES = [
    "bench_roofline",        # Fig 1 + §Roofline cell table
    "bench_tile_sweep",      # Figs 2-5 + Table I
    "bench_linreg",          # Tables II & III
    "bench_dataset",         # §IV-C 16,128-op sweep
    "bench_model_metrics",   # Table IV
    "bench_correlation",     # Table V / Fig 6
    "bench_model_comparison",# Table VI
    "bench_autotune",        # §Abstract 3.2x / 22% claims
    "bench_kernel",          # Pallas kernel micro
]


def main() -> None:
    import importlib

    print("name,us_per_call,derived")
    failed = []
    for name in BENCHES:
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            for r in mod.run():
                derived = str(r["derived"]).replace(",", ";")
                print(f"{r['name']},{r['us_per_call']:.1f},{derived}",
                      flush=True)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
