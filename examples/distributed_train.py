"""Distributed training example: DP x TP on a host mesh with sharded params,
ZeRO-1 optimizer states, and logical-axis activation sharding — the same
code path the 256/512-chip dry-run exercises, scaled to this host's devices.

Uses 8 virtual host devices (set before jax import, like launch/dryrun.py).

Run:  python examples/distributed_train.py      # note: NOT via PYTHONPATH
      (the script sets XLA flags itself, then imports repro from src/)
"""

import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.data.pipeline import DataConfig, SyntheticLMDataset  # noqa: E402
from repro.distributed.sharding import (  # noqa: E402
    param_shardings,
    set_mesh_rules,
)
from repro.models.config import ModelConfig  # noqa: E402
from repro.models.registry import get_model  # noqa: E402
from repro.optim.adamw import AdamWConfig, init_opt_state, zero1_shardings  # noqa: E402
from repro.train.step import make_train_step  # noqa: E402


def main():
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    print(f"mesh: {dict(mesh.shape)} over {len(jax.devices())} host devices")
    cfg = ModelConfig(
        name="dist-demo", kind="dense", n_layers=4, d_model=256, n_heads=8,
        n_kv_heads=4, d_ff=1024, vocab=4096, param_dtype="float32",
        activation_dtype="float32", remat=False,
    )
    model = get_model(cfg)
    set_mesh_rules(mesh, fsdp=cfg.fsdp)

    params_shape = jax.eval_shape(lambda k: model.init(k, cfg),
                                  jax.random.key(0))
    p_sh = param_shardings(params_shape, mesh, fsdp=cfg.fsdp)
    opt_sh = zero1_shardings(params_shape,
                             p_sh, mesh)
    state_sh = {"params": p_sh, "opt": opt_sh,
                "rng": NamedSharding(mesh, P())}
    batch_sh = {"tokens": NamedSharding(mesh, P("data", None)),
                "labels": NamedSharding(mesh, P("data", None))}

    with mesh:
        init = jax.jit(
            lambda k: {
                "params": model.init(k, cfg),
                "opt": init_opt_state(model.init(k, cfg)),
                "rng": jax.random.key_data(jax.random.key(0)),
            },
            out_shardings=state_sh)
        state = init(jax.random.key(0))
        wq = state["params"]["blocks"]["attn"]["wq"]
        print("wq sharding:", wq.sharding.spec, "shape:", wq.shape)

        step = jax.jit(make_train_step(model, cfg, AdamWConfig(lr=1e-3,
                                                               warmup_steps=5)),
                       in_shardings=(state_sh, batch_sh),
                       out_shardings=(state_sh, None),
                       donate_argnums=0)
        ds = SyntheticLMDataset(DataConfig(seq_len=128, global_batch=8,
                                           vocab=cfg.vocab))
        losses = []
        for i in range(40):
            state, metrics = step(state, ds.batch_at(i))
            losses.append(float(metrics["loss"]))
        print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")
        assert losses[-1] < losses[0]
    print("distributed_train OK")


if __name__ == "__main__":
    main()
