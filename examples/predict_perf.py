"""Performance-prediction example: what the paper's Fig 7-9 show — predicted
vs actual runtime/power/energy across matrix sizes, printed as a table, plus
a demonstration of the jitted in-graph predictor ranking candidate configs.

Run:  PYTHONPATH=src python examples/predict_perf.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core.autotuner import GemmAutotuner
from repro.core.features import NUMERIC_FEATURES, config_features
from repro.core.hwsim import GemmConfig, TpuGemmSimulator
from repro.core.mlperf import train_test_split
from repro.core.predictor import PerfPredictor
from repro.core.profiler import collect_dataset


def main():
    table = collect_dataset(n_configs=4000, seed=0)
    tr, _ = train_test_split(table, test_size=0.1, random_state=0)
    pred = PerfPredictor(model="rf", residual=True, fast=True).fit(tr)
    sim = TpuGemmSimulator(seed=42)

    print(f"{'size':>6} {'pred ms':>9} {'actual ms':>9} {'pred W':>7} "
          f"{'actual W':>8} {'pred J':>8} {'actual J':>8}")
    for s in [512, 1024, 2048, 4096, 8192]:
        cfg = GemmConfig(m=s, n=s, k=s, block_m=256, block_n=256, block_k=512)
        f = config_features(cfg)
        out = pred.predict({k: np.array([v]) for k, v in f.items()})
        t = sim.measure(cfg)
        print(f"{s:>6} {out['runtime_ms'][0]:>9.3f} {t.runtime_ms:>9.3f} "
              f"{out['power_w'][0]:>7.1f} {t.power_w:>8.1f} "
              f"{out['energy_j'][0]:>8.3f} {t.energy_j:>8.3f}")

    # jitted in-graph ranking of every candidate config for one GEMM
    tuner = GemmAutotuner(pred, sim)
    cfgs = tuner.candidate_configs(4096, 4096, 4096)
    X = jnp.asarray(
        np.stack([[config_features(c)[k] for k in NUMERIC_FEATURES]
                  for c in cfgs]), jnp.float32)
    jfn = pred.jax_predictor()
    runtimes = np.asarray(jfn(X))[:, 0]
    best = cfgs[int(runtimes.argmin())]
    print(f"\njitted ranking over {len(cfgs)} candidates -> best block "
          f"({best.block_m},{best.block_n},{best.block_k}) "
          f"pred {runtimes.min():.3f} ms")
    print("predict_perf OK")


if __name__ == "__main__":
    main()
