"""Performance-prediction example: what the paper's Fig 7-9 show — predicted
vs actual runtime/power/energy across matrix sizes, printed as a table — plus
the serving stack around it: the versioned pickle-free predictor artifact
(save -> validated load), the batched `tune_many` fleet API, and the compiled
ranking path over a candidate grid.

Run:  PYTHONPATH=src python examples/predict_perf.py
"""

import os
import tempfile

import numpy as np

from repro.core.autotuner import GemmAutotuner
from repro.core.features import config_features
from repro.core.hwsim import GemmConfig, TpuGemmSimulator
from repro.core.mlperf import train_test_split
from repro.core.predictor import PerfPredictor
from repro.core.profiler import collect_dataset


def main():
    table = collect_dataset(n_configs=4000, seed=0)
    tr, _ = train_test_split(table, test_size=0.1, random_state=0)
    pred = PerfPredictor(model="rf", residual=True, fast=True,
                         chip="tpu_v5e").fit(tr)
    sim = TpuGemmSimulator(seed=42)

    # versioned artifact round-trip: .npz arrays + JSON metadata, validated
    # on load (schema + fingerprint), no pickle anywhere.
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "perf_predictor_tpu_v5e.npz")
        pred.save(path)
        pred = PerfPredictor.load(path)
    print(f"artifact: model={pred.model_name} chip={pred.chip_name} "
          f"fingerprint={pred.fingerprint()}")

    print(f"{'size':>6} {'pred ms':>9} {'actual ms':>9} {'pred W':>7} "
          f"{'actual W':>8} {'pred J':>8} {'actual J':>8}")
    for s in [512, 1024, 2048, 4096, 8192]:
        cfg = GemmConfig(m=s, n=s, k=s, block_m=256, block_n=256, block_k=512)
        f = config_features(cfg)
        out = pred.predict({k: np.array([v]) for k, v in f.items()})
        t = sim.measure(cfg)
        print(f"{s:>6} {out['runtime_ms'][0]:>9.3f} {t.runtime_ms:>9.3f} "
              f"{out['power_w'][0]:>7.1f} {t.power_w:>8.1f} "
              f"{out['energy_j'][0]:>8.3f} {t.energy_j:>8.3f}")

    # fleet tuning: one batched scorer call + one verification sweep for
    # every uncached shape (the serving path behind ops.warm_gemm_cache).
    tuner = GemmAutotuner(pred, sim)
    fleet = [(4096, 4096, 4096), (8192, 1024, 8192), (16, 4096, 4096),
             (2048, 2048, 2048), (512, 512, 512)]
    best = tuner.tune_many(fleet)
    print("\ntune_many over the shape fleet:")
    for (m, n, k), cfg in zip(fleet, best):
        print(f"  ({m:>5},{n:>5},{k:>5}) -> block "
              f"({cfg.block_m},{cfg.block_n},{cfg.block_k})")

    # compiled ranking of every candidate config for one GEMM
    cfgs, X = tuner.candidate_table(4096, 4096, 4096, "bf16")
    order = tuner.rank(cfgs, features=X)
    bestc = cfgs[int(order[0])]
    print(f"\nbatched ranking over {len(cfgs)} candidates -> best block "
          f"({bestc.block_m},{bestc.block_n},{bestc.block_k})")
    print("predict_perf OK")


if __name__ == "__main__":
    main()
