"""Quickstart: the paper's pipeline end to end in ~a minute on CPU.

  1. profile a sweep of GEMM configs on the hardware substrate,
  2. fit the multi-output Random Forest predictor (runtime/power/energy/TFLOPS),
  3. evaluate it (the paper's Table IV metrics),
  4. autotune a GEMM's Pallas block config for runtime and for energy,
  5. run the tuned kernel in interpret mode and check it against the oracle.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core.autotuner import GemmAutotuner
from repro.core.hwsim import TpuGemmSimulator
from repro.core.predictor import PerfPredictor
from repro.core.profiler import collect_dataset
from repro.core.mlperf import train_test_split
from repro.kernels.ref import matmul_ref
from repro.kernels.tiled_matmul import BlockConfig, tiled_matmul


def main():
    print("== 1. profile GEMM configs on the TPU-v5e substrate ==")
    table = collect_dataset(n_configs=3000, seed=0)
    print(f"   profiled {len(table['runtime_ms'])} valid configs "
          "(batched measure_batch sweep)")
    ada = collect_dataset(n_configs=500, seed=0, chip="rtx4070")
    print(f"   cross-chip check: rtx4070 median runtime "
          f"{float(np.median(ada['runtime_ms'])):.2f} ms vs v5e "
          f"{float(np.median(table['runtime_ms'])):.2f} ms")

    print("== 2./3. fit + evaluate the multi-output predictor ==")
    tr, te = train_test_split(table, test_size=0.2, random_state=0)
    pred = PerfPredictor(model="rf", residual=True, fast=True).fit(tr)
    rep = pred.evaluate(te)
    for t, m in rep.items():
        print(f"   {t:<12} R2={m['r2']:.4f}  med%err={m['median_pct_err']:.1f}")

    print("== 4. autotune a 4096^3 GEMM ==")
    tuner = GemmAutotuner(pred, TpuGemmSimulator(seed=1))
    for objective in ("runtime", "energy"):
        r = tuner.tune_report(4096, 4096, 4096, objective=objective)
        print(f"   [{objective:<7}] best block={r['best']}  "
              f"speedup={r['speedup']:.2f}x  "
              f"power {r['baseline_power_w']:.0f}->{r['tuned_power_w']:.0f}W")

    print("== 5. run the tuned Pallas kernel (interpret mode) ==")
    best = tuner.best_config(256, 256, 256)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    small = BlockConfig(min(best.block_m, 256), min(best.block_n, 256),
                        min(best.block_k, 256))
    out = tiled_matmul(a, b, config=small, interpret=True)
    err = float(jnp.max(jnp.abs(out - matmul_ref(a, b))))
    print(f"   block={small.as_tuple()}  max|err| vs oracle = {err:.2e}")
    assert err < 1e-4
    print("quickstart OK")


if __name__ == "__main__":
    main()
