"""Serving example: batched greedy generation with the continuous-batching
engine over a small dense LM (random weights — the point is the serving
machinery: prefill, KV cache, lockstep decode, wave packing).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import numpy as np

import jax

from repro.models.config import ModelConfig
from repro.models.registry import get_model
from repro.serving.engine import Request, ServingEngine


def main():
    cfg = ModelConfig(
        name="serve-demo", kind="dense", n_layers=4, d_model=256, n_heads=8,
        n_kv_heads=4, d_ff=1024, vocab=4096, param_dtype="float32",
        activation_dtype="float32", remat=False,
    )
    model = get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    engine = ServingEngine(model, params, cfg, max_batch=4, max_len=128)

    rng = np.random.default_rng(0)
    n_requests = 10
    for uid in range(n_requests):
        prompt = rng.integers(0, cfg.vocab, rng.integers(4, 24))
        engine.submit(Request(uid=uid, prompt=prompt.astype(np.int32),
                              max_new_tokens=16))

    import time
    t0 = time.perf_counter()
    results = engine.run_until_empty()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.tokens) for r in results)
    for r in sorted(results, key=lambda r: r.uid)[:4]:
        print(f"req {r.uid}: prompt_len={r.prompt_len} -> {r.tokens[:8]}...")
    print(f"served {len(results)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.0f} tok/s on CPU)")
    assert len(results) == n_requests
    print("serve_lm OK")


if __name__ == "__main__":
    main()
