"""Serving example: continuous batching with chunked admission prefill
and per-request energy accounting over a small dense LM (random weights —
the point is the serving machinery: prompts chunk-prefill through the
decode loop in bucketed lane calls, finished rows splice into decode
slots, finished slots retire mid-decode and refill, telemetry + J/token
report).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import numpy as np

import jax

from repro.models.config import ModelConfig
from repro.models.registry import get_model
from repro.serving.engine import Request, ServingEngine


def main():
    cfg = ModelConfig(
        name="serve-demo", kind="dense", n_layers=4, d_model=256, n_heads=8,
        n_kv_heads=4, d_ff=1024, vocab=4096, param_dtype="float32",
        activation_dtype="float32", remat=False,
    )
    model = get_model(cfg)
    params = model.init(jax.random.key(0), cfg)

    def submit_all(engine, n_requests=10, seed=0):
        rng = np.random.default_rng(seed)
        for uid in range(n_requests):
            # one long prompt up front — the shape that used to stall
            # every other request behind its serialized prefill
            n = 96 if uid == 0 else int(rng.integers(4, 24))
            prompt = rng.integers(0, cfg.vocab, n)
            engine.submit(Request(
                uid=uid, prompt=prompt.astype(np.int32),
                # mixed budgets — the shape where continuous batching wins
                max_new_tokens=int(rng.choice([4, 8, 32]))))

    # continuous mode (the default for every LM family, SSM included):
    # prompts chunk-prefill through the decode loop (chunk_tokens per
    # step, queued admissions batched per call), finished slots retire
    # mid-decode and refill from the queue
    engine = ServingEngine(model, params, cfg, max_batch=4, max_len=128,
                           chunk_tokens=32)
    submit_all(engine)
    t0 = time.perf_counter()
    results = engine.run_until_empty()
    dt = time.perf_counter() - t0
    for r in sorted(results, key=lambda r: r.uid)[:4]:
        print(f"req {r.uid}: prompt_len={r.prompt_len} "
              f"n_tokens={r.n_tokens} steps={r.steps} "
              f"ttft={r.ttft_s * 1e3:.0f}ms "
              f"energy={r.energy_j * 1e3:.2f}mJ -> {r.tokens[:6]}...")
    rep = engine.report()
    print(f"continuous: {rep['requests']} requests, "
          f"{rep['generated_tokens']} tokens in {dt:.2f}s | "
          f"occupancy={rep['slot_occupancy']:.2f} "
          f"J/token={rep['j_per_token']:.2e} "
          f"slot_steps={rep['slot_steps']:.0f} "
          f"chunk_steps={rep['chunk_steps']}")

    # same workload through the legacy wave loop: identical greedy streams,
    # strictly more executed decode-step*slots ("Racing to Idle")
    wave = ServingEngine(model, params, cfg, max_batch=4, max_len=128,
                         mode="wave")
    submit_all(wave)
    wave_results = {r.uid: r for r in wave.run_until_empty()}
    for r in results:
        np.testing.assert_array_equal(r.tokens, wave_results[r.uid].tokens)
    wrep = wave.report()
    print(f"wave:       identical streams | "
          f"J/token={wrep['j_per_token']:.2e} "
          f"slot_steps={wrep['slot_steps']:.0f}")
    assert len(results) == 10
    print("serve_lm OK")


if __name__ == "__main__":
    main()
