"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
with the full production stack — autotuned GEMM path, AdamW + cosine
schedule, checkpointing every 50 steps, fault-tolerant resume, straggler
monitoring, synthetic-but-learnable data.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, DataLoader, SyntheticLMDataset
from repro.models.config import ModelConfig
from repro.models.registry import get_model
from repro.optim.adamw import AdamWConfig
from repro.train.loop import LoopConfig, resume_or_init, run_train_loop
from repro.train.step import init_train_state, make_train_step


def hundred_m_config() -> ModelConfig:
    """~100M params: 12L x 768 wide, GQA 12/4 heads, 8k vocab."""
    return ModelConfig(
        name="lm-100m", kind="dense", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=4, d_ff=3072, vocab=8192, param_dtype="float32",
        activation_dtype="float32", remat=False,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = hundred_m_config()
    model = get_model(cfg)
    print(f"model: {cfg.name}  params~{cfg.n_params()/1e6:.0f}M  "
          f"devices={jax.devices()}")

    ds = SyntheticLMDataset(DataConfig(seq_len=args.seq,
                                       global_batch=args.batch,
                                       vocab=cfg.vocab, seed=0))
    loader = DataLoader(ds)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)

    opt_cfg = AdamWConfig(lr=3e-4, warmup_steps=20, decay_steps=args.steps)
    train_step = jax.jit(make_train_step(model, cfg, opt_cfg),
                         donate_argnums=0)

    state, start = resume_or_init(
        ckpt=ckpt,
        init_fn=lambda: init_train_state(jax.random.key(0), model, cfg),
        loader=loader)
    if start:
        print(f"resumed from checkpoint at step {start}")

    state, summary = run_train_loop(
        train_step=train_step, state=state, loader=loader, ckpt=ckpt,
        loop_cfg=LoopConfig(total_steps=args.steps, ckpt_every=50,
                            log_every=20),
        start_step=start)
    print(f"done: step={summary['final_step']} "
          f"loss={summary['final_loss']:.4f} "
          f"({summary['mean_step_time_s']*1e3:.0f} ms/step)")
    curve = summary["loss_curve"]
    if len(curve) > 20:
        print(f"loss first10={curve[:10].mean():.3f} "
              f"last10={curve[-10:].mean():.3f}")
        assert curve[-10:].mean() < curve[:10].mean(), "loss did not improve"
    print("train_lm OK")


if __name__ == "__main__":
    main()
