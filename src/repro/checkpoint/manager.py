"""Sharded, atomic, async checkpointing with restart/reshard support.

Layout on disk:
    <dir>/step_000123/
        manifest.json            # tree structure, shapes, dtypes, step
        shard_<host>.npz         # this host's param/opt shards (flattened)
        data_state.json          # data-pipeline position
    <dir>/LATEST                 # atomic pointer, written last

Fault-tolerance properties:
  * atomic publish — LATEST flips only after every shard + manifest is
    fsync'd, so a crash mid-save can never corrupt the restore point;
  * async — the save runs on a writer thread over host-fetched numpy copies,
    overlapping the next train steps (`wait()` joins before the next save);
  * reshard-on-restore — arrays are saved unsharded per leaf (single-host
    container) or per-host shards; restore places them under *any* new mesh
    via `jax.device_put(value, sharding)`, which is what elastic restart
    needs when the device count changed.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

_SEP = "__"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{_SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}{_SEP}"))
    else:
        out[prefix[: -len(_SEP)]] = tree
    return out


def _unflatten(flat: dict):
    tree: dict = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, host_id: int = 0,
                 n_hosts: int = 1):
        self.dir = directory
        self.keep = keep
        self.host_id = host_id
        self.n_hosts = n_hosts
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ---------- save ----------
    def save(self, step: int, state: dict, data_state: dict | None = None,
             blocking: bool = False) -> None:
        """state: arbitrary pytree of jax/np arrays (params, opt, rng...)."""
        self.wait()
        flat = _flatten(state)
        # fetch to host *now* (cheap on CPU; on TPU this is the device->host
        # DMA we overlap with compute), then write on the thread.
        host_flat = {k: np.asarray(v) for k, v in flat.items()}

        def _write():
            d = os.path.join(self.dir, f"step_{step:09d}")
            tmp = d + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, f"shard_{self.host_id}.npz"),
                     **host_flat)
            manifest = {
                "step": step,
                "n_hosts": self.n_hosts,
                "keys": sorted(host_flat),
                "shapes": {k: list(v.shape) for k, v in host_flat.items()},
                "dtypes": {k: str(v.dtype) for k, v in host_flat.items()},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if data_state is not None:
                with open(os.path.join(tmp, "data_state.json"), "w") as f:
                    json.dump(data_state, f)
            os.replace(tmp, d)  # atomic dir publish
            with open(os.path.join(self.dir, "LATEST.tmp"), "w") as f:
                f.write(os.path.basename(d))
            os.replace(os.path.join(self.dir, "LATEST.tmp"),
                       os.path.join(self.dir, "LATEST"))
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # ---------- restore ----------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        path = os.path.join(self.dir, "LATEST")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return int(f.read().strip().split("_")[1])

    def restore(self, step: int | None = None, shardings=None
                ) -> tuple[dict, dict | None]:
        """Returns (state, data_state). `shardings`: optional pytree of
        NamedSharding matching the state tree — arrays are placed onto the
        (possibly different) mesh of the restarted job."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:09d}")
        with np.load(os.path.join(d, f"shard_{self.host_id}.npz")) as z:
            flat = {k: z[k] for k in z.files}
        state = _unflatten(flat)
        if shardings is not None:
            flat_sh = _flatten(shardings)
            flat_st = _flatten(state)
            placed = {}
            for k, v in flat_st.items():
                sh = flat_sh.get(k)
                placed[k] = jax.device_put(v, sh) if sh is not None else v
            state = _unflatten(placed)
        ds_path = os.path.join(d, "data_state.json")
        data_state = None
        if os.path.exists(ds_path):
            with open(ds_path) as f:
                data_state = json.load(f)
        return state, data_state
