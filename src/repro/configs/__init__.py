"""Assigned-architecture registry: one module per arch, exact published
configs, reduced smoke variants, and per-shape input specs.

    from repro.configs import get_config, list_archs, SHAPES
    cfg = get_config("qwen2-7b")            # full config
    cfg = get_config("qwen2-7b", smoke=True)
"""

from __future__ import annotations

import importlib

ARCHS = [
    "falcon_mamba_7b",
    "olmoe_1b_7b",
    "deepseek_v2_236b",
    "codeqwen1_5_7b",
    "starcoder2_3b",
    "qwen2_5_14b",
    "qwen2_7b",
    "seamless_m4t_medium",
    "qwen2_vl_2b",
    "zamba2_2_7b",
]

# canonical ids as given in the assignment (dashes/dots)
CANONICAL = {
    "falcon-mamba-7b": "falcon_mamba_7b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "starcoder2-3b": "starcoder2_3b",
    "qwen2.5-14b": "qwen2_5_14b",
    "qwen2-7b": "qwen2_7b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "zamba2-2.7b": "zamba2_2_7b",
}

SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")

SHAPE_DEFS = {
    "train_4k": {"seq_len": 4096, "global_batch": 256, "step": "train"},
    "prefill_32k": {"seq_len": 32768, "global_batch": 32, "step": "prefill"},
    "decode_32k": {"seq_len": 32768, "global_batch": 128, "step": "decode"},
    "long_500k": {"seq_len": 524288, "global_batch": 1, "step": "decode"},
}


def _module(arch: str):
    name = CANONICAL.get(arch, arch.replace("-", "_").replace(".", "_"))
    return importlib.import_module(f"repro.configs.{name}")


def list_archs() -> list[str]:
    return list(CANONICAL)


def get_config(arch: str, smoke: bool = False):
    mod = _module(arch)
    return mod.smoke_config() if smoke else mod.full_config()


def input_specs(arch: str, shape: str, smoke: bool = False):
    """ShapeDtypeStruct stand-ins for every model input of (arch, shape)."""
    mod = _module(arch)
    return mod.input_specs(shape, smoke=smoke)


def supported_cells(arch: str) -> list[str]:
    """Shapes this arch runs (long_500k only for sub-quadratic archs)."""
    mod = _module(arch)
    cfg = mod.full_config()
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        shapes.append("long_500k")
    return shapes


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in list_archs() for s in supported_cells(a)]
