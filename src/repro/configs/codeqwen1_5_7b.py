"""codeqwen1.5-7b — 32L d_model=4096 32H (kv=32) d_ff=13440 vocab=92416,
QKV bias (qwen1.5 arch) [hf:Qwen/CodeQwen1.5-7B]."""

from repro.configs import common
from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b",
        kind="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        d_ff=13440,
        vocab=92416,
        qkv_bias=True,
        rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b-smoke",
        kind="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=192,
        vocab=256,
        qkv_bias=True,
        param_dtype="float32",
        activation_dtype="float32",
        remat=False,
    )


def input_specs(shape: str, smoke: bool = False) -> dict:
    cfg = smoke_config() if smoke else full_config()
    step = common.SHAPE_DEFS[shape]["step"]
    if step == "train":
        return common.lm_train_specs(cfg, shape, smoke)
    if step == "prefill":
        return common.lm_prefill_specs(cfg, shape, smoke)
    return common.lm_decode_specs(cfg, shape, family="kv", smoke=smoke)
