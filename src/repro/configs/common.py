"""Shared input-spec builders for the assigned shape cells.

Every builder returns a dict of jax.ShapeDtypeStruct — weak-type-correct,
shardable, zero-allocation stand-ins consumed by `jit(...).lower(**specs)`.

Shape semantics (task spec):
  train_4k / prefill_32k  -> full-sequence step at (global_batch, seq_len)
  decode_32k / long_500k  -> ONE new token against a cache of seq_len
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import SHAPE_DEFS
from repro.models.config import ModelConfig

SDS = jax.ShapeDtypeStruct


def _bs(shape: str, smoke: bool) -> tuple[int, int]:
    d = SHAPE_DEFS[shape]
    if smoke:
        return (2, min(d["seq_len"], 64))
    return (d["global_batch"], d["seq_len"])


def lm_train_specs(cfg: ModelConfig, shape: str, smoke: bool = False) -> dict:
    B, S = _bs(shape, smoke)
    return {
        "tokens": SDS((B, S), jnp.int32),
        "labels": SDS((B, S), jnp.int32),
    }


def lm_prefill_specs(cfg: ModelConfig, shape: str, smoke: bool = False) -> dict:
    B, S = _bs(shape, smoke)
    return {"tokens": SDS((B, S), jnp.int32)}


def _cache_specs(cfg: ModelConfig, B: int, S: int, family: str) -> dict:
    L_ = cfg.n_layers
    if family == "kv":
        kv = (L_, B, S, cfg.kv_heads, cfg.hd)
        return {"k": SDS(kv, jnp.bfloat16), "v": SDS(kv, jnp.bfloat16)}
    if family == "mla":
        return {
            "c_kv": SDS((L_, B, S, cfg.kv_lora_rank), jnp.bfloat16),
            "k_pe": SDS((L_, B, S, cfg.rope_head_dim), jnp.bfloat16),
        }
    if family == "mamba1":
        return {
            "conv": SDS((L_, B, cfg.d_conv - 1, cfg.d_inner), jnp.float32),
            "ssm": SDS((L_, B, cfg.d_inner, cfg.ssm_state), jnp.float32),
        }
    if family == "hybrid":
        g = cfg.n_layers // cfg.attn_every
        e = cfg.attn_every
        conv_ch = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
        H = cfg.d_inner // cfg.ssm_headdim
        return {
            "mamba": {
                "conv": SDS((g, e, B, cfg.d_conv - 1, conv_ch), jnp.float32),
                "ssm": SDS((g, e, B, H, cfg.ssm_state, cfg.ssm_headdim),
                           jnp.float32),
            },
            "attn": {
                "k": SDS((g, B, S, cfg.kv_heads, cfg.hd), jnp.bfloat16),
                "v": SDS((g, B, S, cfg.kv_heads, cfg.hd), jnp.bfloat16),
            },
        }
    raise ValueError(family)


def lm_decode_specs(cfg: ModelConfig, shape: str, family: str = "kv",
                    smoke: bool = False) -> dict:
    """Inputs of decode_step: token (B,), state {kv/cache, index}."""
    B, S = _bs(shape, smoke)
    state: dict = {"index": SDS((), jnp.int32)}
    if family == "hybrid":
        state["cache"] = _cache_specs(cfg, B, S, family)
    else:
        state["kv"] = _cache_specs(cfg, B, S, family)
    if family == "vlm_kv":
        state["kv"] = _cache_specs(cfg, B, S, "kv")
        state["index"] = SDS((B,), jnp.int32)
        state["pos_off"] = SDS((B,), jnp.int32)
    return {"token": SDS((B,), jnp.int32), "state": state}
