"""deepseek-v2-236b — 60L d_model=5120 128H MLA (kv_lora=512, decoupled RoPE
64), MoE 2 shared + 160 routed top-6, d_ff_expert=1536, vocab 102400
[arXiv:2405.04434]."""

from repro.configs import common
from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        kind="mla_moe",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        head_dim=128,
        d_ff=12288,              # first-layer dense FFN dim (unused: all MoE)
        vocab=102400,
        n_experts=160,
        top_k=6,
        n_shared_experts=2,
        d_ff_expert=1536,
        kv_lora_rank=512,
        q_lora_rank=1536,
        rope_head_dim=64,
        fsdp=True,               # 236B total params: FSDP mandatory at pod scale
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b-smoke",
        kind="mla_moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab=256,
        n_experts=8,
        top_k=2,
        n_shared_experts=1,
        d_ff_expert=64,
        kv_lora_rank=32,
        q_lora_rank=48,
        rope_head_dim=8,
        capacity_factor=4.0,   # no token drops at smoke scale (exactness)
        param_dtype="float32",
        activation_dtype="float32",
        remat=False,
    )


def input_specs(shape: str, smoke: bool = False) -> dict:
    cfg = smoke_config() if smoke else full_config()
    step = common.SHAPE_DEFS[shape]["step"]
    if step == "train":
        return common.lm_train_specs(cfg, shape, smoke)
    if step == "prefill":
        return common.lm_prefill_specs(cfg, shape, smoke)
    return common.lm_decode_specs(cfg, shape, family="mla", smoke=smoke)
