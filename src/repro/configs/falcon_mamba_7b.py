"""falcon-mamba-7b — 64L d_model=4096 attention-free Mamba1, vocab 65024,
ssm_state=16 [arXiv:2410.05355]."""

from repro.configs import common
from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b",
        kind="mamba1",
        n_layers=64,
        d_model=4096,
        n_heads=1,            # unused (attention-free)
        d_ff=0,               # unused
        vocab=65024,
        ssm_state=16,
        d_conv=4,
        expand=2,
        tie_embeddings=True,
        fsdp=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b-smoke",
        kind="mamba1",
        n_layers=2,
        d_model=64,
        n_heads=1,
        d_ff=0,
        vocab=256,
        ssm_state=8,
        d_conv=4,
        expand=2,
        tie_embeddings=True,
        param_dtype="float32",
        activation_dtype="float32",
        remat=False,
    )


def input_specs(shape: str, smoke: bool = False) -> dict:
    cfg = smoke_config() if smoke else full_config()
    step = common.SHAPE_DEFS[shape]["step"]
    if step == "train":
        return common.lm_train_specs(cfg, shape, smoke)
    if step == "prefill":
        return common.lm_prefill_specs(cfg, shape, smoke)
    return common.lm_decode_specs(cfg, shape, family="mamba1", smoke=smoke)
