"""olmoe-1b-7b — 16L d_model=2048 16H (kv=16) MoE 64 experts top-8,
d_ff_expert=1024, vocab 50304 [arXiv:2409.02060]."""

from repro.configs import common
from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        kind="moe",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1024,
        vocab=50304,
        n_experts=64,
        top_k=8,
        d_ff_expert=1024,
        rope_theta=1e4,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b-smoke",
        kind="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=256,
        n_experts=8,
        top_k=2,
        d_ff_expert=128,
        capacity_factor=4.0,   # no token drops at smoke scale (exactness)
        param_dtype="float32",
        activation_dtype="float32",
        remat=False,
    )


def input_specs(shape: str, smoke: bool = False) -> dict:
    cfg = smoke_config() if smoke else full_config()
    step = common.SHAPE_DEFS[shape]["step"]
    if step == "train":
        return common.lm_train_specs(cfg, shape, smoke)
    if step == "prefill":
        return common.lm_prefill_specs(cfg, shape, smoke)
    return common.lm_decode_specs(cfg, shape, family="kv", smoke=smoke)
