"""qwen2.5-14b — 48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064,
QKV bias [hf:Qwen/Qwen2.5-14B]."""

from repro.configs import common
from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b",
        kind="dense",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=13824,
        vocab=152064,
        qkv_bias=True,
        rope_theta=1e6,
        fsdp=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b-smoke",
        kind="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=192,
        vocab=256,
        qkv_bias=True,
        param_dtype="float32",
        activation_dtype="float32",
        remat=False,
    )


def input_specs(shape: str, smoke: bool = False) -> dict:
    cfg = smoke_config() if smoke else full_config()
    step = common.SHAPE_DEFS[shape]["step"]
    if step == "train":
        return common.lm_train_specs(cfg, shape, smoke)
    if step == "prefill":
        return common.lm_prefill_specs(cfg, shape, smoke)
    return common.lm_decode_specs(cfg, shape, family="kv", smoke=smoke)
