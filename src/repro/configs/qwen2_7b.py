"""qwen2-7b — 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064,
QKV bias [arXiv:2407.10671]."""

from repro.configs import common
from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b",
        kind="dense",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        d_ff=18944,
        vocab=152064,
        qkv_bias=True,
        rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b-smoke",
        kind="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=192,
        vocab=256,
        qkv_bias=True,
        param_dtype="float32",
        activation_dtype="float32",
        remat=False,
    )


def input_specs(shape: str, smoke: bool = False) -> dict:
    cfg = smoke_config() if smoke else full_config()
    step = common.SHAPE_DEFS[shape]["step"]
    if step == "train":
        return common.lm_train_specs(cfg, shape, smoke)
    if step == "prefill":
        return common.lm_prefill_specs(cfg, shape, smoke)
    return common.lm_decode_specs(cfg, shape, family="kv", smoke=smoke)
