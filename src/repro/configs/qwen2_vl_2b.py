"""qwen2-vl-2b — 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936,
M-RoPE, vision frontend stubbed as precomputed patch embeddings
[arXiv:2409.12191].

Shape semantics: 1024 image patches (32x32 grid) + (seq_len - 1024) text
tokens for full-sequence steps; decode is text-only continuation.
"""

import jax
import jax.numpy as jnp

from repro.configs import common
from repro.models.config import ModelConfig

SDS = jax.ShapeDtypeStruct

N_PATCHES = 1024
GRID = (32, 32)


def full_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b",
        kind="vlm",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab=151936,
        qkv_bias=True,
        rope_theta=1e6,
        mrope=True,
        mrope_sections=(16, 24, 24),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b-smoke",
        kind="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        qkv_bias=True,
        mrope=True,
        mrope_sections=(4, 2, 2),
        param_dtype="float32",
        activation_dtype="float32",
        remat=False,
    )


def _split(shape: str, smoke: bool) -> tuple[int, int, int, tuple[int, int]]:
    d = common.SHAPE_DEFS[shape]
    if smoke:
        B, S = 2, 64
        n_patch, grid = 16, (4, 4)
    else:
        B, S = d["global_batch"], d["seq_len"]
        n_patch, grid = N_PATCHES, GRID
    return B, S, n_patch, grid


def input_specs(shape: str, smoke: bool = False) -> dict:
    cfg = smoke_config() if smoke else full_config()
    B, S, n_patch, grid = _split(shape, smoke)
    step = common.SHAPE_DEFS[shape]["step"]
    n_text = S - n_patch
    if step in ("train", "prefill"):
        specs = {
            "tokens": SDS((B, n_text), jnp.int32),
            "patch_embeds": SDS((B, n_patch, cfg.d_model), jnp.bfloat16),
            "positions_3d": SDS((B, S, 3), jnp.int32),
        }
        if step == "train":
            specs["labels"] = SDS((B, S), jnp.int32)
            specs["loss_mask"] = SDS((B, S), jnp.float32)
        return specs
    # decode
    L_ = cfg.n_layers
    kv = (L_, B, S, cfg.kv_heads, cfg.hd)
    return {
        "token": SDS((B,), jnp.int32),
        "state": {
            "kv": {"k": SDS(kv, jnp.bfloat16), "v": SDS(kv, jnp.bfloat16)},
            "index": SDS((B,), jnp.int32),
            "pos_off": SDS((B,), jnp.int32),
        },
    }
