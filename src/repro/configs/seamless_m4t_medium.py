"""seamless-m4t-medium — 12L enc + 12L dec, d_model=1024 16H d_ff=4096
vocab=256206, speech frontend stubbed as precomputed frame embeddings
[arXiv:2308.11596].

Shape semantics: source length = seq_len // 4 (fbank frames after the
conformer downsampler the stub replaces), target length = seq_len.
"""

import jax
import jax.numpy as jnp

from repro.configs import common
from repro.models.config import ModelConfig

SDS = jax.ShapeDtypeStruct


def full_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium",
        kind="encdec",
        n_layers=12,
        n_encoder_layers=12,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=4096,
        vocab=256206,
        gated_mlp=False,   # conformer/NLLB-style plain FFN
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium-smoke",
        kind="encdec",
        n_layers=2,
        n_encoder_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=256,
        param_dtype="float32",
        activation_dtype="float32",
        remat=False,
    )


def _src_len(seq_len: int) -> int:
    return max(seq_len // 4, 8)


def input_specs(shape: str, smoke: bool = False) -> dict:
    cfg = smoke_config() if smoke else full_config()
    d = common.SHAPE_DEFS[shape]
    B, S = (2, min(d["seq_len"], 64)) if smoke else (d["global_batch"],
                                                     d["seq_len"])
    T = _src_len(S)
    if d["step"] == "train":
        return {
            "src_embeds": SDS((B, T, cfg.d_model), jnp.bfloat16),
            "tokens": SDS((B, S), jnp.int32),
            "labels": SDS((B, S), jnp.int32),
        }
    if d["step"] == "prefill":
        return {
            "src_embeds": SDS((B, T, cfg.d_model), jnp.bfloat16),
            "tokens": SDS((B, S), jnp.int32),
        }
    # decode: one token against self-attn cache of S; the per-layer
    # cross-KV (xk/xv) lives in the same cache dict, sized to max source
    # length, with per-row src_len masking the valid rows
    L_ = cfg.n_layers
    kv = (L_, B, S, cfg.kv_heads, cfg.hd)
    cross = (L_, B, T, cfg.kv_heads, cfg.hd)
    return {
        "token": SDS((B,), jnp.int32),
        "state": {
            "kv": {"k": SDS(kv, jnp.bfloat16), "v": SDS(kv, jnp.bfloat16),
                   "xk": SDS(cross, jnp.bfloat16),
                   "xv": SDS(cross, jnp.bfloat16)},
            "src_len": SDS((B,), jnp.int32),
            "index": SDS((B,), jnp.int32),
        },
    }
