"""starcoder2-3b — 30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152,
RoPE [arXiv:2402.19173]."""

from repro.configs import common
from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b",
        kind="dense",
        n_layers=30,
        d_model=3072,
        n_heads=24,
        n_kv_heads=2,
        d_ff=12288,
        vocab=49152,
        rope_theta=1e5,
        gated_mlp=False,   # starcoder2 uses a plain 2-matrix GELU FFN
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b-smoke",
        kind="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=192,
        vocab=256,
        gated_mlp=False,
        param_dtype="float32",
        activation_dtype="float32",
        remat=False,
    )


def input_specs(shape: str, smoke: bool = False) -> dict:
    cfg = smoke_config() if smoke else full_config()
    step = common.SHAPE_DEFS[shape]["step"]
    if step == "train":
        return common.lm_train_specs(cfg, shape, smoke)
    if step == "prefill":
        return common.lm_prefill_specs(cfg, shape, smoke)
    return common.lm_decode_specs(cfg, shape, family="kv", smoke=smoke)
