"""zamba2-2.7b — 54L Mamba2 backbone (d_model=2560, ssm_state=64) + shared
attention blocks (32H, d_ff=10240) every 6 layers, vocab 32000
[arXiv:2411.15242]."""

from repro.configs import common
from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        kind="hybrid",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_ff=10240,
        vocab=32000,
        ssm_state=64,
        ssm_headdim=64,
        ssm_ngroups=1,
        d_conv=4,
        expand=2,
        attn_every=6,
        tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b-smoke",
        kind="hybrid",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=256,
        ssm_state=16,
        ssm_headdim=16,
        ssm_ngroups=1,
        d_conv=4,
        expand=2,
        attn_every=2,
        param_dtype="float32",
        activation_dtype="float32",
        remat=False,
    )


def input_specs(shape: str, smoke: bool = False) -> dict:
    cfg = smoke_config() if smoke else full_config()
    step = common.SHAPE_DEFS[shape]["step"]
    if step == "train":
        return common.lm_train_specs(cfg, shape, smoke)
    if step == "prefill":
        return common.lm_prefill_specs(cfg, shape, smoke)
    return common.lm_decode_specs(cfg, shape, family="hybrid", smoke=smoke)
