"""Predictor-guided GEMM block-config autotuner — the paper's payoff.

For a GEMM shape (m, n, k, dtype), enumerate VMEM-valid Pallas block configs,
rank them with the trained multi-output predictor (one batched model call),
verify the top-k against the measurement substrate, and cache the winner.
Objectives mirror the paper's findings: "runtime" (3.2x speedup claim),
"energy"/"power" (22% power-reduction claim), "edp" (energy-delay product).

Everything is chip-aware: the tuner's candidate filter, feature builder, and
verification all run against the chip backing its simulator, and predictor
artifacts plus tuner caches are keyed per chip so "tpu_v5e" and "rtx4070"
tuners coexist. Candidate validity and top-k verification go through the
batched substrate (`analyze_batch` / `measure_batch`) — no per-config
measurement loop.

`get_tuner(chip=...)` is the per-chip process-wide singleton consulted by
`kernels.ops.matmul` at trace time. On first use it loads (or trains and
persists) the predictor artifact under artifacts/.
"""

from __future__ import annotations

import json
import math
import os
import threading

import numpy as np

from repro.core.chips import TPU_V5E, ChipSpec, get_chip
from repro.core.features import table_from_configs
from repro.core.hwsim import GemmConfig, TpuGemmSimulator
from repro.core.predictor import PerfPredictor
from repro.kernels.tiled_matmul import BlockConfig

_BM = (8, 16, 32, 64, 128, 256, 512, 1024)
_BN = (128, 256, 512, 1024)
_BK = (128, 256, 512, 1024, 2048)

DEFAULT_ARTIFACTS_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))), "artifacts")
BASELINE = BlockConfig(128, 128, 128)  # untuned default (paper's baseline)


def _roundup(x: int, q: int) -> int:
    return max(q, math.ceil(x / q) * q)


class GemmAutotuner:
    def __init__(
        self,
        predictor: PerfPredictor,
        sim: TpuGemmSimulator | None = None,
        verify_top_k: int = 3,
        cache_path: str | None = None,
        chip: ChipSpec | str | None = None,
    ):
        self.predictor = predictor
        self.sim = sim or TpuGemmSimulator(
            chip=chip if chip is not None else TPU_V5E, seed=0)
        self.chip = self.sim.chip
        self.verify_top_k = verify_top_k
        self.cache_path = cache_path
        self._cache: dict[str, tuple[int, int, int]] = {}
        self._lock = threading.Lock()
        if cache_path and os.path.exists(cache_path):
            with open(cache_path) as f:
                self._cache = {k: tuple(v) for k, v in json.load(f).items()}

    # ---------- candidates ----------
    def candidate_configs(self, m: int, n: int, k: int,
                          dtype: str = "bf16") -> list[GemmConfig]:
        """VMEM-valid blocks, clipped to the (padded) problem extents."""
        bm_cap = _roundup(m, 8)
        bn_cap = _roundup(n, 128)
        bk_cap = _roundup(k, 128)
        cand = [
            GemmConfig(m=m, n=n, k=k, block_m=bm, block_n=bn, block_k=bk,
                       dtype=dtype)
            for bm in _BM if bm <= bm_cap * 2
            for bn in _BN if bn <= bn_cap * 2
            for bk in _BK if bk <= bk_cap * 2
        ]
        if not cand:
            return []
        valid = self.sim.analyze_batch(cand)["valid"]
        return [cfg for cfg, ok in zip(cand, valid) if ok]

    # ---------- scoring ----------
    @staticmethod
    def _objective_scores(pred: dict[str, np.ndarray], objective: str
                          ) -> np.ndarray:
        if objective == "runtime":
            return pred["runtime_ms"]
        if objective in ("energy", "power"):
            return pred["energy_j"] if objective == "energy" else pred["power_w"]
        if objective == "edp":
            return pred["energy_j"] * pred["runtime_ms"]
        raise ValueError(f"unknown objective {objective!r}")

    def rank(self, cfgs: list[GemmConfig], objective: str = "runtime"
             ) -> np.ndarray:
        table = table_from_configs(cfgs, chip=self.chip)
        pred = self.predictor.predict(table)
        return np.argsort(self._objective_scores(pred, objective))

    # ---------- tuning ----------
    def best_config(self, m: int, n: int, k: int, *, dtype: str = "bf16",
                    objective: str = "runtime") -> BlockConfig:
        key = f"{m},{n},{k},{dtype},{objective}"
        with self._lock:
            if key in self._cache:
                return BlockConfig(*self._cache[key])
        cfgs = self.candidate_configs(m, n, k, dtype)
        if not cfgs:
            return BASELINE
        order = self.rank(cfgs, objective)
        top = [cfgs[i] for i in order[: self.verify_top_k]]
        # verify against the measurement substrate (wall clock on real HW)
        tel = self.sim.measure_batch(top)
        scores = self._objective_scores(
            {t: tel[t] for t in ("runtime_ms", "power_w", "energy_j")},
            objective)
        winner = top[int(np.argmin(scores))]
        best = (winner.block_m, winner.block_n, winner.block_k)
        with self._lock:
            self._cache[key] = best
            if self.cache_path:
                os.makedirs(os.path.dirname(self.cache_path) or ".",
                            exist_ok=True)
                with open(self.cache_path, "w") as f:
                    json.dump(self._cache, f, indent=0)
        return BlockConfig(*best)

    def tune_report(self, m: int, n: int, k: int, *, dtype: str = "bf16",
                    objective: str = "runtime") -> dict:
        """Tuned-vs-baseline gains (the paper's 3.2x / 22% claims)."""
        best = self.best_config(m, n, k, dtype=dtype, objective=objective)
        base_cfg = GemmConfig(m=m, n=n, k=k, block_m=BASELINE.block_m,
                              block_n=BASELINE.block_n,
                              block_k=BASELINE.block_k, dtype=dtype)
        best_cfg = GemmConfig(m=m, n=n, k=k, block_m=best.block_m,
                              block_n=best.block_n, block_k=best.block_k,
                              dtype=dtype)
        tb = self.sim.analyze(base_cfg)
        tt = self.sim.analyze(best_cfg)
        return {
            "m": m, "n": n, "k": k, "dtype": dtype, "objective": objective,
            "chip": self.chip.name,
            "baseline": BASELINE.as_tuple(),
            "best": best.as_tuple(),
            "baseline_runtime_ms": tb.runtime_ms,
            "tuned_runtime_ms": tt.runtime_ms,
            "speedup": tb.runtime_ms / tt.runtime_ms,
            "baseline_power_w": tb.power_w,
            "tuned_power_w": tt.power_w,
            "power_reduction_pct": 100.0 * (1 - tt.power_w / tb.power_w),
            "baseline_energy_j": tb.energy_j,
            "tuned_energy_j": tt.energy_j,
            "energy_reduction_pct": 100.0 * (1 - tt.energy_j / tb.energy_j),
        }


# ---------- process-wide per-chip tuners ----------
_GLOBAL: dict[str, GemmAutotuner] = {}
_GLOBAL_LOCK = threading.Lock()


def build_default_predictor(artifacts_dir: str = DEFAULT_ARTIFACTS_DIR,
                            n_train: int = 4000,
                            force_retrain: bool = False,
                            chip: ChipSpec | str = TPU_V5E) -> PerfPredictor:
    """Load the persisted per-chip predictor or train one on a fresh sweep."""
    chip = get_chip(chip)
    os.makedirs(artifacts_dir, exist_ok=True)
    path = os.path.join(artifacts_dir, f"perf_predictor_{chip.name}.pkl")
    if os.path.exists(path) and not force_retrain:
        try:
            return PerfPredictor.load(path)
        except Exception:
            pass
    from repro.core.profiler import collect_dataset

    table = collect_dataset(n_configs=n_train, seed=0, chip=chip)
    pred = PerfPredictor(model="rf", residual=True, fast=True,
                         chip=chip.name).fit(table)
    pred.save(path)
    return pred


def get_tuner(artifacts_dir: str = DEFAULT_ARTIFACTS_DIR,
              chip: ChipSpec | str = TPU_V5E) -> GemmAutotuner:
    chip = get_chip(chip)
    with _GLOBAL_LOCK:
        tuner = _GLOBAL.get(chip.name)
        if tuner is None:
            predictor = build_default_predictor(artifacts_dir, chip=chip)
            tuner = GemmAutotuner(
                predictor,
                chip=chip,
                cache_path=os.path.join(
                    artifacts_dir, f"tuner_cache_{chip.name}.json"),
            )
            _GLOBAL[chip.name] = tuner
        return tuner


def set_tuner(tuner: GemmAutotuner | None,
              chip: ChipSpec | str | None = None) -> None:
    """Install (or clear, with tuner=None and chip=None) global tuners."""
    global _GLOBAL
    with _GLOBAL_LOCK:
        if tuner is None and chip is None:
            _GLOBAL = {}
        elif tuner is None:
            _GLOBAL.pop(get_chip(chip).name, None)
        else:
            _GLOBAL[get_chip(chip).name if chip is not None
                    else tuner.chip.name] = tuner
