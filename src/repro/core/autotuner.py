"""Predictor-guided GEMM block-config autotuner — the paper's payoff.

For a GEMM shape (m, n, k, dtype), enumerate VMEM-valid Pallas block configs,
rank them with the trained multi-output predictor (one batched model call),
verify the top-k against the measurement substrate, and cache the winner.
Objectives mirror the paper's findings: "runtime" (3.2x speedup claim),
"energy"/"power" (22% power-reduction claim), "edp" (energy-delay product).

Prediction is the serving hot path, so `rank()` runs through a compiled
scorer: forest predictors score via the cached x64 jit path (bit-identical
branches vs numpy, one XLA call for the whole candidate grid), and the
candidate list + feature table for each (shape, dtype) bucket is computed
once and cached. `tune_many()` tunes a whole fleet of shapes with one scorer
call and one batched verification sweep. The winner cache (in memory and the
JSON sidecar) is keyed by the predictor's artifact fingerprint, so
retraining invalidates stale winners automatically.

Everything is chip-aware: the tuner's candidate filter, feature builder, and
verification all run against the chip backing its simulator, and predictor
artifacts plus tuner caches are keyed per chip so "tpu_v5e" and "rtx4070"
tuners coexist.

`get_tuner(chip=...)` is the per-chip process-wide singleton consulted by
`kernels.ops.matmul` at trace time. On first use it loads (or trains and
persists) the predictor artifact under artifacts/.
"""

from __future__ import annotations

import json
import math
import os
import threading
from collections import OrderedDict
from collections.abc import Sequence

import numpy as np

from repro.core.chips import TPU_V5E, ChipSpec, canon_dtype, get_chip
from repro.core.features import features_matrix
from repro.core.hwsim import GemmConfig, TpuGemmSimulator
from repro.core.predictor import ArtifactError, PerfPredictor
from repro.kernels.tiled_matmul import BlockConfig

_BM = (8, 16, 32, 64, 128, 256, 512, 1024)
_BN = (128, 256, 512, 1024)
_BK = (128, 256, 512, 1024, 2048)

DEFAULT_ARTIFACTS_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))), "artifacts")
BASELINE = BlockConfig(128, 128, 128)  # untuned default (paper's baseline)

_CACHE_FILE_VERSION = 1


def _roundup(x: int, q: int) -> int:
    return max(q, math.ceil(x / q) * q)


def _next_pow2(n: int) -> int:
    return 1 << max(3, (n - 1).bit_length())


class GemmAutotuner:
    def __init__(
        self,
        predictor: PerfPredictor,
        sim: TpuGemmSimulator | None = None,
        verify_top_k: int = 3,
        cache_path: str | None = None,
        chip: ChipSpec | str | None = None,
        candidate_cache_size: int = 512,
        scorer: str = "auto",
    ):
        """`scorer` selects the batched prediction path for `rank`:
        "jit" (the cached x64 jax_predictor — one XLA call per candidate
        grid), "numpy" (the vectorized stacked-descent estimator), or
        "auto" (jit on accelerator backends; numpy on CPU, where per-call
        XLA dispatch overhead exceeds the descent itself at candidate-grid
        sizes). Both paths predict within 1e-9 relative of each other.
        """
        self.predictor = predictor
        self.sim = sim or TpuGemmSimulator(
            chip=chip if chip is not None else TPU_V5E, seed=0)
        self.chip = self.sim.chip
        self.verify_top_k = verify_top_k
        self.cache_path = cache_path
        if scorer not in ("auto", "jit", "numpy"):
            raise ValueError(f"unknown scorer {scorer!r}")
        self.scorer = scorer
        self.artifact_fingerprint = predictor.fingerprint()
        self._cache: dict[str, tuple[int, int, int]] = {}
        # (m, n, k, dtype) -> (candidate configs, feature table) — one bucket
        # per GEMM-call signature on this tuner's (chip, dtype) grid.
        self._cand_cache: OrderedDict[
            tuple[int, int, int, str], tuple[list[GemmConfig], np.ndarray]
        ] = OrderedDict()
        self._cand_cache_size = candidate_cache_size
        self._lock = threading.Lock()
        if cache_path and os.path.exists(cache_path):
            self._cache = self._load_cache_file(cache_path)

    # ---------- winner cache (fingerprint-versioned) ----------
    def _load_cache_file(self, path: str) -> dict[str, tuple[int, int, int]]:
        """Read the winner sidecar; discard it when it predates the current
        artifact (or the pre-versioned flat format)."""
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, ValueError):
            return {}
        if (not isinstance(payload, dict)
                or payload.get("cache_version") != _CACHE_FILE_VERSION
                or payload.get("artifact_fingerprint")
                != self.artifact_fingerprint):
            return {}
        return {k: tuple(v) for k, v in payload.get("entries", {}).items()}

    def _write_cache_locked(self) -> None:
        """Persist the winner cache (caller holds self._lock)."""
        if not self.cache_path:
            return
        os.makedirs(os.path.dirname(self.cache_path) or ".", exist_ok=True)
        with open(self.cache_path, "w") as f:
            json.dump({
                "cache_version": _CACHE_FILE_VERSION,
                "artifact_fingerprint": self.artifact_fingerprint,
                "chip": self.chip.name,
                "entries": self._cache,
            }, f, indent=0)

    # ---------- candidates ----------
    def candidate_configs(self, m: int, n: int, k: int,
                          dtype: str = "bf16") -> list[GemmConfig]:
        """VMEM-valid blocks, clipped to the (padded) problem extents."""
        dtype = canon_dtype(dtype)
        bm_cap = _roundup(m, 8)
        bn_cap = _roundup(n, 128)
        bk_cap = _roundup(k, 128)
        cand = [
            GemmConfig(m=m, n=n, k=k, block_m=bm, block_n=bn, block_k=bk,
                       dtype=dtype)
            for bm in _BM if bm <= bm_cap * 2
            for bn in _BN if bn <= bn_cap * 2
            for bk in _BK if bk <= bk_cap * 2
        ]
        if not cand:
            return []
        valid = self.sim.analyze_batch(cand)["valid"]
        return [cfg for cfg, ok in zip(cand, valid) if ok]

    def candidate_table(self, m: int, n: int, k: int, dtype: str
                             ) -> tuple[list[GemmConfig], np.ndarray]:
        """Candidate list + precomputed feature table for one shape bucket
        (LRU-cached: the grid is static per (chip, dtype), so repeat calls
        — cache misses after retraining, other objectives — skip both the
        validity filter and feature building)."""
        dtype = canon_dtype(dtype)
        key = (m, n, k, dtype)
        with self._lock:
            hit = self._cand_cache.get(key)
            if hit is not None:
                self._cand_cache.move_to_end(key)
                return hit
        cfgs = self.candidate_configs(m, n, k, dtype)
        X = (features_matrix(cfgs, chip=self.chip) if cfgs
             else np.zeros((0, len(self.predictor.feature_names))))
        with self._lock:
            self._cand_cache[key] = (cfgs, X)
            self._cand_cache.move_to_end(key)
            while len(self._cand_cache) > self._cand_cache_size:
                self._cand_cache.popitem(last=False)
        return cfgs, X

    # ---------- scoring ----------
    @staticmethod
    def _objective_scores(pred: dict[str, np.ndarray], objective: str
                          ) -> np.ndarray:
        if objective == "runtime":
            return pred["runtime_ms"]
        if objective in ("energy", "power"):
            return pred["energy_j"] if objective == "energy" else pred["power_w"]
        if objective == "edp":
            return pred["energy_j"] * pred["runtime_ms"]
        raise ValueError(f"unknown objective {objective!r}")

    def _use_jit_scorer(self) -> bool:
        if not self.predictor.supports_jax():
            return False
        if self.scorer != "auto":
            return self.scorer == "jit"
        import jax

        return jax.default_backend() != "cpu"

    def _predict_features(self, X: np.ndarray) -> np.ndarray:
        """(N, F) raw features -> (N, T) predictions via the compiled x64
        scorer (forest models on accelerators) or the vectorized
        stacked-descent estimator — see the `scorer` constructor arg.

        The jit path pads the batch to the next power of two so XLA
        compiles one kernel per size bucket instead of one per candidate
        count."""
        if self._use_jit_scorer():
            fn = self.predictor.jax_predictor(x64=True)
            n = len(X)
            pad = _next_pow2(n)
            if pad != n:
                X = np.concatenate([X, np.tile(X[-1:], (pad - n, 1))])
            return np.asarray(fn(X))[:n]
        table = {name: X[:, i]
                 for i, name in enumerate(self.predictor.feature_names)}
        return self.predictor.predict_matrix(table)

    def _scores_from_matrix(self, Y: np.ndarray, objective: str) -> np.ndarray:
        idx = {t: i for i, t in enumerate(self.predictor.target_names)}
        pred = {t: Y[:, i] for t, i in idx.items()}
        return self._objective_scores(pred, objective)

    def rank(self, cfgs: Sequence[GemmConfig], objective: str = "runtime",
             features: np.ndarray | None = None) -> np.ndarray:
        """Ascending-score candidate order from one batched scorer call."""
        X = (features if features is not None
             else features_matrix(cfgs, chip=self.chip))
        Y = self._predict_features(X)
        return np.argsort(self._scores_from_matrix(Y, objective))

    # ---------- tuning ----------
    @staticmethod
    def _key(m: int, n: int, k: int, dtype: str, objective: str) -> str:
        return f"{m},{n},{k},{dtype},{objective}"

    def best_config(self, m: int, n: int, k: int, *, dtype: str = "bf16",
                    objective: str = "runtime") -> BlockConfig:
        return self.tune_many([(m, n, k)], dtype=dtype,
                              objective=objective)[0]

    def tune_many(self, shapes: Sequence[tuple[int, int, int]], *,
                  dtype: str = "bf16", objective: str = "runtime"
                  ) -> list[BlockConfig]:
        """Tune a fleet of (m, n, k) shapes in one pass: all uncached
        shapes share one batched scorer call and one batched top-k
        verification sweep, then land in the winner cache together."""
        dtype = canon_dtype(dtype)
        out: list[BlockConfig | None] = [None] * len(shapes)
        todo: list[int] = []
        with self._lock:
            for i, (m, n, k) in enumerate(shapes):
                hit = self._cache.get(self._key(m, n, k, dtype, objective))
                if hit is not None:
                    out[i] = BlockConfig(*hit)
                else:
                    todo.append(i)
        if not todo:
            return out  # type: ignore[return-value]

        # candidate gather (per-shape buckets, cached)
        groups: list[tuple[int, list[GemmConfig], np.ndarray]] = []
        for i in todo:
            m, n, k = shapes[i]
            cfgs, X = self.candidate_table(m, n, k, dtype)
            if not cfgs:
                # cache the BASELINE fallback too — an empty candidate list
                # is deterministic for the bucket, so never re-enumerate.
                out[i] = BASELINE
            else:
                groups.append((i, cfgs, X))

        winners: dict[int, tuple[int, int, int]] = {}
        if groups:
            # one compiled scorer call over every candidate of every shape
            scores = self._scores_from_matrix(
                self._predict_features(np.concatenate([X for _, _, X in groups])),
                objective)
            tops: list[list[GemmConfig]] = []
            off = 0
            for _, cfgs, _X in groups:
                order = np.argsort(scores[off:off + len(cfgs)])
                tops.append([cfgs[j] for j in order[:self.verify_top_k]])
                off += len(cfgs)
            # one batched verification sweep across all shapes
            flat = [c for top in tops for c in top]
            tel = self.sim.measure_batch(flat)
            meas = self._objective_scores(
                {t: tel[t] for t in ("runtime_ms", "power_w", "energy_j")},
                objective)
            off = 0
            for (i, _, _), top in zip(groups, tops):
                s = meas[off:off + len(top)]
                w = top[int(np.argmin(s))]
                winners[i] = (w.block_m, w.block_n, w.block_k)
                out[i] = BlockConfig(*winners[i])
                off += len(top)

        with self._lock:
            for i in todo:
                m, n, k = shapes[i]
                best = winners.get(i)
                if best is None:  # BASELINE fallback
                    best = (BASELINE.block_m, BASELINE.block_n,
                            BASELINE.block_k)
                self._cache[self._key(m, n, k, dtype, objective)] = best
            self._write_cache_locked()
        return out  # type: ignore[return-value]

    def tune_report(self, m: int, n: int, k: int, *, dtype: str = "bf16",
                    objective: str = "runtime") -> dict:
        """Tuned-vs-baseline gains (the paper's 3.2x / 22% claims)."""
        dtype = canon_dtype(dtype)
        best = self.best_config(m, n, k, dtype=dtype, objective=objective)
        base_cfg = GemmConfig(m=m, n=n, k=k, block_m=BASELINE.block_m,
                              block_n=BASELINE.block_n,
                              block_k=BASELINE.block_k, dtype=dtype)
        best_cfg = GemmConfig(m=m, n=n, k=k, block_m=best.block_m,
                              block_n=best.block_n, block_k=best.block_k,
                              dtype=dtype)
        tb = self.sim.analyze(base_cfg)
        tt = self.sim.analyze(best_cfg)
        return {
            "m": m, "n": n, "k": k, "dtype": dtype, "objective": objective,
            "chip": self.chip.name,
            "artifact_fingerprint": self.artifact_fingerprint,
            "baseline": BASELINE.as_tuple(),
            "best": best.as_tuple(),
            "baseline_runtime_ms": tb.runtime_ms,
            "tuned_runtime_ms": tt.runtime_ms,
            "speedup": tb.runtime_ms / tt.runtime_ms,
            "baseline_power_w": tb.power_w,
            "tuned_power_w": tt.power_w,
            "power_reduction_pct": 100.0 * (1 - tt.power_w / tb.power_w),
            "baseline_energy_j": tb.energy_j,
            "tuned_energy_j": tt.energy_j,
            "energy_reduction_pct": 100.0 * (1 - tt.energy_j / tb.energy_j),
        }


# ---------- process-wide per-chip tuners ----------
_GLOBAL: dict[str, GemmAutotuner] = {}
_GLOBAL_LOCK = threading.Lock()


def build_default_predictor(artifacts_dir: str = DEFAULT_ARTIFACTS_DIR,
                            n_train: int = 4000,
                            force_retrain: bool = False,
                            chip: ChipSpec | str = TPU_V5E) -> PerfPredictor:
    """Load the persisted per-chip predictor artifact or train one on a
    fresh sweep. Invalid/legacy/tampered artifacts trigger a retrain."""
    chip = get_chip(chip)
    os.makedirs(artifacts_dir, exist_ok=True)
    path = os.path.join(artifacts_dir, f"perf_predictor_{chip.name}.npz")
    if os.path.exists(path) and not force_retrain:
        try:
            return PerfPredictor.load(path)
        except ArtifactError:
            pass
    from repro.core.profiler import collect_dataset

    table = collect_dataset(n_configs=n_train, seed=0, chip=chip)
    pred = PerfPredictor(model="rf", residual=True, fast=True,
                         chip=chip.name).fit(table)
    pred.save(path)
    return pred


def get_tuner(artifacts_dir: str = DEFAULT_ARTIFACTS_DIR,
              chip: ChipSpec | str = TPU_V5E) -> GemmAutotuner:
    chip = get_chip(chip)
    with _GLOBAL_LOCK:
        tuner = _GLOBAL.get(chip.name)
        if tuner is None:
            predictor = build_default_predictor(artifacts_dir, chip=chip)
            tuner = GemmAutotuner(
                predictor,
                chip=chip,
                cache_path=os.path.join(
                    artifacts_dir, f"tuner_cache_{chip.name}.json"),
            )
            _GLOBAL[chip.name] = tuner
        return tuner


def set_tuner(tuner: GemmAutotuner | None,
              chip: ChipSpec | str | None = None) -> None:
    """Install (or clear, with tuner=None and chip=None) global tuners."""
    global _GLOBAL
    with _GLOBAL_LOCK:
        if tuner is None and chip is None:
            _GLOBAL = {}
        elif tuner is None:
            _GLOBAL.pop(get_chip(chip).name, None)
        else:
            _GLOBAL[get_chip(chip).name if chip is not None
                    else tuner.chip.name] = tuner
