"""Predictor-guided GEMM block-config autotuner — the paper's payoff.

For a GEMM shape (m, n, k, dtype), enumerate VMEM-valid Pallas block configs,
rank them with the trained multi-output predictor (one batched model call),
verify the top-k against the measurement substrate, and cache the winner.
Objectives mirror the paper's findings: "runtime" (3.2x speedup claim),
"energy"/"power" (22% power-reduction claim), "edp" (energy-delay product).

Prediction is the serving hot path, so `rank()` runs through a compiled
scorer: every estimator family in the zoo (forest, GBDT, linreg/ridge,
stacking) scores via the cached x64 jit path (bit-identical accumulations
vs numpy, one XLA call for the whole candidate grid), and the candidate
list + feature table for each (shape, dtype) bucket is computed once and
cached. `rank_in_graph()` goes further: the candidate feature grid is built
with jnp ops and argmin'd *inside* `jax.jit`, with the GEMM extents as
traced values — zero Python in the ranking loop and no retrace per shape —
which `tune_many()` uses by default on accelerator backends. `tune_many()`
tunes a whole fleet of shapes with one scorer call and one batched
verification sweep (optionally through a wall-clock `measure_fn` for
on-device tuning). The winner cache (in memory and the JSON sidecar) is
keyed by the predictor's artifact fingerprint and LRU-bounded, so
retraining invalidates stale winners and long-lived processes can't grow
the sidecar without limit.

Everything is chip-aware: the tuner's candidate filter, feature builder, and
verification all run against the chip backing its simulator, and predictor
artifacts plus tuner caches are keyed per chip so "tpu_v5e" and "rtx4070"
tuners coexist.

`get_tuner(chip=...)` is the per-chip process-wide singleton consulted by
`kernels.ops.matmul` at trace time. On first use it loads (or trains and
persists) the predictor artifact under artifacts/.
"""

from __future__ import annotations

import json
import math
import os
import threading
from collections import OrderedDict
from collections.abc import Sequence

import numpy as np

from repro.core.chips import DTYPE_BYTES, TPU_V5E, ChipSpec, canon_dtype, get_chip
from repro.core.features import features_matrix, graph_candidate_features
from repro.core.hwsim import VMEM_USABLE_FRACTION, GemmConfig, TpuGemmSimulator
from repro.core.mlperf.compiled import precision_scope
from repro.core.predictor import ArtifactError, PerfPredictor
from repro.kernels.tiled_matmul import BlockConfig

_BM = (8, 16, 32, 64, 128, 256, 512, 1024)
_BN = (128, 256, 512, 1024)
_BK = (128, 256, 512, 1024, 2048)

DEFAULT_ARTIFACTS_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))), "artifacts")
BASELINE = BlockConfig(128, 128, 128)  # untuned default (paper's baseline)

_CACHE_FILE_VERSION = 1


def baseline_configs(shapes) -> dict[tuple, BlockConfig]:
    """Map every (m, n, k) shape to the paper's BASELINE block config —
    the degraded-mode tuning table the serving engine installs when a
    predictor artifact is corrupt (`ServingEngine.retune`): pricing and
    scheduling keep working on the untuned baseline instead of raising
    mid-serve, and the fallback is explicit in reports rather than an
    absent-config default."""
    return {tuple(int(x) for x in s): BASELINE for s in shapes}


def _roundup(x: int, q: int) -> int:
    return max(q, math.ceil(x / q) * q)


def _next_pow2(n: int) -> int:
    return 1 << max(3, (n - 1).bit_length())


class GemmAutotuner:
    def __init__(
        self,
        predictor: PerfPredictor,
        sim: TpuGemmSimulator | None = None,
        verify_top_k: int = 3,
        cache_path: str | None = None,
        chip: ChipSpec | str | None = None,
        candidate_cache_size: int = 512,
        scorer: str = "auto",
        winner_cache_size: int = 4096,
    ):
        """`scorer` selects the batched prediction path for `rank`:
        "jit" (the cached x64 jax_predictor — one XLA call per candidate
        grid), "numpy" (the vectorized stacked-descent estimator), or
        "auto" (jit on accelerator backends; numpy on CPU, where per-call
        XLA dispatch overhead exceeds the descent itself at candidate-grid
        sizes). Both paths predict within 1e-9 relative of each other.

        `winner_cache_size` bounds the tuned-winner cache (memory + JSON
        sidecar) with LRU eviction, mirroring the candidate-table cache,
        so a long-lived serving process sweeping many shapes can't grow
        the sidecar unboundedly.
        """
        self.predictor = predictor
        self.sim = sim or TpuGemmSimulator(
            chip=chip if chip is not None else TPU_V5E, seed=0)
        self.chip = self.sim.chip
        self.verify_top_k = verify_top_k
        self.cache_path = cache_path
        if scorer not in ("auto", "jit", "numpy"):
            raise ValueError(f"unknown scorer {scorer!r}")
        self.scorer = scorer
        self.artifact_fingerprint = predictor.fingerprint()
        self._winner_cache_size = winner_cache_size
        self._cache: OrderedDict[str, tuple[int, int, int]] = OrderedDict()
        # in-graph ranking state: static candidate block grid, jitted
        # rankers keyed by (objective, x64, k), device-resident predictor
        # params per precision, and a trace counter (tests assert no
        # retrace across shape fleets).
        self._graph_block_grid: np.ndarray | None = None
        self._graph_fns: dict = {}
        self._graph_params: dict = {}
        self.graph_traces = 0
        # (m, n, k, dtype) -> (candidate configs, feature table) — one bucket
        # per GEMM-call signature on this tuner's (chip, dtype) grid.
        self._cand_cache: OrderedDict[
            tuple[int, int, int, str], tuple[list[GemmConfig], np.ndarray]
        ] = OrderedDict()
        self._cand_cache_size = candidate_cache_size
        self._lock = threading.Lock()
        if cache_path and os.path.exists(cache_path):
            self._cache = self._load_cache_file(cache_path)

    # ---------- winner cache (fingerprint-versioned, LRU-bounded) ----------
    def _load_cache_file(self, path: str
                         ) -> "OrderedDict[str, tuple[int, int, int]]":
        """Read the winner sidecar; discard it when it predates the current
        artifact (or the pre-versioned flat format). Entries keep their
        file order (oldest first) and are trimmed to the LRU bound."""
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, ValueError):
            return OrderedDict()
        if (not isinstance(payload, dict)
                or payload.get("cache_version") != _CACHE_FILE_VERSION
                or payload.get("artifact_fingerprint")
                != self.artifact_fingerprint):
            return OrderedDict()
        entries = OrderedDict(
            (k, tuple(v)) for k, v in payload.get("entries", {}).items())
        while len(entries) > self._winner_cache_size:
            entries.popitem(last=False)
        return entries

    def _cache_get(self, key: str) -> tuple[int, int, int] | None:
        """LRU lookup (caller holds self._lock)."""
        hit = self._cache.get(key)
        if hit is not None:
            self._cache.move_to_end(key)
        return hit

    def _cache_put(self, key: str, val: tuple[int, int, int]) -> None:
        """LRU insert + eviction (caller holds self._lock)."""
        self._cache[key] = val
        self._cache.move_to_end(key)
        while len(self._cache) > self._winner_cache_size:
            self._cache.popitem(last=False)

    def _write_cache_locked(self) -> None:
        """Persist the winner cache (caller holds self._lock)."""
        if not self.cache_path:
            return
        os.makedirs(os.path.dirname(self.cache_path) or ".", exist_ok=True)
        with open(self.cache_path, "w") as f:
            json.dump({
                "cache_version": _CACHE_FILE_VERSION,
                "artifact_fingerprint": self.artifact_fingerprint,
                "chip": self.chip.name,
                "entries": self._cache,
            }, f, indent=0)

    # ---------- candidates ----------
    def candidate_configs(self, m: int, n: int, k: int,
                          dtype: str = "bf16") -> list[GemmConfig]:
        """VMEM-valid blocks, clipped to the (padded) problem extents."""
        dtype = canon_dtype(dtype)
        bm_cap = _roundup(m, 8)
        bn_cap = _roundup(n, 128)
        bk_cap = _roundup(k, 128)
        cand = [
            GemmConfig(m=m, n=n, k=k, block_m=bm, block_n=bn, block_k=bk,
                       dtype=dtype)
            for bm in _BM if bm <= bm_cap * 2
            for bn in _BN if bn <= bn_cap * 2
            for bk in _BK if bk <= bk_cap * 2
        ]
        if not cand:
            return []
        valid = self.sim.analyze_batch(cand)["valid"]
        return [cfg for cfg, ok in zip(cand, valid) if ok]

    def candidate_table(self, m: int, n: int, k: int, dtype: str
                             ) -> tuple[list[GemmConfig], np.ndarray]:
        """Candidate list + precomputed feature table for one shape bucket
        (LRU-cached: the grid is static per (chip, dtype), so repeat calls
        — cache misses after retraining, other objectives — skip both the
        validity filter and feature building)."""
        dtype = canon_dtype(dtype)
        key = (m, n, k, dtype)
        with self._lock:
            hit = self._cand_cache.get(key)
            if hit is not None:
                self._cand_cache.move_to_end(key)
                return hit
        cfgs = self.candidate_configs(m, n, k, dtype)
        X = (features_matrix(cfgs, chip=self.chip) if cfgs
             else np.zeros((0, len(self.predictor.feature_names))))
        with self._lock:
            self._cand_cache[key] = (cfgs, X)
            self._cand_cache.move_to_end(key)
            while len(self._cand_cache) > self._cand_cache_size:
                self._cand_cache.popitem(last=False)
        return cfgs, X

    # ---------- scoring ----------
    @staticmethod
    def _objective_scores(pred: dict[str, np.ndarray], objective: str
                          ) -> np.ndarray:
        if objective == "runtime":
            return pred["runtime_ms"]
        if objective in ("energy", "power"):
            return pred["energy_j"] if objective == "energy" else pred["power_w"]
        if objective == "edp":
            return pred["energy_j"] * pred["runtime_ms"]
        raise ValueError(f"unknown objective {objective!r}")

    def _use_jit_scorer(self) -> bool:
        if not self.predictor.supports_jax():
            return False
        if self.scorer != "auto":
            return self.scorer == "jit"
        import jax

        return jax.default_backend() != "cpu"

    def _predict_features(self, X: np.ndarray) -> np.ndarray:
        """(N, F) raw features -> (N, T) predictions via the compiled x64
        scorer (forest models on accelerators) or the vectorized
        stacked-descent estimator — see the `scorer` constructor arg.

        The jit path pads the batch to the next power of two so XLA
        compiles one kernel per size bucket instead of one per candidate
        count."""
        if self._use_jit_scorer():
            fn = self.predictor.jax_predictor(x64=True)
            n = len(X)
            pad = _next_pow2(n)
            if pad != n:
                X = np.concatenate([X, np.tile(X[-1:], (pad - n, 1))])
            return np.asarray(fn(X))[:n]
        table = {name: X[:, i]
                 for i, name in enumerate(self.predictor.feature_names)}
        return self.predictor.predict_matrix(table)

    def _scores_from_matrix(self, Y: np.ndarray, objective: str) -> np.ndarray:
        idx = {t: i for i, t in enumerate(self.predictor.target_names)}
        pred = {t: Y[:, i] for t, i in idx.items()}
        return self._objective_scores(pred, objective)

    def rank(self, cfgs: Sequence[GemmConfig], objective: str = "runtime",
             features: np.ndarray | None = None) -> np.ndarray:
        """Ascending-score candidate order from one batched scorer call."""
        X = (features if features is not None
             else features_matrix(cfgs, chip=self.chip))
        Y = self._predict_features(X)
        # stable: coarse tree predictors tie often, and in-graph top-k
        # breaks ties by index — keep both paths' orders identical.
        return np.argsort(self._scores_from_matrix(Y, objective),
                          kind="stable")

    # ---------- fully in-graph ranking ----------
    def _graph_blocks(self) -> np.ndarray:
        """The static (C, 3) candidate block grid. Shape-dependent pruning
        (extent clipping, VMEM fit) happens in-graph via the validity
        mask, so one compiled ranker serves every GEMM shape."""
        if self._graph_block_grid is None:
            self._graph_block_grid = np.array(
                [(bm, bn, bk) for bm in _BM for bn in _BN for bk in _BK],
                dtype=np.int64)
        return self._graph_block_grid

    def _graph_consts(self, dtype: str) -> dict[str, np.ndarray]:
        """Chip/dtype scalars for `graph_candidate_features`, as 0-d
        arrays: traced (not baked) so XLA can't constant-fold them into
        reciprocal multiplies that drift vs the numpy feature builder."""
        c = self.chip
        return {
            "peak": np.asarray(c.peak_flops[dtype]),
            "hbm_bw": np.asarray(c.hbm_bw),
            "vmem_usable": np.asarray(c.vmem_bytes * VMEM_USABLE_FRACTION),
            "mxu": np.asarray(c.mxu_dim, dtype=np.int64),
            "dtype_bytes": np.asarray(int(DTYPE_BYTES[dtype]),
                                      dtype=np.int64),
            "step_cost": np.asarray(1e-7),
        }

    def _graph_rank_fn(self, objective: str, x64: bool, top_k: int):
        """Build (once per (objective, precision, k)) the jitted ranker:
        feature grid -> scale -> compiled predictor -> decode -> objective
        -> masked top-k, all in one XLA program."""
        key = (objective, x64, top_k)
        hit = self._graph_fns.get(key)
        if hit is not None:
            return hit
        import jax
        import jax.numpy as jnp

        # validate the objective before baking it into a trace
        self._objective_scores(
            {t: np.zeros(1) for t in ("runtime_ms", "power_w", "energy_j")},
            objective)
        # lower + upload once per precision; extra (objective, k) variants
        # only re-trace the thin ranker around the shared apply/params
        cached = self._graph_params.get(x64)
        if cached is None:
            params, apply = self.predictor.jax_components(x64=x64)
            with precision_scope(x64):
                cached = (jax.tree.map(jnp.asarray, params), apply)
            self._graph_params[x64] = cached
        device_params, apply = cached
        t_idx = {t: i for i, t in enumerate(self.predictor.target_names)}
        tuner = self

        def ranker(mnk, blocks, consts, mean, scale, pparams):
            tuner.graph_traces += 1  # python side effect: counts traces
            feats, valid = graph_candidate_features(mnk, blocks, consts,
                                                    exact=x64)
            S, C, F = feats.shape
            flat = feats.reshape(S * C, F)
            Y = apply(pparams, (flat - mean) / scale, flat)
            if objective == "edp":
                score = (Y[:, t_idx["energy_j"]]
                         * Y[:, t_idx["runtime_ms"]])
            else:
                col = {"runtime": "runtime_ms", "energy": "energy_j",
                       "power": "power_w"}[objective]
                score = Y[:, t_idx[col]]
            score = jnp.where(valid, score.reshape(S, C), jnp.inf)
            neg, idx = jax.lax.top_k(-score, top_k)
            return -neg, idx

        entry = (jax.jit(ranker), device_params)
        self._graph_fns[key] = entry
        return entry

    def rank_in_graph(self, shapes: Sequence[tuple[int, int, int]], *,
                      dtype: str = "bf16", objective: str = "runtime",
                      top_k: int | None = None, x64: bool = True
                      ) -> tuple[list[list[GemmConfig]], np.ndarray]:
        """Rank the candidate grid for a fleet of shapes *inside* jax.jit.

        The candidate feature table is built with jnp ops over the static
        block grid, scored through the compiled predictor, and the
        objective argmin'd in-graph — the GEMM extents are traced array
        values, so new shapes reuse the compiled ranker (no retrace; shape
        fleets are padded to power-of-two buckets). ``x64=True`` (default)
        runs the whole graph in scoped float64: features, scaling, and
        descent are bit-identical to the trace-time `rank()` path, so both
        return the same winners. ``x64=False`` is the approximate f32 mode
        for embedding in fp32 programs.

        Returns ``(top_cfgs, top_scores)``: per shape, up to `top_k`
        candidate `GemmConfig`s in ascending predicted-objective order
        (fewer when the valid set is smaller; empty when no candidate
        fits) and the (S, top_k) score matrix (+inf past the valid set).
        """
        import jax
        import jax.numpy as jnp

        dtype = canon_dtype(dtype)
        blocks = self._graph_blocks()
        k = min(top_k if top_k is not None else self.verify_top_k,
                len(blocks))
        S = len(shapes)
        if S == 0:
            return [], np.zeros((0, k))
        pad = _next_pow2(S)
        mnk = np.zeros((pad, 3), dtype=np.int64)
        mnk[:S] = [tuple(int(x) for x in s) for s in shapes]
        mnk[S:] = mnk[S - 1]
        jitted, device_params = self._graph_rank_fn(objective, x64, k)
        consts = self._graph_consts(dtype)
        # The production call path reaches here *during* an outer jit
        # trace (ops.matmul tunes at trace time): every input is a
        # trace-constant, so escape the ambient trace and run the ranker
        # as a normal compiled dispatch — otherwise the pjit call would
        # inline into the caller's graph and hand back tracers.
        with precision_scope(x64), jax.ensure_compile_time_eval():
            scores, idx = jitted(
                jnp.asarray(mnk), jnp.asarray(blocks),
                {name: jnp.asarray(v) for name, v in consts.items()},
                jnp.asarray(self.predictor.scaler.mean_),
                jnp.asarray(self.predictor.scaler.scale_),
                device_params)
        scores = np.asarray(scores)[:S]
        idx = np.asarray(idx)[:S]
        top_cfgs: list[list[GemmConfig]] = []
        for i, (m, n, kk) in enumerate(shapes):
            row = []
            for j in range(k):
                if np.isfinite(scores[i, j]):
                    bm, bn, bk = blocks[idx[i, j]]
                    row.append(GemmConfig(
                        m=int(m), n=int(n), k=int(kk), block_m=int(bm),
                        block_n=int(bn), block_k=int(bk), dtype=dtype))
            top_cfgs.append(row)
        return top_cfgs, scores

    # ---------- tuning ----------
    @staticmethod
    def _key(m: int, n: int, k: int, dtype: str, objective: str) -> str:
        return f"{m},{n},{k},{dtype},{objective}"

    def best_config(self, m: int, n: int, k: int, *, dtype: str = "bf16",
                    objective: str = "runtime", rank_mode: str = "auto",
                    measure_fn=None) -> BlockConfig:
        return self.tune_many([(m, n, k)], dtype=dtype, objective=objective,
                              rank_mode=rank_mode, measure_fn=measure_fn)[0]

    def tune_many(self, shapes: Sequence[tuple[int, int, int]], *,
                  dtype: str = "bf16", objective: str = "runtime",
                  rank_mode: str = "auto", measure_fn=None
                  ) -> list[BlockConfig]:
        """Tune a fleet of (m, n, k) shapes in one pass: all uncached
        shapes share one batched ranking pass and one batched top-k
        verification sweep, then land in the winner cache together.

        `rank_mode` selects the ranking path: "graph" scores candidates
        fully in-graph (`rank_in_graph`: jnp feature grid + compiled
        predictor + in-jit top-k — the accelerator serving path), "trace"
        ranks in Python over the cached candidate tables, and "auto"
        (default) picks "graph" exactly when the compiled scorer is the
        rank backend (accelerator backends; see `scorer`). Both modes
        produce the same winners — the graph path runs scoped-x64.

        `measure_fn`, when given, replaces the simulator for the
        verification sweep — the real-hardware hook. It is called once
        with the flat list of top-k `GemmConfig`s (all shapes
        concatenated) and must return a telemetry-like mapping with
        "runtime_ms", "power_w", and "energy_j" arrays aligned with the
        input order (e.g. wall-clock timings of the actual kernels).
        """
        dtype = canon_dtype(dtype)
        if rank_mode not in ("auto", "graph", "trace"):
            raise ValueError(f"unknown rank_mode {rank_mode!r}")
        out: list[BlockConfig | None] = [None] * len(shapes)
        todo: list[int] = []
        with self._lock:
            for i, (m, n, k) in enumerate(shapes):
                hit = self._cache_get(self._key(m, n, k, dtype, objective))
                if hit is not None:
                    out[i] = BlockConfig(*hit)
                else:
                    todo.append(i)
        if not todo:
            return out  # type: ignore[return-value]

        use_graph = (rank_mode == "graph"
                     or (rank_mode == "auto" and self._use_jit_scorer()))
        # rank: per-uncached-shape top-k candidates, ascending predicted
        # objective. An empty top list means no candidate fits (BASELINE
        # fallback — cached too: the empty set is deterministic per
        # bucket, so never re-enumerate).
        groups: list[tuple[int, list[GemmConfig]]] = []
        if use_graph:
            tops_all, _ = self.rank_in_graph(
                [shapes[i] for i in todo], dtype=dtype, objective=objective)
            for i, top in zip(todo, tops_all):
                if top:
                    groups.append((i, top))
                else:
                    out[i] = BASELINE
        else:
            trace_groups: list[tuple[int, list[GemmConfig], np.ndarray]] = []
            for i in todo:
                m, n, k = shapes[i]
                cfgs, X = self.candidate_table(m, n, k, dtype)
                if not cfgs:
                    out[i] = BASELINE
                else:
                    trace_groups.append((i, cfgs, X))
            if trace_groups:
                # one compiled scorer call over every candidate of every
                # shape
                scores = self._scores_from_matrix(
                    self._predict_features(
                        np.concatenate([X for _, _, X in trace_groups])),
                    objective)
                off = 0
                for i, cfgs, _X in trace_groups:
                    # stable sort: tie-break by index like in-graph top-k
                    order = np.argsort(scores[off:off + len(cfgs)],
                                       kind="stable")
                    groups.append(
                        (i, [cfgs[j] for j in order[:self.verify_top_k]]))
                    off += len(cfgs)

        winners: dict[int, tuple[int, int, int]] = {}
        if groups:
            # one batched verification sweep across all shapes
            flat = [c for _, top in groups for c in top]
            tel = (measure_fn(flat) if measure_fn is not None
                   else self.sim.measure_batch(flat))
            meas = self._objective_scores(
                {t: np.asarray(tel[t], dtype=np.float64)
                 for t in ("runtime_ms", "power_w", "energy_j")},
                objective)
            off = 0
            for i, top in groups:
                s = meas[off:off + len(top)]
                w = top[int(np.argmin(s))]
                winners[i] = (w.block_m, w.block_n, w.block_k)
                out[i] = BlockConfig(*winners[i])
                off += len(top)

        with self._lock:
            for i in todo:
                m, n, k = shapes[i]
                best = winners.get(i)
                if best is None:  # BASELINE fallback
                    best = (BASELINE.block_m, BASELINE.block_n,
                            BASELINE.block_k)
                self._cache_put(self._key(m, n, k, dtype, objective), best)
            self._write_cache_locked()
        return out  # type: ignore[return-value]

    def tune_report(self, m: int, n: int, k: int, *, dtype: str = "bf16",
                    objective: str = "runtime") -> dict:
        """Tuned-vs-baseline gains (the paper's 3.2x / 22% claims)."""
        dtype = canon_dtype(dtype)
        best = self.best_config(m, n, k, dtype=dtype, objective=objective)
        base_cfg = GemmConfig(m=m, n=n, k=k, block_m=BASELINE.block_m,
                              block_n=BASELINE.block_n,
                              block_k=BASELINE.block_k, dtype=dtype)
        best_cfg = GemmConfig(m=m, n=n, k=k, block_m=best.block_m,
                              block_n=best.block_n, block_k=best.block_k,
                              dtype=dtype)
        tb = self.sim.analyze(base_cfg)
        tt = self.sim.analyze(best_cfg)
        return {
            "m": m, "n": n, "k": k, "dtype": dtype, "objective": objective,
            "chip": self.chip.name,
            "artifact_fingerprint": self.artifact_fingerprint,
            "baseline": BASELINE.as_tuple(),
            "best": best.as_tuple(),
            "baseline_runtime_ms": tb.runtime_ms,
            "tuned_runtime_ms": tt.runtime_ms,
            "speedup": tb.runtime_ms / tt.runtime_ms,
            "baseline_power_w": tb.power_w,
            "tuned_power_w": tt.power_w,
            "power_reduction_pct": 100.0 * (1 - tt.power_w / tb.power_w),
            "baseline_energy_j": tb.energy_j,
            "tuned_energy_j": tt.energy_j,
            "energy_reduction_pct": 100.0 * (1 - tt.energy_j / tb.energy_j),
        }


# ---------- process-wide per-chip tuners ----------
_GLOBAL: dict[str, GemmAutotuner] = {}
_GLOBAL_LOCK = threading.Lock()


def build_default_predictor(artifacts_dir: str = DEFAULT_ARTIFACTS_DIR,
                            n_train: int = 4000,
                            force_retrain: bool = False,
                            chip: ChipSpec | str = TPU_V5E) -> PerfPredictor:
    """Load the persisted per-chip predictor artifact or train one on a
    fresh sweep. Invalid/legacy/tampered artifacts trigger a retrain."""
    chip = get_chip(chip)
    os.makedirs(artifacts_dir, exist_ok=True)
    path = os.path.join(artifacts_dir, f"perf_predictor_{chip.name}.npz")
    if os.path.exists(path) and not force_retrain:
        try:
            return PerfPredictor.load(path)
        except ArtifactError:
            pass
    from repro.core.profiler import collect_dataset

    table = collect_dataset(n_configs=n_train, seed=0, chip=chip)
    pred = PerfPredictor(model="rf", residual=True, fast=True,
                         chip=chip.name).fit(table)
    pred.save(path)
    return pred


def get_tuner(artifacts_dir: str = DEFAULT_ARTIFACTS_DIR,
              chip: ChipSpec | str = TPU_V5E) -> GemmAutotuner:
    chip = get_chip(chip)
    with _GLOBAL_LOCK:
        tuner = _GLOBAL.get(chip.name)
        if tuner is None:
            predictor = build_default_predictor(artifacts_dir, chip=chip)
            tuner = GemmAutotuner(
                predictor,
                chip=chip,
                cache_path=os.path.join(
                    artifacts_dir, f"tuner_cache_{chip.name}.json"),
            )
            _GLOBAL[chip.name] = tuner
        return tuner


def set_tuner(tuner: GemmAutotuner | None,
              chip: ChipSpec | str | None = None) -> None:
    """Install (or clear, with tuner=None and chip=None) global tuners."""
    global _GLOBAL
    with _GLOBAL_LOCK:
        if tuner is None and chip is None:
            _GLOBAL = {}
        elif tuner is None:
            _GLOBAL.pop(get_chip(chip).name, None)
        else:
            _GLOBAL[get_chip(chip).name if chip is not None
                    else tuner.chip.name] = tuner
