"""Chip registry: hardware constants for every measurement substrate.

The paper's platform is an RTX 4070 (29.15 TFLOP/s fp32, 504.2 GB/s, ridge
point ~59 FLOPs/B, 46 SMs with 48 KiB shared memory each, ~85 W idle rising
to a 200 W TDP). The reproduction's primary target is TPU v5e (197 TFLOP/s
bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI). Both live in a small
registry so the simulator, profiler, predictor, and autotuner can be pointed
at any chip by name (`get_chip("rtx4070")`) and new substrates can be added
with `register_chip`.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops: dict[str, float]   # dtype -> FLOP/s
    hbm_bw: float                  # B/s
    hbm_bytes: float               # B
    vmem_bytes: float              # B (per core; smem x SMs on GPUs)
    ici_link_bw: float             # B/s per link (one direction)
    ici_links: int                 # links per chip (2D torus: 4)
    clock_hz: float
    mxu_dim: int                   # systolic array edge / GPU tile analogue
    sublane: int                   # second-minor tiling granularity
    lane: int                      # minor tiling granularity
    idle_power_w: float
    mxu_power_w: float             # max dynamic power of compute path
    hbm_power_w: float             # max dynamic power of HBM path
    tdp_w: float
    n_compute_units: int = 1       # SM count on GPUs; cores per chip on TPU
    # aggregate collective bandwidth per chip in GB/s — what one chip can
    # push onto the interconnect during a ring collective (ICI links on TPU,
    # the PCIe/NVLink envelope on GPUs). 0.0 = chip cannot shard.
    link_bw_gbs: float = 0.0
    # fixed per-collective launch/synchronization latency (seconds)
    link_launch_s: float = 2e-6

    def peak(self, dtype: str = "bf16") -> float:
        return self.peak_flops[dtype]

    def ridge_point(self, dtype: str = "bf16") -> float:
        """FLOPs/byte at which compute time == memory time."""
        return self.peak(dtype) / self.hbm_bw

    @property
    def nominal_power_w(self) -> float:
        """Mid-load operating power: idle floor + half the dynamic envelope.

        This is the analytical anchor the predictor's residual mode uses
        for energy (TPU v5e: 60 + (95+45)/2 = 130 W; RTX 4070: 142.5 W).
        """
        return self.idle_power_w + 0.5 * (self.mxu_power_w + self.hbm_power_w)


TPU_V5E = ChipSpec(
    name="tpu_v5e",
    peak_flops={
        "bf16": 197e12,
        "int8": 394e12,
        "f32": 197e12 / 4,  # fp32 runs through the MXU at 1/4 bf16 rate
    },
    hbm_bw=819e9,
    hbm_bytes=16 * 2**30,
    vmem_bytes=128 * 2**20,
    ici_link_bw=50e9,
    ici_links=4,
    clock_hz=940e6,
    mxu_dim=128,
    sublane=8,
    lane=128,
    idle_power_w=60.0,
    mxu_power_w=95.0,
    hbm_power_w=45.0,
    tdp_w=200.0,
    n_compute_units=1,
    link_bw_gbs=200.0,           # 4 ICI links x 50 GB/s
)

# The paper's chip, calibrated to its measurements: 46 SMs x 48 KiB shared
# memory (the VMEM/occupancy analogue), bf16 via fp32 CUDA cores, and the
# 80-100 W idle floor stepping toward the 200 W TDP under load.
RTX_4070 = ChipSpec(
    name="rtx4070",
    peak_flops={"f32": 29.15e12, "bf16": 29.15e12},
    hbm_bw=504.2e9,
    hbm_bytes=12 * 2**30,
    vmem_bytes=48 * 2**10 * 46,  # 48 KiB smem x 46 SMs
    ici_link_bw=0.0,
    ici_links=0,
    clock_hz=1.92e9,
    mxu_dim=16,                  # warp-tile analogue of the MXU edge
    sublane=8,
    lane=32,
    idle_power_w=85.0,
    mxu_power_w=80.0,
    hbm_power_w=35.0,
    tdp_w=200.0,
    n_compute_units=46,
    link_bw_gbs=32.0,            # PCIe 4.0 x16 — no NVLink on a 4070
)


_REGISTRY: dict[str, ChipSpec] = {}


def register_chip(spec: ChipSpec, *aliases: str) -> ChipSpec:
    """Register `spec` under its canonical name plus any aliases."""
    for key in (spec.name, *aliases):
        _REGISTRY[key.lower()] = spec
    return spec


def get_chip(chip: str | ChipSpec) -> ChipSpec:
    """Resolve a chip by registry name (or pass a ChipSpec through)."""
    if isinstance(chip, ChipSpec):
        return chip
    try:
        return _REGISTRY[chip.lower()]
    except KeyError:
        known = sorted(set(_REGISTRY))
        raise ValueError(f"unknown chip {chip!r}; known: {known}") from None


def available_chips() -> list[str]:
    """Canonical (deduplicated) registered chip names."""
    return sorted({spec.name for spec in _REGISTRY.values()})


register_chip(TPU_V5E, "v5e")
register_chip(RTX_4070, "rtx_4070", "ada", "4070")


# Trace-time dtype strings (str(jnp_array.dtype)) -> simulator dtype names.
# The substrate's peak-FLOPs tables are keyed by the short names only, so
# the autotuner canonicalizes before enumerating candidates.
DTYPE_CANON = {"bfloat16": "bf16", "float32": "f32", "float16": "f16",
               "int8": "int8", "s8": "int8", "u8": "int8"}


def canon_dtype(dtype: str) -> str:
    """Map a jax dtype string to the substrate's dtype name."""
    return DTYPE_CANON.get(dtype, dtype)


DTYPE_BYTES = {"bf16": 2, "f32": 4, "float32": 4, "bfloat16": 2, "int8": 1,
               "f16": 2, "float16": 2, "s8": 1, "u8": 1, "s32": 4, "u32": 4,
               "f64": 8, "pred": 1, "s16": 2, "u16": 2, "s64": 8, "u64": 8,
               "f8e4m3fn": 1, "f8e5m2": 1, "s4": 0.5, "u4": 0.5}
