"""Hardware constants for the target platform (TPU v5e) and roofline math.

The paper's platform is an RTX 4070 (29.15 TFLOP/s fp32, 504.2 GB/s, ridge
point 59 FLOPs/B). Our target is TPU v5e with the constants mandated by the
task spec: 197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops: dict[str, float]   # dtype -> FLOP/s
    hbm_bw: float                  # B/s
    hbm_bytes: float               # B
    vmem_bytes: float              # B (per core)
    ici_link_bw: float             # B/s per link (one direction)
    ici_links: int                 # links per chip (2D torus: 4)
    clock_hz: float
    mxu_dim: int                   # systolic array edge
    sublane: int                   # second-minor tiling granularity
    lane: int                      # minor tiling granularity
    idle_power_w: float
    mxu_power_w: float             # max dynamic power of compute path
    hbm_power_w: float             # max dynamic power of HBM path
    tdp_w: float

    def peak(self, dtype: str = "bf16") -> float:
        return self.peak_flops[dtype]

    def ridge_point(self, dtype: str = "bf16") -> float:
        """FLOPs/byte at which compute time == memory time."""
        return self.peak(dtype) / self.hbm_bw


TPU_V5E = ChipSpec(
    name="tpu_v5e",
    peak_flops={
        "bf16": 197e12,
        "int8": 394e12,
        "f32": 197e12 / 4,  # fp32 runs through the MXU at 1/4 bf16 rate
    },
    hbm_bw=819e9,
    hbm_bytes=16 * 2**30,
    vmem_bytes=128 * 2**20,
    ici_link_bw=50e9,
    ici_links=4,
    clock_hz=940e6,
    mxu_dim=128,
    sublane=8,
    lane=128,
    idle_power_w=60.0,
    mxu_power_w=95.0,
    hbm_power_w=45.0,
    tdp_w=200.0,
)

# The paper's chip, kept for the Fig-1 comparison benchmark.
RTX_4070 = ChipSpec(
    name="rtx_4070",
    peak_flops={"f32": 29.15e12, "bf16": 29.15e12},
    hbm_bw=504.2e9,
    hbm_bytes=12 * 2**30,
    vmem_bytes=48 * 2**10 * 46,  # 48 KiB smem x 46 SMs (occupancy analogue only)
    ici_link_bw=0.0,
    ici_links=0,
    clock_hz=1.92e9,
    mxu_dim=16,
    sublane=8,
    lane=32,
    idle_power_w=35.0,
    mxu_power_w=130.0,
    hbm_power_w=35.0,
    tdp_w=200.0,
)


DTYPE_BYTES = {"bf16": 2, "f32": 4, "float32": 4, "bfloat16": 2, "int8": 1,
               "f16": 2, "float16": 2, "s8": 1, "u8": 1, "s32": 4, "u32": 4,
               "f64": 8, "pred": 1, "s16": 2, "u16": 2, "s64": 8, "u64": 8,
               "f8e4m3fn": 1, "f8e5m2": 1, "s4": 0.5, "u4": 0.5}
