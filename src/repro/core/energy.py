"""TPU power/energy model — the paper's energy axis, lifted to step level.

Per-kernel energy comes from `hwsim` (power x runtime). This module adds the
*framework-level* accounting: given a roofline report for a train/serve step,
estimate per-chip power from duty cycles, then energy per step / per token,
and the paper's ETA-style tradeoff metric (energy-delay product) used by the
autotuner's `objective="energy"` / `"edp"` modes.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

from repro.core.chips import DTYPE_BYTES, TPU_V5E, ChipSpec, canon_dtype, get_chip
from repro.core.roofline import RooflineReport

# ICI/link interface power while the wire is busy (matches the
# `step_power_w` default duty-cycle term).
ICI_POWER_W = 12.0


@dataclasses.dataclass
class EnergyReport:
    """Per-step energy telemetry derived from a roofline report: system
    power, J/step, J/token, and the energy-delay product."""

    name: str
    n_chips: int
    step_s: float
    chip_power_w: float
    system_power_w: float
    energy_per_step_j: float
    tokens_per_step: float
    energy_per_token_j: float
    edp: float                      # energy-delay product (J*s)

    def as_row(self) -> dict:
        """Flatten to a plain dict (CSV/markdown table row)."""
        return dataclasses.asdict(self)


def step_power_w(report: RooflineReport, chip: ChipSpec = TPU_V5E,
                 ici_power_w: float = 12.0) -> float:
    """Duty-cycle power model. At the overlap bound, each subsystem is busy
    for its own term's fraction of the bound time."""
    bound = max(report.bound_s, 1e-12)
    duty_mxu = min(report.compute_s / bound, 1.0)
    duty_hbm = min(report.memory_s / bound, 1.0)
    duty_ici = min(report.collective_s / bound, 1.0)
    p = (chip.idle_power_w
         + chip.mxu_power_w * duty_mxu
         + chip.hbm_power_w * duty_hbm
         + ici_power_w * duty_ici)
    return min(p, chip.tdp_w)


@dataclasses.dataclass(frozen=True)
class StepEnergyEstimate:
    """Predicted cost of one serving step (a prefill or one lockstep decode
    iteration of the whole batch) — the unit the engine's per-request
    energy attribution multiplies by resident steps."""

    name: str
    step_s: float                  # predicted wall time of the step
    power_w: float                 # duty-cycle chip power during the step
    energy_j: float                # fleet energy: power_w * step_s * n_chips
    compute_s: float               # summed GEMM compute terms
    memory_s: float                # summed GEMM memory terms
    n_gemms: float                 # weighted GEMM count
    # sharded-fleet terms (tp=1 single-chip estimates leave these at rest)
    n_chips: int = 1
    collective_s: float = 0.0      # unoverlapped wire time on the links
    exposed_collective_s: float = 0.0   # wire+launch time added to step_s
    overlap_factor: float = 0.0    # fraction of wire hidden behind GEMMs

    def as_row(self) -> dict:
        """Flatten to a plain dict (CSV/markdown table row)."""
        return dataclasses.asdict(self)


def combine_shape_counts(
    *maps: Mapping[tuple[int, int, int], float]
) -> dict[tuple[int, int, int], float]:
    """Merge GEMM shape->count maps by summing counts — the fleet of a
    *fused* serving step that issues several sub-steps back-to-back (e.g.
    one admission-prefill chunk + one lockstep decode)."""
    out: dict[tuple[int, int, int], float] = {}
    for m in maps:
        for shape, w in m.items():
            out[shape] = out.get(shape, 0.0) + float(w)
    return out


def fused_step_energy(*shape_counts: Mapping[tuple[int, int, int], float],
                      chip: ChipSpec | str = TPU_V5E,
                      dtype: str = "bf16",
                      configs: Mapping[tuple[int, int, int], object]
                      | None = None,
                      extra_hbm_bytes: float = 0.0,
                      tp: int = 1,
                      collective_bytes: float = 0.0,
                      n_collectives: float = 0.0,
                      overlap_chunks: int = 1,
                      name: str = "fused_step") -> StepEnergyEstimate:
    """Price one fused serving step: the union of several sub-step GEMM
    fleets (decode rows + chunk rows) run back-to-back through one
    duty-cycle power model, so chunked-admission serving is accounted as
    a single engine step rather than separately-idling phases."""
    return gemm_fleet_energy(combine_shape_counts(*shape_counts),
                             chip=chip, dtype=dtype, configs=configs,
                             extra_hbm_bytes=extra_hbm_bytes, tp=tp,
                             collective_bytes=collective_bytes,
                             n_collectives=n_collectives,
                             overlap_chunks=overlap_chunks, name=name)


def gemm_fleet_energy(shape_counts: Mapping[tuple[int, int, int], float], *,
                      chip: ChipSpec | str = TPU_V5E,
                      dtype: str = "bf16",
                      configs: Mapping[tuple[int, int, int], object]
                      | None = None,
                      extra_hbm_bytes: float = 0.0,
                      tp: int = 1,
                      collective_bytes: float = 0.0,
                      n_collectives: float = 0.0,
                      overlap_chunks: int = 1,
                      name: str = "step") -> StepEnergyEstimate:
    """Energy of one step built from its GEMM fleet (the paper's per-kernel
    model lifted to a serving step).

    `shape_counts` maps (m, n, k) -> issue count per step (see
    `models.config.gemm_shape_counts`); `configs` optionally maps shapes to
    tuned `BlockConfig`s (e.g. `ServingEngine.pretuned`) so the estimate
    reflects the block sizes the step actually runs. Runtime per GEMM comes
    from the measurement substrate's analytical model; power comes from
    `step_power_w` over the fleet's aggregate duty cycles.

    `extra_hbm_bytes` charges non-GEMM HBM traffic the step issues on top
    of the fleet — the paged-KV engine's page-table gather/scatter (cache
    bytes read into the dense per-layer view and written back), priced at
    the chip's HBM bandwidth and folded into both the memory duty cycle
    and the step's wall time.

    Sharded fleets: with `tp > 1` the shapes are the *per-shard* extents
    (see `gemm_shape_counts(..., tp=)`) and `collective_bytes` /
    `n_collectives` describe one chip's per-step ring traffic, priced by
    `hwsim.collective_cost` against `ChipSpec.link_bw_gbs` with
    `overlap_chunks`-way interleaved overlap. The returned estimate is
    fleet-level: `step_s` is one lockstep step, `energy_j` multiplies the
    per-chip energy by `tp` chips, and the exposed (non-hidden) collective
    time extends the step.
    """
    from repro.core.hwsim import GemmConfig, TpuGemmSimulator, collective_cost
    from repro.kernels.tiled_matmul import DEFAULT_CONFIG

    chip = get_chip(chip)
    dtype = canon_dtype(dtype)
    shapes = sorted(shape_counts)
    weights = [float(shape_counts[s]) for s in shapes]
    cfgs = []
    for m, n, k in shapes:
        blk = (configs or {}).get((m, n, k)) or DEFAULT_CONFIG
        cfgs.append(GemmConfig(m=int(m), n=int(n), k=int(k),
                               block_m=int(blk.block_m),
                               block_n=int(blk.block_n),
                               block_k=int(blk.block_k), dtype=dtype))
    sim = TpuGemmSimulator(chip=chip)
    tel = sim.analyze_batch(cfgs)

    bytes_per = float(DTYPE_BYTES.get(dtype, 2))
    peak = chip.peak(dtype if dtype in chip.peak_flops else "bf16")
    step_s = compute_s = memory_s = 0.0
    for i, ((m, n, k), w) in enumerate(zip(shapes, weights)):
        # roofline terms are always finite — the fallback when a block
        # config is invalid (VMEM OOM) on this chip and the simulator
        # reports NaN runtime
        c_s = 2.0 * m * n * k / peak
        m_s = (m * k + k * n + m * n) * bytes_per / chip.hbm_bw
        rt = float(tel["runtime_ms"][i]) * 1e-3
        if not rt > 0.0 or rt != rt:            # NaN/invalid -> bound
            rt = max(c_s, m_s)
            compute_s += w * c_s
            memory_s += w * m_s
        else:
            compute_s += w * float(tel["compute_time_ms"][i]) * 1e-3
            memory_s += w * float(tel["memory_time_ms"][i]) * 1e-3
        step_s += w * rt
    if extra_hbm_bytes > 0.0:
        gather_s = float(extra_hbm_bytes) / chip.hbm_bw
        memory_s += gather_s
        step_s += gather_s
    coll = collective_cost(collective_bytes, chip=chip, tp=tp,
                           n_collectives=n_collectives,
                           overlap_chunks=overlap_chunks,
                           compute_s=step_s)
    step_s += coll.exposed_s
    flops = sum(2.0 * m * n * k * w for (m, n, k), w in zip(shapes, weights))
    byts = (sum((m * k + k * n + m * n) * bytes_per * w
                for (m, n, k), w in zip(shapes, weights))
            + float(extra_hbm_bytes))
    # the fleet runs kernels back-to-back, so duty cycles are relative to
    # total step time: setting collective_s = step_s (with zero ICI power)
    # pins `step_power_w`'s bound to the step without adding power; the real
    # ICI duty (unoverlapped wire time over the step) is added separately
    report = RooflineReport(
        name=name, n_chips=max(int(tp), 1), dtype=dtype, hlo_flops=flops,
        hlo_bytes=byts, collective_wire_bytes=coll.wire_bytes,
        compute_s=min(compute_s, step_s),
        memory_s=min(memory_s, step_s), collective_s=step_s)
    if step_s > 0:
        power = step_power_w(report, chip, ici_power_w=0.0)
        if coll.wire_s > 0.0:
            power = min(power + ICI_POWER_W * min(coll.wire_s / step_s, 1.0),
                        chip.tdp_w)
    else:
        power = chip.idle_power_w
    n_chips = max(int(tp), 1)
    return StepEnergyEstimate(
        name=name, step_s=step_s, power_w=power,
        energy_j=power * step_s * n_chips,
        compute_s=compute_s, memory_s=memory_s,
        n_gemms=float(sum(weights)), n_chips=n_chips,
        collective_s=coll.wire_s, exposed_collective_s=coll.exposed_s,
        overlap_factor=coll.overlap_factor)


def parked_energy_j(duration_s: float, *, chip: ChipSpec | str = TPU_V5E,
                    n_chips: int = 1) -> float:
    """Energy of `n_chips` parked at the idle floor for `duration_s`.

    Thin framework-level wrapper over `hwsim.parked_cost` — the term the
    fleet scheduler charges every engine for the gap between its own
    busy time and the fleet makespan (a parked engine burns its
    `ChipSpec.idle_power_w` whether or not it ever serves)."""
    from repro.core.hwsim import parked_cost

    return parked_cost(duration_s, chip=chip, n_chips=n_chips).energy_j


@dataclasses.dataclass(frozen=True)
class MarginalCostEstimate:
    """Predicted marginal cost of placing one request on a serving engine.

    Built from the engine's per-step fleet estimates by
    `marginal_request_cost` with the *same* per-row-share arithmetic the
    engine's energy attribution uses (chunk call split over lane width,
    decode step split over the slot table), so a routing decision priced
    here agrees with the ledger the request will actually be charged
    against."""

    chunk_calls: int        # bucketed prefill chunk calls the prompt needs
    prefill_s: float        # predicted model-clock seconds of those calls
    prefill_energy_j: float  # this request's per-row share of them
    decode_steps: int       # resident decode iterations (token budget)
    decode_s: float         # predicted model-clock seconds of those steps
    decode_energy_j: float  # this request's per-slot share of them
    energy_j: float         # prefill + decode marginal energy
    tokens: int             # expected generated tokens (denominator)
    j_per_token: float      # energy_j / tokens
    service_s: float        # prefill_s + decode_s (completion headroom)

    def as_row(self) -> dict:
        """Flatten to a plain dict (CSV/markdown table row)."""
        return dataclasses.asdict(self)


def marginal_request_cost(chunk_est: StepEnergyEstimate | None,
                          decode_est: StepEnergyEstimate | None, *,
                          chunk_calls: int, chunk_width: int,
                          decode_steps: int, decode_batch: int,
                          tokens: int) -> MarginalCostEstimate:
    """Marginal (engine, chunk-bucket) placement cost of one request.

    `chunk_est` prices one admission chunk call over `chunk_width` lane
    rows (e.g. `ServingEngine.fused_step_estimate` or `_chunk_cost`);
    `decode_est` one lockstep decode step over `decode_batch` slots. The
    request's marginal share is `chunk_calls` per-row slices of the
    former plus `decode_steps` per-slot slices of the latter — exactly
    the shares the engine attributes at retirement, so minimizing this
    across candidate placements minimizes predicted fleet J/token.
    Either estimate may be None (energy model unavailable): its terms
    price as zero, matching the engine's zero telemetry."""
    c_j = c_s = 0.0
    if chunk_est is not None and chunk_calls > 0:
        c_j = chunk_calls * chunk_est.energy_j / max(chunk_width, 1)
        c_s = chunk_calls * chunk_est.step_s
    d_j = d_s = 0.0
    if decode_est is not None and decode_steps > 0:
        d_j = decode_steps * decode_est.energy_j / max(decode_batch, 1)
        d_s = decode_steps * decode_est.step_s
    total = c_j + d_j
    return MarginalCostEstimate(
        chunk_calls=int(chunk_calls), prefill_s=c_s, prefill_energy_j=c_j,
        decode_steps=int(decode_steps), decode_s=d_s, decode_energy_j=d_j,
        energy_j=total, tokens=int(tokens),
        j_per_token=total / max(int(tokens), 1),
        service_s=c_s + d_s)


def energy_report(report: RooflineReport, *, tokens_per_step: float,
                  chip: ChipSpec = TPU_V5E,
                  step_s: float | None = None) -> EnergyReport:
    """Price one step of a roofline report on `chip`: duty-cycle power
    times step time, normalized to J/token and EDP."""
    step = step_s if step_s is not None else report.bound_s
    p_chip = step_power_w(report, chip)
    p_sys = p_chip * report.n_chips
    e_step = p_sys * step
    return EnergyReport(
        name=report.name,
        n_chips=report.n_chips,
        step_s=step,
        chip_power_w=p_chip,
        system_power_w=p_sys,
        energy_per_step_j=e_step,
        tokens_per_step=tokens_per_step,
        energy_per_token_j=e_step / max(tokens_per_step, 1e-12),
        edp=e_step * step,
    )
