"""TPU power/energy model — the paper's energy axis, lifted to step level.

Per-kernel energy comes from `hwsim` (power x runtime). This module adds the
*framework-level* accounting: given a roofline report for a train/serve step,
estimate per-chip power from duty cycles, then energy per step / per token,
and the paper's ETA-style tradeoff metric (energy-delay product) used by the
autotuner's `objective="energy"` / `"edp"` modes.
"""

from __future__ import annotations

import dataclasses

from repro.core.chips import TPU_V5E, ChipSpec
from repro.core.roofline import RooflineReport


@dataclasses.dataclass
class EnergyReport:
    name: str
    n_chips: int
    step_s: float
    chip_power_w: float
    system_power_w: float
    energy_per_step_j: float
    tokens_per_step: float
    energy_per_token_j: float
    edp: float                      # energy-delay product (J*s)

    def as_row(self) -> dict:
        return dataclasses.asdict(self)


def step_power_w(report: RooflineReport, chip: ChipSpec = TPU_V5E,
                 ici_power_w: float = 12.0) -> float:
    """Duty-cycle power model. At the overlap bound, each subsystem is busy
    for its own term's fraction of the bound time."""
    bound = max(report.bound_s, 1e-12)
    duty_mxu = min(report.compute_s / bound, 1.0)
    duty_hbm = min(report.memory_s / bound, 1.0)
    duty_ici = min(report.collective_s / bound, 1.0)
    p = (chip.idle_power_w
         + chip.mxu_power_w * duty_mxu
         + chip.hbm_power_w * duty_hbm
         + ici_power_w * duty_ici)
    return min(p, chip.tdp_w)


def energy_report(report: RooflineReport, *, tokens_per_step: float,
                  chip: ChipSpec = TPU_V5E,
                  step_s: float | None = None) -> EnergyReport:
    step = step_s if step_s is not None else report.bound_s
    p_chip = step_power_w(report, chip)
    p_sys = p_chip * report.n_chips
    e_step = p_sys * step
    return EnergyReport(
        name=report.name,
        n_chips=report.n_chips,
        step_s=step,
        chip_power_w=p_chip,
        system_power_w=p_sys,
        energy_per_step_j=e_step,
        tokens_per_step=tokens_per_step,
        energy_per_token_j=e_step / max(tokens_per_step, 1e-12),
        edp=e_step * step,
    )
