"""GEMM feature engineering — the paper's Algorithm 1 (PREPROCESSDATA +
COMPUTEGEMMCHARS), extended with the TPU-static features the profiler can
derive without running anything (grid size, VMEM working set, occupancy
analogue, alignment waste).

`config_features_batch` is the native path: it evaluates every feature as a
NumPy column over a whole config list at once and returns the dict-of-columns
table that the profiler/predictor consume directly. The scalar
`config_features` is a batch-of-one wrapper kept for convenience. Both take a
`chip` (ChipSpec or registry name) because the roofline-informed features —
naive compute/memory time, occupancy, alignment waste — are chip-dependent.
"""

from __future__ import annotations

from collections.abc import Sequence

import numpy as np

from repro.core.chips import TPU_V5E, ChipSpec, get_chip
from repro.core.hwsim import (
    VMEM_USABLE_FRACTION,
    GemmConfig,
    chip_peak_array,
    config_arrays,
)

# Columns fed to the models (order matters for the jitted predictor path).
NUMERIC_FEATURES = [
    "m", "n", "k",
    "block_m", "block_n", "block_k",
    "stages", "alpha", "beta", "dtype_bytes",
    "mxn", "mxk", "nxk", "mxnxk",
    "total_flops", "bytes_accessed", "arithmetic_intensity",
    "grid_steps", "vmem_working_set", "max_inflight_buffers",
    "alignment_waste", "layout_a_t", "layout_b_t",
    # physics-informed features (beyond-paper; EXPERIMENTS.md §Perf-pred):
    # naive roofline terms from *published* chip specs + tiling algebra.
    # These are static (pre-execution); the learned model supplies the
    # corrections (layout efficiency, VPU fallback, pipeline overlap, ...).
    "refetch_bytes", "naive_compute_ms", "naive_memory_ms",
    "padded_compute_ms", "naive_overhead_ms",
]
TARGETS = ["runtime_ms", "power_w", "energy_j", "tflops"]


def _ceil_div(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return -(-a // b)


def config_features_batch(
    cfgs: Sequence[GemmConfig],
    chip: ChipSpec | str = TPU_V5E,
    arrays: dict[str, np.ndarray] | None = None,
) -> dict[str, np.ndarray]:
    """Static (pre-execution) feature columns for a batch of GEMM configs."""
    c = get_chip(chip)
    arr = arrays if arrays is not None else config_arrays(cfgs)
    m, n, k = arr["m"], arr["n"], arr["k"]
    bm, bn, bk = arr["block_m"], arr["block_n"], arr["block_k"]
    in_bytes = arr["dtype_bytes"]

    grid_m = _ceil_div(m, bm)
    grid_n = _ceil_div(n, bn)
    grid_steps = grid_m * grid_n * _ceil_div(k, bk)
    single = (bm * bk + bk * bn) * in_bytes + bm * bn * 4
    max_buffers = (c.vmem_bytes * VMEM_USABLE_FRACTION
                   // np.maximum(single, 1)).astype(np.int64)
    total_flops = 2.0 * m * n * k
    bytes_accessed = in_bytes * (m * k + k * n) + 4.0 * m * n
    mxu = c.mxu_dim
    padded = (
        grid_steps
        * _ceil_div(bm, mxu) * _ceil_div(bn, mxu) * _ceil_div(bk, mxu)
        * (2 * mxu ** 3)
    )
    beta = arr["beta"]
    refetch_bytes = (
        grid_n * m * k * in_bytes     # A re-read per N-tile
        + grid_m * k * n * in_bytes   # B re-read per M-tile
        + m * n * 4.0 * np.where(beta != 0.0, 2.0, 1.0)
    )
    peak = chip_peak_array(c, arr["dtype"])
    layout = arr["layout"]
    f64 = np.float64
    return {
        "refetch_bytes": refetch_bytes.astype(f64),
        "naive_compute_ms": total_flops / peak * 1e3,
        "naive_memory_ms": refetch_bytes / c.hbm_bw * 1e3,
        "padded_compute_ms": padded / peak * 1e3,
        "naive_overhead_ms": grid_steps * 1e-7 * 1e3,
        "m": m.astype(f64),
        "n": n.astype(f64),
        "k": k.astype(f64),
        "block_m": bm.astype(f64),
        "block_n": bn.astype(f64),
        "block_k": bk.astype(f64),
        "stages": arr["stages"].astype(f64),
        "alpha": arr["alpha"].astype(f64),
        "beta": beta.astype(f64),
        "dtype_bytes": in_bytes.astype(f64),
        "mxn": (m * n).astype(f64),
        "mxk": (m * k).astype(f64),
        "nxk": (n * k).astype(f64),
        "mxnxk": m.astype(f64) * n * k,
        "total_flops": total_flops,
        "bytes_accessed": bytes_accessed,
        "arithmetic_intensity": total_flops / np.maximum(bytes_accessed, 1.0),
        "grid_steps": grid_steps.astype(f64),
        "vmem_working_set": single.astype(f64),
        "max_inflight_buffers": max_buffers.astype(f64),
        "alignment_waste": padded / np.maximum(total_flops, 1.0),
        "layout_a_t": np.array([1.0 if s[0] == "t" else 0.0 for s in layout]),
        "layout_b_t": np.array([1.0 if s[1] == "t" else 0.0 for s in layout]),
    }


def config_features(cfg: GemmConfig,
                    chip: ChipSpec | str = TPU_V5E) -> dict[str, float]:
    """Static features for one GEMM config (batch-of-one wrapper)."""
    cols = config_features_batch([cfg], chip=chip)
    return {key: float(col[0]) for key, col in cols.items()}


def features_matrix(cfgs: Sequence[GemmConfig],
                    chip: ChipSpec | str = TPU_V5E) -> np.ndarray:
    """(n_cfgs, len(NUMERIC_FEATURES)) feature matrix (for jitted ranking)."""
    cols = config_features_batch(cfgs, chip=chip)
    return np.stack([cols[k] for k in NUMERIC_FEATURES], axis=1)


def graph_candidate_features(mnk, blocks, consts, *, exact: bool = True):
    """In-graph (jnp) mirror of `config_features_batch` over an S×C grid.

    For every (shape, block) pair of `mnk` (S, 3) × `blocks` (C, 3) —
    candidate configs with the default trace-time knobs (layout "nn",
    alpha=1, beta=0, stages=2) — build the (S, C, len(NUMERIC_FEATURES))
    feature tensor plus the (S, C) validity mask (VMEM-fit and the
    extent-clipping rule of `GemmAutotuner.candidate_configs`) entirely
    with jax ops, so the autotuner can rank whole candidate grids inside
    `jax.jit` with the shape extents as *traced* values (no retrace per
    GEMM shape).

    `consts` carries the chip/dtype scalars as 0-d arrays — peak FLOP/s
    ("peak"), HBM bandwidth ("hbm_bw"), usable VMEM bytes ("vmem_usable"),
    MXU edge ("mxu"), input dtype bytes ("dtype_bytes"), and the per-step
    sequencer cost ("step_cost", 1e-7). They are traced arguments on
    purpose: baked literals would let XLA fold divisions into reciprocal
    multiplies (and adjacent constant multiplies into one rounded factor),
    drifting the last ulp vs the numpy feature builder.

    `exact=True` (use under a scoped ``enable_x64``) keeps integer terms
    in int64 and mirrors the numpy float-op order, producing bit-identical
    columns for every extent where the integer-valued terms stay below
    2**53 (far beyond any realistic GEMM). `exact=False` computes in
    f32/i32 with early float casts for the overflow-prone products — the
    approximate mode for embedding in fp32 programs.
    """
    import jax.numpy as jnp

    ft = jnp.float64 if exact else jnp.float32
    m, n, k = (mnk[:, i][:, None] for i in range(3))       # (S, 1)
    bm, bn, bk = (blocks[:, i][None, :] for i in range(3))  # (1, C)
    in_b = consts["dtype_bytes"]
    mxu = consts["mxu"]

    grid_m = _ceil_div(m, bm)
    grid_n = _ceil_div(n, bn)
    grid_steps = grid_m * grid_n * _ceil_div(k, bk)
    single = (bm * bk + bk * bn) * in_b + bm * bn * 4
    max_buffers = jnp.floor_divide(
        consts["vmem_usable"], jnp.maximum(single, 1)).astype(mnk.dtype)
    passes = (_ceil_div(bm, mxu) * _ceil_div(bn, mxu) * _ceil_div(bk, mxu))
    total_flops = 2.0 * m * n * k
    if exact:
        # integer-exact paths: numpy adds an int64 subtotal to a float
        # product; both stay < 2**53 so one i64 sum + one convert lands on
        # the identical f64 value with no FMA-contraction hazard.
        bytes_accessed = (in_b * (m * k + k * n) + 4 * m * n).astype(ft)
        refetch = (grid_n * m * k * in_b + grid_m * k * n * in_b
                   + m * n * 4).astype(ft)
        padded = (grid_steps * passes * (2 * mxu ** 3)).astype(ft)
        mxn, mxk, nxk = m * n, m * k, n * k
    else:
        # i32 products overflow above ~46k extents: cast to float early.
        mf, nf, kf = (x.astype(ft) for x in (m, n, k))
        in_f = in_b.astype(ft)
        bytes_accessed = in_f * (mf * kf + kf * nf) + 4.0 * mf * nf
        refetch = (grid_n.astype(ft) * mf * kf * in_f
                   + grid_m.astype(ft) * kf * nf * in_f + mf * nf * 4.0)
        padded = (grid_steps.astype(ft) * passes
                  * (2.0 * mxu.astype(ft) ** 3))
        mxn, mxk, nxk = mf * nf, mf * kf, nf * kf

    S, C = grid_steps.shape[0], grid_steps.shape[1]
    full = lambda v: jnp.full((S, C), v, dtype=ft)
    bcast = lambda a: jnp.broadcast_to(a.astype(ft), (S, C))
    cols = {
        "refetch_bytes": bcast(refetch),
        "naive_compute_ms": bcast(total_flops / consts["peak"] * 1e3),
        "naive_memory_ms": bcast(refetch / consts["hbm_bw"] * 1e3),
        "padded_compute_ms": bcast(padded / consts["peak"] * 1e3),
        # per-step cost as a traced const: two adjacent literal multiplies
        # (1e-7 then 1e3) would be constant-folded into one rounded factor
        "naive_overhead_ms": bcast(grid_steps * consts["step_cost"] * 1e3),
        "m": bcast(m), "n": bcast(n), "k": bcast(k),
        "block_m": bcast(bm), "block_n": bcast(bn), "block_k": bcast(bk),
        "stages": full(2.0), "alpha": full(1.0), "beta": full(0.0),
        "dtype_bytes": bcast(in_b),
        "mxn": bcast(mxn), "mxk": bcast(mxk), "nxk": bcast(nxk),
        "mxnxk": bcast(m.astype(ft) * n * k),
        "total_flops": bcast(total_flops),
        "bytes_accessed": bcast(bytes_accessed),
        "arithmetic_intensity": bcast(
            total_flops / jnp.maximum(bytes_accessed, 1.0)),
        "grid_steps": bcast(grid_steps),
        "vmem_working_set": bcast(single),
        "max_inflight_buffers": bcast(max_buffers),
        "alignment_waste": bcast(padded / jnp.maximum(total_flops, 1.0)),
        "layout_a_t": full(0.0), "layout_b_t": full(0.0),
    }
    feats = jnp.stack([cols[name] for name in NUMERIC_FEATURES], axis=-1)

    def roundup(x, q):
        return jnp.maximum(q, _ceil_div(x, q) * q)

    valid = ((bm <= 2 * roundup(m, 8))
             & (bn <= 2 * roundup(n, 128))
             & (bk <= 2 * roundup(k, 128))
             & (max_buffers >= 1))
    return feats, valid


def table_from_configs(cfgs: Sequence[GemmConfig],
                       chip: ChipSpec | str = TPU_V5E
                       ) -> dict[str, np.ndarray]:
    cols = config_features_batch(cfgs, chip=chip)
    return {k: cols[k] for k in NUMERIC_FEATURES}
