"""GEMM feature engineering — the paper's Algorithm 1 (PREPROCESSDATA +
COMPUTEGEMMCHARS), extended with the TPU-static features the profiler can
derive without running anything (grid size, VMEM working set, occupancy
analogue, alignment waste)."""

from __future__ import annotations

import math

import numpy as np

from repro.core.chips import DTYPE_BYTES, TPU_V5E
from repro.core.hwsim import VMEM_USABLE_FRACTION, GemmConfig

# Columns fed to the models (order matters for the jitted predictor path).
NUMERIC_FEATURES = [
    "m", "n", "k",
    "block_m", "block_n", "block_k",
    "stages", "alpha", "beta", "dtype_bytes",
    "mxn", "mxk", "nxk", "mxnxk",
    "total_flops", "bytes_accessed", "arithmetic_intensity",
    "grid_steps", "vmem_working_set", "max_inflight_buffers",
    "alignment_waste", "layout_a_t", "layout_b_t",
    # physics-informed features (beyond-paper; EXPERIMENTS.md §Perf-pred):
    # naive roofline terms from *published* chip specs + tiling algebra.
    # These are static (pre-execution); the learned model supplies the
    # corrections (layout efficiency, VPU fallback, pipeline overlap, ...).
    "refetch_bytes", "naive_compute_ms", "naive_memory_ms",
    "padded_compute_ms", "naive_overhead_ms",
]
TARGETS = ["runtime_ms", "power_w", "energy_j", "tflops"]


def config_features(cfg: GemmConfig) -> dict[str, float]:
    """Static (pre-execution) features for one GEMM config."""
    c = TPU_V5E
    in_bytes = DTYPE_BYTES[cfg.dtype]
    bm, bn, bk = cfg.block_m, cfg.block_n, cfg.block_k
    grid_steps = (
        math.ceil(cfg.m / bm) * math.ceil(cfg.n / bn) * math.ceil(cfg.k / bk)
    )
    single = (bm * bk + bk * bn) * in_bytes + bm * bn * 4
    max_buffers = int(c.vmem_bytes * VMEM_USABLE_FRACTION // max(single, 1))
    total_flops = 2.0 * cfg.m * cfg.n * cfg.k
    bytes_accessed = in_bytes * (cfg.m * cfg.k + cfg.k * cfg.n) + 4.0 * cfg.m * cfg.n
    mxu = c.mxu_dim
    padded = (
        grid_steps
        * math.ceil(bm / mxu) * math.ceil(bn / mxu) * math.ceil(bk / mxu)
        * (2 * mxu ** 3)
    )
    grid_m = math.ceil(cfg.m / bm)
    grid_n = math.ceil(cfg.n / bn)
    refetch_bytes = (
        grid_n * cfg.m * cfg.k * in_bytes     # A re-read per N-tile
        + grid_m * cfg.k * cfg.n * in_bytes   # B re-read per M-tile
        + cfg.m * cfg.n * 4.0 * (2.0 if cfg.beta != 0.0 else 1.0)
    )
    peak = c.peak(cfg.dtype)
    return {
        "refetch_bytes": refetch_bytes,
        "naive_compute_ms": total_flops / peak * 1e3,
        "naive_memory_ms": refetch_bytes / c.hbm_bw * 1e3,
        "padded_compute_ms": padded / peak * 1e3,
        "naive_overhead_ms": grid_steps * 1e-7 * 1e3,
        "m": float(cfg.m),
        "n": float(cfg.n),
        "k": float(cfg.k),
        "block_m": float(bm),
        "block_n": float(bn),
        "block_k": float(bk),
        "stages": float(cfg.stages),
        "alpha": float(cfg.alpha),
        "beta": float(cfg.beta),
        "dtype_bytes": float(in_bytes),
        "mxn": float(cfg.m * cfg.n),
        "mxk": float(cfg.m * cfg.k),
        "nxk": float(cfg.n * cfg.k),
        "mxnxk": float(cfg.m) * cfg.n * cfg.k,
        "total_flops": total_flops,
        "bytes_accessed": bytes_accessed,
        "arithmetic_intensity": total_flops / max(bytes_accessed, 1.0),
        "grid_steps": float(grid_steps),
        "vmem_working_set": float(single),
        "max_inflight_buffers": float(max_buffers),
        "alignment_waste": padded / max(total_flops, 1.0),
        "layout_a_t": 1.0 if cfg.layout[0] == "t" else 0.0,
        "layout_b_t": 1.0 if cfg.layout[1] == "t" else 0.0,
    }


def features_matrix(cfgs: list[GemmConfig]) -> np.ndarray:
    """(n_cfgs, len(NUMERIC_FEATURES)) feature matrix (for jitted ranking)."""
    rows = np.empty((len(cfgs), len(NUMERIC_FEATURES)))
    for i, cfg in enumerate(cfgs):
        f = config_features(cfg)
        rows[i] = [f[k] for k in NUMERIC_FEATURES]
    return rows


def table_from_configs(cfgs: list[GemmConfig]) -> dict[str, np.ndarray]:
    mat = features_matrix(cfgs)
    return {k: mat[:, i] for i, k in enumerate(NUMERIC_FEATURES)}
