"""Trip-count-aware cost analysis of optimized HLO text.

`compiled.cost_analysis()` is misleading for production JAX programs:

  * while-loop bodies (every `lax.scan` — our layer stacks, q-chunk
    attention, SSM chunk scans) are counted ONCE, not x trip-count, so a
    60-layer model reports ~1/60 of its FLOPs and collectives;
  * "bytes accessed" charges every intermediate op as if it hit HBM,
    ignoring fusion, so memory terms are inflated by an order of magnitude.

This module re-derives roofline-grade costs from the optimized HLO text:

  * FLOPs: every `dot` op contributes 2 x |result| x |contracted dims|
    (batch dims are already in the result shape), recursively through
    fusions/calls, with while bodies multiplied by trip counts parsed from
    their condition computations (`compare(iter, constant(N))`).
  * HBM bytes: counted at fusion boundaries — a fusion (or top-level
    dot/copy/etc.) reads its operands and writes its result once;
    tuple-shuffling ops are free; dynamic-update-slice writes only the
    update slice.
  * Collective wire bytes: same per-op ring formulas as
    `repro.core.roofline.parse_collectives`, x enclosing trip counts.
"""

from __future__ import annotations

import dataclasses
import re

from repro.core.chips import DTYPE_BYTES

_SHAPE_RE = re.compile(
    r"\b([a-z]+\d+(?:e\d+m\d+(?:fn)?)?|pred)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->")
_CALL_ATTR = re.compile(
    r"(?:calls|body|condition|to_apply|branch_computations)="
    r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_RG_DIM_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_RG_RE = re.compile(r"replica_groups=\{([^}]*)\}")

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
# ops that move no data (layout/tuple bookkeeping). Plain `copy` is included
# because the CPU backend materializes while-carry copies that TPU buffer
# assignment elides via donation/aliasing; genuine layout changes appear as
# transpose fusions and are charged at their consumers.
_FREE_OPS = {"tuple", "get-tuple-element", "bitcast", "parameter",
             "constant", "iota", "after-all", "partition-id", "replica-id",
             "reshape", "transpose", "copy", "copy-start", "copy-done",
             "broadcast"}


def _shape_bytes(dtype: str, dims: str) -> float:
    b = DTYPE_BYTES.get(dtype)
    if b is None:
        return 0.0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return float(n) * b


def _all_shapes(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
        out.append((m.group(1), dims))
    return out


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result: str          # result portion (left of opcode)
    args: str            # text in parens after opcode
    attrs: str           # remaining text
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]
    is_entry: bool = False


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s([\w\-]+)\((.*)$")
_PARAM_RE = re.compile(r"([\w.\-]+):\s*([a-z]+\d*[a-z0-9]*\[[0-9,]*\])")
_OPND_RE = re.compile(r"%([\w.\-]+)")


def parse_hlo(text: str) -> tuple[dict[str, Computation], dict[str, list]]:
    """Returns (computations, symbol_table). The symbol table maps
    instruction/parameter names to their result shapes
    [(dtype, dims), ...] — scheduled HLO omits operand shapes inline."""
    comps: dict[str, Computation] = {}
    symtab: dict[str, list] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and "{" in line and "->" in line:
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = Computation(name=m.group(2), instrs=[],
                                  is_entry=bool(m.group(1)))
                comps[cur.name] = cur
                # parameter shapes from the header signature
                hdr = line[: line.rfind("->")]
                for pm in _PARAM_RE.finditer(hdr):
                    symtab.setdefault(pm.group(1),
                                      _all_shapes(pm.group(2)))
            else:
                cur = None  # unrecognized header: don't misattribute instrs
            continue
        if line.strip() == "}":
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, result, opcode, rest = m.groups()
        # split args at the matching close paren
        depth = 1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        args = rest[:i] if depth == 0 else rest
        attrs = rest[i + 1:] if depth == 0 else ""
        ins = Instr(name=name, opcode=opcode, result=result, args=args,
                    attrs=attrs, line=line)
        symtab[name] = _all_shapes(result)
        cur.instrs.append(ins)
    return comps, symtab


def _operand_shapes(ins: Instr, symtab: dict[str, list]) -> list:
    """Shapes of an instruction's operands: inline if present, else via the
    symbol table."""
    inline = _all_shapes(ins.args)
    if inline:
        return inline
    out = []
    for m in _OPND_RE.finditer(ins.args):
        out.extend(symtab.get(m.group(1), []))
    return out


def _dot_flops(ins: Instr, symtab: dict[str, list]) -> float:
    """2 x |result| x |contracted| for dot ops."""
    res_shapes = _all_shapes(ins.result)
    if not res_shapes:
        return 0.0
    dt, rdims = res_shapes[-1]
    out_elems = 1
    for d in rdims:
        out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
    arg_shapes = _operand_shapes(ins, symtab)
    if not m or not arg_shapes:
        return 2.0 * out_elems  # degenerate
    lhs_dims = arg_shapes[0][1]
    contracted = 1
    for idx in m.group(1).split(","):
        if idx.strip() != "" and int(idx) < len(lhs_dims):
            contracted *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contracted


def _group_size(attrs: str, default: int) -> int:
    m = _RG_DIM_RE.search(attrs)
    if m:
        return max(int(m.group(2)), 1)
    m = _RG_RE.search(attrs)
    if m and m.group(1).strip():
        first = m.group(1).split("}")[0].strip("{} ")
        if first:
            return max(len([x for x in first.split(",") if x.strip()]), 1)
    return default


def _collective_wire(ins: Instr, n_chips: int,
                     symtab: dict[str, list]) -> float:
    op = ins.opcode.replace("-start", "")
    if op not in _COLL_OPS:
        return 0.0
    operand = sum(_shape_bytes(d, ",".join(map(str, dims)))
                  for d, dims in _operand_shapes(ins, symtab))
    g = _group_size(ins.attrs + ins.args, n_chips)
    ring = (g - 1) / g if g > 1 else 0.0
    if op == "all-reduce":
        return 2.0 * operand * ring
    if op == "all-gather":
        return operand * (g - 1)
    if op == "reduce-scatter":
        return operand * ring
    if op == "all-to-all":
        return operand * ring
    return operand  # collective-permute


def _shapes_bytes(shapes: list) -> float:
    return sum(_shape_bytes(d, ",".join(map(str, dims)))
               for d, dims in shapes)


# ops that forward data without (significant) movement inside a fusion
_TRANSPARENT = {"convert", "bitcast", "copy", "reshape", "transpose",
                "broadcast"}


def _fused_instr_shapes(u: Instr, symtab: dict[str, list],
                        local: dict[str, list]) -> list:
    inline = _all_shapes(u.args)
    if inline:
        return inline
    out = []
    for m in _OPND_RE.finditer(u.args):
        out.extend(local.get(m.group(1)) or symtab.get(m.group(1), []))
    return out


def _terminal_uses(sub: "Computation", start: str) -> list[tuple[Instr, int]]:
    """Trace a value through transparent ops to its terminal consumers.
    Returns (instr, operand_position) pairs."""
    out: list[tuple[Instr, int]] = []
    frontier = [start]
    seen = set()
    while frontier:
        name = frontier.pop()
        if name in seen:
            continue
        seen.add(name)
        for u in sub.instrs:
            opnds = [m.group(1) for m in _OPND_RE.finditer(u.args)]
            if name not in opnds:
                continue
            if u.opcode in _TRANSPARENT:
                frontier.append(u.name)
            else:
                out.append((u, opnds.index(name)))
    return out


def _slice_like_bytes(uses: list[tuple[Instr, int]],
                      symtab: dict[str, list],
                      sub: "Computation") -> float | None:
    """If every terminal use of a fusion parameter is slice-like, return the
    bytes actually touched; else None (charge the full operand).

      dynamic-slice / gather        -> result bytes
      dus at position 0 (target)    -> 0 (in-place aliased buffer)
      dus at position 1 (update)    -> update bytes
    """
    local = {i.name: _all_shapes(i.result) for i in sub.instrs}
    total = 0.0
    for u, pos in uses:
        if u.opcode in ("dynamic-slice", "gather"):
            total += _shapes_bytes(_all_shapes(u.result))
        elif u.opcode == "dynamic-update-slice":
            if pos == 0:
                total += 0.0
            elif pos == 1:
                shapes = _fused_instr_shapes(u, symtab, local)
                if len(shapes) > 1:
                    total += _shape_bytes(
                        shapes[1][0], ",".join(map(str, shapes[1][1])))
            else:
                total += 0.0  # index operand
        elif u.opcode == "select" and pos == 0:
            total += 0.0  # predicate mask
        else:
            return None
    return total


def _fusion_sub(ins: Instr, comps) -> "Computation | None":
    cm = re.search(r"calls=%?([\w.\-]+)", ins.line)
    return comps.get(cm.group(1)) if cm else None


def _fusion_operand_bytes(ins: Instr, symtab: dict[str, list],
                          comps: dict[str, "Computation"]) -> float:
    """Operand traffic of a fusion, with dataflow-aware corrections for the
    scan patterns (per-layer weight slicing, in-place stacked-cache update)."""
    sub = _fusion_sub(ins, comps)
    opnd_names = [m.group(1) for m in _OPND_RE.finditer(ins.args)]
    if sub is None:
        return _shapes_bytes(_operand_shapes(ins, symtab))
    params: dict[int, str] = {}
    for s_ins in sub.instrs:
        if s_ins.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", s_ins.line)
            if m:
                params[int(m.group(1))] = s_ins.name
    total = 0.0
    for i, opnd in enumerate(opnd_names):
        full = _shapes_bytes(symtab.get(opnd, []))
        pname = params.get(i)
        if pname is None:
            total += full
            continue
        uses = _terminal_uses(sub, pname)
        repl = _slice_like_bytes(uses, symtab, sub) if uses else None
        total += full if repl is None else min(repl, full)
    return total


def _fusion_result_bytes(ins: Instr, symtab: dict[str, list],
                         comps: dict[str, "Computation"]) -> float:
    """Result traffic of a fusion: a dus-rooted fusion (possibly wrapped in
    transparent converts) writes only the update slice."""
    res = _shapes_bytes(_all_shapes(ins.result))
    sub = _fusion_sub(ins, comps)
    if sub is None or not sub.instrs:
        return res
    local = {i.name: _all_shapes(i.result) for i in sub.instrs}
    root = sub.instrs[-1]
    hops = 0
    while root.opcode in _TRANSPARENT and hops < 8:
        m = _OPND_RE.search(root.args)
        nxt = next((i for i in sub.instrs if m and i.name == m.group(1)),
                   None)
        if nxt is None:
            break
        root = nxt
        hops += 1
    if root.opcode == "dynamic-update-slice":
        shapes = _fused_instr_shapes(root, symtab, local)
        if len(shapes) > 1:
            return _shape_bytes(shapes[1][0],
                                ",".join(map(str, shapes[1][1])))
    return res


def _is_layout_artifact(ins: Instr, comps) -> bool:
    """Fusions made only of convert/copy/transpose/slice plumbing are
    CPU-backend materializations (f32 upcasts for dots, layout copies) that
    a TPU compile fuses away; their tensors are charged at the consuming
    compute op instead."""
    sub = _fusion_sub(ins, comps)
    if sub is None:
        return False
    allowed = _TRANSPARENT | {"parameter", "constant", "slice",
                              "dynamic-slice", "bitcast-convert", "iota"}
    return all(i.opcode in allowed for i in sub.instrs)


def _instr_bytes(ins: Instr, symtab: dict[str, list],
                 comps: dict[str, "Computation"] | None = None) -> float:
    """HBM traffic of a top-level (fusion-boundary) op."""
    if ins.opcode in _FREE_OPS or ins.opcode.endswith("-done"):
        return 0.0
    if ins.opcode in ("while", "conditional", "call"):
        return 0.0  # bodies accounted separately
    res = _shapes_bytes(_all_shapes(ins.result))
    if ins.opcode == "fusion" and comps is not None:
        if _is_layout_artifact(ins, comps):
            return 0.0
        return (_fusion_result_bytes(ins, symtab, comps)
                + _fusion_operand_bytes(ins, symtab, comps))
    opnds = _shapes_bytes(_operand_shapes(ins, symtab))
    if ins.opcode == "dynamic-update-slice":
        # reads + writes only the update slice (plus indices, negligible)
        shapes = _operand_shapes(ins, symtab)
        upd = (_shape_bytes(shapes[1][0], ",".join(map(str, shapes[1][1])))
               if len(shapes) > 1 else 0.0)
        return 2.0 * upd
    return res + opnds


@dataclasses.dataclass
class HloCost:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    collective_by_op: dict[str, float]
    while_trips: dict[str, int]


def _trip_count(cond: Computation) -> int:
    """Parse `compare(iter, constant(N)), direction=LT` style conditions."""
    best = 1
    for ins in cond.instrs:
        if ins.opcode == "compare":
            for m in _CONST_RE.finditer(ins.args):
                best = max(best, int(m.group(1)))
            # constant may be a named operand; search the whole computation
    if best == 1:
        for ins in cond.instrs:
            if ins.opcode == "constant":
                m = _CONST_RE.search(ins.line)
                if m:
                    best = max(best, int(m.group(1)))
    return max(best, 1)


def analyze_hlo(text: str, n_chips: int) -> HloCost:
    comps, symtab = parse_hlo(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None and comps:
        entry = list(comps.values())[0]
    if entry is None:
        return HloCost(0.0, 0.0, 0.0, {}, {})

    memo: dict[str, tuple[float, float, float, dict]] = {}
    trips_seen: dict[str, int] = {}

    def comp_cost(name: str, stack=()) -> tuple[float, float, float, dict]:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return (0.0, 0.0, 0.0, {})
        c = comps[name]
        fl = by = co = 0.0
        cby: dict[str, float] = {}
        for ins in c.instrs:
            fl += _dot_flops(ins, symtab) if ins.opcode == "dot" else 0.0
            wire = _collective_wire(ins, n_chips, symtab)
            if wire:
                op = ins.opcode.replace("-start", "")
                co += wire
                cby[op] = cby.get(op, 0.0) + wire
            by += _instr_bytes(ins, symtab, comps)
            if ins.opcode == "while":
                body = cond = None
                bm = re.search(r"body=%?([\w.\-]+)", ins.line)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.line)
                body = bm.group(1) if bm else None
                cond = cm.group(1) if cm else None
                trips = _trip_count(comps[cond]) if cond in comps else 1
                if body:
                    trips_seen[body] = trips
                    bfl, bby, bco, bcby = comp_cost(body, stack + (name,))
                    fl += bfl * trips
                    by += bby * trips
                    co += bco * trips
                    for k, v in bcby.items():
                        cby[k] = cby.get(k, 0.0) + v * trips
            elif ins.opcode in ("fusion", "call", "conditional",
                                "custom-call"):
                for mm in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)",
                                      ins.line):
                    sub = mm.group(1)
                    # fusions: flops+collectives inside count; bytes counted
                    # at the boundary already
                    sfl, sby, sco, scby = comp_cost(sub, stack + (name,))
                    fl += sfl
                    co += sco
                    for k, v in scby.items():
                        cby[k] = cby.get(k, 0.0) + v
                    if ins.opcode != "fusion":
                        by += sby
                for mm in re.finditer(
                        r"branch_computations=\{([^}]*)\}", ins.line):
                    for sub in mm.group(1).replace("%", "").split(","):
                        sfl, sby, sco, scby = comp_cost(sub.strip(),
                                                        stack + (name,))
                        fl += sfl
                        by += sby
                        co += sco
                        for k, v in scby.items():
                            cby[k] = cby.get(k, 0.0) + v
        memo[name] = (fl, by, co, cby)
        return memo[name]

    fl, by, co, cby = comp_cost(entry.name)
    return HloCost(flops=fl, hbm_bytes=by, collective_bytes=co,
                   collective_by_op=cby, while_trips=dict(trips_seen))
