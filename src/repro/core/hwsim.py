"""Calibrated analytical TPU timing/power simulator.

This container has no TPU (or GPU), so — per the reproduction plan in
DESIGN.md §2 — a physics-style analytical model of a TPU v5e core plays the
role the RTX 4070 plays in the paper: it is *the measured hardware* that the
profiling harness sweeps and the ML models learn to predict. The functional
forms encode the paper's observed phenomena translated to TPU
microarchitecture:

  * MXU quantization: a (bm, bn, bk) block matmul consumes
    ceil(bm/128)*ceil(bn/128)*ceil(bk/128) systolic passes — misaligned or
    tiny tiles waste lanes exactly the way sub-warp blocks waste SPs in the
    paper's tile=1/4 study.
  * VMEM-limited concurrency (the paper's Table I SM-occupancy cliff):
    double-buffered block working sets must fit in VMEM; when they don't,
    the pipeline degrades to serial HBM<->compute, and `max_inflight_buffers`
    (our occupancy analogue) drops to 1.
  * Grid overhead: each grid step has a fixed sequencer cost, so tiny tiles
    explode the grid (the paper's "block scheduler flooding" analogue).
  * Roofline coupling: runtime = startup + max(compute, memory) when
    pipelined, + grid overhead; power = idle + duty-cycle-weighted MXU and
    HBM dynamic power, saturating toward TDP for large compute-bound GEMMs
    (the paper's 80-100W base -> stepped saturation behaviour).

Measurement noise (multiplicative lognormal on runtime, additive Gaussian on
power, occasional thermal-drift samples) keeps the learning problem honest —
the ML models see a noisy, non-deterministic "hardware", not a formula.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.chips import DTYPE_BYTES, TPU_V5E, ChipSpec

# Fixed microarchitectural cost constants (calibration surface).
GRID_STEP_OVERHEAD_S = 8.0e-8     # per grid-step sequencer cost
KERNEL_STARTUP_S = 4.0e-6         # pallas_call launch + pipeline warmup
DMA_ISSUE_OVERHEAD_S = 2.0e-8     # per-block DMA issue cost
VMEM_USABLE_FRACTION = 0.75       # compiler scratch eats the rest
LAYOUT_EFFICIENCY = {             # HBM efficiency per operand layout
    "n": 1.0,                     # contiguous reads
    "t": 0.62,                    # strided (transposed) reads
}


@dataclasses.dataclass(frozen=True)
class GemmConfig:
    """One GEMM measurement point — mirrors the paper's swept parameters."""

    m: int
    n: int
    k: int
    block_m: int = 128
    block_n: int = 128
    block_k: int = 512
    dtype: str = "bf16"            # input dtype; accumulation is fp32
    layout: str = "nn"             # nn / nt / tn / tt
    alpha: float = 1.0
    beta: float = 0.0
    stages: int = 2                # pipeline depth (double buffering = 2)

    def key(self) -> tuple:
        return dataclasses.astuple(self)


@dataclasses.dataclass
class GemmTelemetry:
    """What the 'hardware' reports for one run (the profiler's row)."""

    runtime_ms: float
    power_w: float
    energy_j: float
    tflops: float
    # ncu-style derived metrics
    compute_time_ms: float
    memory_time_ms: float
    overhead_ms: float
    mxu_utilization: float         # useful FLOPs / peak over runtime
    hbm_utilization: float
    vmem_working_set_bytes: int
    max_inflight_buffers: int      # occupancy analogue (paper Table I)
    pipelined: bool
    grid_steps: int
    arithmetic_intensity: float
    bound: str                     # "compute" | "memory" | "overhead"
    temperature_c: float
    valid: bool                    # False => config uncompilable (VMEM OOM)


class TpuGemmSimulator:
    """Analytical timing/power model of a tiled GEMM on one TPU core."""

    def __init__(self, chip: ChipSpec = TPU_V5E, noise: float = 0.03,
                 seed: int | None = 0):
        self.chip = chip
        self.noise = noise
        self._rng = np.random.default_rng(seed)
        self._temp_c = 42.0  # slow thermal state, drifts with load

    # ---------- deterministic core model ----------

    def _analyze(self, cfg: GemmConfig) -> GemmTelemetry:
        c = self.chip
        in_bytes = DTYPE_BYTES[cfg.dtype]
        acc_bytes = 4  # fp32 accumulators
        bm, bn, bk = cfg.block_m, cfg.block_n, cfg.block_k

        grid_m = math.ceil(cfg.m / bm)
        grid_n = math.ceil(cfg.n / bn)
        steps_k = math.ceil(cfg.k / bk)
        grid_steps = grid_m * grid_n * steps_k

        # --- VMEM working set & occupancy analogue ---
        block_in_bytes = (bm * bk + bk * bn) * in_bytes
        block_out_bytes = bm * bn * acc_bytes
        single = block_in_bytes + block_out_bytes
        usable = c.vmem_bytes * VMEM_USABLE_FRACTION
        max_buffers = int(usable // max(single, 1))
        if max_buffers < 1:
            # Block does not fit in VMEM at all: uncompilable config.
            return GemmTelemetry(
                runtime_ms=float("nan"), power_w=float("nan"),
                energy_j=float("nan"), tflops=0.0, compute_time_ms=0.0,
                memory_time_ms=0.0, overhead_ms=0.0, mxu_utilization=0.0,
                hbm_utilization=0.0, vmem_working_set_bytes=int(single),
                max_inflight_buffers=0, pipelined=False,
                grid_steps=grid_steps, arithmetic_intensity=0.0,
                bound="invalid", temperature_c=self._temp_c, valid=False,
            )
        stages = min(cfg.stages, max_buffers)
        pipelined = stages >= 2

        # --- compute time: MXU systolic passes with quantization waste ---
        mxu = c.mxu_dim
        passes_per_step = (
            math.ceil(bm / mxu) * math.ceil(bn / mxu) * math.ceil(bk / mxu)
        )
        pass_flops = 2 * mxu * mxu * mxu
        padded_flops = grid_steps * passes_per_step * pass_flops
        useful_flops = 2.0 * cfg.m * cfg.n * cfg.k
        # sub-sublane blocks fall off the MXU fast path onto the VPU
        vpu_penalty = 1.0
        if bm < c.sublane or bn < c.sublane:
            vpu_penalty = 24.0
        compute_s = padded_flops / c.peak(cfg.dtype) * vpu_penalty

        # --- memory time: HBM traffic with layout efficiency ---
        lay_a = LAYOUT_EFFICIENCY[cfg.layout[0]]
        lay_b = LAYOUT_EFFICIENCY[cfg.layout[1]]
        a_traffic = grid_n * cfg.m * cfg.k * in_bytes  # A refetched per N-tile
        b_traffic = grid_m * cfg.k * cfg.n * in_bytes  # B refetched per M-tile
        c_traffic = cfg.m * cfg.n * acc_bytes
        if cfg.beta != 0.0:
            c_traffic *= 2  # read-modify-write
        hbm_bytes = a_traffic / lay_a + b_traffic / lay_b + c_traffic
        memory_s = hbm_bytes / c.hbm_bw

        # --- fixed overheads ---
        overhead_s = (
            KERNEL_STARTUP_S
            + grid_steps * GRID_STEP_OVERHEAD_S
            + grid_steps * (2 + (cfg.beta != 0)) * DMA_ISSUE_OVERHEAD_S
        )

        inner_s = max(compute_s, memory_s) if pipelined else compute_s + memory_s
        runtime_s = inner_s + overhead_s

        actual_bytes = a_traffic + b_traffic + c_traffic
        tflops = useful_flops / runtime_s / 1e12
        mxu_util = useful_flops / (runtime_s * c.peak(cfg.dtype))
        hbm_util = actual_bytes / (runtime_s * c.hbm_bw)
        if overhead_s > inner_s:
            bound = "overhead"
        elif compute_s >= memory_s:
            bound = "compute"
        else:
            bound = "memory"

        # --- power: idle + duty-weighted dynamic terms, TDP-capped ---
        duty_mxu = min(compute_s / runtime_s, 1.0) / max(vpu_penalty ** 0.5, 1.0)
        duty_hbm = min(memory_s / runtime_s, 1.0)
        dtype_power_scale = 1.0 if cfg.dtype == "bf16" else 0.82
        power_w = (
            c.idle_power_w
            + c.mxu_power_w * duty_mxu * dtype_power_scale
            + c.hbm_power_w * duty_hbm
        )
        power_w = min(power_w, c.tdp_w)

        return GemmTelemetry(
            runtime_ms=runtime_s * 1e3,
            power_w=power_w,
            energy_j=power_w * runtime_s,
            tflops=tflops,
            compute_time_ms=compute_s * 1e3,
            memory_time_ms=memory_s * 1e3,
            overhead_ms=overhead_s * 1e3,
            mxu_utilization=mxu_util,
            hbm_utilization=hbm_util,
            vmem_working_set_bytes=int(single * stages),
            max_inflight_buffers=max_buffers,
            pipelined=pipelined,
            grid_steps=grid_steps,
            arithmetic_intensity=useful_flops / max(actual_bytes, 1),
            bound=bound,
            temperature_c=self._temp_c,
            valid=True,
        )

    # ---------- public API ----------

    def analyze(self, cfg: GemmConfig) -> GemmTelemetry:
        """Noise-free analytical telemetry (the 'oracle' view)."""
        return self._analyze(cfg)

    def measure(self, cfg: GemmConfig) -> GemmTelemetry:
        """One noisy 'hardware measurement' — what the profiler records."""
        t = self._analyze(cfg)
        if not t.valid:
            return t
        rng = self._rng
        # thermal state follows load slowly
        target_temp = 40.0 + 35.0 * (t.power_w / self.chip.tdp_w)
        self._temp_c += 0.2 * (target_temp - self._temp_c) + rng.normal(0, 0.3)
        runtime_ms = t.runtime_ms * float(np.exp(rng.normal(0.0, self.noise)))
        # rare scheduler hiccup (long-tail), like a shared-machine blip
        if rng.random() < 0.01:
            runtime_ms *= 1.0 + abs(rng.normal(0.05, 0.05))
        power_w = t.power_w + float(rng.normal(0.0, 1.5)) + 0.08 * (self._temp_c - 42.0)
        power_w = float(np.clip(power_w, self.chip.idle_power_w * 0.9, self.chip.tdp_w))
        energy_j = power_w * runtime_ms / 1e3
        tflops = (2.0 * cfg.m * cfg.n * cfg.k) / (runtime_ms / 1e3) / 1e12
        return dataclasses.replace(
            t, runtime_ms=runtime_ms, power_w=power_w, energy_j=energy_j,
            tflops=tflops, temperature_c=self._temp_c,
        )

    def occupancy_report(self, tiles: list[int], *, bk: int | None = None,
                         dtype: str = "bf16") -> dict[int, int]:
        """Paper Table I analogue: max in-flight VMEM buffers per tile size."""
        out = {}
        for t in tiles:
            cfg = GemmConfig(m=4096, n=4096, k=4096, block_m=t, block_n=t,
                             block_k=bk if bk is not None else t, dtype=dtype)
            out[t] = self._analyze(cfg).max_inflight_buffers
        return out
