"""Calibrated analytical GEMM timing/power simulator (multi-chip).

This container has no TPU (or GPU), so — per the reproduction plan in
DESIGN.md §2 — a physics-style analytical model of the chip plays the role
the RTX 4070 plays in the paper: it is *the measured hardware* that the
profiling harness sweeps and the ML models learn to predict. Any `ChipSpec`
from `chips.get_chip` can back the simulator; TPU v5e is the default target
and an RTX-4070-calibrated spec mirrors the paper's platform. The functional
forms encode the paper's observed phenomena translated to TPU
microarchitecture:

  * MXU quantization: a (bm, bn, bk) block matmul consumes
    ceil(bm/128)*ceil(bn/128)*ceil(bk/128) systolic passes — misaligned or
    tiny tiles waste lanes exactly the way sub-warp blocks waste SPs in the
    paper's tile=1/4 study.
  * VMEM-limited concurrency (the paper's Table I SM-occupancy cliff):
    double-buffered block working sets must fit in VMEM; when they don't,
    the pipeline degrades to serial HBM<->compute, and `max_inflight_buffers`
    (our occupancy analogue) drops to 1.
  * Grid overhead: each grid step has a fixed sequencer cost, so tiny tiles
    explode the grid (the paper's "block scheduler flooding" analogue).
  * Roofline coupling: runtime = startup + max(compute, memory) when
    pipelined, + grid overhead; power = idle + duty-cycle-weighted MXU and
    HBM dynamic power, saturating toward TDP for large compute-bound GEMMs
    (the paper's 80-100W base -> stepped saturation behaviour).

Measurement noise (multiplicative lognormal on runtime, additive Gaussian on
power, occasional thermal-drift samples) keeps the learning problem honest —
the ML models see a noisy, non-deterministic "hardware", not a formula.

The analytical model is fully vectorized: `analyze_batch` / `measure_batch`
evaluate whole arrays of `GemmConfig`s at once and return a struct-of-arrays
telemetry table (the profiler's native format). The scalar `analyze` /
`measure` are thin batch-of-one wrappers, so there is a single source of
truth for the formulas.
"""

from __future__ import annotations

import dataclasses
from collections.abc import Sequence

import numpy as np

from repro.core.chips import DTYPE_BYTES, TPU_V5E, ChipSpec, get_chip

# Fixed microarchitectural cost constants (calibration surface).
GRID_STEP_OVERHEAD_S = 8.0e-8     # per grid-step sequencer cost
KERNEL_STARTUP_S = 4.0e-6         # pallas_call launch + pipeline warmup
DMA_ISSUE_OVERHEAD_S = 2.0e-8     # per-block DMA issue cost
VMEM_USABLE_FRACTION = 0.75       # compiler scratch eats the rest
VPU_FALLBACK_PENALTY = 24.0       # sub-sublane blocks miss the MXU fast path
LAYOUT_EFFICIENCY = {             # HBM efficiency per operand layout
    "n": 1.0,                     # contiguous reads
    "t": 0.62,                    # strided (transposed) reads
}

# Struct-of-arrays telemetry column order (matches GemmTelemetry fields).
TELEMETRY_COLUMNS = (
    "runtime_ms", "power_w", "energy_j", "tflops",
    "compute_time_ms", "memory_time_ms", "overhead_ms",
    "mxu_utilization", "hbm_utilization",
    "vmem_working_set_bytes", "max_inflight_buffers", "pipelined",
    "grid_steps", "arithmetic_intensity", "bound", "temperature_c", "valid",
)


@dataclasses.dataclass(frozen=True)
class GemmConfig:
    """One GEMM measurement point — mirrors the paper's swept parameters."""

    m: int
    n: int
    k: int
    block_m: int = 128
    block_n: int = 128
    block_k: int = 512
    dtype: str = "bf16"            # input dtype; accumulation is fp32
    layout: str = "nn"             # nn / nt / tn / tt
    alpha: float = 1.0
    beta: float = 0.0
    stages: int = 2                # pipeline depth (double buffering = 2)

    def key(self) -> tuple:
        return dataclasses.astuple(self)


@dataclasses.dataclass
class GemmTelemetry:
    """What the 'hardware' reports for one run (the profiler's row)."""

    runtime_ms: float
    power_w: float
    energy_j: float
    tflops: float
    # ncu-style derived metrics
    compute_time_ms: float
    memory_time_ms: float
    overhead_ms: float
    mxu_utilization: float         # useful FLOPs / peak over runtime
    hbm_utilization: float
    vmem_working_set_bytes: int
    max_inflight_buffers: int      # occupancy analogue (paper Table I)
    pipelined: bool
    grid_steps: int
    arithmetic_intensity: float
    bound: str                     # "compute" | "memory" | "overhead"
    temperature_c: float
    valid: bool                    # False => config uncompilable (VMEM OOM)


def config_arrays(cfgs: Sequence[GemmConfig]) -> dict[str, np.ndarray]:
    """Struct-of-arrays view of a config list (field extraction only)."""
    return {
        "m": np.array([c.m for c in cfgs], dtype=np.int64),
        "n": np.array([c.n for c in cfgs], dtype=np.int64),
        "k": np.array([c.k for c in cfgs], dtype=np.int64),
        "block_m": np.array([c.block_m for c in cfgs], dtype=np.int64),
        "block_n": np.array([c.block_n for c in cfgs], dtype=np.int64),
        "block_k": np.array([c.block_k for c in cfgs], dtype=np.int64),
        "stages": np.array([c.stages for c in cfgs], dtype=np.int64),
        "alpha": np.array([c.alpha for c in cfgs], dtype=np.float64),
        "beta": np.array([c.beta for c in cfgs], dtype=np.float64),
        "dtype": np.array([c.dtype for c in cfgs], dtype=object),
        "layout": np.array([c.layout for c in cfgs], dtype=object),
        "dtype_bytes": np.array([DTYPE_BYTES[c.dtype] for c in cfgs],
                                dtype=np.int64),
        "layout_a_eff": np.array(
            [LAYOUT_EFFICIENCY[c.layout[0]] for c in cfgs], dtype=np.float64),
        "layout_b_eff": np.array(
            [LAYOUT_EFFICIENCY[c.layout[1]] for c in cfgs], dtype=np.float64),
    }


def chip_peak_array(chip: ChipSpec, dtypes: np.ndarray) -> np.ndarray:
    """Per-config peak FLOP/s for a dtype column."""
    return np.array([chip.peak_flops[d] for d in dtypes], dtype=np.float64)


def _ceil_div(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return -(-a // b)


def _linear_recurrence(x0: float, a: float, b: np.ndarray) -> np.ndarray:
    """Vectorized s_i = a*s_{i-1} + b_i with s_{-1} = x0.

    Chunked closed form (s_i = a^{i+1}(x0 + sum b_j a^{-j-1})) so the decay
    powers stay inside float64 range; contributions older than a chunk decay
    below machine precision anyway.
    """
    out = np.empty_like(b, dtype=np.float64)
    state = float(x0)
    chunk = 256
    for start in range(0, len(b), chunk):
        bb = b[start:start + chunk]
        powers = a ** np.arange(1, len(bb) + 1)
        seg = powers * (state + np.cumsum(bb / powers))
        out[start:start + chunk] = seg
        state = float(seg[-1])
    return out


def telemetry_row(table: dict[str, np.ndarray], i: int) -> GemmTelemetry:
    """Materialize one struct-of-arrays row as a GemmTelemetry."""
    return GemmTelemetry(
        runtime_ms=float(table["runtime_ms"][i]),
        power_w=float(table["power_w"][i]),
        energy_j=float(table["energy_j"][i]),
        tflops=float(table["tflops"][i]),
        compute_time_ms=float(table["compute_time_ms"][i]),
        memory_time_ms=float(table["memory_time_ms"][i]),
        overhead_ms=float(table["overhead_ms"][i]),
        mxu_utilization=float(table["mxu_utilization"][i]),
        hbm_utilization=float(table["hbm_utilization"][i]),
        vmem_working_set_bytes=int(table["vmem_working_set_bytes"][i]),
        max_inflight_buffers=int(table["max_inflight_buffers"][i]),
        pipelined=bool(table["pipelined"][i]),
        grid_steps=int(table["grid_steps"][i]),
        arithmetic_intensity=float(table["arithmetic_intensity"][i]),
        bound=str(table["bound"][i]),
        temperature_c=float(table["temperature_c"][i]),
        valid=bool(table["valid"][i]),
    )


class TpuGemmSimulator:
    """Analytical timing/power model of a tiled GEMM on one chip.

    `chip` accepts a ChipSpec or a registry name ("tpu_v5e", "rtx4070").
    """

    def __init__(self, chip: ChipSpec | str = TPU_V5E, noise: float = 0.03,
                 seed: int | None = 0):
        self.chip = get_chip(chip)
        self.noise = noise
        self._rng = np.random.default_rng(seed)
        self._temp_c = 42.0  # slow thermal state, drifts with load

    # ---------- deterministic core model (vectorized) ----------

    def analyze_batch(self, cfgs: Sequence[GemmConfig],
                      arrays: dict[str, np.ndarray] | None = None
                      ) -> dict[str, np.ndarray]:
        """Noise-free analytical telemetry for a whole batch of configs.

        Returns a struct-of-arrays table (TELEMETRY_COLUMNS). Invalid
        (VMEM-OOM) configs get NaN runtime/power/energy and valid=False,
        exactly like the scalar path.
        """
        c = self.chip
        arr = arrays if arrays is not None else config_arrays(cfgs)
        m, n, k = arr["m"], arr["n"], arr["k"]
        bm, bn, bk = arr["block_m"], arr["block_n"], arr["block_k"]
        in_bytes = arr["dtype_bytes"]
        acc_bytes = 4  # fp32 accumulators
        beta_nz = arr["beta"] != 0.0

        grid_m = _ceil_div(m, bm)
        grid_n = _ceil_div(n, bn)
        steps_k = _ceil_div(k, bk)
        grid_steps = grid_m * grid_n * steps_k

        # --- VMEM working set & occupancy analogue ---
        block_in_bytes = (bm * bk + bk * bn) * in_bytes
        block_out_bytes = bm * bn * acc_bytes
        single = block_in_bytes + block_out_bytes
        usable = c.vmem_bytes * VMEM_USABLE_FRACTION
        max_buffers = (usable // np.maximum(single, 1)).astype(np.int64)
        valid = max_buffers >= 1
        stages = np.minimum(arr["stages"], max_buffers)
        pipelined = valid & (stages >= 2)

        # --- compute time: MXU systolic passes with quantization waste ---
        mxu = c.mxu_dim
        passes_per_step = (
            _ceil_div(bm, mxu) * _ceil_div(bn, mxu) * _ceil_div(bk, mxu)
        )
        pass_flops = 2 * mxu * mxu * mxu
        padded_flops = grid_steps * passes_per_step * pass_flops
        useful_flops = 2.0 * m * n * k
        # sub-sublane blocks fall off the MXU fast path onto the VPU
        vpu_penalty = np.where((bm < c.sublane) | (bn < c.sublane),
                               VPU_FALLBACK_PENALTY, 1.0)
        peak = chip_peak_array(c, arr["dtype"])
        compute_s = padded_flops / peak * vpu_penalty

        # --- memory time: HBM traffic with layout efficiency ---
        lay_a = arr["layout_a_eff"]
        lay_b = arr["layout_b_eff"]
        a_traffic = grid_n * m * k * in_bytes  # A refetched per N-tile
        b_traffic = grid_m * k * n * in_bytes  # B refetched per M-tile
        c_traffic = m * n * acc_bytes
        c_traffic = np.where(beta_nz, c_traffic * 2, c_traffic)  # RMW
        hbm_bytes = a_traffic / lay_a + b_traffic / lay_b + c_traffic
        memory_s = hbm_bytes / c.hbm_bw

        # --- fixed overheads ---
        dma_per_step = 2 + beta_nz.astype(np.int64)
        overhead_s = (
            KERNEL_STARTUP_S
            + grid_steps * GRID_STEP_OVERHEAD_S
            + grid_steps * dma_per_step * DMA_ISSUE_OVERHEAD_S
        )

        inner_s = np.where(pipelined, np.maximum(compute_s, memory_s),
                           compute_s + memory_s)
        runtime_s = inner_s + overhead_s

        actual_bytes = a_traffic + b_traffic + c_traffic
        tflops = useful_flops / runtime_s / 1e12
        mxu_util = useful_flops / (runtime_s * peak)
        hbm_util = actual_bytes / (runtime_s * c.hbm_bw)
        bound = np.where(
            overhead_s > inner_s, "overhead",
            np.where(compute_s >= memory_s, "compute", "memory"),
        ).astype(object)
        bound[~valid] = "invalid"

        # --- power: idle + duty-weighted dynamic terms, TDP-capped ---
        duty_mxu = (np.minimum(compute_s / runtime_s, 1.0)
                    / np.maximum(vpu_penalty ** 0.5, 1.0))
        duty_hbm = np.minimum(memory_s / runtime_s, 1.0)
        dtype_power_scale = np.where(arr["dtype"] == "bf16", 1.0, 0.82)
        power_w = (
            c.idle_power_w
            + c.mxu_power_w * duty_mxu * dtype_power_scale
            + c.hbm_power_w * duty_hbm
        )
        power_w = np.minimum(power_w, c.tdp_w)

        # invalid rows: NaN runtime/power/energy, zeroed derived metrics
        zero = valid.astype(np.float64)
        table = {
            "runtime_ms": np.where(valid, runtime_s * 1e3, np.nan),
            "power_w": np.where(valid, power_w, np.nan),
            "energy_j": np.where(valid, power_w * runtime_s, np.nan),
            "tflops": tflops * zero,
            "compute_time_ms": compute_s * 1e3 * zero,
            "memory_time_ms": memory_s * 1e3 * zero,
            "overhead_ms": overhead_s * 1e3 * zero,
            "mxu_utilization": mxu_util * zero,
            "hbm_utilization": hbm_util * zero,
            "vmem_working_set_bytes": np.where(valid, single * stages,
                                               single).astype(np.int64),
            "max_inflight_buffers": max_buffers,
            "pipelined": pipelined,
            "grid_steps": grid_steps,
            "arithmetic_intensity": (useful_flops
                                     / np.maximum(actual_bytes, 1)) * zero,
            "bound": bound,
            "temperature_c": np.full(len(single), self._temp_c),
            "valid": valid,
        }
        return table

    def measure_batch(self, cfgs: Sequence[GemmConfig],
                      arrays: dict[str, np.ndarray] | None = None
                      ) -> dict[str, np.ndarray]:
        """Noisy batched 'hardware measurement' — what the profiler records.

        Semantics match running the scalar `measure` sequentially over
        `cfgs`: the thermal state walks across the batch in order (invalid
        configs don't touch it), and the same noise processes apply —
        multiplicative lognormal runtime noise, rare long-tail scheduler
        hiccups, additive Gaussian + thermal-coupled power noise. The RNG is
        consumed column-wise rather than row-wise, so draws are
        statistically identical to (not bit-equal with) the scalar loop.
        """
        arr = arrays if arrays is not None else config_arrays(cfgs)
        t = self.analyze_batch(cfgs, arrays=arr)
        valid = t["valid"]
        n_valid = int(valid.sum())
        out = {k: np.copy(v) for k, v in t.items()}
        if n_valid == 0:
            return out
        rng = self._rng
        chip = self.chip

        # thermal state follows load slowly (only valid runs heat the chip)
        power0 = t["power_w"][valid]
        target_temp = 40.0 + 35.0 * (power0 / chip.tdp_w)
        temp_noise = rng.normal(0, 0.3, n_valid)
        temps = _linear_recurrence(self._temp_c, 0.8,
                                   0.2 * target_temp + temp_noise)

        runtime = t["runtime_ms"][valid] * np.exp(
            rng.normal(0.0, self.noise, n_valid))
        # rare scheduler hiccup (long-tail), like a shared-machine blip
        hiccup = rng.random(n_valid) < 0.01
        hiccup_mag = 1.0 + np.abs(rng.normal(0.05, 0.05, n_valid))
        runtime = np.where(hiccup, runtime * hiccup_mag, runtime)

        power = (power0 + rng.normal(0.0, 1.5, n_valid)
                 + 0.08 * (temps - 42.0))
        power = np.clip(power, chip.idle_power_w * 0.9, chip.tdp_w)
        energy = power * runtime / 1e3
        useful_flops = 2.0 * arr["m"] * arr["n"] * arr["k"]
        tflops = useful_flops[valid] / (runtime / 1e3) / 1e12

        out["runtime_ms"][valid] = runtime
        out["power_w"][valid] = power
        out["energy_j"][valid] = energy
        out["tflops"][valid] = tflops
        # row i sees the state after the last valid row <= i (scalar parity)
        states = np.concatenate(([self._temp_c], temps))
        out["temperature_c"] = states[np.cumsum(valid)]
        self._temp_c = float(temps[-1])
        return out

    # ---------- scalar API (thin batch-of-one wrappers) ----------

    def analyze(self, cfg: GemmConfig) -> GemmTelemetry:
        """Noise-free analytical telemetry (the 'oracle' view)."""
        return telemetry_row(self.analyze_batch([cfg]), 0)

    def measure(self, cfg: GemmConfig) -> GemmTelemetry:
        """One noisy 'hardware measurement' — what the profiler records."""
        return telemetry_row(self.measure_batch([cfg]), 0)

    def occupancy_report(self, tiles: list[int], *, bk: int | None = None,
                         dtype: str = "bf16") -> dict[int, int]:
        """Paper Table I analogue: max in-flight VMEM buffers per tile size."""
        cfgs = [GemmConfig(m=4096, n=4096, k=4096, block_m=t, block_n=t,
                           block_k=bk if bk is not None else t, dtype=dtype)
                for t in tiles]
        buffers = self.analyze_batch(cfgs)["max_inflight_buffers"]
        return {t: int(b) for t, b in zip(tiles, buffers)}


# ---------------------------------------------------------------------------
# Collective cost model (sharded serving).
#
# A ring collective on `tp` chips is decomposed the way the SUMMA pipelining
# exemplars decompose a broadcast cycle: a host/launch phase (fixed latency
# per collective issue), a wire phase (ring bytes at the chip's aggregate
# link bandwidth), and a drain phase folded into the wire term — the
# H2D / compute / D2H shape of the paper's transfer analysis, applied to
# chip-to-chip links instead of the PCIe bus. When the projection is split
# into `chunks` interleaved column chunks (double-buffered in
# `distributed.tp`), every chunk's wire time except the last can hide under
# the next chunk's GEMM, bounded by the compute actually available.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParkedEstimate:
    """Energy of a chip fleet parked (or gap-idling) at its idle floor.

    The race-to-idle ledger: a fleet member that is not dispatching work
    still burns `ChipSpec.idle_power_w` per chip for the whole interval,
    so draining a lagging engine wide and parking it converts high-power
    straggler time into cheap idle-floor time."""

    power_w: float         # idle floor of the whole fleet (per-chip x n)
    duration_s: float      # parked interval (model-clock seconds)
    n_chips: int
    energy_j: float        # power_w * duration_s


def parked_cost(duration_s: float, *, chip: ChipSpec | str = TPU_V5E,
                n_chips: int = 1) -> ParkedEstimate:
    """Price `n_chips` of `chip` sitting parked for `duration_s` seconds.

    A parked engine dispatches nothing: no MXU/HBM/ICI duty, so power is
    exactly the chip's idle floor. This is the counterpart of
    `collective_cost`/`TpuGemmSimulator` for the scheduler's third
    decision — whether racing a queue down and idling beats trickling it
    across more engines ("Racing to Idle")."""
    chip = get_chip(chip)
    n = max(int(n_chips), 1)
    dur = max(float(duration_s), 0.0)
    power = chip.idle_power_w * n
    return ParkedEstimate(power_w=power, duration_s=dur, n_chips=n,
                          energy_j=power * dur)


@dataclasses.dataclass(frozen=True)
class CollectiveEstimate:
    """Predicted cost of one step's collective traffic on one chip."""

    wire_bytes: float      # ring bytes leaving this chip per step
    wire_s: float          # wire_bytes / link bandwidth (unoverlapped)
    launch_s: float        # per-collective issue latency, summed
    hidden_s: float        # wire time hidden behind interleaved GEMM chunks
    exposed_s: float       # wire_s + launch_s - hidden_s (adds to step time)
    overlap_factor: float  # hidden_s / wire_s in [0, 1]


def collective_cost(wire_bytes: float, *, chip: ChipSpec | str = TPU_V5E,
                    tp: int = 1, n_collectives: float = 0.0,
                    overlap_chunks: int = 1,
                    compute_s: float = 0.0) -> CollectiveEstimate:
    """Price one step's collective traffic for a `tp`-way sharded fleet.

    `wire_bytes` is the per-chip ring traffic the step issues (already
    scaled by the (tp-1)/tp ring factor — see
    `models.config.collective_wire_bytes`); `n_collectives` counts logical
    collective phases (each pays the chip's launch latency once — chunk
    sub-issues ride the already-open double-buffered channel); `compute_s`
    bounds how much wire time the interleaved-chunk pipeline can hide.
    """
    chip = get_chip(chip)
    if tp <= 1 or wire_bytes <= 0.0 or chip.link_bw_gbs <= 0.0:
        return CollectiveEstimate(0.0, 0.0, 0.0, 0.0, 0.0, 0.0)
    wire_s = float(wire_bytes) / (chip.link_bw_gbs * 1e9)
    chunks = max(int(overlap_chunks), 1)
    launch_s = float(n_collectives) * chip.link_launch_s
    # double-buffered chunks: all but the trailing 1/chunks of the wire can
    # overlap the next chunk's GEMM, but never more than the compute there is
    hidden_s = min(wire_s * (1.0 - 1.0 / chunks), max(compute_s, 0.0))
    exposed_s = wire_s + launch_s - hidden_s
    overlap = hidden_s / wire_s if wire_s > 0.0 else 0.0
    return CollectiveEstimate(float(wire_bytes), wire_s, launch_s,
                              hidden_s, exposed_s, overlap)
