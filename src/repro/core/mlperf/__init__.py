"""From-scratch ML stack (no sklearn in this environment).

Implements the paper's modelling pipeline:
  StandardScaler -> MultiOutput(RandomForestRegressor(n_estimators=100, max_depth=6))
plus the comparison models from Table VI (linear regression, gradient-boosted
trees standing in for XGBoost, and a stacking ensemble).

All estimators follow a minimal fit/predict protocol and operate on float64
numpy arrays. Trees are histogram-based (quantile binning) so training the
paper-scale dataset (~16k rows) takes seconds on one CPU core. Fitted forests
can be exported to flat arrays for jit-compiled prediction inside JAX
(see `jaxpredict.py`), which the autotuner uses.
"""

from repro.core.mlperf.state import (
    estimator_from_state,
    pack_nested,
    register_estimator,
    registered_estimator_names,
    unpack_nested,
)
from repro.core.mlperf.compiled import (
    compilable_families,
    lower_estimator,
    supports_compile,
)
from repro.core.mlperf.tree import DecisionTreeRegressor, Binner
from repro.core.mlperf.forest import RandomForestRegressor
from repro.core.mlperf.gbdt import GradientBoostedTreesRegressor
from repro.core.mlperf.linreg import LinearRegression, Ridge
from repro.core.mlperf.stacking import StackingRegressor
from repro.core.mlperf.pipeline import (
    StandardScaler,
    TabularPreprocessor,
    Pipeline,
    train_test_split,
)
from repro.core.mlperf.metrics import (
    r2_score,
    mse,
    mae,
    median_pct_error,
    mean_pct_error,
    regression_report,
)

__all__ = [
    "estimator_from_state",
    "pack_nested",
    "register_estimator",
    "registered_estimator_names",
    "unpack_nested",
    "compilable_families",
    "lower_estimator",
    "supports_compile",
    "DecisionTreeRegressor",
    "Binner",
    "RandomForestRegressor",
    "GradientBoostedTreesRegressor",
    "LinearRegression",
    "Ridge",
    "StackingRegressor",
    "StandardScaler",
    "TabularPreprocessor",
    "Pipeline",
    "train_test_split",
    "r2_score",
    "mse",
    "mae",
    "median_pct_error",
    "mean_pct_error",
    "regression_report",
]
