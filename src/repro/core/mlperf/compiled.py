"""Lowering registry: any fitted estimator -> one jit-compiled scorer.

Every estimator family in the mlperf zoo registers a *lowering* here — a
function that exports the fitted model to flat numpy arrays (the same
global-id layout the state contract uses) plus a pure jax `apply(params, X)`
that reproduces the numpy `predict` inside `jax.jit`:

  * tree / forest / GBDT — one stacked level-synchronous descent over the
    concatenated ensemble (leaves self-loop, `max_depth` gather steps),
    combined per family: mean over trees (forest), ``base + lr * sum``
    (GBDT, weighted-sum flat descent).
  * linreg / ridge — a single affine map. Accumulation runs feature-by-
    feature (`lax.fori_loop`), mirroring `linreg.ordered_affine`, because
    BLAS/XLA matmuls don't guarantee a summation order and the x64 contract
    below is *bit*-exactness.
  * stacking — every base model's descent runs in the same graph, the
    meta-ridge combine is one fixed-order affine over the stacked
    predictions.

Two precisions, same contract as the forest predictor always had:

  * ``float64=False`` — float32 arrays for embedding in fp32 jitted
    programs; thresholds are nudged one fp32 ulp (see
    `tree.cast_flat_ensemble`) so fp64-trained splits survive rounding.
  * ``float64=True`` — arrays stay float64 (build and call under a scoped
    ``jax.experimental.enable_x64``); every gather, comparison, and
    accumulation happens in the same order as the numpy reference, so the
    compiled scorer is bit-identical to `est.predict`.

`lower_estimator` dispatches on the estimator class through the registry;
`JaxEstimator` (jaxpredict.py) wraps the result in a ready-to-call object.
"""

from __future__ import annotations

import contextlib
from typing import Callable, NamedTuple

import numpy as np


class Lowered(NamedTuple):
    """Flat-array params + a pure `apply(params, X) -> (N, K)` jax fn."""

    params: dict
    apply: Callable
    n_targets: int


_LOWERINGS: dict[str, Callable] = {}


def register_lowering(cls_name: str):
    """Decorator: register `fn(est, float64) -> Lowered` for a class name."""

    def deco(fn):
        _LOWERINGS[cls_name] = fn
        return fn

    return deco


def compilable_families() -> list[str]:
    """Estimator class names that can serve through the compiled scorer."""
    return sorted(_LOWERINGS)


def supports_compile(est) -> bool:
    return type(est).__name__ in _LOWERINGS


def lower_estimator(est, *, float64: bool = False) -> Lowered:
    """Export any registered fitted estimator for jit-compiled prediction."""
    name = type(est).__name__
    try:
        fn = _LOWERINGS[name]
    except KeyError:
        raise TypeError(
            f"no compiled lowering for estimator {name!r}; "
            f"known: {compilable_families()}"
        ) from None
    return fn(est, float64)


def precision_scope(x64: bool):
    """Scoped x64 so float64 arrays survive asarray/tracing; the default
    fp32 path is a no-op context."""
    if x64:
        from jax.experimental import enable_x64

        return enable_x64()
    return contextlib.nullcontext()


# ---------------------------------------------------------------------------
# shared jax building blocks (imported lazily inside apply fns is not needed:
# this module is only imported from jax-aware call sites)
# ---------------------------------------------------------------------------


def _descend(p: dict, X, *, max_depth: int, n_trees: int):
    """Stacked flat-array descent: leaf values for every (tree, sample)
    pair, shape (T, N, K). All cursors advance together, one gather per
    node array per level; leaves self-loop so a fixed `max_depth` step
    count lands every cursor on its leaf (mirror of
    `tree.predict_stacked`)."""
    import jax
    import jax.numpy as jnp

    N, F = X.shape
    Xr = X.reshape(-1)
    roots = p["roots"]
    node = jnp.repeat(roots, N)                          # (T*N,)
    row = jnp.tile(jnp.arange(N, dtype=roots.dtype) * F, n_trees)
    feature, threshold = p["feature"], p["threshold"]
    left, right = p["left"], p["right"]

    def step(_, node):
        x = Xr[row + feature[node]]
        return jnp.where(x <= threshold[node], left[node], right[node])

    node = jax.lax.fori_loop(0, max_depth, step, node)
    return p["value"][node].reshape(n_trees, N, -1)      # (T, N, K)


def _sum_trees(leaves):
    """Sequential sum over the tree axis. numpy's `leaves.sum(axis=0)`
    accumulates slice-by-slice in order; an XLA `reduce` may reassociate,
    so the x64 bit-exact contract needs this explicit fori accumulation.
    The while-loop body is a separate XLA computation, so the adds can't
    be FMA-contracted with whatever produced `leaves`."""
    import jax
    import jax.numpy as jnp

    T = leaves.shape[0]
    return jax.lax.fori_loop(
        0, T, lambda i, acc: acc + leaves[i], jnp.zeros_like(leaves[0]))


def _ordered_affine(X, coef, intercept):
    """X @ coef + intercept — the jax mirror of `linreg.ordered_affine`.

    The product tensor is materialized *before* the accumulation loop:
    LLVM contracts a `mul` feeding an `add` in the same fused loop into an
    FMA (different rounding than numpy, and no XLA flag disables it), but
    a while-loop body is a separate computation, so products land in
    memory first and the loop runs pure adds — same ops, same order, same
    bits as the numpy reference. coef: (F, K); intercept: (K,)."""
    import jax
    import jax.numpy as jnp

    F = coef.shape[0]
    P = X[:, :, None] * coef[None, :, :]                 # (N, F, K)
    acc0 = jnp.zeros((X.shape[0], coef.shape[1]), dtype=X.dtype)

    def step(f, acc):
        return acc + P[:, f, :]

    return jax.lax.fori_loop(0, F, step, acc0) + intercept[None, :]


# ---------------------------------------------------------------------------
# per-family lowerings
# ---------------------------------------------------------------------------


def _tree_params(flat: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
    return {k: flat[k] for k in
            ("feature", "threshold", "left", "right", "value", "roots")}


@register_lowering("RandomForestRegressor")
def _lower_forest(est, float64: bool) -> Lowered:
    flat = est.to_flat_arrays(float64=float64)
    max_depth = int(flat["max_depth"])
    n_trees = len(flat["roots"])
    params = _tree_params(flat)
    # divisor as a *traced* param: a literal constant would let XLA rewrite
    # the division into a reciprocal multiply (last-ulp drift vs numpy).
    params["count"] = np.asarray(float(n_trees),
                                 dtype=flat["value"].dtype)

    def apply(p, X):
        leaves = _descend(p, X, max_depth=max_depth, n_trees=n_trees)
        return _sum_trees(leaves) / p["count"]

    return Lowered(params, apply, int(est.n_targets_))


@register_lowering("GradientBoostedTreesRegressor")
def _lower_gbdt(est, float64: bool) -> Lowered:
    flat = est.to_flat_arrays(float64=float64)
    max_depth = int(flat["max_depth"])
    n_trees = len(flat["roots"])
    # Pre-scale leaf values by the learning rate HERE, in numpy: the numpy
    # `predict` multiplies leaves elementwise by lr before summing, so
    # gathering pre-scaled values gives bit-identical addends while keeping
    # the jitted combine add-only (no mul feeding an add => no FMA drift).
    value = flat["value"]
    params = {**_tree_params(flat),
              "value": value.dtype.type(est.learning_rate) * value,
              "base": flat["base"]}

    def apply(p, X):
        import jax.numpy as jnp

        base = jnp.broadcast_to(p["base"][None, :],
                                (X.shape[0], p["base"].shape[0]))
        if n_trees == 0:
            return base
        leaves = _descend(p, X, max_depth=max_depth, n_trees=n_trees)
        return base + _sum_trees(leaves)

    return Lowered(params, apply, int(est.n_targets_))


@register_lowering("DecisionTreeRegressor")
def _lower_tree(est, float64: bool) -> Lowered:
    from repro.core.mlperf.tree import cast_flat_ensemble, flatten_ensemble

    flat = cast_flat_ensemble(flatten_ensemble([est.tree_]), float64=float64)
    max_depth = int(est.max_depth)

    def apply(p, X):
        leaves = _descend(p, X, max_depth=max_depth, n_trees=1)
        return leaves[0]

    return Lowered(_tree_params(flat), apply, int(est.n_targets_))


def _affine_params(coef, intercept, float64: bool) -> dict[str, np.ndarray]:
    coef = np.asarray(coef, dtype=np.float64)
    if coef.ndim == 1:
        coef = coef[:, None]
    intercept = np.atleast_1d(np.asarray(intercept, dtype=np.float64))
    intercept = np.broadcast_to(intercept, (coef.shape[1],)).copy()
    if not float64:
        coef = coef.astype(np.float32)
        intercept = intercept.astype(np.float32)
    return {"coef": coef, "intercept": intercept}


@register_lowering("LinearRegression")
def _lower_linear(est, float64: bool) -> Lowered:
    params = _affine_params(est.coef_, est.intercept_, float64)

    def apply(p, X):
        return _ordered_affine(X, p["coef"], p["intercept"])

    return Lowered(params, apply, params["coef"].shape[1])


# Ridge shares LinearRegression's prediction surface exactly.
register_lowering("Ridge")(_lower_linear)


@register_lowering("StackingRegressor")
def _lower_stacking(est, float64: bool) -> Lowered:
    lowered = [lower_estimator(b, float64=float64)
               for b in est.fitted_bases_]
    base_applies = [low.apply for low in lowered]
    # meta ridges are per-target with 1-d coefs over Z; stack to (Z, T) so
    # one fori over Z-columns reproduces every per-target ordered dot.
    meta_coef = np.stack(
        [np.asarray(m.coef_, dtype=np.float64) for m in est.meta_],
        axis=1)                                           # (Z, T)
    meta_intercept = np.array(
        [float(np.ravel(m.intercept_)[0]) for m in est.meta_],
        dtype=np.float64)
    if not float64:
        meta_coef = meta_coef.astype(np.float32)
        meta_intercept = meta_intercept.astype(np.float32)
    params = {"bases": [low.params for low in lowered],
              "meta_coef": meta_coef, "meta_intercept": meta_intercept}
    passthrough = bool(est.passthrough)

    def apply(p, X):
        import jax.numpy as jnp

        preds = [ap(bp, X).reshape(X.shape[0], -1)
                 for ap, bp in zip(base_applies, p["bases"])]
        Z = jnp.concatenate(preds + ([X] if passthrough else []), axis=1)
        return _ordered_affine(Z, p["meta_coef"], p["meta_intercept"])

    return Lowered(params, apply, int(est.n_targets_))
