"""Random forest regressor (multi-output, bagging + feature subsampling).

Matches the paper's configuration surface: `RandomForestRegressor(
n_estimators=100, max_depth=6, n_jobs=-1)` wrapped in MultiOutputRegressor.
Multi-output is native here (one tree predicts all targets), which preserves
inter-target structure (runtime/power/energy are physically coupled); a
`per_target=True` mode replicates sklearn's independent-model behaviour
exactly for comparison.
"""

from __future__ import annotations

import numpy as np

from repro.core.mlperf.tree import Binner, DecisionTreeRegressor


class RandomForestRegressor:
    def __init__(
        self,
        n_estimators: int = 100,
        max_depth: int = 6,
        min_samples_split: int = 2,
        min_samples_leaf: int = 1,
        max_features: int | float | str | None = 1.0,
        bootstrap: bool = True,
        max_bins: int = 255,
        random_state: int | None = None,
        n_jobs: int | None = None,  # accepted for API parity; single-core env
    ):
        self.n_estimators = n_estimators
        self.max_depth = max_depth
        self.min_samples_split = min_samples_split
        self.min_samples_leaf = min_samples_leaf
        self.max_features = max_features
        self.bootstrap = bootstrap
        self.max_bins = max_bins
        self.random_state = random_state
        self.n_jobs = n_jobs
        self.estimators_: list[DecisionTreeRegressor] = []
        self.binner_: Binner | None = None
        self.n_targets_: int | None = None

    def fit(self, X, y, sample_weight=None):
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        if y.ndim == 1:
            y = y[:, None]
        self.n_targets_ = y.shape[1]
        n = len(X)
        if sample_weight is None:
            sample_weight = np.ones(n)
        rng = np.random.default_rng(self.random_state)
        # Shared binning across the whole forest: bin once, reuse per tree.
        self.binner_ = Binner(self.max_bins).fit(X)
        Xb = self.binner_.transform(X)
        self.estimators_ = []
        for i in range(self.n_estimators):
            tree = DecisionTreeRegressor(
                max_depth=self.max_depth,
                min_samples_split=self.min_samples_split,
                min_samples_leaf=self.min_samples_leaf,
                max_features=self.max_features,
                max_bins=self.max_bins,
                random_state=int(rng.integers(0, 2**31 - 1)),
            )
            if self.bootstrap:
                # bagging via multiplicity weights (no row copying)
                counts = np.bincount(
                    rng.integers(0, n, size=n), minlength=n
                ).astype(np.float64)
                w = counts * sample_weight
            else:
                w = sample_weight
            tree.fit(X, y, sample_weight=w, binner=self.binner_, Xb=Xb)
            self.estimators_.append(tree)
        return self

    def predict(self, X) -> np.ndarray:
        assert self.estimators_, "not fitted"
        X = np.asarray(X, dtype=np.float64)
        acc = np.zeros((len(X), self.n_targets_))
        for tree in self.estimators_:
            acc += tree.tree_.predict_raw(X)
        acc /= len(self.estimators_)
        return acc[:, 0] if self.n_targets_ == 1 else acc

    @property
    def feature_importances_(self) -> np.ndarray:
        imps = np.stack([t.feature_importances_ for t in self.estimators_])
        imp = imps.mean(axis=0)
        s = imp.sum()
        return imp / s if s > 0 else imp

    # ---- flat export for jit prediction (see jaxpredict.py) ----
    def to_flat_arrays(self) -> dict[str, np.ndarray]:
        """Pack all trees into rectangular arrays padded to the max node
        count: feature (T, M), threshold (T, M), left/right (T, M),
        value (T, M, n_targets). Padding nodes are leaves with value 0 and
        are unreachable.
        """
        trees = [t.tree_ for t in self.estimators_]
        T = len(trees)
        M = max(t.n_nodes for t in trees)
        K = self.n_targets_
        feature = np.full((T, M), -1, dtype=np.int32)
        threshold = np.zeros((T, M), dtype=np.float32)
        left = np.zeros((T, M), dtype=np.int32)
        right = np.zeros((T, M), dtype=np.int32)
        value = np.zeros((T, M, K), dtype=np.float32)
        for i, t in enumerate(trees):
            m = t.n_nodes
            feature[i, :m] = t.feature
            # thresholds sit exactly on training-data values (quantile bin
            # edges); nudge up one fp32 ulp so values that compared `<=` in
            # fp64 still go left after fp32 rounding in the jitted path.
            thr32 = t.threshold.astype(np.float32)
            threshold[i, :m] = np.nextafter(thr32, np.float32(np.inf))
            left[i, :m] = np.maximum(t.left, 0)
            right[i, :m] = np.maximum(t.right, 0)
            value[i, :m] = t.value
        return {
            "feature": feature,
            "threshold": threshold,
            "left": left,
            "right": right,
            "value": value,
            "max_depth": np.int32(self.max_depth),
        }
