"""Random forest regressor (multi-output, bagging + feature subsampling).

Matches the paper's configuration surface: `RandomForestRegressor(
n_estimators=100, max_depth=6, n_jobs=-1)` wrapped in MultiOutputRegressor.
Multi-output is native here (one tree predicts all targets), which preserves
inter-target structure (runtime/power/energy are physically coupled); a
`per_target=True` mode replicates sklearn's independent-model behaviour
exactly for comparison.
"""

from __future__ import annotations

import numpy as np

from repro.core.mlperf.state import (
    CLASS_KEY,
    class_tag,
    register_estimator,
    scalar,
)
from repro.core.mlperf.tree import (
    Binner,
    DecisionTreeRegressor,
    cast_flat_ensemble,
    concat_flat_trees,
    estimators_from_state,
    flatten_ensemble,
    predict_stacked,
)


@register_estimator
class RandomForestRegressor:
    def __init__(
        self,
        n_estimators: int = 100,
        max_depth: int = 6,
        min_samples_split: int = 2,
        min_samples_leaf: int = 1,
        max_features: int | float | str | None = 1.0,
        bootstrap: bool = True,
        max_bins: int = 255,
        random_state: int | None = None,
        n_jobs: int | None = None,  # accepted for API parity; single-core env
    ):
        self.n_estimators = n_estimators
        self.max_depth = max_depth
        self.min_samples_split = min_samples_split
        self.min_samples_leaf = min_samples_leaf
        self.max_features = max_features
        self.bootstrap = bootstrap
        self.max_bins = max_bins
        self.random_state = random_state
        self.n_jobs = n_jobs
        self.estimators_: list[DecisionTreeRegressor] = []
        self.binner_: Binner | None = None
        self.n_targets_: int | None = None
        self._stacked: dict[str, np.ndarray] | None = None

    def fit(self, X, y, sample_weight=None):
        self._stacked = None
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        if y.ndim == 1:
            y = y[:, None]
        self.n_targets_ = y.shape[1]
        n = len(X)
        if sample_weight is None:
            sample_weight = np.ones(n)
        rng = np.random.default_rng(self.random_state)
        # Shared binning across the whole forest: bin once, reuse per tree.
        self.binner_ = Binner(self.max_bins).fit(X)
        Xb = self.binner_.transform(X)
        self.estimators_ = []
        for i in range(self.n_estimators):
            tree = DecisionTreeRegressor(
                max_depth=self.max_depth,
                min_samples_split=self.min_samples_split,
                min_samples_leaf=self.min_samples_leaf,
                max_features=self.max_features,
                max_bins=self.max_bins,
                random_state=int(rng.integers(0, 2**31 - 1)),
            )
            if self.bootstrap:
                # bagging via multiplicity weights (no row copying)
                counts = np.bincount(
                    rng.integers(0, n, size=n), minlength=n
                ).astype(np.float64)
                w = counts * sample_weight
            else:
                w = sample_weight
            tree.fit(X, y, sample_weight=w, binner=self.binner_, Xb=Xb)
            self.estimators_.append(tree)
        return self

    def _stacked_arrays(self) -> dict[str, np.ndarray]:
        if self._stacked is None:
            self._stacked = flatten_ensemble(
                [t.tree_ for t in self.estimators_])
        return self._stacked

    def predict(self, X) -> np.ndarray:
        """Mean prediction over all trees — one stacked descent, no
        Python per-tree loop (same leaves as `predict_per_tree_loop`)."""
        assert self.estimators_, "not fitted"
        X = np.asarray(X, dtype=np.float64)
        leaves = predict_stacked(self._stacked_arrays(), X,
                                 max_depth=self.max_depth)  # (T, N, K)
        acc = leaves.sum(axis=0) / len(self.estimators_)
        return acc[:, 0] if self.n_targets_ == 1 else acc

    def predict_per_tree_loop(self, X) -> np.ndarray:
        """Pre-vectorization reference path (per-tree Python loop), kept
        for parity tests and rank-latency benchmarks."""
        assert self.estimators_, "not fitted"
        X = np.asarray(X, dtype=np.float64)
        acc = np.zeros((len(X), self.n_targets_))
        for tree in self.estimators_:
            acc += tree.tree_.predict_raw(X)
        acc /= len(self.estimators_)
        return acc[:, 0] if self.n_targets_ == 1 else acc

    @property
    def feature_importances_(self) -> np.ndarray:
        imps = np.stack([t.feature_importances_ for t in self.estimators_])
        imp = imps.mean(axis=0)
        s = imp.sum()
        return imp / s if s > 0 else imp

    # ---- flat export for jit prediction (see jaxpredict.py) ----
    def to_flat_arrays(self, *, float64: bool = False
                       ) -> dict[str, np.ndarray]:
        """Global-id flat ensemble (see `flatten_ensemble`) plus the
        descent step count: feature/threshold/left/right over concatenated
        nodes, `roots` (T,), value (total_nodes, n_targets), max_depth.
        `float64=True` keeps exact thresholds/values so x64 traversal takes
        bit-identical branches vs the numpy reference.
        """
        return {
            **cast_flat_ensemble(self._stacked_arrays(), float64=float64),
            "max_depth": np.int32(self.max_depth),
        }

    # ---- flat-array state contract (see mlperf.state) ----
    def to_state(self) -> dict[str, np.ndarray]:
        assert self.estimators_, "not fitted"
        state = concat_flat_trees([t.tree_ for t in self.estimators_])
        state[CLASS_KEY] = class_tag(type(self))
        state["n_features"] = scalar(np.int64(self.estimators_[0].n_features_))
        state["n_targets"] = scalar(np.int64(self.n_targets_))
        state["max_depth"] = scalar(np.int64(self.max_depth))
        return state

    @classmethod
    def from_state(cls, state: dict[str, np.ndarray]
                   ) -> "RandomForestRegressor":
        estimators = estimators_from_state(state)
        obj = cls(n_estimators=len(estimators),
                  max_depth=int(state["max_depth"][()]))
        obj.n_targets_ = int(state["n_targets"][()])
        obj.estimators_ = estimators
        return obj
