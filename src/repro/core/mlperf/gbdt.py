"""Gradient-boosted regression trees (squared loss) — the XGBoost stand-in
for the paper's Table VI comparison.

Boosting on squared loss fits each round's tree to the current residuals with
shrinkage. Multi-output targets share tree structure (residual vector per
row), which mirrors multi-output XGBoost's `multi_strategy="multi_output_tree"`.
"""

from __future__ import annotations

import numpy as np

from repro.core.mlperf.tree import Binner, DecisionTreeRegressor


class GradientBoostedTreesRegressor:
    def __init__(
        self,
        n_estimators: int = 200,
        learning_rate: float = 0.1,
        max_depth: int = 4,
        min_samples_leaf: int = 3,
        subsample: float = 0.9,
        max_features: int | float | str | None = None,
        max_bins: int = 255,
        random_state: int | None = None,
    ):
        self.n_estimators = n_estimators
        self.learning_rate = learning_rate
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.subsample = subsample
        self.max_features = max_features
        self.max_bins = max_bins
        self.random_state = random_state
        self.estimators_: list[DecisionTreeRegressor] = []
        self.base_: np.ndarray | None = None
        self.n_targets_: int | None = None

    def fit(self, X, y, sample_weight=None):
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        if y.ndim == 1:
            y = y[:, None]
        self.n_targets_ = y.shape[1]
        n = len(X)
        if sample_weight is None:
            sample_weight = np.ones(n)
        rng = np.random.default_rng(self.random_state)
        binner = Binner(self.max_bins).fit(X)
        Xb = binner.transform(X)
        self.base_ = y.mean(axis=0)
        pred = np.tile(self.base_, (n, 1))
        self.estimators_ = []
        for i in range(self.n_estimators):
            resid = y - pred
            w = sample_weight.copy()
            if self.subsample < 1.0:
                mask = rng.random(n) < self.subsample
                w = w * mask
                if w.sum() == 0:
                    continue
            tree = DecisionTreeRegressor(
                max_depth=self.max_depth,
                min_samples_leaf=self.min_samples_leaf,
                max_features=self.max_features,
                max_bins=self.max_bins,
                random_state=int(rng.integers(0, 2**31 - 1)),
            )
            tree.fit(X, resid, sample_weight=w, binner=binner, Xb=Xb)
            upd = tree.tree_.predict_binned(Xb)
            pred = pred + self.learning_rate * upd
            self.estimators_.append(tree)
        return self

    def predict(self, X) -> np.ndarray:
        assert self.base_ is not None, "not fitted"
        X = np.asarray(X, dtype=np.float64)
        acc = np.tile(self.base_, (len(X), 1))
        for tree in self.estimators_:
            acc += self.learning_rate * tree.tree_.predict_raw(X)
        return acc[:, 0] if self.n_targets_ == 1 else acc

    def staged_score_path(self, X, y, metric) -> list[float]:
        """Score after each boosting round (for early-stopping analysis)."""
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        if y.ndim == 1:
            y = y[:, None]
        acc = np.tile(self.base_, (len(X), 1))
        scores = []
        for tree in self.estimators_:
            acc = acc + self.learning_rate * tree.tree_.predict_raw(X)
            scores.append(metric(y, acc))
        return scores
