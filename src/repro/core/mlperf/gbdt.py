"""Gradient-boosted regression trees (squared loss) — the XGBoost stand-in
for the paper's Table VI comparison.

Boosting on squared loss fits each round's tree to the current residuals with
shrinkage. Multi-output targets share tree structure (residual vector per
row), which mirrors multi-output XGBoost's `multi_strategy="multi_output_tree"`.
"""

from __future__ import annotations

import numpy as np

from repro.core.mlperf.state import (
    CLASS_KEY,
    class_tag,
    register_estimator,
    scalar,
)
from repro.core.mlperf.tree import (
    Binner,
    DecisionTreeRegressor,
    cast_flat_ensemble,
    concat_flat_trees,
    estimators_from_state,
    flatten_ensemble,
    predict_stacked,
)


@register_estimator
class GradientBoostedTreesRegressor:
    def __init__(
        self,
        n_estimators: int = 200,
        learning_rate: float = 0.1,
        max_depth: int = 4,
        min_samples_leaf: int = 3,
        subsample: float = 0.9,
        max_features: int | float | str | None = None,
        max_bins: int = 255,
        random_state: int | None = None,
    ):
        self.n_estimators = n_estimators
        self.learning_rate = learning_rate
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.subsample = subsample
        self.max_features = max_features
        self.max_bins = max_bins
        self.random_state = random_state
        self.estimators_: list[DecisionTreeRegressor] = []
        self.base_: np.ndarray | None = None
        self.n_targets_: int | None = None
        self._stacked: dict[str, np.ndarray] | None = None

    def fit(self, X, y, sample_weight=None):
        self._stacked = None
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        if y.ndim == 1:
            y = y[:, None]
        self.n_targets_ = y.shape[1]
        n = len(X)
        if sample_weight is None:
            sample_weight = np.ones(n)
        rng = np.random.default_rng(self.random_state)
        binner = Binner(self.max_bins).fit(X)
        Xb = binner.transform(X)
        self.base_ = y.mean(axis=0)
        pred = np.tile(self.base_, (n, 1))
        self.estimators_ = []
        for i in range(self.n_estimators):
            resid = y - pred
            w = sample_weight.copy()
            if self.subsample < 1.0:
                mask = rng.random(n) < self.subsample
                w = w * mask
                if w.sum() == 0:
                    continue
            tree = DecisionTreeRegressor(
                max_depth=self.max_depth,
                min_samples_leaf=self.min_samples_leaf,
                max_features=self.max_features,
                max_bins=self.max_bins,
                random_state=int(rng.integers(0, 2**31 - 1)),
            )
            tree.fit(X, resid, sample_weight=w, binner=binner, Xb=Xb)
            upd = tree.tree_.predict_binned(Xb)
            pred = pred + self.learning_rate * upd
            self.estimators_.append(tree)
        return self

    def _stacked_arrays(self) -> dict[str, np.ndarray]:
        if self._stacked is None:
            self._stacked = flatten_ensemble(
                [t.tree_ for t in self.estimators_])
        return self._stacked

    def predict(self, X) -> np.ndarray:
        """base + sum of lr-scaled per-round trees — one stacked descent
        across every boosting round (same leaves as
        `predict_per_tree_loop`). Leaves are scaled *before* the
        tree-axis sum so the compiled lowering (which bakes lr into the
        exported leaf values) accumulates bit-identical addends."""
        assert self.base_ is not None, "not fitted"
        X = np.asarray(X, dtype=np.float64)
        acc = np.tile(self.base_, (len(X), 1))
        if self.estimators_:
            leaves = predict_stacked(self._stacked_arrays(), X,
                                     max_depth=self.max_depth)  # (T, N, K)
            acc = acc + (self.learning_rate * leaves).sum(axis=0)
        return acc[:, 0] if self.n_targets_ == 1 else acc

    def predict_per_tree_loop(self, X) -> np.ndarray:
        """Pre-vectorization reference path (per-round Python loop), kept
        for parity tests and rank-latency benchmarks."""
        assert self.base_ is not None, "not fitted"
        X = np.asarray(X, dtype=np.float64)
        acc = np.tile(self.base_, (len(X), 1))
        for tree in self.estimators_:
            acc += self.learning_rate * tree.tree_.predict_raw(X)
        return acc[:, 0] if self.n_targets_ == 1 else acc

    # ---- flat export for jit prediction (see compiled.py) ----
    def to_flat_arrays(self, *, float64: bool = False
                       ) -> dict[str, np.ndarray]:
        """Global-id flat ensemble for the weighted-sum descent: the same
        layout forests export, plus the boosting offset `base` (K,). The
        compiled scorer computes ``base + learning_rate * sum(leaves)``
        with the identical accumulation order as the numpy `predict`.
        `float64=True` keeps exact thresholds/values (x64 bit-parity);
        otherwise thresholds get the one-ulp fp32 nudge.
        """
        assert self.base_ is not None, "not fitted"
        base = np.asarray(self.base_, dtype=np.float64)
        flat = (cast_flat_ensemble(self._stacked_arrays(), float64=float64)
                if self.estimators_ else
                {"feature": np.zeros(0, np.int64),
                 "threshold": np.zeros(0),
                 "left": np.zeros(0, np.int64),
                 "right": np.zeros(0, np.int64),
                 "value": np.zeros((0, len(base))),
                 "roots": np.zeros(0, np.int64)})
        return {
            **flat,
            "base": base if float64 else base.astype(np.float32),
            "max_depth": np.int32(self.max_depth),
        }

    # ---- flat-array state contract (see mlperf.state) ----
    def to_state(self) -> dict[str, np.ndarray]:
        assert self.base_ is not None, "not fitted"
        state = concat_flat_trees([t.tree_ for t in self.estimators_])
        state[CLASS_KEY] = class_tag(type(self))
        state["base"] = np.asarray(self.base_, dtype=np.float64)
        state["learning_rate"] = scalar(np.float64(self.learning_rate))
        state["n_features"] = scalar(np.int64(self.estimators_[0].n_features_))
        state["n_targets"] = scalar(np.int64(self.n_targets_))
        state["max_depth"] = scalar(np.int64(self.max_depth))
        return state

    @classmethod
    def from_state(cls, state: dict[str, np.ndarray]
                   ) -> "GradientBoostedTreesRegressor":
        estimators = estimators_from_state(state)
        obj = cls(n_estimators=len(estimators),
                  learning_rate=float(state["learning_rate"][()]),
                  max_depth=int(state["max_depth"][()]))
        obj.base_ = np.asarray(state["base"], dtype=np.float64)
        obj.n_targets_ = int(state["n_targets"][()])
        obj.estimators_ = estimators
        return obj

    def staged_score_path(self, X, y, metric) -> list[float]:
        """Score after each boosting round (for early-stopping analysis)."""
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        if y.ndim == 1:
            y = y[:, None]
        acc = np.tile(self.base_, (len(X), 1))
        scores = []
        for tree in self.estimators_:
            acc = acc + self.learning_rate * tree.tree_.predict_raw(X)
            scores.append(metric(y, acc))
        return scores
