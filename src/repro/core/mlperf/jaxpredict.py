"""Jit-compiled random-forest inference.

The sklearn original can only predict in Python. Here the fitted forest is
exported to the global-id flat layout (`RandomForestRegressor.to_flat_arrays`:
concatenated node arrays, children rebased to global ids, leaves
self-looping) and traversed with a level-synchronous descent — one (T*N,)
cursor vector advanced `max_depth` gather steps. That keeps the whole
ensemble in a single XLA computation, so the performance predictor can run
*inside* jitted code — e.g. ranking thousands of candidate GEMM block
configs in one call during autotuning.

Two precisions:

  * default (float32) — for embedding inside fp32 jitted programs.
    Thresholds are nudged one ulp so most fp64-trained splits survive fp32
    rounding, but near-threshold samples can still flip branches.
  * ``x64=True`` — arrays stay float64 (built and called under a scoped
    ``jax.experimental.enable_x64``), so traversal takes bit-identical
    branches vs the numpy reference. This is what the autotuner's serving
    scorer uses: XLA speed with exact-parity predictions.
"""

from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64


@functools.partial(jax.jit, static_argnames=("max_depth", "n_trees"))
def _forest_predict(feature, threshold, left, right, value, roots, X, *,
                    max_depth: int, n_trees: int):
    """feature/threshold/left/right: (total_nodes,); value: (total, K);
    roots: (T,); X: (N, F). Returns (N, K) mean-over-trees prediction.

    All (tree, sample) cursors descend together: each step is one gather
    per node array over the (T*N,) cursor vector. Leaves self-loop, so a
    fixed `max_depth` step count lands every cursor on its leaf.
    """
    N, F = X.shape
    Xr = X.reshape(-1)
    node = jnp.repeat(roots, N)                        # (T*N,)
    row = jnp.tile(jnp.arange(N, dtype=roots.dtype) * F, n_trees)

    def step(_, node):
        x = Xr[row + feature[node]]
        return jnp.where(x <= threshold[node], left[node], right[node])

    node = jax.lax.fori_loop(0, max_depth, step, node)
    leaves = value[node].reshape(n_trees, N, -1)       # (T, N, K)
    return leaves.mean(axis=0)


class JaxForestPredictor:
    """Wraps a fitted mlperf RandomForestRegressor for jitted inference."""

    def __init__(self, forest, *, x64: bool = False):
        self.x64 = x64
        flat = forest.to_flat_arrays(float64=x64)
        with self._precision():
            self.feature = jnp.asarray(flat["feature"])
            self.threshold = jnp.asarray(flat["threshold"])
            self.left = jnp.asarray(flat["left"])
            self.right = jnp.asarray(flat["right"])
            self.value = jnp.asarray(flat["value"])
            self.roots = jnp.asarray(flat["roots"])
        self.max_depth = int(flat["max_depth"])
        self.n_trees = int(len(flat["roots"]))
        self.n_targets = int(self.value.shape[-1])

    def _precision(self):
        """Scoped x64 so float64 arrays survive asarray/tracing; the
        default fp32 path is a no-op context."""
        return enable_x64() if self.x64 else contextlib.nullcontext()

    def __call__(self, X) -> jax.Array:
        with self._precision():
            X = jnp.asarray(X, dtype=jnp.float64 if self.x64 else jnp.float32)
            if X.ndim == 1:
                X = X[None]
            return _forest_predict(
                self.feature, self.threshold, self.left, self.right,
                self.value, self.roots, X, max_depth=self.max_depth,
                n_trees=self.n_trees,
            )

    def predict(self, X) -> np.ndarray:
        return np.asarray(self(X))
