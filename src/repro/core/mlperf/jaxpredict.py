"""Jit-compiled estimator inference for the whole mlperf zoo.

The sklearn-style originals can only predict in Python. `JaxEstimator`
wraps any fitted estimator that has a registered lowering (see
`compiled.py`): the model is exported to flat arrays (tree ensembles in the
global-id layout — concatenated node arrays, children rebased to global
ids, leaves self-looping — linear models as coefficient matrices, stacking
as the composition of its bases) and evaluated as ONE jitted computation.
That keeps the entire model in a single XLA program, so the performance
predictor can run *inside* jitted code — e.g. ranking thousands of
candidate GEMM block configs in one call during autotuning, or fully
in-graph via `GemmAutotuner.rank_in_graph`.

Two precisions:

  * default (float32) — for embedding inside fp32 jitted programs.
    Tree thresholds are nudged one ulp so most fp64-trained splits survive
    fp32 rounding, but near-threshold samples can still flip branches.
  * ``x64=True`` — arrays stay float64 (built and called under a scoped
    ``jax.experimental.enable_x64``), and every accumulation runs in the
    numpy reference's order, so predictions are bit-identical to
    `est.predict`. This is what the autotuner's serving scorer uses: XLA
    speed with exact-parity predictions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mlperf.compiled import lower_estimator, precision_scope


class JaxEstimator:
    """Wraps any lowered mlperf estimator for jitted inference."""

    def __init__(self, est, *, x64: bool = False):
        self.x64 = x64
        lowered = lower_estimator(est, float64=x64)
        with self._precision():
            self.params = jax.tree.map(jnp.asarray, lowered.params)
        self._apply = jax.jit(lowered.apply)
        self.n_targets = int(lowered.n_targets)

    def _precision(self):
        """Scoped x64 so float64 arrays survive asarray/tracing; the
        default fp32 path is a no-op context."""
        return precision_scope(self.x64)

    def __call__(self, X) -> jax.Array:
        with self._precision():
            X = jnp.asarray(X, dtype=jnp.float64 if self.x64 else jnp.float32)
            if X.ndim == 1:
                X = X[None]
            return self._apply(self.params, X)

    def predict(self, X) -> np.ndarray:
        return np.asarray(self(X))


class JaxForestPredictor(JaxEstimator):
    """Back-compat name from when only forests could serve compiled."""
