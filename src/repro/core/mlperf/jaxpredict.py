"""Jit-compiled random-forest inference.

The sklearn original can only predict in Python. Here the fitted forest is
exported to flat arrays (`RandomForestRegressor.to_flat_arrays`) and traversed
with a fixed-depth `lax.fori_loop`, so the performance predictor can run
*inside* jitted code — e.g. ranking thousands of candidate GEMM block configs
in one XLA call during autotuning.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("max_depth",))
def _forest_predict(feature, threshold, left, right, value, X, *, max_depth: int):
    """feature/threshold/left/right: (T, M); value: (T, M, K); X: (N, F).
    Returns (N, K) mean-over-trees prediction.
    """

    def one_tree(feat_t, thr_t, left_t, right_t, val_t, x):
        # x: (F,). Descend max_depth steps; leaves self-loop via feature<0.
        def step(_, node):
            f = feat_t[node]
            is_leaf = f < 0
            fx = x[jnp.maximum(f, 0)]
            nxt = jnp.where(fx <= thr_t[node], left_t[node], right_t[node])
            return jnp.where(is_leaf, node, nxt)

        node = jax.lax.fori_loop(0, max_depth + 1, step, jnp.int32(0))
        return val_t[node]  # (K,)

    # vmap over samples, then over trees
    per_sample = jax.vmap(one_tree, in_axes=(None, None, None, None, None, 0))
    per_tree = jax.vmap(per_sample, in_axes=(0, 0, 0, 0, 0, None))
    preds = per_tree(feature, threshold, left, right, value, X)  # (T, N, K)
    return preds.mean(axis=0)


class JaxForestPredictor:
    """Wraps a fitted mlperf RandomForestRegressor for jitted inference."""

    def __init__(self, forest):
        flat = forest.to_flat_arrays()
        self.feature = jnp.asarray(flat["feature"])
        self.threshold = jnp.asarray(flat["threshold"])
        self.left = jnp.asarray(flat["left"])
        self.right = jnp.asarray(flat["right"])
        self.value = jnp.asarray(flat["value"])
        self.max_depth = int(flat["max_depth"])
        self.n_targets = int(self.value.shape[-1])

    def __call__(self, X) -> jax.Array:
        X = jnp.asarray(X, dtype=jnp.float32)
        if X.ndim == 1:
            X = X[None]
        return _forest_predict(
            self.feature, self.threshold, self.left, self.right, self.value,
            X, max_depth=self.max_depth,
        )

    def predict(self, X) -> np.ndarray:
        return np.asarray(self(X))
