"""Ordinary least squares and ridge regression (multi-output).

The paper's baseline model (Tables II/III report its coefficients for the
tiled-matmul study, Table VI its R^2 on the CUTLASS dataset).
"""

from __future__ import annotations

import numpy as np

from repro.core.mlperf.state import CLASS_KEY, class_tag, register_estimator


def ordered_affine(X: np.ndarray, coef: np.ndarray,
                   intercept) -> np.ndarray:
    """X @ coef + intercept with a fixed feature-by-feature accumulation.

    BLAS matmuls reassociate the inner sum (blocking, SIMD lanes), so two
    builds — or numpy vs the jitted scorer — can disagree in the last ulp.
    Summing per-feature products in declared order pins the result and
    lets the compiled lowering (`compiled._ordered_affine`: the same
    products materialized before an add-only fori_loop — jax needs the
    materialization to dodge FMA contraction, numpy has no such hazard)
    reproduce predictions bit-for-bit in float64. F is the feature count
    (tens), so the Python loop over vectorized columns costs nothing at
    serving batch sizes.
    """
    squeeze = coef.ndim == 1
    coef2 = coef[:, None] if squeeze else coef
    acc = np.zeros((len(X), coef2.shape[1]), dtype=np.float64)
    for f in range(coef2.shape[0]):
        acc = acc + X[:, f][:, None] * coef2[f][None, :]
    out = acc[:, 0] if squeeze else acc
    return out + intercept


@register_estimator
class LinearRegression:
    def __init__(self, fit_intercept: bool = True):
        self.fit_intercept = fit_intercept
        self.coef_: np.ndarray | None = None      # (n_features, n_targets) or (n_features,)
        self.intercept_: np.ndarray | float = 0.0

    def fit(self, X, y, sample_weight=None):
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        squeeze = y.ndim == 1
        if squeeze:
            y = y[:, None]
        if sample_weight is not None:
            sw = np.sqrt(np.asarray(sample_weight, dtype=np.float64))
            X = X * sw[:, None]
            y = y * sw[:, None]
        if self.fit_intercept:
            Xd = np.concatenate([X, np.ones((len(X), 1))], axis=1)
        else:
            Xd = X
        beta, *_ = np.linalg.lstsq(Xd, y, rcond=None)
        if self.fit_intercept:
            self.coef_ = beta[:-1]
            self.intercept_ = beta[-1]
        else:
            self.coef_ = beta
            self.intercept_ = np.zeros(y.shape[1])
        if squeeze:
            self.coef_ = self.coef_[:, 0]
            self.intercept_ = float(np.ravel(self.intercept_)[0])
        self._squeeze = squeeze
        return self

    def predict(self, X) -> np.ndarray:
        X = np.asarray(X, dtype=np.float64)
        return ordered_affine(X, self.coef_, self.intercept_)

    # ---- flat-array state contract (see mlperf.state) ----
    def to_state(self) -> dict[str, np.ndarray]:
        assert self.coef_ is not None, "not fitted"
        return {
            CLASS_KEY: class_tag(type(self)),
            "coef": np.asarray(self.coef_, dtype=np.float64),
            "intercept": np.asarray(self.intercept_, dtype=np.float64),
        }

    @classmethod
    def from_state(cls, state: dict[str, np.ndarray]):
        obj = cls()
        obj.coef_ = np.asarray(state["coef"], dtype=np.float64)
        intercept = np.asarray(state["intercept"], dtype=np.float64)
        obj.intercept_ = float(intercept[()]) if intercept.ndim == 0 \
            else intercept
        return obj


@register_estimator
class Ridge(LinearRegression):
    def __init__(self, alpha: float = 1.0, fit_intercept: bool = True):
        super().__init__(fit_intercept=fit_intercept)
        self.alpha = alpha

    def fit(self, X, y, sample_weight=None):
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        squeeze = y.ndim == 1
        if squeeze:
            y = y[:, None]
        if sample_weight is not None:
            sw = np.sqrt(np.asarray(sample_weight, dtype=np.float64))
            X = X * sw[:, None]
            y = y * sw[:, None]
        n, d = X.shape
        if self.fit_intercept:
            xm = X.mean(axis=0)
            ym = y.mean(axis=0)
            Xc, yc = X - xm, y - ym
        else:
            Xc, yc = X, y
        A = Xc.T @ Xc + self.alpha * np.eye(d)
        beta = np.linalg.solve(A, Xc.T @ yc)
        self.coef_ = beta
        self.intercept_ = ym - xm @ beta if self.fit_intercept else np.zeros(y.shape[1])
        if squeeze:
            self.coef_ = self.coef_[:, 0]
            self.intercept_ = float(np.ravel(self.intercept_)[0])
        return self
