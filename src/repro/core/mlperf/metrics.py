"""Regression metrics used throughout the paper (Table IV)."""

from __future__ import annotations

import numpy as np


def _2d(a):
    a = np.asarray(a, dtype=np.float64)
    return a[:, None] if a.ndim == 1 else a


def r2_score(y_true, y_pred, multioutput: str = "uniform_average"):
    yt, yp = _2d(y_true), _2d(y_pred)
    ss_res = ((yt - yp) ** 2).sum(axis=0)
    ss_tot = ((yt - yt.mean(axis=0)) ** 2).sum(axis=0)
    r2 = 1.0 - ss_res / np.where(ss_tot > 0, ss_tot, 1.0)
    r2 = np.where(ss_tot > 0, r2, 0.0)
    if multioutput == "raw_values":
        return r2
    return float(r2.mean())


def mse(y_true, y_pred, multioutput: str = "uniform_average"):
    yt, yp = _2d(y_true), _2d(y_pred)
    v = ((yt - yp) ** 2).mean(axis=0)
    return v if multioutput == "raw_values" else float(v.mean())


def mae(y_true, y_pred, multioutput: str = "uniform_average"):
    yt, yp = _2d(y_true), _2d(y_pred)
    v = np.abs(yt - yp).mean(axis=0)
    return v if multioutput == "raw_values" else float(v.mean())


def _pct_errors(y_true, y_pred, eps: float = 1e-12):
    yt, yp = _2d(y_true), _2d(y_pred)
    return 100.0 * np.abs(yp - yt) / np.maximum(np.abs(yt), eps)


def median_pct_error(y_true, y_pred, multioutput: str = "uniform_average"):
    v = np.median(_pct_errors(y_true, y_pred), axis=0)
    return v if multioutput == "raw_values" else float(v.mean())


def mean_pct_error(y_true, y_pred, multioutput: str = "uniform_average"):
    v = _pct_errors(y_true, y_pred).mean(axis=0)
    return v if multioutput == "raw_values" else float(v.mean())


def regression_report(y_true, y_pred, target_names: list[str] | None = None) -> dict:
    """Per-target dict of {R2, MSE, MAE, MedPctErr, MeanPctErr} — Table IV."""
    yt, yp = _2d(y_true), _2d(y_pred)
    t = yt.shape[1]
    names = target_names or [f"target_{i}" for i in range(t)]
    rep = {}
    for i, name in enumerate(names):
        rep[name] = {
            "r2": float(r2_score(yt[:, i], yp[:, i])),
            "mse": float(mse(yt[:, i], yp[:, i])),
            "mae": float(mae(yt[:, i], yp[:, i])),
            "median_pct_err": float(median_pct_error(yt[:, i], yp[:, i])),
            "mean_pct_err": float(mean_pct_error(yt[:, i], yp[:, i])),
        }
    return rep


def pearson_corr(a, b) -> float:
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    a = a - a.mean()
    b = b - b.mean()
    denom = np.sqrt((a * a).sum() * (b * b).sum())
    return float((a * b).sum() / denom) if denom > 0 else 0.0


def correlation_matrix(table: dict[str, np.ndarray], rows: list[str],
                       cols: list[str]) -> np.ndarray:
    """Paper Table V / Fig 6: corr between dimension products and metrics."""
    out = np.zeros((len(rows), len(cols)))
    for i, r in enumerate(rows):
        for j, c in enumerate(cols):
            out[i, j] = pearson_corr(table[r], table[c])
    return out
