"""Preprocessing pipeline mirroring the paper's Algorithms 1 & 2.

- `TabularPreprocessor`: sanitize numerics, percentile clipping (0.01/0.99),
  median imputation, categorical -> one-hot; computes the derived GEMM
  characteristics (total_flops, bytes_accessed, arithmetic_intensity) when
  the raw m/n/k columns are present.
- `StandardScaler` + `Pipeline`: the paper's
  Pipeline([('preprocessor', ...), ('regressor', ...)]).
- `train_test_split`: 80/20 with random-state control.
"""

from __future__ import annotations

import numpy as np


class StandardScaler:
    def __init__(self):
        self.mean_: np.ndarray | None = None
        self.scale_: np.ndarray | None = None

    def fit(self, X):
        X = np.asarray(X, dtype=np.float64)
        self.mean_ = X.mean(axis=0)
        std = X.std(axis=0)
        self.scale_ = np.where(std > 0, std, 1.0)
        return self

    def transform(self, X):
        X = np.asarray(X, dtype=np.float64)
        return (X - self.mean_) / self.scale_

    def fit_transform(self, X):
        return self.fit(X).transform(X)

    def inverse_transform(self, X):
        return np.asarray(X) * self.scale_ + self.mean_

    # ---- flat-array state contract (see mlperf.state) ----
    def to_state(self) -> dict[str, np.ndarray]:
        assert self.mean_ is not None, "not fitted"
        return {"mean": np.asarray(self.mean_, dtype=np.float64),
                "scale": np.asarray(self.scale_, dtype=np.float64)}

    @classmethod
    def from_state(cls, state) -> "StandardScaler":
        obj = cls()
        obj.mean_ = np.asarray(state["mean"], dtype=np.float64)
        obj.scale_ = np.asarray(state["scale"], dtype=np.float64)
        return obj


class TabularPreprocessor:
    """Dict-of-columns table -> (feature_matrix, feature_names).

    Numerical columns: clip to [q_lo, q_hi] percentiles (fit-time), impute
    missing with the fit-time median. Categorical (string) columns: one-hot
    with an explicit vocabulary learned at fit time (unknowns -> all-zero).
    """

    def __init__(self, clip_quantiles: tuple[float, float] = (0.01, 0.99)):
        self.clip_quantiles = clip_quantiles
        self.numeric_cols_: list[str] = []
        self.categorical_cols_: list[str] = []
        self.clip_lo_: dict[str, float] = {}
        self.clip_hi_: dict[str, float] = {}
        self.median_: dict[str, float] = {}
        self.vocab_: dict[str, list] = {}
        self.feature_names_: list[str] = []

    @staticmethod
    def _is_numeric(col: np.ndarray) -> bool:
        return np.issubdtype(np.asarray(col).dtype, np.number) or np.issubdtype(
            np.asarray(col).dtype, np.bool_
        )

    def fit(self, table: dict[str, np.ndarray]):
        self.numeric_cols_, self.categorical_cols_ = [], []
        for name, col in table.items():
            col = np.asarray(col)
            if self._is_numeric(col):
                self.numeric_cols_.append(name)
                v = col.astype(np.float64)
                finite = v[np.isfinite(v)]
                if finite.size == 0:
                    lo = hi = med = 0.0
                else:
                    lo = float(np.quantile(finite, self.clip_quantiles[0]))
                    hi = float(np.quantile(finite, self.clip_quantiles[1]))
                    med = float(np.median(finite))
                self.clip_lo_[name], self.clip_hi_[name] = lo, hi
                self.median_[name] = med
            else:
                self.categorical_cols_.append(name)
                self.vocab_[name] = sorted({str(x) for x in col})
        self.feature_names_ = list(self.numeric_cols_) + [
            f"{c}={v}" for c in self.categorical_cols_ for v in self.vocab_[c]
        ]
        return self

    def transform(self, table: dict[str, np.ndarray]) -> np.ndarray:
        n = len(next(iter(table.values())))
        cols = []
        for name in self.numeric_cols_:
            v = np.asarray(table[name], dtype=np.float64).copy()
            v = np.where(np.isfinite(v), v, self.median_[name])
            v = np.clip(v, self.clip_lo_[name], self.clip_hi_[name])
            cols.append(v)
        for name in self.categorical_cols_:
            raw = [str(x) for x in table[name]]
            for v in self.vocab_[name]:
                cols.append(np.array([1.0 if x == v else 0.0 for x in raw]))
        return np.stack(cols, axis=1) if cols else np.zeros((n, 0))

    def fit_transform(self, table):
        return self.fit(table).transform(table)


def compute_gemm_characteristics(table: dict[str, np.ndarray],
                                 bytes_per_elem: float = 4.0) -> dict[str, np.ndarray]:
    """Paper Algorithm 1, COMPUTEGEMMCHARS: derived features from m/n/k."""
    m = np.asarray(table["m"], dtype=np.float64)
    n = np.asarray(table["n"], dtype=np.float64)
    k = np.asarray(table["k"], dtype=np.float64)
    out = dict(table)
    out["total_flops"] = 2.0 * m * n * k
    out["bytes_accessed"] = bytes_per_elem * (m * k + k * n + m * n)
    out["arithmetic_intensity"] = out["total_flops"] / np.maximum(out["bytes_accessed"], 1.0)
    return out


class Pipeline:
    """('preprocessor' -> 'scaler' -> 'regressor'), the paper's Algorithm 2."""

    def __init__(self, preprocessor: TabularPreprocessor, regressor,
                 scaler: StandardScaler | None = None):
        self.preprocessor = preprocessor
        self.scaler = scaler or StandardScaler()
        self.regressor = regressor

    def fit(self, table: dict[str, np.ndarray], y: np.ndarray):
        X = self.preprocessor.fit_transform(table)
        Xs = self.scaler.fit_transform(X)
        self.regressor.fit(Xs, y)
        return self

    def predict(self, table: dict[str, np.ndarray]) -> np.ndarray:
        X = self.preprocessor.transform(table)
        return self.regressor.predict(self.scaler.transform(X))


def train_test_split(*arrays, test_size: float = 0.2, random_state: int | None = 0):
    first = arrays[0]
    n = len(next(iter(first.values()))) if isinstance(first, dict) else len(first)
    rng = np.random.default_rng(random_state)
    perm = rng.permutation(n)
    n_test = int(round(n * test_size))
    test_idx, train_idx = perm[:n_test], perm[n_test:]
    out = []
    for a in arrays:
        if isinstance(a, dict):
            out.append({k: np.asarray(v)[train_idx] for k, v in a.items()})
            out.append({k: np.asarray(v)[test_idx] for k, v in a.items()})
        else:
            a = np.asarray(a)
            out.append(a[train_idx])
            out.append(a[test_idx])
    return out
