"""Stacking ensemble: out-of-fold base-model predictions -> ridge meta-learner.

The paper's best model (Table VI, "Stacking Ensemble"): prediction =
sum_i w_i * M_i(x) with learned weights. We learn the combination per target
with a ridge meta-learner on K-fold out-of-fold predictions, which avoids the
leakage a naive refit-on-train stacking would have.
"""

from __future__ import annotations

import copy

import numpy as np

from repro.core.mlperf.linreg import Ridge


class StackingRegressor:
    def __init__(
        self,
        base_estimators: list,
        meta_alpha: float = 1e-3,
        n_folds: int = 5,
        passthrough: bool = False,
        random_state: int | None = 0,
    ):
        self.base_estimators = base_estimators
        self.meta_alpha = meta_alpha
        self.n_folds = n_folds
        self.passthrough = passthrough
        self.random_state = random_state
        self.fitted_bases_: list = []
        self.meta_: list[Ridge] = []
        self.n_targets_: int | None = None

    def _meta_features(self, preds: list[np.ndarray], X: np.ndarray) -> np.ndarray:
        Z = np.concatenate([p.reshape(len(X), -1) for p in preds], axis=1)
        if self.passthrough:
            Z = np.concatenate([Z, X], axis=1)
        return Z

    def fit(self, X, y):
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        if y.ndim == 1:
            y = y[:, None]
        self.n_targets_ = y.shape[1]
        n = len(X)
        rng = np.random.default_rng(self.random_state)
        fold = rng.integers(0, self.n_folds, size=n)

        # out-of-fold predictions per base model
        oof = [np.zeros((n, self.n_targets_)) for _ in self.base_estimators]
        for k in range(self.n_folds):
            tr, va = fold != k, fold == k
            if va.sum() == 0 or tr.sum() == 0:
                continue
            for bi, proto in enumerate(self.base_estimators):
                est = copy.deepcopy(proto)
                est.fit(X[tr], y[tr])
                p = est.predict(X[va])
                oof[bi][va] = p.reshape(va.sum(), -1)

        Z = self._meta_features(oof, X)
        self.meta_ = []
        for t in range(self.n_targets_):
            m = Ridge(alpha=self.meta_alpha)
            m.fit(Z, y[:, t])
            self.meta_.append(m)

        # refit bases on all data for inference
        self.fitted_bases_ = []
        for proto in self.base_estimators:
            est = copy.deepcopy(proto)
            est.fit(X, y)
            self.fitted_bases_.append(est)
        return self

    def predict(self, X) -> np.ndarray:
        X = np.asarray(X, dtype=np.float64)
        preds = [est.predict(X).reshape(len(X), -1) for est in self.fitted_bases_]
        Z = self._meta_features(preds, X)
        out = np.stack([m.predict(Z) for m in self.meta_], axis=1)
        return out[:, 0] if self.n_targets_ == 1 else out
