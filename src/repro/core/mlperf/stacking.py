"""Stacking ensemble: out-of-fold base-model predictions -> ridge meta-learner.

The paper's best model (Table VI, "Stacking Ensemble"): prediction =
sum_i w_i * M_i(x) with learned weights. We learn the combination per target
with a ridge meta-learner on K-fold out-of-fold predictions, which avoids the
leakage a naive refit-on-train stacking would have.
"""

from __future__ import annotations

import copy

import numpy as np

from repro.core.mlperf.linreg import Ridge
from repro.core.mlperf.state import (
    CLASS_KEY,
    class_tag,
    estimator_from_state,
    pack_nested,
    register_estimator,
    scalar,
    unpack_nested,
)


@register_estimator
class StackingRegressor:
    def __init__(
        self,
        base_estimators: list,
        meta_alpha: float = 1e-3,
        n_folds: int = 5,
        passthrough: bool = False,
        random_state: int | None = 0,
    ):
        self.base_estimators = base_estimators
        self.meta_alpha = meta_alpha
        self.n_folds = n_folds
        self.passthrough = passthrough
        self.random_state = random_state
        self.fitted_bases_: list = []
        self.meta_: list[Ridge] = []
        self.n_targets_: int | None = None

    def _meta_features(self, preds: list[np.ndarray], X: np.ndarray) -> np.ndarray:
        Z = np.concatenate([p.reshape(len(X), -1) for p in preds], axis=1)
        if self.passthrough:
            Z = np.concatenate([Z, X], axis=1)
        return Z

    def fit(self, X, y):
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        if y.ndim == 1:
            y = y[:, None]
        self.n_targets_ = y.shape[1]
        n = len(X)
        rng = np.random.default_rng(self.random_state)
        fold = rng.integers(0, self.n_folds, size=n)

        # out-of-fold predictions per base model
        oof = [np.zeros((n, self.n_targets_)) for _ in self.base_estimators]
        for k in range(self.n_folds):
            tr, va = fold != k, fold == k
            if va.sum() == 0 or tr.sum() == 0:
                continue
            for bi, proto in enumerate(self.base_estimators):
                est = copy.deepcopy(proto)
                est.fit(X[tr], y[tr])
                p = est.predict(X[va])
                oof[bi][va] = p.reshape(va.sum(), -1)

        Z = self._meta_features(oof, X)
        self.meta_ = []
        for t in range(self.n_targets_):
            m = Ridge(alpha=self.meta_alpha)
            m.fit(Z, y[:, t])
            self.meta_.append(m)

        # refit bases on all data for inference
        self.fitted_bases_ = []
        for proto in self.base_estimators:
            est = copy.deepcopy(proto)
            est.fit(X, y)
            self.fitted_bases_.append(est)
        return self

    def predict(self, X) -> np.ndarray:
        X = np.asarray(X, dtype=np.float64)
        preds = [est.predict(X).reshape(len(X), -1) for est in self.fitted_bases_]
        Z = self._meta_features(preds, X)
        out = np.stack([m.predict(Z) for m in self.meta_], axis=1)
        return out[:, 0] if self.n_targets_ == 1 else out

    # ---- flat-array state contract (see mlperf.state) ----
    def to_state(self) -> dict[str, np.ndarray]:
        assert self.fitted_bases_, "not fitted"
        state: dict[str, np.ndarray] = {
            CLASS_KEY: class_tag(type(self)),
            "n_bases": scalar(np.int64(len(self.fitted_bases_))),
            "n_targets": scalar(np.int64(self.n_targets_)),
            "passthrough": scalar(np.bool_(self.passthrough)),
            # meta ridges are per-target with 1-d coefs: stack to (T, Z)
            "meta_coef": np.stack(
                [np.asarray(m.coef_, dtype=np.float64) for m in self.meta_]),
            "meta_intercept": np.array(
                [float(np.ravel(m.intercept_)[0]) for m in self.meta_]),
        }
        for i, est in enumerate(self.fitted_bases_):
            state.update(pack_nested(f"base{i}", est.to_state()))
        return state

    @classmethod
    def from_state(cls, state: dict[str, np.ndarray]) -> "StackingRegressor":
        obj = cls([], passthrough=bool(state["passthrough"][()]))
        obj.n_targets_ = int(state["n_targets"][()])
        obj.fitted_bases_ = [
            estimator_from_state(unpack_nested(state, f"base{i}"))
            for i in range(int(state["n_bases"][()]))
        ]
        meta_coef = np.asarray(state["meta_coef"], dtype=np.float64)
        meta_intercept = np.asarray(state["meta_intercept"], dtype=np.float64)
        obj.meta_ = []
        for t in range(obj.n_targets_):
            m = Ridge(alpha=obj.meta_alpha)
            m.coef_ = meta_coef[t]
            m.intercept_ = float(meta_intercept[t])
            obj.meta_.append(m)
        return obj
