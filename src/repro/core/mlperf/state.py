"""Pickle-free estimator serialization: the flat-array state contract.

Every estimator in the mlperf zoo implements

    est.to_state()            -> dict[str, np.ndarray]
    Cls.from_state(state)     -> predict-ready estimator

where the state dict contains ONLY numpy arrays (scalars as 0-d arrays,
class tags as 0-d unicode arrays). That makes any fitted model a plain
bag of arrays that round-trips through ``np.savez`` with
``allow_pickle=False`` — no code execution on load, no version-brittle
byte blobs, and the same arrays double as the content fingerprint for
artifact versioning (see ``repro.core.predictor``).

Nested estimators (stacking bases) are namespaced with '/'-separated key
prefixes via `pack_nested`/`unpack_nested`. `estimator_from_state`
dispatches on the reserved ``__class__`` key through a registry that the
estimator modules populate at import time.

States restore the *prediction* surface (plus feature importances for
trees); refitting a restored estimator starts from scratch like a fresh
instance, it does not resume.
"""

from __future__ import annotations

import numpy as np

CLASS_KEY = "__class__"

_REGISTRY: dict[str, type] = {}


def register_estimator(cls: type) -> type:
    """Class decorator: make `cls` reachable from `estimator_from_state`."""
    _REGISTRY[cls.__name__] = cls
    return cls


def registered_estimator_names() -> list[str]:
    """Class names reachable from `estimator_from_state` (the serialization
    registry; `compiled.compilable_families` is the jit-lowering analogue —
    the parity suite asserts every serializable family also compiles)."""
    return sorted(_REGISTRY)


def class_tag(cls: type) -> np.ndarray:
    return np.array(cls.__name__)


def scalar(x) -> np.ndarray:
    """Store a python scalar as a 0-d numpy array."""
    return np.asarray(x)


def pack_nested(prefix: str, state: dict[str, np.ndarray]
                ) -> dict[str, np.ndarray]:
    """Namespace a child state under `prefix/`."""
    return {f"{prefix}/{k}": v for k, v in state.items()}


def unpack_nested(state: dict[str, np.ndarray], prefix: str
                  ) -> dict[str, np.ndarray]:
    """Extract the child state stored under `prefix/`."""
    p = prefix + "/"
    return {k[len(p):]: v for k, v in state.items() if k.startswith(p)}


def estimator_from_state(state: dict[str, np.ndarray]):
    """Rebuild any registered estimator from its flat-array state."""
    if CLASS_KEY not in state:
        raise ValueError("estimator state missing __class__ tag")
    name = str(state[CLASS_KEY][()])
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown estimator class {name!r}; known: {sorted(_REGISTRY)}"
        ) from None
    return cls.from_state(state)
