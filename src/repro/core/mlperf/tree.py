"""Histogram-based CART regression tree (multi-output).

Split finding follows the classic variance-reduction criterion, evaluated on
quantile-binned features (up to 255 bins). Binning turns per-node split search
into a handful of `np.bincount` calls, which keeps a 100-tree forest on ~16k
rows in the seconds range on a single CPU core.

Trees are stored as flat arrays (struct-of-arrays), which makes them cheap to
serialize and lets `jaxpredict.py` run the whole forest inside `jax.jit`.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.mlperf.state import (
    CLASS_KEY,
    class_tag,
    register_estimator,
    scalar,
)

_MAX_BINS = 255  # bin index 255 reserved for "missing"


class Binner:
    """Quantile binner mapping float features to uint8 bin codes."""

    def __init__(self, max_bins: int = _MAX_BINS):
        if not 2 <= max_bins <= _MAX_BINS:
            raise ValueError(f"max_bins must be in [2, {_MAX_BINS}]")
        self.max_bins = max_bins
        self.bin_edges_: list[np.ndarray] | None = None

    def fit(self, X: np.ndarray) -> "Binner":
        X = np.asarray(X, dtype=np.float64)
        edges = []
        qs = np.linspace(0, 1, self.max_bins + 1)[1:-1]
        for j in range(X.shape[1]):
            col = X[:, j]
            col = col[np.isfinite(col)]
            if col.size == 0:
                edges.append(np.array([0.0]))
                continue
            e = np.unique(np.quantile(col, qs))
            if e.size == 0:  # constant column
                e = np.array([col[0]])
            edges.append(e)
        self.bin_edges_ = edges
        return self

    def transform(self, X: np.ndarray) -> np.ndarray:
        assert self.bin_edges_ is not None, "Binner not fitted"
        X = np.asarray(X, dtype=np.float64)
        out = np.empty(X.shape, dtype=np.uint8)
        for j, e in enumerate(self.bin_edges_):
            code = np.searchsorted(e, X[:, j], side="right").astype(np.uint8)
            code = np.where(np.isfinite(X[:, j]), code, np.uint8(_MAX_BINS))
            out[:, j] = code
        return out

    def fit_transform(self, X: np.ndarray) -> np.ndarray:
        return self.fit(X).transform(X)

    def n_bins(self, j: int) -> int:
        assert self.bin_edges_ is not None
        return len(self.bin_edges_[j]) + 1

    def threshold_value(self, j: int, bin_code: int) -> float:
        """Raw-space threshold for 'go left if x <= t'.

        Uses the midpoint between adjacent bin edges (sklearn-style): data
        values sit exactly on edges, so midpoints keep raw-space prediction
        consistent with binned training *and* robust to fp32 rounding in the
        jitted prediction path.
        """
        assert self.bin_edges_ is not None
        e = self.bin_edges_[j]
        idx = min(int(bin_code), len(e) - 1)
        lo = float(e[idx])
        if idx + 1 < len(e):
            return 0.5 * (lo + float(e[idx + 1]))
        return lo


@dataclasses.dataclass
class _FlatTree:
    """Struct-of-arrays tree. Internal node i tests
    `x[:, feature[i]] <= threshold[i]` (raw feature space); children are
    `left[i]` / `right[i]`. Leaves have feature == -1 and carry `value[i]`
    (n_targets,). `threshold_bin` retains the binned threshold for exactness.
    """

    feature: np.ndarray       # (n_nodes,) int32, -1 for leaf
    threshold: np.ndarray     # (n_nodes,) float64, raw-space
    threshold_bin: np.ndarray # (n_nodes,) int32, binned-space
    left: np.ndarray          # (n_nodes,) int32
    right: np.ndarray         # (n_nodes,) int32
    value: np.ndarray         # (n_nodes, n_targets) float64
    n_samples: np.ndarray     # (n_nodes,) int32
    gain: np.ndarray          # (n_nodes,) float64 (split gain, 0 for leaves)

    @property
    def n_nodes(self) -> int:
        return len(self.feature)

    def predict_binned(self, Xb: np.ndarray) -> np.ndarray:
        """Predict from uint8 binned features (vectorized level descent)."""
        n = Xb.shape[0]
        node = np.zeros(n, dtype=np.int32)
        active = self.feature[node] >= 0
        while active.any():
            idx = np.nonzero(active)[0]
            nd = node[idx]
            f = self.feature[nd]
            go_left = Xb[idx, f] <= self.threshold_bin[nd]
            node[idx] = np.where(go_left, self.left[nd], self.right[nd])
            active = self.feature[node] >= 0
        return self.value[node]

    def predict_raw(self, X: np.ndarray) -> np.ndarray:
        """Predict from raw float features."""
        n = X.shape[0]
        node = np.zeros(n, dtype=np.int32)
        active = self.feature[node] >= 0
        while active.any():
            idx = np.nonzero(active)[0]
            nd = node[idx]
            f = self.feature[nd]
            go_left = X[idx, f] <= self.threshold[nd]
            node[idx] = np.where(go_left, self.left[nd], self.right[nd])
            active = self.feature[node] >= 0
        return self.value[node]


def flatten_ensemble(trees: list[_FlatTree]) -> dict[str, np.ndarray]:
    """Global-id flat layout for batched descent over a whole ensemble.

    Node arrays of every tree are concatenated and children rebased to
    global node ids; leaves self-loop (left == right == own id), so the
    descent is a pure fixed-point iteration with 1-d gathers only — no
    per-tree padding, no 2-d advanced indexing.
    """
    offsets = np.cumsum([0] + [t.n_nodes for t in trees[:-1]]).astype(np.int64)
    feature = np.concatenate([t.feature for t in trees])
    threshold = np.concatenate([t.threshold for t in trees])
    left = np.concatenate([t.left + o for t, o in zip(trees, offsets)])
    right = np.concatenate([t.right + o for t, o in zip(trees, offsets)])
    node_ids = np.arange(len(feature), dtype=np.int64)
    is_leaf = feature < 0
    left = np.where(is_leaf, node_ids, left)
    right = np.where(is_leaf, node_ids, right)
    return {
        "feature": np.maximum(feature, 0).astype(np.int64),
        "threshold": threshold.astype(np.float64),
        "left": left.astype(np.int64),
        "right": right.astype(np.int64),
        "value": np.concatenate([t.value for t in trees], axis=0),
        "roots": offsets,
    }


def predict_stacked(flat: dict[str, np.ndarray], X: np.ndarray,
                    max_depth: int | None = None) -> np.ndarray:
    """Leaf values for every (tree, sample) pair at once: (T, N, K).

    One level-synchronous descent over the whole ensemble — a (T*N,)
    cursor vector advanced together — instead of a Python loop over
    trees. Reaches the identical leaves as `_FlatTree.predict_raw`.
    With `max_depth` the loop runs a fixed step count (leaves self-loop,
    so overshooting is a no-op); otherwise it iterates to convergence.
    """
    X = np.ascontiguousarray(X, dtype=np.float64)
    N, F = X.shape
    Xr = X.ravel()
    roots = flat["roots"]
    T = len(roots)
    feature, threshold = flat["feature"], flat["threshold"]
    left, right = flat["left"], flat["right"]
    node = np.repeat(roots, N)                       # (T*N,) cursor vector
    row = np.tile(np.arange(N, dtype=np.int64) * F, T)
    steps = 0
    while True:
        x = Xr[row + feature[node]]                  # per-cursor feature
        nxt = np.where(x <= threshold[node], left[node], right[node])
        steps += 1
        if max_depth is not None:
            node = nxt
            if steps >= max_depth:
                break
        else:
            if np.array_equal(nxt, node):            # all cursors on leaves
                break
            node = nxt
    return flat["value"][node].reshape(T, N, -1)     # (T, N, K)


def cast_flat_ensemble(flat: dict[str, np.ndarray], *, float64: bool
                       ) -> dict[str, np.ndarray]:
    """Precision-cast a `flatten_ensemble` layout for the compiled scorer.

    `float64=True` keeps exact thresholds/values so x64 traversal takes
    bit-identical branches vs the numpy reference. The fp32 path nudges
    each threshold up one fp32 ulp: thresholds sit exactly on training-data
    values (quantile bin edges), so values that compared `<=` in fp64 must
    still go left after fp32 rounding in the jitted path.
    """
    if float64:
        return dict(flat)
    thr32 = flat["threshold"].astype(np.float32)
    return {
        "feature": flat["feature"],
        "threshold": np.nextafter(thr32, np.float32(np.inf)),
        "left": flat["left"],
        "right": flat["right"],
        "value": flat["value"].astype(np.float32),
        "roots": flat["roots"],
    }


def concat_flat_trees(trees: list[_FlatTree]) -> dict[str, np.ndarray]:
    """Ragged ensemble -> concatenated arrays + `tree_offsets` (T+1,)."""
    offsets = np.cumsum([0] + [t.n_nodes for t in trees]).astype(np.int64)
    return {
        "feature": np.concatenate([t.feature for t in trees]),
        "threshold": np.concatenate([t.threshold for t in trees]),
        "threshold_bin": np.concatenate([t.threshold_bin for t in trees]),
        "left": np.concatenate([t.left for t in trees]),
        "right": np.concatenate([t.right for t in trees]),
        "value": np.concatenate([t.value for t in trees], axis=0),
        "n_samples": np.concatenate([t.n_samples for t in trees]),
        "gain": np.concatenate([t.gain for t in trees]),
        "tree_offsets": offsets,
    }


def split_flat_trees(state: dict[str, np.ndarray]) -> list[_FlatTree]:
    """Inverse of `concat_flat_trees`."""
    offsets = np.asarray(state["tree_offsets"], dtype=np.int64)
    trees = []
    for a, b in zip(offsets[:-1], offsets[1:]):
        trees.append(_FlatTree(
            feature=np.asarray(state["feature"][a:b], dtype=np.int32),
            threshold=np.asarray(state["threshold"][a:b], dtype=np.float64),
            threshold_bin=np.asarray(state["threshold_bin"][a:b],
                                     dtype=np.int32),
            left=np.asarray(state["left"][a:b], dtype=np.int32),
            right=np.asarray(state["right"][a:b], dtype=np.int32),
            value=np.asarray(state["value"][a:b], dtype=np.float64),
            n_samples=np.asarray(state["n_samples"][a:b], dtype=np.int32),
            gain=np.asarray(state["gain"][a:b], dtype=np.float64),
        ))
    return trees


def estimators_from_state(state: dict[str, np.ndarray]
                          ) -> list["DecisionTreeRegressor"]:
    """Rebuild predict-ready DecisionTreeRegressor wrappers from a
    concatenated-ensemble state (the shared tail of forest/GBDT
    `from_state`)."""
    max_depth = int(state["max_depth"][()])
    n_features = int(state["n_features"][()])
    n_targets = int(state["n_targets"][()])
    out = []
    for t in split_flat_trees(state):
        est = DecisionTreeRegressor(max_depth=max_depth)
        est.tree_ = t
        est.n_features_ = n_features
        est.n_targets_ = n_targets
        out.append(est)
    return out


class _TreeBuilder:
    """Depth-first histogram CART builder on pre-binned features."""

    def __init__(
        self,
        binner: Binner,
        max_depth: int,
        min_samples_split: int,
        min_samples_leaf: int,
        max_features: int | None,
        rng: np.random.Generator,
    ):
        self.binner = binner
        self.max_depth = max_depth
        self.min_samples_split = min_samples_split
        self.min_samples_leaf = min_samples_leaf
        self.max_features = max_features
        self.rng = rng

    def build(self, Xb: np.ndarray, y: np.ndarray, sample_weight: np.ndarray) -> _FlatTree:
        n, n_features = Xb.shape
        n_targets = y.shape[1]
        feature, thr, thr_bin, left, right, value, nsmp, gain = (
            [], [], [], [], [], [], [], []
        )

        def new_node() -> int:
            feature.append(-1)
            thr.append(0.0)
            thr_bin.append(0)
            left.append(-1)
            right.append(-1)
            value.append(np.zeros(n_targets))
            nsmp.append(0)
            gain.append(0.0)
            return len(feature) - 1

        root = new_node()
        # stack entries: (node_id, row_indices, depth)
        stack: list[tuple[int, np.ndarray, int]] = [(root, np.arange(n), 0)]
        while stack:
            node_id, rows, depth = stack.pop()
            w = sample_weight[rows]
            wsum = w.sum()
            ymean = (y[rows] * w[:, None]).sum(axis=0) / wsum
            value[node_id] = ymean
            nsmp[node_id] = len(rows)
            if (
                depth >= self.max_depth
                or len(rows) < self.min_samples_split
                or wsum <= 0
            ):
                continue
            best = self._best_split(Xb, y, rows, w, ymean)
            if best is None:
                continue
            f, b, g = best
            go_left = Xb[rows, f] <= b
            lrows, rrows = rows[go_left], rows[~go_left]
            if len(lrows) < self.min_samples_leaf or len(rrows) < self.min_samples_leaf:
                continue
            lid, rid = new_node(), new_node()
            feature[node_id] = f
            thr_bin[node_id] = b
            thr[node_id] = self.binner.threshold_value(f, b)
            left[node_id], right[node_id] = lid, rid
            gain[node_id] = g
            stack.append((lid, lrows, depth + 1))
            stack.append((rid, rrows, depth + 1))

        return _FlatTree(
            feature=np.array(feature, dtype=np.int32),
            threshold=np.array(thr, dtype=np.float64),
            threshold_bin=np.array(thr_bin, dtype=np.int32),
            left=np.array(left, dtype=np.int32),
            right=np.array(right, dtype=np.int32),
            value=np.array(value, dtype=np.float64).reshape(len(feature), n_targets),
            n_samples=np.array(nsmp, dtype=np.int32),
            gain=np.array(gain, dtype=np.float64),
        )

    def _best_split(self, Xb, y, rows, w, parent_mean):
        """Weighted variance-reduction split over candidate features.

        Returns (feature, bin_threshold, gain) or None. Gain is the decrease
        in total weighted SSE summed across targets.
        """
        n_features = Xb.shape[1]
        if self.max_features is not None and self.max_features < n_features:
            feats = self.rng.choice(n_features, size=self.max_features, replace=False)
        else:
            feats = np.arange(n_features)

        yr = y[rows]                      # (m, t)
        wy = yr * w[:, None]              # weighted targets
        wy2 = (yr * yr * w[:, None]).sum(axis=1)  # (m,) sum over targets of w*y^2
        wsum_tot = w.sum()
        wy_tot = wy.sum(axis=0)           # (t,)
        # parent SSE = sum w*y^2 - sum_t (sum w*y)^2 / sum w
        parent_sse = wy2.sum() - float((wy_tot**2).sum() / wsum_tot)

        best_gain = 1e-12
        best = None
        nb_all = _MAX_BINS + 1
        for f in feats:
            codes = Xb[rows, f].astype(np.int64)
            nb = self.binner.n_bins(f)
            if nb <= 1:
                continue
            cnt_w = np.bincount(codes, weights=w, minlength=nb_all)[:nb]
            if (cnt_w > 0).sum() <= 1:
                continue
            s2 = np.bincount(codes, weights=wy2, minlength=nb_all)[:nb]
            # per-target weighted sums per bin
            t = yr.shape[1]
            s1 = np.empty((nb, t))
            for k in range(t):
                s1[:, k] = np.bincount(codes, weights=wy[:, k], minlength=nb_all)[:nb]
            cw = np.cumsum(cnt_w)[:-1]
            cs1 = np.cumsum(s1, axis=0)[:-1]
            cs2 = np.cumsum(s2)[:-1]
            rw = wsum_tot - cw
            rs1 = wy_tot[None, :] - cs1
            rs2 = wy2.sum() - cs2
            valid = (cw > 0) & (rw > 0)
            if not valid.any():
                continue
            with np.errstate(divide="ignore", invalid="ignore"):
                lsse = cs2 - (cs1**2).sum(axis=1) / cw
                rsse = rs2 - (rs1**2).sum(axis=1) / rw
            child = np.where(valid, lsse + rsse, np.inf)
            b = int(np.argmin(child))
            g = parent_sse - float(child[b])
            if g > best_gain:
                best_gain = g
                best = (int(f), b, g)
        return best


@register_estimator
class DecisionTreeRegressor:
    """Multi-output CART regression tree (histogram split finding)."""

    def __init__(
        self,
        max_depth: int = 6,
        min_samples_split: int = 2,
        min_samples_leaf: int = 1,
        max_features: int | float | str | None = None,
        max_bins: int = _MAX_BINS,
        random_state: int | None = None,
    ):
        self.max_depth = max_depth
        self.min_samples_split = min_samples_split
        self.min_samples_leaf = min_samples_leaf
        self.max_features = max_features
        self.max_bins = max_bins
        self.random_state = random_state
        self.tree_: _FlatTree | None = None
        self.binner_: Binner | None = None
        self.n_features_: int | None = None
        self.n_targets_: int | None = None

    def _resolve_max_features(self, n_features: int) -> int | None:
        mf = self.max_features
        if mf is None:
            return None
        if mf == "sqrt":
            return max(1, int(np.sqrt(n_features)))
        if mf == "log2":
            return max(1, int(np.log2(n_features)))
        if isinstance(mf, float):
            return max(1, int(mf * n_features))
        return int(mf)

    def fit(self, X, y, sample_weight=None, *, binner: Binner | None = None,
            Xb: np.ndarray | None = None):
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        if y.ndim == 1:
            y = y[:, None]
        self.n_features_ = X.shape[1]
        self.n_targets_ = y.shape[1]
        if sample_weight is None:
            sample_weight = np.ones(len(X))
        sample_weight = np.asarray(sample_weight, dtype=np.float64)
        if binner is None:
            binner = Binner(self.max_bins).fit(X)
            Xb = binner.transform(X)
        elif Xb is None:
            Xb = binner.transform(X)
        self.binner_ = binner
        rng = np.random.default_rng(self.random_state)
        builder = _TreeBuilder(
            binner,
            self.max_depth,
            self.min_samples_split,
            self.min_samples_leaf,
            self._resolve_max_features(X.shape[1]),
            rng,
        )
        self.tree_ = builder.build(Xb, y, sample_weight)
        return self

    def predict(self, X) -> np.ndarray:
        assert self.tree_ is not None, "not fitted"
        X = np.asarray(X, dtype=np.float64)
        out = self.tree_.predict_raw(X)
        return out[:, 0] if self.n_targets_ == 1 else out

    @property
    def feature_importances_(self) -> np.ndarray:
        """Gain-based importances, normalized to sum 1."""
        assert self.tree_ is not None and self.n_features_ is not None
        imp = np.zeros(self.n_features_)
        mask = self.tree_.feature >= 0
        np.add.at(imp, self.tree_.feature[mask], self.tree_.gain[mask])
        s = imp.sum()
        return imp / s if s > 0 else imp

    # ---- flat-array state contract (see mlperf.state) ----
    def to_state(self) -> dict[str, np.ndarray]:
        assert self.tree_ is not None, "not fitted"
        state = concat_flat_trees([self.tree_])
        state[CLASS_KEY] = class_tag(type(self))
        state["n_features"] = scalar(np.int64(self.n_features_))
        state["n_targets"] = scalar(np.int64(self.n_targets_))
        state["max_depth"] = scalar(np.int64(self.max_depth))
        return state

    @classmethod
    def from_state(cls, state: dict[str, np.ndarray]
                   ) -> "DecisionTreeRegressor":
        obj = cls(max_depth=int(state["max_depth"][()]))
        obj.tree_ = split_flat_trees(state)[0]
        obj.n_features_ = int(state["n_features"][()])
        obj.n_targets_ = int(state["n_targets"][()])
        return obj
