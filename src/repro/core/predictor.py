"""Multi-target performance predictor — the paper's Algorithm 2 pipeline.

  Pipeline([('preprocessor', StandardScaler over numeric features),
            ('regressor', MultiOutput(RandomForest(n_estimators=100,
                                                   max_depth=6)))])

predicting [runtime_ms, power_w, energy_j, tflops] simultaneously.
`model=` selects the Table VI architecture: rf / gbdt / linreg / stacking.
"""

from __future__ import annotations

import pickle

import numpy as np

from repro.core.features import NUMERIC_FEATURES, TARGETS
from repro.core.mlperf import (
    GradientBoostedTreesRegressor,
    LinearRegression,
    RandomForestRegressor,
    StackingRegressor,
    StandardScaler,
    regression_report,
)
from repro.core.mlperf.jaxpredict import JaxForestPredictor


def make_model(name: str, random_state: int = 0, fast: bool = False):
    """Table VI model zoo. `fast` shrinks ensembles for unit tests."""
    ne = 24 if fast else 100
    if name == "rf":
        return RandomForestRegressor(n_estimators=ne, max_depth=6,
                                     random_state=random_state, n_jobs=-1)
    if name == "rf_deep":  # beyond-paper: depth 12 (see EXPERIMENTS §Perf)
        return RandomForestRegressor(n_estimators=ne, max_depth=12,
                                     random_state=random_state, n_jobs=-1)
    if name == "gbdt":
        return GradientBoostedTreesRegressor(
            n_estimators=60 if fast else 300, max_depth=5,
            random_state=random_state)
    if name == "linreg":
        return LinearRegression()
    if name == "stacking":
        return StackingRegressor(
            [
                RandomForestRegressor(n_estimators=ne, max_depth=10,
                                      random_state=random_state),
                GradientBoostedTreesRegressor(
                    n_estimators=60 if fast else 250, max_depth=5,
                    random_state=random_state),
                LinearRegression(),
            ],
            n_folds=4,
        )
    raise ValueError(f"unknown model {name!r}")


class PerfPredictor:
    """fit(table) / predict(table) over dict-of-columns GEMM tables.

    Targets are learned in log-space for runtime/energy (they span 5+ orders
    of magnitude; the paper's high mean-%-error on energy is exactly the
    linear-space pathology) — `log_targets=False` reproduces the paper's
    exact setup for the faithful baseline.
    """

    LOG_TARGETS = ("runtime_ms", "energy_j", "tflops")

    def __init__(self, model: str = "rf", log_targets: bool = True,
                 residual: bool = False, random_state: int = 0,
                 fast: bool = False, chip: str | None = None):
        """residual=True predicts log(target / analytical_anchor) for the
        log-scale targets — the anchor (a naive roofline estimate from
        published chip specs) carries the 5-orders-of-magnitude dynamic
        range and the forest learns bounded corrections. This is the
        beyond-paper hybrid analytical+ML mode (EXPERIMENTS.md §Perf-pred);
        residual=False is the paper-faithful direct-regression mode.
        """
        self.model_name = model
        self.chip_name = chip  # substrate the training table came from
        self.log_targets = log_targets
        self.residual = residual
        self.scaler = StandardScaler()
        # Targets are standardized too: with a shared multi-output tree the
        # split criterion sums variance across targets, so an unscaled target
        # (power_w, var ~1e3) would monopolize every split.
        self.y_scaler = StandardScaler()
        self.model = make_model(model, random_state=random_state, fast=fast)
        self.feature_names = list(NUMERIC_FEATURES)
        self.target_names = list(TARGETS)
        self._fitted = False

    # ----- table <-> matrix -----
    def _X(self, table: dict[str, np.ndarray]) -> np.ndarray:
        cols = [np.asarray(table[k], dtype=np.float64)
                for k in self.feature_names]
        return np.stack(cols, axis=1)

    def _anchors(self, table: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
        """Analytical anchors per log-target (naive roofline estimates)."""
        rt = (np.maximum(np.asarray(table["naive_compute_ms"], np.float64),
                         np.asarray(table["naive_memory_ms"], np.float64))
              + np.asarray(table["naive_overhead_ms"], np.float64))
        rt = np.maximum(rt, 1e-9)
        flops = np.asarray(table["total_flops"], np.float64)
        return {
            "runtime_ms": rt,
            "energy_j": rt / 1e3 * 130.0,           # nominal mid-load power
            "tflops": flops / (rt / 1e3) / 1e12,
        }

    def _encode_y(self, Y: np.ndarray,
                  table: dict[str, np.ndarray] | None = None) -> np.ndarray:
        Y = Y.copy()
        anchors = self._anchors(table) if (self.residual and table) else {}
        if self.log_targets:
            for i, t in enumerate(self.target_names):
                if t in self.LOG_TARGETS:
                    y = np.maximum(Y[:, i], 1e-12)
                    if t in anchors:
                        y = y / np.maximum(anchors[t], 1e-12)
                    Y[:, i] = np.log(y)
        return Y

    def _decode_y(self, Y: np.ndarray,
                  table: dict[str, np.ndarray] | None = None) -> np.ndarray:
        Y = Y.copy()
        anchors = self._anchors(table) if (self.residual and table) else {}
        if self.log_targets:
            for i, t in enumerate(self.target_names):
                if t in self.LOG_TARGETS:
                    y = np.exp(Y[:, i])
                    if t in anchors:
                        y = y * np.maximum(anchors[t], 1e-12)
                    Y[:, i] = y
        return Y

    # ----- public API -----
    def fit(self, table: dict[str, np.ndarray],
            targets: np.ndarray | None = None) -> "PerfPredictor":
        X = self._X(table)
        if targets is None:
            targets = np.stack(
                [np.asarray(table[t], dtype=np.float64)
                 for t in self.target_names], axis=1)
        Xs = self.scaler.fit_transform(X)
        self.model.fit(
            Xs, self.y_scaler.fit_transform(self._encode_y(targets, table)))
        self._fitted = True
        return self

    def predict(self, table: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
        Y = self.predict_matrix(table)
        return {t: Y[:, i] for i, t in enumerate(self.target_names)}

    def predict_matrix(self, table: dict[str, np.ndarray]) -> np.ndarray:
        assert self._fitted, "predictor not fitted"
        X = self.scaler.transform(self._X(table))
        Y = np.asarray(self.model.predict(X), dtype=np.float64)
        if Y.ndim == 1:
            Y = Y[:, None]
        return self._decode_y(self.y_scaler.inverse_transform(Y), table)

    def evaluate(self, table: dict[str, np.ndarray]) -> dict:
        """Table IV: per-target R2/MSE/MAE/median%/mean% report."""
        truth = np.stack(
            [np.asarray(table[t], dtype=np.float64)
             for t in self.target_names], axis=1)
        pred = self.predict_matrix(table)
        return regression_report(truth, pred, self.target_names)

    # ----- jitted path (forest models only) -----
    def jax_predictor(self):
        """JaxForestPredictor over *scaled* features. Returns (fn, meta):
        fn(X_raw (N,F) jnp) -> (N, T) decoded predictions via pure jax."""
        if not isinstance(self.model, RandomForestRegressor):
            raise TypeError("jitted prediction requires a forest model")
        import jax.numpy as jnp

        jp = JaxForestPredictor(self.model)
        mean = jnp.asarray(self.scaler.mean_, dtype=jnp.float32)
        scale = jnp.asarray(self.scaler.scale_, dtype=jnp.float32)
        y_mean = jnp.asarray(self.y_scaler.mean_, dtype=jnp.float32)
        y_scale = jnp.asarray(self.y_scaler.scale_, dtype=jnp.float32)
        log_mask = jnp.asarray(
            [1.0 if t in self.LOG_TARGETS else 0.0 for t in self.target_names],
            dtype=jnp.float32)
        i_nc = self.feature_names.index("naive_compute_ms")
        i_nm = self.feature_names.index("naive_memory_ms")
        i_no = self.feature_names.index("naive_overhead_ms")
        i_fl = self.feature_names.index("total_flops")
        residual = self.residual
        t_idx = {t: i for i, t in enumerate(self.target_names)}

        def fn(X_raw):
            Xs = (X_raw - mean) / scale
            Y = jp(Xs) * y_scale + y_mean
            Y = jnp.where(log_mask > 0, jnp.exp(Y), Y)
            if residual:
                rt = (jnp.maximum(X_raw[:, i_nc], X_raw[:, i_nm])
                      + X_raw[:, i_no])
                rt = jnp.maximum(rt, 1e-9)
                anchors = {
                    "runtime_ms": rt,
                    "energy_j": rt / 1e3 * 130.0,
                    "tflops": X_raw[:, i_fl] / (rt / 1e3) / 1e12,
                }
                cols = []
                for t in self.target_names:
                    col = Y[:, t_idx[t]]
                    if t in anchors:
                        col = col * anchors[t]
                    cols.append(col)
                Y = jnp.stack(cols, axis=1)
            return Y

        return fn

    # ----- persistence -----
    def save(self, path: str) -> None:
        with open(path, "wb") as f:
            pickle.dump(self, f)

    @staticmethod
    def load(path: str) -> "PerfPredictor":
        with open(path, "rb") as f:
            obj = pickle.load(f)
        if not isinstance(obj, PerfPredictor):
            raise TypeError(f"{path} is not a PerfPredictor checkpoint")
        return obj
