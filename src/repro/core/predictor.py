"""Multi-target performance predictor — the paper's Algorithm 2 pipeline.

  Pipeline([('preprocessor', StandardScaler over numeric features),
            ('regressor', MultiOutput(RandomForest(n_estimators=100,
                                                   max_depth=6)))])

predicting [runtime_ms, power_w, energy_j, tflops] simultaneously.
`model=` selects the Table VI architecture: rf / gbdt / linreg / stacking.

Persistence is pickle-free: `save`/`load` speak a versioned artifact format —
one ``.npz`` holding the estimator's flat-array state (see
``repro.core.mlperf.state``) plus a ``__meta__`` JSON record (schema version,
chip, feature/target schema, model name, log/residual flags, content
fingerprint). `load` validates the metadata and refuses artifacts whose
feature schema doesn't match the running code or whose arrays were tampered
with; the fingerprint also versions downstream caches (the autotuner keys its
winner cache by it, so retraining invalidates stale winners).
"""

from __future__ import annotations

import hashlib
import json

import numpy as np

from repro.core.chips import TPU_V5E, get_chip
from repro.core.features import NUMERIC_FEATURES, TARGETS
from repro.core.mlperf import (
    GradientBoostedTreesRegressor,
    LinearRegression,
    RandomForestRegressor,
    StackingRegressor,
    StandardScaler,
    estimator_from_state,
    pack_nested,
    regression_report,
    unpack_nested,
)
from repro.core.mlperf.compiled import (
    lower_estimator,
    precision_scope,
    supports_compile,
)

ARTIFACT_FORMAT = "repro.perf_predictor"
ARTIFACT_SCHEMA_VERSION = 1
_META_KEY = "__meta__"


class ArtifactError(ValueError):
    """A predictor artifact is malformed, tampered, or schema-incompatible."""


def artifact_fingerprint(meta: dict, state: dict) -> str:
    """Content hash of an artifact's (meta flags, state arrays) — the
    exact digest `PerfPredictor.fingerprint` would produce for a loaded
    copy. Schema upgraders use this to restamp ``meta["fingerprint"]``
    after transforming arrays (see docs/artifacts.md)."""
    h = hashlib.sha256()
    h.update(json.dumps({
        "model": meta["model"],
        "chip": meta.get("chip"),
        "nominal_power_w": meta.get("nominal_power_w"),
        "feature_names": list(meta["feature_names"]),
        "target_names": list(meta["target_names"]),
        "log_targets": bool(meta["log_targets"]),
        "residual": bool(meta["residual"]),
    }, sort_keys=True).encode())
    for key, arr in sorted(state.items()):
        h.update(key.encode())
        a = np.ascontiguousarray(arr)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()[:16]


# Schema migrations: version N -> a callable producing the version-N+1
# (meta, state) pair. When ARTIFACT_SCHEMA_VERSION is bumped, register the
# v(N-1) -> v(N) upgrader here so existing artifacts load without a
# retrain; `load` walks the chain until it reaches the current version and
# refuses artifacts with no path. An upgrader must bump
# meta["schema_version"] itself and restamp meta["fingerprint"] via
# `artifact_fingerprint` whenever it rewrites arrays or flag fields —
# the tamper check runs after the chain. Contract + example in
# docs/artifacts.md.
_SCHEMA_UPGRADERS: dict[int, object] = {}


def make_model(name: str, random_state: int = 0, fast: bool = False):
    """Table VI model zoo. `fast` shrinks ensembles for unit tests."""
    ne = 24 if fast else 100
    if name == "rf":
        return RandomForestRegressor(n_estimators=ne, max_depth=6,
                                     random_state=random_state, n_jobs=-1)
    if name == "rf_deep":  # beyond-paper: depth 12 (see EXPERIMENTS §Perf)
        return RandomForestRegressor(n_estimators=ne, max_depth=12,
                                     random_state=random_state, n_jobs=-1)
    if name == "gbdt":
        return GradientBoostedTreesRegressor(
            n_estimators=60 if fast else 300, max_depth=5,
            random_state=random_state)
    if name == "linreg":
        return LinearRegression()
    if name == "stacking":
        return StackingRegressor(
            [
                RandomForestRegressor(n_estimators=ne, max_depth=10,
                                      random_state=random_state),
                GradientBoostedTreesRegressor(
                    n_estimators=60 if fast else 250, max_depth=5,
                    random_state=random_state),
                LinearRegression(),
            ],
            n_folds=4,
        )
    raise ValueError(f"unknown model {name!r}")


MODEL_NAMES = ("rf", "rf_deep", "gbdt", "linreg", "stacking")


def _chip_nominal_power(chip: str | None) -> float:
    """Anchor power from the chip the table was collected on (the old code
    hardcoded 130.0, which is only right for TPU v5e)."""
    if chip is not None:
        try:
            return get_chip(chip).nominal_power_w
        except ValueError:
            pass  # unregistered chip name: fall back to the default chip
    return TPU_V5E.nominal_power_w


class PerfPredictor:
    """fit(table) / predict(table) over dict-of-columns GEMM tables.

    Targets are learned in log-space for runtime/energy (they span 5+ orders
    of magnitude; the paper's high mean-%-error on energy is exactly the
    linear-space pathology) — `log_targets=False` reproduces the paper's
    exact setup for the faithful baseline.
    """

    LOG_TARGETS = ("runtime_ms", "energy_j", "tflops")

    def __init__(self, model: str = "rf", log_targets: bool = True,
                 residual: bool = False, random_state: int = 0,
                 fast: bool = False, chip: str | None = None):
        """residual=True predicts log(target / analytical_anchor) for the
        log-scale targets — the anchor (a naive roofline estimate from
        published chip specs) carries the 5-orders-of-magnitude dynamic
        range and the forest learns bounded corrections. This is the
        beyond-paper hybrid analytical+ML mode (EXPERIMENTS.md §Perf-pred);
        residual=False is the paper-faithful direct-regression mode.
        """
        self.model_name = model
        self.chip_name = chip  # substrate the training table came from
        self.nominal_power_w = _chip_nominal_power(chip)
        self.log_targets = log_targets
        self.residual = residual
        self.scaler = StandardScaler()
        # Targets are standardized too: with a shared multi-output tree the
        # split criterion sums variance across targets, so an unscaled target
        # (power_w, var ~1e3) would monopolize every split.
        self.y_scaler = StandardScaler()
        self.model = make_model(model, random_state=random_state, fast=fast)
        self.feature_names = list(NUMERIC_FEATURES)
        self.target_names = list(TARGETS)
        self._fitted = False
        self._reset_caches()

    def _reset_caches(self) -> None:
        self._jax_cache: dict[bool, object] = {}
        self._fingerprint: str | None = None

    # ----- table <-> matrix -----
    def _X(self, table: dict[str, np.ndarray]) -> np.ndarray:
        cols = [np.asarray(table[k], dtype=np.float64)
                for k in self.feature_names]
        return np.stack(cols, axis=1)

    def _anchors(self, table: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
        """Analytical anchors per log-target (naive roofline estimates)."""
        rt = (np.maximum(np.asarray(table["naive_compute_ms"], np.float64),
                         np.asarray(table["naive_memory_ms"], np.float64))
              + np.asarray(table["naive_overhead_ms"], np.float64))
        rt = np.maximum(rt, 1e-9)
        flops = np.asarray(table["total_flops"], np.float64)
        return {
            "runtime_ms": rt,
            "energy_j": rt / 1e3 * self.nominal_power_w,
            "tflops": flops / (rt / 1e3) / 1e12,
        }

    def _encode_y(self, Y: np.ndarray,
                  table: dict[str, np.ndarray] | None = None) -> np.ndarray:
        Y = Y.copy()
        anchors = self._anchors(table) if (self.residual and table) else {}
        if self.log_targets:
            for i, t in enumerate(self.target_names):
                if t in self.LOG_TARGETS:
                    y = np.maximum(Y[:, i], 1e-12)
                    if t in anchors:
                        y = y / np.maximum(anchors[t], 1e-12)
                    Y[:, i] = np.log(y)
        return Y

    def _decode_y(self, Y: np.ndarray,
                  table: dict[str, np.ndarray] | None = None) -> np.ndarray:
        Y = Y.copy()
        anchors = self._anchors(table) if (self.residual and table) else {}
        if self.log_targets:
            for i, t in enumerate(self.target_names):
                if t in self.LOG_TARGETS:
                    y = np.exp(Y[:, i])
                    if t in anchors:
                        y = y * np.maximum(anchors[t], 1e-12)
                    Y[:, i] = y
        return Y

    # ----- public API -----
    def fit(self, table: dict[str, np.ndarray],
            targets: np.ndarray | None = None) -> "PerfPredictor":
        X = self._X(table)
        if targets is None:
            targets = np.stack(
                [np.asarray(table[t], dtype=np.float64)
                 for t in self.target_names], axis=1)
        Xs = self.scaler.fit_transform(X)
        self.model.fit(
            Xs, self.y_scaler.fit_transform(self._encode_y(targets, table)))
        self._fitted = True
        self._reset_caches()
        return self

    def predict(self, table: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
        Y = self.predict_matrix(table)
        return {t: Y[:, i] for i, t in enumerate(self.target_names)}

    def predict_matrix(self, table: dict[str, np.ndarray]) -> np.ndarray:
        assert self._fitted, "predictor not fitted"
        X = self.scaler.transform(self._X(table))
        Y = np.asarray(self.model.predict(X), dtype=np.float64)
        if Y.ndim == 1:
            Y = Y[:, None]
        return self._decode_y(self.y_scaler.inverse_transform(Y), table)

    def predict_matrix_reference(self, table: dict[str, np.ndarray]
                                 ) -> np.ndarray:
        """Pre-refactor prediction path: the estimator's per-tree Python
        loop instead of the stacked descent. Kept as the parity/latency
        baseline for tests and benchmarks."""
        assert self._fitted, "predictor not fitted"
        X = self.scaler.transform(self._X(table))
        predict = getattr(self.model, "predict_per_tree_loop",
                          self.model.predict)
        Y = np.asarray(predict(X), dtype=np.float64)
        if Y.ndim == 1:
            Y = Y[:, None]
        return self._decode_y(self.y_scaler.inverse_transform(Y), table)

    def evaluate(self, table: dict[str, np.ndarray]) -> dict:
        """Table IV: per-target R2/MSE/MAE/median%/mean% report."""
        truth = np.stack(
            [np.asarray(table[t], dtype=np.float64)
             for t in self.target_names], axis=1)
        pred = self.predict_matrix(table)
        return regression_report(truth, pred, self.target_names)

    # ----- jitted path (every lowered estimator family) -----
    def supports_jax(self) -> bool:
        """True when the fitted model has a compiled lowering — all of the
        Table VI zoo (forest, GBDT, linreg/ridge, stacking) does."""
        return supports_compile(self.model)

    def jax_components(self, *, x64: bool = False):
        """(params, apply) for embedding the decoded predictor in a larger
        jitted program (e.g. the autotuner's in-graph ranker).

        `apply(params, Xs, X_raw) -> (N, T)` is a pure jax function:
        estimator forward (via the compiled lowering) + target decode
        (y-descaling, log-target exp, residual anchor multiply). `params`
        is a flat pytree of numpy arrays; keeping the decode constants as
        *traced* arguments (not baked literals) stops XLA from
        constant-folding divisions into reciprocal multiplies, which would
        drift the last ulp vs the numpy path.
        """
        lowered = lower_estimator(self.model, float64=x64)
        ft = np.float64 if x64 else np.float32
        params = {
            "est": lowered.params,
            "y_mean": np.asarray(self.y_scaler.mean_, dtype=ft),
            "y_scale": np.asarray(self.y_scaler.scale_, dtype=ft),
            "log_mask": np.asarray(
                [1.0 if t in self.LOG_TARGETS else 0.0
                 for t in self.target_names], dtype=ft),
            "nominal_power": np.asarray(self.nominal_power_w, dtype=ft),
        }
        i_nc = self.feature_names.index("naive_compute_ms")
        i_nm = self.feature_names.index("naive_memory_ms")
        i_no = self.feature_names.index("naive_overhead_ms")
        i_fl = self.feature_names.index("total_flops")
        residual = self.residual
        t_idx = {t: i for i, t in enumerate(self.target_names)}
        target_names = list(self.target_names)
        est_apply = lowered.apply

        def apply(p, Xs, X_raw):
            import jax.numpy as jnp

            Y = est_apply(p["est"], Xs) * p["y_scale"] + p["y_mean"]
            Y = jnp.where(p["log_mask"] > 0, jnp.exp(Y), Y)
            if residual:
                rt = (jnp.maximum(X_raw[:, i_nc], X_raw[:, i_nm])
                      + X_raw[:, i_no])
                rt = jnp.maximum(rt, 1e-9)
                anchors = {
                    "runtime_ms": rt,
                    "energy_j": rt / 1e3 * p["nominal_power"],
                    "tflops": X_raw[:, i_fl] / (rt / 1e3) / 1e12,
                }
                cols = []
                for t in target_names:
                    col = Y[:, t_idx[t]]
                    if t in anchors:
                        col = col * anchors[t]
                    cols.append(col)
                Y = jnp.stack(cols, axis=1)
            return Y

        return params, apply

    def jax_predictor(self, *, x64: bool = False):
        """Compiled scorer over *raw* features: fn(X_raw (N, F)) -> (N, T)
        decoded predictions via pure jax, for any estimator family in the
        zoo. Built once per precision and cached on the instance (refit
        invalidates). ``x64=True`` runs the estimator in float64 — tree
        branch decisions and accumulations bit-identical to the numpy
        path — which is what the autotuner's serving scorer uses.
        """
        if not self.supports_jax():
            raise TypeError(
                f"no compiled lowering for model "
                f"{type(self.model).__name__!r}")
        fn = self._jax_cache.get(x64)
        if fn is None:
            fn = self._build_jax_predictor(x64)
            self._jax_cache[x64] = fn
        return fn

    def _build_jax_predictor(self, x64: bool):
        import jax
        import jax.numpy as jnp

        params, apply = self.jax_components(x64=x64)
        dt = jnp.float64 if x64 else jnp.float32
        with precision_scope(x64):
            device_params = jax.tree.map(jnp.asarray, params)
        scorer = jax.jit(apply)
        scaler = self.scaler

        # estimator forward -> decode as ONE jitted computation (single
        # dispatch). Feature standardization stays OUTSIDE the jit on
        # purpose: with mean/scale as captured constants XLA rewrites the
        # division into a reciprocal multiply, and the last-ulp difference
        # flips near-threshold tree branches vs the numpy path. Scaling in
        # numpy keeps the traversal input bit-identical to
        # `predict_matrix`.
        def fn(X_raw):
            Xs = scaler.transform(np.asarray(X_raw, dtype=np.float64))
            with precision_scope(x64):
                return scorer(device_params, jnp.asarray(Xs, dtype=dt),
                              jnp.asarray(X_raw, dtype=dt))

        return fn

    # ----- persistence: versioned .npz artifact -----
    def to_state(self) -> dict[str, np.ndarray]:
        """Everything `predict` needs, as flat numpy arrays."""
        assert self._fitted, "predictor not fitted"
        state = {
            **pack_nested("scaler", self.scaler.to_state()),
            **pack_nested("y_scaler", self.y_scaler.to_state()),
            **pack_nested("model", self.model.to_state()),
        }
        return state

    def meta(self) -> dict:
        """The artifact's JSON metadata record."""
        return {
            "format": ARTIFACT_FORMAT,
            "schema_version": ARTIFACT_SCHEMA_VERSION,
            "model": self.model_name,
            "chip": self.chip_name,
            "nominal_power_w": self.nominal_power_w,
            "feature_names": list(self.feature_names),
            "target_names": list(self.target_names),
            "log_targets": bool(self.log_targets),
            "residual": bool(self.residual),
            "fingerprint": self.fingerprint(),
        }

    def fingerprint(self) -> str:
        """Deterministic content hash of the fitted state + schema/flags.

        Versions the artifact: downstream caches (tuner winners) key on it,
        so retraining — or any array tampering — invalidates them.
        """
        if self._fingerprint is None:
            self._fingerprint = artifact_fingerprint({
                "model": self.model_name,
                "chip": self.chip_name,
                "nominal_power_w": self.nominal_power_w,
                "feature_names": list(self.feature_names),
                "target_names": list(self.target_names),
                "log_targets": bool(self.log_targets),
                "residual": bool(self.residual),
            }, self.to_state())
        return self._fingerprint

    def save(self, path: str) -> None:
        """Write the versioned artifact (.npz arrays + JSON metadata)."""
        meta = self.meta()
        with open(path, "wb") as f:
            np.savez_compressed(f, **{_META_KEY: np.array(json.dumps(meta))},
                                **self.to_state())

    @classmethod
    def load(cls, path: str) -> "PerfPredictor":
        """Load + validate an artifact. Raises ArtifactError on a missing
        or mismatched schema — never unpickles anything."""
        try:
            with np.load(path, allow_pickle=False) as z:
                if _META_KEY not in z.files:
                    raise ArtifactError(
                        f"{path} is not a perf-predictor artifact (no "
                        "__meta__ record; legacy pickle checkpoints are "
                        "not supported — retrain to produce one)")
                meta = json.loads(str(z[_META_KEY][()]))
                state = {k: z[k] for k in z.files if k != _META_KEY}
        except (OSError, ValueError, KeyError) as e:
            if isinstance(e, ArtifactError):
                raise
            raise ArtifactError(f"cannot read artifact {path}: {e}") from e
        if meta.get("format") != ARTIFACT_FORMAT:
            raise ArtifactError(
                f"{path}: unexpected artifact format {meta.get('format')!r}")
        version = meta.get("schema_version")
        while (isinstance(version, int)
               and version < ARTIFACT_SCHEMA_VERSION
               and version in _SCHEMA_UPGRADERS):
            meta, state = _SCHEMA_UPGRADERS[version](meta, state)
            if meta.get("schema_version") != version + 1:
                raise ArtifactError(
                    f"{path}: schema upgrader for v{version} produced "
                    f"version {meta.get('schema_version')}, expected "
                    f"{version + 1}")
            version = meta["schema_version"]
        if version != ARTIFACT_SCHEMA_VERSION:
            raise ArtifactError(
                f"{path}: schema version {meta.get('schema_version')} has "
                f"no upgrade path to supported {ARTIFACT_SCHEMA_VERSION} — "
                "retrain the predictor")
        if list(meta.get("feature_names", [])) != list(NUMERIC_FEATURES):
            raise ArtifactError(
                f"{path}: feature schema mismatch — artifact was trained on "
                f"{meta.get('feature_names')}, this build expects "
                f"{list(NUMERIC_FEATURES)}; retrain the predictor")
        if list(meta.get("target_names", [])) != list(TARGETS):
            raise ArtifactError(
                f"{path}: target schema mismatch — retrain the predictor")
        obj = cls.__new__(cls)
        try:
            obj.model_name = meta["model"]
            obj.chip_name = meta.get("chip")
            obj.nominal_power_w = float(
                meta.get("nominal_power_w",
                         _chip_nominal_power(obj.chip_name)))
            obj.log_targets = bool(meta["log_targets"])
            obj.residual = bool(meta["residual"])
            obj.feature_names = list(meta["feature_names"])
            obj.target_names = list(meta["target_names"])
        except (KeyError, TypeError, ValueError) as e:
            raise ArtifactError(
                f"{path}: incomplete artifact metadata: {e}") from e
        try:
            obj.scaler = StandardScaler.from_state(
                unpack_nested(state, "scaler"))
            obj.y_scaler = StandardScaler.from_state(
                unpack_nested(state, "y_scaler"))
            obj.model = estimator_from_state(unpack_nested(state, "model"))
        except (KeyError, ValueError, IndexError) as e:
            raise ArtifactError(f"{path}: corrupt estimator state: {e}") from e
        obj._fitted = True
        obj._reset_caches()
        if meta.get("fingerprint") != obj.fingerprint():
            raise ArtifactError(
                f"{path}: fingerprint mismatch — artifact arrays or metadata "
                "were modified after save")
        return obj
