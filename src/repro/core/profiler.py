"""Profiling harness — the CUTLASS-profiler/ncu analogue.

Systematically sweeps GEMM configurations (matrix dims x block configs x
layouts x alpha/beta x dtype), "measures" each on the hardware substrate
(`hwsim.TpuGemmSimulator`) and materializes the training table the paper
collects (16,128 CUTLASS ops -> our default sweep is >= that).

The hot path is fully batched: configs are converted to a struct-of-arrays
once, telemetry comes from `TpuGemmSimulator.measure_batch`, and features
from `config_features_batch` — no per-config Python loop. The substrate is
selectable per chip (`collect_dataset(chip="rtx4070")`).

On a real TPU deployment the same harness runs with `measure_fn` swapped for
a wall-clock runner around the Pallas kernel (a per-config callable, since
real hardware measures one launch at a time); everything downstream (feature
building, model fitting, autotuning) is measurement-source-agnostic.
"""

from __future__ import annotations

import itertools
import time
from collections.abc import Callable, Iterable

import numpy as np

from repro.core.chips import TPU_V5E, ChipSpec
from repro.core.features import (
    NUMERIC_FEATURES,
    TARGETS,
    config_features,
    config_features_batch,
)
from repro.core.hwsim import (
    GemmConfig,
    GemmTelemetry,
    TpuGemmSimulator,
    config_arrays,
)

# Default sweep axes (the CUTLASS-profiler flag grid, TPU-quantized).
DIM_CHOICES = (256, 512, 1024, 2048, 3072, 4096, 6144, 8192)
BLOCK_M_CHOICES = (8, 64, 128, 256, 512)
BLOCK_N_CHOICES = (128, 256, 512)
BLOCK_K_CHOICES = (128, 512, 2048)
LAYOUTS = ("nn", "nt", "tn", "tt")
ALPHA_BETA = ((1.0, 0.0), (1.0, 1.0), (0.5, 0.5), (2.0, 0.0))
DTYPES = ("bf16", "f32")

# Telemetry columns copied into the profiled table alongside the features.
_TELEMETRY_KEEP = ("runtime_ms", "power_w", "energy_j", "tflops",
                   "mxu_utilization", "hbm_utilization", "temperature_c",
                   "bound")
# Batch chunk size: fixed (never derived from progress_every) so the RNG
# draw order — hence the dataset — is independent of progress printing.
_CHUNK = 8192


def sweep_configs(
    *,
    dims: Iterable[int] = DIM_CHOICES,
    block_m: Iterable[int] = BLOCK_M_CHOICES,
    block_n: Iterable[int] = BLOCK_N_CHOICES,
    block_k: Iterable[int] = BLOCK_K_CHOICES,
    layouts: Iterable[str] = LAYOUTS,
    alpha_beta: Iterable[tuple[float, float]] = ALPHA_BETA,
    dtypes: Iterable[str] = DTYPES,
    n_configs: int | None = None,
    seed: int = 0,
) -> list[GemmConfig]:
    """Cartesian sweep, subsampled to `n_configs` if given.

    Matrix dims are sampled as (m, n, k) triples from `dims` (the paper
    sweeps m/n/k independently) rather than the full cube, to keep the
    blocks x layouts x scalars cube as the dominant factor like CUTLASS'
    kernel-variant grid.
    """
    rng = np.random.default_rng(seed)
    dims = list(dims)
    triples = [(m, n, k) for m in dims for n in dims for k in dims]
    rng.shuffle(triples)
    blocks = list(itertools.product(block_m, block_n, block_k))
    cfgs: list[GemmConfig] = []
    lay = list(layouts)
    ab = list(alpha_beta)
    dts = list(dtypes)
    # round-robin dims against the full (block, layout, ab, dtype) grid
    combo = list(itertools.product(blocks, lay, ab, dts))
    i = 0
    target = n_configs or (len(combo) * 24)
    while len(cfgs) < target:
        (bm, bn, bk), l, (a, b), dt = combo[i % len(combo)]
        m, n, k = triples[i % len(triples)]
        cfgs.append(GemmConfig(m=m, n=n, k=k, block_m=bm, block_n=bn,
                               block_k=bk, dtype=dt, layout=l, alpha=a,
                               beta=b))
        i += 1
    return cfgs


def _batch_table(cfgs: list[GemmConfig], sim: TpuGemmSimulator
                 ) -> dict[str, np.ndarray]:
    """Features + measured telemetry for one chunk, as dict-of-columns."""
    arrays = config_arrays(cfgs)
    table = config_features_batch(cfgs, chip=sim.chip, arrays=arrays)
    table["layout"] = arrays["layout"]
    table["dtype"] = arrays["dtype"]
    tel = sim.measure_batch(cfgs, arrays=arrays)
    for key in _TELEMETRY_KEEP:
        table[key] = tel[key]
    table["valid"] = tel["valid"]
    return table


def profile_configs(
    cfgs: list[GemmConfig],
    sim: TpuGemmSimulator | None = None,
    *,
    measure_fn: Callable[[GemmConfig], GemmTelemetry] | None = None,
    drop_invalid: bool = True,
    progress_every: int = 0,
    chip: ChipSpec | str | None = None,
) -> dict[str, np.ndarray]:
    """Run the sweep; return dict-of-columns (features + targets + extras).

    Without `measure_fn` the whole sweep runs through the vectorized
    `measure_batch` substrate. Passing `measure_fn` (one GemmConfig ->
    GemmTelemetry, e.g. a wall-clock runner on real hardware) falls back to
    the per-config loop.
    """
    sim = sim or TpuGemmSimulator(chip=chip if chip is not None else TPU_V5E,
                                  seed=0)
    t0 = time.time()
    if measure_fn is None:
        chunks = []
        done = 0
        next_report = progress_every
        for start in range(0, len(cfgs), _CHUNK):
            chunks.append(_batch_table(cfgs[start:start + _CHUNK], sim))
            done = min(start + _CHUNK, len(cfgs))
            if progress_every and done >= next_report:
                print(f"profiled {done}/{len(cfgs)} "
                      f"({time.time() - t0:.1f}s)")
                next_report = done + progress_every
        if not chunks:
            raise RuntimeError("no valid configurations in sweep")
        table = {key: np.concatenate([c[key] for c in chunks])
                 for key in chunks[0]}
        if drop_invalid:
            mask = table.pop("valid")
            table = {k: v[mask] for k, v in table.items()}
        else:
            table.pop("valid")
        if not len(table["runtime_ms"]):
            raise RuntimeError("no valid configurations in sweep")
        return table

    # real-hardware path: one measurement per call, rows accumulated
    rows: list[dict[str, float]] = []
    for i, cfg in enumerate(cfgs):
        tel = measure_fn(cfg)
        if drop_invalid and not tel.valid:
            continue
        row = config_features(cfg, chip=sim.chip)
        row["layout"] = cfg.layout
        row["dtype"] = cfg.dtype
        row["runtime_ms"] = tel.runtime_ms
        row["power_w"] = tel.power_w
        row["energy_j"] = tel.energy_j
        row["tflops"] = tel.tflops
        row["mxu_utilization"] = tel.mxu_utilization
        row["hbm_utilization"] = tel.hbm_utilization
        row["temperature_c"] = tel.temperature_c
        row["bound"] = tel.bound
        rows.append(row)
        if progress_every and (i + 1) % progress_every == 0:
            print(f"profiled {i + 1}/{len(cfgs)} ({time.time() - t0:.1f}s)")
    if not rows:
        raise RuntimeError("no valid configurations in sweep")
    table = {}
    for key in rows[0]:
        vals = [r[key] for r in rows]
        if isinstance(vals[0], str):
            table[key] = np.array(vals, dtype=object)
        else:
            table[key] = np.array(vals, dtype=np.float64)
    return table


def collect_dataset(n_configs: int = 16128, seed: int = 0,
                    sim: TpuGemmSimulator | None = None,
                    progress_every: int = 0,
                    chip: ChipSpec | str = TPU_V5E) -> dict[str, np.ndarray]:
    """The paper's dataset: >=16,128 profiled GEMM operations.

    `chip` selects the measurement substrate ("tpu_v5e", "rtx4070", or any
    registered ChipSpec); an explicit `sim` wins over `chip`.
    """
    cfgs = sweep_configs(n_configs=n_configs, seed=seed)
    sim = sim or TpuGemmSimulator(chip=chip, seed=seed)
    return profile_configs(cfgs, sim, progress_every=progress_every)


def save_dataset(table: dict[str, np.ndarray], path: str) -> None:
    np.savez_compressed(path, **{k: np.asarray(v) for k, v in table.items()})


def load_dataset(path: str) -> dict[str, np.ndarray]:
    with np.load(path, allow_pickle=True) as z:
        return {k: z[k] for k in z.files}


def feature_table(table: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
    """Project the profiled table onto model-input columns."""
    out = {k: table[k] for k in NUMERIC_FEATURES if k in table}
    return out


def target_matrix(table: dict[str, np.ndarray]) -> np.ndarray:
    return np.stack([np.asarray(table[t], dtype=np.float64) for t in TARGETS],
                    axis=1)
