"""Profiling harness — the CUTLASS-profiler/ncu analogue.

Systematically sweeps GEMM configurations (matrix dims x block configs x
layouts x alpha/beta x dtype), "measures" each on the hardware substrate
(`hwsim.TpuGemmSimulator`) and materializes the training table the paper
collects (16,128 CUTLASS ops -> our default sweep is >= that).

On a real TPU deployment the same harness runs with `measure_fn` swapped for
a wall-clock runner around the Pallas kernel; everything downstream (feature
building, model fitting, autotuning) is measurement-source-agnostic.
"""

from __future__ import annotations

import itertools
import time
from collections.abc import Callable, Iterable

import numpy as np

from repro.core.features import NUMERIC_FEATURES, TARGETS, config_features
from repro.core.hwsim import GemmConfig, GemmTelemetry, TpuGemmSimulator

# Default sweep axes (the CUTLASS-profiler flag grid, TPU-quantized).
DIM_CHOICES = (256, 512, 1024, 2048, 3072, 4096, 6144, 8192)
BLOCK_M_CHOICES = (8, 64, 128, 256, 512)
BLOCK_N_CHOICES = (128, 256, 512)
BLOCK_K_CHOICES = (128, 512, 2048)
LAYOUTS = ("nn", "nt", "tn", "tt")
ALPHA_BETA = ((1.0, 0.0), (1.0, 1.0), (0.5, 0.5), (2.0, 0.0))
DTYPES = ("bf16", "f32")


def sweep_configs(
    *,
    dims: Iterable[int] = DIM_CHOICES,
    block_m: Iterable[int] = BLOCK_M_CHOICES,
    block_n: Iterable[int] = BLOCK_N_CHOICES,
    block_k: Iterable[int] = BLOCK_K_CHOICES,
    layouts: Iterable[str] = LAYOUTS,
    alpha_beta: Iterable[tuple[float, float]] = ALPHA_BETA,
    dtypes: Iterable[str] = DTYPES,
    n_configs: int | None = None,
    seed: int = 0,
) -> list[GemmConfig]:
    """Cartesian sweep, subsampled to `n_configs` if given.

    Matrix dims are sampled as (m, n, k) triples from `dims` (the paper
    sweeps m/n/k independently) rather than the full cube, to keep the
    blocks x layouts x scalars cube as the dominant factor like CUTLASS'
    kernel-variant grid.
    """
    rng = np.random.default_rng(seed)
    dims = list(dims)
    triples = [(m, n, k) for m in dims for n in dims for k in dims]
    rng.shuffle(triples)
    blocks = list(itertools.product(block_m, block_n, block_k))
    cfgs: list[GemmConfig] = []
    lay = list(layouts)
    ab = list(alpha_beta)
    dts = list(dtypes)
    # round-robin dims against the full (block, layout, ab, dtype) grid
    combo = list(itertools.product(blocks, lay, ab, dts))
    i = 0
    target = n_configs or (len(combo) * 24)
    while len(cfgs) < target:
        (bm, bn, bk), l, (a, b), dt = combo[i % len(combo)]
        m, n, k = triples[i % len(triples)]
        cfgs.append(GemmConfig(m=m, n=n, k=k, block_m=bm, block_n=bn,
                               block_k=bk, dtype=dt, layout=l, alpha=a,
                               beta=b))
        i += 1
    return cfgs


def profile_configs(
    cfgs: list[GemmConfig],
    sim: TpuGemmSimulator | None = None,
    *,
    measure_fn: Callable[[GemmConfig], GemmTelemetry] | None = None,
    drop_invalid: bool = True,
    progress_every: int = 0,
) -> dict[str, np.ndarray]:
    """Run the sweep; return dict-of-columns (features + targets + extras)."""
    sim = sim or TpuGemmSimulator(seed=0)
    measure = measure_fn or sim.measure
    rows: list[dict[str, float]] = []
    t0 = time.time()
    for i, cfg in enumerate(cfgs):
        tel = measure(cfg)
        if drop_invalid and not tel.valid:
            continue
        row = config_features(cfg)
        row["layout"] = cfg.layout
        row["dtype"] = cfg.dtype
        row["runtime_ms"] = tel.runtime_ms
        row["power_w"] = tel.power_w
        row["energy_j"] = tel.energy_j
        row["tflops"] = tel.tflops
        row["mxu_utilization"] = tel.mxu_utilization
        row["hbm_utilization"] = tel.hbm_utilization
        row["temperature_c"] = tel.temperature_c
        row["bound"] = tel.bound
        rows.append(row)
        if progress_every and (i + 1) % progress_every == 0:
            print(f"profiled {i + 1}/{len(cfgs)} ({time.time() - t0:.1f}s)")
    if not rows:
        raise RuntimeError("no valid configurations in sweep")
    table: dict[str, np.ndarray] = {}
    for key in rows[0]:
        vals = [r[key] for r in rows]
        if isinstance(vals[0], str):
            table[key] = np.array(vals, dtype=object)
        else:
            table[key] = np.array(vals, dtype=np.float64)
    return table


def collect_dataset(n_configs: int = 16128, seed: int = 0,
                    sim: TpuGemmSimulator | None = None,
                    progress_every: int = 0) -> dict[str, np.ndarray]:
    """The paper's dataset: >=16,128 profiled GEMM operations."""
    cfgs = sweep_configs(n_configs=n_configs, seed=seed)
    return profile_configs(cfgs, sim or TpuGemmSimulator(seed=seed),
                           progress_every=progress_every)


def save_dataset(table: dict[str, np.ndarray], path: str) -> None:
    np.savez_compressed(path, **{k: np.asarray(v) for k, v in table.items()})


def load_dataset(path: str) -> dict[str, np.ndarray]:
    with np.load(path, allow_pickle=True) as z:
        return {k: z[k] for k in z.files}


def feature_table(table: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
    """Project the profiled table onto model-input columns."""
    out = {k: table[k] for k in NUMERIC_FEATURES if k in table}
    return out


def target_matrix(table: dict[str, np.ndarray]) -> np.ndarray:
    return np.stack([np.asarray(table[t], dtype=np.float64) for t in TARGETS],
                    axis=1)
