"""Three-term roofline analysis from compiled XLA artifacts.

    compute term    = HLO_FLOPs   / (chips x peak FLOP/s)
    memory term     = HLO_bytes   / (chips x HBM B/s)
    collective term = coll_bytes  / (chips x ICI link B/s)

`cost_analysis()` supplies FLOPs and bytes. Collective bytes are NOT in
cost_analysis, so we parse the optimized HLO text and apply per-op ring
formulas to operand sizes (all-reduce moves ~2x operand bytes per chip on a
ring; all-gather/reduce-scatter move (g-1)/g of the full tensor; all-to-all
and collective-permute move the operand once).
"""

from __future__ import annotations

import dataclasses
import re

from repro.core.chips import DTYPE_BYTES, TPU_V5E, ChipSpec

_SHAPE_RE = re.compile(r"\b([a-z]+\d+(?:e\d+m\d+(?:fn)?)?|pred)\[([0-9,]*)\]")
_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
_RG_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_RG_DIM_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    b = DTYPE_BYTES.get(dtype)
    if b is None:
        return 0.0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return float(n) * b


def _line_operand_bytes(line: str) -> float:
    """Sum of operand tensor sizes on an HLO instruction line.

    HLO lines look like:
      %all-reduce.5 = f32[128,512]{1,0} all-reduce(f32[128,512]{1,0} %p),
    The first shape is the result; shapes after the opcode's '(' are operands.
    """
    lhs, _, rhs = line.partition("=")
    if not rhs:
        return 0.0
    paren = rhs.find("(")
    if paren < 0:
        return 0.0
    args = rhs[paren:]
    total = 0.0
    for m in _SHAPE_RE.finditer(args):
        total += _shape_bytes(m.group(1), m.group(2))
    return total


def _group_size(line: str, default: int) -> int:
    m = _RG_DIM_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _RG_RE.search(line)
    if m and m.group(1).strip():
        first = m.group(1).split("}")[0].strip("{} ")
        if first:
            return max(len([x for x in first.split(",") if x.strip() != ""]), 1)
    return default


@dataclasses.dataclass
class CollectiveStats:
    counts: dict[str, int]
    operand_bytes: dict[str, float]   # raw operand bytes by op kind
    wire_bytes: dict[str, float]      # ring-model bytes per chip by op kind

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    @property
    def total_operand_bytes(self) -> float:
        return sum(self.operand_bytes.values())


def parse_collectives(hlo_text: str, n_chips: int) -> CollectiveStats:
    counts = {k: 0 for k in _COLL_OPS}
    operand = {k: 0.0 for k in _COLL_OPS}
    wire = {k: 0.0 for k in _COLL_OPS}
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if "=" not in line:
            continue
        # opcode appears right after the result shape
        op = None
        for k in _COLL_OPS:
            if re.search(rf"\b{k}(-start|-done)?\(", line):
                op = k
                break
        if op is None:
            continue
        if f"{op}-done(" in line:
            continue  # -done pairs with -start; count once
        nbytes = _line_operand_bytes(line)
        if nbytes == 0.0:
            continue
        g = _group_size(line, n_chips)
        counts[op] += 1
        operand[op] += nbytes
        ring = (g - 1) / g if g > 1 else 0.0
        if op == "all-reduce":
            wire[op] += 2.0 * nbytes * ring
        elif op in ("all-gather", "reduce-scatter"):
            # operand is per-shard for all-gather; result for reduce-scatter
            wire[op] += nbytes * ring if op == "reduce-scatter" else nbytes * (g - 1)
        elif op == "all-to-all":
            wire[op] += nbytes * ring
        else:  # collective-permute
            wire[op] += nbytes
    return CollectiveStats(counts=counts, operand_bytes=operand, wire_bytes=wire)


@dataclasses.dataclass
class RooflineReport:
    name: str
    n_chips: int
    dtype: str
    hlo_flops: float
    hlo_bytes: float
    collective_wire_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float = 0.0          # 6*N*D (or 6*N_active*D for MoE)
    collectives: CollectiveStats | None = None
    bytes_per_device: float = 0.0     # from memory_analysis

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        """Lower-bound step time if the three terms fully overlap."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def serial_s(self) -> float:
        return self.compute_s + self.memory_s + self.collective_s

    @property
    def useful_flops_fraction(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the overlap bound:
        MODEL_FLOPS time / bound time."""
        if self.bound_s <= 0:
            return 0.0
        chip = TPU_V5E
        ideal_s = self.model_flops / (self.n_chips * chip.peak(self.dtype))
        return ideal_s / self.bound_s

    def as_row(self) -> dict:
        return {
            "name": self.name,
            "chips": self.n_chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "bound_s": self.bound_s,
            "model_flops": self.model_flops,
            "hlo_flops": self.hlo_flops,
            "useful_frac": self.useful_flops_fraction,
            "roofline_frac": self.roofline_fraction,
            "bytes_per_device": self.bytes_per_device,
        }


def roofline_from_artifacts(
    *,
    name: str,
    cost: dict,
    hlo_text: str,
    n_chips: int,
    model_flops: float = 0.0,
    dtype: str = "bf16",
    chip: ChipSpec = TPU_V5E,
    bytes_per_device: float = 0.0,
) -> RooflineReport:
    """Build a report from `compiled.cost_analysis()` + HLO text.

    cost_analysis flops/bytes on a host-device compile are *per-program*
    (already partitioned when compiled under a mesh with n_chips programs).
    """
    flops = float(cost.get("flops", 0.0))
    # sum all "bytes accessed*" keys (XLA splits by operand/output)
    nbytes = float(cost.get("bytes accessed", 0.0))
    if nbytes == 0.0:
        nbytes = sum(float(v) for k, v in cost.items()
                     if k.startswith("bytes accessed"))
    coll = parse_collectives(hlo_text, n_chips)
    # cost_analysis is per-partition under SPMD: per-chip flops/bytes.
    per_chip_flops = flops
    per_chip_bytes = nbytes
    per_chip_coll = coll.total_wire_bytes
    return RooflineReport(
        name=name,
        n_chips=n_chips,
        dtype=dtype,
        hlo_flops=per_chip_flops * n_chips,
        hlo_bytes=per_chip_bytes * n_chips,
        collective_wire_bytes=per_chip_coll * n_chips,
        compute_s=per_chip_flops / chip.peak(dtype),
        memory_s=per_chip_bytes / chip.hbm_bw,
        collective_s=per_chip_coll / chip.ici_link_bw,
        model_flops=model_flops,
        collectives=coll,
        bytes_per_device=bytes_per_device,
    )


def format_report_table(reports: list[RooflineReport]) -> str:
    hdr = (f"{'cell':<42} {'chips':>5} {'compute_s':>10} {'memory_s':>10} "
           f"{'collect_s':>10} {'dominant':>10} {'useful%':>8} {'roofline%':>9}")
    lines = [hdr, "-" * len(hdr)]
    for r in reports:
        lines.append(
            f"{r.name:<42} {r.n_chips:>5} {r.compute_s:>10.4e} "
            f"{r.memory_s:>10.4e} {r.collective_s:>10.4e} {r.dominant:>10} "
            f"{100*r.useful_flops_fraction:>7.1f}% {100*r.roofline_fraction:>8.1f}%"
        )
    return "\n".join(lines)
