"""Deterministic, resumable, shardable synthetic data pipeline.

Production shape: an index-based sampler (step -> global batch) so any host
can materialize exactly its shard of any step without coordination — the
property that makes checkpoint-resume and elastic re-sharding trivial
(the sampler is a pure function of (seed, step)).

Synthetic text: a mixture of Zipfian unigrams and a repeated-ngram process so
the LM loss actually decreases during the example runs (pure uniform noise
would pin loss at log V).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class DataConfig:
    seq_len: int
    global_batch: int
    vocab: int
    seed: int = 0
    zipf_alpha: float = 1.2
    repeat_prob: float = 0.5   # prob. a token copies seq_len//8 back


class SyntheticLMDataset:
    """Pure-function batch source: batch_at(step) is deterministic."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_alpha)
        self.probs = probs / probs.sum()

    def batch_at(self, step: int, *, host_id: int = 0,
                 n_hosts: int = 1) -> dict[str, np.ndarray]:
        cfg = self.cfg
        assert cfg.global_batch % n_hosts == 0
        local = cfg.global_batch // n_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, host_id]))
        toks = rng.choice(cfg.vocab, size=(local, cfg.seq_len + 1),
                          p=self.probs).astype(np.int32)
        # inject copy structure: some positions repeat lag-k history
        lag = max(cfg.seq_len // 8, 1)
        copy_mask = rng.random((local, cfg.seq_len + 1)) < cfg.repeat_prob
        copy_mask[:, :lag] = False
        idx = np.arange(cfg.seq_len + 1)[None, :] - lag
        toks = np.where(copy_mask, np.take_along_axis(
            toks, np.broadcast_to(idx, toks.shape).clip(0), axis=1), toks)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass
class PipelineState:
    """Checkpointable pipeline position."""
    step: int = 0

    def to_dict(self) -> dict:
        return {"step": self.step}

    @staticmethod
    def from_dict(d: dict) -> "PipelineState":
        return PipelineState(step=int(d["step"]))


class DataLoader:
    """Host-sharded loader with a software prefetch queue and resume."""

    def __init__(self, dataset: SyntheticLMDataset, *, host_id: int = 0,
                 n_hosts: int = 1, prefetch: int = 2,
                 state: PipelineState | None = None):
        self.dataset = dataset
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.prefetch = prefetch
        self.state = state or PipelineState()
        self._queue: list[dict] = []

    def _fill(self):
        while len(self._queue) < self.prefetch:
            step = self.state.step + len(self._queue)
            self._queue.append(
                self.dataset.batch_at(step, host_id=self.host_id,
                                      n_hosts=self.n_hosts))

    def next(self) -> dict[str, np.ndarray]:
        self._fill()
        batch = self._queue.pop(0)
        self.state.step += 1
        return batch

    def checkpoint(self) -> dict:
        return self.state.to_dict()

    def restore(self, d: dict) -> None:
        self.state = PipelineState.from_dict(d)
        self._queue.clear()


def smoke_batch(arch: str, shape: str = "train_4k", seed: int = 0
                ) -> tuple[ModelConfig, dict]:
    """Materialized (reduced-config) training batch for any assigned arch."""
    from repro.configs import get_config, input_specs

    cfg = get_config(arch, smoke=True)
    specs = input_specs(arch, shape, smoke=True)
    rng = np.random.default_rng(seed)
    batch = {}
    for name, spec in specs.items():
        if name == "state":
            continue
        shape_, dtype = spec.shape, spec.dtype
        if name in ("tokens", "labels", "token"):
            batch[name] = rng.integers(0, cfg.vocab, shape_).astype(dtype)
        elif name == "positions_3d":
            from repro.models.vlm import build_mrope_positions
            B, S, _ = shape_
            n_patch = S - specs["tokens"].shape[1]
            grid = (4, 4) if n_patch == 16 else (32, 32)
            pos = build_mrope_positions(n_patch, grid, S - n_patch)
            batch[name] = np.broadcast_to(pos, (B, S, 3)).astype(dtype)
        elif name == "loss_mask":
            B, S = shape_
            n_patch = S - specs["tokens"].shape[1]
            m = np.ones((B, S), np.float32)
            m[:, :n_patch] = 0.0
            batch[name] = m
        else:  # float embeddings (model casts to its activation dtype)
            batch[name] = rng.normal(size=shape_).astype(np.float32)
    return cfg, batch
