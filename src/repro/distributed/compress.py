"""Gradient compression for cross-pod data parallelism.

At 1000+ nodes the scarce resource is the inter-pod (DCN/ICI-bridge) link,
not in-pod ICI. Two mechanisms:

  * bf16 gradient all-reduce (default): params are bf16, so backward
    cotangents — and therefore the SPMD-inserted all-reduce — are bf16,
    halving DP collective bytes vs fp32. Visible directly in the dry-run HLO.
  * int8 error-feedback all-reduce (`compressed_allreduce`): explicit
    shard_map collective for the pod axis. Per-tensor max-abs scale,
    stochastic rounding, residual carried by the caller (error feedback
    keeps the compression unbiased over steps).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _quantize(x: jax.Array, key: jax.Array, bits: int = 8
              ) -> tuple[jax.Array, jax.Array]:
    qmax = 2.0 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(x)) / qmax + 1e-12
    scaled = x / scale
    noise = jax.random.uniform(key, x.shape, x.dtype, -0.5, 0.5)
    q = jnp.clip(jnp.round(scaled + noise), -qmax, qmax).astype(jnp.int8)
    return q, scale


def compressed_psum(x: jax.Array, key: jax.Array, axis_name: str,
                    residual: jax.Array | None = None, bits: int = 8
                    ) -> tuple[jax.Array, jax.Array]:
    """Inside shard_map: int8-quantized psum over `axis_name` with error
    feedback. Returns (mean_gradient, new_residual)."""
    if residual is not None:
        x = x + residual
    q, scale = _quantize(x.astype(jnp.float32), key, bits)
    # int8 wire format; accumulate in int32 (worlds <= 2^23 summands safe)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    scale_sum = jax.lax.psum(scale, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    # each shard contributed ~q*scale; approximate with mean scale
    mean = total.astype(jnp.float32) * (scale_sum / n) / n
    new_residual = x - (q.astype(jnp.float32) * scale)
    return mean, new_residual


def compressed_allreduce_tree(grads, key: jax.Array, mesh, axis: str = "pod",
                              residuals=None, bits: int = 8):
    """Apply compressed_psum leaf-wise over `axis` via shard_map. Grads must
    already be reduced over other axes. Residual tree is threaded through."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    if axis not in mesh.axis_names:
        return grads, residuals
    leaves, treedef = jax.tree.flatten(grads)
    res_leaves = (jax.tree.leaves(residuals) if residuals is not None
                  else [jnp.zeros_like(l, jnp.float32) for l in leaves])
    keys = jax.random.split(key, len(leaves))

    outs = []
    for leaf, res, k in zip(leaves, res_leaves, keys):
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(), P(), P()),
            out_specs=(P(), P()),
            check_rep=False,
        )
        def _one(x, r, kk):
            return compressed_psum(x, kk, axis, residual=r, bits=bits)

        outs.append(_one(leaf, res, k))
    new_grads = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_res = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_grads, new_res


def wire_bytes_saved(grads, bits: int = 8, from_bits: int = 16) -> float:
    total = sum(x.size for x in jax.tree.leaves(grads))
    return total * (from_bits - bits) / 8.0
