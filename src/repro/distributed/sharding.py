"""Logical-axis sharding: rules mapping tensor axes -> mesh axes.

Models annotate activations with logical names (`shard_activation(x, "batch",
"seq", "embed")`) and parameters get PartitionSpecs derived from their pytree
path (`param_pspecs`). The translation is strategy-dependent:

  megatron: TP over "model"; params replicated across "data"
  fsdp:     TP over "model"; the non-TP param dim additionally sharded over
            "data" (ZeRO-3-style), all-gathered on use by GSPMD

Batch always shards over every data-parallel mesh axis ("pod" + "data" when
multi-pod). GSPMD handles non-divisible dims by padding, so head counts that
don't divide the 16-way model axis (e.g. 28 heads) remain legal.
"""

from __future__ import annotations

import fnmatch
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def set_mesh_rules(mesh: Mesh | None, fsdp: bool = False,
                   expert_axis: str = "model") -> None:
    _STATE.mesh = mesh
    _STATE.fsdp = fsdp
    _STATE.expert_axis = expert_axis


def current_mesh() -> Mesh | None:
    return getattr(_STATE, "mesh", None)


def _logical_to_mesh(name: str | None, mesh: Mesh):
    if name is None:
        return None
    if name == "batch":
        ax = dp_axes(mesh)
        return ax if len(ax) > 1 else (ax[0] if ax else None)
    if name == "model":
        return "model" if "model" in mesh.axis_names else None
    if name == "fsdp":
        if getattr(_STATE, "fsdp", False) and "data" in mesh.axis_names:
            return "data"
        return None
    if name == "seq_shard":  # sequence parallelism for long-context caches
        return "data" if "data" in mesh.axis_names else None
    if name == "seq_tp":     # Megatron-style SP: residual seq over TP axis
        return "model" if "model" in mesh.axis_names else None
    if name == "expert":     # EP axis: "model" (default) or "data"
        ax = getattr(_STATE, "expert_axis", "model")
        return ax if ax in mesh.axis_names else None
    if name == "fsdp_or_tp":
        # expert inner dim: fsdp over data under EP=TP; nothing under EP=DP
        if getattr(_STATE, "expert_axis", "model") == "model":
            return _logical_to_mesh("fsdp", mesh)
        return None
    if name == "tp_if_ep_data":
        # expert d_ff dim: TP-sharded when experts moved to the data axis
        if getattr(_STATE, "expert_axis", "model") == "data":
            return "model" if "model" in mesh.axis_names else None
        return None
    return None


def pspec(*names: str | None, mesh: Mesh | None = None) -> P:
    mesh = mesh or current_mesh()
    if mesh is None:
        return P()
    return P(*[_logical_to_mesh(n, mesh) for n in names])


def shard_activation(x: jax.Array, *names: str | None) -> jax.Array:
    """with_sharding_constraint under the active mesh rules (no-op if none).
    Axes whose mesh size does not divide the dim are dropped."""
    mesh = current_mesh()
    if mesh is None:
        return x
    names = names + (None,) * (x.ndim - len(names))
    spec = sanitize_spec(pspec(*names, mesh=mesh), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter partitioning: path-pattern -> logical axes for the *trailing*
# dims (leading stacked-layer axes are unsharded). First match wins.
# ---------------------------------------------------------------------------

PARAM_RULES: list[tuple[str, tuple[str | None, ...]]] = [
    # embeddings / heads
    ("*embed/table", ("model", "fsdp")),          # (vocab, d)
    ("*head/w", ("fsdp", "model")),               # (d, vocab)
    # attention (incl. stacked (L, ...) leaves — trailing dims matched)
    ("*attn/wq", ("fsdp", "model")),
    ("*attn/wk", ("fsdp", "model")),
    ("*attn/wv", ("fsdp", "model")),
    ("*attn/wo", ("model", "fsdp")),
    ("*attn/b?", ("model",)),
    # MLA projections
    ("*attn/w_dkv", ("fsdp", None)),              # (d, kv_lora)
    ("*attn/w_kpe", ("fsdp", None)),
    ("*attn/w_uk", (None, "model")),              # (kv_lora, H*hd)
    ("*attn/w_uv", (None, "model")),
    ("*attn/w_dq", ("fsdp", None)),
    ("*attn/w_uq", (None, "model")),
    # dense MLP
    ("*mlp/w_gate", ("fsdp", "model")),
    ("*mlp/w_up", ("fsdp", "model")),
    ("*mlp/w_down", ("model", "fsdp")),
    # MoE experts: expert axis -> EP mesh axis; remaining dims -> TP/fsdp.
    # expert_axis="model": classic EP=TP (weights stationary per TP shard,
    #   inner dims fsdp-sharded over data when enabled);
    # expert_axis="data":  EP over the data axis with TP on d_ff — kills the
    #   per-layer FSDP weight all-gather for huge expert blocks (the
    #   DeepSeek-V2 hillclimb, EXPERIMENTS.md §Perf).
    ("*experts/w_gate", ("expert", "fsdp_or_tp", "tp_if_ep_data")),
    ("*experts/w_up", ("expert", "fsdp_or_tp", "tp_if_ep_data")),
    ("*experts/w_down", ("expert", "tp_if_ep_data", "fsdp_or_tp")),
    ("*router/w", ("fsdp", None)),                 # (d, E)
    ("*shared_mlp/w_gate", ("fsdp", "model")),
    ("*shared_mlp/w_up", ("fsdp", "model")),
    ("*shared_mlp/w_down", ("model", "fsdp")),
    # SSM (mamba1/mamba2)
    ("*ssm/in_proj", ("fsdp", "model")),           # (d, 2*di) / (d, proj)
    ("*ssm/conv_w", ("model", None)),              # (channels, d_conv)
    ("*ssm/conv_b", ("model",)),
    ("*ssm/x_proj", ("model", None)),              # (di, dt_rank + 2*ds)
    ("*ssm/dt_proj", (None, "model")),             # (dt_rank, di)
    ("*ssm/dt_bias", ("model",)),
    ("*ssm/A_log", ("model", None)),               # (di, ds) or (H,)
    ("*ssm/D", ("model",)),
    ("*ssm/out_proj", ("model", "fsdp")),          # (di, d)
    ("*ssm/norm_scale", ("model",)),
    # norms and everything else: replicated
    ("*scale", (None,)),
    ("*", ()),
]

# Gather-mode TP (the serving engine's bit-stable mode, cfg.tp_reduce ==
# "gather"): row-parallel weights flip to COLUMN sharding (the full
# contraction stays on one chip — see distributed.tp.row_parallel_gather)
# and no weight may leave a *contracting* dim sharded for a plain dot,
# where GSPMD could pick a fp32-re-associating split-k strategy. Checked
# before PARAM_RULES; first match wins.
GATHER_PARAM_RULES: list[tuple[str, tuple[str | None, ...]]] = [
    ("*attn/wo", (None, "model")),
    ("*mlp/w_down", (None, "model")),
    ("*shared_mlp/w_down", (None, "model")),
    ("*ssm/out_proj", (None, "model")),
    ("*ssm/x_proj", (None, None)),   # tiny; contracts the sharded di
]


def _match(path: str, tp_reduce: str = "psum") -> tuple[str | None, ...]:
    if tp_reduce == "gather":
        for pat, spec in GATHER_PARAM_RULES:
            if fnmatch.fnmatch(path, pat):
                return spec
    for pat, spec in PARAM_RULES:
        if fnmatch.fnmatch(path, pat):
            return spec
    return ()


def _leaf_path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def axis_size(mesh: Mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, (tuple, list)):
        n = 1
        for a in ax:
            n *= mesh.shape[a]
        return n
    return mesh.shape[ax]


def sanitize_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop mesh axes whose size does not divide the corresponding dim —
    jit in_shardings demand exact divisibility (no GSPMD edge padding)."""
    axes = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for ax, dim in zip(axes, shape):
        out.append(ax if (ax is not None and dim % axis_size(mesh, ax) == 0)
                   else None)
    return P(*out)


def param_pspecs(params_tree, mesh: Mesh, fsdp: bool = False,
                 tp_reduce: str = "psum"):
    """PartitionSpec pytree matching `params_tree` (shapes or arrays)."""

    def leaf_spec(path, leaf):
        shape = getattr(leaf, "shape", ())
        logical = _match(_leaf_path_str(path), tp_reduce)
        ndim = len(shape)
        logical = logical[:ndim]
        # left-pad with None for stacked leading axes (layers)
        pad = (None,) * (ndim - len(logical))
        names = pad + tuple(logical)
        set_mesh_rules(mesh, fsdp)
        spec_axes = [_logical_to_mesh(n, mesh) for n in names]
        return sanitize_spec(P(*spec_axes), shape, mesh)

    return jax.tree_util.tree_map_with_path(leaf_spec, params_tree)


def param_shardings(params_tree, mesh: Mesh, fsdp: bool = False,
                    tp_reduce: str = "psum"):
    specs = param_pspecs(params_tree, mesh, fsdp, tp_reduce)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Serving-state sharding: decode caches sharded along their head axis.
# ---------------------------------------------------------------------------

# leaf basename -> axis carrying heads (or head-grouped channels). KV cache
# leaves are (L, B, T, KV, hd) (dense) or (L, pages, page, KV, hd) (paged
# pool): heads sit second-to-last. SSM conv history (L, B, K-1, channels)
# shards its channel axis; SSM scan state is (L, B, di, ds) (mamba1) or
# (L, B, H, ...) (mamba2) — axis 2 either way. MLA latent leaves (c_kv /
# k_pe / c_kv_pages / k_pe_pages) are rank-compressed, shared across
# heads: replicated. Enc-dec cross-KV (xk/xv) is (L, B, T, KV, hd) like
# self-attn KV: heads second-to-last. Per-row scalars (src_len, pos_off)
# have no rule and stay replicated.
SERVING_STATE_AXES: dict[str, int] = {"k": -2, "v": -2,
                                      "xk": -2, "xv": -2,
                                      "k_pages": -2, "v_pages": -2,
                                      "conv": -1, "ssm": 2}


def serving_state_pspecs(state_tree, mesh: Mesh):
    """PartitionSpec pytree sharding decode-slot caches on the head axis.

    Leaves whose basename has no rule — or whose head axis the mesh's
    "model" size does not divide — stay replicated (GSPMD keeps numerics
    identical either way; sharding is purely a memory/bandwidth win)."""

    def leaf_spec(path, leaf):
        shape = getattr(leaf, "shape", ())
        name = _leaf_path_str(path).rsplit("/", 1)[-1]
        ax = SERVING_STATE_AXES.get(name)
        if ax is None or not shape or "model" not in mesh.axis_names:
            return P()
        ax = ax % len(shape)
        spec = P(*["model" if i == ax else None for i in range(len(shape))])
        return sanitize_spec(spec, shape, mesh)

    return jax.tree_util.tree_map_with_path(leaf_spec, state_tree)


def serving_state_shardings(state_tree, mesh: Mesh):
    specs = serving_state_pspecs(state_tree, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
