"""Explicit-collective tensor-parallel linear layers.

Under plain SPMD, TP matmuls accumulate in fp32 and GSPMD inserts the
partial-sum all-reduce at the dot output — *before* the bf16 cast — so every
TP collective (forward and its AD transposes) moves fp32 bytes: 2x the wire
traffic the math needs. The dry-run measured this as the dominant term on
every dense train cell (EXPERIMENTS.md §Perf).

These wrappers take manual control with shard_map + custom_vjp:

  column_parallel:  y_loc = x @ w_loc          (w col-sharded over "model")
      fwd: no collective;  bwd dx: psum over "model" in bf16.
  row_parallel:     y = psum(x_loc @ w_loc)    (w row-sharded over "model")
      fwd: psum (or psum_scatter under SP) in bf16;  bwd: NO collective
      (the upstream cotangent is already replicated).
  row_parallel_gather:  y = reassemble(all_gather(all_gather(x) @ w_loc))
      (w COLUMN-sharded) — the serving engine's bit-stable mode: every
      output element is one full-contraction dot, so the result is
      bit-identical to the unsharded matmul (a psum re-associates the
      fp32 accumulation across shards; a gather never does).

Both row-parallel forms split the projection into `tp_overlap_chunks`
interleaved column chunks: chunk c's collective (psum / all-gather) has no
consumer until the final concat, so XLA's latency-hiding scheduler runs it
on the wire while chunk c+1's GEMM occupies the MXU — the double-buffered
SUMMA-pipelining idea, with identical numerics (per-chunk reductions touch
disjoint output columns).

Per-shard dots keep fp32 accumulation (preferred_element_type) — only the
wire format changes. Weight grads stay sharded like the weights; the data-
axis gradient reduction stays with SPMD (bf16 cotangents).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import current_mesh, dp_axes


def _dp(mesh):
    ax = dp_axes(mesh)
    return ax if len(ax) > 1 else (ax[0] if ax else None)


def _dot(x, w):
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _ctx(ctx):
    """Unpack a (mesh,) or (mesh, chunks) nondiff context tuple."""
    mesh = ctx[0]
    chunks = int(ctx[1]) if len(ctx) > 1 else 1
    return mesh, max(chunks, 1)


def _n_chunks(n_cols: int, chunks: int) -> int:
    """Largest chunk count <= `chunks` that divides the column extent."""
    c = max(min(chunks, n_cols), 1)
    while n_cols % c:
        c -= 1
    return c


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def column_parallel(x: jax.Array, w: jax.Array, ctx: tuple) -> jax.Array:
    """x: (..., d) replicated over model; w: (d, F) col-sharded on F.
    Returns y: (..., F) col-sharded."""
    return _col_fwd(x, w, ctx)[0]


def _col_fwd(x, w, ctx):
    mesh, = ctx
    dp = _dp(mesh)

    def local(xl, wl):
        return _dot(xl, wl).astype(xl.dtype)

    y = shard_map(local, mesh=mesh,
                  in_specs=(P(dp), P(None, "model")),
                  out_specs=P(dp, *([None] * (x.ndim - 2)), "model"),
                  check_rep=False)(x, w)
    return y, (x, w)


def _col_bwd(ctx, res, g):
    mesh, = ctx
    x, w = res
    dp = _dp(mesh)
    dp_names = dp if isinstance(dp, tuple) else ((dp,) if dp else ())
    lead = x.ndim - 1

    def local(gl, wl, xl):
        # dx: partial over the model axis; cast BEFORE the psum -> bf16 wire
        dxl = jax.lax.dot_general(
            gl, wl, (((gl.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32).astype(x.dtype)
        dx = jax.lax.psum(dxl, "model")
        gf = gl.reshape(-1, gl.shape[-1])
        xf = xl.reshape(-1, xl.shape[-1])
        dwl = jax.lax.dot_general(
            xf, gf, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(w.dtype)
        # data-axis gradient reduction (bf16 wire), explicit under shard_map
        for ax in dp_names:
            dwl = jax.lax.psum(dwl, ax)
        return dx, dwl

    dx, dw = shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, *([None] * (lead - 1)), "model"),
                  P(None, "model"), P(dp)),
        out_specs=(P(dp), P(None, "model")),
        check_rep=False)(g, w, x)
    return dx, dw


column_parallel.defvjp(_col_fwd, _col_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def row_parallel(x: jax.Array, w: jax.Array, ctx: tuple) -> jax.Array:
    """x: (..., F) col-sharded on F over model; w: (F, d) row-sharded.
    Returns y: (..., d) replicated over model (psum in bf16)."""
    return _row_fwd(x, w, ctx)[0]


def _row_fwd(x, w, ctx):
    mesh, chunks = _ctx(ctx)
    dp = _dp(mesh)
    c = _n_chunks(w.shape[-1], chunks)

    def local(xl, wl):
        if c == 1:
            yl = _dot(xl, wl).astype(xl.dtype)   # cast before the wire
            return jax.lax.psum(yl, "model")
        # interleaved chunks: psum(chunk i) rides the wire while the MXU
        # computes chunk i+1 (disjoint columns -> identical numerics)
        width = wl.shape[-1] // c
        outs = [jax.lax.psum(
            _dot(xl, jax.lax.slice_in_dim(wl, i * width, (i + 1) * width,
                                          axis=1)).astype(xl.dtype),
            "model") for i in range(c)]
        return jnp.concatenate(outs, axis=-1)

    y = shard_map(local, mesh=mesh,
                  in_specs=(P(dp, *([None] * (x.ndim - 2)), "model"),
                            P("model", None)),
                  out_specs=P(dp),
                  check_rep=False)(x, w)
    return y, (x, w)


def _row_bwd(ctx, res, g):
    mesh, _ = _ctx(ctx)
    x, w = res
    dp = _dp(mesh)
    dp_names = dp if isinstance(dp, tuple) else ((dp,) if dp else ())

    def local(gl, wl, xl):
        # g is replicated over model: dx needs NO collective
        dxl = jax.lax.dot_general(
            gl, wl, (((gl.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32).astype(x.dtype)
        gf = gl.reshape(-1, gl.shape[-1])
        xf = xl.reshape(-1, xl.shape[-1])
        dwl = jax.lax.dot_general(
            xf, gf, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(w.dtype)
        for ax in dp_names:
            dwl = jax.lax.psum(dwl, ax)
        return dxl, dwl

    dx, dw = shard_map(
        local, mesh=mesh,
        in_specs=(P(dp), P("model", None),
                  P(dp, *([None] * (x.ndim - 2)), "model")),
        out_specs=(P(dp, *([None] * (x.ndim - 2)), "model"),
                   P("model", None)),
        check_rep=False)(g, w, x)
    return dx, dw


row_parallel.defvjp(_row_fwd, _row_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def row_parallel_gather(x: jax.Array, w: jax.Array, ctx: tuple) -> jax.Array:
    """x: (..., F) col-sharded on F over model; w: (F, d) COLUMN-sharded.
    Returns y: (..., d) replicated, bit-identical to the unsharded matmul:
    x is all-gathered once, then every shard computes its d/tp output
    columns with the full-F contraction — no cross-shard reduction ever
    re-associates the fp32 accumulation. Output chunks are all-gathered
    interleaved with the next chunk's GEMM (double-buffered)."""
    return _row_gather_fwd(x, w, ctx)[0]


def _row_gather_fwd(x, w, ctx):
    mesh, chunks = _ctx(ctx)
    dp = _dp(mesh)
    tp = mesh.shape["model"]

    def local(xl, wl):
        xf = jax.lax.all_gather(xl, "model", axis=xl.ndim - 1, tiled=True)
        n_loc = wl.shape[-1]
        c = _n_chunks(n_loc, chunks)
        width = n_loc // c
        outs = []
        for i in range(c):
            yl = _dot(xf, jax.lax.slice_in_dim(
                wl, i * width, (i + 1) * width, axis=1)).astype(xl.dtype)
            # gather of chunk i overlaps chunk i+1's GEMM in the schedule
            outs.append(jax.lax.all_gather(yl, "model", axis=yl.ndim - 1,
                                           tiled=True))
        if c == 1:
            return outs[0]
        # gathered chunk i holds columns [shard j, chunk i] interleaved;
        # restore the global shard-major column order (pure layout ops)
        g = jnp.stack(outs, axis=-2)             # (..., c, tp*width)
        lead = g.shape[:-2]
        g = g.reshape(*lead, c, tp, width)
        g = jnp.swapaxes(g, -3, -2)
        return g.reshape(*lead, tp * n_loc)

    y = shard_map(local, mesh=mesh,
                  in_specs=(P(dp, *([None] * (x.ndim - 2)), "model"),
                            P(None, "model")),
                  out_specs=P(dp),
                  check_rep=False)(x, w)
    return y, (x, w)


def _row_gather_bwd(ctx, res, g):
    mesh, _ = _ctx(ctx)
    x, w = res
    dp = _dp(mesh)
    dp_names = dp if isinstance(dp, tuple) else ((dp,) if dp else ())
    f_loc = x.shape[-1] // mesh.shape["model"]

    def local(gl, wl, xl):
        # my slice of the (replicated) cotangent columns
        j = jax.lax.axis_index("model")
        n_loc = wl.shape[-1]
        g_my = jax.lax.dynamic_slice_in_dim(gl, j * n_loc, n_loc,
                                            axis=gl.ndim - 1)
        # dx = g @ w.T: partial over my output columns, psum, slice my F rows
        dxf = jax.lax.dot_general(
            g_my, wl, (((g_my.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32).astype(x.dtype)
        dxf = jax.lax.psum(dxf, "model")
        dxl = jax.lax.dynamic_slice_in_dim(dxf, j * f_loc, f_loc,
                                           axis=dxf.ndim - 1)
        xf = jax.lax.all_gather(xl, "model", axis=xl.ndim - 1, tiled=True)
        xflat = xf.reshape(-1, xf.shape[-1])
        gflat = g_my.reshape(-1, g_my.shape[-1])
        dwl = jax.lax.dot_general(
            xflat, gflat, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(w.dtype)
        for ax in dp_names:
            dwl = jax.lax.psum(dwl, ax)
        return dxl, dwl

    dx, dw = shard_map(
        local, mesh=mesh,
        in_specs=(P(dp), P(None, "model"),
                  P(dp, *([None] * (x.ndim - 2)), "model")),
        out_specs=(P(dp, *([None] * (x.ndim - 2)), "model"),
                   P(None, "model")),
        check_rep=False)(g, w, x)
    return dx, dw


row_parallel_gather.defvjp(_row_gather_fwd, _row_gather_bwd)


def tp_enabled(cfg) -> bool:
    mesh = current_mesh()
    return (getattr(cfg, "tp_collectives", "auto") == "explicit"
            and mesh is not None and "model" in mesh.axis_names)


def replicate(x: jax.Array) -> jax.Array:
    """Force `x` fully replicated under the active mesh (no-op without one).

    The parity escape hatch: a plain dot whose *contracting* dim is sharded
    lets GSPMD pick a split-k partial-sum strategy, re-associating the fp32
    accumulation. Re-replicating first costs one all-gather and keeps the
    contraction bit-identical to the unsharded path.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    from jax.sharding import NamedSharding
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P()))


def replicate_for_parity(x: jax.Array, cfg) -> jax.Array:
    """`replicate(x)` only in bit-stable gather mode — activations headed
    into a plain contraction or axis reduction (x_proj, gated-norm mean)
    must not carry a sharded axis there, or GSPMD may re-associate the
    fp32 sum. psum-mode training keeps its sharding (perf over bits)."""
    if tp_enabled(cfg) and getattr(cfg, "tp_reduce", "psum") == "gather":
        return replicate(x)
    return x


def tp_column(x, w, cfg):
    if tp_enabled(cfg) and w.shape[-1] % current_mesh().shape["model"] == 0:
        return column_parallel(x, w, (current_mesh(),))
    from repro.kernels import ops
    if tp_enabled(cfg):
        x = replicate(x)
    return ops.matmul(x, w)


def tp_row(x, w, cfg):
    if tp_enabled(cfg):
        mesh = current_mesh()
        tp = mesh.shape["model"]
        chunks = max(int(getattr(cfg, "tp_overlap_chunks", 1)), 1)
        if (getattr(cfg, "tp_reduce", "psum") == "gather"
                and w.shape[-1] % tp == 0 and x.shape[-1] % tp == 0):
            return row_parallel_gather(x, w, (mesh, chunks))
        if getattr(cfg, "tp_reduce", "psum") != "gather" \
                and w.shape[0] % tp == 0:
            return row_parallel(x, w, (mesh, chunks))
        x = replicate(x)          # keep the fallback contraction unsharded
    from repro.kernels import ops
    return ops.matmul(x, w)
