"""Explicit-collective tensor-parallel linear layers.

Under plain SPMD, TP matmuls accumulate in fp32 and GSPMD inserts the
partial-sum all-reduce at the dot output — *before* the bf16 cast — so every
TP collective (forward and its AD transposes) moves fp32 bytes: 2x the wire
traffic the math needs. The dry-run measured this as the dominant term on
every dense train cell (EXPERIMENTS.md §Perf).

These wrappers take manual control with shard_map + custom_vjp:

  column_parallel:  y_loc = x @ w_loc          (w col-sharded over "model")
      fwd: no collective;  bwd dx: psum over "model" in bf16.
  row_parallel:     y = psum(x_loc @ w_loc)    (w row-sharded over "model")
      fwd: psum (or psum_scatter under SP) in bf16;  bwd: NO collective
      (the upstream cotangent is already replicated).

Per-shard dots keep fp32 accumulation (preferred_element_type) — only the
wire format changes. Weight grads stay sharded like the weights; the data-
axis gradient reduction stays with SPMD (bf16 cotangents).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import current_mesh, dp_axes


def _dp(mesh):
    ax = dp_axes(mesh)
    return ax if len(ax) > 1 else (ax[0] if ax else None)


def _dot(x, w):
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def column_parallel(x: jax.Array, w: jax.Array, ctx: tuple) -> jax.Array:
    """x: (..., d) replicated over model; w: (d, F) col-sharded on F.
    Returns y: (..., F) col-sharded."""
    return _col_fwd(x, w, ctx)[0]


def _col_fwd(x, w, ctx):
    mesh, = ctx
    dp = _dp(mesh)

    def local(xl, wl):
        return _dot(xl, wl).astype(xl.dtype)

    y = shard_map(local, mesh=mesh,
                  in_specs=(P(dp), P(None, "model")),
                  out_specs=P(dp, *([None] * (x.ndim - 2)), "model"),
                  check_rep=False)(x, w)
    return y, (x, w)


def _col_bwd(ctx, res, g):
    mesh, = ctx
    x, w = res
    dp = _dp(mesh)
    dp_names = dp if isinstance(dp, tuple) else ((dp,) if dp else ())
    lead = x.ndim - 1

    def local(gl, wl, xl):
        # dx: partial over the model axis; cast BEFORE the psum -> bf16 wire
        dxl = jax.lax.dot_general(
            gl, wl, (((gl.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32).astype(x.dtype)
        dx = jax.lax.psum(dxl, "model")
        gf = gl.reshape(-1, gl.shape[-1])
        xf = xl.reshape(-1, xl.shape[-1])
        dwl = jax.lax.dot_general(
            xf, gf, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(w.dtype)
        # data-axis gradient reduction (bf16 wire), explicit under shard_map
        for ax in dp_names:
            dwl = jax.lax.psum(dwl, ax)
        return dx, dwl

    dx, dw = shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, *([None] * (lead - 1)), "model"),
                  P(None, "model"), P(dp)),
        out_specs=(P(dp), P(None, "model")),
        check_rep=False)(g, w, x)
    return dx, dw


column_parallel.defvjp(_col_fwd, _col_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def row_parallel(x: jax.Array, w: jax.Array, ctx: tuple) -> jax.Array:
    """x: (..., F) col-sharded on F over model; w: (F, d) row-sharded.
    Returns y: (..., d) replicated over model (psum in bf16)."""
    return _row_fwd(x, w, ctx)[0]


def _row_fwd(x, w, ctx):
    mesh, = ctx
    dp = _dp(mesh)

    def local(xl, wl):
        yl = _dot(xl, wl).astype(xl.dtype)   # cast before the wire
        return jax.lax.psum(yl, "model")

    y = shard_map(local, mesh=mesh,
                  in_specs=(P(dp, *([None] * (x.ndim - 2)), "model"),
                            P("model", None)),
                  out_specs=P(dp),
                  check_rep=False)(x, w)
    return y, (x, w)


def _row_bwd(ctx, res, g):
    mesh, = ctx
    x, w = res
    dp = _dp(mesh)
    dp_names = dp if isinstance(dp, tuple) else ((dp,) if dp else ())

    def local(gl, wl, xl):
        # g is replicated over model: dx needs NO collective
        dxl = jax.lax.dot_general(
            gl, wl, (((gl.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32).astype(x.dtype)
        gf = gl.reshape(-1, gl.shape[-1])
        xf = xl.reshape(-1, xl.shape[-1])
        dwl = jax.lax.dot_general(
            xf, gf, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(w.dtype)
        for ax in dp_names:
            dwl = jax.lax.psum(dwl, ax)
        return dxl, dwl

    dx, dw = shard_map(
        local, mesh=mesh,
        in_specs=(P(dp), P("model", None),
                  P(dp, *([None] * (x.ndim - 2)), "model")),
        out_specs=(P(dp, *([None] * (x.ndim - 2)), "model"),
                   P("model", None)),
        check_rep=False)(g, w, x)
    return dx, dw


row_parallel.defvjp(_row_fwd, _row_bwd)


def tp_enabled(cfg) -> bool:
    mesh = current_mesh()
    return (getattr(cfg, "tp_collectives", "auto") == "explicit"
            and mesh is not None and "model" in mesh.axis_names)


def tp_column(x, w, cfg):
    if tp_enabled(cfg) and w.shape[-1] % current_mesh().shape["model"] == 0:
        return column_parallel(x, w, (current_mesh(),))
    from repro.kernels import ops
    return ops.matmul(x, w)


def tp_row(x, w, cfg):
    if tp_enabled(cfg) and w.shape[0] % current_mesh().shape["model"] == 0:
        return row_parallel(x, w, (current_mesh(),))
    from repro.kernels import ops
    return ops.matmul(x, w)
