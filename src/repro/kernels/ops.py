"""Public GEMM ops — the framework's single entry point for matmuls.

Every matmul site in the model zoo calls `ops.matmul` / `ops.linear`. The op
dispatches per backend:

  * TPU: the Pallas tiled kernel with a block config chosen by the
    performance-predictor autotuner (the paper's technique, applied at every
    call site). Shapes are static at trace time, so tuning happens in Python
    during tracing and is cached process-wide.
  * CPU/GPU (tests, dry-run lowering): `lax.dot_general` — the Pallas kernel
    is TPU-target-only and is validated separately in interpret mode.

Set `force_mode("pallas_interpret")` in tests to route through the kernel.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels.tiled_matmul import BlockConfig, DEFAULT_CONFIG, tiled_matmul

_MODE: Literal["auto", "pallas", "pallas_interpret", "xla"] = "auto"
_CHIP: str = "tpu_v5e"


def force_mode(mode: Literal["auto", "pallas", "pallas_interpret", "xla"]):
    """Override dispatch (tests use 'pallas_interpret'; dry-run uses 'xla')."""
    global _MODE
    _MODE = mode


def force_chip(chip: str) -> None:
    """Select the chip registry entry the trace-time autotuner targets."""
    global _CHIP
    from repro.core.chips import get_chip

    _CHIP = get_chip(chip).name


def _resolve_mode() -> str:
    if _MODE != "auto":
        return _MODE
    return "pallas" if jax.default_backend() == "tpu" else "xla"


@functools.lru_cache(maxsize=None)
def _tuned_config(m: int, n: int, k: int, dtype: str,
                  objective: str, chip: str) -> BlockConfig:
    # Late import: autotuner depends on the trained predictor artifacts.
    try:
        from repro.core.autotuner import get_tuner

        return get_tuner(chip=chip).best_config(m, n, k, dtype=dtype,
                                                objective=objective)
    except Exception:
        return DEFAULT_CONFIG


def warm_gemm_cache(shapes, *, dtype: str = "bfloat16",
                    objective: str = "runtime",
                    chip: str | None = None,
                    rank_mode: str = "auto",
                    strict: bool = False) -> dict[tuple, BlockConfig]:
    """Pre-tune a fleet of (m, n, k) GEMM shapes in one batched
    `tune_many` pass and prime the trace-time config cache, so the first
    jit trace of a model pays zero per-shape tuning latency.

    `dtype` uses trace-time spelling (str(a.dtype), e.g. "bfloat16") —
    the tuner canonicalizes. Trace-time lookups consult the *active*
    chip only (`force_chip`), so pass `chip=None` to warm the chip the
    traces will actually run against; warming an explicit other chip
    fills that chip's tuner/winner caches but cannot serve traces until
    `force_chip` selects it. `rank_mode` selects the candidate-ranking
    path ("auto" ranks fully in-graph on accelerator backends — see
    `GemmAutotuner.rank_in_graph` — and at trace time on CPU; "graph" /
    "trace" force one). Returns {shape: BlockConfig}; on any tuner
    failure (e.g. no artifacts and no substrate) returns {} and traces
    fall back to DEFAULT_CONFIG exactly like the untuned path.

    ``strict=True`` re-raises tuner failures instead of degrading
    silently — the serving engine's mid-run `retune` needs to *observe*
    a corrupt predictor artifact (`core.predictor.ArtifactError`) so it
    can flag degraded-mode tuning rather than quietly pricing on
    defaults.
    """
    shapes = [tuple(int(x) for x in s) for s in shapes]
    # validate eagerly: a rank_mode typo must stay loud, not vanish into
    # the tuner-failure fallback below
    if rank_mode not in ("auto", "graph", "trace"):
        raise ValueError(f"unknown rank_mode {rank_mode!r}")
    try:
        from repro.core.autotuner import get_tuner
        from repro.core.chips import get_chip

        chip_name = get_chip(chip).name if chip else _CHIP

        best = get_tuner(chip=chip_name).tune_many(
            shapes, dtype=dtype, objective=objective, rank_mode=rank_mode)
    except Exception:
        if strict:
            raise
        return {}
    for m, n, k in shapes:
        # the tuner cache is hot now, so this just fills the lru wrapper
        _tuned_config(m, n, k, dtype, objective, chip_name)
    return dict(zip(shapes, best))


SSM_SERVE_GRAIN = 8  # min prefill bucket == SSM serve-scan block (ssm.SERVE_CHUNK)


@functools.lru_cache(maxsize=None)
def prefill_buckets(max_len: int, min_bucket: int = SSM_SERVE_GRAIN
                    ) -> tuple[int, ...]:
    """Power-of-two row buckets the serving engine pads prefill chunks to,
    so distinct prompt/chunk lengths share jit traces and tuned GEMM
    shapes. Memoized per (max_len, min_bucket): the engine's per-admission
    bucket lookup bisects this tuple instead of rebuilding a list."""
    buckets, b = [], min_bucket
    while b < max_len:
        buckets.append(b)
        b *= 2
    buckets.append(max_len)
    return tuple(buckets)


def chunk_buckets(max_len: int, chunk_tokens: int,
                  grain: int = SSM_SERVE_GRAIN) -> tuple[int, ...]:
    """The chunk sizes an engine's chunked-admission prefill may trace:
    the prefill buckets capped at `chunk_tokens` (a prompt longer than the
    cap is fed through the decode loop `chunk_tokens` tokens per step).
    `grain` sets the bucket floor (the engine's SSM serve-scan block)."""
    caps = [b for b in prefill_buckets(max_len, grain) if b <= chunk_tokens]
    return tuple(caps) if caps else prefill_buckets(max_len, grain)[:1]


def serving_gemm_fleet(cfg, *, max_batch: int, max_len: int,
                       include_slot_prefill: bool = True,
                       chunk_tokens: int | None = None,
                       lane_width: int | None = None,
                       kv_cap: int | None = None,
                       tp: int = 1,
                       grain: int = SSM_SERVE_GRAIN
                       ) -> list[tuple[int, int, int]]:
    """Every GEMM shape a serving engine will trace: the batched prefill
    (max_batch * max_len rows, LM head over max_batch last positions), the
    lockstep decode step (max_batch rows), and — for continuous batching —
    the chunked-admission prefill grid: each (admission-width, chunk-
    bucket) pair the chunk scheduler can issue (pow2 widths up to
    max_batch x pow2 chunk buckets, LM head over the admission rows), plus
    the legacy single-slot buckets for `admission="serial"`. Feed to
    `warm_gemm_cache` so neither the first wave nor the first fused
    chunk+decode step pays per-shape tuning latency.

    `kv_cap` overrides the per-row cached-token capacity that sizes MLA's
    whole-cache `w_uk`/`w_uv` decompression rows (default `max_len`): the
    paged engine's gathered view spans `n_row_pages * page_size` logical
    positions per row, which is what the decompress GEMMs actually run
    over there.

    With `tp > 1` the fleet is the *per-shard* extents — gather-mode TP
    leaves every projection an (M, N/tp, K) GEMM per chip (see
    `gemm_shape_counts(..., tp=)`), so the autotuner tunes exactly the
    shapes a sharded engine step runs. `grain` is the engine's SSM
    serve-scan block (the prefill-bucket floor).
    """
    from repro.models.config import gemm_shape_counts

    cap_len = kv_cap if kv_cap is not None else max_len
    fleet = set(gemm_shape_counts(cfg, max_batch * max_len,
                                  head_tokens=max_batch,
                                  kv_rows=max_batch * cap_len, tp=tp))
    fleet |= set(gemm_shape_counts(cfg, max_batch,
                                   kv_rows=max_batch * cap_len, tp=tp))
    if include_slot_prefill:
        if chunk_tokens is None:
            # serial admission / legacy callers: single-shot slot prefills
            # only ever trace width 1
            widths = {1}
            chunks = prefill_buckets(max_len, grain)
        else:
            # chunked admission rounds the lane up to the next pow2, so
            # pre-tune the full pow2 ladder through the lane cap
            cap = lane_width if lane_width is not None else max_batch
            widths = {1}
            a = 1
            while a < cap:
                a *= 2
                widths.add(a)
            chunks = chunk_buckets(max_len, chunk_tokens, grain)
        for b in set(chunks) | set(prefill_buckets(max_len, grain)):
            # buckets past the chunk cap are only ever traced by width-1
            # serial slot prefills — don't pre-tune wide variants of them
            ws = sorted(widths) if b in chunks else [1]
            for w in ws:
                fleet |= set(gemm_shape_counts(cfg, w * b, head_tokens=w,
                                               kv_rows=w * cap_len, tp=tp))
    if getattr(cfg, "kind", None) in ("encdec", "vlm"):
        # prefill-once admission grid: encoder + cross-KV (encdec) or the
        # patch-prefix decoder pass (vlm) runs once per request over the
        # source/patch rows, bucketed by the full prefill ladder (admission
        # is not capped at chunk_tokens) at pow2 widths plus the full batch
        cap = lane_width if lane_width is not None else max_batch
        widths = {1, max_batch}
        a = 1
        while a < cap:
            a *= 2
            widths.add(a)
        for b in prefill_buckets(max_len, grain):
            for w in sorted(widths):
                if cfg.kind == "encdec":
                    fleet |= set(gemm_shape_counts(
                        cfg, 0, head_tokens=0, src_tokens=w * b, tp=tp))
                else:
                    fleet |= set(gemm_shape_counts(
                        cfg, w * b, head_tokens=0, tp=tp))
    return sorted(fleet)


def warm_fleet_gemm_cache(specs, *, objective: str = "runtime",
                          rank_mode: str = "auto"
                          ) -> list[dict[tuple, BlockConfig]]:
    """Cross-engine fleet pre-tuning: warm a *heterogeneous* fleet of
    serving engines in one batched tuning pass per chip.

    `specs` is a list of dicts, one per engine: a ``cfg`` (ModelConfig)
    plus `serving_gemm_fleet` keyword args (``max_batch``, ``max_len``,
    ``chunk_tokens``, ``lane_width``, ``tp``, ``grain``, ...) and
    optionally ``chip`` / ``dtype``. Engines sharing a (chip, dtype) are
    unioned into one shape fleet and tuned together — N engines on the
    same chip pay one `tune_many` pass, not N — while engines on
    different chips each warm their own chip's tuner/winner caches.
    Returns one ``{shape: BlockConfig}`` dict per input spec (the
    engine's own shapes only), suitable for `ServingEngine.pretuned`;
    tuner failures degrade to ``{}`` per group exactly like
    `warm_gemm_cache`."""
    specs = [dict(sp) for sp in specs]
    fleets: list[list[tuple[int, int, int]]] = []
    groups: dict[tuple, set] = {}
    for sp in specs:
        kw = {k: v for k, v in sp.items()
              if k not in ("cfg", "chip", "dtype")}
        fleet = serving_gemm_fleet(sp["cfg"], **kw)
        fleets.append(fleet)
        groups.setdefault((sp.get("chip"), sp.get("dtype", "bfloat16")),
                          set()).update(fleet)
    tuned = {
        (chip, dtype): warm_gemm_cache(sorted(shapes), dtype=dtype,
                                       objective=objective, chip=chip,
                                       rank_mode=rank_mode)
        for (chip, dtype), shapes in groups.items()}
    return [
        {s: grp[s] for s in fleet if s in grp}
        for sp, fleet in zip(specs, fleets)
        for grp in [tuned[(sp.get("chip"), sp.get("dtype", "bfloat16"))]]]


def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    config: BlockConfig | None = None,
    objective: str = "runtime",
    transpose_b: bool = False,
    out_dtype=None,
) -> jax.Array:
    """out = a @ op(b) over the last axis of `a`; leading dims are batch."""
    *lead, k = a.shape
    if transpose_b:
        n, kb = b.shape
    else:
        kb, n = b.shape
    if kb != k:
        raise ValueError(f"contraction mismatch {k} vs {kb}")
    m = 1
    for d in lead:
        m *= d
    mode = _resolve_mode()
    out_dtype = out_dtype or a.dtype
    if mode == "xla":
        dn = (((1,), (1 if transpose_b else 0,)), ((), ()))
        out = jax.lax.dot_general(
            a.reshape(m, k), b, dn, preferred_element_type=jnp.float32
        ).astype(out_dtype)
    else:
        cfg = config or _tuned_config(m, n, k, str(a.dtype), objective, _CHIP)
        out = tiled_matmul(
            a.reshape(m, k), b,
            config=cfg,
            transpose_b=transpose_b,
            out_dtype=out_dtype,
            interpret=(mode == "pallas_interpret"),
        )
    return out.reshape(*lead, n)


def linear(x: jax.Array, w: jax.Array, b: jax.Array | None = None,
           **kw) -> jax.Array:
    """y = x @ w (+ b). w: (K, N)."""
    y = matmul(x, w, **kw)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def gemm(a, b, c=None, *, alpha=1.0, beta=0.0, transpose_a=False,
         transpose_b=False, config: BlockConfig | None = None,
         out_dtype=None, interpret: bool | None = None) -> jax.Array:
    """Full BLAS-3 surface (rank-2 only) — used by benchmarks/tests."""
    mode = _resolve_mode()
    use_interpret = (mode == "pallas_interpret") if interpret is None else interpret
    if mode == "xla" and interpret is None:
        from repro.kernels.ref import matmul_ref

        return matmul_ref(a, b, c, transpose_a=transpose_a,
                          transpose_b=transpose_b, alpha=alpha, beta=beta,
                          out_dtype=out_dtype)
    return tiled_matmul(
        a, b, c, config=config or DEFAULT_CONFIG, transpose_a=transpose_a,
        transpose_b=transpose_b, alpha=alpha, beta=beta, out_dtype=out_dtype,
        interpret=use_interpret,
    )
