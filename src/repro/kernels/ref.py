"""Pure-jnp oracles for every Pallas kernel in this package."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(
    a: jax.Array,
    b: jax.Array,
    c: jax.Array | None = None,
    *,
    transpose_a: bool = False,
    transpose_b: bool = False,
    alpha: float = 1.0,
    beta: float = 0.0,
    out_dtype=None,
) -> jax.Array:
    """C = alpha * op(A) @ op(B) + beta * C with fp32 accumulation."""
    out_dtype = out_dtype or a.dtype
    if transpose_a:
        a = a.T
    if transpose_b:
        b = b.T
    acc = jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    out = alpha * acc
    if beta != 0.0:
        if c is None:
            raise ValueError("beta != 0 requires c")
        out = out + beta * c.astype(jnp.float32)
    return out.astype(out_dtype)


def grouped_matmul_ref(
    x: jax.Array,          # (T, K) tokens
    w: jax.Array,          # (E, K, N) per-expert weights
    group_ids: jax.Array,  # (T,) expert id per token
    *,
    out_dtype=None,
) -> jax.Array:
    """Per-token expert GEMM oracle: out[t] = x[t] @ w[group_ids[t]]."""
    out_dtype = out_dtype or x.dtype
    wg = w[group_ids]  # (T, K, N)
    out = jnp.einsum("tk,tkn->tn", x.astype(jnp.float32),
                     wg.astype(jnp.float32))
    return out.astype(out_dtype)
