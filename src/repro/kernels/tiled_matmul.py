"""Pallas TPU tiled GEMM — the paper's custom kernel, rebuilt TPU-native.

The CUDA original stages (tile x tile) squares of A and B through shared
memory with one thread per output element. The TPU version stages
(block_m x block_k) / (block_k x block_n) slabs through VMEM with an fp32
accumulator held in VMEM scratch across the contraction grid dimension, and
feeds the MXU via `lax.dot_general`:

  grid = (M/bm, N/bn, K/bk); k is the innermost ("arbitrary") dimension so
  the accumulator tile lives across k-steps and is flushed once at k == last.

Supports alpha/beta scaling (the paper's CUTLASS sweep axis), all four
nn/nt/tn/tt layouts (transposes happen on the VMEM-resident block, feeding
the MXU directly), bf16/f32 inputs with fp32 accumulation.

TARGET is TPU (compiled path); correctness is validated on CPU with
`interpret=True` against `ref.matmul_ref` in tests.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax releases
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    """VMEM tiling for one GEMM call — the TPU analogue of 'tile size'."""

    block_m: int = 256
    block_n: int = 256
    block_k: int = 512

    def vmem_bytes(self, in_bytes: int = 2, acc_bytes: int = 4,
                   stages: int = 2) -> int:
        return stages * (self.block_m * self.block_k
                         + self.block_k * self.block_n) * in_bytes + (
            self.block_m * self.block_n * acc_bytes)

    def as_tuple(self) -> tuple[int, int, int]:
        return (self.block_m, self.block_n, self.block_k)


DEFAULT_CONFIG = BlockConfig()


def _matmul_kernel(a_ref, b_ref, c_in_ref, c_ref, acc_ref, *,
                   alpha: float, beta: float, n_k_steps: int,
                   transpose_a: bool, transpose_b: bool):
    """One (i, j, k) grid step: acc += A_blk @ B_blk, flush at last k."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    if transpose_a:
        a = a.T  # block was loaded as (bk, bm)
    if transpose_b:
        b = b.T  # block was loaded as (bn, bk)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == n_k_steps - 1)
    def _flush():
        out = alpha * acc_ref[...]
        if beta != 0.0:
            out = out + beta * c_in_ref[...].astype(jnp.float32)
        c_ref[...] = out.astype(c_ref.dtype)


def _pad_to(x: jax.Array, multiples: tuple[int, ...]) -> jax.Array:
    pads = []
    needs = False
    for dim, mult in zip(x.shape, multiples):
        target = math.ceil(dim / mult) * mult
        pads.append((0, target - dim))
        needs = needs or target != dim
    return jnp.pad(x, pads) if needs else x


@functools.partial(
    jax.jit,
    static_argnames=("config", "transpose_a", "transpose_b", "alpha", "beta",
                     "out_dtype", "interpret"),
)
def tiled_matmul(
    a: jax.Array,
    b: jax.Array,
    c: jax.Array | None = None,
    *,
    config: BlockConfig = DEFAULT_CONFIG,
    transpose_a: bool = False,
    transpose_b: bool = False,
    alpha: float = 1.0,
    beta: float = 0.0,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """C = alpha * op(A) @ op(B) + beta * C  (paper's GEMM surface).

    a: (M, K) or (K, M) if transpose_a; b: (K, N) or (N, K) if transpose_b.
    Shapes need not divide the block config; inputs are zero-padded and the
    output is sliced back (TPU-style explicit padding).
    """
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError("tiled_matmul expects rank-2 operands")
    m = a.shape[1] if transpose_a else a.shape[0]
    ka = a.shape[0] if transpose_a else a.shape[1]
    kb = b.shape[1] if transpose_b else b.shape[0]
    n = b.shape[0] if transpose_b else b.shape[1]
    if ka != kb:
        raise ValueError(f"contraction mismatch: {ka} vs {kb}")
    k = ka
    if beta != 0.0 and c is None:
        raise ValueError("beta != 0 requires c")
    out_dtype = out_dtype or a.dtype

    bm, bn, bk = config.block_m, config.block_n, config.block_k
    # clamp blocks to (padded) problem so tiny problems stay single-block
    bm = min(bm, math.ceil(m / 8) * 8)
    bn = min(bn, math.ceil(n / 128) * 128)
    bk = min(bk, math.ceil(k / 128) * 128)

    a = _pad_to(a, (bk, bm) if transpose_a else (bm, bk))
    b = _pad_to(b, (bn, bk) if transpose_b else (bk, bn))
    mp = a.shape[1] if transpose_a else a.shape[0]
    kp = a.shape[0] if transpose_a else a.shape[1]
    np_ = b.shape[0] if transpose_b else b.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)

    if transpose_a:
        a_spec = pl.BlockSpec((bk, bm), lambda i, j, kk: (kk, i))
    else:
        a_spec = pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))
    if transpose_b:
        b_spec = pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk))
    else:
        b_spec = pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))
    c_spec = pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))

    if c is None:
        c_in = jnp.zeros((mp, np_), dtype=out_dtype)
    else:
        c_in = _pad_to(c.astype(out_dtype), (bm, bn))

    kernel = functools.partial(
        _matmul_kernel,
        alpha=alpha,
        beta=beta,
        n_k_steps=grid[2],
        transpose_a=transpose_a,
        transpose_b=transpose_b,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[a_spec, b_spec, c_spec],
        out_specs=c_spec,
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name=f"tiled_matmul_{bm}x{bn}x{bk}",
    )(a, b, c_in)
    return out[:m, :n]
