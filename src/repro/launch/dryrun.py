import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first backend initialization. 512 host devices back both the
# (16,16) single-pod and (2,16,16) multi-pod production meshes.

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (  # noqa: E402
    SHAPE_DEFS,
    all_cells,
    get_config,
    input_specs,
)
from repro.core.hloanalyze import analyze_hlo  # noqa: E402
from repro.distributed.sharding import (  # noqa: E402
    dp_axes,
    param_shardings,
    sanitize_spec,
    set_mesh_rules,
)
from repro.kernels import ops  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.registry import get_model  # noqa: E402
from repro.optim.adamw import AdamWConfig, init_opt_state, zero1_shardings  # noqa: E402
from repro.train.step import make_train_step  # noqa: E402

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "dryrun")


def _dp(mesh, size: int):
    """Data-parallel axes for a batch dim of `size` (replicate if indivisible)."""
    ax = dp_axes(mesh)
    total = 1
    for a in ax:
        total *= mesh.shape[a]
    if size % total == 0:
        return ax if len(ax) > 1 else ax[0]
    return None


def batch_shardings(arch: str, shape: str, mesh, specs,
                    cfg=None, variant: str | None = None) -> dict:
    """NamedSharding tree matching input_specs(arch, shape). Every spec is
    sanitized against the actual dims (jit demands exact divisibility)."""
    cfg = cfg or get_config(arch)
    step = SHAPE_DEFS[shape]["step"]
    B = (specs["token"].shape[0] if step == "decode"
         else specs["tokens"].shape[0])
    dp = _dp(mesh, B)
    long_ctx = shape == "long_500k"
    mdl = "model" if "model" in mesh.axis_names else None
    tp = mesh.shape[mdl] if mdl else 1

    def ns(sds, *spec):
        return NamedSharding(mesh, sanitize_spec(P(*spec), sds.shape, mesh))

    out = {}
    for name, sds in specs.items():
        if name == "state":
            continue
        nd = len(sds.shape)
        out[name] = ns(sds, dp, *([None] * (nd - 1)))
    if step != "decode":
        return out

    st = specs["state"]
    sharded_state = {}
    kind = cfg.kind

    def kv_spec(sds):
        """(L, B, S, KV, hd): batch over dp; TP lands on kv heads if they
        divide, else on the *sequence* dim (flash-decode layout — head_dim
        sharding makes SPMD re-gather the cache every layer, which the
        dry-run exposed); long-context (B=1) cells use sequence parallelism
        over 'data' instead of batch.

        kv_batch* variants: batch-only sharding, seq unsharded — the
        masked-select rewrite that sequence-sharded dus pays per decode step
        disappears (EXPERIMENTS.md §Perf decode hillclimb)."""
        L_, Bc, S_, KV, hd = sds.shape
        if variant in ("kv_batch", "kv_batch_fp8"):
            return ns(sds, None, dp, None, mdl if KV % tp == 0 else None,
                      None)
        if KV % tp == 0:
            seq_ax, tp_axes = None, (mdl, None)
        else:
            seq_ax, tp_axes = mdl, (None, None)
        if long_ctx:
            return ns(sds, None, None, ("data",) if seq_ax is None
                      else ("data", seq_ax), *tp_axes)
        return ns(sds, None, dp, seq_ax, *tp_axes)

    if kind in ("dense", "moe", "vlm"):
        sharded_state["kv"] = {"k": kv_spec(st["kv"]["k"]),
                               "v": kv_spec(st["kv"]["v"])}
    elif kind == "mla_moe":
        sharded_state["kv"] = {
            "c_kv": ns(st["kv"]["c_kv"], None, dp, None, mdl),  # latent -> TP
            "k_pe": ns(st["kv"]["k_pe"], None, dp, None, None),
        }
    elif kind == "mamba1":
        sharded_state["kv"] = {
            "conv": ns(st["kv"]["conv"], None, dp, None, mdl),
            "ssm": ns(st["kv"]["ssm"], None, dp, mdl, None),
        }
    elif kind == "hybrid":
        sharded_state["cache"] = {
            "mamba": {
                "conv": ns(st["cache"]["mamba"]["conv"],
                           None, None, dp, None, mdl),
                "ssm": ns(st["cache"]["mamba"]["ssm"],
                          None, None, dp, mdl, None, None),
            },
            "attn": {
                "k": (ns(st["cache"]["attn"]["k"], None, None, "data", mdl,
                         None) if long_ctx else
                      ns(st["cache"]["attn"]["k"], None, dp, None, mdl, None)),
                "v": (ns(st["cache"]["attn"]["v"], None, None, "data", mdl,
                         None) if long_ctx else
                      ns(st["cache"]["attn"]["v"], None, dp, None, mdl, None)),
            },
        }
    elif kind == "encdec":
        sharded_state["kv"] = {
            "k": kv_spec(st["kv"]["k"]), "v": kv_spec(st["kv"]["v"]),
            # cross-KV: head-sharded like self-attn, seq never sharded
            # (source length is short and read-only after admission)
            "xk": ns(st["kv"]["xk"], None, dp, None, mdl, None),
            "xv": ns(st["kv"]["xv"], None, dp, None, mdl, None),
        }
        sharded_state["src_len"] = ns(st["src_len"], dp)
    if "pos_off" in st:
        sharded_state["pos_off"] = ns(st["pos_off"], dp)
    idx = st["index"]
    sharded_state["index"] = (ns(idx, dp) if getattr(idx, "shape", ())
                              else NamedSharding(mesh, P()))
    out["state"] = sharded_state
    return out


# --- perf-variant transforms (EXPERIMENTS.md §Perf hillclimbs) ---
import dataclasses  # noqa: E402

VARIANTS = {
    None: lambda cfg: cfg,
    "sp": lambda cfg: dataclasses.replace(cfg, sequence_parallel=True),
    "ep_data": lambda cfg: dataclasses.replace(cfg, moe_expert_axis="data",
                                               fsdp=False),
    "kv_batch": lambda cfg: cfg,     # sharding-level change only (see below)
    "kv_batch_fp8": lambda cfg: dataclasses.replace(
        cfg, kv_cache_dtype="float8_e4m3fn"),
    "kv_fp8": lambda cfg: dataclasses.replace(
        cfg, kv_cache_dtype="float8_e4m3fn"),  # keeps default (seq) sharding
    "sp_ep_data": lambda cfg: dataclasses.replace(
        cfg, sequence_parallel=True, moe_expert_axis="data", fsdp=False),
    "moe_smap": lambda cfg: dataclasses.replace(cfg, moe_impl="shard_map"),
    "moe_smap_sp": lambda cfg: dataclasses.replace(
        cfg, moe_impl="shard_map", sequence_parallel=True),
    "tpx": lambda cfg: dataclasses.replace(cfg, tp_collectives="explicit"),
    "tpx_sp": lambda cfg: dataclasses.replace(
        cfg, tp_collectives="explicit", sequence_parallel=True),
}


def _apply_variant_to_specs(specs, variant):
    """Adjust input specs for variants that change cache dtype."""
    if variant not in ("kv_batch_fp8", "kv_fp8") or "state" not in specs:
        return specs
    f8 = jnp.float8_e4m3fn

    def conv(s):
        if hasattr(s, "dtype") and s.dtype == jnp.bfloat16:
            return jax.ShapeDtypeStruct(s.shape, f8)
        return s

    out = dict(specs)
    out["state"] = jax.tree.map(conv, specs["state"])
    return out


def build_cell(arch: str, shape: str, mesh, *, include_optimizer: bool = True,
               variant: str | None = None):
    """Returns (fn, example_args, in_shardings, donate, cfg, out_shardings)."""
    cfg = VARIANTS[variant](get_config(arch))
    model = get_model(cfg)
    set_mesh_rules(mesh, fsdp=cfg.fsdp, expert_axis=cfg.moe_expert_axis)
    specs = _apply_variant_to_specs(input_specs(arch, shape), variant)
    step = SHAPE_DEFS[shape]["step"]

    params_shape = jax.eval_shape(lambda k: model.init(k, cfg),
                                  jax.random.key(0))
    p_sh = param_shardings(params_shape, mesh, fsdp=cfg.fsdp)
    b_sh = batch_shardings(arch, shape, mesh, specs, cfg=cfg, variant=variant)

    if step == "train":
        opt_shape = jax.eval_shape(init_opt_state, params_shape)
        opt_sh = zero1_shardings(params_shape, p_sh, mesh)
        rng_shape = jax.eval_shape(
            lambda: jax.random.key_data(jax.random.key(0)))
        state_shape = {"params": params_shape, "opt": opt_shape,
                       "rng": rng_shape}
        state_sh = {"params": p_sh, "opt": opt_sh,
                    "rng": NamedSharding(mesh, P())}
        if not include_optimizer:
            state_shape.pop("opt")
            state_sh.pop("opt")
        train_step = make_train_step(model, cfg, AdamWConfig())
        fn = train_step
        args = (state_shape, specs)
        in_sh = (state_sh, b_sh)
        donate = (0,)
        out_sh = (state_sh, None)
    elif step == "prefill":
        def fn(params, batch):
            logits, state = model.prefill(params, batch, cfg)
            return logits

        args = (params_shape, {k: v for k, v in specs.items()})
        in_sh = (p_sh, b_sh)
        donate = ()
        out_sh = None
    else:  # decode
        def fn(params, token, state):
            return model.decode_step(params, token, state, cfg)

        args = (params_shape, specs["token"], specs["state"])
        in_sh = (p_sh, b_sh["token"], b_sh["state"])
        donate = (2,)
        # pin the output state to the input cache sharding: donation then
        # reuses buffers and no round-trip reshard collectives appear
        out_sh = (None, b_sh["state"])
    return fn, args, in_sh, donate, cfg, out_sh


def run_cell(arch: str, shape: str, *, multi_pod: bool,
             out_dir: str = ARTIFACTS, force: bool = False,
             include_optimizer: bool = True,
             variant: str | None = None) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(os.path.join(out_dir, mesh_name), exist_ok=True)
    suffix = f"__{variant}" if variant else ""
    out_path = os.path.join(out_dir, mesh_name, f"{arch}_{shape}{suffix}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    ops.force_mode("xla")  # Pallas kernels are TPU-target; dry-run lowers XLA
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    fn, args, in_sh, donate, cfg, out_sh = build_cell(
        arch, shape, mesh, include_optimizer=include_optimizer,
        variant=variant)

    with mesh:
        kw = {"out_shardings": out_sh} if out_sh is not None else {}
        jitted = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate, **kw)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    n_chips = mesh.size
    # Trip-count-aware analysis of the per-device SPMD program. XLA's own
    # cost_analysis counts scan bodies once and charges every intermediate
    # as HBM traffic — see core/hloanalyze.py.
    hc = analyze_hlo(hlo, n_chips)
    step_kind = SHAPE_DEFS[shape]["step"]
    tokens = (SHAPE_DEFS[shape]["global_batch"]
              * (SHAPE_DEFS[shape]["seq_len"] if step_kind != "decode" else 1))
    n_active = cfg.n_active_params()
    model_flops = (6 if step_kind == "train" else 2) * n_active * tokens

    result = {
        "arch": arch,
        "shape": shape,
        "variant": variant,
        "mesh": mesh_name,
        "n_chips": n_chips,
        "step": step_kind,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops_per_chip": float(hc.flops),
        "bytes_per_chip": float(hc.hbm_bytes),
        "collective_wire_bytes_per_chip": float(hc.collective_bytes),
        "collective_wire_bytes_by_op": hc.collective_by_op,
        "while_trip_counts": hc.while_trips,
        "xla_cost_analysis_flops_raw": float(cost.get("flops", 0.0)),
        "xla_cost_analysis_bytes_raw": float(sum(
            v for k, v in cost.items() if k.startswith("bytes accessed"))),
        "model_flops": float(model_flops),
        "tokens_per_step": tokens,
        "memory_analysis": {
            "argument_size_in_bytes": getattr(
                mem, "argument_size_in_bytes", 0),
            "output_size_in_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_size_in_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_size_in_bytes": getattr(
                mem, "generated_code_size_in_bytes", 0),
        },
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--variant", default=None, choices=list(VARIANTS))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=ARTIFACTS)
    args = ap.parse_args()

    cells = all_cells()
    if args.arch:
        cells = [(a, s) for a, s in cells if a == args.arch]
    if args.shape:
        cells = [(a, s) for a, s in cells if s == args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
            try:
                t0 = time.time()
                r = run_cell(arch, shape, multi_pod=mp, out_dir=args.out,
                             force=args.force, variant=args.variant)
                print(f"[ok] {tag}: flops/chip={r['flops_per_chip']:.3e} "
                      f"coll/chip={r['collective_wire_bytes_per_chip']:.3e}B "
                      f"args/dev={r['memory_analysis']['argument_size_in_bytes']/2**30:.2f}GiB "
                      f"({time.time()-t0:.0f}s)", flush=True)
            except Exception as e:
                failures.append((tag, repr(e)))
                print(f"[FAIL] {tag}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(f"  {t}: {e}")
        raise SystemExit(1)
    print(f"\nall {len(cells) * len(meshes)} dry-run cells passed")


if __name__ == "__main__":
    main()
