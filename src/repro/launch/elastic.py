"""Elastic re-meshing: resume a run on a different device count.

When nodes die, the scheduler restarts the job with whatever survives; this
module picks the best (data, model) factorization for the new world size,
rebuilds shardings, and restores the latest checkpoint onto the new mesh
(CheckpointManager.restore already supports arbitrary re-placement because
shards are saved host-side and re-placed via device_put).
"""

from __future__ import annotations

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.distributed.sharding import param_shardings, set_mesh_rules


def best_mesh_for(n_devices: int, *, prefer_model: int = 16):
    """Largest model-parallel degree <= prefer_model that divides the world,
    remainder goes to data parallelism."""
    model = 1
    for m in range(min(prefer_model, n_devices), 0, -1):
        if n_devices % m == 0:
            model = m
            break
    return jax.make_mesh((n_devices // model, model), ("data", "model"))


def resume_elastic(ckpt_dir: str, model, cfg, *, prefer_model: int = 16):
    """Returns (mesh, state, step) with state placed on the current world."""
    n = len(jax.devices())
    mesh = best_mesh_for(n, prefer_model=prefer_model)
    set_mesh_rules(mesh, fsdp=cfg.fsdp, expert_axis=cfg.moe_expert_axis)
    params_shape = jax.eval_shape(lambda k: model.init(k, cfg),
                                  jax.random.key(0))
    p_sh = param_shardings(params_shape, mesh, fsdp=cfg.fsdp)
    mgr = CheckpointManager(ckpt_dir)
    step = mgr.latest_step()
    if step is None:
        return mesh, None, 0
    state, _ = mgr.restore(step, shardings={"params": p_sh})
    return mesh, state, step
