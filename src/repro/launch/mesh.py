"""Production mesh construction.

Single pod: (16, 16) = ("data", "model") — 256 chips (one v5e pod).
Multi-pod:  (2, 16, 16) = ("pod", "data", "model") — 512 chips.

Defined as functions so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist locally (tests / examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


def make_serving_mesh(tp: int = 1):
    """(1, tp) mesh for a tensor-parallel serving engine.

    Keeps the batch axis unsharded (decode-slot surgery stays a local
    dynamic-slice on every chip) and puts `tp` devices on "model". Uses
    the first `tp` local devices so several engines of different tp
    degrees can coexist in one process.
    """
    devices = jax.devices()
    if len(devices) < tp:
        raise ValueError(
            f"tp={tp} needs {tp} devices, have {len(devices)} "
            f"(force more with --xla_force_host_platform_device_count)")
    import numpy as np
    return jax.sharding.Mesh(
        np.asarray(devices[:tp]).reshape(1, tp), ("data", "model"))
