"""Production mesh construction.

Single pod: (16, 16) = ("data", "model") — 256 chips (one v5e pod).
Multi-pod:  (2, 16, 16) = ("pod", "data", "model") — 512 chips.

Defined as functions so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist locally (tests / examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))
