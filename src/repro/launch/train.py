"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
        --steps 100 --ckpt-dir /tmp/run1

Full configs target the production mesh (run under real TPU runtime or the
dry-run); --smoke trains the reduced config on local devices end-to-end with
the same code path (checkpointing, fault tolerance, resume).
"""

from __future__ import annotations

import argparse

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataConfig, DataLoader, SyntheticLMDataset
from repro.models.registry import get_model
from repro.optim.adamw import AdamWConfig
from repro.train.loop import LoopConfig, resume_or_init, run_train_loop
from repro.train.step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on local devices")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if not args.smoke and len(jax.devices()) < 16:
        raise SystemExit(
            "full configs need the production mesh; use --smoke locally "
            "or launch under the TPU runtime (see launch/dryrun.py for the "
            "mesh/sharding construction)")
    model = get_model(cfg)
    print(f"arch={cfg.name} params~{cfg.n_params()/1e9:.2f}B "
          f"devices={len(jax.devices())}")

    ds = SyntheticLMDataset(DataConfig(seq_len=args.seq,
                                       global_batch=args.batch,
                                       vocab=cfg.vocab))
    loader = DataLoader(ds)
    ckpt = CheckpointManager(args.ckpt_dir, keep=3)
    step_fn = jax.jit(make_train_step(
        model, cfg, AdamWConfig(lr=args.lr, warmup_steps=10,
                                decay_steps=args.steps)), donate_argnums=0)
    state, start = resume_or_init(
        ckpt=ckpt,
        init_fn=lambda: init_train_state(jax.random.key(0), model, cfg),
        loader=loader)
    state, summary = run_train_loop(
        train_step=step_fn, state=state, loader=loader, ckpt=ckpt,
        loop_cfg=LoopConfig(total_steps=args.steps,
                            ckpt_every=args.ckpt_every, log_every=10),
        start_step=start)
    print(f"final step={summary['final_step']} "
          f"loss={summary['final_loss']:.4f}")


if __name__ == "__main__":
    main()
