"""Unified model configuration covering all ten assigned architectures.

One dataclass, many families. `kind` selects the forward function:
  dense        - standard decoder-only transformer (GQA, RoPE, opt. QKV bias)
  moe          - dense attention + mixture-of-experts FFN (top-k routing)
  mla_moe      - DeepSeek-V2: multi-head latent attention + shared+routed MoE
  mamba1       - attention-free selective-SSM stack (Falcon-Mamba)
  mamba2       - attention-free SSD stack (Mamba2 blocks, no shared attn)
  hybrid       - Mamba2 backbone with shared attention blocks (Zamba2)
  encdec       - encoder-decoder with cross attention (Seamless-M4T)
  vlm          - decoder-only with M-RoPE + patch-embedding input (Qwen2-VL)
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Kind = Literal["dense", "moe", "mla_moe", "mamba1", "mamba2", "hybrid",
               "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    kind: Kind
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    n_kv_heads: int | None = None          # GQA; None => MHA
    head_dim: int | None = None            # None => d_model // n_heads
    qkv_bias: bool = False
    gated_mlp: bool = True                 # SwiGLU; False => 2-matrix GELU FFN
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0                   # per-expert hidden dim
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25
    # --- MLA (DeepSeek-V2) ---
    kv_lora_rank: int = 0                  # latent KV compression dim
    q_lora_rank: int = 0
    rope_head_dim: int = 64                # decoupled RoPE key dim
    # --- SSM (Mamba1/Mamba2) ---
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2
    ssm_headdim: int = 64                  # mamba2 head dim
    ssm_ngroups: int = 1
    # --- hybrid (Zamba2) ---
    attn_every: int = 6                    # shared attn block period
    # --- encdec ---
    n_encoder_layers: int = 0
    # --- vlm ---
    mrope: bool = False
    mrope_sections: tuple[int, int, int] = (16, 24, 24)  # t/h/w split of head_dim/2
    # --- numerics ---
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    # --- distribution hints ---
    fsdp: bool = False                     # shard params over data axis too
    remat: bool = True                     # activation checkpoint per layer
    # --- perf levers (EXPERIMENTS.md §Perf) ---
    sequence_parallel: bool = False        # shard residual stream seq over TP
    moe_expert_axis: str = "model"         # "model" (EP=TP) | "data" (EP=DP)
    moe_impl: str = "spmd"                 # "spmd" | "shard_map" (explicit EP)
    tp_collectives: str = "auto"           # "auto" | "explicit" (bf16 wires)
    # row-parallel reduction: "psum" (all-reduce, lowest wire — training) or
    # "gather" (all-gather in/out, bit-identical to the unsharded dot — the
    # serving engine's parity-safe mode; see distributed.tp)
    tp_reduce: str = "psum"
    # interleaved column chunks per row-parallel projection: chunk c's
    # collective overlaps chunk c+1's GEMM (double-buffered SUMMA pipelining)
    tp_overlap_chunks: int = 1
    # serving-prefill SSM scan block; 0 => ssm.SERVE_CHUNK (8). Wider grains
    # (32/64) recover long-prompt prefill throughput; chunk_tokens must stay
    # a multiple (bit-parity contract — see ssm.SERVE_CHUNK)
    ssm_serve_grain: int = 0
    kv_cache_dtype: str = "bfloat16"       # "float8_e4m3fn" halves cache bytes

    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads if self.n_kv_heads is not None else self.n_heads

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def attention_free(self) -> bool:
        return self.kind in ("mamba1", "mamba2")

    @property
    def sub_quadratic(self) -> bool:
        return self.kind in ("mamba1", "mamba2", "hybrid")

    def n_params(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        emb = V * d * (1 if self.tie_embeddings else 2)
        hd, H, KV = self.hd, self.n_heads, self.kv_heads
        if self.kind == "mamba1":
            di, ds = self.d_inner, self.ssm_state
            per = (d * 2 * di          # in_proj
                   + di * self.d_conv  # conv
                   + di * (2 * ds + 2) # x_proj(B,C,dt) approx + dt_proj
                   + di * ds + di      # A, D
                   + di * d)           # out_proj
            return emb + L * per + d
        if self.kind == "mamba2":
            di, ds = self.d_inner, self.ssm_state
            H = di // max(self.ssm_headdim, 1)
            conv_ch = di + 2 * self.ssm_ngroups * ds
            per = (d * (2 * di + 2 * self.ssm_ngroups * ds + H)  # in_proj
                   + conv_ch * (self.d_conv + 1)                 # conv w+b
                   + 3 * H + di                                  # A/D/dt/norm
                   + di * d)                                     # out_proj
            return emb + L * per + d
        attn = d * (H * hd) + d * (KV * hd) * 2 + (H * hd) * d
        if self.kind == "mla_moe":
            attn = (d * self.kv_lora_rank + d * self.rope_head_dim
                    + self.kv_lora_rank * (H * hd) * 2
                    + (d * (H * hd) if not self.q_lora_rank else
                       d * self.q_lora_rank + self.q_lora_rank * H * (hd + self.rope_head_dim))
                    + (H * hd) * d)
        mlp_dense = (3 if self.gated_mlp else 2) * d * self.d_ff
        per = attn + mlp_dense
        if self.kind in ("moe", "mla_moe"):
            moe = 3 * d * self.d_ff_expert * (self.n_experts + self.n_shared_experts)
            per = attn + moe + d * self.n_experts  # + router
        if self.kind == "hybrid":
            di, ds = self.d_inner, self.ssm_state
            mamba = (d * 2 * di + di * self.d_conv + di // self.ssm_headdim * 3
                     + 2 * self.ssm_ngroups * ds * di // 1 + di * d)
            shared_attn = attn + mlp_dense  # counted once (shared)
            return emb + L * mamba + shared_attn + d
        if self.kind == "encdec":
            enc = self.n_encoder_layers * (attn + mlp_dense)
            dec = L * (attn * 2 + mlp_dense)  # self + cross
            return emb + enc + dec + d
        return emb + L * per + d

    def n_active_params(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if self.kind not in ("moe", "mla_moe"):
            return self.n_params()
        full = self.n_params()
        all_experts = 3 * self.d_model * self.d_ff_expert * self.n_experts * self.n_layers
        active_experts = 3 * self.d_model * self.d_ff_expert * self.top_k * self.n_layers
        return full - all_experts + active_experts


def kv_cache_bytes(cfg: ModelConfig, tokens: int,
                   dtype_bytes: int = 2) -> int:
    """Bytes of KV (or MLA latent) cache for `tokens` cached positions,
    summed over layers.

    The HBM-pricing primitive behind the paged-KV energy model: a paged
    attention step gathers (and re-reads) exactly this many bytes for the
    tokens it touches, so `ops.serving_gemm_fleet` charges the gather as
    `extra_hbm_bytes` in `energy.gemm_fleet_energy`. Attention-free
    families cache O(1) state per row, not per token — 0 here; hybrid
    counts only its shared attention blocks.
    """
    if cfg.attention_free:
        return 0
    L = cfg.n_layers
    if cfg.kind == "hybrid":
        L = max(cfg.n_layers // max(cfg.attn_every, 1), 1)
    if cfg.kind == "mla_moe" and cfg.kv_lora_rank:
        per_tok = cfg.kv_lora_rank + cfg.rope_head_dim
    else:
        per_tok = 2 * cfg.kv_heads * cfg.hd
    return int(tokens) * per_tok * L * int(dtype_bytes)


def gemm_shape_counts(cfg: ModelConfig, n_tokens: int,
                      head_tokens: int | None = None,
                      kv_rows: int | None = None,
                      tp: int = 1,
                      src_tokens: int | None = None
                      ) -> dict[tuple[int, int, int], float]:
    """Dominant (m, n, k) GEMMs of one forward pass over `n_tokens` rows,
    with per-step multiplicities — the denominator the serving engine's
    energy attribution needs (one decode step issues each projection once
    per layer, K and V separately, but the LM head only once).

    `head_tokens` sizes the LM-head GEMM's rows separately: training
    unembeds every position (default, = n_tokens), but a serving prefill
    unembeds only each row's last position, so the engine passes its row
    count (see `lm_prefill`).

    `kv_rows` sizes MLA's per-step K/V decompression (`w_uk`/`w_uv` run
    over the *whole* latent cache, B * cache_len rows, every serving step
    — see `moe.mla_apply`); default = n_tokens, the no-cache training
    case where the cache is the sequence itself.

    `src_tokens` sizes encdec's prefill-once admission fleet: the encoder
    stack plus every decoder layer's cross-KV projection run over the
    source rows exactly once per request (`encdec_admit`), so the engine
    prices admission with ``n_tokens=0, src_tokens=T`` and steady-state
    steps with ``src_tokens=0`` — the per-step cross-attention Q/O reads
    are always counted for encdec. Zero-row GEMMs are dropped.

    Counts are an analytical estimate: MoE expert GEMMs are counted
    ``top_k + n_shared_experts`` times per layer at full `n_tokens` rows
    (capacity effects ignored), and hybrid attention blocks are amortized
    over their `attn_every` period.

    `tp > 1` returns the *per-shard* fleet of a tensor-parallel engine:
    column-parallel projections shrink their out-features to N/tp,
    gather-mode row-parallel projections keep the full contraction K but
    emit N/tp columns (the (M, N/tp, K) extents the autotuner must tune —
    per-shard shapes land on different throughput cliffs than the global
    ones), and EP-sharded routed-expert fleets divide their issue counts.
    Extents that `tp` does not divide stay whole (that dim falls back to
    replicated compute, matching `tp_column`/`tp_row`).
    """
    t = int(n_tokens)
    tp = max(int(tp), 1)

    def shard(n: int) -> int:
        return n // tp if n % tp == 0 else n

    d, hd, kv = cfg.d_model, cfg.hd, cfg.kv_heads
    L = cfg.n_layers
    # mamba1/mamba2 are attention-free (no Q/K/V/O projections at all);
    # hybrid (Zamba2) runs one shared attention block every attn_every
    # layers, the backbone being SSM (no ops.matmul work beyond
    # projections)
    if cfg.kind in ("mamba1", "mamba2"):
        attn_layers = 0
    elif cfg.kind == "hybrid":
        attn_layers = max(L // max(cfg.attn_every, 1), 1)
    else:
        attn_layers = L
    counts: dict[tuple[int, int, int], float] = {}

    def add(shape: tuple[int, int, int], n: float) -> None:
        if shape[0] <= 0 or n <= 0:
            return
        counts[shape] = counts.get(shape, 0.0) + n

    src = int(src_tokens) if src_tokens is not None else 0
    if cfg.kind == "encdec":
        # decoder: self-attention Q/K/V/O over the step's rows plus the
        # cross-attention Q/O read of the admission-time cross-KV
        add((t, shard(cfg.n_heads * hd), d), 2 * L)   # self + cross Q
        add((t, shard(kv * hd), d), 2 * L)            # self K and V
        add((t, shard(d), cfg.n_heads * hd), 2 * L)   # self + cross O
        if src:
            # prefill-once admission: encoder stack + per-decoder-layer
            # cross-KV projection over the source rows
            eL = cfg.n_encoder_layers
            gm = 2 if cfg.gated_mlp else 1
            add((src, shard(cfg.n_heads * hd), d), eL)
            add((src, shard(kv * hd), d), 2 * eL)
            add((src, shard(d), cfg.n_heads * hd), eL)
            if cfg.d_ff:
                add((src, shard(cfg.d_ff), d), gm * eL)
                add((src, shard(d), cfg.d_ff), eL)
            add((src, shard(kv * hd), d), 2 * L)      # cross-KV projection
    elif cfg.kind == "mla_moe" and cfg.kv_lora_rank:
        # multi-head latent attention traces its own projection fleet
        # (moe.mla_apply), not the generic Q/K/V/O skeleton
        r, rq, pe = cfg.kv_lora_rank, cfg.q_lora_rank, cfg.rope_head_dim
        kvr = int(kv_rows) if kv_rows is not None else t
        if rq:
            add((t, rq, d), L)                       # w_dq (Q compress)
            add((t, shard(cfg.n_heads * (hd + pe)), rq), L)  # w_uq
        else:
            add((t, shard(cfg.n_heads * (hd + pe)), d), L)  # w_uq
        add((t, r, d), L)                            # w_dkv (KV compress)
        add((t, pe, d), L)                           # w_kpe (RoPE key)
        add((kvr, shard(cfg.n_heads * hd), r), 2 * L)  # w_uk / w_uv
        add((t, shard(d), cfg.n_heads * hd), L)      # output projection
    elif attn_layers:
        add((t, shard(cfg.n_heads * hd), d), attn_layers)  # Q projection
        add((t, shard(kv * hd), d), 2 * attn_layers)  # K and V projections
        add((t, shard(d), cfg.n_heads * hd), attn_layers)  # output proj
    add((int(head_tokens) if head_tokens is not None else t,
         shard(cfg.vocab), d), 1)                    # LM head
    ff = cfg.d_ff_expert if cfg.n_experts else cfg.d_ff
    if ff:
        ffn_layers = attn_layers if cfg.kind == "hybrid" else L
        gate_mult = 2 if cfg.gated_mlp else 1
        if cfg.n_experts:
            # routed experts are EP-sharded: each chip runs E/tp experts'
            # GEMMs, so the per-chip issue count divides (extents whole)
            ep = tp if cfg.n_experts % tp == 0 else 1
            add((t, ff, d), gate_mult * cfg.top_k * ffn_layers / ep)
            add((t, d, ff), cfg.top_k * ffn_layers / ep)
            if cfg.n_shared_experts:
                add((t, shard(ff), d),
                    gate_mult * cfg.n_shared_experts * ffn_layers)
                add((t, shard(d), ff), cfg.n_shared_experts * ffn_layers)
        else:
            add((t, shard(ff), d), gate_mult * ffn_layers)  # up (and gate)
            add((t, shard(d), ff), ffn_layers)       # down projection
    if cfg.kind == "mamba1":
        add((t, shard(2 * cfg.d_inner), d), L)       # SSM in_proj
        add((t, shard(d), cfg.d_inner), L)           # SSM out_proj
    elif cfg.kind in ("mamba2", "hybrid"):
        # mamba2/SSD in_proj also carries B/C state projections and the
        # per-head dt channel (see ssm.mamba2_block_init)
        di = cfg.d_inner
        n_in = (2 * di + 2 * cfg.ssm_ngroups * cfg.ssm_state
                + di // max(cfg.ssm_headdim, 1))
        add((t, shard(n_in), d), L)                  # SSD in_proj
        add((t, shard(d), di), L)                    # SSD out_proj
    return counts


def collective_wire_bytes(cfg: ModelConfig, n_tokens: int, tp: int,
                          head_tokens: int | None = None,
                          src_tokens: int | None = None
                          ) -> tuple[float, float]:
    """Per-chip ring traffic of one tensor-parallel forward pass.

    Returns ``(wire_bytes, n_collectives)``: the bytes one chip pushes onto
    its links per step and the number of logical collective phases issued —
    the inputs `hwsim.collective_cost` prices against
    `ChipSpec.link_bw_gbs`. Counts the gather-mode serving collectives
    (`cfg.tp_reduce == "gather"`): every row-parallel projection all-gathers
    its sharded input and its chunked output (2 phases), EP-sharded routed
    experts all-gather their combine, and the column-sharded LM head
    gathers logits. A ring all-gather moves ``(tp-1)/tp`` of the full array
    through each chip.
    """
    tp = max(int(tp), 1)
    if tp <= 1:
        return 0.0, 0.0
    from repro.core.chips import DTYPE_BYTES, canon_dtype
    t = int(n_tokens)
    ht = int(head_tokens) if head_tokens is not None else t
    d, hd = cfg.d_model, cfg.hd
    L = cfg.n_layers
    if cfg.kind in ("mamba1", "mamba2"):
        attn_layers = 0
    elif cfg.kind == "hybrid":
        attn_layers = max(L // max(cfg.attn_every, 1), 1)
    else:
        attn_layers = L
    bpe = float(DTYPE_BYTES.get(canon_dtype(cfg.activation_dtype), 2))
    ring = (tp - 1) / tp
    elems = 0.0
    phases = 0.0
    src = int(src_tokens) if src_tokens is not None else 0
    if attn_layers:
        # attention output projection: gather (t, H*hd) in, (t, d) out
        elems += attn_layers * t * (cfg.n_heads * hd + d)
        phases += 2 * attn_layers
    if cfg.kind == "encdec":
        # one more gather pair per decoder layer for the cross-attention
        # output projection, plus the admission-time encoder stack
        elems += L * t * (cfg.n_heads * hd + d)
        phases += 2 * L
        if src:
            eL = cfg.n_encoder_layers
            elems += eL * src * (cfg.n_heads * hd + d)
            phases += 2 * eL
            if cfg.d_ff:
                elems += eL * src * (cfg.d_ff + d)
                phases += 2 * eL
    ff = cfg.d_ff_expert if cfg.n_experts else cfg.d_ff
    if ff:
        ffn_layers = attn_layers if cfg.kind == "hybrid" else L
        dense_calls = cfg.n_shared_experts if cfg.n_experts else 1
        if dense_calls:
            elems += ffn_layers * dense_calls * t * (ff + d)
            phases += 2 * ffn_layers * dense_calls
        if cfg.n_experts and cfg.n_experts % tp == 0:
            # EP combine: gather each token's routed-expert outputs
            elems += ffn_layers * t * cfg.top_k * d
            phases += ffn_layers
    if cfg.kind == "mamba1":
        # out_proj gather in/out + the x_proj input re-replication
        elems += L * t * (2 * cfg.d_inner + d)
        phases += 3 * L
    elif cfg.kind in ("mamba2", "hybrid"):
        elems += L * t * (cfg.d_inner + d)
        phases += 2 * L
    elems += ht * cfg.vocab                      # sharded logits gather
    phases += 1
    return elems * bpe * ring, phases


def gemm_shapes(cfg: ModelConfig, n_tokens: int) -> list[tuple[int, int, int]]:
    """The dominant (m, n, k) GEMMs one forward pass issues over `n_tokens`
    rows — the shape fleet `kernels.ops.warm_gemm_cache` pre-tunes so the
    first jit trace of a model never pays per-shape autotuning.

    Shapes follow `ops.matmul`'s convention (m rows, n out-features, k
    in-features). This is the projection/FFN/head skeleton per family
    (attention projections omitted for attention-free mamba1); SSM scans
    and conv mixers don't go through `ops.matmul`. Multiplicity-aware
    variant: `gemm_shape_counts`.
    """
    return sorted(gemm_shape_counts(cfg, n_tokens))
