"""Unified model configuration covering all ten assigned architectures.

One dataclass, many families. `kind` selects the forward function:
  dense        - standard decoder-only transformer (GQA, RoPE, opt. QKV bias)
  moe          - dense attention + mixture-of-experts FFN (top-k routing)
  mla_moe      - DeepSeek-V2: multi-head latent attention + shared+routed MoE
  mamba1       - attention-free selective-SSM stack (Falcon-Mamba)
  hybrid       - Mamba2 backbone with shared attention blocks (Zamba2)
  encdec       - encoder-decoder with cross attention (Seamless-M4T)
  vlm          - decoder-only with M-RoPE + patch-embedding input (Qwen2-VL)
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Kind = Literal["dense", "moe", "mla_moe", "mamba1", "hybrid", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    kind: Kind
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    n_kv_heads: int | None = None          # GQA; None => MHA
    head_dim: int | None = None            # None => d_model // n_heads
    qkv_bias: bool = False
    gated_mlp: bool = True                 # SwiGLU; False => 2-matrix GELU FFN
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0                   # per-expert hidden dim
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25
    # --- MLA (DeepSeek-V2) ---
    kv_lora_rank: int = 0                  # latent KV compression dim
    q_lora_rank: int = 0
    rope_head_dim: int = 64                # decoupled RoPE key dim
    # --- SSM (Mamba1/Mamba2) ---
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2
    ssm_headdim: int = 64                  # mamba2 head dim
    ssm_ngroups: int = 1
    # --- hybrid (Zamba2) ---
    attn_every: int = 6                    # shared attn block period
    # --- encdec ---
    n_encoder_layers: int = 0
    # --- vlm ---
    mrope: bool = False
    mrope_sections: tuple[int, int, int] = (16, 24, 24)  # t/h/w split of head_dim/2
    # --- numerics ---
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    # --- distribution hints ---
    fsdp: bool = False                     # shard params over data axis too
    remat: bool = True                     # activation checkpoint per layer
    # --- perf levers (EXPERIMENTS.md §Perf) ---
    sequence_parallel: bool = False        # shard residual stream seq over TP
    moe_expert_axis: str = "model"         # "model" (EP=TP) | "data" (EP=DP)
    moe_impl: str = "spmd"                 # "spmd" | "shard_map" (explicit EP)
    tp_collectives: str = "auto"           # "auto" | "explicit" (bf16 wires)
    kv_cache_dtype: str = "bfloat16"       # "float8_e4m3fn" halves cache bytes

    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads if self.n_kv_heads is not None else self.n_heads

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def attention_free(self) -> bool:
        return self.kind == "mamba1"

    @property
    def sub_quadratic(self) -> bool:
        return self.kind in ("mamba1", "hybrid")

    def n_params(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        emb = V * d * (1 if self.tie_embeddings else 2)
        hd, H, KV = self.hd, self.n_heads, self.kv_heads
        if self.kind == "mamba1":
            di, ds = self.d_inner, self.ssm_state
            per = (d * 2 * di          # in_proj
                   + di * self.d_conv  # conv
                   + di * (2 * ds + 2) # x_proj(B,C,dt) approx + dt_proj
                   + di * ds + di      # A, D
                   + di * d)           # out_proj
            return emb + L * per + d
        attn = d * (H * hd) + d * (KV * hd) * 2 + (H * hd) * d
        if self.kind == "mla_moe":
            attn = (d * self.kv_lora_rank + d * self.rope_head_dim
                    + self.kv_lora_rank * (H * hd) * 2
                    + (d * (H * hd) if not self.q_lora_rank else
                       d * self.q_lora_rank + self.q_lora_rank * H * (hd + self.rope_head_dim))
                    + (H * hd) * d)
        mlp_dense = (3 if self.gated_mlp else 2) * d * self.d_ff
        per = attn + mlp_dense
        if self.kind in ("moe", "mla_moe"):
            moe = 3 * d * self.d_ff_expert * (self.n_experts + self.n_shared_experts)
            per = attn + moe + d * self.n_experts  # + router
        if self.kind == "hybrid":
            di, ds = self.d_inner, self.ssm_state
            mamba = (d * 2 * di + di * self.d_conv + di // self.ssm_headdim * 3
                     + 2 * self.ssm_ngroups * ds * di // 1 + di * d)
            shared_attn = attn + mlp_dense  # counted once (shared)
            return emb + L * mamba + shared_attn + d
        if self.kind == "encdec":
            enc = self.n_encoder_layers * (attn + mlp_dense)
            dec = L * (attn * 2 + mlp_dense)  # self + cross
            return emb + enc + dec + d
        return emb + L * per + d

    def n_active_params(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if self.kind not in ("moe", "mla_moe"):
            return self.n_params()
        full = self.n_params()
        all_experts = 3 * self.d_model * self.d_ff_expert * self.n_experts * self.n_layers
        active_experts = 3 * self.d_model * self.d_ff_expert * self.top_k * self.n_layers
        return full - all_experts + active_experts


def gemm_shapes(cfg: ModelConfig, n_tokens: int) -> list[tuple[int, int, int]]:
    """The dominant (m, n, k) GEMMs one forward pass issues over `n_tokens`
    rows — the shape fleet `kernels.ops.warm_gemm_cache` pre-tunes so the
    first jit trace of a model never pays per-shape autotuning.

    Shapes follow `ops.matmul`'s convention (m rows, n out-features, k
    in-features). This is the projection/FFN/head skeleton shared by every
    family; SSM scans and conv mixers don't go through `ops.matmul`.
    """
    t = int(n_tokens)
    d, hd, kv = cfg.d_model, cfg.hd, cfg.kv_heads
    shapes = {
        (t, cfg.n_heads * hd, d),      # Q projection
        (t, kv * hd, d),               # K/V projections
        (t, d, cfg.n_heads * hd),      # output projection
        (t, cfg.vocab, d),             # LM head
    }
    ff = cfg.d_ff_expert if cfg.n_experts else cfg.d_ff
    if ff:
        shapes.add((t, ff, d))         # up (and gate) projection
        shapes.add((t, d, ff))         # down projection
    if cfg.kind in ("mamba1", "hybrid"):
        shapes.add((t, 2 * cfg.d_inner, d))
        shapes.add((t, d, cfg.d_inner))
    return sorted(shapes)
