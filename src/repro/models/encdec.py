"""Encoder-decoder transformer (Seamless-M4T medium backbone).

Per the task spec the modality frontend is a STUB: `src_embeds` arrive as
precomputed speech-frame embeddings (B, T_src, d_model). The text decoder is
a standard causal transformer with cross-attention into the encoder output.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_activation
from repro.kernels import ops
from repro.models import layers as L
from repro.models.config import ModelConfig

Params = dict[str, Any]


# ---------------- blocks ----------------

def enc_block_init(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model, cfg),
        "attn": L.attention_init(k1, cfg),
        "ln2": L.rmsnorm_init(cfg.d_model, cfg),
        "mlp": L.swiglu_init(k2, cfg),
    }


def enc_block_apply(p: Params, x: jax.Array, cfg: ModelConfig, *, positions):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    attn, _ = L.attention_apply(p["attn"], h, cfg, positions=positions,
                                causal=False)
    x = x + attn
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = x + L.swiglu_apply(p["mlp"], h)
    return shard_activation(x, "batch", None, None)


def dec_block_init(key, cfg: ModelConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model, cfg),
        "self_attn": L.attention_init(k1, cfg),
        "ln_x": L.rmsnorm_init(cfg.d_model, cfg),
        "cross_attn": L.attention_init(k2, cfg),
        "ln2": L.rmsnorm_init(cfg.d_model, cfg),
        "mlp": L.swiglu_init(k3, cfg),
    }


def _cross_kv(p: Params, memory: jax.Array, cfg: ModelConfig):
    B, T, _ = memory.shape
    KV, hd = cfg.kv_heads, cfg.hd
    k = ops.matmul(memory, p["wk"]).reshape(B, T, KV, hd)
    v = ops.matmul(memory, p["wv"]).reshape(B, T, KV, hd)
    if cfg.qkv_bias:
        k = k + p["bk"].reshape(KV, hd).astype(k.dtype)
        v = v + p["bv"].reshape(KV, hd).astype(v.dtype)
    return {"k": k, "v": v}


def _cross_attend(p: Params, x: jax.Array, ckv: dict, cfg: ModelConfig):
    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.hd
    q = ops.matmul(x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
    q = q.reshape(B, S, H, hd)
    out = L._sdpa(q, ckv["k"], ckv["v"], causal=False)
    return ops.matmul(out.reshape(B, S, H * hd), p["wo"])


def dec_block_apply(p: Params, x: jax.Array, cfg: ModelConfig, *, positions,
                    cross_kv: dict, cache: dict | None = None,
                    cache_index=None):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    attn, new_cache = L.attention_apply(
        p["self_attn"], h, cfg, positions=positions, kv_cache=cache,
        cache_index=cache_index)
    x = x + attn
    h = L.rmsnorm(p["ln_x"], x, cfg.norm_eps)
    x = x + _cross_attend(p["cross_attn"], h, cross_kv, cfg)
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = x + L.swiglu_apply(p["mlp"], h)
    return shard_activation(x, "batch", None, None), new_cache


# ---------------- model ----------------

def encdec_init(key, cfg: ModelConfig) -> Params:
    ke, kenc, kdec, kh = jax.random.split(key, 4)
    enc_keys = jax.random.split(kenc, cfg.n_encoder_layers)
    dec_keys = jax.random.split(kdec, cfg.n_layers)
    return {
        "embed": {"table": L.embed_init(ke, cfg.vocab, cfg.d_model, cfg)},
        "encoder": jax.vmap(lambda k: enc_block_init(k, cfg))(enc_keys),
        "enc_ln_f": L.rmsnorm_init(cfg.d_model, cfg),
        "decoder": jax.vmap(lambda k: dec_block_init(k, cfg))(dec_keys),
        "ln_f": L.rmsnorm_init(cfg.d_model, cfg),
        "head": {"w": L.dense_init(kh, cfg.d_model, cfg.vocab, cfg)},
    }


def encode(params: Params, src_embeds: jax.Array, cfg: ModelConfig):
    B, T, _ = src_embeds.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    x = shard_activation(src_embeds.astype(jnp.dtype(cfg.activation_dtype)),
                         "batch", None, None)

    def body(h, blk):
        return enc_block_apply(blk, h, cfg, positions=positions), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["encoder"])
    return L.rmsnorm(params["enc_ln_f"], x, cfg.norm_eps)


def _decode_stack(params: Params, x: jax.Array, memory: jax.Array | None,
                  cfg: ModelConfig, *, positions, cross_cache=None,
                  cache=None, cache_index=None):
    """If `memory` given, compute per-layer cross-KV on the fly (training);
    otherwise use precomputed `cross_cache` (decode)."""

    def body(h, xs):
        if cache is None:
            blk = xs
            ckv = _cross_kv(blk["cross_attn"], memory, cfg)
            h, _ = dec_block_apply(blk, h, cfg, positions=positions,
                                   cross_kv=ckv)
            return h, None
        blk, ckv, layer_cache = xs
        h, new_cache = dec_block_apply(blk, h, cfg, positions=positions,
                                       cross_kv=ckv, cache=layer_cache,
                                       cache_index=cache_index)
        return h, new_cache

    body_fn = jax.checkpoint(body) if cfg.remat else body
    if cache is None:
        x, _ = jax.lax.scan(body_fn, x, params["decoder"])
        return x, None
    x, new_cache = jax.lax.scan(body_fn, x,
                                (params["decoder"], cross_cache, cache))
    return x, new_cache


def encdec_loss(params: Params, batch: dict, cfg: ModelConfig):
    """batch: src_embeds (B,T,d), tokens (B,S), labels (B,S)."""
    memory = encode(params, batch["src_embeds"], cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = params["embed"]["table"][tokens].astype(
        jnp.dtype(cfg.activation_dtype))
    x, _ = _decode_stack(params, x, memory, cfg, positions=positions)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = ops.matmul(x, params["head"]["w"], out_dtype=jnp.float32)
    loss, metrics = L.cross_entropy(logits, batch["labels"],
                                    batch.get("loss_mask"))
    metrics["loss"] = loss
    return loss, metrics


def encdec_prefill(params: Params, batch: dict, cfg: ModelConfig,
                   max_len: int | None = None):
    """Encode source + prefill decoder self-attn cache; precompute cross-KV."""
    memory = encode(params, batch["src_embeds"], cfg)
    # per-layer cross KV, stacked (L, B, T, KV, hd)
    cross = jax.vmap(
        lambda blk: _cross_kv(blk["cross_attn"], memory, cfg)
    )(params["decoder"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    max_len = max_len or S
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    cache = batch.get("cache")
    if cache is None:
        cache = {
            "k": jnp.zeros((cfg.n_layers, B, max_len, cfg.kv_heads, cfg.hd),
                           jnp.bfloat16),
            "v": jnp.zeros((cfg.n_layers, B, max_len, cfg.kv_heads, cfg.hd),
                           jnp.bfloat16),
        }
    x = params["embed"]["table"][tokens].astype(
        jnp.dtype(cfg.activation_dtype))
    x, cache = _decode_stack(params, x, None, cfg, positions=positions,
                             cross_cache=cross, cache=cache,
                             cache_index=jnp.int32(0))
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = ops.matmul(x[:, -1:], params["head"]["w"], out_dtype=jnp.float32)
    return logits[:, 0], {"kv": cache, "cross": cross, "index": jnp.int32(S)}


def encdec_decode_step(params: Params, token: jax.Array, state: dict,
                       cfg: ModelConfig):
    B = token.shape[0]
    idx = state["index"]
    positions = jnp.broadcast_to(idx, (B, 1)).astype(jnp.int32)
    x = params["embed"]["table"][token[:, None]].astype(
        jnp.dtype(cfg.activation_dtype))
    x, cache = _decode_stack(params, x, None, cfg, positions=positions,
                             cross_cache=state["cross"], cache=state["kv"],
                             cache_index=idx)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = ops.matmul(x, params["head"]["w"], out_dtype=jnp.float32)
    return logits[:, 0], {"kv": cache, "cross": state["cross"],
                          "index": idx + 1}
