"""Encoder-decoder transformer (Seamless-M4T medium backbone).

Per the task spec the modality frontend is a STUB: `src_embeds` arrive as
precomputed speech-frame embeddings (B, T_src, d_model). The text decoder is
a standard causal transformer with cross-attention into the encoder output.

Serving follows the prefill-once contract: the encoder and every decoder
layer's cross-attention KV run ONCE at admission (`encdec_admit`) and land
in the decode state next to the self-attention cache — `xk`/`xv` leaves of
`max_len` source-row capacity, carried through chunk/decode calls unchanged
like MLA's latent cache. Decoder self-attention then chunks through the
standard right-pad / per-row-`index` path via the `transformer`
lm generics, with a per-row `src_len` masking cross-attention keys to each
row's true source length (non-causal attention is not right-pad-safe by
construction — see `layers.attention_apply`).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import shard_activation
from repro.kernels import ops
from repro.models import layers as L
from repro.models import transformer as tfm
from repro.models.config import ModelConfig

Params = dict[str, Any]


# ---------------- blocks ----------------

def enc_block_init(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model, cfg),
        "attn": L.attention_init(k1, cfg),
        "ln2": L.rmsnorm_init(cfg.d_model, cfg),
        "mlp": L.swiglu_init(k2, cfg),
    }


def enc_block_apply(p: Params, x: jax.Array, cfg: ModelConfig, *, positions,
                    src_lens: jax.Array | None = None):
    """Bidirectional encoder block. `src_lens` masks self-attention keys to
    each row's valid source rows (required whenever the batch is
    right-padded — encoder attention is non-causal, so pad keys would
    otherwise take softmax weight)."""
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    attn, _ = L.attention_apply(p["attn"], h, cfg, positions=positions,
                                causal=False, kv_lens=src_lens)
    x = x + attn
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = x + L.swiglu_apply(p["mlp"], h)
    return shard_activation(x, "batch", None, None)


def dec_block_init(key, cfg: ModelConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model, cfg),
        "self_attn": L.attention_init(k1, cfg),
        "ln_x": L.rmsnorm_init(cfg.d_model, cfg),
        "cross_attn": L.attention_init(k2, cfg),
        "ln2": L.rmsnorm_init(cfg.d_model, cfg),
        "mlp": L.swiglu_init(k3, cfg),
    }


def _cross_kv(p: Params, memory: jax.Array, cfg: ModelConfig):
    from repro.distributed.tp import tp_column

    B, T, _ = memory.shape
    KV, hd = cfg.kv_heads, cfg.hd
    k = tp_column(memory, p["wk"], cfg)
    v = tp_column(memory, p["wv"], cfg)
    if cfg.qkv_bias:
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    return {"k": k.reshape(B, T, KV, hd), "v": v.reshape(B, T, KV, hd)}


def _cross_attend(p: Params, x: jax.Array, ckv: dict, cfg: ModelConfig, *,
                  kv_len: jax.Array | None = None):
    """Cross-attention over a precomputed (possibly right-padded) memory
    KV; `kv_len` masks each row's keys to its true source length. Runs
    through the tp_column/tp_row wrappers so gather-mode TP keeps the
    bit-identical-to-tp=1 contract the serving engine stands on."""
    from repro.distributed.tp import tp_column, tp_row

    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.hd
    q = tp_column(x, p["wq"], cfg)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
    q = q.reshape(B, S, H, hd)
    k, v = ckv["k"], ckv["v"]
    if k.dtype != q.dtype:       # bf16 decode-state storage converts at read
        k, v = k.astype(q.dtype), v.astype(q.dtype)
    out = L._sdpa(q, k, v, causal=False, kv_len=kv_len)
    return tp_row(out.reshape(B, S, H * hd), p["wo"], cfg)


def dec_block_apply(p: Params, x: jax.Array, cfg: ModelConfig, *, positions,
                    cross_kv: dict, cache: dict | None = None,
                    cache_index=None, src_lens: jax.Array | None = None):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    attn, new_cache = L.attention_apply(
        p["self_attn"], h, cfg, positions=positions, kv_cache=cache,
        cache_index=cache_index)
    x = x + attn
    h = L.rmsnorm(p["ln_x"], x, cfg.norm_eps)
    x = x + _cross_attend(p["cross_attn"], h, cross_kv, cfg, kv_len=src_lens)
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = x + L.swiglu_apply(p["mlp"], h)
    return shard_activation(x, "batch", None, None), new_cache


def dec_serve_block(p: Params, x: jax.Array, cfg: ModelConfig, *, positions,
                    cache: dict | None = None, cache_index=None,
                    seq_lens=None, src_len: jax.Array | None = None):
    """Serving decoder block over the fused decode cache: self-attention
    KV (dense ``k``/``v`` or paged ``k_pages``/``v_pages``/``table``) plus
    the admission-time cross-attention KV (``xk``/``xv``, read-only, masked
    to ``src_len``). Signature matches the `transformer` generics' block
    contract; `src_len` is closed over per call."""
    sa = {k: v for k, v in cache.items() if k not in ("xk", "xv")}
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    attn, new_sa = L.attention_apply(
        p["self_attn"], h, cfg, positions=positions, kv_cache=sa,
        cache_index=cache_index, seq_lens=seq_lens)
    x = x + attn
    h = L.rmsnorm(p["ln_x"], x, cfg.norm_eps)
    x = x + _cross_attend(p["cross_attn"], h,
                          {"k": cache["xk"], "v": cache["xv"]}, cfg,
                          kv_len=src_len)
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = x + L.swiglu_apply(p["mlp"], h)
    new_cache = {**new_sa, "xk": cache["xk"], "xv": cache["xv"]}
    return (shard_activation(x, "batch", None, None), new_cache,
            jnp.zeros((), jnp.float32))


# ---------------- model ----------------

def encdec_init(key, cfg: ModelConfig) -> Params:
    ke, kenc, kdec, kh = jax.random.split(key, 4)
    enc_keys = jax.random.split(kenc, cfg.n_encoder_layers)
    dec_keys = jax.random.split(kdec, cfg.n_layers)
    return {
        "embed": {"table": L.embed_init(ke, cfg.vocab, cfg.d_model, cfg)},
        "encoder": jax.vmap(lambda k: enc_block_init(k, cfg))(enc_keys),
        "enc_ln_f": L.rmsnorm_init(cfg.d_model, cfg),
        "decoder": jax.vmap(lambda k: dec_block_init(k, cfg))(dec_keys),
        "ln_f": L.rmsnorm_init(cfg.d_model, cfg),
        "head": {"w": L.dense_init(kh, cfg.d_model, cfg.vocab, cfg)},
    }


def _dec_view(params: Params) -> Params:
    """Decoder-only params view in the layout the `transformer` lm
    generics expect (embed / blocks / ln_f / head)."""
    return {"embed": params["embed"], "blocks": params["decoder"],
            "ln_f": params["ln_f"], "head": params["head"]}


def encode(params: Params, src_embeds: jax.Array, cfg: ModelConfig,
           src_lens: jax.Array | None = None):
    B, T, _ = src_embeds.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    x = shard_activation(src_embeds.astype(jnp.dtype(cfg.activation_dtype)),
                         "batch", None, None)

    def body(h, blk):
        return enc_block_apply(blk, h, cfg, positions=positions,
                               src_lens=src_lens), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["encoder"])
    return L.rmsnorm(params["enc_ln_f"], x, cfg.norm_eps)


def _decode_stack(params: Params, x: jax.Array, memory: jax.Array | None,
                  cfg: ModelConfig, *, positions, cross_cache=None,
                  cache=None, cache_index=None):
    """If `memory` given, compute per-layer cross-KV on the fly (training);
    otherwise use precomputed `cross_cache` (decode)."""

    def body(h, xs):
        if cache is None:
            blk = xs
            ckv = _cross_kv(blk["cross_attn"], memory, cfg)
            h, _ = dec_block_apply(blk, h, cfg, positions=positions,
                                   cross_kv=ckv)
            return h, None
        blk, ckv, layer_cache = xs
        h, new_cache = dec_block_apply(blk, h, cfg, positions=positions,
                                       cross_kv=ckv, cache=layer_cache,
                                       cache_index=cache_index)
        return h, new_cache

    body_fn = jax.checkpoint(body) if cfg.remat else body
    if cache is None:
        x, _ = jax.lax.scan(body_fn, x, params["decoder"])
        return x, None
    x, new_cache = jax.lax.scan(body_fn, x,
                                (params["decoder"], cross_cache, cache))
    return x, new_cache


def encdec_loss(params: Params, batch: dict, cfg: ModelConfig):
    """batch: src_embeds (B,T,d), tokens (B,S), labels (B,S)."""
    memory = encode(params, batch["src_embeds"], cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = params["embed"]["table"][tokens].astype(
        jnp.dtype(cfg.activation_dtype))
    x, _ = _decode_stack(params, x, memory, cfg, positions=positions)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = ops.matmul(x, params["head"]["w"], out_dtype=jnp.float32)
    loss, metrics = L.cross_entropy(logits, batch["labels"],
                                    batch.get("loss_mask"))
    metrics["loss"] = loss
    return loss, metrics


# ---------------- serving (prefill-once admission + chunked decode) -------

def encdec_init_state(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> dict:
    """Zeroed decode state: self-attention KV plus cross-attention KV
    (``xk``/``xv``, `max_len` source-row capacity — the source shares the
    row's length budget) and a per-row ``src_len``/``index``."""
    kv = tfm.init_kv_cache(cfg, batch, max_len, dtype)
    shape = (cfg.n_layers, batch, max_len, cfg.kv_heads, cfg.hd)
    kv["xk"] = jnp.zeros(shape, dtype)
    kv["xv"] = jnp.zeros(shape, dtype)
    return {"kv": kv,
            "src_len": jnp.zeros((batch,), jnp.int32),
            "index": jnp.zeros((batch,), jnp.int32)}


def encdec_admit_dims(cfg: ModelConfig, extras: dict | None
                      ) -> tuple[int, int]:
    """(cache-prefix rows, source rows) one request's admission consumes.
    The encoder writes no decoder-cache rows (prefix 0); the source length
    sizes the cross-KV leaves and the admission GEMM fleet."""
    if not extras or "src_embeds" not in extras:
        raise ValueError(
            "encdec requests need extras={'src_embeds': (T_src, d_model)}")
    return 0, int(np.asarray(extras["src_embeds"]).shape[0])


def encdec_pack_admit(cfg: ModelConfig, extras_list: list, width: int,
                      bucket: int) -> dict:
    """Host-side admission batch: source embeddings right-padded to the
    shared `bucket`, rows padded to `width` (pad rows are all-zero with
    src_len 0 — fully masked downstream)."""
    src = np.zeros((width, bucket, cfg.d_model), np.float32)
    sl = np.zeros((width,), np.int32)
    for i, ex in enumerate(extras_list):
        if not ex:
            continue
        e = np.asarray(ex["src_embeds"], np.float32)
        src[i, :e.shape[0]] = e
        sl[i] = e.shape[0]
    return {"src_embeds": jnp.asarray(src), "src_len": jnp.asarray(sl)}


def encdec_admit(params: Params, packed: dict, state: dict,
                 cfg: ModelConfig) -> dict:
    """Prefill-once admission: encode the (padded) source and write every
    decoder layer's cross-attention KV into the decode state. Touches only
    the ``xk``/``xv``/``src_len`` leaves — the self-attention cache (dense
    or paged) threads through untouched."""
    src_len = jnp.asarray(packed["src_len"], jnp.int32)
    memory = encode(params, packed["src_embeds"], cfg, src_lens=src_len)
    cross = jax.vmap(
        lambda blk: _cross_kv(blk["cross_attn"], memory, cfg)
    )(params["decoder"])
    kv = dict(state["kv"])
    T = memory.shape[1]
    kv["xk"] = kv["xk"].at[:, :, :T].set(cross["k"].astype(kv["xk"].dtype))
    kv["xv"] = kv["xv"].at[:, :, :T].set(cross["v"].astype(kv["xv"].dtype))
    return {**state, "kv": kv, "src_len": src_len}


def encdec_prefill_chunk(params: Params, tokens: jax.Array,
                         lengths: jax.Array, state: dict, cfg: ModelConfig
                         ) -> tuple[jax.Array, dict]:
    """One admission-prefill chunk of the *decoder* (standard right-pad /
    per-row-`index` contract via `transformer.lm_prefill_chunk`); the
    cross-KV computed at admission rides along read-only."""
    src_len = jnp.asarray(state["src_len"], jnp.int32)
    block = functools.partial(dec_serve_block, src_len=src_len)
    logits, st = tfm.lm_prefill_chunk(
        _dec_view(params), tokens, lengths,
        {"kv": state["kv"], "index": state["index"]}, cfg, block)
    return logits, {**st, "src_len": src_len}


def encdec_decode_step(params: Params, token: jax.Array, state: dict,
                       cfg: ModelConfig):
    src_len = jnp.asarray(state["src_len"], jnp.int32)
    block = functools.partial(dec_serve_block, src_len=src_len)
    logits, st = tfm.lm_decode_step(
        _dec_view(params), token,
        {"kv": state["kv"], "index": state["index"]}, cfg, block)
    return logits, {**st, "src_len": src_len}


def encdec_prefill(params: Params, batch: dict, cfg: ModelConfig,
                   max_len: int | None = None):
    """Single-shot prefill: admission (encode + cross-KV) plus one decoder
    chunk over the whole prompt. Same code path as the serving engine's
    chunked admission, so the returned state layout (and every bit of the
    cache) matches a chunked prefill of the same rows."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    max_len = max_len or S
    src = batch["src_embeds"]
    src_lens = batch.get("src_lens")
    if src_lens is None:
        src_lens = jnp.full((B,), src.shape[1], jnp.int32)
    state = encdec_init_state(cfg, B, max_len)
    state = encdec_admit(
        params, {"src_embeds": src, "src_len": src_lens}, state, cfg)
    lengths = batch.get("lengths")
    if lengths is None:
        lengths = jnp.full((B,), S, jnp.int32)
    return encdec_prefill_chunk(params, tokens, lengths, state, cfg)
