"""Zamba2-style hybrid: Mamba2 backbone + shared attention blocks.

`n_layers` Mamba2 blocks in groups of `attn_every`; after each group one
*shared* attention+MLP block runs — a single parameter set reused at every
application (Zamba2's parameter-efficiency trick). Each application still has
its own KV cache (states differ even though weights are shared).

Layout: mamba blocks stacked (n_groups, attn_every, ...) and driven by a
nested scan; the shared block's KV caches are stacked (n_groups, ...).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import ssm
from repro.models.config import ModelConfig
from repro.models.transformer import dense_block_apply, dense_block_init

Params = dict[str, Any]


def n_groups(cfg: ModelConfig) -> int:
    assert cfg.n_layers % cfg.attn_every == 0
    return cfg.n_layers // cfg.attn_every


def hybrid_init(key, cfg: ModelConfig) -> Params:
    ke, km, ka, kh = jax.random.split(key, 4)
    layer_keys = jax.random.split(km, cfg.n_layers)
    blocks = jax.vmap(lambda k: ssm.mamba2_block_init(k, cfg))(layer_keys)
    # reshape to (groups, attn_every, ...)
    g, e = n_groups(cfg), cfg.attn_every
    blocks = jax.tree.map(lambda x: x.reshape(g, e, *x.shape[1:]), blocks)
    params: Params = {
        "embed": {"table": L.embed_init(ke, cfg.vocab, cfg.d_model, cfg)},
        "blocks": blocks,
        "shared_attn": dense_block_init(ka, cfg),
        "ln_f": L.rmsnorm_init(cfg.d_model, cfg),
    }
    if not cfg.tie_embeddings:
        params["head"] = {"w": L.dense_init(kh, cfg.d_model, cfg.vocab, cfg)}
    return params


def _hybrid_backbone(params: Params, x: jax.Array, cfg: ModelConfig, *,
                     positions, cache: dict | None = None, cache_index=None,
                     seq_lens=None):
    """cache: {"mamba": leaves (G, E, B, ...), "attn": {"k","v"} (G, B, ...)}"""
    shared = params["shared_attn"]

    def group_body(carry, inp):
        h = carry
        if cache is None:
            mamba_grp = inp
            def inner(hh, blk):
                hh, _, _ = ssm.mamba2_block_apply(blk, hh, cfg,
                                                  positions=positions)
                return hh, None
            h, _ = jax.lax.scan(inner, h, mamba_grp)
            h, _, _ = dense_block_apply(shared, h, cfg, positions=positions)
            return h, None
        mamba_grp, mamba_cache_grp, attn_cache = inp
        def inner(hh, xs):
            blk, c = xs
            hh, nc, _ = ssm.mamba2_block_apply(blk, hh, cfg,
                                               positions=positions, cache=c,
                                               cache_index=cache_index,
                                               seq_lens=seq_lens)
            return hh, nc
        h, new_mamba = jax.lax.scan(inner, h, (mamba_grp, mamba_cache_grp))
        h, new_attn, _ = dense_block_apply(shared, h, cfg, positions=positions,
                                           cache=attn_cache,
                                           cache_index=cache_index,
                                           seq_lens=seq_lens)
        return h, (new_mamba, new_attn)

    if cfg.remat:
        group_body = jax.checkpoint(group_body)
    if cache is None:
        x, _ = jax.lax.scan(group_body, x, params["blocks"])
        return x, None
    x, (new_mamba, new_attn) = jax.lax.scan(
        group_body, x, (params["blocks"], cache["mamba"], cache["attn"]))
    return x, {"mamba": new_mamba, "attn": new_attn}


def init_hybrid_cache(cfg: ModelConfig, batch: int, max_len: int,
                      kv_dtype=jnp.bfloat16) -> dict:
    g, e = n_groups(cfg), cfg.attn_every
    mamba = ssm.init_mamba2_cache(cfg, batch, n_layers=cfg.n_layers)
    mamba = jax.tree.map(lambda x: x.reshape(g, e, *x.shape[1:]), mamba)
    attn = {
        "k": jnp.zeros((g, batch, max_len, cfg.kv_heads, cfg.hd), kv_dtype),
        "v": jnp.zeros((g, batch, max_len, cfg.kv_heads, cfg.hd), kv_dtype),
    }
    return {"mamba": mamba, "attn": attn}


def hybrid_loss(params: Params, batch: dict, cfg: ModelConfig):
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = params["embed"]["table"][tokens].astype(
        jnp.dtype(cfg.activation_dtype))
    x, _ = _hybrid_backbone(params, x, cfg, positions=positions)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        from repro.kernels import ops
        logits = ops.matmul(x, params["embed"]["table"], transpose_b=True,
                            out_dtype=jnp.float32)
    else:
        from repro.kernels import ops
        logits = ops.matmul(x, params["head"]["w"], out_dtype=jnp.float32)
    loss, metrics = L.cross_entropy(logits, batch["labels"],
                                    batch.get("loss_mask"))
    metrics["loss"] = loss
    return loss, metrics


def hybrid_prefill(params: Params, batch: dict, cfg: ModelConfig,
                   max_len: int | None = None):
    """Serving prefill. ``batch["lengths"]`` selects the right-padded
    contract (`transformer.lm_prefill`): per-row last-logit gather and a
    per-row ``index``, with `seq_lens` threaded into the SSD blocks so
    conv/scan state stops exactly at each row's last valid token — pad
    rows are bit-invisible even for the recurrent state."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    lengths = batch.get("lengths")
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    cache = batch.get("cache") or init_hybrid_cache(cfg, B, max_len or S)
    x = params["embed"]["table"][tokens].astype(
        jnp.dtype(cfg.activation_dtype))
    lens32 = (None if lengths is None
              else jnp.asarray(lengths, jnp.int32))
    x, cache = _hybrid_backbone(params, x, cfg, positions=positions,
                                cache=cache, cache_index=jnp.int32(0),
                                seq_lens=lens32)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    from repro.kernels import ops
    if lens32 is None:
        logits = ops.matmul(x[:, -1:], params["head"]["w"],
                            out_dtype=jnp.float32)
        return logits[:, 0], {"cache": cache, "index": jnp.int32(S)}
    last = jnp.take_along_axis(
        x, jnp.broadcast_to((lens32 - 1)[:, None, None],
                            (B, 1, x.shape[-1])), axis=1)
    logits = ops.matmul(last, params["head"]["w"], out_dtype=jnp.float32)
    return logits[:, 0], {"cache": cache, "index": lens32}


def hybrid_prefill_chunk(params: Params, tokens: jax.Array,
                         lengths: jax.Array, state: dict, cfg: ModelConfig):
    """One admission-prefill chunk (see `transformer.lm_prefill_chunk`):
    per-row base offsets in ``state["index"]``, right-padded rows, SSD
    state carried across chunk boundaries bit-exactly."""
    B, S = tokens.shape
    base = jnp.asarray(state["index"], jnp.int32)
    lens32 = jnp.asarray(lengths, jnp.int32)
    positions = base[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    x = params["embed"]["table"][tokens].astype(
        jnp.dtype(cfg.activation_dtype))
    x, cache = _hybrid_backbone(params, x, cfg, positions=positions,
                                cache=state["cache"], cache_index=base,
                                seq_lens=lens32)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    from repro.kernels import ops
    last = jnp.take_along_axis(
        x, jnp.broadcast_to(jnp.maximum(lens32 - 1, 0)[:, None, None],
                            (B, 1, x.shape[-1])), axis=1)
    logits = ops.matmul(last, params["head"]["w"], out_dtype=jnp.float32)
    return logits[:, 0], {"cache": cache, "index": base + lens32}


def hybrid_decode_step(params: Params, token: jax.Array, state: dict,
                       cfg: ModelConfig):
    """One-token decode; ``index`` is a scalar (wave) or (B,) (continuous
    — each slot at its own position; see `transformer.lm_decode_step`)."""
    B = token.shape[0]
    idx = state["index"]
    if jnp.ndim(idx) == 0:
        positions = jnp.broadcast_to(idx, (B, 1)).astype(jnp.int32)
    else:
        positions = jnp.asarray(idx)[:, None].astype(jnp.int32)
    x = params["embed"]["table"][token[:, None]].astype(
        jnp.dtype(cfg.activation_dtype))
    x, cache = _hybrid_backbone(params, x, cfg, positions=positions,
                                cache=state["cache"], cache_index=idx)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    from repro.kernels import ops
    logits = ops.matmul(x, params["head"]["w"], out_dtype=jnp.float32)
    return logits[:, 0], {"cache": cache, "index": idx + 1}
