"""Shared neural building blocks (pure JAX, bf16 activations, fp32 math).

Every matmul routes through `repro.kernels.ops.matmul`, so the paper's
predictor-tuned Pallas GEMM is the compute path on TPU and XLA dot elsewhere.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.config import ModelConfig

Params = dict[str, Any]


def _dt(config: ModelConfig):
    return jnp.dtype(config.param_dtype)


def dense_init(key, d_in: int, d_out: int, config: ModelConfig,
               scale: float | None = None) -> jax.Array:
    """Init a (d_in, d_out) weight matrix (default 1/sqrt(d_in) scale)."""
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(
        _dt(config))


def embed_init(key, vocab: int, d: int, config: ModelConfig) -> jax.Array:
    """Init a (vocab, d) embedding table (N(0, 0.02))."""
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(
        _dt(config))


# ---------------- norms ----------------

def rmsnorm_init(d: int, config: ModelConfig) -> Params:
    """RMSNorm params: a unit scale vector."""
    return {"scale": jnp.ones((d,), _dt(config))}


def rmsnorm(p: Params, x: jax.Array, eps: float) -> jax.Array:
    """RMS-normalize in f32, apply the learned scale, cast back."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------- rotary embeddings ----------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Rotary base frequencies for a head dim (theta^(-2i/hd))."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: tuple[int, int, int]) -> jax.Array:
    """Qwen2-VL multimodal RoPE. positions: (B, S, 3) = (t, h, w) ids.

    The hd/2 frequency channels are partitioned into (t, h, w) sections;
    each section rotates by its own position stream.
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    sec = jnp.concatenate([
        jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)
    ])  # (hd/2,) in {0,1,2}
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),                   # (B, S, 3)
        jnp.broadcast_to(sec[None, None, :], positions.shape[:2] + sec.shape),
        axis=-1,
    )                                                    # (B, S, hd/2)
    ang = pos * freqs
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------- attention ----------------

def attention_init(key, config: ModelConfig, d_model: int | None = None
                   ) -> Params:
    """Init q/k/v/o projections for (possibly grouped-query) attention."""
    d = d_model or config.d_model
    hd, H, KV = config.hd, config.n_heads, config.kv_heads
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(ks[0], d, H * hd, config),
        "wk": dense_init(ks[1], d, KV * hd, config),
        "wv": dense_init(ks[2], d, KV * hd, config),
        "wo": dense_init(ks[3], H * hd, d, config, scale=1.0 / math.sqrt(H * hd)),
    }
    if config.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), _dt(config))
        p["bk"] = jnp.zeros((KV * hd,), _dt(config))
        p["bv"] = jnp.zeros((KV * hd,), _dt(config))
    return p


Q_CHUNK = 1024  # query-block size for memory-bounded exact attention


def attention_mask(Sq: int, Sk: int, *, causal: bool,
                   q_offset: jax.Array | int = 0,
                   kv_len: jax.Array | None = None) -> jax.Array | None:
    """(Bm, Sq, Sk) boolean mask (Bm broadcasts over batch).

    `q_offset` and `kv_len` may be scalars (whole-batch, the wave-serving
    contract) or (B,) arrays (per-row, the continuous-batching contract
    where every decode slot sits at its own sequence position).
    """
    mask = None
    if causal:
        off = jnp.asarray(q_offset)
        off = off[:, None, None] if off.ndim else off[None, None, None]
        qpos = jnp.arange(Sq)[None, :, None] + off       # (Bm, Sq, 1)
        mask = jnp.arange(Sk)[None, None, :] <= qpos     # (Bm, Sq, Sk)
    if kv_len is not None:
        kl = jnp.asarray(kv_len)
        kl = kl[:, None, None] if kl.ndim else kl[None, None, None]
        valid = jnp.arange(Sk)[None, None, :] < kl       # (Bm, 1, Sk)
        mask = valid if mask is None else (mask & valid)
    return mask


def _sdpa_block(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
                q_offset: jax.Array | int = 0,
                kv_len: jax.Array | None = None,
                kv_valid: jax.Array | None = None) -> jax.Array:
    """One query block. q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd).

    Matmuls stay in the input dtype (bf16 on TPU -> MXU) with fp32
    accumulation; softmax in fp32. `kv_valid` (B, Sk) ANDs an extra
    key-validity mask in — the paged-KV page-table mask.
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    rep = H // KV
    qg = q.reshape(B, Sq, KV, rep, hd)
    scores = jnp.einsum("bqgrh,bkgh->bgrqk", qg, k,
                        preferred_element_type=jnp.float32) / math.sqrt(hd)
    mask = attention_mask(Sq, Sk, causal=causal, q_offset=q_offset,
                          kv_len=kv_len)
    if kv_valid is not None:
        kvm = kv_valid[:, None, :]                       # (B, 1, Sk)
        mask = kvm if mask is None else (mask & kvm)
    if mask is not None:
        scores = jnp.where(mask[:, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrqk,bkgh->bqgrh", w.astype(q.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
          q_offset: jax.Array | int = 0,
          kv_len: jax.Array | None = None,
          kv_valid: jax.Array | None = None) -> jax.Array:
    """Exact attention, query-chunked so peak score memory is
    O(Q_CHUNK x Sk) instead of O(Sq x Sk) — required for the 32k/500k cells.
    """
    B, Sq, H, hd = q.shape
    if Sq <= Q_CHUNK or Sq % Q_CHUNK != 0:
        return _sdpa_block(q, k, v, causal=causal, q_offset=q_offset,
                           kv_len=kv_len, kv_valid=kv_valid)
    nb = Sq // Q_CHUNK
    qb = q.reshape(B, nb, Q_CHUNK, H, hd).swapaxes(0, 1)  # (nb, B, qc, H, hd)

    def body(_, xs):
        blk, i = xs
        off = q_offset + i * Q_CHUNK
        o = _sdpa_block(blk, k, v, causal=causal, q_offset=off, kv_len=kv_len,
                        kv_valid=kv_valid)
        return None, o

    _, outs = jax.lax.scan(body, None, (qb, jnp.arange(nb)))
    return outs.swapaxes(0, 1).reshape(B, Sq, H, hd)


def cache_update(cache: jax.Array, update: jax.Array,
                 index: jax.Array,
                 update_lens: jax.Array | None = None) -> jax.Array:
    """Write `update` (B, S, ...) into `cache` (B, L, ...) at sequence
    position `index` — scalar (all rows at one position) or (B,) (each row
    at its own position; the continuous-batching decode contract).

    `update_lens` (B,), with a per-row `index`, limits each row's write to
    its first `update_lens[b]` update rows — the chunked-prefill contract.
    This matters beyond tidiness: `dynamic_update_slice` *clamps*
    out-of-range starts instead of failing, so an unmasked bucket-padded
    write whose junk tail crosses the cache end would silently shift the
    whole window back over valid earlier keys. The masked write merges
    only valid rows (valid data always fits: index + update_lens <= L),
    so pad junk can never land in — or displace — the cache.

    Literal 0s must match index's dtype: under JAX_ENABLE_X64 they'd
    otherwise promote to int64 next to an int32 index, which
    dynamic_update_slice rejects.
    """
    index = jnp.asarray(index)
    zero = jnp.zeros((), dtype=index.dtype)
    if index.ndim == 0:
        starts = (zero, index) + (zero,) * (cache.ndim - 2)
        return jax.lax.dynamic_update_slice(cache, update, starts)

    if update_lens is None:
        def row(c, u, i):
            starts = (i,) + (zero,) * (c.ndim - 1)
            return jax.lax.dynamic_update_slice(c, u, starts)

        return jax.vmap(row)(cache, update, index)

    L, C = cache.shape[1], update.shape[1]

    def row_masked(c, u, i, n):
        # window start clamped exactly like dynamic_update_slice would;
        # `shift` realigns update rows to their true positions inside it
        start = jnp.clip(i, zero, jnp.asarray(max(L - C, 0), index.dtype))
        shift = i - start
        pos = jnp.arange(C, dtype=index.dtype)
        window = jax.lax.dynamic_slice(
            c, (start,) + (zero,) * (c.ndim - 1),
            (C,) + c.shape[1:])
        shifted = jnp.roll(u.astype(c.dtype), shift, axis=0)
        mask = (pos >= shift) & (pos < shift + n)
        merged = jnp.where(mask.reshape((C,) + (1,) * (c.ndim - 1)),
                           shifted, window)
        return jax.lax.dynamic_update_slice(
            c, merged, (start,) + (zero,) * (c.ndim - 1))

    return jax.vmap(row_masked)(cache, update, index,
                                jnp.asarray(update_lens, index.dtype))


# ---------------- paged KV cache ----------------
#
# The paged layout replaces each row's dense (max_len, ...) cache with a
# shared pool of fixed-size pages, (P, T, ...) per layer, plus a per-row
# page table (B, n) of physical page ids mapping logical page slot j to
# pool page table[b, j]. Page 0 is the reserved null page (see
# `repro.serving.paging.NULL_PAGE`): rows point unreserved slots — and
# dead/padded rows their whole table — at it, writes through it are
# dropped, and reads from it are masked by `page_valid_mask`. Attention
# gathers each row's pages back into a dense (B, n*T, ...) view per layer,
# so with n*T == max_len the post-mask score tensor is bit-identical to
# the dense path (junk behind the mask is replaced wholly by -1e30 either
# way) — the engine's paged-vs-dense parity contract rests on this.


def paged_gather(pages: jax.Array, table: jax.Array) -> jax.Array:
    """Gather a dense per-row view from the page pool.

    pages: (P, T, ...) one layer's pool; table: (B, n) int32 physical page
    ids. Returns (B, n*T, ...) — row b's logical positions in order. The
    view is a transient (one layer at a time under the block scan); the
    resident footprint stays the pool's.
    """
    B, n = table.shape
    T = pages.shape[1]
    out = jnp.take(pages, table, axis=0)                 # (B, n, T, ...)
    return out.reshape((B, n * T) + pages.shape[2:])


def paged_cache_update(pages: jax.Array, update: jax.Array,
                       table: jax.Array, index: jax.Array,
                       update_lens: jax.Array | None = None) -> jax.Array:
    """Scatter `update` (B, S, ...) into the page pool at each row's
    logical positions ``index[b] .. index[b]+S`` (table-translated).

    The paged counterpart of `cache_update`: `index` is scalar or (B,),
    `update_lens` (B,) limits each row's write to its valid tokens.
    Invalid positions — beyond `update_lens`, past the table, or mapping
    to the null page (dead rows) — are routed out of bounds and dropped,
    so a shared page can never be corrupted by pad junk or dead slots.
    Live rows write only pages they own exclusively (the allocator's
    copy-on-write contract), hence no scatter collisions.
    """
    B, S = update.shape[:2]
    P, T = pages.shape[:2]
    n = table.shape[1]
    index = jnp.asarray(index)
    if index.ndim == 0:
        index = jnp.broadcast_to(index, (B,))
    pos = index[:, None] + jnp.arange(S, dtype=index.dtype)[None, :]
    slot = pos // T                                      # logical page slot
    phys = jnp.take_along_axis(table, jnp.clip(slot, 0, n - 1), axis=1)
    flat = phys.astype(index.dtype) * T + pos % T
    valid = (slot < n) & (phys != 0)
    if update_lens is not None:
        lens = jnp.asarray(update_lens, index.dtype)
        valid = valid & (jnp.arange(S, dtype=index.dtype)[None, :]
                         < lens[:, None])
    flat = jnp.where(valid, flat, P * T)                 # OOB -> dropped
    flat_pool = pages.reshape((P * T,) + pages.shape[2:])
    upd = update.reshape((B * S,) + update.shape[2:]).astype(pages.dtype)
    new = flat_pool.at[flat.reshape(B * S)].set(upd, mode="drop")
    return new.reshape(pages.shape)


def page_valid_mask(table: jax.Array, Sk: int) -> jax.Array:
    """(B, Sk) bool — True where a gathered view position maps to a real
    (non-null) page. Sk must equal n*T for the (B, n) table."""
    B, n = table.shape
    T = Sk // n
    return jnp.repeat(table != 0, T, axis=1)


def paged_attention_mask(Sq: int, Sk: int, table: jax.Array, *,
                         causal: bool, q_offset: jax.Array | int = 0,
                         kv_len: jax.Array | None = None) -> jax.Array:
    """`attention_mask` AND page-table validity — the paged-KV mask.

    Where every in-range logical position has a real page (the allocator
    reserves full capacity up front), this equals the dense mask on all
    unmasked positions, which is what makes paged attention bit-identical
    to dense.
    """
    mask = attention_mask(Sq, Sk, causal=causal, q_offset=q_offset,
                          kv_len=kv_len)
    pv = page_valid_mask(table, Sk)[:, None, :]          # (B, 1, Sk)
    return pv if mask is None else (mask & pv)


def copy_pool_pages(pool: dict, src: jax.Array, dst: jax.Array) -> dict:
    """Copy pool pages src[i] -> dst[i] on every leaf (and every layer).

    The device half of the allocator's copy-on-write fork and partial-page
    snapshot: leaves are (L, P, T, ...), src/dst are (C,) int32. Padding
    entries with src == dst == 0 is a harmless null-page self-copy (the
    engine pads copy batches to a bucketed size to bound jit variants).
    """

    return {k: (v if k == "table" else v.at[:, dst].set(v[:, src]))
            for k, v in pool.items()}


def attention_apply(
    p: Params,
    x: jax.Array,
    config: ModelConfig,
    *,
    positions: jax.Array,
    causal: bool = True,
    kv_cache: dict | None = None,
    cache_index: jax.Array | None = None,
    seq_lens: jax.Array | None = None,    # per-row valid rows of a chunk
    xa: jax.Array | None = None,          # cross-attention memory
    kv_lens: jax.Array | None = None,     # per-row valid KEY rows (non-causal)
) -> tuple[jax.Array, dict | None]:
    """Standard (GQA) attention with optional KV cache and cross-attention.

    `seq_lens` (with a per-row `cache_index`) masks the KV write to each
    row's valid tokens — the chunked-prefill junk-free write contract
    (see `cache_update`).

    `kv_lens` masks the *keys* of the non-cached and cached-cross paths to
    each row's valid rows. Causal self-attention hides right-pad keys for
    free (pad keys sit at positions > every valid query); non-causal
    attention — encoder self-attention, cross-attention over a padded
    memory — does not, so right-padded batches must pass `kv_lens` or the
    zero-pad keys take softmax weight."""
    B, S, d = x.shape
    H, KV, hd = config.n_heads, config.kv_heads, config.hd
    from repro.distributed.tp import tp_column, tp_row

    src = xa if xa is not None else x
    q = tp_column(x, p["wq"], config)
    k = tp_column(src, p["wk"], config)
    v = tp_column(src, p["wv"], config)
    if config.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, src.shape[1], KV, hd)
    v = v.reshape(B, src.shape[1], KV, hd)
    if xa is None:  # self-attention gets RoPE
        if config.mrope:
            q = apply_mrope(q, positions, config.rope_theta,
                            config.mrope_sections)
            k = apply_mrope(k, positions, config.rope_theta,
                            config.mrope_sections)
        else:
            q = apply_rope(q, positions, config.rope_theta)
            k = apply_rope(k, positions, config.rope_theta)

    new_cache = None
    if kv_cache is not None and xa is None and "k_pages" in kv_cache:
        # paged decode/chunk: scatter new k/v through the page table, then
        # gather the dense per-row view back for exact attention. Same
        # contracts as the dense branch (scalar/per-row cache_index,
        # seq_lens-masked chunk writes); bit-identical outputs when the
        # table spans max_len (see the paged-KV section above).
        table = kv_cache["table"]
        ck = paged_cache_update(kv_cache["k_pages"],
                                k.astype(kv_cache["k_pages"].dtype),
                                table, cache_index, update_lens=seq_lens)
        cv = paged_cache_update(kv_cache["v_pages"],
                                v.astype(kv_cache["v_pages"].dtype),
                                table, cache_index, update_lens=seq_lens)
        new_cache = {"k_pages": ck, "v_pages": cv, "table": table}
        ck_d = paged_gather(ck, table)
        cv_d = paged_gather(cv, table)
        ck_c = ck_d if ck_d.dtype == q.dtype else ck_d.astype(q.dtype)
        cv_c = cv_d if cv_d.dtype == q.dtype else cv_d.astype(q.dtype)
        out = _sdpa(q, ck_c, cv_c, causal=True, q_offset=cache_index,
                    kv_len=cache_index + S,
                    kv_valid=page_valid_mask(table, ck_d.shape[1]))
    elif kv_cache is not None and xa is None:
        # decode: write new k/v at cache_index, attend over the prefix.
        # cache_index is a scalar (whole batch at one position — wave
        # serving) or (B,) (per-slot positions — continuous batching).
        ck, cv = kv_cache["k"], kv_cache["v"]
        ck = cache_update(ck, k.astype(ck.dtype), cache_index,
                          update_lens=seq_lens)
        cv = cache_update(cv, v.astype(cv.dtype), cache_index,
                          update_lens=seq_lens)
        new_cache = {"k": ck, "v": cv}
        # quantized caches (e.g. fp8) convert at read; on TPU the convert
        # fuses into the attention loads
        ck_c = ck if ck.dtype == q.dtype else ck.astype(q.dtype)
        cv_c = cv if cv.dtype == q.dtype else cv.astype(q.dtype)
        out = _sdpa(q, ck_c, cv_c, causal=True, q_offset=cache_index,
                    kv_len=cache_index + S)
    elif kv_cache is not None:  # cached cross-attention (enc-dec decode)
        out = _sdpa(q, kv_cache["k"], kv_cache["v"], causal=False,
                    kv_len=kv_lens)
        new_cache = kv_cache
    else:
        out = _sdpa(q, k, v, causal=causal and xa is None, kv_len=kv_lens)
    y = tp_row(out.reshape(B, S, H * hd), p["wo"], config)
    return y, new_cache


# ---------------- MLPs ----------------

def swiglu_init(key, config: ModelConfig, d_ff: int | None = None,
                d_model: int | None = None) -> Params:
    """Gated (SwiGLU) or plain (GELU) FFN depending on config.gated_mlp."""
    d = d_model or config.d_model
    f = d_ff or config.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(k2, d, f, config),
        "w_down": dense_init(k3, f, d, config, scale=1.0 / math.sqrt(f)),
    }
    if config.gated_mlp:
        p["w_gate"] = dense_init(k1, d, f, config)
    return p


def swiglu_apply(p: Params, x: jax.Array,
                 config: ModelConfig | None = None) -> jax.Array:
    """SwiGLU / MLP forward (gated when `w_gate` is present); routes
    through explicit TP collectives when the config asks for them."""
    if config is not None and config.tp_collectives == "explicit":
        from repro.distributed.tp import tp_column, tp_row

        u = tp_column(x, p["w_up"], config)
        if "w_gate" in p:
            g = tp_column(x, p["w_gate"], config)
            h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        else:
            h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
        return tp_row(h, p["w_down"], config)
    u = ops.matmul(x, p["w_up"])
    if "w_gate" in p:
        g = ops.matmul(x, p["w_gate"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    return ops.matmul(h, p["w_down"])


# ---------------- decode-state slot surgery ----------------
#
# Continuous batching keeps one batched decode state of `max_batch` slots
# and retires/refills individual slots mid-decode. A freshly prefilled
# single-request state (batch 1) is spliced into slot `b` of the batched
# state with `dynamic_update_slice` along each leaf's batch axis. The batch
# axis differs per leaf (KV caches are (L, B, S, ...), per-row indices are
# (B,)), so it is discovered structurally: evaluate the state shape at two
# batch sizes and find the axis that scaled.


def state_batch_axes(tree_b1, tree_b2):
    """Per-leaf batch axis of a decode-state pytree.

    `tree_b1` / `tree_b2` are the same state (or its ShapeDtypeStructs, e.g.
    from `jax.eval_shape`) built at two different batch sizes. Returns a
    matching pytree of ints (batch axis per leaf; -1 for leaves whose shape
    does not depend on batch — None would read better but is an empty
    subtree to the pytree machinery). Raises if a leaf's shape differs
    along more than one axis.
    """

    def axis(a, b):
        diff = [i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y]
        if len(a.shape) != len(b.shape) or len(diff) > 1:
            raise ValueError(
                f"ambiguous batch axis: {a.shape} vs {b.shape}")
        return diff[0] if diff else -1

    return jax.tree.map(axis, tree_b1, tree_b2)


def take_slot_state(batch_state, axes, slot: jax.Array):
    """Extract slot `slot` of `batch_state` as a batch-1 state — the
    inverse of `insert_slot_state` (pure `dynamic_slice` along each leaf's
    batch axis; `slot` may be traced). The chunked-admission prefill uses
    it to move a finished admission row into its decode slot."""
    slot = jnp.asarray(slot)

    def take(big, ax):
        if ax < 0:
            return big
        zero = jnp.zeros((), dtype=slot.dtype)
        starts = tuple(slot if i == ax else zero for i in range(big.ndim))
        sizes = tuple(1 if i == ax else d for i, d in enumerate(big.shape))
        return jax.lax.dynamic_slice(big, starts, sizes)

    return jax.tree.map(take, batch_state, axes)


def insert_slot_state(batch_state, slot_state, axes, slot: jax.Array):
    """Splice a batch-1 `slot_state` into slot `slot` of `batch_state`.

    Pure function of its inputs (jit-friendly; `slot` may be traced). Leaves
    with `ax < 0` (batch-independent state) keep the batched value.
    """
    slot = jnp.asarray(slot)

    def insert(big, small, ax):
        if ax < 0:
            return big
        zero = jnp.zeros((), dtype=slot.dtype)
        starts = tuple(slot if i == ax else zero for i in range(big.ndim))
        return jax.lax.dynamic_update_slice(big, small.astype(big.dtype),
                                            starts)

    return jax.tree.map(insert, batch_state, slot_state, axes)


def state_structures_match(a, b) -> bool:
    """True when two decode-state pytrees (or their ShapeDtypeStructs)
    share treedef, per-leaf shapes, and dtypes — the structural gate for
    splicing a checkpointed batch-1 slot row into another engine's state
    (`ServingEngine.adopt`): a tp or family mismatch shows up here as a
    shape/treedef difference before any device op runs."""
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    if ta != tb or len(la) != len(lb):
        return False
    return all(tuple(x.shape) == tuple(y.shape)
               and jnp.dtype(x.dtype) == jnp.dtype(y.dtype)
               for x, y in zip(la, lb))


# ---------------- losses ----------------

def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None,
                  z_loss: float = 1e-4) -> tuple[jax.Array, dict]:
    """Token-level CE with optional z-loss, fp32 softmax."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    zl = z_loss * lse ** 2
    per_tok = nll + zl
    if mask is None:
        mask = jnp.ones_like(per_tok)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (per_tok * mask).sum() / denom
    acc = ((jnp.argmax(lf, -1) == labels).astype(jnp.float32) * mask).sum() / denom
    return loss, {"nll": (nll * mask).sum() / denom, "accuracy": acc}
