"""Mixture-of-experts blocks: OLMoE-style (top-8 of 64) and DeepSeek-V2
(MLA attention + 2 shared + 160 routed top-6 experts).

Routing is dense-dispatch (token x expert one-hot einsum) with a capacity
factor — the production-standard formulation that keeps shapes static for
XLA SPMD and shards cleanly: experts over the "model" axis (EP), tokens over
"data". An auxiliary load-balancing loss (Switch-style) is returned in
metrics and added to the train loss.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.distributed import tp
from repro.distributed.sharding import shard_activation
from repro.kernels import ops
from repro.models import layers as L
from repro.models.config import ModelConfig

Params = dict[str, Any]


# ---------------- experts ----------------

def experts_init(key, cfg: ModelConfig) -> Params:
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    k1, k2, k3 = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)

    def init(k, shape, scale):
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dt)

    return {
        "w_gate": init(k1, (E, d, f), s_in),
        "w_up": init(k2, (E, d, f), s_in),
        "w_down": init(k3, (E, f, d), s_out),
    }


def moe_ffn_init(key, cfg: ModelConfig) -> Params:
    kr, ke, ks = jax.random.split(key, 3)
    p: Params = {
        "router": {"w": L.dense_init(kr, cfg.d_model, cfg.n_experts, cfg)},
        "experts": experts_init(ke, cfg),
    }
    if cfg.n_shared_experts:
        p["shared_mlp"] = L.swiglu_init(
            ks, cfg, d_ff=cfg.d_ff_expert * cfg.n_shared_experts)
    return p


def moe_ffn_apply(p: Params, x: jax.Array, cfg: ModelConfig
                  ) -> tuple[jax.Array, jax.Array]:
    """Returns (out, aux_loss). x: (B, S, d).

    Sort/scatter dispatch: tokens are ranked within their (row, expert)
    group via a stable argsort (first-come-first-served, identical
    semantics to the textbook cumsum-one-hot dispatch) and scattered into
    a static (E, B*capacity, d) buffer. Capacity is PER ROW — derived from
    S, not the flattened T = B*S — so whether a row's tokens reach their
    experts never depends on which other rows share the batch: a request
    served alone and the same request served in a full continuous-batching
    wave take bit-identical expert paths, and decode steps (S=1, distinct
    top-k experts) can never drop a token. Memory is O(T*K*d) — no
    (T, E, C) dispatch tensor — which is what keeps the 1M-token x
    160-expert DeepSeek-V2 train step compilable. Under EP sharding
    (experts on "model") XLA lowers the scatter/gather to the expected
    all-to-all pattern.
    """
    B, S, d = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k
    xt = x.reshape(T, d)

    logits = ops.matmul(xt, p["router"]["w"], out_dtype=jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                     # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    capacity = max(1, int(cfg.capacity_factor * S * K / E))           # per row
    TK = T * K
    idx_flat = gate_idx.reshape(TK)                                   # expert id
    row_flat = jnp.arange(TK, dtype=jnp.int32) // (S * K)             # batch row
    grp = row_flat * E + idx_flat                                     # (row, e)
    order = jnp.argsort(grp, stable=True)
    sorted_grp = grp[order]
    group_start = jnp.searchsorted(sorted_grp, jnp.arange(B * E),
                                   side="left")                       # (B*E,)
    pos_sorted = jnp.arange(TK, dtype=jnp.int32) - group_start[sorted_grp]
    pos_flat = jnp.zeros((TK,), jnp.int32).at[order].set(
        pos_sorted.astype(jnp.int32))
    keep = pos_flat < capacity
    slot = row_flat * capacity + jnp.where(keep, pos_flat, 0)

    gate_flat = (gate_vals.reshape(TK) * keep.astype(gate_vals.dtype))
    x_rep = jnp.repeat(xt, K, axis=0)                                 # (TK, d)
    contrib = jnp.where(keep[:, None], x_rep.astype(jnp.float32), 0.0)
    xe = jnp.zeros((E, B * capacity, d), jnp.float32).at[
        idx_flat, slot].add(contrib)
    # NOTE: sharding the capacity dim over "batch" here looks like it should
    # data-parallelize the expert GEMM, but SPMD then lowers the token
    # scatter as a giant cross-shard exchange (measured 14x collective blowup
    # — EXPERIMENTS.md §Perf iteration log). The production layout is
    # expert_axis="data" (tokens all-to-all over data, stationary experts,
    # TP over d_ff), selected per-config via cfg.moe_expert_axis.
    xe = shard_activation(xe.astype(x.dtype), "expert", None, None)

    w = p["experts"]
    g = jnp.einsum("ecd,edf->ecf", xe, w["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, w["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    ye = jnp.einsum("ecf,efd->ecd", h, w["w_down"])                   # (E,C,d)
    ye = shard_activation(ye, "expert", None, None)

    y_tok = ye[idx_flat, slot].astype(jnp.float32)                    # (TK, d)
    y_tok = y_tok * gate_flat[:, None]
    out = y_tok.reshape(T, K, d).sum(axis=1).astype(x.dtype)

    if cfg.n_shared_experts:
        out = out + L.swiglu_apply(p["shared_mlp"], xt, cfg)

    # Switch-transformer load-balancing loss (density normalized by top-k so
    # the balanced floor is exactly router_aux_coef per layer)
    density = (jnp.zeros((E,), jnp.float32).at[idx_flat].add(1.0) / TK)
    router_prob = probs.mean(0)
    aux = cfg.router_aux_coef * E * jnp.sum(density * router_prob)
    return out.reshape(B, S, d), aux


# ---------------- explicit shard_map MoE (production EP path) ----------------


def _local_dispatch(xt, logits, cfg: ModelConfig, capacity: int,
                    n_rows: int):
    """Per-shard top-k dispatch into (E, n_rows*capacity, d) — same math
    as the SPMD path but over this shard's tokens only. `capacity` is per
    batch row (this shard holds ``n_rows`` rows of S = T/n_rows tokens),
    so expert admission is independent of batch composition."""
    T, d = xt.shape
    E, K = cfg.n_experts, cfg.top_k
    S = T // n_rows
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True),
                                        1e-9)
    TK = T * K
    idx_flat = gate_idx.reshape(TK)
    row_flat = jnp.arange(TK, dtype=jnp.int32) // (S * K)
    grp = row_flat * E + idx_flat
    order = jnp.argsort(grp, stable=True)
    sorted_grp = grp[order]
    group_start = jnp.searchsorted(sorted_grp, jnp.arange(n_rows * E),
                                   side="left")
    pos_sorted = jnp.arange(TK, dtype=jnp.int32) - group_start[sorted_grp]
    pos_flat = jnp.zeros((TK,), jnp.int32).at[order].set(
        pos_sorted.astype(jnp.int32))
    keep = pos_flat < capacity
    slot = row_flat * capacity + jnp.where(keep, pos_flat, 0)
    gate_flat = gate_vals.reshape(TK) * keep.astype(gate_vals.dtype)
    x_rep = jnp.repeat(xt, K, axis=0)
    contrib = jnp.where(keep[:, None], x_rep.astype(jnp.float32), 0.0)
    xe = jnp.zeros((E, n_rows * capacity, d), jnp.float32).at[
        idx_flat, slot].add(contrib)
    density = jnp.zeros((E,), jnp.float32).at[idx_flat].add(1.0) / TK
    aux = cfg.router_aux_coef * E * jnp.sum(density * probs.mean(0))
    return xe.astype(xt.dtype), idx_flat, slot, gate_flat, aux


def moe_ffn_shard_map(p: Params, x: jax.Array, cfg: ModelConfig
                      ) -> tuple[jax.Array, jax.Array]:
    """Explicit-collective MoE over mesh ("data", "model"):

      * tokens: sharded over "data", replicated over "model";
      * experts: sharded over "model" (EP); their d-dim fsdp shards are
        all-gathered over "data" *inside* (one small gather per layer:
        the E/tp factor already divided the weights);
      * each model shard computes only its experts' slots and contributes a
        partial per-token output; one psum over "model" combines.

    Backward collectives are the AD transposes of these — no SPMD-inferred
    full-buffer reductions (the baseline's dominant cost, §Perf).
    """
    from jax.experimental.shard_map import shard_map
    from repro.distributed.sharding import current_mesh

    mesh = current_mesh()
    d, E, K = cfg.d_model, cfg.n_experts, cfg.top_k
    w = p["experts"]
    fsdp_axis = "data" if cfg.fsdp else None

    B, S, _ = x.shape
    dp_ax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_data = 1
    for a in dp_ax:
        n_data *= mesh.shape[a]
    n_model = mesh.shape["model"]
    capacity = max(1, int(cfg.capacity_factor * S * K / E))  # per row
    E_loc = E // n_model

    def local_fn(x_loc, router_w, w_gate, w_up, w_down, shared):
        Bl, Sl, _ = x_loc.shape
        xt = x_loc.reshape(Bl * Sl, d)
        if fsdp_axis:
            router_w = jax.lax.all_gather(router_w, fsdp_axis, axis=0,
                                          tiled=True)
            w_gate = jax.lax.all_gather(w_gate, fsdp_axis, axis=1, tiled=True)
            w_up = jax.lax.all_gather(w_up, fsdp_axis, axis=1, tiled=True)
            w_down = jax.lax.all_gather(w_down, fsdp_axis, axis=2, tiled=True)
        logits = (xt.astype(jnp.float32) @ router_w.astype(jnp.float32))
        xe, idx_flat, slot, gate_flat, aux = _local_dispatch(
            xt, logits, cfg, capacity, Bl)
        # my expert block
        j = jax.lax.axis_index("model")
        xe_my = jax.lax.dynamic_slice_in_dim(xe, j * E_loc, E_loc, axis=0)
        g = jnp.einsum("ecd,edf->ecf", xe_my, w_gate)
        u = jnp.einsum("ecd,edf->ecf", xe_my, w_up)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(xt.dtype) * u
        ye_my = jnp.einsum("ecf,efd->ecd", h, w_down)          # (E_loc, C, d)
        # partial per-token combine: only slots routed to my experts
        rel = idx_flat - j * E_loc
        mine = (rel >= 0) & (rel < E_loc)
        rel_c = jnp.clip(rel, 0, E_loc - 1)
        y_tok = ye_my[rel_c, slot].astype(jnp.float32)
        y_tok = jnp.where(mine[:, None], y_tok, 0.0) * gate_flat[:, None]
        partial = y_tok.reshape(Bl * Sl, K, d).sum(axis=1)
        if cfg.n_shared_experts and shared is not None:
            sg, su, sd = shared
            if fsdp_axis:
                sg = jax.lax.all_gather(sg, fsdp_axis, axis=0, tiled=True)
                su = jax.lax.all_gather(su, fsdp_axis, axis=0, tiled=True)
                sd = jax.lax.all_gather(sd, fsdp_axis, axis=1, tiled=True)
            hh = jax.nn.silu((xt @ sg).astype(jnp.float32)).astype(
                xt.dtype) * (xt @ su)
            partial = partial + (hh @ sd).astype(jnp.float32)
        out = jax.lax.psum(partial.astype(jnp.float32), "model")
        for ax in dp_ax:
            aux = jax.lax.pmean(aux, ax)
        aux = jax.lax.pmean(aux, "model")  # identical; enforce replication
        return out.astype(x_loc.dtype).reshape(Bl, Sl, d), aux

    P_ = PartitionSpec
    fa = fsdp_axis
    shared_specs = (P_(fa, "model"), P_(fa, "model"), P_("model", fa))
    shared_args = None
    if cfg.n_shared_experts:
        sm = p["shared_mlp"]
        shared_args = (sm["w_gate"], sm["w_up"], sm["w_down"])
    batch_ax = dp_ax if len(dp_ax) > 1 else dp_ax[0]
    fn = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P_(batch_ax, None, None),    # x
                  P_(fa, None),                # router w (d, E)
                  P_("model", fa, None),       # experts w_gate (E, d, f)
                  P_("model", fa, None),       # experts w_up
                  P_("model", None, fa),       # experts w_down (E, f, d)
                  shared_specs if shared_args is not None else None),
        out_specs=(P_(batch_ax, None, None), P_()),
        check_rep=False,
    )
    return fn(x, p["router"]["w"], w["w_gate"], w["w_up"], w["w_down"],
              shared_args)


def moe_ffn(p: Params, x: jax.Array, cfg: ModelConfig):
    from repro.distributed.sharding import current_mesh

    mesh = current_mesh()
    # gather-mode serving must not take the shard_map path: its per-token
    # combine is a psum over "model", which re-associates the fp32 sum
    # (the SPMD dense-dispatch path keeps E-sharded experts bit-exact —
    # dispatch/combine are gathers/scatters, contractions stay local)
    if (cfg.moe_impl == "shard_map" and mesh is not None
            and getattr(cfg, "tp_reduce", "psum") != "gather"
            and {"data", "model"}.issubset(set(mesh.axis_names))):
        return moe_ffn_shard_map(p, x, cfg)
    return moe_ffn_apply(p, x, cfg)


# ---------------- OLMoE block: GQA attention + MoE FFN ----------------

def moe_block_init(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model, cfg),
        "attn": L.attention_init(k1, cfg),
        "ln2": L.rmsnorm_init(cfg.d_model, cfg),
        "moe": moe_ffn_init(k2, cfg),
    }


def moe_block_apply(p: Params, x: jax.Array, cfg: ModelConfig, *,
                    positions, cache=None, cache_index=None,
                    seq_lens=None):
    # seq_lens masks the chunked KV write to valid rows (clamp-proof
    # cache_update); MoE routing itself is per-token
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    attn_out, new_cache = L.attention_apply(
        p["attn"], h, cfg, positions=positions, kv_cache=cache,
        cache_index=cache_index, seq_lens=seq_lens)
    x = x + attn_out
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    moe_out, aux = moe_ffn(p["moe"], h, cfg)
    x = x + moe_out
    from repro.models.transformer import residual_spec
    x = shard_activation(x, *residual_spec(cfg, x))
    return x, new_cache, aux


# ---------------- DeepSeek-V2 MLA attention ----------------

def mla_init(key, cfg: ModelConfig) -> Params:
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    r, rq, pe = cfg.kv_lora_rank, cfg.q_lora_rank, cfg.rope_head_dim
    ks = jax.random.split(key, 8)
    p: Params = {
        "w_dkv": L.dense_init(ks[0], d, r, cfg),       # latent KV compress
        "w_kpe": L.dense_init(ks[1], d, pe, cfg),      # decoupled RoPE key
        "w_uk": L.dense_init(ks[2], r, H * hd, cfg),   # K decompress
        "w_uv": L.dense_init(ks[3], r, H * hd, cfg),   # V decompress
        "wo": L.dense_init(ks[4], H * hd, d, cfg,
                           scale=1.0 / math.sqrt(H * hd)),
    }
    if rq:
        p["w_dq"] = L.dense_init(ks[5], d, rq, cfg)
        p["w_uq"] = L.dense_init(ks[6], rq, H * (hd + pe), cfg)
    else:
        p["w_uq"] = L.dense_init(ks[7], d, H * (hd + pe), cfg)
    return p


def mla_apply(p: Params, x: jax.Array, cfg: ModelConfig, *, positions,
              kv_cache: dict | None = None, cache_index=None,
              seq_lens=None) -> tuple[jax.Array, dict | None]:
    """Multi-head latent attention. The cache stores the *latent* c_kv
    (rank r) and the shared RoPE key (rank pe) — the MLA memory win."""
    B, S, d = x.shape
    H, hd, pe = cfg.n_heads, cfg.hd, cfg.rope_head_dim

    if cfg.q_lora_rank:
        q = tp.tp_column(ops.matmul(x, p["w_dq"]), p["w_uq"], cfg)
    else:
        q = tp.tp_column(x, p["w_uq"], cfg)
    q = q.reshape(B, S, H, hd + pe)
    q_c, q_pe = q[..., :hd], q[..., hd:]
    q_pe = L.apply_rope(q_pe, positions, cfg.rope_theta)

    c_kv = ops.matmul(x, p["w_dkv"])                    # (B, S, r)
    k_pe = ops.matmul(x, p["w_kpe"]).reshape(B, S, 1, pe)
    k_pe = L.apply_rope(k_pe, positions, cfg.rope_theta)

    new_cache = None
    kv_valid = None
    if kv_cache is not None and "c_kv_pages" in kv_cache:
        # paged latent cache: scatter c_kv/k_pe through the page table,
        # gather the dense per-row view back (same bit-parity contract as
        # layers.attention_apply's paged branch)
        table = kv_cache["table"]
        cc = L.paged_cache_update(kv_cache["c_kv_pages"],
                                  c_kv.astype(kv_cache["c_kv_pages"].dtype),
                                  table, cache_index, update_lens=seq_lens)
        cp = L.paged_cache_update(kv_cache["k_pe_pages"],
                                  k_pe[:, :, 0].astype(
                                      kv_cache["k_pe_pages"].dtype),
                                  table, cache_index, update_lens=seq_lens)
        new_cache = {"c_kv_pages": cc, "k_pe_pages": cp, "table": table}
        c_kv_full = L.paged_gather(cc, table)
        k_pe_full = L.paged_gather(cp, table)[:, :, None]
        kv_valid = L.page_valid_mask(table, c_kv_full.shape[1])
        kv_len = cache_index + S
        q_offset = cache_index
    elif kv_cache is not None:
        # cache_index: scalar (wave serving) or (B,) per-slot positions
        # (continuous batching) — L.cache_update handles both
        cc = L.cache_update(kv_cache["c_kv"],
                            c_kv.astype(kv_cache["c_kv"].dtype), cache_index,
                            update_lens=seq_lens)
        cp = L.cache_update(kv_cache["k_pe"],
                            k_pe[:, :, 0].astype(kv_cache["k_pe"].dtype),
                            cache_index, update_lens=seq_lens)
        new_cache = {"c_kv": cc, "k_pe": cp}
        c_kv_full, k_pe_full = cc, cp[:, :, None]
        kv_len = cache_index + S
        q_offset = cache_index
    else:
        c_kv_full, k_pe_full = c_kv, k_pe
        kv_len = None
        q_offset = 0

    Sk = c_kv_full.shape[1]
    k_c = tp.tp_column(c_kv_full, p["w_uk"], cfg).reshape(B, Sk, H, hd)
    v = tp.tp_column(c_kv_full, p["w_uv"], cfg).reshape(B, Sk, H, hd)

    scale = 1.0 / math.sqrt(hd + pe)

    def attend_block(q_c_b, q_pe_b, off):
        """Query block attention (fp32 accum, bf16 matmul)."""
        Sq = q_c_b.shape[1]
        scores = jnp.einsum("bqhd,bkhd->bhqk", q_c_b, k_c,
                            preferred_element_type=jnp.float32)
        scores += jnp.einsum("bqhp,bkgp->bhqk", q_pe_b, k_pe_full,
                             preferred_element_type=jnp.float32)
        scores *= scale
        mask = L.attention_mask(Sq, Sk, causal=True, q_offset=off,
                                kv_len=kv_len)
        if kv_valid is not None:
            mask = mask & kv_valid[:, None, :]
        scores = jnp.where(mask[:, None], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", w.astype(x.dtype), v,
                          preferred_element_type=jnp.float32).astype(x.dtype)

    qc = L.Q_CHUNK
    if S <= qc or S % qc != 0:
        out = attend_block(q_c, q_pe, q_offset)
    else:
        nb = S // qc
        qcb = q_c.reshape(B, nb, qc, H, hd).swapaxes(0, 1)
        qpb = q_pe.reshape(B, nb, qc, H, pe).swapaxes(0, 1)

        def body(_, xs):
            cb, pb, i = xs
            return None, attend_block(cb, pb, q_offset + i * qc)

        _, outs = jax.lax.scan(body, None, (qcb, qpb, jnp.arange(nb)))
        out = outs.swapaxes(0, 1).reshape(B, nb * qc, H, hd)
    out = out.reshape(B, S, H * hd)
    return tp.tp_row(out, p["wo"], cfg), new_cache


def mla_moe_block_init(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model, cfg),
        "attn": mla_init(k1, cfg),
        "ln2": L.rmsnorm_init(cfg.d_model, cfg),
        "moe": moe_ffn_init(k2, cfg),
    }


def mla_moe_block_apply(p, x, cfg, *, positions, cache=None,
                        cache_index=None, seq_lens=None):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    attn_out, new_cache = mla_apply(p["attn"], h, cfg, positions=positions,
                                    kv_cache=cache, cache_index=cache_index,
                                    seq_lens=seq_lens)
    x = x + attn_out
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    moe_out, aux = moe_ffn(p["moe"], h, cfg)
    x = x + moe_out
    from repro.models.transformer import residual_spec
    x = shard_activation(x, *residual_spec(cfg, x))
    return x, new_cache, aux


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> dict:
    return {
        "c_kv": jnp.zeros((cfg.n_layers, batch, max_len, cfg.kv_lora_rank),
                          dtype),
        "k_pe": jnp.zeros((cfg.n_layers, batch, max_len, cfg.rope_head_dim),
                          dtype),
    }


def init_mla_page_pool(cfg: ModelConfig, num_pages: int, page_size: int,
                       dtype=jnp.bfloat16) -> dict:
    """Paged MLA latent pool (the rank-r/pe analogue of
    `transformer.init_kv_page_pool`; page 0 reserved as the null page)."""
    return {
        "c_kv_pages": jnp.zeros(
            (cfg.n_layers, num_pages, page_size, cfg.kv_lora_rank), dtype),
        "k_pe_pages": jnp.zeros(
            (cfg.n_layers, num_pages, page_size, cfg.rope_head_dim), dtype),
    }
