"""Uniform model API over all families.

    model = get_model(cfg)
    params = model.init(key, cfg)
    loss, metrics = model.loss(params, batch, cfg)          # train forward
    logits, state = model.prefill(params, batch, cfg)       # serving
    logits, state = model.decode_step(params, token, state, cfg)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax.numpy as jnp

from repro.models import encdec, hybrid, moe, ssm, vlm
from repro.models.config import ModelConfig
from repro.models import transformer as tfm

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelApi:
    init: Callable
    loss: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable | None = None   # (cfg, batch, max_len) -> cache
    # chunked-admission prefill: (params, tokens (B, C), lengths (B,),
    # state, cfg) -> (last-valid logits (B, V), state); state carries a
    # per-row base ``index``. `init_state` builds the zeroed decode-state
    # pytree the first chunk writes into: (cfg, batch, max_len) -> state.
    prefill_chunk: Callable | None = None
    init_state: Callable | None = None
    # paged-KV pool for families the engine can serve paged:
    # (cfg, num_pages, page_size) -> pool leaves (L, P, T, ...); the
    # engine pairs it with a per-row page table (see repro.serving.paging)
    init_page_pool: Callable | None = None
    # prefill-once admission hooks for modality families (encdec source
    # encoding + cross-KV, VLM patch prefix). A family is an "admit
    # family" iff `admit` is non-None.
    #   admit_dims(cfg, extras) -> (prefix_len, src_len) host ints: cache
    #     rows the admission writes ahead of the prompt, and side
    #     (non-cache) source rows it encodes.
    #   pack_admit(cfg, extras_list, width, bucket) -> packed host batch
    #     (rows padded to `width`, sequence dim to `bucket`).
    #   admit(params, packed, state, cfg) -> state: jittable and
    #     batch-generic — the wave path admits a full batch in one call,
    #     the continuous path packs fresh admissions and splices rows.
    admit_dims: Callable | None = None
    pack_admit: Callable | None = None
    admit: Callable | None = None


def _zero_index_state(init_cache, key: str = "kv"):
    def init_state(cfg, batch: int, max_len: int):
        return {key: init_cache(cfg, batch, max_len),
                "index": jnp.zeros((batch,), jnp.int32)}
    return init_state


# ---- per-family wiring ----

def _dense_api() -> ModelApi:
    return ModelApi(
        init=lambda key, cfg: tfm.lm_init(key, cfg, tfm.dense_block_init),
        loss=lambda p, b, cfg: tfm.lm_loss(p, b, cfg, tfm.dense_block_apply),
        prefill=lambda p, b, cfg, **kw: tfm.lm_prefill(
            p, b, cfg, tfm.dense_block_apply, **kw),
        decode_step=lambda p, t, s, cfg: tfm.lm_decode_step(
            p, t, s, cfg, tfm.dense_block_apply),
        init_cache=lambda cfg, b, ml: tfm.init_kv_cache(cfg, b, ml),
        prefill_chunk=lambda p, t, ln, s, cfg: tfm.lm_prefill_chunk(
            p, t, ln, s, cfg, tfm.dense_block_apply),
        init_state=_zero_index_state(
            lambda cfg, b, ml: tfm.init_kv_cache(cfg, b, ml)),
        init_page_pool=tfm.init_kv_page_pool,
    )


def _moe_api() -> ModelApi:
    return ModelApi(
        init=lambda key, cfg: tfm.lm_init(key, cfg, moe.moe_block_init),
        loss=lambda p, b, cfg: tfm.lm_loss(p, b, cfg, moe.moe_block_apply),
        prefill=lambda p, b, cfg, **kw: tfm.lm_prefill(
            p, b, cfg, moe.moe_block_apply, **kw),
        decode_step=lambda p, t, s, cfg: tfm.lm_decode_step(
            p, t, s, cfg, moe.moe_block_apply),
        init_cache=lambda cfg, b, ml: tfm.init_kv_cache(cfg, b, ml),
        prefill_chunk=lambda p, t, ln, s, cfg: tfm.lm_prefill_chunk(
            p, t, ln, s, cfg, moe.moe_block_apply),
        init_state=_zero_index_state(
            lambda cfg, b, ml: tfm.init_kv_cache(cfg, b, ml)),
        init_page_pool=tfm.init_kv_page_pool,
    )


def _with_cache(batch: dict, cfg: ModelConfig, init_cache, max_len=None):
    if "cache" in batch and batch["cache"] is not None:
        return batch
    b = dict(batch)
    bs = b["tokens"].shape[0]
    ml = max_len or b["tokens"].shape[1]
    b["cache"] = init_cache(cfg, bs, ml)
    return b


def _mla_moe_api() -> ModelApi:
    ic = lambda cfg, b, ml: moe.init_mla_cache(cfg, b, ml)
    return ModelApi(
        init=lambda key, cfg: tfm.lm_init(key, cfg, moe.mla_moe_block_init),
        loss=lambda p, b, cfg: tfm.lm_loss(p, b, cfg, moe.mla_moe_block_apply),
        prefill=lambda p, b, cfg, max_len=None: tfm.lm_prefill(
            p, _with_cache(b, cfg, ic, max_len), cfg, moe.mla_moe_block_apply),
        decode_step=lambda p, t, s, cfg: tfm.lm_decode_step(
            p, t, s, cfg, moe.mla_moe_block_apply),
        init_cache=ic,
        prefill_chunk=lambda p, t, ln, s, cfg: tfm.lm_prefill_chunk(
            p, t, ln, s, cfg, moe.mla_moe_block_apply),
        init_state=_zero_index_state(ic),
        init_page_pool=moe.init_mla_page_pool,
    )


def _mamba1_api() -> ModelApi:
    ic = lambda cfg, b, ml: ssm.init_mamba1_cache(cfg, b)
    return ModelApi(
        init=lambda key, cfg: tfm.lm_init(key, cfg, ssm.mamba1_block_init),
        loss=lambda p, b, cfg: tfm.lm_loss(p, b, cfg, ssm.mamba1_block_apply),
        prefill=lambda p, b, cfg, max_len=None: tfm.lm_prefill(
            p, _with_cache(b, cfg, ic, max_len), cfg, ssm.mamba1_block_apply),
        decode_step=lambda p, t, s, cfg: tfm.lm_decode_step(
            p, t, s, cfg, ssm.mamba1_block_apply),
        init_cache=ic,
        prefill_chunk=lambda p, t, ln, s, cfg: tfm.lm_prefill_chunk(
            p, t, ln, s, cfg, ssm.mamba1_block_apply),
        init_state=_zero_index_state(ic),
    )


def _mamba2_api() -> ModelApi:
    ic = lambda cfg, b, ml: ssm.init_mamba2_cache(cfg, b)
    return ModelApi(
        init=lambda key, cfg: tfm.lm_init(key, cfg, ssm.mamba2_block_init),
        loss=lambda p, b, cfg: tfm.lm_loss(p, b, cfg, ssm.mamba2_block_apply),
        prefill=lambda p, b, cfg, max_len=None: tfm.lm_prefill(
            p, _with_cache(b, cfg, ic, max_len), cfg, ssm.mamba2_block_apply),
        decode_step=lambda p, t, s, cfg: tfm.lm_decode_step(
            p, t, s, cfg, ssm.mamba2_block_apply),
        init_cache=ic,
        prefill_chunk=lambda p, t, ln, s, cfg: tfm.lm_prefill_chunk(
            p, t, ln, s, cfg, ssm.mamba2_block_apply),
        init_state=_zero_index_state(ic),
    )


def _hybrid_api() -> ModelApi:
    return ModelApi(
        init=hybrid.hybrid_init,
        loss=hybrid.hybrid_loss,
        prefill=hybrid.hybrid_prefill,
        decode_step=hybrid.hybrid_decode_step,
        init_cache=lambda cfg, b, ml: hybrid.init_hybrid_cache(cfg, b, ml),
        prefill_chunk=hybrid.hybrid_prefill_chunk,
        init_state=_zero_index_state(
            lambda cfg, b, ml: hybrid.init_hybrid_cache(cfg, b, ml),
            key="cache"),
    )


def _encdec_api() -> ModelApi:
    return ModelApi(
        init=encdec.encdec_init,
        loss=encdec.encdec_loss,
        prefill=encdec.encdec_prefill,
        decode_step=encdec.encdec_decode_step,
        prefill_chunk=encdec.encdec_prefill_chunk,
        init_state=encdec.encdec_init_state,
        # decoder self-attention KV pages; cross-KV stays dense per-request
        init_page_pool=tfm.init_kv_page_pool,
        admit_dims=encdec.encdec_admit_dims,
        pack_admit=encdec.encdec_pack_admit,
        admit=encdec.encdec_admit,
    )


def _vlm_api() -> ModelApi:
    return ModelApi(
        init=vlm.vlm_init,
        loss=vlm.vlm_loss,
        prefill=vlm.vlm_prefill,
        decode_step=vlm.vlm_decode_step,
        init_cache=lambda cfg, b, ml: tfm.init_kv_cache(cfg, b, ml),
        prefill_chunk=vlm.vlm_prefill_chunk,
        init_state=vlm.vlm_init_state,
        init_page_pool=tfm.init_kv_page_pool,
        admit_dims=vlm.vlm_admit_dims,
        pack_admit=vlm.vlm_pack_admit,
        admit=vlm.vlm_admit,
    )


_FAMILIES: dict[str, Callable[[], ModelApi]] = {
    "dense": _dense_api,
    "moe": _moe_api,
    "mla_moe": _mla_moe_api,
    "mamba1": _mamba1_api,
    "mamba2": _mamba2_api,
    "hybrid": _hybrid_api,
    "encdec": _encdec_api,
    "vlm": _vlm_api,
}


def get_model(cfg: ModelConfig) -> ModelApi:
    if cfg.kind not in _FAMILIES:
        raise KeyError(f"unknown model kind {cfg.kind!r}")
    return _FAMILIES[cfg.kind]()
