"""State-space blocks: Mamba1 (Falcon-Mamba) and Mamba2/SSD (Zamba2 backbone).

TPU adaptation notes (DESIGN.md §2): the CUDA selective-scan kernel is a
fused sequential scan with shared-memory staging; on TPU we use
  * Mamba1: chunked first-order recurrence — `lax.associative_scan` inside a
    chunk (parallel, VPU-friendly), `lax.scan` across chunks (O(S/Q) sequential
    steps, bounded VMEM working set per chunk).
  * Mamba2: the SSD block decomposition — intra-chunk attention-like matmuls
    (MXU work) + inter-chunk state recurrence. This is the TPU-native
    formulation of the paper's "adapt the insight, don't port the kernel".

States: mamba1 h is (B, d_inner, d_state) per layer; mamba2 h is
(B, H_ssm, d_state, headdim). Decode is O(1) per token for both.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed import tp
from repro.distributed.sharding import shard_activation
from repro.kernels import ops
from repro.models import layers as L
from repro.models.config import ModelConfig

Params = dict[str, Any]

CHUNK = 128

# Serving-prefill scan block. Chunked admission prefill feeds a prompt
# through the decode loop in pow2-bucket chunks and must carry the SSM
# state across chunk boundaries *bit-exactly* (the engine's parity
# contract). A first-order/SSD scan split at a multiple of its inner block
# size (with the carried state threaded through) executes the identical
# op sequence, and identity-padded tails (decay=1 / input=0) are
# bit-transparent — so every serving-path scan uses this block size, which
# divides every chunk bucket (`ops.prefill_buckets(min_bucket=8)`), and an
# unchunked serve prefill is bit-identical to any chunking of it. Training
# (no cache) keeps the wide CHUNK blocks.
SERVE_CHUNK = 8


def serve_chunk(cfg: ModelConfig) -> int:
    """Serving-scan block size: `cfg.ssm_serve_grain` when set (wider
    grains amortize the O(S/Q) sequential scan steps over long prompts),
    else the module default. The engine validates `chunk_tokens` is a
    multiple so the bit-parity argument above still applies."""
    return int(getattr(cfg, "ssm_serve_grain", 0) or 0) or SERVE_CHUNK


# ---------------- causal depthwise conv ----------------

def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                history: jax.Array | None = None) -> jax.Array:
    """x: (B, S, C); w: (C, K); history: (B, K-1, C) carried state."""
    B, S, C = x.shape
    K = w.shape[1]
    if history is None:
        history = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([history, x], axis=1)          # (B, S+K-1, C)
    out = jnp.zeros((B, S, C), jnp.float32)
    for i in range(K):
        out = out + xp[:, i:i + S].astype(jnp.float32) * w[:, i].astype(
            jnp.float32)
    out = out + b.astype(jnp.float32)
    return out.astype(x.dtype)


def conv_history(history: jax.Array, x: jax.Array,
                 seq_lens: jax.Array) -> jax.Array:
    """Carried conv state over a right-padded chunk: the last K-1 *valid*
    inputs per row (pad positions must not enter the next chunk's
    receptive field). `seq_lens`: (B,) valid token count per row; a row
    with 0 valid tokens keeps its history unchanged."""
    Km1 = history.shape[1]
    xp = jnp.concatenate([history, x], axis=1)          # (B, Km1+S, C)
    lens = jnp.asarray(seq_lens, jnp.int32)

    def row(xp_b, n):
        return jax.lax.dynamic_slice_in_dim(xp_b, n, Km1, axis=0)

    return jax.vmap(row)(xp, lens)


def _seq_mask(seq_lens, S: int) -> jax.Array:
    """(B, S) validity mask for right-padded chunk rows."""
    lens = jnp.asarray(seq_lens, jnp.int32)
    return jnp.arange(S, dtype=jnp.int32)[None, :] < lens[:, None]


# ---------------- first-order recurrence (chunked) ----------------

def _chunk_recurrence(decay_c, inp_c, h0):
    """Within-chunk h_t = decay_t*h_{t-1} + inp_t via associative scan.
    decay_c/inp_c: (Q, ...) leading time axis. h0: (...). Returns h for all
    t in chunk and the final state."""

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    A, Bc = jax.lax.associative_scan(combine, (decay_c, inp_c), axis=0)
    h_all = Bc + A * h0[None]
    return h_all, h_all[-1]


def mamba1_scan(decay: jax.Array, inp: jax.Array, C: jax.Array,
                h0: jax.Array, chunk: int = CHUNK
                ) -> tuple[jax.Array, jax.Array]:
    """decay/inp: (B, S, di, ds); C: (B, S, ds); h0: (B, di, ds).
    Returns y: (B, S, di) = C_t . h_t, and final state.

    A non-divisible tail is identity-padded (decay=1, input=0) to a
    multiple of the block size — bit-transparent to the recurrence, so
    arbitrary lengths scan without changing any real position's value."""
    B, S, di, ds = decay.shape
    q = min(chunk, S)
    pad = (-S) % q
    if pad:
        decay = jnp.concatenate(
            [decay, jnp.ones((B, pad, di, ds), decay.dtype)], axis=1)
        inp = jnp.concatenate(
            [inp, jnp.zeros((B, pad, di, ds), inp.dtype)], axis=1)
        C = jnp.concatenate([C, jnp.zeros((B, pad, ds), C.dtype)], axis=1)
    nc = (S + pad) // q
    dec = decay.reshape(B, nc, q, di, ds).swapaxes(0, 1)   # (nc,B,q,di,ds)
    ip = inp.reshape(B, nc, q, di, ds).swapaxes(0, 1)
    Cm = C.reshape(B, nc, q, ds).swapaxes(0, 1)            # (nc,B,q,ds)

    def body(h, xs):
        d_c, i_c, c_c = xs                                  # (B,q,di,ds), (B,q,ds)
        # time axis first for the associative scan
        h_all, h_last = _chunk_recurrence(
            d_c.swapaxes(0, 1), i_c.swapaxes(0, 1), h)      # (q,B,di,ds)
        y = jnp.einsum("qbds,bqs->bqd", h_all, c_c)
        return h_last, y

    h_final, ys = jax.lax.scan(body, h0, (dec, ip, Cm))
    y = ys.swapaxes(0, 1).reshape(B, S + pad, di)
    return y[:, :S], h_final


# ---------------- Mamba1 block ----------------

def mamba1_block_init(key, cfg: ModelConfig) -> Params:
    d, di, ds, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.d_conv
    r = max(1, cfg.d_model // 16)  # dt_rank
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)
    # S4D-real initialization for A
    A = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))
    return {
        "ln": L.rmsnorm_init(d, cfg),
        "ssm": {
            "in_proj": L.dense_init(ks[0], d, 2 * di, cfg),
            "conv_w": (jax.random.normal(ks[1], (di, K), jnp.float32)
                       / math.sqrt(K)).astype(dt),
            "conv_b": jnp.zeros((di,), dt),
            "x_proj": L.dense_init(ks[2], di, r + 2 * ds, cfg),
            "dt_proj": L.dense_init(ks[3], r, di, cfg),
            "dt_bias": jnp.full((di,), -4.6, dt),  # softplus^-1(0.01)
            "A_log": jnp.log(A),
            "D": jnp.ones((di,), jnp.float32),
            "out_proj": L.dense_init(ks[4], di, d, cfg),
        },
    }


def _mamba1_core(p: Params, x_conv: jax.Array, cfg: ModelConfig,
                 h0: jax.Array, *, single_step: bool = False,
                 seq_mask: jax.Array | None = None, chunk: int = CHUNK):
    """x_conv: post-conv activations (B, S, di). Returns (y, h_final).

    `seq_mask` (B, S) marks valid positions of a right-padded chunk: pad
    steps become the identity update (decay=1, input=0), so the carried
    state stops exactly at each row's last valid token. `chunk` sets the
    scan block size (serving paths use SERVE_CHUNK so chunked prefill is
    bit-identical to an unchunked serve — see SERVE_CHUNK)."""
    s = p["ssm"]
    di, ds = cfg.d_inner, cfg.ssm_state
    r = max(1, cfg.d_model // 16)
    # x_proj contracts di — re-replicate in gather mode so the sharded
    # channel axis never enters a plain dot (bit-parity contract)
    x_conv = tp.replicate_for_parity(x_conv, cfg)
    proj = ops.matmul(x_conv, s["x_proj"])
    dt_low, Bm, Cm = jnp.split(proj, [r, r + ds], axis=-1)
    dtv = ops.matmul(dt_low, s["dt_proj"]).astype(jnp.float32)
    dtv = jax.nn.softplus(dtv + s["dt_bias"].astype(jnp.float32))  # (B,S,di)
    A = -jnp.exp(s["A_log"].astype(jnp.float32))                   # (di,ds)
    decay = jnp.exp(dtv[..., None] * A)                            # (B,S,di,ds)
    xf = x_conv.astype(jnp.float32)
    inp = (dtv * xf)[..., None] * Bm.astype(jnp.float32)[:, :, None, :]
    if seq_mask is not None:
        decay = jnp.where(seq_mask[..., None, None], decay, 1.0)
        inp = jnp.where(seq_mask[..., None, None], inp, 0.0)
    if single_step:
        h = decay[:, 0] * h0 + inp[:, 0]                           # (B,di,ds)
        y = jnp.einsum("bds,bs->bd", h, Cm[:, 0].astype(jnp.float32))[:, None]
        h_final = h
    else:
        y, h_final = mamba1_scan(decay, inp, Cm.astype(jnp.float32), h0,
                                 chunk=chunk)
    y = y + s["D"].astype(jnp.float32) * xf
    return y, h_final


def mamba1_block_apply(p: Params, x: jax.Array, cfg: ModelConfig, *,
                       positions=None, cache: dict | None = None,
                       cache_index=None, seq_lens=None):
    """cache: {"conv": (B, K-1, di), "ssm": (B, di, ds)} or None.

    `seq_lens` (B,) marks each row's valid token count in a right-padded
    prefill chunk: conv history and SSM state advance only over valid
    positions (the chunked-admission contract)."""
    B, S, d = x.shape
    di = cfg.d_inner
    s = p["ssm"]
    h = L.rmsnorm(p["ln"], x, cfg.norm_eps)
    xz = tp.tp_column(h, s["in_proj"], cfg)
    x_, z = jnp.split(xz, 2, axis=-1)
    x_ = shard_activation(x_, "batch", None, "model")

    new_cache = None
    if cache is not None:
        x_conv = causal_conv(x_, s["conv_w"], s["conv_b"], cache["conv"])
        if seq_lens is None:
            hist = jnp.concatenate([cache["conv"], x_],
                                   axis=1)[:, -(cfg.d_conv - 1):]
        else:
            hist = conv_history(cache["conv"], x_, seq_lens)
        x_conv = jax.nn.silu(x_conv.astype(jnp.float32)).astype(x.dtype)
        y, h_final = _mamba1_core(
            p, x_conv, cfg, cache["ssm"].astype(jnp.float32),
            single_step=(S == 1),
            seq_mask=None if seq_lens is None else _seq_mask(seq_lens, S),
            chunk=serve_chunk(cfg))
        new_cache = {"conv": hist.astype(cache["conv"].dtype),
                     "ssm": h_final.astype(cache["ssm"].dtype)}
    else:
        x_conv = causal_conv(x_, s["conv_w"], s["conv_b"])
        x_conv = jax.nn.silu(x_conv.astype(jnp.float32)).astype(x.dtype)
        h0 = jnp.zeros((B, di, cfg.ssm_state), jnp.float32)
        y, _ = _mamba1_core(p, x_conv, cfg, h0)

    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = tp.tp_row(y.astype(x.dtype), s["out_proj"], cfg)
    x = x + out
    x = shard_activation(x, "batch", None, None)
    return x, new_cache, jnp.zeros((), jnp.float32)


def init_mamba1_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.d_conv - 1, cfg.d_inner),
                          dtype),
        "ssm": jnp.zeros((cfg.n_layers, batch, cfg.d_inner, cfg.ssm_state),
                         dtype),
    }


# ---------------- Mamba2 (SSD) block ----------------

def mamba2_block_init(key, cfg: ModelConfig) -> Params:
    d, di, ds = cfg.d_model, cfg.d_inner, cfg.ssm_state
    P_, G = cfg.ssm_headdim, cfg.ssm_ngroups
    H = di // P_
    K = cfg.d_conv
    conv_ch = di + 2 * G * ds
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "ln": L.rmsnorm_init(d, cfg),
        "ssm": {
            "in_proj": L.dense_init(ks[0], d, 2 * di + 2 * G * ds + H, cfg),
            "conv_w": (jax.random.normal(ks[1], (conv_ch, K), jnp.float32)
                       / math.sqrt(K)).astype(dt),
            "conv_b": jnp.zeros((conv_ch,), dt),
            "A_log": jnp.zeros((H,), jnp.float32),
            "D": jnp.ones((H,), jnp.float32),
            "dt_bias": jnp.full((H,), -4.6, dt),
            "norm_scale": jnp.ones((di,), dt),
            "out_proj": L.dense_init(ks[2], di, d, cfg),
        },
    }


def ssd_scan(x: jax.Array, a_log: jax.Array, Bm: jax.Array, Cm: jax.Array,
             h0: jax.Array, chunk: int = CHUNK
             ) -> tuple[jax.Array, jax.Array]:
    """SSD chunked recurrence.

    x: (B, S, H, P) inputs already scaled by dt;
    a_log: (B, S, H) per-step log decay (<= 0);
    Bm/Cm: (B, S, N) state in/out projections (ngroups=1 broadcast);
    h0: (B, H, N, P). Returns y (B, S, H, P), h_final.
    """
    Bsz, S, H, P_ = x.shape
    N = Bm.shape[-1]
    q = min(chunk, S)
    pad = (-S) % q
    if pad:
        # identity tail: zero input/B kills state updates, zero log-decay
        # keeps the carried state — bit-transparent to real positions
        x = jnp.concatenate(
            [x, jnp.zeros((Bsz, pad, H, P_), x.dtype)], axis=1)
        a_log = jnp.concatenate(
            [a_log, jnp.zeros((Bsz, pad, H), a_log.dtype)], axis=1)
        Bm = jnp.concatenate(
            [Bm, jnp.zeros((Bsz, pad, N), Bm.dtype)], axis=1)
        Cm = jnp.concatenate(
            [Cm, jnp.zeros((Bsz, pad, N), Cm.dtype)], axis=1)
    nc = (S + pad) // q
    xr = x.reshape(Bsz, nc, q, H, P_).swapaxes(0, 1)
    ar = a_log.reshape(Bsz, nc, q, H).swapaxes(0, 1)
    Br = Bm.reshape(Bsz, nc, q, N).swapaxes(0, 1)
    Cr = Cm.reshape(Bsz, nc, q, N).swapaxes(0, 1)

    def body(h, xs):
        xc, ac, bc, cc = xs          # (B,q,H,P), (B,q,H), (B,q,N), (B,q,N)
        la = jnp.cumsum(ac, axis=1)                    # (B,q,H)
        # intra-chunk: attention-like causal matmul with decay weights
        scores = jnp.einsum("bqn,bkn->bqk", cc, bc)    # (B,q,q)
        decay_qk = jnp.exp(la[:, :, None, :] - la[:, None, :, :])  # (B,q,k,H)
        causal = jnp.tril(jnp.ones((q, q), bool))
        w = jnp.where(causal[None, :, :, None],
                      scores[..., None] * decay_qk, 0.0)
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", w, xc)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bqn,bhnp,bqh->bqhp", cc, h, jnp.exp(la))
        # next chunk state
        rem = jnp.exp(la[:, -1:, :] - la)              # (B,q,H)
        s_c = jnp.einsum("bkn,bkhp,bkh->bhnp", bc, xc, rem)
        h_next = jnp.exp(la[:, -1])[:, :, None, None] * h + s_c
        return h_next, y_intra + y_inter

    h_final, ys = jax.lax.scan(body, h0, (xr, ar, Br, Cr))
    y = ys.swapaxes(0, 1).reshape(Bsz, S + pad, H, P_)
    return y[:, :S], h_final


def _mamba2_split(cfg: ModelConfig, proj: jax.Array):
    di, ds, G = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups
    z, rest = jnp.split(proj, [di], axis=-1)
    xBC, dt = jnp.split(rest, [di + 2 * G * ds], axis=-1)
    return z, xBC, dt  # dt: (..., H)


def mamba2_block_apply(p: Params, x: jax.Array, cfg: ModelConfig, *,
                       positions=None, cache: dict | None = None,
                       cache_index=None, seq_lens=None):
    """cache: {"conv": (B, K-1, conv_ch), "ssm": (B, H, N, P)}.

    `seq_lens` (B,) marks each row's valid token count in a right-padded
    prefill chunk: conv history and SSD state advance only over valid
    positions (pad steps carry zero input/B and zero log-decay — the
    identity update)."""
    B, S, d = x.shape
    di, ds, G = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups
    P_ = cfg.ssm_headdim
    H = di // P_
    s = p["ssm"]
    hin = L.rmsnorm(p["ln"], x, cfg.norm_eps)
    proj = tp.tp_column(hin, s["in_proj"], cfg)
    z, xBC, dt_raw = _mamba2_split(cfg, proj)
    # z feeds the gated-norm mean (an axis reduction) and dt_raw the decay
    # path — neither may carry a sharded axis in gather mode
    z = tp.replicate_for_parity(z, cfg)
    dt_raw = tp.replicate_for_parity(dt_raw, cfg)
    xBC = shard_activation(xBC, "batch", None, "model")

    new_cache = None
    if cache is not None:
        conv_hist = cache["conv"]
        xBC_c = causal_conv(xBC, s["conv_w"], s["conv_b"], conv_hist)
        if seq_lens is None:
            hist = jnp.concatenate([conv_hist, xBC],
                                   axis=1)[:, -(cfg.d_conv - 1):]
        else:
            hist = conv_history(conv_hist, xBC, seq_lens)
    else:
        xBC_c = causal_conv(xBC, s["conv_w"], s["conv_b"])
        hist = None
    # the SSD einsums contract the state dim of Bm/Cm — re-replicate the
    # conv output in gather mode before anything reaches a contraction
    xBC_c = tp.replicate_for_parity(xBC_c, cfg)
    xBC_c = jax.nn.silu(xBC_c.astype(jnp.float32)).astype(x.dtype)
    xs, Bm, Cm = jnp.split(xBC_c, [di, di + G * ds], axis=-1)
    xs = xs.reshape(B, S, H, P_)
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32)
                          + s["dt_bias"].astype(jnp.float32))    # (B,S,H)
    a_log = -jnp.exp(s["A_log"].astype(jnp.float32)) * dtv        # (B,S,H)
    x_dt = xs.astype(jnp.float32) * dtv[..., None]
    Bm_f = Bm.astype(jnp.float32)
    if seq_lens is not None:
        mask = _seq_mask(seq_lens, S)
        a_log = jnp.where(mask[..., None], a_log, 0.0)
        x_dt = jnp.where(mask[..., None, None], x_dt, 0.0)
        Bm_f = jnp.where(mask[..., None], Bm_f, 0.0)

    h0 = (cache["ssm"].astype(jnp.float32) if cache is not None
          else jnp.zeros((B, H, ds, P_), jnp.float32))
    if cache is not None and S == 1:
        decay = jnp.exp(a_log[:, 0])                              # (B,H)
        upd = jnp.einsum("bn,bhp->bhnp", Bm_f[:, 0], x_dt[:, 0])
        h1 = decay[:, :, None, None] * h0 + upd
        y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), h1)
        y = y[:, None]                                            # (B,1,H,P)
        h_final = h1
    else:
        y, h_final = ssd_scan(x_dt, a_log, Bm_f,
                              Cm.astype(jnp.float32), h0,
                              chunk=serve_chunk(cfg) if cache is not None
                              else CHUNK)
    if cache is not None:
        new_cache = {"conv": hist.astype(cache["conv"].dtype),
                     "ssm": h_final.astype(cache["ssm"].dtype)}

    y = y + s["D"].astype(jnp.float32)[None, None, :, None] * xs.astype(
        jnp.float32)
    y = y.reshape(B, S, di)
    # gated RMSNorm (mamba2)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + cfg.norm_eps)
    y = y * s["norm_scale"].astype(jnp.float32)
    out = tp.tp_row(y.astype(x.dtype), s["out_proj"], cfg)
    x = x + out
    x = shard_activation(x, "batch", None, None)
    return x, new_cache, jnp.zeros((), jnp.float32)


def init_mamba2_cache(cfg: ModelConfig, batch: int, n_layers: int | None = None,
                      dtype=jnp.float32) -> dict:
    di, ds, G = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups
    H = di // cfg.ssm_headdim
    Lc = n_layers if n_layers is not None else cfg.n_layers
    conv_ch = di + 2 * G * ds
    return {
        "conv": jnp.zeros((Lc, batch, cfg.d_conv - 1, conv_ch), dtype),
        "ssm": jnp.zeros((Lc, batch, H, ds, cfg.ssm_headdim), dtype),
    }
