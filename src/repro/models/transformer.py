"""Decoder-only transformer scaffolding (dense blocks; scan over layers).

Generic over the block functions so MoE/VLM/hybrid families reuse the same
embedding / scan / head / cache plumbing. Layers are stacked along a leading
axis and driven by `lax.scan` to keep the HLO size O(1) in depth (critical
for the 512-device dry-run compiles).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_activation
from repro.kernels import ops
from repro.models import layers as L
from repro.models.config import ModelConfig

Params = dict[str, Any]

# ---------------- dense block ----------------


def dense_block_init(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model, cfg),
        "attn": L.attention_init(k1, cfg),
        "ln2": L.rmsnorm_init(cfg.d_model, cfg),
        "mlp": L.swiglu_init(k2, cfg),
    }


def residual_spec(cfg: ModelConfig, x: jax.Array) -> tuple:
    """Sharding names for the residual stream. With sequence parallelism the
    seq dim additionally shards over the TP axis between blocks (Megatron
    SP): the surrounding all-reduces become reduce-scatter + all-gather
    (half the wire bytes) and norms/residual math run on 1/TP of the
    activations."""
    if cfg.sequence_parallel and x.ndim >= 3 and x.shape[1] > 1:
        return ("batch", "seq_tp", None)
    return ("batch", None, None)


def dense_block_apply(p: Params, x: jax.Array, cfg: ModelConfig, *,
                      positions: jax.Array, cache: dict | None = None,
                      cache_index=None, seq_lens=None):
    """Uniform block API across families: returns (x, cache, aux_loss).

    `seq_lens` (the per-row valid-token counts of a right-padded prefill
    chunk) masks the chunked KV write to valid rows (`cache_update`
    clamp-proofing); masking beyond that is unnecessary here — with
    causal attention + per-row cache indices, right-pad rows are already
    invisible to every real query.

    With sequence parallelism the canonical Megatron-SP structure applies:
    the residual stream and norms stay seq-sharded over TP; activations are
    all-gathered only at the qkv/gate matmul inputs, and the wo/w_down
    partial sums are constrained seq-sharded *before* the residual add so
    XLA lowers them as reduce-scatter (half the all-reduce wire bytes)."""
    sp = cfg.sequence_parallel and x.ndim == 3 and x.shape[1] > 1
    rs = residual_spec(cfg, x)
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if sp:
        h = shard_activation(h, "batch", None, None)   # all-gather point
    attn_out, new_cache = L.attention_apply(
        p["attn"], h, cfg, positions=positions, kv_cache=cache,
        cache_index=cache_index, seq_lens=seq_lens)
    if sp:
        attn_out = shard_activation(attn_out, *rs)     # reduce-scatter point
    x = x + attn_out
    x = shard_activation(x, *rs)
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if sp:
        h = shard_activation(h, "batch", None, None)   # all-gather point
    mlp_out = L.swiglu_apply(p["mlp"], h, cfg)
    if sp:
        mlp_out = shard_activation(mlp_out, *rs)       # reduce-scatter point
    x = x + mlp_out
    x = shard_activation(x, *rs)
    return x, new_cache, jnp.zeros((), jnp.float32)


# ---------------- generic LM over any block ----------------


def lm_init(key, cfg: ModelConfig,
            block_init: Callable = dense_block_init) -> Params:
    ke, kl, kh = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    blocks = jax.vmap(lambda k: block_init(k, cfg))(layer_keys)
    params: Params = {
        "embed": {"table": L.embed_init(ke, cfg.vocab, cfg.d_model, cfg)},
        "blocks": blocks,
        "ln_f": L.rmsnorm_init(cfg.d_model, cfg),
    }
    if not cfg.tie_embeddings:
        params["head"] = {"w": L.dense_init(kh, cfg.d_model, cfg.vocab, cfg)}
    return params


def _embed(params: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = params["embed"]["table"][tokens]
    return shard_activation(x.astype(jnp.dtype(cfg.activation_dtype)),
                            "batch", None, None)


def _unembed(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return ops.matmul(x, params["embed"]["table"], transpose_b=True,
                          out_dtype=jnp.float32)
    return ops.matmul(x, params["head"]["w"], out_dtype=jnp.float32)


def _scan_blocks(params: Params, x: jax.Array, cfg: ModelConfig,
                 block_apply: Callable, *, positions, cache=None,
                 cache_index=None):
    """Run stacked blocks via lax.scan; threads per-layer cache if given.

    Returns (x, new_caches, total_aux_loss)."""

    def body(carry, inp):
        h, aux_acc = carry
        if cache is None:
            blk = inp
            h, _, aux = block_apply(blk, h, cfg, positions=positions)
            return (h, aux_acc + aux), None
        blk, layer_cache = inp
        h, new_cache, aux = block_apply(blk, h, cfg, positions=positions,
                                        cache=layer_cache,
                                        cache_index=cache_index)
        return (h, aux_acc + aux), new_cache

    if cfg.remat:
        body = jax.checkpoint(body)
    xs = params["blocks"] if cache is None else (params["blocks"], cache)
    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, caches, aux


def lm_loss(params: Params, batch: dict, cfg: ModelConfig,
            block_apply: Callable = dense_block_apply) -> tuple[jax.Array, dict]:
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = _embed(params, tokens, cfg)
    x, _, aux = _scan_blocks(params, x, cfg, block_apply, positions=positions)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = _unembed(params, x, cfg)
    loss, metrics = L.cross_entropy(logits, batch["labels"],
                                    batch.get("loss_mask"))
    loss = loss + aux
    metrics["aux_loss"] = aux
    metrics["loss"] = loss
    return loss, metrics


# ---------------- serving (prefill / decode) ----------------


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16) -> dict:
    shape = (cfg.n_layers, batch, max_len, cfg.kv_heads, cfg.hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def init_kv_page_pool(cfg: ModelConfig, num_pages: int, page_size: int,
                      dtype=jnp.bfloat16) -> dict:
    """Paged KV pool: `num_pages` shared pages of `page_size` tokens per
    layer (page 0 reserved as the null page — see `repro.serving.paging`).
    The engine pairs this with a per-row page table to form the paged
    decode cache `{"k_pages", "v_pages", "table"}`."""
    shape = (cfg.n_layers, num_pages, page_size, cfg.kv_heads, cfg.hd)
    return {
        "k_pages": jnp.zeros(shape, dtype),
        "v_pages": jnp.zeros(shape, dtype),
    }


def lm_prefill(params: Params, batch: dict, cfg: ModelConfig,
               block_apply: Callable = dense_block_apply,
               max_len: int | None = None) -> tuple[jax.Array, dict]:
    """Full-sequence forward filling the KV cache; returns last logits.

    Two decode-state contracts, selected by ``batch["lengths"]``:

    * absent (legacy/wave): every row is exactly S tokens; returns the
      logits at position S-1 and a shared scalar ``index = S``.
    * present, a (B,) int32 of true prompt lengths over *right-padded*
      rows: returns each row's logits at ``lengths[b] - 1`` and a per-row
      ``index = lengths``. Right-padding is causal-safe — pad keys sit
      after every valid query, so no real token ever attends to padding,
      and decode overwrites pad cache rows before its per-row ``kv_len``
      mask can reach them. SSM blocks additionally receive the lengths as
      `seq_lens`, so conv/scan state stops exactly at each row's last
      valid token. A padded row is therefore bit-identical to the same
      prompt served unpadded (the continuous-batching slot-prefill
      contract), for attention and recurrent families alike.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    max_len = max_len or S
    lengths = batch.get("lengths")
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    cache = batch.get("cache")
    if cache is None:
        cache = init_kv_cache(cfg, B, max_len)
    # constrain only the batch dim; per-family inner-dim shardings are set by
    # the launcher's explicit in_shardings (see launch/dryrun.py). Gather-
    # mode serving keeps its head-axis cache sharding (sharding.
    # serving_state_pspecs) — a batch-only constraint would all-gather it.
    if getattr(cfg, "tp_reduce", "psum") != "gather":
        cache = jax.tree.map(lambda c: shard_activation(c, None, "batch"),
                             cache)
    x = _embed(params, tokens, cfg)
    if lengths is not None:
        lens32 = jnp.asarray(lengths, jnp.int32)

        def ba(bp, h, c, **kw):
            return block_apply(bp, h, c, seq_lens=lens32, **kw)
    else:
        ba = block_apply

    x, cache, _ = _scan_blocks(params, x, cfg, ba,
                               positions=positions, cache=cache,
                               cache_index=jnp.int32(0))
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if lengths is None:
        logits = _unembed(params, x[:, -1:], cfg)
        return logits[:, 0], {"kv": cache, "index": jnp.int32(S)}
    lengths = jnp.asarray(lengths, jnp.int32)
    last = jnp.take_along_axis(
        x, jnp.broadcast_to((lengths - 1)[:, None, None],
                            (B, 1, x.shape[-1])), axis=1)
    logits = _unembed(params, last, cfg)
    return logits[:, 0], {"kv": cache, "index": lengths}


def lm_prefill_chunk(params: Params, tokens: jax.Array, lengths: jax.Array,
                     state: dict, cfg: ModelConfig,
                     block_apply: Callable = dense_block_apply,
                     positions: jax.Array | None = None
                     ) -> tuple[jax.Array, dict]:
    """One admission-prefill chunk, fused into the serving loop.

    tokens: (B, S) — each row's next `lengths[b]` prompt tokens, right-
    padded to the shared chunk bucket S; state: {"kv", "index"} with a
    per-row ``index`` holding each row's chunk base offset (tokens already
    written; 0 on the first chunk). KV rows are written at
    ``index[b] .. index[b]+S`` (`layers.cache_update` per-row contract),
    attention masks use the per-row base as ``q_offset``, and SSM blocks
    receive `seq_lens` so conv/scan state advances only over valid
    positions. Returns each row's logits at its last valid position
    (meaningful on a row's final chunk) and the advanced state
    (``index + lengths``).

    A prompt prefilled in chunks is bit-identical to `lm_prefill` over the
    whole (bucketed) prompt: attention reads the same cache with the same
    masks, and the SSM serve-scan block size divides every chunk bucket
    (see `ssm.SERVE_CHUNK`).

    `positions` overrides the default per-row ``base + arange(S)`` rotary
    positions (families whose position ids are not the cache index — e.g.
    the VLM's mRoPE text offsets — pass their own; cache writes still land
    at the per-row cache index).
    """
    B, S = tokens.shape
    base = jnp.asarray(state["index"], jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    if positions is None:
        positions = base[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    x = _embed(params, tokens, cfg)

    def chunk_block(bp, h, c, **kw):
        return block_apply(bp, h, c, seq_lens=lengths, **kw)

    x, cache, _ = _scan_blocks(params, x, cfg, chunk_block,
                               positions=positions, cache=state["kv"],
                               cache_index=base)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    last = jnp.take_along_axis(
        x, jnp.broadcast_to(jnp.maximum(lengths - 1, 0)[:, None, None],
                            (B, 1, x.shape[-1])), axis=1)
    logits = _unembed(params, last, cfg)
    return logits[:, 0], {"kv": cache, "index": base + lengths}


def lm_decode_step(params: Params, token: jax.Array, state: dict,
                   cfg: ModelConfig,
                   block_apply: Callable = dense_block_apply,
                   positions: jax.Array | None = None
                   ) -> tuple[jax.Array, dict]:
    """One-token decode. token: (B,) int32. state: {"kv", "index"}.

    ``index`` is either a scalar (all rows at the same position — the wave
    contract) or (B,) (each slot at its own position — the continuous-
    batching contract; see `lm_prefill`). `positions` overrides the rotary
    position ids (defaults to the cache index)."""
    B = token.shape[0]
    idx = state["index"]
    if positions is not None:
        pass
    elif jnp.ndim(idx) == 0:
        positions = jnp.broadcast_to(idx, (B, 1)).astype(jnp.int32)
    else:
        positions = idx[:, None].astype(jnp.int32)
    x = _embed(params, token[:, None], cfg)
    x, cache, _ = _scan_blocks(params, x, cfg, block_apply,
                               positions=positions, cache=state["kv"],
                               cache_index=idx)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = _unembed(params, x, cfg)
    return logits[:, 0], {"kv": cache, "index": idx + 1}
