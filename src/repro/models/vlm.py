"""Qwen2-VL-style VLM backbone: decoder-only LM with M-RoPE and a stubbed
vision frontend (precomputed patch embeddings, per the task spec).

The sequence is [patch embeddings | text tokens]; M-RoPE position ids are
(t, h, w) triples — image patches advance h/w at fixed t, text advances all
three together (Qwen2-VL's scheme). `input_specs` supplies `positions_3d`;
helpers here build them for the smoke tests.

Serving follows the prefill-once contract: the patch prefix runs through
the decoder ONCE at admission (`vlm_admit`), landing its KV in rows
[0, prefix) of the cache; the text tail then chunks through the standard
right-pad / per-row-`index` path via the `transformer` lm generics with
mRoPE positions rebuilt per row from `index + pos_off`, where
``pos_off = t0 - n_patches`` is carried in the decode state.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.models.transformer import (
    _unembed,
    dense_block_apply,
    dense_block_init,
    init_kv_cache,
    lm_init,
    _scan_blocks,
)

Params = dict[str, Any]


def vlm_init(key, cfg: ModelConfig) -> Params:
    return lm_init(key, cfg, block_init=dense_block_init)


def build_mrope_positions(n_patches: int, grid_hw: tuple[int, int],
                          text_len: int, text_start: int = 0) -> np.ndarray:
    """(n_patches + text_len, 3) position ids: patches at t=0 on an h/w
    grid, then text rows ``text_start .. text_start + text_len`` at
    ``t0 + row`` (all three axes advance together). `text_start` makes the
    helper per-row-offset aware: a chunked text tail resumes mid-sequence
    without re-emitting the patch prefix."""
    gh, gw = grid_hw
    assert gh * gw == n_patches
    t0 = max(gh, gw)
    text = np.arange(text_start, text_start + text_len)[:, None] + t0
    text = np.repeat(text, 3, axis=1)
    if n_patches == 0:
        return text.astype(np.int32)
    hh, ww = np.meshgrid(np.arange(gh), np.arange(gw), indexing="ij")
    patch = np.stack([np.zeros(n_patches), hh.ravel(), ww.ravel()], axis=1)
    return np.concatenate([patch, text], axis=0).astype(np.int32)


def _vlm_embed(params: Params, batch: dict, cfg: ModelConfig) -> jax.Array:
    tok = params["embed"]["table"][batch["tokens"]]
    x = jnp.concatenate(
        [batch["patch_embeds"].astype(tok.dtype), tok], axis=1)
    return x.astype(jnp.dtype(cfg.activation_dtype))


def vlm_loss(params: Params, batch: dict, cfg: ModelConfig):
    """batch: tokens (B,S_txt), patch_embeds (B,P,d), positions_3d (B,S,3),
    labels (B,S), loss_mask (B,S) masking patch positions."""
    x = _vlm_embed(params, batch, cfg)
    x, _, aux = _scan_blocks(params, x, cfg, dense_block_apply,
                             positions=batch["positions_3d"])
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = _unembed(params, x, cfg)
    loss, metrics = L.cross_entropy(logits, batch["labels"],
                                    batch.get("loss_mask"))
    metrics["loss"] = loss
    return loss, metrics


def vlm_prefill(params: Params, batch: dict, cfg: ModelConfig,
                max_len: int | None = None):
    x = _vlm_embed(params, batch, cfg)
    B, S, _ = x.shape
    max_len = max_len or S
    cache = batch.get("cache") or init_kv_cache(cfg, B, max_len)
    x, cache, _ = _scan_blocks(params, x, cfg, dense_block_apply,
                               positions=batch["positions_3d"], cache=cache,
                               cache_index=jnp.int32(0))
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = _unembed(params, x[:, -1:], cfg)
    # next positions continue from max text position + 1; the state carries
    # the fixed mRoPE offset pos_off = next_pos - index instead of next_pos
    # itself so chunked and single-shot prefill share one layout.
    next_pos = batch["positions_3d"][:, -1, 0] + 1
    return logits[:, 0], {"kv": cache, "index": jnp.int32(S),
                          "pos_off": (next_pos - S).astype(jnp.int32)}


def _mrope3(pos: jax.Array) -> jax.Array:
    """Text-token (…, 3) triples: all three axes share the scalar id."""
    return jnp.repeat(pos[..., None].astype(jnp.int32), 3, axis=-1)


def vlm_decode_step(params: Params, token: jax.Array, state: dict,
                    cfg: ModelConfig):
    off = jnp.asarray(state["pos_off"], jnp.int32)    # (B,)
    idx = state["index"]                              # scalar or (B,)
    positions = _mrope3((idx + off)[:, None])         # (B, 1, 3)
    logits, st = tfm.lm_decode_step(
        params, token, {"kv": state["kv"], "index": idx}, cfg,
        dense_block_apply, positions=positions)
    return logits, {**st, "pos_off": off}


# ---------------- serving (patch-prefix admission + chunked text) --------

def vlm_init_state(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    return {"kv": init_kv_cache(cfg, batch, max_len),
            "index": jnp.zeros((batch,), jnp.int32),
            "pos_off": jnp.zeros((batch,), jnp.int32)}


def vlm_admit_dims(cfg: ModelConfig, extras: dict | None) -> tuple[int, int]:
    """(cache-prefix rows, source rows): the patch prefix occupies cache
    rows; there is no side (non-cache) source. Text-only requests (no
    extras) admit nothing and serve exactly like a dense LM."""
    if not extras or "patch_embeds" not in extras:
        return 0, 0
    return int(np.asarray(extras["patch_embeds"]).shape[0]), 0


def vlm_pack_admit(cfg: ModelConfig, extras_list: list, width: int,
                   bucket: int) -> dict:
    """Host-side admission batch: patch embeddings right-padded to the
    shared `bucket`, rows padded to `width`; grid mRoPE positions and the
    per-row text offset ``pos_off = t0 - n_patches`` are built here."""
    pe = np.zeros((width, bucket, cfg.d_model), np.float32)
    plen = np.zeros((width,), np.int32)
    off = np.zeros((width,), np.int32)
    pos = np.zeros((width, bucket, 3), np.int32)
    for i, ex in enumerate(extras_list):
        if not ex or "patch_embeds" not in ex:
            continue
        e = np.asarray(ex["patch_embeds"], np.float32)
        p = e.shape[0]
        gh, gw = ex["grid_hw"]
        pe[i, :p] = e
        plen[i] = p
        pos[i, :p] = build_mrope_positions(p, (gh, gw), 0)
        off[i] = max(gh, gw) - p
    return {"patch_embeds": jnp.asarray(pe), "prefix_len": jnp.asarray(plen),
            "pos_off": jnp.asarray(off), "positions": jnp.asarray(pos)}


def vlm_admit(params: Params, packed: dict, state: dict,
              cfg: ModelConfig) -> dict:
    """Prefill-once admission: run the patch prefix through the decoder,
    writing its KV into rows [0, prefix_len) of each row's cache (dense or
    paged — `seq_lens` masks pad-row writes), and start the text tail at
    ``index = prefix_len``. Attention is causal over the prefix, matching
    `vlm_prefill`'s single-shot pass bit for bit."""
    plen = jnp.asarray(packed["prefix_len"], jnp.int32)
    x = packed["patch_embeds"].astype(jnp.dtype(cfg.activation_dtype))

    def block(bp, h, c, **kw):
        return dense_block_apply(bp, h, c, seq_lens=plen, **kw)

    _, cache, _ = _scan_blocks(params, x, cfg, block,
                               positions=packed["positions"],
                               cache=state["kv"],
                               cache_index=jnp.zeros_like(plen))
    return {**state, "kv": cache, "index": plen,
            "pos_off": jnp.asarray(packed["pos_off"], jnp.int32)}


def vlm_prefill_chunk(params: Params, tokens: jax.Array, lengths: jax.Array,
                      state: dict, cfg: ModelConfig
                      ) -> tuple[jax.Array, dict]:
    """One text-tail chunk via `transformer.lm_prefill_chunk`, with mRoPE
    positions rebuilt per row from the cache index plus the admission
    offset (text id = index + pos_off)."""
    B, S = tokens.shape
    off = jnp.asarray(state["pos_off"], jnp.int32)
    base = jnp.asarray(state["index"], jnp.int32)
    pos = (base + off)[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    logits, st = tfm.lm_prefill_chunk(
        params, tokens, lengths, {"kv": state["kv"], "index": base}, cfg,
        dense_block_apply, positions=_mrope3(pos))
    return logits, {**st, "pos_off": off}
