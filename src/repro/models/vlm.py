"""Qwen2-VL-style VLM backbone: decoder-only LM with M-RoPE and a stubbed
vision frontend (precomputed patch embeddings, per the task spec).

The sequence is [patch embeddings | text tokens]; M-RoPE position ids are
(t, h, w) triples — image patches advance h/w at fixed t, text advances all
three together (Qwen2-VL's scheme). `input_specs` supplies `positions_3d`;
helpers here build them for the smoke tests.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.transformer import (
    _unembed,
    dense_block_apply,
    dense_block_init,
    init_kv_cache,
    lm_init,
    _scan_blocks,
)

Params = dict[str, Any]


def vlm_init(key, cfg: ModelConfig) -> Params:
    return lm_init(key, cfg, block_init=dense_block_init)


def build_mrope_positions(n_patches: int, grid_hw: tuple[int, int],
                          text_len: int) -> np.ndarray:
    """(S, 3) position ids: patches at t=0 on an h/w grid, then text."""
    gh, gw = grid_hw
    assert gh * gw == n_patches
    hh, ww = np.meshgrid(np.arange(gh), np.arange(gw), indexing="ij")
    patch = np.stack([np.zeros(n_patches), hh.ravel(), ww.ravel()], axis=1)
    t0 = max(gh, gw)
    text = np.arange(text_len)[:, None] + t0
    text = np.repeat(text, 3, axis=1)
    return np.concatenate([patch, text], axis=0).astype(np.int32)


def _vlm_embed(params: Params, batch: dict, cfg: ModelConfig) -> jax.Array:
    tok = params["embed"]["table"][batch["tokens"]]
    x = jnp.concatenate(
        [batch["patch_embeds"].astype(tok.dtype), tok], axis=1)
    return x.astype(jnp.dtype(cfg.activation_dtype))


def vlm_loss(params: Params, batch: dict, cfg: ModelConfig):
    """batch: tokens (B,S_txt), patch_embeds (B,P,d), positions_3d (B,S,3),
    labels (B,S), loss_mask (B,S) masking patch positions."""
    x = _vlm_embed(params, batch, cfg)
    x, _, aux = _scan_blocks(params, x, cfg, dense_block_apply,
                             positions=batch["positions_3d"])
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = _unembed(params, x, cfg)
    loss, metrics = L.cross_entropy(logits, batch["labels"],
                                    batch.get("loss_mask"))
    metrics["loss"] = loss
    return loss, metrics


def vlm_prefill(params: Params, batch: dict, cfg: ModelConfig,
                max_len: int | None = None):
    x = _vlm_embed(params, batch, cfg)
    B, S, _ = x.shape
    max_len = max_len or S
    cache = batch.get("cache") or init_kv_cache(cfg, B, max_len)
    x, cache, _ = _scan_blocks(params, x, cfg, dense_block_apply,
                               positions=batch["positions_3d"], cache=cache,
                               cache_index=jnp.int32(0))
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = _unembed(params, x[:, -1:], cfg)
    # next positions continue from max text position + 1
    next_pos = batch["positions_3d"][:, -1, 0] + 1
    return logits[:, 0], {"kv": cache, "index": jnp.int32(S),
                          "next_pos": next_pos}


def vlm_decode_step(params: Params, token: jax.Array, state: dict,
                    cfg: ModelConfig):
    idx = state["index"]
    pos_scalar = state["next_pos"]                       # (B,)
    positions = jnp.repeat(pos_scalar[:, None, None], 3, axis=2)  # (B,1,3)
    x = params["embed"]["table"][token[:, None]].astype(
        jnp.dtype(cfg.activation_dtype))
    x, cache, _ = _scan_blocks(params, x, cfg, dense_block_apply,
                               positions=positions.astype(jnp.int32),
                               cache=state["kv"], cache_index=idx)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = _unembed(params, x, cfg)
    return logits[:, 0], {"kv": cache, "index": idx + 1,
                          "next_pos": pos_scalar + 1}
