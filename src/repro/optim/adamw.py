"""AdamW with mixed-precision master weights and distributed-friendly layout.

Params may live in bf16; the optimizer keeps fp32 master copies + moments.
Under the production mesh the moments/master inherit the param sharding
*plus* ZeRO-1 sharding over the data axis where the leading dim allows
(see `zero1_shardings`), which is what keeps 236B-param configs within HBM.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decayed = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, decayed)


def init_opt_state(params: Params) -> dict:
    # copy=True: fp32 params must not alias their master weights, or a
    # donated train-state would donate the same buffer twice
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params: Params, grads: Params, opt_state: dict,
                  cfg: AdamWConfig) -> tuple[Params, dict, dict]:
    """One AdamW step. grads are fp32 (already all-reduced by SPMD)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / (1 - b1 ** step.astype(jnp.float32))
        vhat = v / (1 - b2 ** step.astype(jnp.float32))
        new_master = master - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                                    + cfg.weight_decay * master)
        return m, v, new_master

    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    flat_ma = jax.tree.leaves(opt_state["master"])
    treedef = jax.tree.structure(grads)
    new = [upd(g, m, v, ma) for g, m, v, ma in
           zip(flat_g, flat_m, flat_v, flat_ma)]
    new_m = jax.tree.unflatten(treedef, [x[0] for x in new])
    new_v = jax.tree.unflatten(treedef, [x[1] for x in new])
    new_master = jax.tree.unflatten(treedef, [x[2] for x in new])
    new_params = jax.tree.map(lambda ma, p: ma.astype(p.dtype), new_master,
                              params)
    metrics = {"grad_norm": gnorm, "lr": lr, "clip_scale": scale}
    return new_params, {
        "step": step, "master": new_master, "m": new_m, "v": new_v,
    }, metrics


def zero1_shardings(params_shape, param_shardings, mesh):
    """ZeRO-1: shard optimizer moments further over the data axis on the
    first unsharded dim whose size the data axis divides (best-effort; falls
    back to the param sharding otherwise). Keeps the 3x fp32 optimizer state
    from being replicated across data parallelism."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    if "data" not in mesh.axis_names:
        return {
            "step": NamedSharding(mesh, P()),
            "master": param_shardings,
            "m": param_shardings,
            "v": param_shardings,
        }
    dsize = mesh.shape["data"]

    def shard_more(shape_leaf, ns):
        shape = getattr(shape_leaf, "shape", ())
        spec = list(ns.spec) + [None] * (len(shape) - len(ns.spec))
        used = set()
        for s in spec:
            if isinstance(s, tuple):
                used.update(s)
            elif s is not None:
                used.add(s)
        if "data" in used:
            return ns
        for i, dim in enumerate(shape):
            if spec[i] is None and dim % dsize == 0 and dim > 0:
                spec[i] = "data"
                return NamedSharding(ns.mesh, P(*spec))
        return ns

    zs = jax.tree.map(shard_more, params_shape, param_shardings,
                      is_leaf=lambda x: hasattr(x, "shape"))
    return {
        "step": NamedSharding(mesh, P()),
        "master": zs,
        "m": zs,
        "v": zs,
    }
