"""Batched serving engine: continuous-batching-lite over fixed decode slots.

Requests enter a queue; the engine packs up to `max_batch` prompts per
prefill wave, then decodes all active slots in lockstep (one jitted decode
step per token). Finished sequences (EOS or budget) free their slot for the
next wave — the static-shape analogue of continuous batching that serves
TPU-style compiled steps well.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 32
    eos_id: int | None = None


@dataclasses.dataclass
class Result:
    uid: int
    tokens: np.ndarray          # generated ids
    prompt_len: int
    steps: int


class ServingEngine:
    def __init__(self, model, params, cfg: ModelConfig, *,
                 max_batch: int = 8, max_len: int = 512,
                 greedy: bool = True, seed: int = 0,
                 pretune: bool = False, tune_objective: str = "runtime",
                 tune_rank_mode: str = "auto",
                 chip: str | None = None):
        """`pretune=True` batch-tunes the engine's GEMM fleet up front:
        every projection/FFN/head shape the prefill (max_batch * max_len
        rows) and decode (max_batch rows) steps will trace goes through
        one `ops.warm_gemm_cache` pass (predictor-ranked, substrate-
        verified, cached per chip + artifact version), so the first
        request pays no per-shape autotuning. `tune_objective` picks the
        paper's serving objective ("runtime", "energy", "power", "edp");
        `tune_rank_mode` picks the candidate-ranking path ("auto" ranks
        fully in-graph on accelerator backends, at trace time on CPU).
        """
        self.model = model
        self.params = params
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.greedy = greedy
        self.queue: deque[Request] = deque()
        self._rng = np.random.default_rng(seed)
        self.pretuned: dict[tuple, object] = {}
        if pretune:
            from repro.kernels import ops
            from repro.models.config import gemm_shapes

            fleet = sorted(set(gemm_shapes(cfg, max_batch * max_len))
                           | set(gemm_shapes(cfg, max_batch)))
            self.pretuned = ops.warm_gemm_cache(
                fleet, dtype=cfg.activation_dtype,
                objective=tune_objective, chip=chip,
                rank_mode=tune_rank_mode)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cfg, max_len=max_len))
        self._decode = jax.jit(
            lambda p, t, s: model.decode_step(p, t, s, cfg))

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        if self.greedy:
            return logits.argmax(-1).astype(np.int32)
        z = logits - logits.max(-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(-1, keepdims=True)
        return np.array([self._rng.choice(len(q), p=q) for q in p],
                        dtype=np.int32)

    def run_wave(self) -> list[Result]:
        """Serve one wave: take up to max_batch queued requests, prefill
        (padded to a common length), decode until all finish."""
        if not self.queue:
            return []
        batch_reqs = [self.queue.popleft()
                      for _ in range(min(self.max_batch, len(self.queue)))]
        B = len(batch_reqs)
        S = max(len(r.prompt) for r in batch_reqs)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(batch_reqs):
            toks[i, S - len(r.prompt):] = r.prompt  # left-pad
        logits, state = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        logits = np.asarray(logits, np.float32)

        budget = max(r.max_new_tokens for r in batch_reqs)
        out = [[] for _ in range(B)]
        done = np.zeros(B, bool)
        steps = 0
        cur = self._sample(logits)
        for i in range(B):
            out[i].append(int(cur[i]))
        while steps < budget - 1 and not done.all():
            logits, state = self._decode(self.params, jnp.asarray(cur), state)
            logits = np.asarray(logits, np.float32)
            cur = self._sample(logits)
            steps += 1
            for i, r in enumerate(batch_reqs):
                if done[i]:
                    continue
                tok = int(cur[i])
                out[i].append(tok)
                if (r.eos_id is not None and tok == r.eos_id) or (
                        len(out[i]) >= r.max_new_tokens):
                    done[i] = True
        return [
            Result(uid=r.uid, tokens=np.array(out[i], np.int32),
                   prompt_len=len(r.prompt), steps=len(out[i]))
            for i, r in enumerate(batch_reqs)
        ]

    def run_until_empty(self) -> list[Result]:
        results = []
        while self.queue:
            results.extend(self.run_wave())
        return results
