"""Continuous-batching serving engine with chunked admission prefill and
per-request energy accounting.

The engine keeps one batched decode state of ``max_batch`` fixed slots.
Admission is **chunked and fused into the decode loop**: queued prompts
are split into power-of-two chunk buckets (`ops.chunk_buckets`) and every
engine step processes one chunk call over the whole *admission lane* — a
compact pow2-width batch of all in-flight admissions — alongside the
lockstep decode step of the resident slots. A long prompt therefore never
stops the world (resident slots keep generating between its chunks), and
queued short prompts prefill together in one bucketed call instead of N
serial traces — the TTFT stall under load that serialized slot prefill
produced. KV rows are written at per-row cache offsets (chunk base +
row index — `layers.cache_update` / `attention_mask` per-row contract),
and SSM/SSD conv+scan state is carried across chunk boundaries
bit-exactly (`ssm.SERVE_CHUNK`), which promotes mamba1/mamba2/hybrid out
of the wave-mode fallback. A finished admission row is spliced into its
reserved decode slot (`layers.take_slot_state` + `insert_slot_state`).

Bit parity is the hard contract: a prompt prefilled in chunks produces
the identical greedy stream to a single-shot prefill
(``admission="serial"``, the PR 4 path, kept as a baseline) and to the
wave loop. Each request carries telemetry (queue time, TTFT, resident
decode steps, tokens/s) and an energy estimate: the engine prices each
chunk call and each decode step via `core.energy.gemm_fleet_energy` (a
fused engine step is decode rows + chunk rows —
`core.energy.fused_step_energy` combines the fleets) and attributes each
call's per-row share to the occupying request. `report()` aggregates
tokens/s, J/token and slot occupancy for benchmarks to regress.

The legacy wave API (`run_wave`) remains as a compatibility shim: one
batched right-padded prefill, lockstep decode until every request in the
wave finishes. Finished rows keep executing until the wave drains — which
is exactly the waste continuous mode exists to remove — but EOS / budget
termination (including on the *first* sampled token) is honored in both
modes.
"""

from __future__ import annotations

import bisect
import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

# families whose decode state supports per-row indices + slot surgery and
# whose prefill honors the right-padded `lengths` contract (attention KV
# caches via per-row cache_update/attention_mask; SSM/SSD state via
# seq_lens pad-skipping). encdec/vlm are *admit families*: their modality
# inputs (source embeddings / patch prefix) run through a prefill-once
# admission call (`ModelApi.admit`) whose outputs live in the decode state
# like any other cache leaf, after which the text prompt chunks through
# the same right-pad path as everyone else. MoE expert capacity is per
# row (`moe.moe_ffn_apply`), so rows are batch-independent at any
# capacity factor.
CONTINUOUS_KINDS = ("dense", "moe", "mla_moe", "mamba1", "mamba2",
                    "hybrid", "encdec", "vlm")


@dataclasses.dataclass
class Request:
    """One generation request: a prompt, a budget, an optional EOS id."""

    uid: int
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 32
    eos_id: int | None = None
    submit_s: float = 0.0       # stamped by ServingEngine.submit
    submit_model_s: float = 0.0  # engine model-clock at submission
    sla: str | None = None      # SLA-class name (FleetScheduler telemetry)
    # tokens a failed attempt already emitted (fault recovery): the
    # request prefills over prompt + replay[:-1] and decodes from the
    # last emitted token, so the client-visible stream stays an
    # append-only continuation and the final Result carries the full
    # stream exactly once. Chunked-admission path only.
    replay: list[int] | None = None
    # modality inputs consumed by the family's prefill-once admission:
    # encdec {"src_embeds": (T, d)}, vlm {"patch_embeds": (P, d),
    # "grid_hw": (gh, gw)}; None for text-only requests
    extras: dict | None = None


@dataclasses.dataclass
class Result:
    """A finished request's tokens plus latency/energy telemetry."""

    uid: int
    tokens: np.ndarray          # generated ids (includes EOS if emitted)
    prompt_len: int
    steps: int                  # decode iterations the request was resident
    n_tokens: int = 0           # generated-token count (energy denominator)
    queue_s: float = 0.0        # submit -> prefill start
    ttft_s: float = 0.0         # submit -> first token
    ttft_model_s: float = 0.0   # submit -> first token, model clock
    decode_s: float = 0.0       # first token -> last token
    tokens_per_s: float = 0.0
    energy_j: float = 0.0       # attributed prefill + resident-step energy
    energy_per_token_j: float = 0.0


@dataclasses.dataclass
class _Slot:
    req: Request
    tokens: list[int]
    prefill_energy_j: float
    t_start: float              # prefill start (wall)
    t_first: float              # first-token time (wall)
    t_first_model: float = 0.0  # first-token time (model clock)
    steps: int = 0              # resident decode iterations so far
    rng: np.random.Generator | None = None   # per-request sampling stream
    pages: list[int] | None = None  # paged layout: owned/shared page ids
    index: int = 0              # paged layout: host-tracked cache position
    # paged admit families: per-request dense admission leaves (encdec
    # cross-KV + src_len, vlm pos_off) concatenated into each call's state
    extra_top: dict | None = None
    extra_kv: dict | None = None


@dataclasses.dataclass
class _Admission:
    """A request mid-chunked-prefill: `row` in the admission-lane state,
    `base` prompt tokens written. Admission is decoupled from decode-slot
    availability: the first token is sampled when the last chunk lands
    (TTFT is lane-bound, not slot-bound), after which the finished row
    *parks* in the lane (`ready`/`first_tok`) until a decode slot frees
    and it is spliced in."""
    req: Request
    row: int = -1
    base: int = 0
    chunk_energy_j: float = 0.0
    t_start: float = 0.0        # first chunk dispatch (wall)
    rng: np.random.Generator | None = None
    ready: "_Slot | None" = None  # prefilled + first token sampled
    first_tok: int = 0
    pages: list[int] | None = None  # paged layout: reserved page ids
    prefix: int = 0             # admission-prefix cache rows (vlm patches)
    extra_top: dict | None = None   # paged admit families (see _Slot)
    extra_kv: dict | None = None
    # effective prefill token sequence: the prompt, extended by the
    # already-emitted replay prefix for fault-recovery requests (the
    # last replay token is decoded, not prefilled)
    eff: np.ndarray | None = None


class _LiveState:
    """The chunked stepper's cross-yield mutable state, held on the
    engine (not in generator locals) so `checkpoint_inflight` can
    surgically extract in-flight rows when the fleet scheduler declares
    this member crashed or evicted."""

    __slots__ = ("slots", "batch_state", "token_buf", "adm", "adm_state",
                 "adm_w", "lane_free", "lane_dirty", "zero_src")

    def __init__(self, max_batch: int):
        self.slots: list[_Slot | None] = [None] * max_batch
        self.batch_state = None
        self.token_buf = np.zeros(max_batch, np.int32)
        self.adm: list[_Admission] = []
        self.adm_state = None
        self.adm_w = 0
        self.lane_free: list[int] = []
        self.lane_dirty: set[int] = set()
        self.zero_src = None


# families whose cache the paged layout supports: per-token KV (or MLA
# latent) rows that page cleanly. SSM/hybrid state is O(1)-per-row (or
# mixed) and stays dense. encdec/vlm page their decoder self-attention
# KV; encdec's cross-KV stays dense per-request (read-only after
# admission, never grows).
PAGED_KINDS = ("dense", "moe", "mla_moe", "encdec", "vlm")


class ServingEngine:
    """Continuous-batching serving engine (see the module docstring for
    the serving model; `docs/serving.md` for the full guide)."""

    def __init__(self, model, params, cfg: ModelConfig, *,
                 max_batch: int = 8, max_len: int = 512,
                 greedy: bool = True, seed: int = 0,
                 mode: str = "auto",
                 admission: str = "chunked", chunk_tokens: int = 64,
                 kv_layout: str = "dense", page_size: int = 64,
                 num_pages: int | None = None, prefix_cache: bool = True,
                 pretune: bool = False, tune_objective: str = "runtime",
                 tune_rank_mode: str = "auto",
                 chip: str | None = None,
                 tp: int = 1, mesh=None, tp_overlap_chunks: int = 4,
                 ssm_serve_grain: int | None = None):
        """`mode` picks the serving loop: "continuous" (slot table with
        mid-decode retire/refill), "wave" (legacy batch-of-waves), or
        "auto" (continuous for the families that support per-slot decode
        state — see CONTINUOUS_KINDS — wave otherwise).

        `kv_layout` picks the KV-cache layout: "dense" (one max_len
        buffer per decode slot and lane row) or "paged" (a shared pool of
        `page_size`-token pages with per-row page tables, host-side
        free-list allocator, and — with `prefix_cache` — shared-prefix
        page reuse across requests; see `repro.serving.paging`).
        `num_pages` sizes the pool (default: full capacity for every slot
        and lane row, i.e. no HBM saving until callers lower it). Paged
        serving requires chunked admission, a PAGED_KINDS family, and
        `page_size` dividing `max_len`; token streams are bit-identical
        to the dense layout.

        `admission` picks how continuous mode prefills: "chunked"
        (default — prompts feed through the decode loop `chunk_tokens`
        tokens per engine step, queued admissions batched into one
        bucketed call) or "serial" (the PR 4 baseline: each request
        prefills alone in one single-shot call, stalling the loop for the
        whole prompt). Both produce bit-identical token streams.

        `pretune=True` batch-tunes the engine's GEMM fleet up front:
        every projection/FFN/head shape the batched prefill (max_batch *
        max_len rows), the decode step (max_batch rows), and each
        (admission-width x chunk-bucket) chunk call will trace goes
        through one `ops.warm_gemm_cache` pass (predictor-ranked,
        substrate-verified, cached per chip + artifact version), so the
        first request pays no per-shape autotuning. `tune_objective`
        picks the paper's serving objective ("runtime", "energy",
        "power", "edp"); `tune_rank_mode` picks the candidate-ranking
        path ("auto" ranks fully in-graph on accelerator backends, at
        trace time on CPU).

        `tp > 1` serves tensor-parallel over a (1, tp) device mesh
        (`mesh` overrides the default `launch.mesh.make_serving_mesh`):
        the config is flipped to explicit gather-mode TP collectives
        (`tp_reduce="gather"` — bit-identical streams to tp=1, see
        `docs/serving.md`), params and decode caches are sharded along
        the head/expert axes, row-parallel all-gathers are interleaved
        with the GEMM in `tp_overlap_chunks` column chunks, and the
        energy model prices the per-shard fleet plus the ring traffic.

        `ssm_serve_grain` widens the SSM serve-scan block (default
        `ops.SSM_SERVE_GRAIN`) — a pow2 multiple of it; chunk boundaries
        and prefill buckets align to the grain, so long SSM prompts scan
        in fewer, larger blocks per chunk call.
        """
        from repro.kernels import ops

        self.tp = max(int(tp), 1)
        self.mesh = None
        grain = int(ssm_serve_grain) if ssm_serve_grain else 0
        if grain and (grain < ops.SSM_SERVE_GRAIN
                      or grain % ops.SSM_SERVE_GRAIN
                      or grain & (grain - 1)):
            raise ValueError(
                f"ssm_serve_grain={grain} must be a power-of-two "
                f"multiple of {ops.SSM_SERVE_GRAIN}")
        self.ssm_grain = grain or ops.SSM_SERVE_GRAIN
        overrides: dict = {}
        if self.tp > 1:
            # gather-mode explicit collectives: the one TP strategy that
            # keeps greedy streams bit-identical to tp=1 (no psum/split-k
            # fp32 re-association anywhere in the layer graph)
            overrides.update(tp_collectives="explicit", tp_reduce="gather",
                             tp_overlap_chunks=max(int(tp_overlap_chunks),
                                                   1))
        if grain:
            overrides["ssm_serve_grain"] = grain
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        if self.tp > 1:
            from repro.distributed.sharding import param_shardings
            from repro.launch.mesh import make_serving_mesh

            self.mesh = mesh if mesh is not None else make_serving_mesh(
                self.tp)
            if ("model" not in self.mesh.axis_names
                    or self.mesh.shape["model"] != self.tp):
                raise ValueError(
                    f"mesh {dict(self.mesh.shape)} must carry a 'model' "
                    f"axis of size tp={self.tp}")
            params = jax.device_put(
                params, param_shardings(params, self.mesh,
                                        tp_reduce="gather"))

        self.model = model
        self.params = params
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.greedy = greedy
        if mode not in ("auto", "continuous", "wave"):
            raise ValueError(f"unknown serving mode {mode!r}")
        if admission not in ("chunked", "serial"):
            raise ValueError(f"unknown admission mode {admission!r}")
        self.mode = mode
        self.admission = admission
        if (admission == "chunked" and chunk_tokens < max_len
                and chunk_tokens % self.ssm_grain):
            # chunk boundaries must stay multiples of the SSM serve-scan
            # block or chunked prefill loses bit parity for SSM families
            raise ValueError(
                f"chunk_tokens={chunk_tokens} must be a multiple of "
                f"{self.ssm_grain} (or >= max_len)")
        if (admission == "chunked" and cfg.sub_quadratic
                and cfg.attention_free and max_len < self.ssm_grain):
            # attention-free prompts may exceed max_len (multi-chunk), and
            # non-final chunk boundaries then need an SSM-grain-aligned
            # bucket, which a sub-grain bucket ladder cannot provide
            raise ValueError(
                f"max_len={max_len} < {self.ssm_grain} cannot serve "
                f"chunked SSM prefill; raise max_len or use wave mode")
        self.chunk_tokens = chunk_tokens
        # admission-lane capacity: prefill (and first-token sampling) for
        # up to this many in-flight requests is decoupled from decode-slot
        # availability — finished admissions park in the lane until a slot
        # frees, so TTFT under a burst is lane-bound, not retirement-bound
        self.lane_width = 2 * max_batch
        if kv_layout not in ("dense", "paged"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}")
        self.kv_layout = kv_layout
        self.page_size = page_size
        self._allocator = None
        self._pool = None           # device page pool, built on first run
        self._copy_pages = None
        if kv_layout == "paged":
            from repro.serving.paging import PageAllocator

            if cfg.kind not in PAGED_KINDS:
                raise ValueError(
                    f"kv_layout='paged' unsupported for kind="
                    f"{cfg.kind!r} (SSM/hybrid state is O(1) per row, "
                    f"not per token); use dense")
            if mode == "wave" or admission != "chunked":
                raise ValueError(
                    "kv_layout='paged' requires continuous serving with "
                    "admission='chunked'")
            if max_len % page_size:
                raise ValueError(
                    f"page_size={page_size} must divide "
                    f"max_len={max_len} (page tables span max_len)")
            self._n_row_pages = max_len // page_size
            if num_pages is None:
                # full capacity for every slot and lane row + null page:
                # parity-safe default; benches shrink it to realize the
                # fixed-HBM concurrency win
                num_pages = ((max_batch + self.lane_width)
                             * self._n_row_pages + 1)
            self._allocator = PageAllocator(num_pages, page_size,
                                            prefix_cache=prefix_cache)
        self.queue: deque[Request] = deque()
        self.seed = seed
        if chip is not None:
            # validate eagerly: a chip typo must raise here, not silently
            # zero every energy estimate later
            from repro.core.chips import get_chip

            chip = get_chip(chip).name
        self.chip = chip
        self.pretuned: dict[tuple, object] = {}
        if pretune:
            fleet = ops.serving_gemm_fleet(
                cfg, max_batch=max_batch, max_len=max_len,
                include_slot_prefill=self._continuous_supported(),
                chunk_tokens=(chunk_tokens if admission == "chunked"
                              else None),
                lane_width=(self.lane_width if admission == "chunked"
                            else None),
                tp=self.tp, grain=self.ssm_grain)
            self.pretuned = ops.warm_gemm_cache(
                fleet, dtype=cfg.activation_dtype,
                objective=tune_objective, chip=chip,
                rank_mode=tune_rank_mode)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cfg, max_len=max_len))
        # decode/chunk/splice rebind their state output over the input:
        # donating the input state lets XLA update the KV caches in place
        # instead of copying the whole decode state every step
        self._decode = jax.jit(
            lambda p, t, s: model.decode_step(p, t, s, cfg),
            donate_argnums=(2,))
        self._chunk = (jax.jit(
            lambda p, t, ln, s: model.prefill_chunk(p, t, ln, s, cfg),
            donate_argnums=(3,))
            if model.prefill_chunk is not None else None)
        # prefill-once admission call for admit families (encdec source
        # encoding + cross-KV, vlm patch prefix); batch-generic, donates
        # the state it writes into
        self._admit_fn = (jax.jit(
            lambda p, pk, s: model.admit(p, pk, s, cfg),
            donate_argnums=(2,))
            if getattr(model, "admit", None) is not None else None)
        self._splice_fn = None          # built lazily with the axes spec
        self._state_axes = None
        # model clock: predicted seconds of dispatched engine calls (the
        # analytical GEMM model's step_s), advanced per prefill/chunk/
        # decode call. TTFT measured against it is deterministic and
        # hardware-independent — the regression surface CI gates on.
        self._clock = 0.0
        self._step_energy_cache: dict[tuple | str | int, object] = {}
        # scheduler hooks (repro.serving.scheduler): `chunk_policy` is an
        # optional callable `(engine, pending) -> int | None` consulted by
        # the chunk stage — `pending` is a list of (Request,
        # remaining_prompt_tokens) for the rows still prefilling; a
        # returned token count is snapped up to the chunk-bucket ladder
        # (SSM-grain alignment still applies), None keeps the default SJF
        # sizing. `_stepper` holds the resumable chunked-serving generator
        # behind `serve_step`; `_lane_view` is the host-visible admission
        # snapshot refreshed after every step (routing reads it).
        self.chunk_policy = None
        self._stepper = None
        self._live: _LiveState | None = None
        self._lane_view = {"pending": 0, "pending_tokens": 0,
                           "parked": 0, "resident": 0, "in_flight": 0}
        # fault recovery: decode-state rows checkpointed off a failed
        # fleet member, waiting for a free decode slot here (`adopt`);
        # degraded-mode tuning flag set by `retune` on ArtifactError
        self._adopted: deque[dict] = deque()
        self.tuning_degraded = False
        self._degraded_reason: str | None = None
        # engine-level counters (reset per run_* call family, reported
        # cumulatively)
        self._stats = {
            "decode_steps": 0, "chunk_steps": 0,
            "resident_slot_steps": 0.0,
            "slot_steps": 0.0, "generated_tokens": 0, "energy_j": 0.0,
            "idle_energy_j": 0.0, "requests": 0, "wall_s": 0.0,
            # model-clock seconds of dispatched calls, collective wire
            # time on the links, and the share hidden behind GEMM compute
            # (tp=1 leaves the wire terms at zero); lane_rebuilds counts
            # admission-lane reallocations (free-list reuse keeps it at
            # width growths only)
            "model_s": 0.0, "wire_s": 0.0, "hidden_wire_s": 0.0,
            "lane_rebuilds": 0,
            # fault-recovery ledger: energy a failed attempt spent on
            # work that had to be replayed (charged here, to the failed
            # member, never to the request's final Result) and rows this
            # engine adopted from a failed member
            "lost_energy_j": 0.0, "adopted_in": 0,
        }

    # ------------------------------------------------------------------
    # mesh / clock
    # ------------------------------------------------------------------
    def _activate(self) -> None:
        """Install this engine's mesh rules on the thread (clearing them
        for tp=1 engines). Jitted calls trace lazily, so the rules must
        be the engine's own at dispatch time — engines of different tp
        degrees can interleave in one process."""
        from repro.distributed.sharding import set_mesh_rules

        set_mesh_rules(self.mesh)

    def _tick(self, step_s: float, est=None) -> None:
        """Advance the model clock by one dispatched call's predicted
        time and fold its collective wire telemetry into the counters
        (`report()`'s model_tokens_per_s / overlap_factor surface)."""
        self._clock += step_s
        self._stats["model_s"] += step_s
        if est is not None and getattr(est, "collective_s", 0.0) > 0.0:
            self._stats["wire_s"] += est.collective_s
            self._stats["hidden_wire_s"] += (est.overlap_factor
                                             * est.collective_s)

    @property
    def model_clock_s(self) -> float:
        """Current model-clock reading (predicted seconds of every call
        this engine has dispatched, monotone across runs). The fleet
        scheduler orders engine steps by it."""
        return self._clock

    @property
    def chip_spec(self):
        """The `ChipSpec` this engine prices energy on (`tpu_v5e` when
        no chip was named at construction)."""
        from repro.core.chips import get_chip

        return get_chip(self.chip or "tpu_v5e")

    @property
    def idle_power_w(self) -> float:
        """Idle-floor power of this engine's whole chip fleet (per-chip
        `ChipSpec.idle_power_w` x tp chips) — what a parked engine burns
        per model-clock second in the fleet scheduler's ledger."""
        return self.chip_spec.idle_power_w * self.tp

    @property
    def has_work(self) -> bool:
        """True while the engine holds queued or in-flight requests
        (adopted rows included). May stay True for one extra
        `serve_step()` after the last retirement (the step that observes
        the drained loop returns `[]`)."""
        return (bool(self.queue) or bool(self._adopted)
                or self._stepper is not None)

    @property
    def lane_view(self) -> dict:
        """Host-visible admission-lane snapshot, refreshed after every
        `serve_step`: rows still prefilling (`pending` /
        `pending_tokens`), parked rows awaiting a decode slot, resident
        decode slots, and total in-flight admissions."""
        return dict(self._lane_view)

    @property
    def backlog_tokens(self) -> int:
        """Prompt tokens this engine still has to prefill: queued prompts
        plus the unwritten remainder of in-flight admissions. The fleet
        scheduler's TTFT predictor divides this by chunk throughput."""
        return (sum(len(r.prompt) for r in self.queue)
                + int(self._lane_view["pending_tokens"]))

    # ------------------------------------------------------------------
    # queue
    # ------------------------------------------------------------------
    def _row_capacity(self) -> int | None:
        """Per-row cache capacity in tokens, or None when unbounded —
        the ONE length bound `submit`/`_budget` apply, uniformly per row.
        Attention KV caches (and encdec's cross-KV leaves) hold `max_len`
        rows; attention-free SSM state is O(1) per row, so its capacity
        is unbounded (long prompts scan through in multiple chunks)."""
        return None if self.cfg.attention_free else self.max_len

    def _admit_dims(self, req: Request) -> tuple[int, int]:
        """(cache-prefix rows, side source rows) this request's admission
        consumes ahead of its prompt — (0, 0) for families without
        admission hooks. Validates the request's `extras` as a side
        effect (encdec requires source embeddings)."""
        if self.model.admit_dims is None:
            return (0, 0)
        return self.model.admit_dims(self.cfg, req.extras)

    def submit(self, req: Request) -> None:
        """Queue a request (stamps submit wall/model-clock times).

        One uniform per-row bound across every family: the row's
        admission prefix + prompt must fit its cache capacity with at
        least one decode position to spare, and an encdec source must fit
        the row's cross-KV capacity. Unbounded-capacity (attention-free)
        rows skip the bound entirely."""
        prefix, src = self._admit_dims(req)
        cap = self._row_capacity()
        if cap is not None:
            if prefix + len(req.prompt) >= cap:
                raise ValueError(
                    f"admission prefix {prefix} + prompt of "
                    f"{len(req.prompt)} tokens does not fit "
                    f"max_len={cap} (need >= 1 decode position)")
            if src > cap:
                raise ValueError(
                    f"source of {src} rows does not fit the per-row "
                    f"cross-KV capacity max_len={cap}")
        if req.submit_s == 0.0:
            req.submit_s = time.perf_counter()
        req.submit_model_s = self._clock
        self.queue.append(req)

    # ------------------------------------------------------------------
    # sampling
    # ------------------------------------------------------------------
    def _req_rng(self, uid: int) -> np.random.Generator:
        """Each request samples from its own (engine seed, uid) stream, so
        its tokens can never depend on which neighbors share the batch or
        when they retire."""
        return np.random.default_rng((self.seed, uid))

    def _sample(self, logits: np.ndarray,
                rngs: list[np.random.Generator | None] | None = None
                ) -> np.ndarray:
        """Next token per row. Greedy is a single vectorized argmax.
        Non-greedy draws a per-request Gumbel-max (`_req_rng` streams;
        `rngs[b] is None` marks a finished/dead row) — dead slots neither
        advance any RNG nor influence live rows."""
        if self.greedy:
            return logits.argmax(-1).astype(np.int32)
        out = np.zeros(logits.shape[0], np.int32)
        for b, rng in enumerate(rngs or []):
            if rng is None:
                continue
            z = logits[b]
            out[b] = np.int32((z + rng.gumbel(size=z.shape)).argmax())
        return out

    # ------------------------------------------------------------------
    # energy model
    # ------------------------------------------------------------------
    def _kv_gather_bytes(self, batch_rows: int) -> float:
        """Non-GEMM KV-cache HBM traffic one call issues: attention reads
        each row's cached keys/values once under the dense layout; the
        paged layout additionally materializes the gathered per-row view
        through the page table before reading it — 2x the cache bytes.
        Pricing both layouts keeps the bench's J/token comparison
        apples-to-apples (zero for attention-free families either way).
        Sharded engines read 1/tp of the cache per chip (head-sharded
        K/V); MLA's latent cache is replicated, so it is not divided."""
        from repro.models.config import kv_cache_bytes

        scale = 2.0 if self.kv_layout == "paged" else 1.0
        if self.cfg.kind == "encdec":
            # decode reads each row's dense cross-KV leaves (max_len
            # source-row capacity) alongside the self-attention cache
            scale += 1.0
        shard = 1 if self.cfg.kind == "mla_moe" else self.tp
        return (scale * kv_cache_bytes(self.cfg, batch_rows * self.max_len)
                / max(shard, 1))

    def _step_energy(self, key, n_rows: int, head_rows: int | None = None,
                     batch_rows: int | None = None,
                     src_rows: int | None = None):
        """Predicted StepEnergyEstimate for a step over `n_rows` GEMM rows
        (decode: max_batch; prefill/chunk: padded token count, with the LM
        head sized to the rows actually unembedded and MLA's cache-wide
        K/V decompression sized to batch_rows * max_len), cached per key.
        Under the paged layout the per-call page-gather traffic is charged
        as extra HBM bytes. Returns None (once, with a warning) when the
        energy model is unavailable."""
        hit = self._step_energy_cache.get(key, "miss")
        if hit != "miss":
            return hit
        try:
            from repro.core.energy import gemm_fleet_energy
            from repro.models.config import (collective_wire_bytes,
                                             gemm_shape_counts)

            kv_rows = (batch_rows * self.max_len
                       if batch_rows is not None else None)
            wire_b, n_coll = collective_wire_bytes(
                self.cfg, n_rows, self.tp, head_tokens=head_rows,
                src_tokens=src_rows)
            est = gemm_fleet_energy(
                gemm_shape_counts(self.cfg, n_rows, head_tokens=head_rows,
                                  kv_rows=kv_rows, tp=self.tp,
                                  src_tokens=src_rows),
                chip=self.chip or "tpu_v5e",
                dtype=self.cfg.activation_dtype,
                configs=self.pretuned or None,
                extra_hbm_bytes=self._kv_gather_bytes(batch_rows or 0),
                tp=self.tp, collective_bytes=wire_b,
                n_collectives=n_coll,
                overlap_chunks=getattr(self.cfg, "tp_overlap_chunks", 1),
                name=f"{self.cfg.name}:{key}")
        except Exception as e:
            import warnings

            warnings.warn(
                f"serving energy model unavailable ({e!r}); "
                f"energy telemetry for step {key!r} will read 0",
                stacklevel=2)
            est = None
        self._step_energy_cache[key] = est
        return est

    @staticmethod
    def _cost(est) -> tuple[float, float, object]:
        """(energy_j, step_s, estimate) of a priced step — zeros (and a
        None estimate) when the energy model is unavailable."""
        if est is None:
            return (0.0, 0.0, None)
        return (est.energy_j, est.step_s, est)

    def _decode_cost(self) -> tuple[float, float, object]:
        """(energy_j, predicted step_s, est) of one lockstep decode
        step."""
        return self._cost(self._step_energy(
            ("decode", self.max_batch), self.max_batch,
            batch_rows=self.max_batch))

    def _prefill_cost(self, n_tokens: int, head_rows: int
                      ) -> tuple[float, float, object]:
        """(energy_j, step_s, est) of one prefill over `n_tokens` padded
        rows unembedding `head_rows` last positions (1 for slot prefill,
        B for a wave). `head_rows` is also the prefill's batch-row count,
        which sizes MLA's cache-wide decompression."""
        return self._cost(self._step_energy(
            ("prefill", int(n_tokens), int(head_rows)),
            int(n_tokens), int(head_rows), batch_rows=int(head_rows)))

    def _chunk_cost(self, width: int, chunk: int
                    ) -> tuple[float, float, object]:
        """(energy_j, step_s, est) of one admission chunk call: `width`
        lane rows of `chunk` tokens, LM head over last-valid positions."""
        return self._cost(self._step_energy(
            ("chunk", int(width), int(chunk)),
            int(width * chunk), int(width), batch_rows=int(width)))

    def _admit_cost(self, width: int, bucket: int
                    ) -> tuple[float, float, object]:
        """(energy_j, step_s, est) of one prefill-once admission call:
        encdec prices the encoder stack + per-decoder-layer cross-KV
        projections over `width * bucket` source rows (no decoder-token
        rows); vlm prices the patch prefix through the decoder. Neither
        runs the LM head."""
        if self.cfg.kind == "encdec":
            return self._cost(self._step_energy(
                ("admit", int(width), int(bucket)), 0, 0,
                batch_rows=int(width), src_rows=int(width * bucket)))
        return self._cost(self._step_energy(
            ("admit", int(width), int(bucket)), int(width * bucket), 0,
            batch_rows=int(width)))

    def decode_step_estimate(self):
        """Predicted `StepEnergyEstimate` of one lockstep decode step
        over the full slot table — the public handle the fleet
        scheduler's marginal-cost pricing divides per slot (None when
        the energy model is unavailable)."""
        return self._decode_cost()[2]

    def fused_step_estimate(self, width: int, chunk: int):
        """Predicted cost of one *fused* engine step — the decode fleet
        (max_batch rows) plus one chunk call's fleet (`width` x `chunk`
        rows) priced through a single duty-cycle power model
        (`core.energy.fused_step_energy`). Cached per (width, chunk):
        the fleet scheduler prices every candidate placement through
        this, so repeat lookups must be dict-cheap."""
        key = ("fused", int(width), int(chunk))
        hit = self._step_energy_cache.get(key, "miss")
        if hit != "miss":
            return hit
        from repro.core.energy import fused_step_energy
        from repro.models.config import (collective_wire_bytes,
                                         gemm_shape_counts)

        decode = gemm_shape_counts(self.cfg, self.max_batch,
                                   kv_rows=self.max_batch * self.max_len,
                                   tp=self.tp)
        ch = gemm_shape_counts(self.cfg, width * chunk, head_tokens=width,
                               kv_rows=width * self.max_len, tp=self.tp)
        wb_d, nc_d = collective_wire_bytes(self.cfg, self.max_batch,
                                           self.tp)
        wb_c, nc_c = collective_wire_bytes(self.cfg, width * chunk,
                                           self.tp, head_tokens=width)
        est = fused_step_energy(
            decode, ch, chip=self.chip or "tpu_v5e",
            dtype=self.cfg.activation_dtype,
            configs=self.pretuned or None,
            extra_hbm_bytes=(self._kv_gather_bytes(self.max_batch)
                             + self._kv_gather_bytes(width)),
            tp=self.tp, collective_bytes=wb_d + wb_c,
            n_collectives=nc_d + nc_c,
            overlap_chunks=getattr(self.cfg, "tp_overlap_chunks", 1),
            name=f"{self.cfg.name}:fused:{width}x{chunk}")
        self._step_energy_cache[key] = est
        return est

    # ------------------------------------------------------------------
    # continuous batching
    # ------------------------------------------------------------------
    def _continuous_supported(self) -> bool:
        if self.cfg.kind not in CONTINUOUS_KINDS:
            return False
        if (self.model.admit_dims is not None
                and (self.model.admit is None
                     or self.model.pack_admit is None)):
            return False
        if self.kv_layout == "paged":
            return (self.model.prefill_chunk is not None
                    and self.model.init_page_pool is not None)
        if self.admission == "chunked" or self.model.admit is not None:
            # admit families run serial admission through the same
            # admit + full-prompt-chunk path chunked admission uses
            return (self.model.prefill_chunk is not None
                    and self.model.init_state is not None)
        return (self.model.init_cache is not None
                and self.model.init_state is not None)

    def _bucket(self, n: int) -> int:
        """Smallest prefill bucket holding `n` prompt tokens — a bisect
        over the memoized `ops.prefill_buckets` tuple (the same list
        `serving_gemm_fleet` pre-tunes, so prefills only ever trace
        pre-warmed shapes). Attention-free prompts may exceed max_len;
        the bucket ladder keeps doubling past it."""
        from repro.kernels import ops

        buckets = ops.prefill_buckets(self.max_len, self.ssm_grain)
        i = bisect.bisect_left(buckets, n)
        if i < len(buckets):
            return buckets[i]
        b = buckets[-1]
        while b < n:
            b *= 2
        return b

    def _chunk_bucket(self, n: int) -> int:
        """Smallest chunk bucket holding `n` remaining prompt tokens,
        capped at `chunk_tokens` (longer remainders feed through the
        decode loop one chunk per step)."""
        from repro.kernels import ops

        buckets = ops.chunk_buckets(self.max_len, self.chunk_tokens,
                                    self.ssm_grain)
        i = bisect.bisect_left(buckets, n)
        return buckets[min(i, len(buckets) - 1)]

    def _budget(self, req: Request) -> int:
        """Effective token budget: >= 1, bounded by the row's remaining
        cache room — capacity minus its admission prefix and its own
        prompt length. This is the uniform per-row `lengths` bound; no row
        is ever clamped by another row's padded length."""
        cap = self._row_capacity()
        if cap is None:
            return max(1, req.max_new_tokens)
        prefix, _ = self._admit_dims(req)
        return max(1, min(req.max_new_tokens,
                          cap - prefix - len(req.prompt)))

    def _init_state(self, batch: int):
        """Zeroed decode-state pytree of `batch` rows (head-axis-sharded
        under tp — `sharding.SERVING_STATE_AXES`). Not cached: the jitted
        consumers donate their state argument, so a shared zero state
        would be consumed by its first use."""
        state = self.model.init_state(self.cfg, batch, self.max_len)
        if self.mesh is not None:
            from repro.distributed.sharding import serving_state_shardings

            state = jax.device_put(
                state, serving_state_shardings(state, self.mesh))
        return state

    def _ensure_splice(self) -> None:
        """Discover the decode-state batch-axis spec (state shapes at
        batch 1 vs 2, via eval_shape — no allocation) and jit the row
        splice: take row `i` of `src`, insert as row `j` of `dst`."""
        if self._splice_fn is not None:
            return
        from repro.models import layers as L

        # bypass the zero-state cache: eval_shape traces, and caching a
        # traced pytree would leak tracers into later real calls
        s1 = jax.eval_shape(
            lambda: self.model.init_state(self.cfg, 1, self.max_len))
        s2 = jax.eval_shape(
            lambda: self.model.init_state(self.cfg, 2, self.max_len))
        axes = L.state_batch_axes(s1, s2)
        self._state_axes = axes
        self._splice_fn = jax.jit(
            lambda dst, src, i, j: L.insert_slot_state(
                dst, L.take_slot_state(src, axes, i), axes, j),
            donate_argnums=(0,))

    def _admit_rows(self, reqs: list[Request], width: int
                    ) -> tuple[dict, float]:
        """Prefill-once admission of `reqs` into a fresh `width`-row zero
        state, one batched call (the wave path admits a whole batch at
        once; chunked admission packs the step's fresh admissions).
        Returns (admitted state, total admission energy)."""
        dims = [self._admit_dims(r) for r in reqs]
        bucket = self._bucket(max(max(p, s) for p, s in dims) or 1)
        packed = self.model.pack_admit(
            self.cfg, [r.extras for r in reqs], width, bucket)
        state = self._admit_fn(self.params, packed,
                               self._init_state(width))
        adm_j, adm_s, adm_est = self._admit_cost(width, bucket)
        self._tick(adm_s, adm_est)
        return state, adm_j

    def _prefill_slot(self, req: Request, rng) -> tuple[int, dict, float]:
        """Single-shot slot prefill (`admission="serial"`): one request
        alone, right-padded to a pow2 bucket; samples its first token.
        Admit families run their admission call plus one full-prompt
        chunk — the exact path chunked admission takes, so serial/chunked
        parity holds by construction. Returns (first_token, slot_state,
        prefill_energy_j)."""
        if req.replay:
            raise ValueError(
                "replay requests require chunked admission (serve_step)")
        n = len(req.prompt)
        bucket = self._bucket(n)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :n] = req.prompt
        if self._admit_fn is not None:
            state, adm_j = self._admit_rows([req], 1)
            logits, state = self._chunk(
                self.params, jnp.asarray(toks),
                jnp.asarray([n], np.int32), state)
            pre_j, pre_s, pre_est = self._chunk_cost(1, bucket)
            self._tick(pre_s, pre_est)
            logits = np.asarray(logits, np.float32)
            tok = int(self._sample(logits, [rng])[0])
            return tok, state, adm_j + pre_j
        logits, state = self._prefill(
            self.params, {"tokens": jnp.asarray(toks),
                          "lengths": jnp.asarray([n], np.int32)})
        logits = np.asarray(logits, np.float32)
        tok = int(self._sample(logits, [rng])[0])
        pre_j, pre_s, pre_est = self._prefill_cost(bucket, head_rows=1)
        self._tick(pre_s, pre_est)
        return tok, state, pre_j

    def _finish(self, slot: _Slot, now: float, decode_energy_j: float,
                results: list[Result]) -> None:
        req = slot.req
        n_tok = len(slot.tokens)
        decode_s = max(now - slot.t_first, 0.0)
        energy = (slot.prefill_energy_j
                  + slot.steps * decode_energy_j / self.max_batch)
        self._stats["generated_tokens"] += n_tok
        self._stats["energy_j"] += energy
        self._stats["requests"] += 1
        results.append(Result(
            uid=req.uid, tokens=np.array(slot.tokens, np.int32),
            prompt_len=len(req.prompt), steps=slot.steps,
            n_tokens=n_tok,
            queue_s=max(slot.t_start - req.submit_s, 0.0),
            ttft_s=max(slot.t_first - req.submit_s, 0.0),
            ttft_model_s=max(slot.t_first_model - req.submit_model_s, 0.0),
            decode_s=decode_s,
            tokens_per_s=(n_tok / decode_s if decode_s > 0 else 0.0),
            energy_j=energy,
            energy_per_token_j=energy / max(n_tok, 1)))

    def _decode_step(self, slots, batch_state, token_buf, decode_cost,
                     results):
        """One lockstep decode step over the slot table; retires finished
        slots in place. Returns the new batch state."""
        decode_energy_j, decode_step_s, decode_est = decode_cost
        B = self.max_batch
        active = np.array([s is not None for s in slots])
        if not active.any():
            return batch_state
        self._tick(decode_step_s, decode_est)
        logits, batch_state = self._decode(
            self.params, jnp.asarray(token_buf), batch_state)
        logits = np.asarray(logits, np.float32)
        cur = self._sample(
            logits, [s.rng if s is not None else None for s in slots])
        now = time.perf_counter()
        n_active = int(active.sum())
        self._stats["decode_steps"] += 1
        self._stats["slot_steps"] += B
        self._stats["resident_slot_steps"] += n_active
        # dead slots still execute: their energy share is real spend,
        # charged to the engine (idle) rather than to any request, so
        # report()'s J/token stays comparable with wave mode
        self._stats["idle_energy_j"] += (
            (B - n_active) * decode_energy_j / B)
        for b in range(B):
            slot = slots[b]
            if slot is None:
                continue
            tok = int(cur[b])
            slot.tokens.append(tok)
            slot.steps += 1
            token_buf[b] = tok
            req = slot.req
            if (req.eos_id is not None and tok == req.eos_id) or (
                    len(slot.tokens) >= self._budget(req)):
                self._finish(slot, now, decode_energy_j, results)
                slots[b] = None      # retired mid-decode; refilled
                token_buf[b] = 0     # next loop iteration
        return batch_state

    def run_continuous(self) -> list[Result]:
        """Drain the queue with true continuous batching: retire finished
        slots mid-decode and refill them immediately."""
        self._activate()
        if not self._continuous_supported():
            raise ValueError(
                f"continuous batching unsupported for kind="
                f"{self.cfg.kind!r} (needs the per-row decode-state "
                f"contract); use wave mode")
        if self.kv_layout == "paged":
            return self._run_paged()
        if self.admission == "serial":
            return self._run_serial()
        return self._run_chunked()

    def _run_chunked(self) -> list[Result]:
        """Chunked admission fused into the decode loop, driven through
        the resumable stepper (`serve_step`) to exhaustion — token
        streams and telemetry are identical to running the loop
        inline."""
        out: list[Result] = []
        while self.has_work:
            out.extend(self.serve_step())
        return out

    def serve_step(self) -> list[Result]:
        """Advance chunked continuous serving by exactly one fused engine
        step — admit from the queue, one bucketed chunk call over the
        admission lane, one lockstep decode step over the residents — and
        return the requests that finished during it.

        This is the fleet scheduler's handle on the engine: between
        steps the caller may submit more requests, install or retarget
        `chunk_policy`, and interleave steps of other engines (each
        engine advances its own model clock). Requires continuous mode
        with ``admission="chunked"`` and the dense KV layout — the
        paged/serial/wave loops are not steppable. Returns ``[]`` on the
        final call that observes the drained loop; poll `has_work` to
        drive to exhaustion."""
        self._activate()
        if self._stepper is None:
            if not self.queue and not self._adopted:
                return []
            if (self.mode == "wave" or self.admission != "chunked"
                    or self.kv_layout != "dense"
                    or not self._continuous_supported()):
                raise ValueError(
                    f"serve_step requires chunked continuous serving on "
                    f"the dense KV layout (kind={self.cfg.kind!r}, "
                    f"mode={self.mode!r}, admission={self.admission!r}, "
                    f"kv_layout={self.kv_layout!r})")
            self._ensure_splice()
            self._stepper = self._chunked_stepper()
        try:
            return next(self._stepper)
        except StopIteration:
            self._stepper = None
            self._live = None
            self._lane_view = dict.fromkeys(self._lane_view, 0)
            return []

    def _chunked_stepper(self):
        """Generator behind `serve_step`: owns the admission lane, slot
        table and decode state across yields, emitting each step's newly
        finished `Result`s. Created lazily on the first `serve_step` with
        a non-empty queue; exhausts (StopIteration) when queue, lane and
        slots all drain."""
        B = self.max_batch
        results: list[Result] = []
        # cross-yield mutable state lives on the engine (`_LiveState`)
        # so `checkpoint_inflight` can extract in-flight rows when the
        # fleet scheduler declares this member crashed or evicted
        lv = self._live = _LiveState(B)
        decode_cost = self._decode_cost()
        decode_energy_j = decode_cost[0]
        # lane-row free list (lv.lane_free): vacated rows (spliced-out,
        # or finished on their first token) are reused in place by later
        # admissions — the device lane state reallocates only when the
        # pow2 width must *grow* past its high-water mark (satellite of
        # the stall fix: steady-state churn costs zero lane rebuilds). A
        # vacated row still holds its old occupant's state (cache write
        # index, SSM scan carry), so reused rows are zeroed by a one-row
        # splice before the new admission's first chunk.

        def zero_lane_row(r: int) -> None:
            """Overwrite lane row `r` with zeros (row 0 of a cached
            1-row zero state — the splice jit donates only dst, so the
            source survives reuse)."""
            if lv.zero_src is None:
                lv.zero_src = self._init_state(1)
            lv.adm_state = self._splice_fn(lv.adm_state, lv.zero_src,
                                           jnp.int32(0), jnp.int32(r))

        def adopt_ready() -> None:
            """Splice adopted decode-state rows (checkpointed off a
            failed fleet member) into free decode slots. The row's
            accumulated energy rides in as its prefill energy, so the
            final Result's attribution covers both attempts; already
            terminal rows retire immediately (defensive — the scheduler
            migrates only live requests)."""
            free = [b for b in range(B) if lv.slots[b] is None]
            now = time.perf_counter()
            while self._adopted and free:
                rec = self._adopted.popleft()
                b = free.pop(0)
                if lv.batch_state is None:
                    lv.batch_state = self._init_state(B)
                lv.batch_state = self._splice_fn(
                    lv.batch_state, rec["state"], jnp.int32(0),
                    jnp.int32(b))
                req = rec["req"]
                slot = _Slot(req=req,
                             tokens=[int(t) for t in rec["tokens"]],
                             prefill_energy_j=float(rec["energy_j"]),
                             t_start=now, t_first=now,
                             t_first_model=self._clock,
                             rng=rec.get("rng"))
                self._stats["adopted_in"] += 1
                tok = slot.tokens[-1]
                if (req.eos_id is not None and tok == req.eos_id) or (
                        len(slot.tokens) >= self._budget(req)):
                    self._finish(slot, now, decode_energy_j, results)
                    continue
                lv.slots[b] = slot
                lv.token_buf[b] = tok

        def splice_ready() -> None:
            """Move parked (prefilled) admissions into free decode slots,
            FIFO by first-token time; their lane rows return to the free
            list."""
            free = [b for b in range(B) if lv.slots[b] is None]
            if not free:
                return
            keep: list[_Admission] = []
            for a in lv.adm:
                if a.ready is None or not free:
                    keep.append(a)
                    continue
                b = free.pop(0)
                if lv.batch_state is None:
                    lv.batch_state = self._init_state(B)
                lv.batch_state = self._splice_fn(
                    lv.batch_state, lv.adm_state, jnp.int32(a.row),
                    jnp.int32(b))
                lv.lane_free.append(a.row)
                lv.lane_dirty.add(a.row)
                lv.slots[b] = a.ready
                lv.token_buf[b] = a.first_tok
            lv.adm = keep

        def chunk_stage() -> bool:
            """Run one chunk call over the rows still prefilling (parked
            and vacant rows ride along as zero-length identity rows).
            Samples first tokens for rows whose last chunk landed.
            Returns True when a request finished outright on its first
            sampled token (a lane row freed — the caller re-admits in
            the same pass)."""
            W = lv.adm_w or 1
            while W < len(lv.adm):
                W *= 2
            if lv.adm_state is None or W > lv.adm_w:
                # width growth (or first build): reallocate, carrying
                # every in-progress row across *at its own index* — row
                # assignments are sticky so no repacking splices happen
                new_state = self._init_state(W)
                held = set()
                for a in lv.adm:
                    if a.row >= 0:
                        held.add(a.row)
                        if a.base > 0:
                            new_state = self._splice_fn(
                                new_state, lv.adm_state, jnp.int32(a.row),
                                jnp.int32(a.row))
                lv.adm_state, lv.adm_w = new_state, W
                lv.lane_free = [r for r in range(W) if r not in held]
                lv.lane_dirty.clear()
                self._stats["lane_rebuilds"] += 1
            lv.lane_free.sort()
            fresh: list[_Admission] = []
            for a in lv.adm:
                if a.row < 0:
                    a.row = lv.lane_free.pop(0)
                    if self._admit_fn is not None:
                        # admit families: the admission splice below
                        # overwrites the whole row (a complete batch-1
                        # state), so no zeroing splice is needed
                        lv.lane_dirty.discard(a.row)
                        fresh.append(a)
                    elif a.row in lv.lane_dirty:
                        lv.lane_dirty.discard(a.row)
                        zero_lane_row(a.row)
            if fresh:
                # prefill-once admission: one packed call over this
                # step's fresh admissions, each row spliced into its lane
                # slot (encoder + cross-KV for encdec, patch prefix for
                # vlm — their outputs are decode-state leaves)
                Wb = 1
                while Wb < len(fresh):
                    Wb *= 2
                t_adm = time.perf_counter()
                src_state, adm_j = self._admit_rows(
                    [a.req for a in fresh], Wb)
                for i, a in enumerate(fresh):
                    lv.adm_state = self._splice_fn(lv.adm_state, src_state,
                                                   jnp.int32(i),
                                                   jnp.int32(a.row))
                    a.chunk_energy_j += adm_j / Wb
                    a.prefix = self._admit_dims(a.req)[0]
                    if a.t_start == 0.0:
                        a.t_start = t_adm
            pending = [a for a in lv.adm if a.ready is None]
            rem = [len(a.eff) - a.base for a in pending]
            # shortest-remainder-first bucket: short admissions finish in
            # cheap narrow calls (their TTFT is the point); long prompts
            # still progress min(C, rem) tokens per step and get full
            # chunks once the lane holds only longs
            C = self._chunk_bucket(min(rem))
            if self.chunk_policy is not None:
                # scheduler override: an SLO-aware policy may widen (or
                # narrow) the chunk against the SJF default; any request
                # still progresses min(C, rem) tokens per step, so every
                # ladder bucket is functionally valid — parity holds
                # because chunk boundaries stay bucket/grain aligned
                want = self.chunk_policy(
                    self, [(a.req, len(a.eff) - a.base)
                           for a in pending])
                if want:
                    C = self._chunk_bucket(int(want))
            if self.cfg.sub_quadratic and any(r > C for r in rem):
                # a *non-final* chunk boundary must stay a multiple of the
                # SSM serve-scan block or the carried scan state loses bit
                # parity with the unchunked prefill; the only unaligned
                # bucket is a non-multiple max_len, so drop to the widest
                # aligned one (validated to exist at construction)
                while C % self.ssm_grain:
                    C = self._chunk_bucket(C // 2)
            toks = np.zeros((W, C), np.int32)
            lens = np.zeros(W, np.int32)
            t_disp = time.perf_counter()
            for a in pending:
                n = min(C, len(a.eff) - a.base)
                toks[a.row, :n] = a.eff[a.base:a.base + n]
                lens[a.row] = n
                if a.t_start == 0.0:
                    a.t_start = t_disp
            logits, lv.adm_state = self._chunk(
                self.params, jnp.asarray(toks), jnp.asarray(lens),
                lv.adm_state)
            logits = np.asarray(logits, np.float32)
            now = time.perf_counter()
            est_j, est_s, est = self._chunk_cost(W, C)
            self._tick(est_s, est)
            self._stats["chunk_steps"] += 1
            # lane pad/parked rows are executed spend with no owner
            self._stats["idle_energy_j"] += (W - len(pending)) * est_j / W
            keep: list[_Admission] = []
            freed = False
            for a in lv.adm:
                if a.ready is not None:
                    keep.append(a)
                    continue
                a.base += int(lens[a.row])
                a.chunk_energy_j += est_j / W
                if a.base < len(a.eff):
                    keep.append(a)
                    continue
                replay = a.req.replay
                if replay:
                    # fault replay: the emitted prefix is forced, not
                    # resampled — the stream stays an exact append-only
                    # continuation. Non-greedy rows burn the failed
                    # attempt's Gumbel draws so later tokens keep bit
                    # parity with the no-fault run.
                    if a.rng is not None:
                        for _ in replay:
                            a.rng.gumbel(size=logits.shape[-1])
                    tok = int(replay[-1])
                    toks0 = [int(t) for t in replay]
                else:
                    tok = int(self._sample(logits[a.row:a.row + 1],
                                           [a.rng])[0])
                    toks0 = [tok]
                srec = _Slot(req=a.req, tokens=toks0,
                             prefill_energy_j=a.chunk_energy_j,
                             t_start=a.t_start, t_first=now,
                             t_first_model=self._clock, rng=a.rng)
                # EOS or an exhausted budget on the first (or last
                # replayed) token: finished before occupying a decode
                # slot
                if (a.req.eos_id is not None and tok == a.req.eos_id) or (
                        len(toks0) >= self._budget(a.req)):
                    self._finish(srec, now, decode_energy_j, results)
                    lv.lane_free.append(a.row)
                    lv.lane_dirty.add(a.row)
                    freed = True
                    continue
                a.ready = srec
                a.first_tok = tok
                keep.append(a)
            lv.adm = keep
            if not lv.adm:
                lv.adm_state, lv.adm_w = None, 0
                lv.lane_free = []
                lv.lane_dirty.clear()
            return freed

        emitted = 0
        while (self.queue or self._adopted or lv.adm
               or any(s is not None for s in lv.slots)):
            t_it0 = time.perf_counter()
            # ---- adopt + admit + chunk: splice adopted rows and fill
            # free lane rows from the queue, then run one chunk call; a
            # request finishing on its first sampled token frees its
            # lane row again, so keep admitting until the lane is full
            # of live work or the queue drains ----
            adopt_ready()
            splice_ready()
            while True:
                while self.queue and len(lv.adm) < self.lane_width:
                    req = self.queue.popleft()
                    rng = None if self.greedy else self._req_rng(req.uid)
                    eff = np.asarray(req.prompt, np.int32)
                    if req.replay and len(req.replay) > 1:
                        eff = np.concatenate(
                            [eff, np.asarray(req.replay[:-1], np.int32)])
                    lv.adm.append(_Admission(req=req, rng=rng, eff=eff))
                if not any(a.ready is None for a in lv.adm):
                    break
                freed = chunk_stage()
                if not (freed and self.queue):
                    break
            adopt_ready()
            splice_ready()
            # ---- one lockstep decode step over the residents ----
            lv.batch_state = self._decode_step(
                lv.slots, lv.batch_state, lv.token_buf, decode_cost,
                results)
            self._stats["wall_s"] += time.perf_counter() - t_it0
            pending_n = sum(a.ready is None for a in lv.adm)
            self._lane_view = {
                "pending": pending_n,
                "pending_tokens": sum(len(a.eff) - a.base
                                      for a in lv.adm if a.ready is None),
                "parked": len(lv.adm) - pending_n,
                "resident": sum(s is not None for s in lv.slots),
                "in_flight": len(lv.adm),
            }
            new, emitted = results[emitted:], len(results)
            yield new
        self._live = None

    # ------------------------------------------------------------------
    # fault recovery (repro.serving.faults / scheduler)
    # ------------------------------------------------------------------
    def state_compatible(self, other: "ServingEngine") -> bool:
        """True when a decode-state row checkpointed from `other` can be
        spliced into this engine with a bit-identical continuation:
        same model/params objects, config, cache geometry, layout, and
        sampling contract (seed + greedy). The scheduler consults this
        to choose migration over replay."""
        return (self.model is other.model
                and self.params is other.params
                and self.cfg == other.cfg
                and self.max_len == other.max_len
                and self.tp == other.tp
                and self.kv_layout == "dense"
                and other.kv_layout == "dense"
                and self.seed == other.seed
                and self.greedy == other.greedy)

    def checkpoint_inflight(self, *, state_lost: bool = False
                            ) -> list[dict]:
        """Surgically extract every in-flight request for recovery on
        another member, then clear this engine (crash semantics: the
        queue, lane, and slot table are gone afterwards).

        Each record carries the request, its emitted tokens, its
        accumulated attributable energy, its sampling stream, the
        engine-relative TTFT if the first token was already emitted, and
        — for rows whose device state survives (resident decode slots
        and parked admissions, unless ``state_lost``) — the batch-1
        decode-state pytree `layers.take_slot_state` carves out, ready
        for `adopt` on a compatible member. Mid-prefill admissions and
        queued requests always restart from scratch (their partial chunk
        energy is the failed attempt's lost spend — the scheduler
        charges it back via `charge_lost_energy`)."""
        from repro.models import layers as L

        records: list[dict] = []
        lv = self._live
        decode_j = self._decode_cost()[0]

        def rec(req, tokens, state, energy, rng, ttft, lost=0.0):
            records.append({
                "req": req, "tokens": list(tokens), "state": state,
                "energy_j": float(energy), "rng": rng,
                "ttft_model_s": ttft, "lost_energy_j": float(lost)})

        if lv is not None:
            for b, slot in enumerate(lv.slots):
                if slot is None:
                    continue
                state = None
                if not state_lost and lv.batch_state is not None:
                    state = L.take_slot_state(lv.batch_state,
                                              self._state_axes, b)
                energy = (slot.prefill_energy_j
                          + slot.steps * decode_j / self.max_batch)
                rec(slot.req, slot.tokens, state, energy, slot.rng,
                    max(slot.t_first_model - slot.req.submit_model_s,
                        0.0),
                    lost=0.0 if state is not None else energy)
            for a in lv.adm:
                if a.ready is not None:
                    state = None
                    if not state_lost and lv.adm_state is not None:
                        state = L.take_slot_state(lv.adm_state,
                                                  self._state_axes, a.row)
                    energy = a.ready.prefill_energy_j
                    rec(a.req, a.ready.tokens, state, energy, a.rng,
                        max(a.ready.t_first_model
                            - a.req.submit_model_s, 0.0),
                        lost=0.0 if state is not None else energy)
                else:
                    # mid-prefill: partial chunks cannot migrate — the
                    # spend so far is lost to the failed attempt
                    rec(a.req, [], None, 0.0, None, None,
                        lost=a.chunk_energy_j)
        for r in self._adopted:
            records.append(dict(r) if state_lost is False
                           else {**r, "state": None,
                                 "lost_energy_j": r["energy_j"],
                                 "energy_j": 0.0})
        for req in self.queue:
            rec(req, req.replay or [], None, 0.0, None, None)
        self.queue.clear()
        self._adopted.clear()
        self._stepper = None
        self._live = None
        self._lane_view = dict.fromkeys(self._lane_view, 0)
        return records

    def adopt(self, record: dict) -> None:
        """Accept a checkpointed decode-state row from a failed member
        (migration). The row waits in the adoption queue until a decode
        slot frees; `has_work` counts it. Raises when the record carries
        no state or a structurally incompatible one — the scheduler
        falls back to replay."""
        from repro.models import layers as L

        if record.get("state") is None:
            raise ValueError(
                "adopt needs a checkpointed state row; replay lost-state "
                "requests instead")
        if self.kv_layout != "dense" or self.admission != "chunked":
            raise ValueError(
                "adoption requires chunked continuous serving on the "
                "dense KV layout")
        self._ensure_splice()
        spec = jax.eval_shape(
            lambda: self.model.init_state(self.cfg, 1, self.max_len))
        if not L.state_structures_match(record["state"], spec):
            raise ValueError(
                "checkpointed state row is structurally incompatible "
                "with this engine's decode state")
        self._adopted.append(record)

    def charge_lost_energy(self, j: float) -> None:
        """Charge energy a failed attempt spent on work that must be
        replayed: real spend with no surviving owner, folded into this
        engine's idle share (so fleet ledgers still sum) and tracked in
        `lost_energy_j` for the robustness report."""
        self._stats["idle_energy_j"] += float(j)
        self._stats["lost_energy_j"] += float(j)

    def retune(self, *, objective: str = "runtime",
               rank_mode: str = "auto", _inject=None) -> bool:
        """Re-tune the engine's GEMM fleet mid-run (e.g. after a chip or
        artifact change). On `ArtifactError` — a corrupt or missing
        predictor artifact, or the injected fault ``_inject`` — tuning
        degrades to the paper's BASELINE block configs instead of
        raising: serving continues, pricing uses BASELINE everywhere,
        and `report()` carries ``tuning_degraded`` plus the reason.
        Token streams are unaffected either way (block configs change
        cost predictions, never semantics). Returns True when tuning
        succeeded, False when it degraded."""
        from repro.core.predictor import ArtifactError
        from repro.kernels import ops

        fleet = ops.serving_gemm_fleet(
            self.cfg, max_batch=self.max_batch, max_len=self.max_len,
            include_slot_prefill=self._continuous_supported(),
            chunk_tokens=(self.chunk_tokens
                          if self.admission == "chunked" else None),
            lane_width=(self.lane_width
                        if self.admission == "chunked" else None),
            tp=self.tp, grain=self.ssm_grain)
        try:
            if _inject is not None:
                raise _inject
            self.pretuned = ops.warm_gemm_cache(
                fleet, dtype=self.cfg.activation_dtype,
                objective=objective, chip=self.chip,
                rank_mode=rank_mode, strict=True)
            self.tuning_degraded = False
            self._degraded_reason = None
        except ArtifactError as e:
            from repro.core.autotuner import baseline_configs

            self.pretuned = baseline_configs(fleet)
            self.tuning_degraded = True
            self._degraded_reason = str(e)
        # step-energy estimates were priced under the old configs
        self._step_energy_cache.clear()
        return not self.tuning_degraded

    # ------------------------------------------------------------------
    # paged layout: pool pressure (fault injection / degraded mode)
    # ------------------------------------------------------------------
    def inject_page_pressure(self, pages: int) -> int:
        """Squeeze `pages` pages out of the paged KV pool (an external
        tenant, a chaos fault). Returns how many were actually taken;
        `release_page_pressure` gives them back."""
        if self._allocator is None:
            raise ValueError("page pressure requires kv_layout='paged'")
        return self._allocator.squeeze(pages)

    def release_page_pressure(self) -> int:
        """Return every squeezed page to the paged KV pool."""
        if self._allocator is None:
            raise ValueError("page pressure requires kv_layout='paged'")
        return self._allocator.unsqueeze()

    def _ensure_pool(self) -> None:
        """Build the device page pool and the jitted page-copy call on
        first use (the pool is the engine's single biggest allocation —
        engines constructed but never run shouldn't pay it)."""
        if self._pool is not None:
            return
        from repro.models import layers as L

        self._pool = self.model.init_page_pool(
            self.cfg, self._allocator.num_pages, self.page_size)
        if self.mesh is not None:
            from repro.distributed.sharding import serving_state_shardings

            self._pool = jax.device_put(
                self._pool,
                serving_state_shardings(self._pool, self.mesh))
        self._copy_pages = jax.jit(
            lambda pool, src, dst: L.copy_pool_pages(pool, src, dst),
            donate_argnums=(0,))

    def _run_paged(self) -> list[Result]:
        """Chunked-admission continuous batching over the paged KV layout.

        Structure mirrors `_run_chunked`, but all per-row cache state
        lives in one shared device page pool addressed through host-built
        page tables (`repro.serving.paging.PageAllocator` owns the
        bookkeeping), which changes three things:

        * the pool threads *sequentially* through the donated chunk and
          decode calls (one device state, not a lane state + a slot
          state), with each call's page table and cache positions rebuilt
          from host records — so parking a finished admission and
          splicing it into a decode slot are pure host moves of a page
          list, zero device copies;
        * admission reserves a request's full page capacity up front
          (`PageAllocator.admit`) and reuses registered shared-prefix
          pages, skipping their prefill chunks entirely (`base` starts
          past the matched tokens) — the TTFT win prefix reuse exists
          for. Pool exhaustion defers admission until a retirement frees
          pages (deadlock-free: the failure surfaces only at admission);
        * a finished prompt registers its pages in the prefix registry
          (plus a frozen snapshot of a partial last page) for later
          requests to map copy-on-write.

        Token streams are bit-identical to the dense layout: the gathered
        per-row view spans the same max_len positions with the same
        masks, and every unmasked position holds the same written values.
        """
        self._ensure_pool()
        if any(r.replay for r in self.queue):
            raise ValueError(
                "replay requests require the dense KV layout")
        t_run0 = time.perf_counter()
        from repro.serving.paging import PageCacheFull

        B = self.max_batch
        n_pg = self._n_row_pages
        n_layers = self.cfg.n_layers
        results: list[Result] = []
        slots: list[_Slot | None] = [None] * B
        token_buf = np.zeros(B, np.int32)
        decode_cost = self._decode_cost()
        decode_energy_j = decode_cost[0]
        adm: list[_Admission] = []
        alloc = self._allocator
        pool = self._pool
        pool_keys = set(pool)
        admit_family = self._admit_fn is not None
        extra_top_spec: dict = {}
        extra_kv_spec: dict = {}
        if admit_family:
            # admit families carry dense per-request leaves alongside the
            # page pool (encdec cross-KV + src_len, vlm pos_off): discover
            # them — and their batch axes — from the dense state spec. A
            # dense leaf is "extra" iff the pool holds no paged twin.
            self._ensure_splice()
            spec1 = jax.eval_shape(lambda: self.model.init_state(
                self.cfg, 1, self.max_len))
            extra_top_spec = {k: v for k, v in spec1.items()
                              if k not in ("kv", "index")}
            extra_kv_spec = {k: v for k, v in spec1["kv"].items()
                             if f"{k}_pages" not in pool_keys
                             and k not in pool_keys}

        def _zero_leaf(spec, axis: int, width: int):
            shape = list(spec.shape)
            shape[axis] = width
            return jnp.zeros(tuple(shape), spec.dtype)

        def zero_extras(width: int) -> tuple[dict, dict]:
            top = {k: _zero_leaf(v, self._state_axes[k], width)
                   for k, v in extra_top_spec.items()}
            kvx = {k: _zero_leaf(v, self._state_axes["kv"][k], width)
                   for k, v in extra_kv_spec.items()}
            return top, kvx

        def gather_extras(recs: list, width: int) -> tuple[dict, dict]:
            """Per-call extra state: concatenate each record's batch-1
            admission leaves along the leaf's batch axis (zero rows for
            empty slots). Records are _Admission or _Slot objects. The
            result feeds a buffer-donating jit, so a width-1 gather must
            COPY — returning the record's stored leaf would let donation
            delete it out from under the next step."""
            rows = list(recs[:width]) + [None] * (width - len(recs[:width]))
            ztop, zkv = zero_extras(1)

            def cat(parts, axis):
                if len(parts) == 1:
                    return jnp.copy(parts[0])
                return jnp.concatenate(parts, axis=axis)

            top = {}
            kvx = {}
            for k in extra_top_spec:
                parts = [(r.extra_top[k] if r is not None and r.extra_top
                          else ztop[k]) for r in rows]
                top[k] = cat(parts, self._state_axes[k])
            for k in extra_kv_spec:
                parts = [(r.extra_kv[k] if r is not None and r.extra_kv
                          else zkv[k]) for r in rows]
                kvx[k] = cat(parts, self._state_axes["kv"][k])
            return top, kvx

        def dev_table(rows: list[list[int] | None], width: int):
            """(L, width, n_pg) device table from per-row page lists
            (missing/short rows padded with the null page)."""
            tbl = np.zeros((width, n_pg), np.int32)
            for i, pgs in enumerate(rows):
                if pgs:
                    tbl[i, :len(pgs)] = pgs
            return jnp.broadcast_to(jnp.asarray(tbl)[None],
                                    (n_layers, width, n_pg))

        def apply_copies(copies: list[tuple[int, int]]) -> None:
            """Run the allocator's pending (src, dst) page copies on the
            pool — COW forks and prefix snapshots. Copy batches pad to a
            pow2 bucket with null-page self-copies to bound jit traces."""
            nonlocal pool
            if not copies:
                return
            n = 1
            while n < len(copies):
                n *= 2
            src = np.zeros(n, np.int32)
            dst = np.zeros(n, np.int32)
            for i, (s, d) in enumerate(copies):
                src[i], dst[i] = s, d
            pool = self._copy_pages(pool, jnp.asarray(src),
                                    jnp.asarray(dst))

        def admit_from_queue() -> None:
            """Admit queued requests while the lane has room and the pool
            can cover their full reservation; on exhaustion the request
            waits at the head of the queue for a retirement — unless
            nothing is in flight to retire, which is a hard failure.
            Admit families run their prefill-once admission call here:
            the patch prefix writes through the reserved pages (vlm), the
            cross-KV lands in per-request dense leaves (encdec); prefix
            reuse is disabled for them — their self-attention KV depends
            on the modality input, not the token prefix alone."""
            nonlocal pool
            while self.queue and len(adm) < self.lane_width:
                req = self.queue[0]
                prefix, src = self._admit_dims(req)
                try:
                    a = alloc.admit(np.asarray(req.prompt, np.int32),
                                    self._budget(req),
                                    prefix_rows=prefix,
                                    reuse=not admit_family)
                except PageCacheFull:
                    # degraded mode: under pool pressure the shared-
                    # prefix registry is a cache, not a promise — shed
                    # it (dropping the registry's references frees
                    # sole-owner pages now, shared ones at their last
                    # reader) and retry before deferring the admission
                    if alloc.shed_registry():
                        continue
                    if not adm and not any(s is not None for s in slots):
                        raise
                    break
                self.queue.popleft()
                apply_copies(a.copies)
                rng = None if self.greedy else self._req_rng(req.uid)
                rec = _Admission(req=req, rng=rng, base=a.base,
                                 pages=a.pages, prefix=prefix)
                if admit_family and (prefix or src):
                    bucket = self._bucket(max(prefix, src))
                    packed = self.model.pack_admit(
                        self.cfg, [req.extras], 1, bucket)
                    top0, kv0 = zero_extras(1)
                    st = {"kv": {**pool, "table": dev_table([a.pages], 1),
                                 **kv0},
                          "index": jnp.zeros((1,), jnp.int32), **top0}
                    st = self._admit_fn(self.params, packed, st)
                    pool = {k: st["kv"][k] for k in pool_keys}
                    rec.extra_top = {k: st[k] for k in extra_top_spec}
                    rec.extra_kv = {k: st["kv"][k]
                                    for k in extra_kv_spec}
                    adm_j, adm_s, adm_est = self._admit_cost(1, bucket)
                    self._tick(adm_s, adm_est)
                    rec.chunk_energy_j += adm_j
                    rec.t_start = time.perf_counter()
                adm.append(rec)

        def splice_ready() -> None:
            """Move parked admissions into free decode slots — a pure
            host transfer of the page list (the row's KV already lives in
            the shared pool)."""
            nonlocal adm
            free = [b for b in range(B) if slots[b] is None]
            if not free:
                return
            keep: list[_Admission] = []
            for a in adm:
                if a.ready is None or not free:
                    keep.append(a)
                    continue
                b = free.pop(0)
                slots[b] = a.ready
                token_buf[b] = a.first_tok
            adm = keep

        def chunk_stage() -> bool:
            """One bucketed chunk call over the rows still prefilling
            (parked rows hold no lane state here, so the call width
            covers only pending rows). Returns True when a request
            finished outright on its first sampled token (lane row and
            pages freed — the caller re-admits in the same pass)."""
            nonlocal adm, pool
            pending = [a for a in adm if a.ready is None]
            W = 1
            while W < len(pending):
                W *= 2
            for i, a in enumerate(pending):
                a.row = i
            rem = [len(a.req.prompt) - a.base for a in pending]
            C = self._chunk_bucket(min(rem))
            toks = np.zeros((W, C), np.int32)
            lens = np.zeros(W, np.int32)
            base = np.zeros(W, np.int32)
            rows: list[list[int] | None] = [None] * W
            t_disp = time.perf_counter()
            recs: list[_Admission | None] = [None] * W
            for a in pending:
                n = min(C, len(a.req.prompt) - a.base)
                toks[a.row, :n] = a.req.prompt[a.base:a.base + n]
                lens[a.row] = n
                # cache positions sit past the admission prefix (vlm
                # patch rows occupy [0, prefix) of the row's pages)
                base[a.row] = a.prefix + a.base
                rows[a.row] = a.pages
                recs[a.row] = a
                if a.t_start == 0.0:
                    a.t_start = t_disp
            extra_top, extra_kv = (gather_extras(recs, W)
                                   if admit_family else ({}, {}))
            state = {"kv": {**pool, "table": dev_table(rows, W),
                            **extra_kv},
                     "index": jnp.asarray(base), **extra_top}
            logits, state = self._chunk(
                self.params, jnp.asarray(toks), jnp.asarray(lens), state)
            pool = {k: v for k, v in state["kv"].items()
                    if k in pool_keys}
            logits = np.asarray(logits, np.float32)
            now = time.perf_counter()
            est_j, est_s, est = self._chunk_cost(W, C)
            self._tick(est_s, est)
            self._stats["chunk_steps"] += 1
            self._stats["idle_energy_j"] += (W - len(pending)) * est_j / W
            keep: list[_Admission] = []
            freed = False
            for a in adm:
                if a.ready is not None:
                    keep.append(a)
                    continue
                a.base += int(lens[a.row])
                a.chunk_energy_j += est_j / W
                plen = len(a.req.prompt)
                if a.base < plen:
                    keep.append(a)
                    continue
                if not admit_family:
                    # prompt fully cached: publish its pages to the
                    # prefix registry (may snapshot a partial last page).
                    # Admit families never register — their KV depends on
                    # the modality input, so token-prefix reuse is unsound
                    apply_copies(alloc.register(
                        np.asarray(a.req.prompt, np.int32), a.pages,
                        a.base))
                tok = int(self._sample(logits[a.row:a.row + 1],
                                       [a.rng])[0])
                srec = _Slot(req=a.req, tokens=[tok],
                             prefill_energy_j=a.chunk_energy_j,
                             t_start=a.t_start, t_first=now,
                             t_first_model=self._clock, rng=a.rng,
                             pages=a.pages, index=a.prefix + plen,
                             extra_top=a.extra_top, extra_kv=a.extra_kv)
                if (a.req.eos_id is not None and tok == a.req.eos_id) or (
                        self._budget(a.req) <= 1):
                    self._finish(srec, now, decode_energy_j, results)
                    alloc.release(a.pages)
                    freed = True
                    continue
                a.ready = srec
                a.first_tok = tok
                keep.append(a)
            adm = keep
            return freed

        def decode_step() -> None:
            """One lockstep decode step: page tables and per-slot cache
            positions rebuilt from host records, pool threaded through
            the donated call; finished slots release their pages (shared
            prefix pages drop a reference, freeing only with the last
            reader)."""
            nonlocal pool
            if not any(s is not None for s in slots):
                return
            self._tick(decode_cost[1], decode_cost[2])
            extra_top, extra_kv = (gather_extras(slots, B)
                                   if admit_family else ({}, {}))
            state = {"kv": {**pool,
                            "table": dev_table(
                                [s.pages if s else None for s in slots],
                                B),
                            **extra_kv},
                     "index": jnp.asarray(np.array(
                         [s.index if s else 0 for s in slots], np.int32)),
                     **extra_top}
            logits, state = self._decode(
                self.params, jnp.asarray(token_buf), state)
            pool = {k: v for k, v in state["kv"].items()
                    if k in pool_keys}
            logits = np.asarray(logits, np.float32)
            cur = self._sample(
                logits, [s.rng if s is not None else None for s in slots])
            now = time.perf_counter()
            n_active = sum(s is not None for s in slots)
            self._stats["decode_steps"] += 1
            self._stats["slot_steps"] += B
            self._stats["resident_slot_steps"] += n_active
            self._stats["idle_energy_j"] += (
                (B - n_active) * decode_energy_j / B)
            for b in range(B):
                slot = slots[b]
                if slot is None:
                    continue
                tok = int(cur[b])
                slot.tokens.append(tok)
                slot.steps += 1
                slot.index += 1
                token_buf[b] = tok
                req = slot.req
                if (req.eos_id is not None and tok == req.eos_id) or (
                        len(slot.tokens) >= self._budget(req)):
                    self._finish(slot, now, decode_energy_j, results)
                    alloc.release(slot.pages)
                    slots[b] = None
                    token_buf[b] = 0

        while self.queue or adm or any(s is not None for s in slots):
            splice_ready()
            while True:
                admit_from_queue()
                if not any(a.ready is None for a in adm):
                    break
                freed = chunk_stage()
                if not (freed and self.queue):
                    break
            splice_ready()
            decode_step()
        self._pool = pool
        self._stats["wall_s"] += time.perf_counter() - t_run0
        return results

    def _run_serial(self) -> list[Result]:
        """PR 4-style admission: each request prefills alone (single-shot
        bucketed call) and is spliced into a free slot — kept as the
        stall-prone baseline `benchmarks/bench_serving.py` regresses
        chunked admission against."""
        self._ensure_splice()
        t_run0 = time.perf_counter()
        B = self.max_batch
        results: list[Result] = []
        slots: list[_Slot | None] = [None] * B
        batch_state = None
        token_buf = np.zeros(B, np.int32)
        decode_cost = self._decode_cost()
        decode_energy_j = decode_cost[0]

        while self.queue or any(s is not None for s in slots):
            # ---- refill free slots from the queue (a request finishing
            # on its very first token frees the slot again, so keep
            # admitting until the slot holds a live request or the queue
            # drains — no decode step runs with a needlessly dead slot) --
            for b in range(B):
                while slots[b] is None and self.queue:
                    req = self.queue.popleft()
                    rng = (None if self.greedy
                           else self._req_rng(req.uid))
                    t0 = time.perf_counter()
                    tok, slot_state, pre_j = self._prefill_slot(req, rng)
                    t1 = time.perf_counter()
                    slot = _Slot(req=req, tokens=[tok],
                                 prefill_energy_j=pre_j,
                                 t_start=t0, t_first=t1,
                                 t_first_model=self._clock, rng=rng)
                    # EOS or a 1-token budget on the *first* sampled
                    # token: finished before ever occupying a decode slot
                    if (req.eos_id is not None and tok == req.eos_id) or (
                            self._budget(req) <= 1):
                        self._finish(slot, t1, decode_energy_j, results)
                        continue
                    if batch_state is None:
                        batch_state = self._init_state(B)
                    batch_state = self._splice_fn(
                        batch_state, slot_state, jnp.int32(0),
                        jnp.int32(b))
                    slots[b] = slot
                    token_buf[b] = tok
            if not any(s is not None for s in slots):
                break                  # queue drained, no live slots
            batch_state = self._decode_step(
                slots, batch_state, token_buf, decode_cost, results)
        self._stats["wall_s"] += time.perf_counter() - t_run0
        return results

    # ------------------------------------------------------------------
    # wave mode (compatibility shim)
    # ------------------------------------------------------------------
    def run_wave(self) -> list[Result]:
        """Serve one wave: take up to max_batch queued requests, prefill
        (one batched right-padded call), decode until all finish. Finished
        rows stay resident to the end of the wave (counted in `steps` so
        energy attribution reflects the waste)."""
        if not self.queue:
            return []
        if any(r.replay for r in self.queue):
            raise ValueError(
                "replay requests require chunked admission (serve_step)")
        self._activate()
        t_run0 = time.perf_counter()
        batch_reqs = [self.queue.popleft()
                      for _ in range(min(self.max_batch, len(self.queue)))]
        B = len(batch_reqs)
        lens = np.array([len(r.prompt) for r in batch_reqs], np.int32)
        S = int(lens.max())
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(batch_reqs):
            toks[i, :lens[i]] = r.prompt           # right-pad + lengths
        t0 = time.perf_counter()
        if self._admit_fn is not None:
            # admit families: one batched prefill-once admission (all B
            # rows in a single call against a width-B zero state) + one
            # full-width chunk over the right-padded prompts — the same
            # path chunked admission runs, so wave/chunked parity holds
            # by construction
            state, adm_j = self._admit_rows(batch_reqs, B)
            Sb = self._bucket(S)
            wt = np.zeros((B, Sb), np.int32)
            wt[:, :S] = toks
            logits, state = self._chunk(
                self.params, jnp.asarray(wt), jnp.asarray(lens), state)
            logits = np.asarray(logits, np.float32)
            t_first = time.perf_counter()
            prefill_j, prefill_s, pre_est = self._chunk_cost(B, Sb)
            prefill_j += adm_j
        else:
            batch = {"tokens": jnp.asarray(toks),
                     "lengths": jnp.asarray(lens)}
            logits, state = self._prefill(self.params, batch)
            logits = np.asarray(logits, np.float32)
            t_first = time.perf_counter()
            prefill_j, prefill_s, pre_est = self._prefill_cost(
                B * S, head_rows=B)
        self._tick(prefill_s, pre_est)
        t_first_model = self._clock
        est = self._step_energy(("decode", B), B, batch_rows=B)
        decode_energy_j, decode_step_s, _ = self._cost(est)

        # per-row budgets: the uniform `lengths` bound (each row clamps by
        # its own prefix + prompt, never by the wave's shared padded
        # length)
        budgets = np.array([self._budget(r) for r in batch_reqs])
        out: list[list[int]] = [[] for _ in range(B)]
        done = np.zeros(B, bool)
        steps = 0
        rngs = [None if self.greedy else self._req_rng(r.uid)
                for r in batch_reqs]
        cur = self._sample(logits, rngs)
        for i, r in enumerate(batch_reqs):
            tok = int(cur[i])
            out[i].append(tok)
            # honor EOS / a 1-token budget on the first sampled token
            if (r.eos_id is not None and tok == r.eos_id) or (
                    budgets[i] <= 1):
                done[i] = True
        while not done.all():
            self._tick(decode_step_s, est)
            logits, state = self._decode(self.params, jnp.asarray(cur), state)
            logits = np.asarray(logits, np.float32)
            cur = self._sample(
                logits, [None if done[i] else rngs[i] for i in range(B)])
            steps += 1
            for i, r in enumerate(batch_reqs):
                if done[i]:
                    continue
                tok = int(cur[i])
                out[i].append(tok)
                if (r.eos_id is not None and tok == r.eos_id) or (
                        len(out[i]) >= budgets[i]):
                    done[i] = True
        t_end = time.perf_counter()
        self._stats["decode_steps"] += steps
        self._stats["slot_steps"] += steps * B
        self._stats["resident_slot_steps"] += steps * B
        results = []
        for i, r in enumerate(batch_reqs):
            n_tok = len(out[i])
            # resident until the wave drains — the Racing-to-Idle cost
            energy = prefill_j / B + steps * decode_energy_j / B
            decode_s = max(t_end - t_first, 0.0)
            self._stats["generated_tokens"] += n_tok
            self._stats["energy_j"] += energy
            self._stats["requests"] += 1
            results.append(Result(
                uid=r.uid, tokens=np.array(out[i], np.int32),
                prompt_len=len(r.prompt), steps=steps, n_tokens=n_tok,
                queue_s=max(t0 - r.submit_s, 0.0),
                ttft_s=max(t_first - r.submit_s, 0.0),
                ttft_model_s=max(t_first_model - r.submit_model_s, 0.0),
                decode_s=decode_s,
                tokens_per_s=n_tok / decode_s if decode_s > 0 else 0.0,
                energy_j=energy,
                energy_per_token_j=energy / max(n_tok, 1)))
        self._stats["wall_s"] += time.perf_counter() - t_run0
        return results

    # ------------------------------------------------------------------
    def reset_stats(self) -> None:
        """Zero the cumulative counters behind `report()` — e.g. after a
        warm-up pass, so throughput excludes jit compilation time."""
        for k, v in self._stats.items():
            self._stats[k] = type(v)(0)

    def run_until_empty(self) -> list[Result]:
        """Serve every queued request to completion in the engine's mode
        (``mode="auto"`` picks continuous batching when the family
        supports it, else the wave loop)."""
        self._activate()
        mode = self.mode
        if mode == "auto":
            mode = ("continuous" if self._continuous_supported()
                    else "wave")
        if mode == "continuous":
            return self.run_continuous()
        results = []
        while self.queue:
            results.extend(self.run_wave())
        return results

    def report(self) -> dict:
        """Engine-level serving report: throughput, energy, occupancy.

        `energy_j` / `j_per_token` count *total* spend — per-request
        attributed energy plus the idle share of decode steps executed
        with dead slots (and of chunk-call pad rows) — so continuous and
        wave modes compare like-for-like."""
        s = self._stats
        toks = s["generated_tokens"]
        slot_steps = s["slot_steps"]
        total_j = s["energy_j"] + s["idle_energy_j"]
        paging = ({"paging": self._allocator.report()}
                  if self._allocator is not None else {})
        return {
            **paging,
            "tp": self.tp,
            # model-clock throughput: tokens over the analytical model's
            # predicted seconds of dispatched calls — deterministic and
            # host-independent, the surface the sharded bench gates on
            # (wall_s on a host-platform mesh measures emulation, not tp)
            "model_s": s["model_s"],
            "model_tokens_per_s": (toks / s["model_s"]
                                   if s["model_s"] > 0 else 0.0),
            "collective_wire_s": s["wire_s"],
            "overlap_factor": (s["hidden_wire_s"] / s["wire_s"]
                               if s["wire_s"] > 0 else 0.0),
            "lane_rebuilds": s["lane_rebuilds"],
            "requests": s["requests"],
            "generated_tokens": toks,
            "decode_steps": s["decode_steps"],
            "chunk_steps": s["chunk_steps"],
            "slot_steps": slot_steps,
            "resident_slot_steps": s["resident_slot_steps"],
            "slot_occupancy": (s["resident_slot_steps"] / slot_steps
                               if slot_steps else 0.0),
            "wall_s": s["wall_s"],
            "tokens_per_s": toks / s["wall_s"] if s["wall_s"] > 0 else 0.0,
            "energy_j": total_j,
            "attributed_energy_j": s["energy_j"],
            "idle_energy_j": s["idle_energy_j"],
            "j_per_token": total_j / toks if toks else 0.0,
            # robustness surface: replayed work charged to this engine
            # as the failed attempt, rows adopted from failed members,
            # and whether tuning fell back to BASELINE configs
            "lost_energy_j": s["lost_energy_j"],
            "adopted_in": s["adopted_in"],
            "tuning_degraded": self.tuning_degraded,
            "tuning_degraded_reason": self._degraded_reason,
        }
