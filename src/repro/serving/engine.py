"""Continuous-batching serving engine with per-request energy accounting.

The engine keeps one batched decode state of ``max_batch`` fixed slots. A
request is prefilled *alone* (batch 1, right-padded to a power-of-two
bucket so prompt lengths share jit traces) and spliced into a free slot of
the batched state mid-decode (`layers.insert_slot_state` — pure
`dynamic_update_slice` surgery over the decode-state pytree). The jitted
decode step therefore always runs at full static shape, but a finished
slot is retired the step it finishes and immediately refilled from the
queue — no slot ever burns decode steps on a dead request, the
"Racing to Idle" energy waste the paper's energy axis quantifies.

Each request carries telemetry (queue time, TTFT, resident decode steps,
tokens/s) and an energy estimate: the engine prices one decode step of the
whole batch (and each prefill bucket) via `core.energy.gemm_fleet_energy`
— the pretuned GEMM fleet's predicted runtimes under the duty-cycle power
model — and attributes each resident step's 1/max_batch share to the
request occupying the slot. `report()` aggregates tokens/s, J/token and
slot occupancy for benchmarks to regress.

The legacy wave API (`run_wave`) remains as a compatibility shim: one
batched right-padded prefill, lockstep decode until every request in the
wave finishes. Finished rows keep executing until the wave drains — which
is exactly the waste continuous mode exists to remove — but EOS / budget
termination (including on the *first* sampled token) is honored in both
modes.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

# families whose decode state supports per-row indices + slot surgery
# (attention KV caches; SSM/hybrid/encdec states thread a shared scalar
# position and are served in wave mode). MoE families note: rows are
# batch-independent — and continuous/wave token streams bit-identical —
# only while expert capacity doesn't bind (capacity-factor token dropping
# is first-come-first-served across the flattened batch); serve MoE with a
# capacity_factor sized for the decode batch.
CONTINUOUS_KINDS = ("dense", "moe", "mla_moe")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 32
    eos_id: int | None = None
    submit_s: float = 0.0       # stamped by ServingEngine.submit


@dataclasses.dataclass
class Result:
    uid: int
    tokens: np.ndarray          # generated ids (includes EOS if emitted)
    prompt_len: int
    steps: int                  # decode iterations the request was resident
    n_tokens: int = 0           # generated-token count (energy denominator)
    queue_s: float = 0.0        # submit -> prefill start
    ttft_s: float = 0.0         # submit -> first token
    decode_s: float = 0.0       # first token -> last token
    tokens_per_s: float = 0.0
    energy_j: float = 0.0       # attributed prefill + resident-step energy
    energy_per_token_j: float = 0.0


@dataclasses.dataclass
class _Slot:
    req: Request
    tokens: list[int]
    prefill_energy_j: float
    t_start: float              # prefill start (wall)
    t_first: float              # first-token time (wall)
    steps: int = 0              # resident decode iterations so far
    rng: np.random.Generator | None = None   # per-request sampling stream


class ServingEngine:
    def __init__(self, model, params, cfg: ModelConfig, *,
                 max_batch: int = 8, max_len: int = 512,
                 greedy: bool = True, seed: int = 0,
                 mode: str = "auto",
                 pretune: bool = False, tune_objective: str = "runtime",
                 tune_rank_mode: str = "auto",
                 chip: str | None = None):
        """`mode` picks the serving loop: "continuous" (slot table with
        mid-decode retire/refill), "wave" (legacy batch-of-waves), or
        "auto" (continuous for the families that support per-slot decode
        state — see CONTINUOUS_KINDS — wave otherwise).

        `pretune=True` batch-tunes the engine's GEMM fleet up front:
        every projection/FFN/head shape the batched prefill (max_batch *
        max_len rows), the decode step (max_batch rows), and each
        slot-prefill bucket will trace goes through one
        `ops.warm_gemm_cache` pass (predictor-ranked, substrate-verified,
        cached per chip + artifact version), so the first request pays no
        per-shape autotuning. `tune_objective` picks the paper's serving
        objective ("runtime", "energy", "power", "edp"); `tune_rank_mode`
        picks the candidate-ranking path ("auto" ranks fully in-graph on
        accelerator backends, at trace time on CPU).
        """
        self.model = model
        self.params = params
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.greedy = greedy
        if mode not in ("auto", "continuous", "wave"):
            raise ValueError(f"unknown serving mode {mode!r}")
        self.mode = mode
        self.queue: deque[Request] = deque()
        self.seed = seed
        if chip is not None:
            # validate eagerly: a chip typo must raise here, not silently
            # zero every energy estimate later
            from repro.core.chips import get_chip

            chip = get_chip(chip).name
        self.chip = chip
        self.pretuned: dict[tuple, object] = {}
        if pretune:
            from repro.kernels import ops

            fleet = ops.serving_gemm_fleet(
                cfg, max_batch=max_batch, max_len=max_len,
                include_slot_prefill=self._continuous_supported())
            self.pretuned = ops.warm_gemm_cache(
                fleet, dtype=cfg.activation_dtype,
                objective=tune_objective, chip=chip,
                rank_mode=tune_rank_mode)
        if (cfg.n_experts and mode != "wave"
                and cfg.capacity_factor * cfg.top_k < cfg.n_experts):
            # capacity = cf*T*K/E binds when too many tokens pick one
            # expert; dropping is first-come-first-served across the
            # flattened batch, so a bound batch makes a request's tokens
            # depend on its neighbors (and breaks wave/continuous
            # bit-parity). One expert receives at most T assignments
            # (top-k indices are distinct per token), so cf >= E/K
            # guarantees no drop at any T.
            import warnings

            warnings.warn(
                f"continuous batching with capacity_factor="
                f"{cfg.capacity_factor} < n_experts/top_k="
                f"{cfg.n_experts / cfg.top_k:g}: expert capacity can "
                f"bind, making generations depend on batch composition; "
                f"raise capacity_factor (>= n_experts/top_k guarantees "
                f"batch-independent serving) or use wave mode",
                stacklevel=2)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cfg, max_len=max_len))
        self._decode = jax.jit(
            lambda p, t, s: model.decode_step(p, t, s, cfg))
        self._insert_fn = None          # built lazily with the axes spec
        self._state_axes = None
        self._step_energy_cache: dict[str | int, object] = {}
        # engine-level counters (reset per run_* call family, reported
        # cumulatively)
        self._stats = {
            "decode_steps": 0, "resident_slot_steps": 0.0,
            "slot_steps": 0.0, "generated_tokens": 0, "energy_j": 0.0,
            "idle_energy_j": 0.0, "requests": 0, "wall_s": 0.0,
        }

    # ------------------------------------------------------------------
    # queue
    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        # attention-free (SSM) decode state is O(1) per token — no
        # length-bounded KV cache, so no prompt/budget bound applies
        if not self.cfg.attention_free and len(req.prompt) >= self.max_len:
            raise ValueError(
                f"prompt of {len(req.prompt)} tokens does not fit "
                f"max_len={self.max_len} (need >= 1 decode position)")
        if req.submit_s == 0.0:
            req.submit_s = time.perf_counter()
        self.queue.append(req)

    # ------------------------------------------------------------------
    # sampling
    # ------------------------------------------------------------------
    def _req_rng(self, uid: int) -> np.random.Generator:
        """Each request samples from its own (engine seed, uid) stream, so
        its tokens can never depend on which neighbors share the batch or
        when they retire."""
        return np.random.default_rng((self.seed, uid))

    def _sample(self, logits: np.ndarray,
                rngs: list[np.random.Generator | None] | None = None
                ) -> np.ndarray:
        """Next token per row. Greedy is a single vectorized argmax.
        Non-greedy draws a per-request Gumbel-max (`_req_rng` streams;
        `rngs[b] is None` marks a finished/dead row) — dead slots neither
        advance any RNG nor influence live rows, and the old per-row
        O(B*V)-work `np.random.choice` probability loop is gone."""
        if self.greedy:
            return logits.argmax(-1).astype(np.int32)
        out = np.zeros(logits.shape[0], np.int32)
        for b, rng in enumerate(rngs or []):
            if rng is None:
                continue
            z = logits[b]
            out[b] = np.int32((z + rng.gumbel(size=z.shape)).argmax())
        return out

    # ------------------------------------------------------------------
    # energy model
    # ------------------------------------------------------------------
    def _step_energy(self, key, n_rows: int, head_rows: int | None = None,
                     batch_rows: int | None = None):
        """Predicted StepEnergyEstimate for a step over `n_rows` GEMM rows
        (decode: max_batch; prefill: padded token count, with the LM head
        sized to the rows actually unembedded and MLA's cache-wide K/V
        decompression sized to batch_rows * max_len), cached per key.
        Returns None (once, with a warning) when the energy model is
        unavailable."""
        hit = self._step_energy_cache.get(key, "miss")
        if hit != "miss":
            return hit
        try:
            from repro.core.energy import gemm_fleet_energy
            from repro.models.config import gemm_shape_counts

            kv_rows = (batch_rows * self.max_len
                       if batch_rows is not None else None)
            est = gemm_fleet_energy(
                gemm_shape_counts(self.cfg, n_rows, head_tokens=head_rows,
                                  kv_rows=kv_rows),
                chip=self.chip or "tpu_v5e",
                dtype=self.cfg.activation_dtype,
                configs=self.pretuned or None,
                name=f"{self.cfg.name}:{key}")
        except Exception as e:
            import warnings

            warnings.warn(
                f"serving energy model unavailable ({e!r}); "
                f"energy telemetry for step {key!r} will read 0",
                stacklevel=2)
            est = None
        self._step_energy_cache[key] = est
        return est

    def _decode_energy_j(self) -> float:
        est = self._step_energy(("decode", self.max_batch), self.max_batch,
                                batch_rows=self.max_batch)
        return est.energy_j if est is not None else 0.0

    def _prefill_energy_j(self, n_tokens: int, head_rows: int) -> float:
        """Energy of one prefill over `n_tokens` padded rows unembedding
        `head_rows` last positions (1 for slot prefill, B for a wave).
        `head_rows` is also the prefill's batch-row count, which sizes
        MLA's cache-wide decompression."""
        est = self._step_energy(("prefill", int(n_tokens), int(head_rows)),
                                int(n_tokens), int(head_rows),
                                batch_rows=int(head_rows))
        return est.energy_j if est is not None else 0.0

    # ------------------------------------------------------------------
    # continuous batching
    # ------------------------------------------------------------------
    def _continuous_supported(self) -> bool:
        return (self.cfg.kind in CONTINUOUS_KINDS
                and self.model.init_cache is not None)

    def _bucket(self, n: int) -> int:
        """Smallest slot-prefill bucket holding `n` prompt tokens — the
        same `ops.prefill_buckets` list `serving_gemm_fleet` pre-tunes, so
        slot prefills only ever trace pre-warmed shapes."""
        from repro.kernels import ops

        for b in ops.prefill_buckets(self.max_len):
            if b >= n:
                return b
        return self.max_len

    def _budget(self, req: Request) -> int:
        """Effective token budget: >= 1, bounded by KV-cache room for
        families with a length-bounded cache (attention-free SSM state
        has no such bound)."""
        if self.cfg.attention_free:
            return max(1, req.max_new_tokens)
        return max(1, min(req.max_new_tokens,
                          self.max_len - len(req.prompt)))

    def _prefill_slot(self, req: Request, rng) -> tuple[int, dict, float]:
        """Prefill one request alone (right-padded to a pow2 bucket) and
        sample its first token. Returns (first_token, slot_state,
        prefill_energy_j)."""
        n = len(req.prompt)
        bucket = self._bucket(n)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :n] = req.prompt
        logits, state = self._prefill(
            self.params, {"tokens": jnp.asarray(toks),
                          "lengths": jnp.asarray([n], np.int32)})
        logits = np.asarray(logits, np.float32)
        tok = int(self._sample(logits, [rng])[0])
        return tok, state, self._prefill_energy_j(bucket, head_rows=1)

    def _make_insert(self, slot_state) -> None:
        """Discover the decode-state batch-axis spec (shapes at batch 1 vs
        max_batch, via eval_shape — no allocation) and jit the splice."""
        from repro.models import layers as L

        if self.max_batch == 1:
            self._state_axes = jax.tree.map(lambda _: -1, slot_state)
            self._insert_fn = lambda big, small, b: small
            return
        s1 = jax.eval_shape(lambda s: s, slot_state)
        probe_len = self._bucket(1)    # smallest real slot-prefill shape

        def shape_at(bs: int):
            toks = jnp.zeros((bs, probe_len), jnp.int32)
            lens = jnp.full((bs,), probe_len, jnp.int32)
            return jax.eval_shape(
                lambda p: self.model.prefill(
                    p, {"tokens": toks, "lengths": lens}, self.cfg,
                    max_len=self.max_len)[1], self.params)

        sb = shape_at(self.max_batch)
        axes = L.state_batch_axes(shape_at(1), sb)
        # sanity: the slot state we actually produced must match the probe
        jax.tree.map(lambda a, b: None, s1, axes)
        self._state_axes = axes
        self._insert_fn = jax.jit(
            lambda big, small, b: L.insert_slot_state(big, small, axes, b))

    def run_continuous(self) -> list[Result]:
        """Drain the queue with true continuous batching: retire finished
        slots mid-decode and refill them immediately."""
        if not self._continuous_supported():
            raise ValueError(
                f"continuous batching unsupported for kind="
                f"{self.cfg.kind!r} (needs per-slot KV decode state); "
                f"use wave mode")
        from repro.models import layers as L

        t_run0 = time.perf_counter()
        B = self.max_batch
        results: list[Result] = []
        slots: list[_Slot | None] = [None] * B
        batch_state = None
        token_buf = np.zeros(B, np.int32)
        decode_energy_j = self._decode_energy_j()

        def finish(slot: _Slot, now: float) -> Result:
            req = slot.req
            n_tok = len(slot.tokens)
            decode_s = max(now - slot.t_first, 0.0)
            energy = (slot.prefill_energy_j
                      + slot.steps * decode_energy_j / B)
            self._stats["generated_tokens"] += n_tok
            self._stats["energy_j"] += energy
            self._stats["requests"] += 1
            return Result(
                uid=req.uid, tokens=np.array(slot.tokens, np.int32),
                prompt_len=len(req.prompt), steps=slot.steps,
                n_tokens=n_tok,
                queue_s=max(slot.t_start - req.submit_s, 0.0),
                ttft_s=max(slot.t_first - req.submit_s, 0.0),
                decode_s=decode_s,
                tokens_per_s=(n_tok / decode_s if decode_s > 0 else 0.0),
                energy_j=energy,
                energy_per_token_j=energy / max(n_tok, 1))

        while self.queue or any(s is not None for s in slots):
            # ---- refill free slots from the queue (a request finishing
            # on its very first token frees the slot again, so keep
            # admitting until the slot holds a live request or the queue
            # drains — no decode step runs with a needlessly dead slot) --
            for b in range(B):
                while slots[b] is None and self.queue:
                    req = self.queue.popleft()
                    rng = (None if self.greedy
                           else self._req_rng(req.uid))
                    t0 = time.perf_counter()
                    tok, slot_state, pre_j = self._prefill_slot(req, rng)
                    t1 = time.perf_counter()
                    slot = _Slot(req=req, tokens=[tok],
                                 prefill_energy_j=pre_j,
                                 t_start=t0, t_first=t1, rng=rng)
                    # EOS or a 1-token budget on the *first* sampled
                    # token: finished before ever occupying a decode slot
                    if (req.eos_id is not None and tok == req.eos_id) or (
                            self._budget(req) <= 1):
                        results.append(finish(slot, t1))
                        continue
                    if self._insert_fn is None:
                        self._make_insert(slot_state)
                    if batch_state is None:
                        batch_state = L.expand_slot_state(
                            slot_state, self._state_axes, B)
                    batch_state = self._insert_fn(
                        batch_state, slot_state, jnp.int32(b))
                    slots[b] = slot
                    token_buf[b] = tok
            active = np.array([s is not None for s in slots])
            if not active.any():
                break                  # queue drained, no live slots
            # ---- one lockstep decode step over all slots ----
            logits, batch_state = self._decode(
                self.params, jnp.asarray(token_buf), batch_state)
            logits = np.asarray(logits, np.float32)
            cur = self._sample(
                logits, [s.rng if s is not None else None for s in slots])
            now = time.perf_counter()
            n_active = int(active.sum())
            self._stats["decode_steps"] += 1
            self._stats["slot_steps"] += B
            self._stats["resident_slot_steps"] += n_active
            # dead slots still execute: their energy share is real spend,
            # charged to the engine (idle) rather than to any request, so
            # report()'s J/token stays comparable with wave mode
            self._stats["idle_energy_j"] += (
                (B - n_active) * decode_energy_j / B)
            for b in range(B):
                slot = slots[b]
                if slot is None:
                    continue
                tok = int(cur[b])
                slot.tokens.append(tok)
                slot.steps += 1
                token_buf[b] = tok
                req = slot.req
                if (req.eos_id is not None and tok == req.eos_id) or (
                        len(slot.tokens) >= self._budget(req)):
                    results.append(finish(slot, now))
                    slots[b] = None      # retired mid-decode; refilled
                    token_buf[b] = 0     # next loop iteration
        self._stats["wall_s"] += time.perf_counter() - t_run0
        return results

    # ------------------------------------------------------------------
    # wave mode (compatibility shim)
    # ------------------------------------------------------------------
    def run_wave(self) -> list[Result]:
        """Serve one wave: take up to max_batch queued requests, prefill
        (one batched right-padded call), decode until all finish. Finished
        rows stay resident to the end of the wave (counted in `steps` so
        energy attribution reflects the waste)."""
        if not self.queue:
            return []
        t_run0 = time.perf_counter()
        batch_reqs = [self.queue.popleft()
                      for _ in range(min(self.max_batch, len(self.queue)))]
        B = len(batch_reqs)
        lens = np.array([len(r.prompt) for r in batch_reqs], np.int32)
        S = int(lens.max())
        use_lengths = self.cfg.kind in CONTINUOUS_KINDS
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(batch_reqs):
            if use_lengths:
                toks[i, :lens[i]] = r.prompt       # right-pad + lengths
            else:
                toks[i, S - lens[i]:] = r.prompt   # legacy left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if use_lengths:
            batch["lengths"] = jnp.asarray(lens)
        t0 = time.perf_counter()
        logits, state = self._prefill(self.params, batch)
        logits = np.asarray(logits, np.float32)
        t_first = time.perf_counter()
        prefill_j = self._prefill_energy_j(B * S, head_rows=B)

        budgets = np.array([self._budget(r) for r in batch_reqs])
        if not use_lengths and not self.cfg.attention_free:
            # left-padded rows share the scalar cache index starting at the
            # padded length S, so every row's KV room is max_len - S (not
            # max_len - its own prompt length); without this clamp decode
            # writes past max_len and dynamic_update_slice silently
            # corrupts the last cache slot for the whole batch
            budgets = np.minimum(budgets, self.max_len - S)
        out: list[list[int]] = [[] for _ in range(B)]
        done = np.zeros(B, bool)
        steps = 0
        rngs = [None if self.greedy else self._req_rng(r.uid)
                for r in batch_reqs]
        cur = self._sample(logits, rngs)
        for i, r in enumerate(batch_reqs):
            tok = int(cur[i])
            out[i].append(tok)
            # honor EOS / a 1-token budget on the first sampled token
            if (r.eos_id is not None and tok == r.eos_id) or (
                    budgets[i] <= 1):
                done[i] = True
        while not done.all():
            logits, state = self._decode(self.params, jnp.asarray(cur), state)
            logits = np.asarray(logits, np.float32)
            cur = self._sample(
                logits, [None if done[i] else rngs[i] for i in range(B)])
            steps += 1
            for i, r in enumerate(batch_reqs):
                if done[i]:
                    continue
                tok = int(cur[i])
                out[i].append(tok)
                if (r.eos_id is not None and tok == r.eos_id) or (
                        len(out[i]) >= budgets[i]):
                    done[i] = True
        t_end = time.perf_counter()
        est = self._step_energy(("decode", B), B, batch_rows=B)
        decode_energy_j = est.energy_j if est is not None else 0.0
        self._stats["decode_steps"] += steps
        self._stats["slot_steps"] += steps * B
        self._stats["resident_slot_steps"] += steps * B
        results = []
        for i, r in enumerate(batch_reqs):
            n_tok = len(out[i])
            # resident until the wave drains — the Racing-to-Idle cost
            energy = prefill_j / B + steps * decode_energy_j / B
            decode_s = max(t_end - t_first, 0.0)
            self._stats["generated_tokens"] += n_tok
            self._stats["energy_j"] += energy
            self._stats["requests"] += 1
            results.append(Result(
                uid=r.uid, tokens=np.array(out[i], np.int32),
                prompt_len=len(r.prompt), steps=steps, n_tokens=n_tok,
                queue_s=max(t0 - r.submit_s, 0.0),
                ttft_s=max(t_first - r.submit_s, 0.0),
                decode_s=decode_s,
                tokens_per_s=n_tok / decode_s if decode_s > 0 else 0.0,
                energy_j=energy,
                energy_per_token_j=energy / max(n_tok, 1)))
        self._stats["wall_s"] += time.perf_counter() - t_run0
        return results

    # ------------------------------------------------------------------
    def reset_stats(self) -> None:
        """Zero the cumulative counters behind `report()` — e.g. after a
        warm-up pass, so throughput excludes jit compilation time."""
        for k, v in self._stats.items():
            self._stats[k] = type(v)(0)

    def run_until_empty(self) -> list[Result]:
        mode = self.mode
        if mode == "auto":
            mode = ("continuous" if self._continuous_supported()
                    else "wave")
        if mode == "continuous":
            return self.run_continuous()
        results = []
        while self.queue:
            results.extend(self.run_wave())
        return results

    def report(self) -> dict:
        """Engine-level serving report: throughput, energy, occupancy.

        `energy_j` / `j_per_token` count *total* spend — per-request
        attributed energy plus the idle share of decode steps executed
        with dead slots — so continuous and wave modes compare
        like-for-like."""
        s = self._stats
        toks = s["generated_tokens"]
        slot_steps = s["slot_steps"]
        total_j = s["energy_j"] + s["idle_energy_j"]
        return {
            "requests": s["requests"],
            "generated_tokens": toks,
            "decode_steps": s["decode_steps"],
            "slot_steps": slot_steps,
            "resident_slot_steps": s["resident_slot_steps"],
            "slot_occupancy": (s["resident_slot_steps"] / slot_steps
                               if slot_steps else 0.0),
            "wall_s": s["wall_s"],
            "tokens_per_s": toks / s["wall_s"] if s["wall_s"] > 0 else 0.0,
            "energy_j": total_j,
            "attributed_energy_j": s["energy_j"],
            "idle_energy_j": s["idle_energy_j"],
            "j_per_token": total_j / toks if toks else 0.0,
        }
