"""Deterministic fault injection for the serving fleet.

A `FaultPlan` is a seeded schedule of `FaultEvent`s pinned to the fleet
*model clock* — the deterministic timeline of predicted call seconds the
scheduler already orders engine steps by — so a chaos run is exactly
reproducible from its seed: the same faults land between the same engine
steps on every host and platform. The scheduler polls `due(now)` once
per tick and applies whatever fired.

Four fault kinds cover the serving failure model (`docs/serving.md`,
"Failure model & recovery"):

* ``crash`` — the member dies. Its in-flight requests are checkpointed
  (`ServingEngine.checkpoint_inflight`) and migrated or replayed by the
  scheduler; ``state_lost=True`` models losing the device state with the
  node (every request replays). A crashed member is charged its idle
  floor only up to the crash instant.
* ``stall`` — the member's steps dilate by ``factor`` for
  ``duration_s`` of fleet time (thermal throttling, a sick NIC). The
  scheduler does NOT act on the plan directly: detection goes through
  `train.ft.StragglerDetector` EWMAs over per-member step times, the
  same machinery the training stack trusts, and eviction follows the
  detector's flag, not the schedule.
* ``page_pressure`` — ``pages`` pages vanish from the member's page
  pool for ``duration_s`` (`PageAllocator.squeeze`), modelling an
  external tenant; the engine sheds shared-prefix registry entries
  before deferring admissions. Only meaningful for paged engines.
* ``artifact_corruption`` — the member's next (re)tune hits an
  `ArtifactError` (`ServingEngine.retune`); tuning degrades to the
  paper's BASELINE block configs and serving continues, flagged in
  `report()`.

Faults change *where and when* work runs — never what it computes. The
engine's bit-parity contract (streams are placement/batch/chunk
independent) is what makes migration bit-identical and replay
append-only, so the chaos property suite can diff token streams against
a no-fault run directly.
"""

from __future__ import annotations

import dataclasses

import numpy as np

KINDS = ("crash", "stall", "page_pressure", "artifact_corruption")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault, pinned to the fleet model clock."""

    t_model_s: float            # fleet-clock firing time
    kind: str                   # one of KINDS
    member: str                 # fleet member the fault targets
    duration_s: float = 0.0     # stall / page_pressure window
    factor: float = 4.0         # stall: step-time dilation
    state_lost: bool = False    # crash: device state unrecoverable
    pages: int = 0              # page_pressure: pages squeezed

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.kind == "stall" and self.factor <= 1.0:
            raise ValueError("stall factor must exceed 1.0")


class FaultPlan:
    """A deterministic, seeded schedule of faults on the fleet clock.

    Events fire in time order via `due(now)`, which pops and returns
    every event with ``t_model_s <= now`` — the scheduler calls it once
    per tick. `random()` draws a reproducible schedule; `report()`
    serializes the plan (seed included) so a chaos bench artifact alone
    reproduces the run.
    """

    def __init__(self, events: list[FaultEvent] | None = None, *,
                 seed: int | None = None):
        self.seed = seed
        self._events = sorted(events or [], key=lambda e: e.t_model_s)
        self._fired: list[FaultEvent] = []

    @classmethod
    def random(cls, members: list[str], seed: int, *,
               horizon_s: float, n_events: int = 3,
               kinds: tuple[str, ...] = ("crash", "stall"),
               stall_factor: float = 8.0,
               stall_duration_frac: float = 0.3,
               state_lost_p: float = 0.5) -> "FaultPlan":
        """Draw `n_events` faults uniformly over ``(0, horizon_s)`` with
        kinds/members chosen by the seeded stream. At most one crash per
        member is drawn (a member only dies once), and never every
        member: at least one survivor remains to absorb the work."""
        for k in kinds:
            if k not in KINDS:
                raise ValueError(f"unknown fault kind {k!r}")
        rng = np.random.default_rng(seed)
        events: list[FaultEvent] = []
        crashed: set[str] = set()
        for _ in range(n_events):
            kind = str(rng.choice(list(kinds)))
            member = str(rng.choice(members))
            t = float(rng.uniform(0.0, horizon_s))
            if kind == "crash":
                if member in crashed or len(crashed) + 1 >= len(members):
                    kind = "stall"     # keep a survivor
                else:
                    crashed.add(member)
            if kind == "crash":
                events.append(FaultEvent(
                    t, "crash", member,
                    state_lost=bool(rng.random() < state_lost_p)))
            elif kind == "stall":
                events.append(FaultEvent(
                    t, "stall", member, factor=stall_factor,
                    duration_s=stall_duration_frac * horizon_s))
            elif kind == "page_pressure":
                events.append(FaultEvent(
                    t, "page_pressure", member,
                    duration_s=stall_duration_frac * horizon_s,
                    pages=int(rng.integers(1, 9))))
            else:
                events.append(FaultEvent(t, "artifact_corruption", member))
        return cls(events, seed=seed)

    def due(self, now: float) -> list[FaultEvent]:
        """Pop and return every event scheduled at or before `now`."""
        fired: list[FaultEvent] = []
        while self._events and self._events[0].t_model_s <= now:
            fired.append(self._events.pop(0))
        self._fired.extend(fired)
        return fired

    @property
    def remaining(self) -> int:
        """Events scheduled but not yet fired."""
        return len(self._events)

    def __len__(self) -> int:
        return len(self._events) + len(self._fired)

    def report(self) -> dict:
        """Serializable view of the plan: the seed plus every event and
        whether it has fired — the chaos bench embeds this in its JSON
        artifact so a fault run is auditable (and reproducible) from the
        artifact alone."""
        def row(e: FaultEvent, fired: bool) -> dict:
            return {**dataclasses.asdict(e), "fired": fired}
        return {
            "seed": self.seed,
            "events": ([row(e, True) for e in self._fired]
                       + [row(e, False) for e in self._events]),
        }


def retry_backoff_s(attempt: int, *, base_s: float = 0.05,
                    cap_s: float = 1.0) -> float:
    """Capped exponential backoff for replay/defer retries: ``base *
    2**(attempt-1)`` clamped to `cap_s` (attempt counts from 1).
    Deterministic — no jitter — so retry timelines replay exactly under
    a fixed seed."""
    if attempt < 1:
        raise ValueError("attempt counts from 1")
    return min(base_s * (2.0 ** (attempt - 1)), cap_s)
