"""Paged KV-cache bookkeeping: page allocator, refcounts, prefix registry.

The serving engine's paged KV layout (`ServingEngine(kv_layout="paged")`)
splits the cache into fixed-size pages of ``page_size`` tokens living in one
shared device pool; every decode slot and admission-lane row holds a *page
table* (a short list of physical page ids) instead of a dense ``max_len``
allocation. This module is the host-side brain of that layout:

* a **free list** of physical pages, recycled across slot retire/refill and
  admission-lane parking (splicing a parked row into a decode slot moves a
  page list between host records — zero device copies);
* **refcounts** per page, so shared-prefix pages outlive individual readers
  and are returned to the free list only when the last reader retires;
* a **prefix registry**: prompts register their full prompt pages under a
  token-chain key (and, at prompt completion, a frozen snapshot of the final
  partial page), and later admissions whose prompt starts with a registered
  chain map those pages instead of re-prefilling them. A reader that must
  *write* into a matched page — its prompt diverges inside the page, or
  generation appends to it — gets a **copy-on-write fork**: a fresh page is
  allocated and the shared content copied, so registered pages are immutable
  (the write path never touches a page with more than one reference).

Allocation policy is full reservation: `admit` allocates every page a
request can touch (prompt + clamped decode budget) up front, so the decode
loop never allocates mid-flight and free-list exhaustion surfaces only at
admission, where the engine can simply defer the request. Device-side data
movement (the COW copies) is returned to the caller as ``(src, dst)`` page
id pairs; the allocator itself never touches device memory.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

NULL_PAGE = 0  # physical page 0 is reserved: dead/pad rows point (and
# scribble) here; real rows never receive it, and gathers through it are
# masked by `layers.page_valid_mask`.


class PageCacheFull(RuntimeError):
    """Raised when an allocation cannot be satisfied even after evicting
    every reclaimable prefix-registry entry."""


@dataclasses.dataclass
class _PrefixEntry:
    """One registered page of a prompt-prefix chain (or a frozen snapshot
    of a final partial page)."""

    page: int
    n_tokens: int            # tokens of the chain this entry completes
    last_hit: int = 0        # LRU clock for eviction


@dataclasses.dataclass
class Admission:
    """What `PageAllocator.admit` hands the engine for one request."""

    pages: list[int]         # physical pages covering the row's capacity
    base: int                # prompt tokens already cached (skip prefill)
    copies: list[tuple[int, int]]  # device page copies (src, dst) to apply


class PageAllocator:
    """Host-side page bookkeeping for the paged KV cache.

    ``num_pages`` counts physical pages including the reserved null page;
    ``page_size`` is tokens per page. All methods are O(pages touched);
    nothing here allocates device memory.
    """

    def __init__(self, num_pages: int, page_size: int, *,
                 prefix_cache: bool = True):
        """Build an allocator over ``num_pages`` physical pages (page 0 is
        reserved as the null/scratch page and never handed out)."""
        if num_pages < 2:
            raise ValueError("num_pages must be >= 2 (page 0 is reserved)")
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.prefix_cache = prefix_cache
        self.refs = np.zeros(self.num_pages, np.int32)
        self.refs[NULL_PAGE] = 1                     # permanently resident
        self._free: list[int] = list(range(self.num_pages - 1, 0, -1))
        # full-page chains: key = tokens[:k*page_size].tobytes() -> entry
        # holding the k-th page; partial tails: key = full-chain bytes ->
        # (tail token bytes, entry) holding a frozen snapshot page
        self._chains: dict[bytes, _PrefixEntry] = {}
        self._partials: dict[bytes, tuple[bytes, _PrefixEntry]] = {}
        self._clock = 0
        # pages parked aside by `squeeze` (simulated external pressure):
        # neither free nor referenced until `unsqueeze` returns them
        self._squeezed: list[int] = []
        self.stats = {
            "allocs": 0, "frees": 0, "cow_forks": 0, "evictions": 0,
            "prefix_hits": 0, "prefix_hit_tokens": 0, "peak_in_use": 0,
            "squeezed": 0, "registry_sheds": 0,
        }

    # ------------------------------------------------------------------
    # core alloc/free
    # ------------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        """Pages immediately available without evicting registry entries."""
        return len(self._free)

    @property
    def in_use(self) -> int:
        """Pages currently referenced (excluding the null page)."""
        return self.num_pages - 1 - len(self._free)

    def _take(self) -> int:
        if not self._free:
            raise PageCacheFull(
                f"page pool exhausted ({self.num_pages - 1} usable pages)")
        p = self._free.pop()
        assert self.refs[p] == 0
        self.refs[p] = 1
        self.stats["allocs"] += 1
        self.stats["peak_in_use"] = max(self.stats["peak_in_use"],
                                        self.in_use)
        return p

    def alloc(self, n: int = 1) -> list[int]:
        """Allocate ``n`` fresh pages (refcount 1 each), evicting
        reclaimable prefix-registry entries if the free list runs dry.
        Raises `PageCacheFull` — after rolling back the partial grab — if
        the pool cannot satisfy the request."""
        if len(self._free) < n:
            self._evict(n - len(self._free))
        if len(self._free) < n:
            raise PageCacheFull(
                f"need {n} pages, {len(self._free)} free of "
                f"{self.num_pages - 1} usable")
        return [self._take() for _ in range(n)]

    def retain(self, pages: list[int]) -> None:
        """Add one reference to each page (a new reader of shared pages)."""
        for p in pages:
            assert p != NULL_PAGE and self.refs[p] > 0
            self.refs[p] += 1

    def release(self, pages: list[int]) -> None:
        """Drop one reference per page; pages reaching zero return to the
        free list (the last-reader-retires contract)."""
        for p in pages:
            if p == NULL_PAGE:
                continue
            assert self.refs[p] > 0, f"double free of page {p}"
            self.refs[p] -= 1
            if self.refs[p] == 0:
                self._free.append(p)
                self.stats["frees"] += 1

    # ------------------------------------------------------------------
    # prefix registry
    # ------------------------------------------------------------------
    def _key(self, tokens: np.ndarray, n: int) -> bytes:
        return np.ascontiguousarray(tokens[:n], np.int32).tobytes()

    def _evict(self, need: int) -> None:
        """Drop LRU registry entries whose page only the registry holds
        (evicting shared entries would reclaim nothing) until ``need``
        pages were freed or no reclaimable entry remains."""
        freed = 0
        order = sorted(
            [(e.last_hit, k, None) for k, e in self._chains.items()
             if self.refs[e.page] == 1]
            + [(e.last_hit, k, t) for k, (t, e) in self._partials.items()
               if self.refs[e.page] == 1])
        for _, key, tail in order:
            if freed >= need:
                break
            entry = (self._partials.pop(key)[1] if tail is not None
                     else self._chains.pop(key))
            self.release([entry.page])
            self.stats["evictions"] += 1
            freed += 1

    def match(self, prompt: np.ndarray) -> tuple[list[int], int]:
        """Longest registered prefix of ``prompt``: full-page chain walk,
        then an optional partial tail. Returns (shared pages, tokens
        covered) WITHOUT retaining — `admit` does the bookkeeping."""
        if not self.prefix_cache:
            return [], 0
        T = self.page_size
        pages: list[int] = []
        k = 0
        while (k + 1) * T <= len(prompt):
            e = self._chains.get(self._key(prompt, (k + 1) * T))
            if e is None:
                break
            self._clock += 1
            e.last_hit = self._clock
            pages.append(e.page)
            k += 1
        covered = k * T
        part = self._partials.get(self._key(prompt, covered))
        if part is not None:
            tail, e = part
            n_tail = e.n_tokens - covered
            if (covered + n_tail <= len(prompt)
                    and self._key(prompt[covered:], n_tail) == tail):
                self._clock += 1
                e.last_hit = self._clock
                pages.append(e.page)
                covered = e.n_tokens
        return pages, covered

    # ------------------------------------------------------------------
    # engine-facing operations
    # ------------------------------------------------------------------
    def admit(self, prompt: np.ndarray, budget: int, *,
              prefix_rows: int = 0, reuse: bool = True) -> Admission:
        """Reserve a request's full page capacity (prompt + ``budget``
        generated tokens), reusing registered shared-prefix pages.

        The returned ``base`` is how many leading prompt tokens are already
        cached (always <= len(prompt) - 1, so the final prompt token is
        recomputed and its logits can seed sampling). Any matched page the
        row will *write* into — the page containing ``base`` — is forked
        copy-on-write; ``copies`` lists the device page copies to apply.
        Raises `PageCacheFull` with no state change when the pool cannot
        cover the reservation.

        ``prefix_rows`` reserves extra leading cache rows written by an
        admission hook ahead of the prompt (e.g. a VLM patch prefix);
        ``reuse=False`` skips prefix matching entirely — admit-family rows
        carry modality-dependent cache content, so token-keyed sharing
        would be unsound (``base`` stays 0).
        """
        T = self.page_size
        plen = len(prompt)
        n_total = max(1, math.ceil(
            (int(prefix_rows) + plen + max(budget, 1)) / T))
        if not reuse:
            owned = self.alloc(n_total)
            return Admission(pages=owned, base=0, copies=[])
        shared, covered = self.match(prompt)
        base = min(covered, plen - 1)
        # the page holding position `base` gets written -> must be owned
        n_keep = min(len(shared), base // T)
        fork_src = shared[n_keep] if n_keep < len(shared) else None
        n_own = n_total - n_keep
        if len(self._free) < n_own:
            self._evict(n_own - len(self._free))
            # eviction may have dropped the entries we just matched; the
            # conservative re-match keeps bookkeeping consistent
            shared, covered = self.match(prompt)
            base = min(covered, plen - 1)
            n_keep = min(len(shared), base // T)
            fork_src = shared[n_keep] if n_keep < len(shared) else None
            n_own = n_total - n_keep
        owned = self.alloc(n_own)                     # raises if short
        kept = shared[:n_keep]
        self.retain(kept)
        copies: list[tuple[int, int]] = []
        if fork_src is not None:
            copies.append((int(fork_src), int(owned[0])))
            self.stats["cow_forks"] += 1
        if base > 0:
            self.stats["prefix_hits"] += 1
            self.stats["prefix_hit_tokens"] += base
        return Admission(pages=kept + owned, base=base, copies=copies)

    def register(self, prompt: np.ndarray, pages: list[int],
                 written: int) -> list[tuple[int, int]]:
        """Register the prompt pages a row has fully cached so far.

        Every full page covered by ``written`` prompt tokens joins the
        chain registry (idempotent; the registry takes one reference per
        new entry). When the whole prompt is cached and ends mid-page, a
        frozen *snapshot* of the partial page is registered instead of the
        live page — the row keeps appending generated tokens to its own
        copy — which costs one device page copy, returned as (src, dst).
        Registration is best-effort: pool exhaustion skips the snapshot
        rather than failing admission-critical allocation paths.
        """
        if not self.prefix_cache:
            return []
        T = self.page_size
        plen = len(prompt)
        for j in range(min(written, plen) // T):
            key = self._key(prompt, (j + 1) * T)
            if key in self._chains:
                continue
            self._clock += 1
            self.retain([pages[j]])
            self._chains[key] = _PrefixEntry(
                page=pages[j], n_tokens=(j + 1) * T, last_hit=self._clock)
        copies: list[tuple[int, int]] = []
        if written >= plen and plen % T:
            k = plen // T
            key = self._key(prompt, k * T)
            if key not in self._partials:
                try:
                    (snap,) = self.alloc(1)
                except PageCacheFull:
                    return copies
                self._clock += 1
                copies.append((int(pages[k]), int(snap)))
                self._partials[key] = (
                    self._key(prompt[k * T:], plen - k * T),
                    _PrefixEntry(page=snap, n_tokens=plen,
                                 last_hit=self._clock))
        return copies

    # ------------------------------------------------------------------
    # degraded modes: pool pressure + registry shedding
    # ------------------------------------------------------------------
    def squeeze(self, n: int) -> int:
        """Remove up to `n` pages from the free list, modelling external
        pool pressure (another tenant, a chaos fault) — the pages are
        parked aside, not freed, and `unsqueeze` returns them. Returns
        how many were actually taken (the free list may be shorter)."""
        take = min(int(n), len(self._free))
        for _ in range(take):
            self._squeezed.append(self._free.pop())
        self.stats["squeezed"] = len(self._squeezed)
        return take

    def unsqueeze(self) -> int:
        """Return every squeezed page to the free list (pressure
        relieved). Returns the count returned."""
        n = len(self._squeezed)
        self._free.extend(self._squeezed)
        self._squeezed.clear()
        self.stats["squeezed"] = 0
        return n

    def shed_registry(self) -> int:
        """Drop EVERY shared-prefix registry entry, releasing the
        registry's reference on each page: sole-owner pages return to
        the free list immediately, shared ones when their last reader
        retires. This is the engine's first response to sustained pool
        pressure — the registry is a latency cache, and shedding it can
        never change token streams (prefix reuse only skips recompute of
        identical KV rows). Returns the number of entries dropped."""
        entries = ([e for e in self._chains.values()]
                   + [e for _, e in self._partials.values()])
        self._chains.clear()
        self._partials.clear()
        for e in entries:
            self.release([e.page])
        self.stats["registry_sheds"] += len(entries)
        return len(entries)

    def report(self) -> dict:
        """Allocator counters for the engine's serving report."""
        return {
            "num_pages": self.num_pages - 1,
            "page_size": self.page_size,
            "pages_in_use": int(self.in_use),
            "pages_free": int(self.free_pages),
            "registry_entries": len(self._chains) + len(self._partials),
            **{k: int(v) for k, v in self.stats.items()},
        }
