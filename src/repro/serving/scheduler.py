"""Predictor-driven SLA- and energy-aware fleet scheduler.

`FleetScheduler` owns one admission queue over N `ServingEngine`
instances — possibly on different `ChipSpec`s and tp widths — and makes
three predictor-priced decisions per tick, closing the loop the paper's
predictor exists for (price a GEMM configuration *before* running it):

1. **Routing** (`_route`): each pending request is priced on every
   active (engine, chunk-bucket) placement via the engine's cached
   `fused_step_estimate` (which runs `core.energy.fused_step_energy`
   over the decode + chunk GEMM fleets and `hwsim.collective_cost` over
   the ring traffic) folded into a per-request share by
   `core.energy.marginal_request_cost` — the same per-row/per-slot
   arithmetic the engine's attribution ledger uses. The scheduler picks
   the placement with the lowest predicted marginal fleet J/token among
   those whose predicted TTFT meets the request's SLA-class deadline,
   falling back to the fastest placement when none does.

2. **Chunk sizing** (`_chunk_policy_for`): each engine's SJF chunk
   sizing is replaced by a deadline-aware policy when SLO-classed
   requests are in its lane — the smallest chunk bucket predicted to
   land every pending deadline wins (small buckets waste no padded
   positions and interleave more decode; wide buckets cut calls when
   slack runs short). A draining engine always chunks at the widest
   bucket.

3. **Race to idle** (`_race_to_idle`): the ledger charges every fleet
   member its `ChipSpec` idle floor for the whole fleet makespan
   (`core.energy.parked_energy_j`), so shrinking the makespan — or
   finishing a lagging, expensive engine's work early and parking it —
   saves real energy. When the remaining fleet's predicted completion
   of all outstanding prefill work still meets every outstanding SLO
   deadline, the most expensive active engine is marked *draining*
   (no new routes, widest chunks) and parks at idle power once empty.

**Fault tolerance** (`serving/faults.py`, `docs/serving.md` "Failure
model & recovery"): a seeded `FaultPlan` injects crashes, stalls,
page-pool pressure, and predictor-artifact corruption on the same fleet
model clock the scheduler orders steps by, so chaos runs replay exactly.
A crashed (or straggler-evicted) member's in-flight requests are
checkpointed (`ServingEngine.checkpoint_inflight`) and either *migrated*
— their decode-state rows spliced into a `state_compatible` survivor for
a bit-identical continuation — or *replayed*: requeued with the tokens
already emitted as a forced prefix (`Request.replay`), so client-visible
streams stay append-only and every request finishes exactly once.
Replays pay capped exponential backoff (`faults.retry_backoff_s`) and
re-enter routing through the normal marginal-J/token pricing; the failed
attempt's unusable spend is charged back to the failed member
(`charge_lost_energy`) so fleet ledgers still sum. Stalls are not read
off the plan: detection reuses `train.ft.StragglerDetector` EWMAs over
each member's observed-vs-predicted step-time ratio, and eviction
follows the detector's flag. Overload admission control is per SLA
class (`SLAClass.policy`): `accept` places least-late, `defer` rotates
the request with capped backoff, `shed` records a terminal disposition;
the `admission_watermark_tokens` backlog watermark and predicted-TTFT
infeasibility both trigger it.

Fleet accounting: ``fleet_energy_j`` = every engine's served energy
(attributed + in-call idle shares) **plus** each engine's idle-floor
energy over the gap between its own busy time and the fleet makespan
(a crashed member's horizon truncates at the crash instant — dead chips
burn nothing). A single-engine baseline is the same ledger with all
work forced onto one member (``route_to=``) while the others sit parked
for its whole makespan — so the scheduler beats the best such baseline
by routing to efficient chips *and* by shrinking the makespan
(parallelism cuts the idle-floor term). `benchmarks/bench_serving.py
--fleet` gates both that comparison and SLO attainment (`--chaos` gates
the fault path); `tests/test_fleet_scheduler.py` holds the conservation
and routing-invariance properties and `tests/test_fault_injection.py`
the recovery ones.

Time base: each engine advances its own deterministic model clock
(predicted seconds of dispatched calls). The scheduler aligns them into
one fleet timeline by always stepping the busiest-backlogged engine
with the *smallest* elapsed clock and fast-forwarding an idle engine's
clock to "now" at handoff — so TTFT measured against the fleet timeline
(`ttft_fleet_model_s`) includes scheduler queue wait and is
deterministic and hardware-independent, like the engine's own model
clock.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque

from repro.serving.engine import Request, Result, ServingEngine
from repro.serving.faults import FaultPlan, retry_backoff_s
from repro.train.ft import StragglerConfig, StragglerDetector

_POLICIES = ("accept", "defer", "shed")


@dataclasses.dataclass(frozen=True)
class SLAClass:
    """A named TTFT service class.

    `ttft_model_s` is the per-request time-to-first-token bound on the
    fleet model clock (submit -> first token, queue wait included);
    None declares a best-effort class with no deadline. The bench's
    attainment gate reads the fraction of a class's requests that met
    the bound.

    `policy` is the class's overload admission policy, applied when no
    placement is predicted to meet the deadline or the fleet backlog
    crosses the scheduler's admission watermark: ``accept`` places on
    the least-late engine anyway, ``defer`` pushes the request back
    with capped exponential backoff (`defer_s` base, at most
    `max_defers` times, then accepts late rather than starving it),
    ``shed`` rejects it with a terminal disposition in the request
    log."""

    name: str
    ttft_model_s: float | None = None
    policy: str = "accept"
    defer_s: float = 0.05
    max_defers: int = 4

    def __post_init__(self):
        if self.policy not in _POLICIES:
            raise ValueError(f"unknown admission policy {self.policy!r} "
                             f"(expected one of {_POLICIES})")
        if self.defer_s <= 0.0:
            raise ValueError("defer_s must be positive")


@dataclasses.dataclass
class _ReqMeta:
    """Scheduler-side bookkeeping for one in-flight request."""

    sla: str | None
    t_submit: float             # fleet clock at scheduler submission
    t_handoff: float = 0.0      # fleet clock at engine handoff
    engine: str | None = None   # member the request was routed to
    bucket: int = 0             # chunk bucket chosen at routing time
    pred_j_per_token: float = 0.0
    pred_ttft_s: float = 0.0
    not_before: float = 0.0     # earliest fleet clock routing may place
    defers: int = 0             # admission-control deferrals so far
    retries: int = 0            # replay attempts after member failures
    migrations: int = 0         # state-row migrations between members
    ttft_override: float | None = None  # pinned fleet TTFT (see below)
    # ttft_override: once a request's first token has streamed, its
    # fleet TTFT is a historical fact — a later migration or replay of
    # the tail must not rewrite it, so the value is pinned at failure
    # time and _finish prefers it over the finishing engine's measure.


@dataclasses.dataclass
class _Member:
    """One fleet engine plus the scheduler's view of it."""

    name: str
    engine: ServingEngine
    host_idx: int = 0           # row in the straggler detector
    clock0: float = 0.0         # engine clock at scheduler epoch
    routed: int = 0
    completed: int = 0
    parked: bool = False
    draining: bool = False
    parks: int = 0
    drains: int = 0
    parked_model_s: float = 0.0  # closed park intervals (fleet clock)
    parked_from: float = 0.0     # open park interval start
    crashed: bool = False        # permanent loss (fault plan)
    crashed_at: float = 0.0      # fleet clock at the crash
    crashes: int = 0
    evicted: bool = False        # straggler eviction (may rejoin)
    evictions: int = 0
    stall_until: float = 0.0     # open stall window end (fleet clock)
    stall_factor: float = 1.0    # active step-time dilation
    stalls: int = 0

    @property
    def alive(self) -> bool:
        """False for members routing/stepping must never touch: crashed
        permanently, or evicted until their stall window passes."""
        return not self.crashed and not self.evicted

    @property
    def elapsed(self) -> float:
        """Fleet-timeline position of this engine (clock - epoch)."""
        return self.engine.model_clock_s - self.clock0

    @property
    def has_room(self) -> bool:
        """True while the engine can absorb another admission without
        queueing past its lane (the scheduler's late-binding
        backpressure)."""
        eng = self.engine
        return (len(eng.queue) + eng.lane_view["in_flight"]
                < eng.lane_width)


def _pow2ceil(n: int) -> int:
    """Smallest power of two >= max(n, 1)."""
    w = 1
    while w < n:
        w *= 2
    return w


def _percentile(xs: list[float], q: float) -> float:
    """Nearest-rank percentile of a list (0 for an empty one)."""
    if not xs:
        return 0.0
    xs = sorted(xs)
    i = min(int(math.ceil(q / 100.0 * len(xs))) - 1, len(xs) - 1)
    return xs[max(i, 0)]


def _prefill_len(req: Request) -> int:
    """Effective prefill length of a request: the prompt plus any
    forced replay prefix (all but the last replayed token prefills; the
    last one is re-emitted as the first decode token)."""
    extra = max(len(req.replay) - 1, 0) if req.replay else 0
    return len(req.prompt) + extra


class FleetScheduler:
    """One admission queue over a fleet of `ServingEngine`s (see the
    module docstring for the decision loop; `docs/serving.md` for the
    guide)."""

    def __init__(self, engines: dict[str, ServingEngine], *,
                 sla: dict[str, SLAClass] | None = None,
                 default_sla: str | None = None,
                 route_to: str | None = None,
                 race_to_idle: bool = True,
                 pretune: bool = False,
                 tune_objective: str = "energy",
                 tune_rank_mode: str = "auto",
                 fault_plan: FaultPlan | None = None,
                 straggler_cfg: StragglerConfig | None = None,
                 admission_watermark_tokens: int | None = None):
        """`engines` maps member names to steppable engines (continuous
        chunked admission on the dense KV layout — `serve_step`'s
        contract). `sla` maps class names to `SLAClass` bounds;
        `default_sla` is applied to requests submitted without one.

        `route_to` forces every request onto one named member while the
        others sit parked — the single-engine baseline the fleet bench
        compares against (same ledger, so the comparison is
        apples-to-apples). `race_to_idle=False` disables the
        drain-and-park decision (routing and chunk sizing stay on).

        `pretune=True` warms the whole fleet's GEMM shapes up front via
        `ops.warm_fleet_gemm_cache` — engines sharing a chip are
        unioned into one batched tuning pass, and each engine's
        `pretuned` map (which its energy pricing consults) is filled
        from its chip's results.

        `fault_plan` is a seeded chaos schedule polled once per tick
        (`serving/faults.py`). `straggler_cfg` tunes the eviction
        detector (`train.ft.StragglerDetector` over observed/predicted
        step-time ratios — 1.0 is healthy, so detection is
        chip-independent). `admission_watermark_tokens` is the fleet
        prefill-backlog level above which SLA admission policies kick
        in even for placements predicted feasible."""
        if not engines:
            raise ValueError("FleetScheduler needs at least one engine")
        self.members: dict[str, _Member] = {}
        for idx, (name, eng) in enumerate(engines.items()):
            if (eng.mode == "wave" or eng.admission != "chunked"
                    or eng.kv_layout != "dense"
                    or not eng._continuous_supported()):
                raise ValueError(
                    f"engine {name!r} is not steppable (fleet scheduling "
                    f"requires continuous chunked admission on the dense "
                    f"KV layout)")
            self.members[name] = _Member(name=name, engine=eng,
                                         host_idx=idx,
                                         clock0=eng.model_clock_s)
            eng.chunk_policy = self._chunk_policy_for(name)
        self.sla = dict(sla or {})
        for cname, cls in self.sla.items():
            if cname != cls.name:
                raise ValueError(f"SLA key {cname!r} != class {cls.name!r}")
        if default_sla is not None and default_sla not in self.sla:
            raise ValueError(f"default_sla {default_sla!r} not in sla map")
        self.default_sla = default_sla
        if route_to is not None and route_to not in self.members:
            raise ValueError(f"route_to {route_to!r} not in fleet")
        self.route_to = route_to
        self.race_to_idle = race_to_idle
        self.admission_watermark_tokens = admission_watermark_tokens
        self._fault_plan = fault_plan
        self._straggler_cfg = straggler_cfg
        self._detector = StragglerDetector(len(self.members),
                                           straggler_cfg)
        self._pending: deque[Request] = deque()
        self._recovery: deque[dict] = deque()
        self._meta: dict[int, _ReqMeta] = {}
        self._done: dict[int, dict] = {}
        self.routed_to: dict[int, str] = {}
        self._counters = {"migrations": 0, "replays": 0, "retries": 0}
        self._shed_counts: dict[str, int] = {}
        self._defer_counts: dict[str, int] = {}
        if pretune:
            self._pretune_fleet(tune_objective, tune_rank_mode)

    # ------------------------------------------------------------------
    # fleet pre-tuning
    # ------------------------------------------------------------------
    def _pretune_fleet(self, objective: str, rank_mode: str) -> None:
        """Warm every member's GEMM fleet in one batched pass per chip
        (`ops.warm_fleet_gemm_cache`) and install the per-engine config
        maps, invalidating any step-energy estimates priced before."""
        from repro.kernels import ops

        names = list(self.members)
        specs = []
        for name in names:
            e = self.members[name].engine
            specs.append({
                "cfg": e.cfg, "chip": e.chip,
                "dtype": e.cfg.activation_dtype,
                "max_batch": e.max_batch, "max_len": e.max_len,
                "include_slot_prefill": True,
                "chunk_tokens": e.chunk_tokens,
                "lane_width": e.lane_width,
                "tp": e.tp, "grain": e.ssm_grain})
        tuned = ops.warm_fleet_gemm_cache(specs, objective=objective,
                                          rank_mode=rank_mode)
        for name, configs in zip(names, tuned):
            eng = self.members[name].engine
            if configs:
                eng.pretuned = configs
                eng._step_energy_cache.clear()

    # ------------------------------------------------------------------
    # clocks
    # ------------------------------------------------------------------
    def fleet_now(self) -> float:
        """Current fleet-timeline position: the smallest elapsed clock
        among busy live members (the next engine to step), or the
        largest elapsed anywhere when the fleet is idle."""
        busy = [m.elapsed for m in self.members.values()
                if m.alive and m.engine.has_work and not m.parked]
        if busy:
            return min(busy)
        return max((m.elapsed for m in self.members.values()), default=0.0)

    def _sync_clock(self, m: _Member, now: float) -> None:
        """Fast-forward an idle-lagging member's clock to `now`: its
        model clock only advances while dispatching, so an engine that
        sat idle re-enters the fleet timeline at the present, not in
        the past (handoff wait must never read negative)."""
        gap = now - m.elapsed
        if gap > 0.0:
            m.engine._clock += gap

    # ------------------------------------------------------------------
    # fault plane
    # ------------------------------------------------------------------
    def arm_faults(self, plan: FaultPlan | None) -> None:
        """Install (or clear) the chaos plan. The chaos bench arms it
        *after* its warm-up pass + `reset_stats`, so the plan's model-
        clock event times land on the measured run's timeline."""
        self._fault_plan = plan

    def _poll_faults(self) -> None:
        """Apply due chaos events and close expired stall windows (an
        evicted member whose stall has passed rejoins with fresh
        detector history). Runs once at the top of every tick."""
        now = self.fleet_now()
        for m in self.members.values():
            if m.stall_factor > 1.0 and now >= m.stall_until:
                m.stall_factor = 1.0
            if (m.evicted and m.stall_factor == 1.0
                    and now >= m.stall_until):
                m.evicted = False
                self._detector.reset(m.host_idx)
        if self._fault_plan is None:
            return
        for ev in self._fault_plan.due(now):
            m = self.members.get(ev.member)
            if m is None or not m.alive:
                continue
            if ev.kind == "crash":
                self._fail_member(m, evict=False,
                                  state_lost=ev.state_lost)
            elif ev.kind == "stall":
                m.stall_factor = max(float(ev.factor), 1.0)
                m.stall_until = now + float(ev.duration_s)
                m.stalls += 1
            elif ev.kind == "artifact_corruption":
                from repro.core.predictor import ArtifactError

                # retune degrades itself to BASELINE configs on the
                # injected error; serving continues, report() flags it
                m.engine.retune(_inject=ArtifactError(
                    f"chaos: corrupt predictor artifact on {m.name}"))
            elif ev.kind == "page_pressure":
                # fleet members are dense (serve_step contract); the
                # event only bites engines running the paged layout
                if m.engine.kv_layout == "paged":
                    m.engine.inject_page_pressure(ev.pages)

    def _fail_member(self, m: _Member, *, evict: bool,
                     state_lost: bool = False) -> None:
        """Take a member out of service (crash: permanent; evict:
        until its stall window passes) and checkpoint its in-flight
        work into the recovery queue. Requests whose first token
        already streamed get their fleet TTFT pinned here — migration
        or replay of the tail must not rewrite history."""
        now = self.fleet_now()
        records = m.engine.checkpoint_inflight(state_lost=state_lost)
        for rec in records:
            rec["src"] = m.name
            uid = rec["req"].uid
            meta = self._meta.get(uid)
            if meta is not None:
                if (rec["tokens"] and rec["ttft_model_s"] is not None
                        and meta.ttft_override is None):
                    wait = max(meta.t_handoff - meta.t_submit, 0.0)
                    meta.ttft_override = rec["ttft_model_s"] + wait
                meta.engine = None
            self.routed_to.pop(uid, None)
        self._recovery.extend(records)
        if m.parked:
            self._unpark(m, now)
        if evict:
            m.evicted = True
            m.evictions += 1
        else:
            m.crashed = True
            m.crashed_at = now
            m.crashes += 1

    def _maybe_evict(self) -> None:
        """Evict any member the straggler detector flags, as long as a
        survivor exists to absorb its work (a lone member rides out its
        stall instead — slow beats dead)."""
        flagged = set(self._detector.update_flags())
        if not flagged:
            return
        by_host = {m.host_idx: m for m in self.members.values()}
        for h in sorted(flagged):
            m = by_host.get(h)
            if m is None or not m.alive:
                continue
            if not any(o.alive for o in self.members.values()
                       if o is not m):
                continue
            self._fail_member(m, evict=True)

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def submit(self, req: Request, sla: str | None = None) -> None:
        """Queue a request on the fleet. `sla` (or `req.sla`, or the
        scheduler default) names its `SLAClass`; None serves best
        effort. Routing happens lazily inside `run_until_empty` /
        `step`, so a request's placement sees the fleet state at
        admission time, not submission time."""
        cname = sla or req.sla or self.default_sla
        if cname is not None and cname not in self.sla:
            raise ValueError(f"unknown SLA class {cname!r}")
        req.sla = cname
        self._meta[req.uid] = _ReqMeta(sla=cname, t_submit=self.fleet_now())
        self._pending.append(req)

    def _deadline(self, meta: _ReqMeta) -> float | None:
        """Absolute fleet-clock TTFT deadline of a request (None when
        best-effort)."""
        if meta.sla is None:
            return None
        bound = self.sla[meta.sla].ttft_model_s
        return None if bound is None else meta.t_submit + bound

    # ------------------------------------------------------------------
    # decision (a): predictor-priced routing
    # ------------------------------------------------------------------
    def _place_cost(self, m: _Member, req: Request, bucket: int,
                    now: float) -> tuple[float, float]:
        """(predicted marginal J/token, predicted fleet TTFT seconds) of
        placing `req` on member `m` with chunk bucket `bucket`.

        The chunk side prices the *fused* step the engine will actually
        dispatch (decode fleet + one `width x bucket` chunk call) at the
        width the lane would grow to; the per-request share and the
        decode-step share come from `core.energy.marginal_request_cost`.
        TTFT is first-order: the engine's unfinished prefill backlog
        plus this prompt's own chunk calls (replay prefixes included),
        at the fused step cadence, starting from the later of `now` and
        the engine's own clock."""
        eng = m.engine
        view = eng.lane_view
        width = _pow2ceil(min(view["in_flight"] + 1, eng.lane_width))
        fused = eng.fused_step_estimate(width, bucket)
        n_calls = max(int(math.ceil(_prefill_len(req) / bucket)), 1)
        budget = eng._budget(req)
        cost = _marginal(fused, eng.decode_step_estimate(),
                         chunk_calls=n_calls, chunk_width=width,
                         decode_steps=budget, decode_batch=eng.max_batch,
                         tokens=budget)
        step_s = fused.step_s if fused is not None else 0.0
        backlog_calls = eng.backlog_tokens / max(width * bucket, 1)
        start = max(m.elapsed, now)
        ttft = (start - now) + (n_calls + backlog_calls) * step_s
        return cost.j_per_token, ttft

    def _buckets(self, eng: ServingEngine) -> tuple[int, ...]:
        """The engine's chunk-bucket ladder (`ops.chunk_buckets`)."""
        from repro.kernels import ops

        return ops.chunk_buckets(eng.max_len, eng.chunk_tokens,
                                 eng.ssm_grain)

    def _candidates(self, include_parked: bool) -> list[_Member]:
        """Members routing may currently target, cheapest-first order
        left to the cost search."""
        return [m for m in self.members.values()
                if m.alive and (include_parked or not m.parked)
                and not m.draining and m.has_room]

    def _overloaded(self) -> bool:
        """True when the fleet's live prefill backlog has crossed the
        admission watermark — SLA policies then gate even placements
        predicted feasible."""
        wm = self.admission_watermark_tokens
        if wm is None:
            return False
        backlog = sum(m.engine.backlog_tokens
                      for m in self.members.values() if m.alive)
        return backlog >= wm

    def _route(self) -> None:
        """Place recovery records, then pending requests FIFO onto
        (engine, chunk-bucket) placements: lowest predicted marginal
        fleet J/token among the SLO-feasible candidates. Requests in a
        backoff window rotate past; requests nothing can absorb stop
        the scan so FIFO fairness holds within the queue; infeasible or
        overloaded admissions go through their SLA class's policy."""
        self._route_recovery()
        hold: list[Request] = []
        while self._pending:
            req = self._pending.popleft()
            meta = self._meta[req.uid]
            now = self.fleet_now()
            if meta.not_before > now:
                hold.append(req)
                continue
            verdict = self._place(req, meta, now)
            if verdict == "wait":
                hold.append(req)
                break              # every lane full: the rest waits too
            if verdict == "deferred":
                hold.append(req)
        self._pending.extendleft(reversed(hold))

    def _place(self, req: Request, meta: _ReqMeta, now: float) -> str:
        """Try to hand one request off. Returns ``placed``, ``wait``
        (no candidate has room), ``deferred`` (admission control pushed
        it back with backoff) or ``shed`` (terminal disposition)."""
        if self.route_to is not None:
            target = self.members[self.route_to]
            bucket = self._buckets(target.engine)[-1]
            meta.pred_j_per_token, meta.pred_ttft_s = self._place_cost(
                target, req, bucket, now)
            self._handoff(target, req, meta, bucket)
            return "placed"
        deadline = self._deadline(meta)
        slack = (None if deadline is None
                 else max(deadline - now, 0.0))
        pick = None
        feasible_found = False
        for widen in (False, True):
            scored = [
                (m, b, *self._place_cost(m, req, b, now))
                for m in self._candidates(include_parked=widen)
                for b in self._buckets(m.engine)]
            if not scored:
                continue
            feasible = [c for c in scored
                        if slack is None or c[3] <= slack]
            if feasible:
                # cheapest predicted marginal J/token among the
                # placements that make the deadline
                pick = min(feasible, key=lambda c: (c[2], c[3]))
                feasible_found = True
                break
            if widen:
                # nothing makes the deadline even woken: the least-late
                # placement (admission control may still intervene)
                pick = min(scored, key=lambda c: (c[3], c[2]))
        if pick is None:
            return "wait"
        if not feasible_found or self._overloaded():
            verdict = self._admission_control(req, meta, now)
            if verdict is not None:
                return verdict
        target, bucket = pick[0], pick[1]
        meta.pred_j_per_token, meta.pred_ttft_s = pick[2], pick[3]
        self._handoff(target, req, meta, bucket)
        return "placed"

    def _admission_control(self, req: Request, meta: _ReqMeta,
                           now: float) -> str | None:
        """Apply the request's SLA-class overload policy; None means
        accept (place on the pick anyway)."""
        cls = self.sla.get(meta.sla) if meta.sla is not None else None
        if cls is None or cls.policy == "accept":
            return None
        if cls.policy == "shed":
            self._shed_request(req, meta, now)
            return "shed"
        if meta.defers >= cls.max_defers:
            return None            # cap hit: accept late, don't starve
        meta.defers += 1
        self._defer_counts[meta.sla] = (
            self._defer_counts.get(meta.sla, 0) + 1)
        meta.not_before = now + retry_backoff_s(meta.defers,
                                                base_s=cls.defer_s)
        return "deferred"

    def _shed_request(self, req: Request, meta: _ReqMeta, now: float,
                      *, status: str = "shed") -> None:
        """Record a terminal non-served disposition (admission shed, or
        work lost with the whole fleet) so every submitted request has
        exactly one entry in the request log."""
        self._meta.pop(req.uid, None)
        self.routed_to.pop(req.uid, None)
        key = meta.sla if meta.sla is not None else "_best_effort"
        self._shed_counts[key] = self._shed_counts.get(key, 0) + 1
        self._done[req.uid] = {
            "engine": None, "sla": meta.sla, "status": status,
            "ttft_fleet_model_s": None,
            "queue_wait_model_s": max(now - meta.t_submit, 0.0),
            "met_slo": False,
            "pred_j_per_token": meta.pred_j_per_token,
            "pred_ttft_model_s": meta.pred_ttft_s,
            "bucket": meta.bucket,
            "energy_j": 0.0, "n_tokens": 0,
            "retries": meta.retries, "migrations": meta.migrations,
        }

    def _route_recovery(self) -> None:
        """Place work checkpointed off failed members. A record with a
        surviving decode-state row *migrates*: the row is adopted by the
        cheapest state-compatible member with lane room (bit-identical
        continuation — same tokens as the no-fault run). Otherwise it
        *replays*: the request is requeued with its emitted tokens as a
        forced prefix (`Request.replay`, streams stay append-only)
        after capped exponential backoff, and the failed attempt's
        unusable spend is charged back to the source member so fleet
        ledgers still sum. Records whose compatible members are merely
        full wait for the next tick rather than degrade to replay."""
        if not self._recovery:
            return
        requeue: list[Request] = []
        keep: deque[dict] = deque()
        while self._recovery:
            rec = self._recovery.popleft()
            req = rec["req"]
            meta = self._meta.get(req.uid)
            if meta is None:
                continue           # already terminal
            now = self.fleet_now()
            src = self.members.get(rec.get("src", ""))
            if rec.get("state") is not None and src is not None:
                compat = [m for m in self.members.values()
                          if m.alive and m is not src
                          and m.engine.state_compatible(src.engine)]
                if compat:
                    roomy = [m for m in compat
                             if not m.draining and m.has_room]
                    if not roomy:
                        keep.append(rec)
                        continue
                    dst = min(roomy, key=self._decode_j_per_token)
                    if dst.parked:
                        self._unpark(dst, now)
                    self._sync_clock(dst, now)
                    dst.engine.adopt(rec)
                    meta.engine = dst.name
                    meta.migrations += 1
                    self.routed_to[req.uid] = dst.name
                    dst.routed += 1
                    self._counters["migrations"] += 1
                    continue
            # replay: the failed attempt's spend has no surviving owner
            # (engine_j and lost_j overlap by construction — the larger
            # of the two is the attempt's total unusable spend)
            meta.retries += 1
            self._counters["replays"] += 1
            self._counters["retries"] += 1
            meta.not_before = now + retry_backoff_s(meta.retries)
            req.replay = [int(t) for t in rec["tokens"]] or None
            lost = max(float(rec.get("energy_j", 0.0)),
                       float(rec.get("lost_energy_j", 0.0)))
            if src is not None and lost > 0.0:
                src.engine.charge_lost_energy(lost)
            requeue.append(req)
        self._recovery = keep
        self._pending.extendleft(reversed(requeue))

    def _handoff(self, m: _Member, req: Request, meta: _ReqMeta,
                 bucket: int) -> None:
        """Commit a routing decision: wake a parked member, align its
        clock with the fleet timeline, and enqueue the request on the
        engine."""
        now = self.fleet_now()
        if m.parked:
            self._unpark(m, now)
        self._sync_clock(m, now)
        meta.engine = m.name
        meta.bucket = int(bucket)
        meta.t_handoff = m.elapsed
        self.routed_to[req.uid] = m.name
        m.routed += 1
        m.engine.submit(req)

    # ------------------------------------------------------------------
    # decision (b): SLO-aware chunk sizing
    # ------------------------------------------------------------------
    def _chunk_policy_for(self, name: str):
        """Build the `ServingEngine.chunk_policy` hook for one member.

        Draining members chunk at the widest bucket (finish prefill in
        the fewest steps and get to idle). Otherwise, when any pending
        lane row carries an SLO deadline, pick the smallest chunk
        bucket whose predicted cadence lands *every* pending deadline —
        small buckets waste no padded positions (J/token) and
        interleave more decode steps; slack that has burned down forces
        wider chunks. Lanes holding only best-effort rows return None,
        keeping the engine's SJF default."""
        def policy(eng: ServingEngine,
                   pending: list[tuple[Request, int]]) -> int | None:
            """Chunk-bucket override for this member's pending lane
            (None keeps the engine's SJF default)."""
            m = self.members[name]
            ladder = self._buckets(eng)
            if m.draining:
                return ladder[-1]
            now = m.elapsed
            deadlines = []
            for req, rem in pending:
                meta = self._meta.get(req.uid)
                if meta is None:
                    continue
                dl = self._deadline(meta)
                if dl is not None:
                    deadlines.append((dl, rem))
            if not deadlines:
                return None
            width = _pow2ceil(len(pending))
            for bucket in ladder:
                est = eng.fused_step_estimate(width, bucket)
                step_s = est.step_s if est is not None else 0.0
                if all(now + math.ceil(rem / bucket) * step_s <= dl
                       for dl, rem in deadlines):
                    return bucket
            return ladder[-1]
        return policy

    # ------------------------------------------------------------------
    # decision (c): race to idle
    # ------------------------------------------------------------------
    def _decode_j_per_token(self, m: _Member) -> float:
        """Marginal decode J/token of a member (its full-batch decode
        step's energy split per slot) — the expense ranking the drain
        decision uses."""
        est = m.engine.decode_step_estimate()
        if est is None:
            return 0.0
        return est.energy_j / max(m.engine.max_batch, 1)

    def _outstanding_deadlines(self) -> list[tuple[float, float]]:
        """(deadline, remaining prefill tokens) of every request that
        has not yet produced its first token, fleet-wide — the load the
        remaining fleet must absorb for a drain/park to be safe."""
        out = []
        for req in self._pending:
            meta = self._meta[req.uid]
            dl = self._deadline(meta)
            if dl is not None:
                out.append((dl, float(_prefill_len(req))))
        return out

    def _fleet_meets_slo_without(self, excl: _Member) -> bool:
        """Would the remaining active members still land every
        outstanding SLO deadline if `excl` stopped taking work?

        First-order feasibility: the other members' aggregate
        widest-chunk prefill throughput must finish the fleet's whole
        unstarted prefill backlog (pending queue + every member's lane
        backlog) before the tightest outstanding deadline."""
        others = [m for m in self.members.values()
                  if m is not excl and m.alive
                  and not m.parked and not m.draining]
        if not others:
            return False
        deadlines = self._outstanding_deadlines()
        if not deadlines:
            return True
        rate = 0.0
        for m in others:
            eng = m.engine
            bucket = self._buckets(eng)[-1]
            width = _pow2ceil(eng.lane_width)
            est = eng.fused_step_estimate(width, bucket)
            if est is not None and est.step_s > 0.0:
                rate += width * bucket / est.step_s
        if rate <= 0.0:
            return False
        backlog = (sum(tok for _, tok in deadlines)
                   + sum(m.engine.backlog_tokens
                         for m in self.members.values()
                         if m is not excl and m.alive))
        t_done = self.fleet_now() + backlog / rate
        return t_done <= min(dl for dl, _ in deadlines)

    def _park(self, m: _Member, now: float) -> None:
        """Park an empty member at its chip's idle floor."""
        m.parked = True
        m.parks += 1
        m.parked_from = now

    def _unpark(self, m: _Member, now: float) -> None:
        """Wake a parked member (closing its park interval) so routing
        can hand it work again."""
        m.parked_model_s += max(now - m.parked_from, 0.0)
        m.parked = False
        m.draining = False

    def _race_to_idle(self) -> None:
        """Drain-and-park pass, run once per scheduler tick.

        Parks any live member that has fully drained (idle engines burn
        the same idle floor either way — parking records the decision
        and removes the member from routing). Separately, while more
        than one member is active and the remaining fleet is predicted
        to absorb all outstanding SLO load, the most expensive active
        member (marginal decode J/token) is marked draining: no new
        routes, widest chunks, park on empty."""
        now = self.fleet_now()
        for m in self.members.values():
            if m.alive and not m.parked and not m.engine.has_work:
                if m.draining or not self._pending:
                    self._park(m, now)
        if not self.race_to_idle or self.route_to is not None:
            return
        active = [m for m in self.members.values()
                  if m.alive and not m.parked and not m.draining]
        if len(active) < 2:
            return
        costly = max(active, key=self._decode_j_per_token)
        if (self._decode_j_per_token(costly) > 0.0
                and self._outstanding_deadlines()
                and self._fleet_meets_slo_without(costly)):
            costly.draining = True
            costly.drains += 1

    # ------------------------------------------------------------------
    # serving loop
    # ------------------------------------------------------------------
    def step(self) -> list[Result]:
        """One scheduler tick: poll the fault plane, route recovery and
        pending work, advance the live busy member with the smallest
        elapsed clock by one fused engine step (dilating its clock when
        a stall window is open), fold its finished requests into the
        fleet ledger, feed the straggler detector, then run the
        race-to-idle pass. When nothing can step but work is backlogged
        — every member parked or draining, deferrals pending, or the
        whole fleet dead — `_rescue` wakes a member, advances the clock
        past the earliest backoff, or sheds with a terminal disposition
        (the livelock guarantee). Returns the finished `Result`s."""
        self._poll_faults()
        self._route()
        busy = [m for m in self.members.values()
                if m.alive and m.engine.has_work]
        if not busy:
            if self._pending or self._recovery:
                self._rescue()
            return []
        m = min(busy, key=lambda mm: mm.elapsed)
        if m.parked:
            self._unpark(m, self.fleet_now())
        t0 = m.engine.model_clock_s
        out = m.engine.serve_step()
        dt = m.engine.model_clock_s - t0
        if m.stall_factor > 1.0 and dt > 0.0:
            # the stalled member really takes stall_factor x the
            # predicted model time: dilate its clock by the overhead
            m.engine._clock += (m.stall_factor - 1.0) * dt
        for r in out:
            self._finish(m, r)
        if dt > 0.0:
            # observed/predicted step-time ratio: 1.0 when healthy,
            # ~stall_factor under a stall — chip-independent, so a
            # naturally slower chip never reads as a straggler
            self._detector.record(m.host_idx,
                                  (dt * m.stall_factor) / dt)
            self._maybe_evict()
        self._race_to_idle()
        return out

    def _rescue(self) -> None:
        """Unblock a stalled tick (the livelock edge): backlogged work
        with no member able to step. Wakes the cheapest parked or
        draining member when routable work exists, fast-forwards the
        fleet clock to the earliest backoff expiry when everything is
        deferred, and sheds with terminal ``lost`` dispositions when
        the whole fleet is dead."""
        now = self.fleet_now()
        alive = [m for m in self.members.values() if m.alive]
        if not alive:
            while self._recovery:
                rec = self._recovery.popleft()
                meta = self._meta.get(rec["req"].uid)
                if meta is not None:
                    self._shed_request(rec["req"], meta, now,
                                       status="lost")
            while self._pending:
                req = self._pending.popleft()
                self._shed_request(req, self._meta[req.uid], now,
                                   status="lost")
            return
        blocked = [m for m in alive if m.parked or m.draining]
        routable = (bool(self._recovery)
                    or any(self._meta[r.uid].not_before <= now
                           for r in self._pending))
        if blocked and routable:
            m = min(blocked, key=self._decode_j_per_token)
            if m.parked:
                self._unpark(m, now)
            m.draining = False
            return
        nb = [self._meta[r.uid].not_before for r in self._pending
              if self._meta[r.uid].not_before > now]
        if nb:
            # deferred-only backlog: idle the fleet forward to the
            # earliest wake-up so backoffs expire on the model clock
            target = max(alive, key=lambda mm: mm.elapsed)
            gap = min(nb) - target.elapsed
            if gap > 0.0:
                target.engine._clock += gap

    def _finish(self, m: _Member, r: Result) -> None:
        """Record one retirement: provenance (the member that produced
        it must be the member it was routed to), fleet-timeline TTFT
        (engine TTFT plus scheduler queue wait, or the value pinned at
        a mid-stream failure), and SLO attainment."""
        meta = self._meta.pop(r.uid, None)
        if meta is None or meta.engine != m.name:
            raise RuntimeError(
                f"request {r.uid} finished on {m.name!r} but was routed "
                f"to {None if meta is None else meta.engine!r}")
        m.completed += 1
        wait = max(meta.t_handoff - meta.t_submit, 0.0)
        ttft_fleet = (meta.ttft_override
                      if meta.ttft_override is not None
                      else r.ttft_model_s + wait)
        dl_bound = (None if meta.sla is None
                    else self.sla[meta.sla].ttft_model_s)
        self._done[r.uid] = {
            "engine": m.name, "sla": meta.sla, "status": "ok",
            "ttft_fleet_model_s": ttft_fleet,
            "queue_wait_model_s": wait,
            "met_slo": (True if dl_bound is None
                        else ttft_fleet <= dl_bound),
            "pred_j_per_token": meta.pred_j_per_token,
            "pred_ttft_model_s": meta.pred_ttft_s,
            "bucket": meta.bucket,
            "energy_j": r.energy_j, "n_tokens": r.n_tokens,
            "retries": meta.retries, "migrations": meta.migrations,
        }

    def run_until_empty(self) -> list[Result]:
        """Serve every submitted request to a terminal disposition
        (finished, shed, or lost) across the fleet and return the
        finished `Result`s (engine telemetry intact; fleet-level
        telemetry in `report()` / `request_log`)."""
        results: list[Result] = []
        guard = None
        stuck = 0
        while (self._pending or self._recovery
               or any(m.alive and m.engine.has_work
                      for m in self.members.values())):
            out = self.step()
            results.extend(out)
            if out:
                stuck = 0
                continue
            snap = (len(self._pending), len(self._recovery),
                    len(self._done), round(self.fleet_now(), 9))
            if snap == guard:
                stuck += 1
                if stuck > 1000:
                    raise RuntimeError(
                        "fleet scheduler made no progress for 1000 "
                        "idle ticks — livelock")
            else:
                stuck = 0
                guard = snap
        now = self.fleet_now()
        for m in self.members.values():
            if m.alive and not m.parked and not m.engine.has_work:
                self._park(m, now)
        return results

    # ------------------------------------------------------------------
    # ledger / reporting
    # ------------------------------------------------------------------
    @property
    def request_log(self) -> dict[int, dict]:
        """Per-terminal-request fleet telemetry keyed by uid: status
        (``ok``/``shed``/``lost``), routed engine, fleet-timeline TTFT,
        queue wait, SLO attainment, retries/migrations, the routing
        decision's predicted costs, and the engine's energy
        attribution."""
        return dict(self._done)

    def reset_stats(self) -> None:
        """Re-zero the fleet ledger (engines' counters, members' park/
        drain/route/fault records, the request log, the straggler
        detector) after a warm-up pass. Requires a drained fleet."""
        if (self._pending or self._recovery
                or any(m.engine.has_work for m in self.members.values())):
            raise RuntimeError("reset_stats with in-flight work")
        self._done.clear()
        self.routed_to.clear()
        self._meta.clear()
        self._counters = {"migrations": 0, "replays": 0, "retries": 0}
        self._shed_counts.clear()
        self._defer_counts.clear()
        self._detector = StragglerDetector(len(self.members),
                                           self._straggler_cfg)
        for m in self.members.values():
            m.engine.reset_stats()
            m.clock0 = m.engine.model_clock_s
            m.routed = m.completed = m.parks = m.drains = 0
            m.parked_model_s = 0.0
            m.parked = m.draining = False
            m.crashed = m.evicted = False
            m.crashed_at = 0.0
            m.crashes = m.evictions = m.stalls = 0
            m.stall_factor = 1.0
            m.stall_until = 0.0

    def report(self) -> dict:
        """Fleet-level serving report.

        `fleet_energy_j` is the full ledger: every member's served
        energy (attributed + in-call idle, replayed work's lost spend
        included) plus its idle-floor energy
        (`core.energy.parked_energy_j`) over the gap between its busy
        model time and the fleet makespan — a parked or never-used
        member is charged for the whole run (a crashed one only up to
        the crash), which is what makes the single-engine baselines
        comparable. Per-SLA-class blocks carry measured fleet-TTFT
        p50/p95, attainment against the class bound, and the class's
        shed/defer/retry counts; the ``faults`` block aggregates the
        robustness counters and the fault plan's audit trail."""
        from repro.core.energy import parked_energy_j

        makespan = max((m.elapsed for m in self.members.values()),
                       default=0.0)
        engines = {}
        fleet_j = 0.0
        toks = 0
        lost_j = 0.0
        for m in self.members.values():
            rep = m.engine.report()
            busy = rep["model_s"]
            horizon = m.crashed_at if m.crashed else makespan
            gap = max(horizon - busy, 0.0)
            gap_j = parked_energy_j(gap, chip=m.engine.chip or "tpu_v5e",
                                    n_chips=m.engine.tp)
            fleet_j += rep["energy_j"] + gap_j
            toks += rep["generated_tokens"]
            lost_j += rep.get("lost_energy_j", 0.0)
            engines[m.name] = {
                "chip": m.engine.chip or "tpu_v5e",
                "tp": m.engine.tp,
                "routed": m.routed, "completed": m.completed,
                "busy_model_s": busy, "gap_idle_model_s": gap,
                "gap_idle_j": gap_j,
                "idle_power_w": m.engine.idle_power_w,
                "parked": m.parked, "parks": m.parks,
                "drains": m.drains,
                "parked_model_s": m.parked_model_s,
                "crashed": m.crashed, "crashes": m.crashes,
                "evicted": m.evicted, "evictions": m.evictions,
                "stalls": m.stalls,
                "tuning_degraded": m.engine.tuning_degraded,
                "engine": rep,
            }
        classes = {}
        names = set(self.sla) | {d["sla"] for d in self._done.values()
                                 if d["sla"] is not None}
        for cname in sorted(names):
            rows = [d for d in self._done.values() if d["sla"] == cname]
            bound = (self.sla[cname].ttft_model_s
                     if cname in self.sla else None)
            ttfts = [d["ttft_fleet_model_s"] for d in rows
                     if d["ttft_fleet_model_s"] is not None]
            classes[cname] = {
                "ttft_slo_model_s": bound,
                "requests": len(rows),
                "attainment": (sum(d["met_slo"] for d in rows) / len(rows)
                               if rows else 1.0),
                "ttft_fleet_p50_model_s": _percentile(ttfts, 50),
                "ttft_fleet_p95_model_s": _percentile(ttfts, 95),
                "shed": self._shed_counts.get(cname, 0),
                "deferred": self._defer_counts.get(cname, 0),
                "retries": sum(d.get("retries", 0) for d in rows),
                "migrations": sum(d.get("migrations", 0) for d in rows),
            }
        slo_rows = [d for d in self._done.values()
                    if d["sla"] is not None
                    and self.sla.get(d["sla"], SLAClass(d["sla"])
                                     ).ttft_model_s is not None]
        return {
            "requests": len(self._done),
            "generated_tokens": toks,
            "makespan_model_s": makespan,
            "fleet_energy_j": fleet_j,
            "fleet_j_per_token": fleet_j / toks if toks else 0.0,
            "attainment": (sum(d["met_slo"] for d in slo_rows)
                           / len(slo_rows) if slo_rows else 1.0),
            "parks": sum(m.parks for m in self.members.values()),
            "drains": sum(m.drains for m in self.members.values()),
            "route_to": self.route_to,
            "sla": classes,
            "engines": engines,
            "faults": {
                "plan": (self._fault_plan.report()
                         if self._fault_plan is not None else None),
                "crashes": sum(m.crashes for m in self.members.values()),
                "evictions": sum(m.evictions
                                 for m in self.members.values()),
                "stalls": sum(m.stalls for m in self.members.values()),
                "migrations": self._counters["migrations"],
                "replays": self._counters["replays"],
                "retries": self._counters["retries"],
                "shed": dict(self._shed_counts),
                "deferred": dict(self._defer_counts),
                "lost_energy_j": lost_j,
                "degraded_members": sorted(
                    n for n, m in self.members.items()
                    if m.engine.tuning_degraded),
            },
        }


def _marginal(chunk_est, decode_est, **kw):
    """Thin alias for `core.energy.marginal_request_cost` (imported
    lazily so the scheduler module imports without the energy stack)."""
    from repro.core.energy import marginal_request_cost

    return marginal_request_cost(chunk_est, decode_est, **kw)
