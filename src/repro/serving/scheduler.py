"""Predictor-driven SLA- and energy-aware fleet scheduler.

`FleetScheduler` owns one admission queue over N `ServingEngine`
instances — possibly on different `ChipSpec`s and tp widths — and makes
three predictor-priced decisions per tick, closing the loop the paper's
predictor exists for (price a GEMM configuration *before* running it):

1. **Routing** (`_route`): each pending request is priced on every
   active (engine, chunk-bucket) placement via the engine's cached
   `fused_step_estimate` (which runs `core.energy.fused_step_energy`
   over the decode + chunk GEMM fleets and `hwsim.collective_cost` over
   the ring traffic) folded into a per-request share by
   `core.energy.marginal_request_cost` — the same per-row/per-slot
   arithmetic the engine's attribution ledger uses. The scheduler picks
   the placement with the lowest predicted marginal fleet J/token among
   those whose predicted TTFT meets the request's SLA-class deadline,
   falling back to the fastest placement when none does.

2. **Chunk sizing** (`_chunk_policy_for`): each engine's SJF chunk
   sizing is replaced by a deadline-aware policy when SLO-classed
   requests are in its lane — the smallest chunk bucket predicted to
   land every pending deadline wins (small buckets waste no padded
   positions and interleave more decode; wide buckets cut calls when
   slack runs short). A draining engine always chunks at the widest
   bucket.

3. **Race to idle** (`_race_to_idle`): the ledger charges every fleet
   member its `ChipSpec` idle floor for the whole fleet makespan
   (`core.energy.parked_energy_j`), so shrinking the makespan — or
   finishing a lagging, expensive engine's work early and parking it —
   saves real energy. When the remaining fleet's predicted completion
   of all outstanding prefill work still meets every outstanding SLO
   deadline, the most expensive active engine is marked *draining*
   (no new routes, widest chunks) and parks at idle power once empty.

Fleet accounting: ``fleet_energy_j`` = every engine's served energy
(attributed + in-call idle shares) **plus** each engine's idle-floor
energy over the gap between its own busy time and the fleet makespan.
A single-engine baseline is the same ledger with all work forced onto
one member (``route_to=``) while the others sit parked for its whole
makespan — so the scheduler beats the best such baseline by routing to
efficient chips *and* by shrinking the makespan (parallelism cuts the
idle-floor term). `benchmarks/bench_serving.py --fleet` gates both that
comparison and SLO attainment; `tests/test_fleet_scheduler.py` holds
the conservation and routing-invariance properties.

Time base: each engine advances its own deterministic model clock
(predicted seconds of dispatched calls). The scheduler aligns them into
one fleet timeline by always stepping the busiest-backlogged engine
with the *smallest* elapsed clock and fast-forwarding an idle engine's
clock to "now" at handoff — so TTFT measured against the fleet timeline
(`ttft_fleet_model_s`) includes scheduler queue wait and is
deterministic and hardware-independent, like the engine's own model
clock.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque

from repro.serving.engine import Request, Result, ServingEngine


@dataclasses.dataclass(frozen=True)
class SLAClass:
    """A named TTFT service class.

    `ttft_model_s` is the per-request time-to-first-token bound on the
    fleet model clock (submit -> first token, queue wait included);
    None declares a best-effort class with no deadline. The bench's
    attainment gate reads the fraction of a class's requests that met
    the bound."""

    name: str
    ttft_model_s: float | None = None


@dataclasses.dataclass
class _ReqMeta:
    """Scheduler-side bookkeeping for one in-flight request."""

    sla: str | None
    t_submit: float             # fleet clock at scheduler submission
    t_handoff: float = 0.0      # fleet clock at engine handoff
    engine: str | None = None   # member the request was routed to
    bucket: int = 0             # chunk bucket chosen at routing time
    pred_j_per_token: float = 0.0
    pred_ttft_s: float = 0.0


@dataclasses.dataclass
class _Member:
    """One fleet engine plus the scheduler's view of it."""

    name: str
    engine: ServingEngine
    clock0: float = 0.0         # engine clock at scheduler epoch
    routed: int = 0
    completed: int = 0
    parked: bool = False
    draining: bool = False
    parks: int = 0
    drains: int = 0
    parked_model_s: float = 0.0  # closed park intervals (fleet clock)
    parked_from: float = 0.0     # open park interval start

    @property
    def elapsed(self) -> float:
        """Fleet-timeline position of this engine (clock - epoch)."""
        return self.engine.model_clock_s - self.clock0

    @property
    def has_room(self) -> bool:
        """True while the engine can absorb another admission without
        queueing past its lane (the scheduler's late-binding
        backpressure)."""
        eng = self.engine
        return (len(eng.queue) + eng.lane_view["in_flight"]
                < eng.lane_width)


def _pow2ceil(n: int) -> int:
    """Smallest power of two >= max(n, 1)."""
    w = 1
    while w < n:
        w *= 2
    return w


def _percentile(xs: list[float], q: float) -> float:
    """Nearest-rank percentile of a list (0 for an empty one)."""
    if not xs:
        return 0.0
    xs = sorted(xs)
    i = min(int(math.ceil(q / 100.0 * len(xs))) - 1, len(xs) - 1)
    return xs[max(i, 0)]


class FleetScheduler:
    """One admission queue over a fleet of `ServingEngine`s (see the
    module docstring for the decision loop; `docs/serving.md` for the
    guide)."""

    def __init__(self, engines: dict[str, ServingEngine], *,
                 sla: dict[str, SLAClass] | None = None,
                 default_sla: str | None = None,
                 route_to: str | None = None,
                 race_to_idle: bool = True,
                 pretune: bool = False,
                 tune_objective: str = "energy",
                 tune_rank_mode: str = "auto"):
        """`engines` maps member names to steppable engines (continuous
        chunked admission on the dense KV layout — `serve_step`'s
        contract). `sla` maps class names to `SLAClass` bounds;
        `default_sla` is applied to requests submitted without one.

        `route_to` forces every request onto one named member while the
        others sit parked — the single-engine baseline the fleet bench
        compares against (same ledger, so the comparison is
        apples-to-apples). `race_to_idle=False` disables the
        drain-and-park decision (routing and chunk sizing stay on).

        `pretune=True` warms the whole fleet's GEMM shapes up front via
        `ops.warm_fleet_gemm_cache` — engines sharing a chip are
        unioned into one batched tuning pass, and each engine's
        `pretuned` map (which its energy pricing consults) is filled
        from its chip's results."""
        if not engines:
            raise ValueError("FleetScheduler needs at least one engine")
        self.members: dict[str, _Member] = {}
        for name, eng in engines.items():
            if (eng.mode == "wave" or eng.admission != "chunked"
                    or eng.kv_layout != "dense"
                    or not eng._continuous_supported()):
                raise ValueError(
                    f"engine {name!r} is not steppable (fleet scheduling "
                    f"requires continuous chunked admission on the dense "
                    f"KV layout)")
            self.members[name] = _Member(name=name, engine=eng,
                                         clock0=eng.model_clock_s)
            eng.chunk_policy = self._chunk_policy_for(name)
        self.sla = dict(sla or {})
        for cname, cls in self.sla.items():
            if cname != cls.name:
                raise ValueError(f"SLA key {cname!r} != class {cls.name!r}")
        if default_sla is not None and default_sla not in self.sla:
            raise ValueError(f"default_sla {default_sla!r} not in sla map")
        self.default_sla = default_sla
        if route_to is not None and route_to not in self.members:
            raise ValueError(f"route_to {route_to!r} not in fleet")
        self.route_to = route_to
        self.race_to_idle = race_to_idle
        self._pending: deque[Request] = deque()
        self._meta: dict[int, _ReqMeta] = {}
        self._done: dict[int, dict] = {}
        self.routed_to: dict[int, str] = {}
        if pretune:
            self._pretune_fleet(tune_objective, tune_rank_mode)

    # ------------------------------------------------------------------
    # fleet pre-tuning
    # ------------------------------------------------------------------
    def _pretune_fleet(self, objective: str, rank_mode: str) -> None:
        """Warm every member's GEMM fleet in one batched pass per chip
        (`ops.warm_fleet_gemm_cache`) and install the per-engine config
        maps, invalidating any step-energy estimates priced before."""
        from repro.kernels import ops

        names = list(self.members)
        specs = []
        for name in names:
            e = self.members[name].engine
            specs.append({
                "cfg": e.cfg, "chip": e.chip,
                "dtype": e.cfg.activation_dtype,
                "max_batch": e.max_batch, "max_len": e.max_len,
                "include_slot_prefill": True,
                "chunk_tokens": e.chunk_tokens,
                "lane_width": e.lane_width,
                "tp": e.tp, "grain": e.ssm_grain})
        tuned = ops.warm_fleet_gemm_cache(specs, objective=objective,
                                          rank_mode=rank_mode)
        for name, configs in zip(names, tuned):
            eng = self.members[name].engine
            if configs:
                eng.pretuned = configs
                eng._step_energy_cache.clear()

    # ------------------------------------------------------------------
    # clocks
    # ------------------------------------------------------------------
    def fleet_now(self) -> float:
        """Current fleet-timeline position: the smallest elapsed clock
        among busy members (the next engine to step), or the largest
        elapsed anywhere when the fleet is idle."""
        busy = [m.elapsed for m in self.members.values()
                if m.engine.has_work and not m.parked]
        if busy:
            return min(busy)
        return max((m.elapsed for m in self.members.values()), default=0.0)

    def _sync_clock(self, m: _Member, now: float) -> None:
        """Fast-forward an idle-lagging member's clock to `now`: its
        model clock only advances while dispatching, so an engine that
        sat idle re-enters the fleet timeline at the present, not in
        the past (handoff wait must never read negative)."""
        gap = now - m.elapsed
        if gap > 0.0:
            m.engine._clock += gap

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def submit(self, req: Request, sla: str | None = None) -> None:
        """Queue a request on the fleet. `sla` (or `req.sla`, or the
        scheduler default) names its `SLAClass`; None serves best
        effort. Routing happens lazily inside `run_until_empty` /
        `step`, so a request's placement sees the fleet state at
        admission time, not submission time."""
        cname = sla or req.sla or self.default_sla
        if cname is not None and cname not in self.sla:
            raise ValueError(f"unknown SLA class {cname!r}")
        req.sla = cname
        self._meta[req.uid] = _ReqMeta(sla=cname, t_submit=self.fleet_now())
        self._pending.append(req)

    def _deadline(self, meta: _ReqMeta) -> float | None:
        """Absolute fleet-clock TTFT deadline of a request (None when
        best-effort)."""
        if meta.sla is None:
            return None
        bound = self.sla[meta.sla].ttft_model_s
        return None if bound is None else meta.t_submit + bound

    # ------------------------------------------------------------------
    # decision (a): predictor-priced routing
    # ------------------------------------------------------------------
    def _place_cost(self, m: _Member, req: Request, bucket: int,
                    now: float) -> tuple[float, float]:
        """(predicted marginal J/token, predicted fleet TTFT seconds) of
        placing `req` on member `m` with chunk bucket `bucket`.

        The chunk side prices the *fused* step the engine will actually
        dispatch (decode fleet + one `width x bucket` chunk call) at the
        width the lane would grow to; the per-request share and the
        decode-step share come from `core.energy.marginal_request_cost`.
        TTFT is first-order: the engine's unfinished prefill backlog
        plus this prompt's own chunk calls, at the fused step cadence,
        starting from the later of `now` and the engine's own clock."""
        eng = m.engine
        view = eng.lane_view
        width = _pow2ceil(min(view["in_flight"] + 1, eng.lane_width))
        fused = eng.fused_step_estimate(width, bucket)
        n_calls = max(int(math.ceil(len(req.prompt) / bucket)), 1)
        budget = eng._budget(req)
        cost = _marginal(fused, eng.decode_step_estimate(),
                         chunk_calls=n_calls, chunk_width=width,
                         decode_steps=budget, decode_batch=eng.max_batch,
                         tokens=budget)
        step_s = fused.step_s if fused is not None else 0.0
        backlog_calls = eng.backlog_tokens / max(width * bucket, 1)
        start = max(m.elapsed, now)
        ttft = (start - now) + (n_calls + backlog_calls) * step_s
        return cost.j_per_token, ttft

    def _buckets(self, eng: ServingEngine) -> tuple[int, ...]:
        """The engine's chunk-bucket ladder (`ops.chunk_buckets`)."""
        from repro.kernels import ops

        return ops.chunk_buckets(eng.max_len, eng.chunk_tokens,
                                 eng.ssm_grain)

    def _candidates(self, include_parked: bool) -> list[_Member]:
        """Members routing may currently target, cheapest-first order
        left to the cost search."""
        return [m for m in self.members.values()
                if (include_parked or not m.parked) and not m.draining
                and m.has_room]

    def _route(self) -> None:
        """Place pending requests FIFO onto (engine, chunk-bucket)
        placements: lowest predicted marginal fleet J/token among the
        SLO-feasible candidates; the fastest predicted TTFT when no
        candidate is feasible (a missed-deadline request still gets the
        least-late engine). Parked members are woken only when no
        active member can make the deadline (or has room). Stops at the
        first request nothing can absorb — later requests wait so FIFO
        fairness holds within the queue."""
        while self._pending:
            req = self._pending[0]
            meta = self._meta[req.uid]
            now = self.fleet_now()
            target = None
            bucket = 0
            if self.route_to is not None:
                target = self.members[self.route_to]
                bucket = self._buckets(target.engine)[-1]
                meta.pred_j_per_token, meta.pred_ttft_s = self._place_cost(
                    target, req, bucket, now)
            else:
                deadline = self._deadline(meta)
                slack = (None if deadline is None
                         else max(deadline - now, 0.0))
                for widen in (False, True):
                    scored = [
                        (m, b, *self._place_cost(m, req, b, now))
                        for m in self._candidates(include_parked=widen)
                        for b in self._buckets(m.engine)]
                    if not scored:
                        continue
                    feasible = [c for c in scored
                                if slack is None or c[3] <= slack]
                    if feasible:
                        # cheapest predicted marginal J/token among the
                        # placements that make the deadline
                        pick = min(feasible, key=lambda c: (c[2], c[3]))
                    elif not widen:
                        continue       # try again with parked members
                    else:
                        # nothing makes the deadline even woken: take
                        # the least-late placement rather than starving
                        pick = min(scored, key=lambda c: (c[3], c[2]))
                    target, bucket = pick[0], pick[1]
                    meta.pred_j_per_token = pick[2]
                    meta.pred_ttft_s = pick[3]
                    break
                if target is None:
                    return             # every lane is full: wait
            self._pending.popleft()
            self._handoff(target, req, meta, bucket)

    def _handoff(self, m: _Member, req: Request, meta: _ReqMeta,
                 bucket: int) -> None:
        """Commit a routing decision: wake a parked member, align its
        clock with the fleet timeline, and enqueue the request on the
        engine."""
        now = self.fleet_now()
        if m.parked:
            self._unpark(m, now)
        self._sync_clock(m, now)
        meta.engine = m.name
        meta.bucket = int(bucket)
        meta.t_handoff = m.elapsed
        self.routed_to[req.uid] = m.name
        m.routed += 1
        m.engine.submit(req)

    # ------------------------------------------------------------------
    # decision (b): SLO-aware chunk sizing
    # ------------------------------------------------------------------
    def _chunk_policy_for(self, name: str):
        """Build the `ServingEngine.chunk_policy` hook for one member.

        Draining members chunk at the widest bucket (finish prefill in
        the fewest steps and get to idle). Otherwise, when any pending
        lane row carries an SLO deadline, pick the smallest chunk
        bucket whose predicted cadence lands *every* pending deadline —
        small buckets waste no padded positions (J/token) and
        interleave more decode steps; slack that has burned down forces
        wider chunks. Lanes holding only best-effort rows return None,
        keeping the engine's SJF default."""
        def policy(eng: ServingEngine,
                   pending: list[tuple[Request, int]]) -> int | None:
            """Chunk-bucket override for this member's pending lane
            (None keeps the engine's SJF default)."""
            m = self.members[name]
            ladder = self._buckets(eng)
            if m.draining:
                return ladder[-1]
            now = m.elapsed
            deadlines = []
            for req, rem in pending:
                meta = self._meta.get(req.uid)
                if meta is None:
                    continue
                dl = self._deadline(meta)
                if dl is not None:
                    deadlines.append((dl, rem))
            if not deadlines:
                return None
            width = _pow2ceil(len(pending))
            for bucket in ladder:
                est = eng.fused_step_estimate(width, bucket)
                step_s = est.step_s if est is not None else 0.0
                if all(now + math.ceil(rem / bucket) * step_s <= dl
                       for dl, rem in deadlines):
                    return bucket
            return ladder[-1]
        return policy

    # ------------------------------------------------------------------
    # decision (c): race to idle
    # ------------------------------------------------------------------
    def _decode_j_per_token(self, m: _Member) -> float:
        """Marginal decode J/token of a member (its full-batch decode
        step's energy split per slot) — the expense ranking the drain
        decision uses."""
        est = m.engine.decode_step_estimate()
        if est is None:
            return 0.0
        return est.energy_j / max(m.engine.max_batch, 1)

    def _outstanding_deadlines(self) -> list[tuple[float, float]]:
        """(deadline, remaining prompt tokens) of every request that has
        not yet produced its first token, fleet-wide — the load the
        remaining fleet must absorb for a drain/park to be safe."""
        out = []
        for req in self._pending:
            meta = self._meta[req.uid]
            dl = self._deadline(meta)
            if dl is not None:
                out.append((dl, float(len(req.prompt))))
        return out

    def _fleet_meets_slo_without(self, excl: _Member) -> bool:
        """Would the remaining active members still land every
        outstanding SLO deadline if `excl` stopped taking work?

        First-order feasibility: the other members' aggregate
        widest-chunk prefill throughput must finish the fleet's whole
        unstarted prefill backlog (pending queue + every member's lane
        backlog) before the tightest outstanding deadline."""
        others = [m for m in self.members.values()
                  if m is not excl and not m.parked and not m.draining]
        if not others:
            return False
        deadlines = self._outstanding_deadlines()
        if not deadlines:
            return True
        rate = 0.0
        for m in others:
            eng = m.engine
            bucket = self._buckets(eng)[-1]
            width = _pow2ceil(eng.lane_width)
            est = eng.fused_step_estimate(width, bucket)
            if est is not None and est.step_s > 0.0:
                rate += width * bucket / est.step_s
        if rate <= 0.0:
            return False
        backlog = (sum(tok for _, tok in deadlines)
                   + sum(m.engine.backlog_tokens
                         for m in self.members.values() if m is not excl))
        t_done = self.fleet_now() + backlog / rate
        return t_done <= min(dl for dl, _ in deadlines)

    def _park(self, m: _Member, now: float) -> None:
        """Park an empty member at its chip's idle floor."""
        m.parked = True
        m.parks += 1
        m.parked_from = now

    def _unpark(self, m: _Member, now: float) -> None:
        """Wake a parked member (closing its park interval) so routing
        can hand it work again."""
        m.parked_model_s += max(now - m.parked_from, 0.0)
        m.parked = False
        m.draining = False

    def _race_to_idle(self) -> None:
        """Drain-and-park pass, run once per scheduler tick.

        Parks any member that has fully drained (idle engines burn the
        same idle floor either way — parking records the decision and
        removes the member from routing). Separately, while more than
        one member is active and the remaining fleet is predicted to
        absorb all outstanding SLO load, the most expensive active
        member (marginal decode J/token) is marked draining: no new
        routes, widest chunks, park on empty."""
        now = self.fleet_now()
        for m in self.members.values():
            if not m.parked and not m.engine.has_work:
                if m.draining or not self._pending:
                    self._park(m, now)
        if not self.race_to_idle or self.route_to is not None:
            return
        active = [m for m in self.members.values()
                  if not m.parked and not m.draining]
        if len(active) < 2:
            return
        costly = max(active, key=self._decode_j_per_token)
        if (self._decode_j_per_token(costly) > 0.0
                and self._outstanding_deadlines()
                and self._fleet_meets_slo_without(costly)):
            costly.draining = True
            costly.drains += 1

    # ------------------------------------------------------------------
    # serving loop
    # ------------------------------------------------------------------
    def step(self) -> list[Result]:
        """One scheduler tick: route pending requests, advance the
        busy member with the smallest elapsed clock by one fused engine
        step, fold its finished requests into the fleet ledger, then
        run the race-to-idle pass. Returns the finished `Result`s."""
        self._route()
        busy = [m for m in self.members.values() if m.engine.has_work]
        if not busy:
            return []
        m = min(busy, key=lambda mm: mm.elapsed)
        if m.parked:
            self._unpark(m, self.fleet_now())
        out = m.engine.serve_step()
        for r in out:
            self._finish(m, r)
        self._race_to_idle()
        return out

    def _finish(self, m: _Member, r: Result) -> None:
        """Record one retirement: provenance (the member that produced
        it must be the member it was routed to), fleet-timeline TTFT
        (engine TTFT plus scheduler queue wait), and SLO attainment."""
        meta = self._meta.pop(r.uid, None)
        if meta is None or meta.engine != m.name:
            raise RuntimeError(
                f"request {r.uid} finished on {m.name!r} but was routed "
                f"to {None if meta is None else meta.engine!r}")
        m.completed += 1
        wait = max(meta.t_handoff - meta.t_submit, 0.0)
        ttft_fleet = r.ttft_model_s + wait
        dl_bound = (None if meta.sla is None
                    else self.sla[meta.sla].ttft_model_s)
        self._done[r.uid] = {
            "engine": m.name, "sla": meta.sla,
            "ttft_fleet_model_s": ttft_fleet,
            "queue_wait_model_s": wait,
            "met_slo": (True if dl_bound is None
                        else ttft_fleet <= dl_bound),
            "pred_j_per_token": meta.pred_j_per_token,
            "pred_ttft_model_s": meta.pred_ttft_s,
            "bucket": meta.bucket,
            "energy_j": r.energy_j, "n_tokens": r.n_tokens,
        }

    def run_until_empty(self) -> list[Result]:
        """Serve every submitted request to completion across the fleet
        and return their `Result`s (engine telemetry intact; fleet-level
        telemetry in `report()` / `request_log`)."""
        results: list[Result] = []
        while (self._pending
               or any(m.engine.has_work for m in self.members.values())):
            out = self.step()
            results.extend(out)
            if not out and not any(m.engine.has_work
                                   for m in self.members.values()):
                # pending work but nothing absorbed it and nothing is
                # running: wake the whole fleet so routing can't stall
                for m in self.members.values():
                    if m.parked:
                        self._unpark(m, self.fleet_now())
        now = self.fleet_now()
        for m in self.members.values():
            if not m.parked and not m.engine.has_work:
                self._park(m, now)
        return results

    # ------------------------------------------------------------------
    # ledger / reporting
    # ------------------------------------------------------------------
    @property
    def request_log(self) -> dict[int, dict]:
        """Per-finished-request fleet telemetry keyed by uid: routed
        engine, fleet-timeline TTFT, queue wait, SLO attainment, the
        routing decision's predicted costs, and the engine's energy
        attribution."""
        return dict(self._done)

    def reset_stats(self) -> None:
        """Re-zero the fleet ledger (engines' counters, members' park/
        drain/route records, the request log) after a warm-up pass.
        Requires a drained fleet."""
        if self._pending or any(m.engine.has_work
                                for m in self.members.values()):
            raise RuntimeError("reset_stats with in-flight work")
        self._done.clear()
        self.routed_to.clear()
        self._meta.clear()
        for m in self.members.values():
            m.engine.reset_stats()
            m.clock0 = m.engine.model_clock_s
            m.routed = m.completed = m.parks = m.drains = 0
            m.parked_model_s = 0.0
            m.parked = m.draining = False

    def report(self) -> dict:
        """Fleet-level serving report.

        `fleet_energy_j` is the full ledger: every member's served
        energy (attributed + in-call idle) plus its idle-floor energy
        (`core.energy.parked_energy_j`) over the gap between its busy
        model time and the fleet makespan — a parked or never-used
        member is charged for the whole run, which is what makes the
        single-engine baselines comparable. Per-SLA-class blocks carry
        measured fleet-TTFT p50/p95 and attainment against the class
        bound."""
        from repro.core.energy import parked_energy_j

        makespan = max((m.elapsed for m in self.members.values()),
                       default=0.0)
        engines = {}
        fleet_j = 0.0
        toks = 0
        for m in self.members.values():
            rep = m.engine.report()
            busy = rep["model_s"]
            gap = max(makespan - busy, 0.0)
            gap_j = parked_energy_j(gap, chip=m.engine.chip or "tpu_v5e",
                                    n_chips=m.engine.tp)
            fleet_j += rep["energy_j"] + gap_j
            toks += rep["generated_tokens"]
            engines[m.name] = {
                "chip": m.engine.chip or "tpu_v5e",
                "tp": m.engine.tp,
                "routed": m.routed, "completed": m.completed,
                "busy_model_s": busy, "gap_idle_model_s": gap,
                "gap_idle_j": gap_j,
                "idle_power_w": m.engine.idle_power_w,
                "parked": m.parked, "parks": m.parks,
                "drains": m.drains,
                "parked_model_s": m.parked_model_s,
                "engine": rep,
            }
        classes = {}
        names = set(self.sla) | {d["sla"] for d in self._done.values()
                                 if d["sla"] is not None}
        for cname in sorted(names):
            rows = [d for d in self._done.values() if d["sla"] == cname]
            bound = (self.sla[cname].ttft_model_s
                     if cname in self.sla else None)
            ttfts = [d["ttft_fleet_model_s"] for d in rows]
            classes[cname] = {
                "ttft_slo_model_s": bound,
                "requests": len(rows),
                "attainment": (sum(d["met_slo"] for d in rows) / len(rows)
                               if rows else 1.0),
                "ttft_fleet_p50_model_s": _percentile(ttfts, 50),
                "ttft_fleet_p95_model_s": _percentile(ttfts, 95),
            }
        slo_rows = [d for d in self._done.values()
                    if d["sla"] is not None
                    and self.sla.get(d["sla"], SLAClass(d["sla"])
                                     ).ttft_model_s is not None]
        return {
            "requests": len(self._done),
            "generated_tokens": toks,
            "makespan_model_s": makespan,
            "fleet_energy_j": fleet_j,
            "fleet_j_per_token": fleet_j / toks if toks else 0.0,
            "attainment": (sum(d["met_slo"] for d in slo_rows)
                           / len(slo_rows) if slo_rows else 1.0),
            "parks": sum(m.parks for m in self.members.values()),
            "drains": sum(m.drains for m in self.members.values()),
            "route_to": self.route_to,
            "sla": classes,
            "engines": engines,
        }


def _marginal(chunk_est, decode_est, **kw):
    """Thin alias for `core.energy.marginal_request_cost` (imported
    lazily so the scheduler module imports without the energy stack)."""
    from repro.core.energy import marginal_request_cost

    return marginal_request_cost(chunk_est, decode_est, **kw)
