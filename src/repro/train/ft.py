"""Fault tolerance: straggler detection, preemption handling, restart policy.

At 1000+ nodes, three failure classes matter:
  1. hard node loss  -> checkpoint/restart (CheckpointManager) onto the
     surviving topology (launch/elastic.py re-meshes);
  2. stragglers      -> per-host step-time heartbeats; a host whose EWMA
     exceeds `threshold` x the fleet median for `patience` consecutive
     steps is flagged for eviction (the scheduler then restarts without it);
  3. preemption      -> SIGTERM triggers a final blocking save.
"""

from __future__ import annotations

import dataclasses
import signal
import time


@dataclasses.dataclass
class StragglerConfig:
    threshold: float = 1.5     # x fleet median
    patience: int = 5          # consecutive slow steps before flagging
    ewma: float = 0.2


class StragglerDetector:
    """Tracks per-host step-time EWMAs; flags persistent outliers.

    The serving fleet reuses this over per-member `serve_step` model
    times (`serving/scheduler.py`): a stalled member's EWMA crosses
    `threshold` x the fleet median and, after `patience` consecutive
    slow observations, the scheduler evicts it and migrates its
    in-flight requests. A recovered host re-enters via `reset`."""

    def __init__(self, n_hosts: int, cfg: StragglerConfig | None = None):
        self.cfg = cfg or StragglerConfig()
        self.n_hosts = n_hosts
        self._ewma = [None] * n_hosts
        self._slow_streak = [0] * n_hosts

    def record(self, host: int, step_time_s: float) -> None:
        prev = self._ewma[host]
        a = self.cfg.ewma
        self._ewma[host] = (step_time_s if prev is None
                            else (1 - a) * prev + a * step_time_s)

    def reset(self, host: int) -> None:
        """Forget a host's history — a recovered (or replaced) straggler
        starts a fresh EWMA and a zero streak, so a past stall cannot
        re-flag it the moment it rejoins."""
        self._ewma[host] = None
        self._slow_streak[host] = 0

    def update_flags(self) -> list[int]:
        """Call once per step after all records; returns flagged hosts.

        The reference is the *true* median of known EWMAs (central pair
        averaged for even counts): taking the upper-median element made
        the slowest of two hosts its own reference, so a 2-host fleet
        could never flag its straggler. A single-host fleet never flags
        (no peer to compare against)."""
        known = [e for e in self._ewma if e is not None]
        if len(known) < max(2, self.n_hosts // 2):
            return []
        ks = sorted(known)
        n = len(ks)
        med = ks[n // 2] if n % 2 else 0.5 * (ks[n // 2 - 1] + ks[n // 2])
        flagged = []
        for h in range(self.n_hosts):
            e = self._ewma[h]
            if e is not None and e > self.cfg.threshold * med:
                self._slow_streak[h] += 1
            else:
                self._slow_streak[h] = 0
            if self._slow_streak[h] >= self.cfg.patience:
                flagged.append(h)
        return flagged


class PreemptionHandler:
    """SIGTERM/SIGINT -> set flag; the loop saves and exits cleanly."""

    def __init__(self, install: bool = True):
        self.preempted = False
        self._orig = {}
        if install:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._orig[sig] = signal.signal(sig, self._handler)
                except ValueError:  # non-main thread (tests)
                    pass

    def _handler(self, signum, frame):
        self.preempted = True

    def restore(self):
        for sig, h in self._orig.items():
            signal.signal(sig, h)


@dataclasses.dataclass
class StepTimer:
    """Wall-clock step timing with warmup discard and simple stats."""

    warmup: int = 2
    times: list = dataclasses.field(default_factory=list)
    _t0: float = 0.0
    _count: int = 0

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> float:
        dt = time.perf_counter() - self._t0
        self._count += 1
        if self._count > self.warmup:
            self.times.append(dt)
        return dt

    @property
    def mean(self) -> float:
        return sum(self.times) / len(self.times) if self.times else 0.0
