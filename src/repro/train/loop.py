"""The training loop: step fn + data + checkpoints + fault tolerance.

Single-host-runnable (this container) but written for multi-host: all
host-side coordination is factored through host_id/n_hosts, and every
restart path (preemption, crash, elastic re-mesh) resumes bit-exact from
(checkpoint, data pipeline state, rng).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataLoader
from repro.train.ft import PreemptionHandler, StepTimer, StragglerDetector


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 100
    log_every: int = 10
    keep_ckpts: int = 3


def run_train_loop(
    *,
    train_step: Callable,
    state: dict,
    loader: DataLoader,
    ckpt: CheckpointManager,
    loop_cfg: LoopConfig,
    start_step: int = 0,
    host_id: int = 0,
    n_hosts: int = 1,
    log_fn: Callable[[str], None] = print,
    install_signal_handlers: bool = True,
) -> tuple[dict, dict]:
    """Returns (final_state, summary)."""
    timer = StepTimer()
    stragglers = StragglerDetector(n_hosts)
    preempt = PreemptionHandler(install=install_signal_handlers)
    losses = []
    step = start_step
    flagged_hosts: list[int] = []

    while step < loop_cfg.total_steps:
        batch = loader.next()
        timer.start()
        state, metrics = train_step(state, batch)
        # block on the loss so step time includes device work
        loss = float(jax.device_get(metrics["loss"]))
        dt = timer.stop()
        losses.append(loss)
        stragglers.record(host_id, dt)
        flagged_hosts = stragglers.update_flags()
        step += 1

        if step % loop_cfg.log_every == 0:
            log_fn(f"step {step:6d} loss {loss:.4f} "
                   f"({dt * 1e3:.0f} ms/step)"
                   + (f" STRAGGLERS={flagged_hosts}" if flagged_hosts else ""))
        if step % loop_cfg.ckpt_every == 0:
            ckpt.save(step, state, data_state=loader.checkpoint())
        if preempt.preempted:
            log_fn(f"preempted at step {step}; saving final checkpoint")
            ckpt.save(step, state, data_state=loader.checkpoint(),
                      blocking=True)
            break

    ckpt.wait()
    summary = {
        "final_step": step,
        "final_loss": losses[-1] if losses else float("nan"),
        "mean_step_time_s": timer.mean,
        "loss_curve": np.array(losses),
        "stragglers": flagged_hosts,
        "preempted": preempt.preempted,
    }
    preempt.restore()
    return state, summary


def resume_or_init(
    *,
    ckpt: CheckpointManager,
    init_fn: Callable[[], dict],
    loader: DataLoader,
    shardings=None,
) -> tuple[dict, int]:
    """Restart-safe state construction: restore the latest checkpoint if one
    exists (placing arrays on the current mesh), else initialize fresh."""
    latest = ckpt.latest_step()
    if latest is None:
        return init_fn(), 0
    state, data_state = ckpt.restore(latest, shardings=shardings)
    if data_state is not None:
        loader.restore(data_state)
    return state, latest
