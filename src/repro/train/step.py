"""pjit train/serve step builders.

`make_train_step(model, cfg, opt_cfg)` returns a pure (state, batch) ->
(state, metrics) function with donated state, microbatch gradient
accumulation (scan), and bf16 gradient all-reduce (params are bf16, so SPMD
reduces cotangents in bf16 — half the DP wire bytes of fp32).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig, apply_updates, init_opt_state

State = dict[str, Any]


def init_train_state(key, model, cfg: ModelConfig) -> State:
    params = model.init(key, cfg)
    return {
        "params": params,
        "opt": init_opt_state(params),
        "rng": jax.random.key_data(jax.random.key(0)),
    }


def make_train_step(model, cfg: ModelConfig, opt_cfg: AdamWConfig,
                    microbatches: int = 1) -> Callable:
    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch, cfg)
        return loss, metrics

    def train_step(state: State, batch: dict) -> tuple[State, dict]:
        params = state["params"]
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            # gradient accumulation over leading micro-splits
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches,
                                 *x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), m

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss), ms = jax.lax.scan(acc_body, (g0, 0.0), micro)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            metrics = jax.tree.map(lambda x: x[-1], ms)

        new_params, new_opt, opt_metrics = apply_updates(
            params, grads, state["opt"], opt_cfg)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        new_state = {"params": new_params, "opt": new_opt,
                     "rng": state["rng"]}
        return new_state, metrics

    return train_step


def make_eval_step(model, cfg: ModelConfig) -> Callable:
    def eval_step(params, batch):
        _, metrics = model.loss(params, batch, cfg)
        return metrics

    return eval_step


def warm_train_gemms(cfg: ModelConfig, batch_size: int, seq_len: int, *,
                     objective: str = "runtime",
                     chip: str | None = None) -> dict:
    """Pre-tune the GEMM fleet a train step will trace.

    Enumerates the forward shapes for batch_size * seq_len token rows and
    batch-tunes them through one `ops.warm_gemm_cache` call so the first
    `train_step` trace pays no per-shape autotuning. Only forward shapes
    are warmed: backward-pass GEMMs are lowered by autodiff's
    dot_general transpose rules and never consult the tuner. Returns
    {shape: BlockConfig} for the fleet ({} if no tuner is available —
    traces then use the default config).
    """
    from repro.kernels import ops
    from repro.models.config import gemm_shapes

    fleet = gemm_shapes(cfg, batch_size * seq_len)
    return ops.warm_gemm_cache(fleet, dtype=cfg.activation_dtype,
                               objective=objective, chip=chip)


def make_serve_steps(model, cfg: ModelConfig):
    """(prefill_fn, decode_fn) suitable for jit/pjit."""

    def prefill(params, batch):
        return model.prefill(params, batch, cfg)

    def decode(params, token, state):
        return model.decode_step(params, token, state, cfg)

    return prefill, decode
