"""Regenerate the golden artifacts under tests/fixtures/.

Run from the repo root after an *intentional* schema or descent change:

    PYTHONPATH=src python tests/gen_golden_fixtures.py

Each model family gets a tiny committed predictor artifact
(``golden_<family>.npz``, the full versioned `.npz` + JSON-metadata format)
plus one shared ``golden_expected.npz`` holding the frozen input feature
block and the expected numpy / compiled-scorer predictions. Ridge has no
`PerfPredictor` model name, so it ships as a raw estimator state
(``golden_ridge_state.npz``) with its own expected outputs.

`tests/test_golden_artifacts.py` loads these and fails CI whenever a
schema bump, descent rewrite, or serialization change silently shifts
predictions — regeneration (and a review of the diff) is the explicit
acknowledgement that outputs were supposed to move.
"""

import os

import numpy as np

FIXTURE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "fixtures")
GOLDEN_CHIP = "tpu_v5e"
GOLDEN_FAMILIES = ("rf", "gbdt", "linreg", "stacking")
N_ROWS = 48


def _tiny_model(name: str):
    """Drastically shrunken Table VI models so the committed artifacts
    stay a few KB each."""
    from repro.core.mlperf import (
        GradientBoostedTreesRegressor,
        LinearRegression,
        RandomForestRegressor,
        StackingRegressor,
    )

    if name == "rf":
        return RandomForestRegressor(n_estimators=4, max_depth=4,
                                     random_state=0)
    if name == "gbdt":
        return GradientBoostedTreesRegressor(n_estimators=8, max_depth=3,
                                             random_state=0)
    if name == "linreg":
        return LinearRegression()
    if name == "stacking":
        return StackingRegressor(
            [RandomForestRegressor(n_estimators=3, max_depth=3,
                                   random_state=0),
             LinearRegression()],
            n_folds=2,
        )
    raise ValueError(name)


def generate() -> dict[str, str]:
    from repro.core.predictor import PerfPredictor
    from repro.core.profiler import collect_dataset

    os.makedirs(FIXTURE_DIR, exist_ok=True)
    table = collect_dataset(n_configs=200, seed=0, chip=GOLDEN_CHIP)
    written = {}
    expected: dict[str, np.ndarray] = {}
    X_block = None
    for family in GOLDEN_FAMILIES:
        pred = PerfPredictor(model=family, residual=True, fast=True,
                             chip=GOLDEN_CHIP, random_state=0)
        pred.model = _tiny_model(family)
        pred.fit(table)
        if X_block is None:
            X_block = np.stack(
                [table[k][:N_ROWS] for k in pred.feature_names], axis=1)
            expected["X"] = X_block
            expected["feature_names"] = np.array(pred.feature_names)
            expected["target_names"] = np.array(pred.target_names)
        path = os.path.join(FIXTURE_DIR, f"golden_{family}.npz")
        pred.save(path)
        written[family] = path
        sub = {k: table[k][:N_ROWS] for k in table}
        expected[f"{family}/predict"] = pred.predict_matrix(sub)
        expected[f"{family}/jit_x64"] = np.asarray(
            pred.jax_predictor(x64=True)(X_block))

    # ridge: raw estimator state (no PerfPredictor model name)
    from repro.core.mlperf import Ridge

    rng = np.random.default_rng(0)
    Xr = rng.normal(size=(300, 8))
    yr = np.stack([Xr @ rng.normal(size=8) + 1.0,
                   Xr @ rng.normal(size=8) - 2.0], axis=1)
    ridge = Ridge(alpha=0.5).fit(Xr, yr)
    ridge_path = os.path.join(FIXTURE_DIR, "golden_ridge_state.npz")
    with open(ridge_path, "wb") as f:
        np.savez_compressed(f, **ridge.to_state())
    written["ridge"] = ridge_path
    expected["ridge/X"] = Xr[:N_ROWS]
    expected["ridge/predict"] = ridge.predict(Xr[:N_ROWS])

    exp_path = os.path.join(FIXTURE_DIR, "golden_expected.npz")
    with open(exp_path, "wb") as f:
        np.savez_compressed(f, **expected)
    written["expected"] = exp_path
    return written


if __name__ == "__main__":
    for name, path in generate().items():
        print(f"{name}: {path} ({os.path.getsize(path)} bytes)")
