"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and finiteness; plus prefill/decode consistency
for every family (decode logits must match a full forward at that position).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, input_specs, list_archs, supported_cells
from repro.data.pipeline import smoke_batch
from repro.models.registry import get_model

ARCHS = list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg, batch = smoke_batch(arch, "train_4k")
    model = get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    loss, metrics = jax.jit(
        lambda p, b: model.loss(p, b, cfg))(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert float(loss) > 0
    assert np.isfinite(float(metrics["accuracy"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_grad_step_smoke(arch):
    cfg, batch = smoke_batch(arch, "train_4k")
    model = get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    grads = jax.jit(jax.grad(
        lambda p, b: model.loss(p, b, cfg)[0]))(params, batch)
    leaves = jax.tree.leaves(grads)
    assert leaves
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in leaves), (
        f"{arch}: non-finite grads")
    norms = [float(jnp.linalg.norm(g.astype(jnp.float32))) for g in leaves]
    assert sum(norms) > 0, f"{arch}: all-zero grads"


def _prefill_decode(arch):
    """Prefill on S tokens, then decode token S; compare against a full
    prefill over S+1 tokens (logits at the last position must agree)."""
    cfg, batch = smoke_batch(arch, "train_4k")
    model = get_model(cfg)
    params = model.init(jax.random.key(0), cfg)
    toks = jnp.asarray(batch["tokens"])
    B, S = toks.shape
    cut = S - 1

    n_patch = batch["patch_embeds"].shape[1] if "patch_embeds" in batch else 0
    max_len = n_patch + S + 8
    pre_batch = dict(batch)
    pre_batch["tokens"] = toks[:, :cut]
    if "positions_3d" in batch:
        pre_batch["positions_3d"] = jnp.asarray(
            batch["positions_3d"])[:, : n_patch + cut]
    logits_a, state = model.prefill(params, pre_batch, cfg, max_len=max_len)
    logits_b, state = model.decode_step(params, toks[:, cut], state, cfg)

    full_batch = dict(batch)
    full_batch["tokens"] = toks
    if "positions_3d" in batch:
        full_batch["positions_3d"] = jnp.asarray(
            batch["positions_3d"])[:, : n_patch + S]
    logits_full, _ = model.prefill(params, full_batch, cfg, max_len=max_len)
    return np.asarray(logits_b), np.asarray(logits_full)


@pytest.mark.parametrize("arch", [
    "qwen2-7b", "olmoe-1b-7b", "deepseek-v2-236b", "falcon-mamba-7b",
    "zamba2-2.7b", "seamless-m4t-medium", "qwen2-vl-2b",
])
def test_prefill_decode_consistency(arch):
    got, want = _prefill_decode(arch)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_instantiable(arch):
    cfg = get_config(arch)
    assert cfg.n_params() > 1e8, f"{arch}: implausibly few params"
    # every supported cell must have lowerable input specs
    for shape in supported_cells(arch):
        specs = input_specs(arch, shape)
        assert specs, (arch, shape)


def test_param_counts_sane():
    """Full-config param counts within +-40% of the published sizes."""
    expect = {
        "falcon-mamba-7b": 7.3e9,
        "olmoe-1b-7b": 6.9e9,
        "deepseek-v2-236b": 236e9,
        "codeqwen1.5-7b": 7.3e9,
        "starcoder2-3b": 3.0e9,
        "qwen2.5-14b": 14.8e9,
        "qwen2-7b": 7.6e9,
        "zamba2-2.7b": 2.7e9,
    }
    for arch, want in expect.items():
        got = get_config(arch).n_params()
        assert 0.6 * want < got < 1.4 * want, (arch, got, want)


def test_long_500k_only_subquadratic():
    for arch in ARCHS:
        cells = supported_cells(arch)
        if arch in ("falcon-mamba-7b", "zamba2-2.7b"):
            assert "long_500k" in cells
        else:
            assert "long_500k" not in cells


def test_moe_router_balanced_under_uniform_tokens():
    """Property: with random tokens the aux loss sits near its floor of
    router_aux_coef (perfectly balanced) and well below 2x."""
    cfg, batch = smoke_batch("olmoe-1b-7b", "train_4k")
    from repro.models.registry import get_model

    model = get_model(cfg)
    params = model.init(jax.random.key(1), cfg)
    _, metrics = model.loss(params, batch, cfg)
    aux_per_layer = float(metrics["aux_loss"]) / cfg.n_layers
    assert cfg.router_aux_coef * 0.5 < aux_per_layer < cfg.router_aux_coef * 2
