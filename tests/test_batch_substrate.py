"""Tests for the vectorized multi-chip measurement substrate:

  * analyze_batch == per-config analyze, exactly, on both registered chips;
  * measure_batch is statistically identical to the sequential scalar loop;
  * config_features_batch == per-config config_features;
  * the chip registry resolves names/aliases and rejects unknown chips;
  * the RTX-4070 spec yields plausible roofline behaviour;
  * profiler -> predictor -> autotuner round-trips per chip.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.autotuner import GemmAutotuner, build_default_predictor
from repro.core.chips import RTX_4070, TPU_V5E, available_chips, get_chip
from repro.core.features import (
    NUMERIC_FEATURES,
    config_features,
    config_features_batch,
)
from repro.core.hwsim import (
    GemmConfig,
    TpuGemmSimulator,
    telemetry_row,
)
from repro.core.predictor import PerfPredictor
from repro.core.profiler import collect_dataset, profile_configs, sweep_configs

CHIPS = ("tpu_v5e", "rtx4070")

_FLOAT_FIELDS = (
    "runtime_ms", "power_w", "energy_j", "tflops", "compute_time_ms",
    "memory_time_ms", "overhead_ms", "mxu_utilization", "hbm_utilization",
    "arithmetic_intensity", "temperature_c",
)
_EXACT_FIELDS = (
    "vmem_working_set_bytes", "max_inflight_buffers", "pipelined",
    "grid_steps", "bound", "valid",
)


def _sample_configs(n=200, seed=11):
    cfgs = sweep_configs(n_configs=n, seed=seed)
    # include edge cases: invalid (VMEM OOM), sub-sublane, misaligned
    cfgs += [
        GemmConfig(8192, 8192, 8192, 4096, 4096, 4096),   # OOM -> invalid
        GemmConfig(2048, 2048, 2048, 8, 8, 8),            # VPU fallback
        GemmConfig(4096, 4096, 4096, 100, 100, 500),      # misaligned
        GemmConfig(2048, 2048, 256, 256, 256, 256, beta=1.0),
        GemmConfig(512, 512, 512, 128, 128, 128, layout="tt", dtype="f32"),
    ]
    return cfgs


class TestBatchScalarParity:
    @pytest.mark.parametrize("chip", CHIPS)
    def test_analyze_batch_matches_scalar_exactly(self, chip):
        cfgs = _sample_configs()
        batch = TpuGemmSimulator(chip=chip, seed=0).analyze_batch(cfgs)
        scalar_sim = TpuGemmSimulator(chip=chip, seed=0)
        for i, cfg in enumerate(cfgs):
            want = scalar_sim.analyze(cfg)
            got = telemetry_row(batch, i)
            for f in _EXACT_FIELDS:
                assert getattr(got, f) == getattr(want, f), (f, cfg)
            for f in _FLOAT_FIELDS:
                a, b = getattr(want, f), getattr(got, f)
                if np.isnan(a):
                    assert np.isnan(b), (f, cfg)
                else:
                    assert a == b, (f, cfg)  # bit-exact, not approx

    def test_batch_invariant_to_batch_size(self):
        """Splitting a batch must not change the analytical telemetry."""
        cfgs = _sample_configs(n=64)
        sim = TpuGemmSimulator(seed=0)
        whole = sim.analyze_batch(cfgs)
        halves = [sim.analyze_batch(cfgs[:32]), sim.analyze_batch(cfgs[32:])]
        for key in ("runtime_ms", "power_w", "grid_steps"):
            merged = np.concatenate([h[key] for h in halves])
            np.testing.assert_array_equal(merged[whole["valid"]],
                                          whole[key][whole["valid"]])

    def test_measure_batch_statistically_matches_scalar_loop(self):
        cfgs = sweep_configs(n_configs=400, seed=5)
        batch = TpuGemmSimulator(seed=9).measure_batch(cfgs)
        scalar_sim = TpuGemmSimulator(seed=9)
        scalar_rt = np.array([scalar_sim.measure(c).runtime_ms for c in cfgs])
        scalar_pw = np.array([scalar_sim.measure(c).power_w for c in cfgs])
        # same noise law, different draw order: compare noise distributions
        # relative to the shared noise-free oracle
        oracle = TpuGemmSimulator(seed=0).analyze_batch(cfgs)
        ratio_batch = batch["runtime_ms"] / oracle["runtime_ms"]
        ratio_scalar = scalar_rt / oracle["runtime_ms"]
        assert abs(np.median(ratio_batch) - np.median(ratio_scalar)) < 0.01
        assert abs(np.std(np.log(ratio_batch))
                   - np.std(np.log(ratio_scalar))) < 0.015
        dp_batch = batch["power_w"] - oracle["power_w"]
        dp_scalar = scalar_pw - oracle["power_w"]
        assert abs(np.mean(dp_batch) - np.mean(dp_scalar)) < 1.5

    def test_measure_batch_thermal_state_walks(self):
        sim = TpuGemmSimulator(seed=0)
        hot = [GemmConfig(8192, 8192, 8192, 256, 256, 512)] * 50
        out = sim.measure_batch(hot)
        assert out["temperature_c"][-1] > out["temperature_c"][0]
        assert sim._temp_c == pytest.approx(out["temperature_c"][-1])

    @pytest.mark.parametrize("chip", CHIPS)
    def test_config_features_batch_matches_scalar(self, chip):
        cfgs = _sample_configs(n=100, seed=3)
        cols = config_features_batch(cfgs, chip=chip)
        assert set(cols) >= set(NUMERIC_FEATURES)
        for i, cfg in enumerate(cfgs[:40]):
            want = config_features(cfg, chip=chip)
            for key in NUMERIC_FEATURES:
                assert float(cols[key][i]) == want[key], (key, cfg)


class TestChipRegistry:
    def test_known_chips(self):
        assert set(available_chips()) >= {"tpu_v5e", "rtx4070"}
        assert get_chip("tpu_v5e") is TPU_V5E
        assert get_chip("rtx4070") is RTX_4070
        assert get_chip("rtx_4070") is RTX_4070  # alias
        assert get_chip(RTX_4070) is RTX_4070    # pass-through

    def test_unknown_chip_raises(self):
        with pytest.raises(ValueError, match="unknown chip"):
            get_chip("h100")

    def test_rtx4070_spec_matches_paper(self):
        assert RTX_4070.ridge_point("f32") == pytest.approx(57.8, rel=0.02)
        assert 80.0 <= RTX_4070.idle_power_w <= 100.0
        assert RTX_4070.tdp_w == 200.0
        assert RTX_4070.n_compute_units == 46
        assert RTX_4070.vmem_bytes == 48 * 2**10 * 46

    def test_rtx4070_roofline_split_plausible(self):
        """Big well-blocked GEMMs are compute-bound, skinny ones
        memory-bound, on the paper's chip."""
        sim = TpuGemmSimulator(chip="rtx4070", seed=0)
        big = sim.analyze(GemmConfig(4096, 4096, 4096, 128, 256, 512))
        skinny = sim.analyze(GemmConfig(16, 4096, 4096, 16, 256, 512))
        assert big.valid and big.bound == "compute"
        assert skinny.valid and skinny.bound == "memory"
        assert RTX_4070.idle_power_w <= big.power_w <= RTX_4070.tdp_w

    def test_sweep_produces_both_bounds_per_chip(self):
        for chip in CHIPS:
            table = collect_dataset(n_configs=400, seed=2, chip=chip)
            bounds = set(str(b) for b in table["bound"])
            assert {"compute", "memory"} <= bounds, (chip, bounds)


class TestCrossChipPipeline:
    @pytest.mark.parametrize("chip", CHIPS)
    def test_profile_fit_tune_roundtrip(self, chip, tmp_path):
        table = collect_dataset(n_configs=600, seed=1, chip=chip)
        pred = PerfPredictor(model="rf", residual=True, fast=True,
                             chip=chip).fit(table)
        assert pred.chip_name == chip
        tuner = GemmAutotuner(pred, chip=chip,
                              cache_path=str(tmp_path / f"{chip}.json"))
        assert tuner.chip.name == get_chip(chip).name
        best = tuner.best_config(2048, 2048, 2048)
        assert tuner.sim.analyze(
            GemmConfig(2048, 2048, 2048, best.block_m, best.block_n,
                       best.block_k)).valid
        rep = tuner.tune_report(4096, 4096, 4096)
        assert rep["chip"] == get_chip(chip).name
        assert rep["speedup"] > 0.9

    def test_chips_disagree_on_telemetry(self):
        """The same config must measure differently across substrates —
        otherwise per-chip datasets/predictors are pointless."""
        cfg = GemmConfig(4096, 4096, 4096, 128, 256, 512)
        v5e = TpuGemmSimulator(chip="tpu_v5e", seed=0).analyze(cfg)
        ada = TpuGemmSimulator(chip="rtx4070", seed=0).analyze(cfg)
        assert ada.runtime_ms > 2 * v5e.runtime_ms  # ~7x peak-FLOPs gap
        assert ada.power_w != v5e.power_w

    def test_build_default_predictor_per_chip_artifacts(self, tmp_path):
        art = str(tmp_path)
        p1 = build_default_predictor(art, n_train=300, chip="tpu_v5e")
        p2 = build_default_predictor(art, n_train=300, chip="rtx4070")
        assert (tmp_path / "perf_predictor_tpu_v5e.npz").exists()
        assert (tmp_path / "perf_predictor_rtx4070.npz").exists()
        assert p1.chip_name == "tpu_v5e"
        assert p2.chip_name == "rtx4070"
        # reload path hits the per-chip artifact, not a retrain
        p1b = build_default_predictor(art, n_train=300, chip="tpu_v5e")
        assert p1b.chip_name == "tpu_v5e"


class TestBatchProfilerSpeed:
    @pytest.mark.slow
    def test_batch_collect_faster_than_scalar_loop(self):
        """Acceptance: the batched sweep is >=5x the per-config loop."""
        import time

        cfgs = sweep_configs(n_configs=2000, seed=0)
        sim_b = TpuGemmSimulator(seed=0)
        t0 = time.perf_counter()
        profile_configs(cfgs, sim_b)
        batch_s = time.perf_counter() - t0

        sim_s = TpuGemmSimulator(seed=0)
        t0 = time.perf_counter()
        profile_configs(cfgs, sim_s, measure_fn=sim_s.measure)
        scalar_s = time.perf_counter() - t0
        assert scalar_s > 5 * batch_s, (scalar_s, batch_s)

    def test_measure_fn_override_still_supported(self):
        """Real-hardware path: a per-config callable drives the profiler."""
        sim = TpuGemmSimulator(seed=0)
        calls = []

        def fake_hw(cfg):
            calls.append(cfg)
            tel = sim.analyze(cfg)
            return dataclasses.replace(tel, runtime_ms=tel.runtime_ms * 2)

        cfgs = sweep_configs(n_configs=30, seed=0)
        table = profile_configs(cfgs, sim, measure_fn=fake_hw)
        assert len(calls) == 30
        oracle = TpuGemmSimulator(seed=0).analyze_batch(cfgs)
        np.testing.assert_allclose(table["runtime_ms"],
                                   2 * oracle["runtime_ms"][oracle["valid"]])
