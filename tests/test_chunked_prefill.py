"""Chunked-admission prefill: bit-parity, bucket math, fused energy.

The tentpole contract: a prompt prefilled in chunks through the decode
loop produces the *bit-identical* greedy stream to PR 4's single-shot
slot prefill (`admission="serial"`) and to the wave loop, for every
servable family — including the SSM families, whose conv/scan state is
carried across chunk boundaries exactly (`ssm.SERVE_CHUNK` alignment +
identity-padded tails). Also covers the memoized/bisected bucket lookup
and the fused-step (decode rows + chunk rows) energy pricing.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig, gemm_shape_counts
from repro.models.registry import get_model
from repro.serving.engine import Request, ServingEngine

BASE = dict(name="chunk-test", n_layers=2, d_model=64, n_heads=4,
            n_kv_heads=2, vocab=128, param_dtype="float32",
            activation_dtype="float32", remat=False)

FAMILY_KW = {
    "dense": dict(d_ff=128),
    "moe": dict(d_ff=0, n_experts=4, top_k=2, d_ff_expert=64,
                capacity_factor=16.0),
    "mla_moe": dict(d_ff=128, n_experts=4, top_k=2, d_ff_expert=64,
                    capacity_factor=16.0, n_shared_experts=1,
                    kv_lora_rank=16, rope_head_dim=8),
    "mamba1": dict(d_ff=0, ssm_state=8, expand=2, d_conv=4),
    "mamba2": dict(d_ff=0, ssm_state=8, expand=2, d_conv=4,
                   ssm_headdim=16, ssm_ngroups=1),
    "hybrid": dict(d_ff=128, ssm_state=8, expand=2, d_conv=4,
                   ssm_headdim=16, ssm_ngroups=1, attn_every=2),
    "encdec": dict(d_ff=128, n_encoder_layers=2, gated_mlp=False),
    "vlm": dict(d_ff=128, qkv_bias=True, mrope=True,
                mrope_sections=(4, 2, 2)),
}

FAMILIES = sorted(FAMILY_KW)


def family_extras(kind: str, cfg: ModelConfig, uid: int) -> dict | None:
    """Per-request admission extras: encdec always carries a source
    embedding (lengths straddle the bucket grid), vlm mixes image
    requests with one text-only request (uid 2) that must serve exactly
    like a dense LM."""
    if kind == "encdec":
        rng = np.random.default_rng(1000 + uid)
        t = 6 + 3 * (uid % 3)
        return {"src_embeds": rng.standard_normal(
            (t, cfg.d_model)).astype(np.float32)}
    if kind == "vlm":
        grid = {0: (4, 4), 1: (2, 3), 2: None, 3: (3, 2)}[uid % 4]
        if grid is None:
            return None
        gh, gw = grid
        rng = np.random.default_rng(2000 + uid)
        return {"patch_embeds": rng.standard_normal(
            (gh * gw, cfg.d_model)).astype(np.float32), "grid_hw": grid}
    return None


@pytest.fixture(scope="module")
def served():
    out = {}
    for kind, kw in FAMILY_KW.items():
        cfg = ModelConfig(kind=kind, **{**BASE, **kw})
        model = get_model(cfg)
        out[kind] = (cfg, model, model.init(jax.random.key(0), cfg))
    return out


def prompt(seed: int, n: int, vocab: int = 128) -> np.ndarray:
    return np.random.default_rng(seed).integers(0, vocab, n).astype(np.int32)


def serve(served, kind, reqs, *, mode="continuous", admission="chunked",
          chunk_tokens=8, max_batch=2, max_len=64, **ekw):
    cfg, model, params = served[kind]
    eng = ServingEngine(model, params, cfg, max_batch=max_batch,
                        max_len=max_len, mode=mode, admission=admission,
                        chunk_tokens=chunk_tokens, **ekw)
    for uid, p, mnt in reqs:
        eng.submit(Request(uid=uid, prompt=p.copy(), max_new_tokens=mnt,
                           extras=family_extras(kind, cfg, uid)))
    return eng, {r.uid: r for r in eng.run_until_empty()}


# prompt lengths straddle the chunk size (8): 21 needs 3 chunks, 11 needs
# 2, 5 and 8 fit one (8 exactly on the bucket edge)
def workload(vocab=128):
    return [(0, prompt(10, 21, vocab), 5), (1, prompt(11, 5, vocab), 4),
            (2, prompt(12, 11, vocab), 6), (3, prompt(13, 8, vocab), 3)]


# ---------------------------------------------------------------------------
# bit-parity: chunked vs single-shot vs wave
# ---------------------------------------------------------------------------


class TestChunkedParity:
    @pytest.mark.parametrize("kind", FAMILIES)
    def test_chunked_matches_serial_single_shot(self, served, kind):
        """Acceptance: chunked prefill produces bit-identical greedy
        streams to PR 4 single-shot slot prefill for every family."""
        reqs = workload()
        ec, rc = serve(served, kind, reqs, admission="chunked")
        es, rs = serve(served, kind, reqs, admission="serial")
        assert ec.report()["chunk_steps"] > 0
        assert es.report()["chunk_steps"] == 0
        for uid, _, mnt in reqs:
            assert rc[uid].n_tokens == mnt
            np.testing.assert_array_equal(rc[uid].tokens, rs[uid].tokens)

    @pytest.mark.parametrize("kind", FAMILIES)
    def test_chunked_matches_wave(self, served, kind):
        reqs = workload()
        _, rc = serve(served, kind, reqs, admission="chunked")
        _, rw = serve(served, kind, reqs, mode="wave")
        for uid, _, _ in reqs:
            np.testing.assert_array_equal(rc[uid].tokens, rw[uid].tokens)

    @pytest.mark.parametrize("kind", ["dense", "mamba2", "hybrid",
                                      "encdec", "vlm"])
    def test_chunk_size_invariance(self, served, kind):
        """The stream must not depend on the chunking grid (8 vs 16 vs
        whole-prompt chunks)."""
        reqs = workload()
        streams = []
        for ct in (8, 16, 64):
            _, r = serve(served, kind, reqs, chunk_tokens=ct)
            streams.append([r[uid].tokens for uid, _, _ in reqs])
        for other in streams[1:]:
            for a, b in zip(streams[0], other):
                np.testing.assert_array_equal(a, b)

    @pytest.mark.parametrize("kind", ["mamba1", "mamba2", "hybrid"])
    def test_ssm_state_matches_unchunked_prefill(self, served, kind):
        """SSM conv/scan state after chunked prefill is bit-identical to
        the single-shot (unchunked) prefill state."""
        cfg, model, params = served[kind]
        p = prompt(42, 21, cfg.vocab)
        n, max_len = len(p), 64
        toks = np.zeros((1, 32), np.int32)
        toks[0, :n] = p
        _, ref = model.prefill(
            params, {"tokens": jnp.asarray(toks),
                     "lengths": jnp.asarray([n], np.int32)},
            cfg, max_len=max_len)
        st = model.init_state(cfg, 1, max_len)
        for lo in range(0, n, 8):
            ln = min(8, n - lo)
            ch = np.zeros((1, 8), np.int32)
            ch[0, :ln] = p[lo:lo + ln]
            _, st = model.prefill_chunk(
                params, jnp.asarray(ch), jnp.asarray([ln], np.int32),
                st, cfg)
        np.testing.assert_array_equal(np.asarray(st["index"]),
                                      np.asarray(ref["index"]))
        key = "kv" if "kv" in ref else "cache"
        ref_state, got_state = ref[key], st[key]
        if kind == "hybrid":
            # the shared-attn KV cache holds bucket-dependent pad junk
            # past each path's written region (covered by stream parity);
            # the recurrent state is the exact-carry contract under test
            ref_state, got_state = ref_state["mamba"], got_state["mamba"]
        for a, b in zip(jax.tree.leaves(ref_state),
                        jax.tree.leaves(got_state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_long_prompt_fed_through_decode_loop(self, served):
        """A prompt longer than chunk_tokens admits without stalling:
        residents keep decoding between its chunks, and under chunked
        admission the short request's first token lands *before* the
        long prompt finishes prefilling (the TTFT win); serial admission
        stalls the short request behind the whole long prefill."""
        cfg, model, params = served["dense"]
        long_p, short_p = prompt(50, 33), prompt(51, 5)
        reqs = [(0, long_p, 4), (1, short_p, 4)]
        _, rc = serve(served, "dense", reqs, admission="chunked")
        assert rc[0].n_tokens == 4 and rc[1].n_tokens == 4
        assert rc[1].ttft_s < rc[0].ttft_s          # short served first
        _, rs = serve(served, "dense", reqs, admission="serial")
        assert rs[1].ttft_s > rs[0].ttft_s          # serial stalls it
        np.testing.assert_array_equal(rc[0].tokens, rs[0].tokens)
        np.testing.assert_array_equal(rc[1].tokens, rs[1].tokens)

    def test_nongreedy_chunked_streams_are_batch_independent(self, served):
        """Per-request RNG streams survive the chunked admission path."""

        def sampled(kind, companion):
            reqs = [(0, prompt(60, 13), 5)]
            if companion:
                reqs.append((1, prompt(61, 21), companion))
            _, r = serve(served, kind, reqs, greedy=False, seed=7)
            return r[0].tokens

        base = sampled("dense", 5)
        np.testing.assert_array_equal(base, sampled("dense", 2))
        np.testing.assert_array_equal(base, sampled("dense", 0))

    def test_drifted_base_near_max_len_cannot_overrun_kv(self, served):
        """Regression: SJF chunk sizing can leave a long prompt's base at
        a point where base + chunk_bucket > max_len (a short co-admission
        shrinks an early chunk, later solo chunks grow again). The
        bucket-padded KV write must not clamp back over valid keys —
        `cache_update(update_lens=...)` masks the write to valid rows."""
        # long 60-token prompt in max_len=64: first chunk C=8 (short's
        # remainder), then solo chunks C=32 put base at 40 with rem 20 —
        # an unmasked 32-wide write at 40 would clamp to 32 and corrupt
        reqs = [(0, prompt(80, 60), 3), (1, prompt(81, 8), 2)]
        _, rc = serve(served, "dense", reqs, chunk_tokens=32, max_len=64)
        _, rs = serve(served, "dense", reqs, admission="serial",
                      chunk_tokens=32, max_len=64)
        for uid in (0, 1):
            np.testing.assert_array_equal(rc[uid].tokens, rs[uid].tokens)

    def test_parked_row_kv_not_overwritten_by_lane_chunks(self, served):
        """Regression: a parked (prefilled, slot-waiting) lane row must
        not receive junk KV writes from subsequent chunk calls — its
        state is spliced into a decode slot later and must stay exact."""
        # B=1: the short parks behind the resident while the long keeps
        # chunking in the lane; B=1 also forces maximal slot contention
        reqs = [(0, prompt(82, 10), 8), (1, prompt(83, 12), 4),
                (2, prompt(84, 33), 4)]
        _, rc = serve(served, "dense", reqs, max_batch=1, chunk_tokens=8,
                      max_len=64)
        _, rw = serve(served, "dense", reqs, mode="wave", max_batch=1,
                      max_len=64)
        for uid, _, _ in reqs:
            np.testing.assert_array_equal(rc[uid].tokens, rw[uid].tokens)

    def test_ssm_long_prompt_with_unaligned_max_len_bucket(self, served):
        """Regression: an attention-free prompt longer than a
        non-multiple-of-8 max_len must keep chunk boundaries SSM-grain
        aligned (the max_len bucket is dropped for non-final chunks), or
        the carried scan state loses bit parity with the unchunked scan."""
        cfg, model, params = served["mamba1"]
        from repro.serving.engine import Request, ServingEngine

        streams = {}
        for mode in ("continuous", "wave"):
            eng = ServingEngine(model, params, cfg, max_batch=2,
                                max_len=60, chunk_tokens=64, mode=mode)
            eng.submit(Request(uid=0, prompt=prompt(85, 100, cfg.vocab),
                               max_new_tokens=4))
            (res,) = eng.run_until_empty()
            streams[mode] = res.tokens
        np.testing.assert_array_equal(streams["continuous"],
                                      streams["wave"])

    def test_attention_free_long_prompt_exceeds_max_len(self, served):
        """Chunked admission serves attention-free prompts longer than
        max_len (no KV bound): state just keeps scanning."""
        cfg, _, _ = served["mamba1"]
        reqs = [(0, prompt(70, 40, cfg.vocab), 4)]
        _, rc = serve(served, "mamba1", reqs, max_len=32)
        assert rc[0].n_tokens == 4
        _, rw = serve(served, "mamba1", reqs, mode="wave", max_len=32)
        np.testing.assert_array_equal(rc[0].tokens, rw[0].tokens)


# ---------------------------------------------------------------------------
# prefill-once admission families (encdec source encoding, vlm patches)
# ---------------------------------------------------------------------------


class TestAdmitFamilies:
    def test_encdec_prefill_once_cross_kv_carry(self, served):
        """The cross-KV computed ONCE at admission is carried bit-exactly
        through chunked decoder prefill: admit + chunks reproduces the
        single-shot `encdec_prefill` state leaf for leaf."""
        cfg, model, params = served["encdec"]
        p = prompt(42, 21, cfg.vocab)
        ex = family_extras("encdec", cfg, 0)
        T, n, max_len, bucket = ex["src_embeds"].shape[0], len(p), 64, 16
        toks = np.zeros((1, 32), np.int32)
        toks[0, :n] = p
        src = np.zeros((1, bucket, cfg.d_model), np.float32)
        src[0, :T] = ex["src_embeds"]
        _, ref = model.prefill(
            params, {"tokens": jnp.asarray(toks),
                     "lengths": jnp.asarray([n], np.int32),
                     "src_embeds": jnp.asarray(src),
                     "src_lens": jnp.asarray([T], np.int32)},
            cfg, max_len=max_len)
        st = model.init_state(cfg, 1, max_len)
        st = model.admit(params, model.pack_admit(cfg, [ex], 1, bucket),
                         st, cfg)
        for lo in range(0, n, 8):
            ln = min(8, n - lo)
            ch = np.zeros((1, 8), np.int32)
            ch[0, :ln] = p[lo:lo + ln]
            _, st = model.prefill_chunk(
                params, jnp.asarray(ch), jnp.asarray([ln], np.int32),
                st, cfg)
        np.testing.assert_array_equal(np.asarray(st["index"]),
                                      np.asarray(ref["index"]))
        np.testing.assert_array_equal(np.asarray(st["src_len"]),
                                      np.asarray(ref["src_len"]))
        for k in ("xk", "xv"):
            np.testing.assert_array_equal(np.asarray(st["kv"][k]),
                                          np.asarray(ref["kv"][k]))
        # decoder self-attn KV: compare the written region (pad tails
        # past each chunk grid's bucket differ by construction)
        for k in ("k", "v"):
            np.testing.assert_array_equal(np.asarray(st["kv"][k][:, :, :n]),
                                          np.asarray(ref["kv"][k][:, :, :n]))

    def test_vlm_patch_prefix_carry(self, served):
        """The patch prefix lands in cache rows [0, P) at admission and
        the chunked text tail starts at index = P with mRoPE positions
        resuming mid-sequence — matching single-shot `vlm_prefill`."""
        from repro.models.vlm import build_mrope_positions

        cfg, model, params = served["vlm"]
        p = prompt(43, 11, cfg.vocab)
        ex = family_extras("vlm", cfg, 0)
        P = ex["patch_embeds"].shape[0]
        n, max_len = len(p), 64
        pos = build_mrope_positions(P, ex["grid_hw"], n)
        _, ref = model.prefill(
            params, {"tokens": jnp.asarray(p[None]),
                     "patch_embeds": jnp.asarray(ex["patch_embeds"][None]),
                     "positions_3d": jnp.asarray(pos[None])},
            cfg, max_len=max_len)
        st = model.init_state(cfg, 1, max_len)
        st = model.admit(params, model.pack_admit(cfg, [ex], 1, P),
                         st, cfg)
        assert int(np.asarray(st["index"])[0]) == P
        for lo in range(0, n, 8):
            ln = min(8, n - lo)
            ch = np.zeros((1, 8), np.int32)
            ch[0, :ln] = p[lo:lo + ln]
            logits, st = model.prefill_chunk(
                params, jnp.asarray(ch), jnp.asarray([ln], np.int32),
                st, cfg)
        np.testing.assert_array_equal(np.asarray(st["pos_off"]),
                                      np.asarray(ref["pos_off"]))
        S = P + n
        for k in ("k", "v"):
            np.testing.assert_array_equal(
                np.asarray(st["kv"][k][:, :, :S]),
                np.asarray(ref["kv"][k][:, :, :S]))

    def test_encdec_requires_src_embeds(self, served):
        cfg, model, params = served["encdec"]
        eng = ServingEngine(model, params, cfg, max_batch=2, max_len=64)
        with pytest.raises(ValueError, match="src_embeds"):
            eng.submit(Request(uid=0, prompt=prompt(0, 5),
                               max_new_tokens=2))

    def test_source_longer_than_max_len_rejected(self, served):
        """The uniform per-row bound covers the source side too: a
        source that cannot fit the cross-KV capacity is rejected at
        submit, not silently truncated."""
        cfg, model, params = served["encdec"]
        eng = ServingEngine(model, params, cfg, max_batch=2, max_len=32)
        rng = np.random.default_rng(0)
        big = {"src_embeds": rng.standard_normal(
            (40, cfg.d_model)).astype(np.float32)}
        with pytest.raises(ValueError):
            eng.submit(Request(uid=0, prompt=prompt(0, 5),
                               max_new_tokens=2, extras=big))


# ---------------------------------------------------------------------------
# scan-level invariants the serving contract relies on
# ---------------------------------------------------------------------------


class TestScanInvariants:
    def _mamba1_inputs(self, S, B=2, di=4, ds=3, seed=0):
        rng = np.random.default_rng(seed)
        r = lambda *s: jnp.asarray(rng.standard_normal(s).astype(np.float32))
        decay = jnp.exp(-jnp.abs(r(B, S, di, ds)))
        return decay, r(B, S, di, ds), r(B, S, ds), r(B, di, ds)

    def test_mamba1_scan_boundary_split_is_exact(self):
        from repro.models.ssm import mamba1_scan

        decay, inp, C, h0 = self._mamba1_inputs(48)
        y, h = mamba1_scan(decay, inp, C, h0, chunk=8)
        y1, h1 = mamba1_scan(decay[:, :32], inp[:, :32], C[:, :32], h0,
                             chunk=8)
        y2, h2 = mamba1_scan(decay[:, 32:], inp[:, 32:], C[:, 32:], h1,
                             chunk=8)
        np.testing.assert_array_equal(np.asarray(h), np.asarray(h2))
        np.testing.assert_array_equal(
            np.asarray(y), np.asarray(jnp.concatenate([y1, y2], 1)))

    def test_mamba1_scan_pads_non_divisible_tail(self):
        """S not divisible by the block no longer asserts; the identity
        tail is bit-transparent."""
        from repro.models.ssm import mamba1_scan

        decay, inp, C, h0 = self._mamba1_inputs(21)
        y, h = mamba1_scan(decay, inp, C, h0, chunk=8)
        assert y.shape[1] == 21
        yf, hf = mamba1_scan(decay[:, :16], inp[:, :16], C[:, :16], h0,
                             chunk=8)
        np.testing.assert_array_equal(np.asarray(y[:, :16]), np.asarray(yf))

    def test_ssd_scan_boundary_split_is_exact(self):
        from repro.models.ssm import ssd_scan

        rng = np.random.default_rng(1)
        r = lambda *s: jnp.asarray(rng.standard_normal(s).astype(np.float32))
        B, S, H, N, P = 2, 48, 2, 4, 4
        x, a = r(B, S, H, P), -jnp.abs(r(B, S, H))
        Bm, Cm, h0 = r(B, S, N), r(B, S, N), r(B, H, N, P)
        y, h = ssd_scan(x, a, Bm, Cm, h0, chunk=8)
        y1, h1 = ssd_scan(x[:, :32], a[:, :32], Bm[:, :32], Cm[:, :32],
                          h0, chunk=8)
        y2, h2 = ssd_scan(x[:, 32:], a[:, 32:], Bm[:, 32:], Cm[:, 32:],
                          h1, chunk=8)
        np.testing.assert_array_equal(np.asarray(h), np.asarray(h2))
        np.testing.assert_array_equal(
            np.asarray(y), np.asarray(jnp.concatenate([y1, y2], 1)))

    def test_cache_update_masked_write_is_junk_free_and_clamp_proof(self):
        from repro.models.layers import cache_update

        L_, C = 16, 8
        cache = jnp.zeros((2, L_, 3))
        upd = jnp.asarray(np.arange(2 * C * 3, dtype=np.float32)
                          .reshape(2, C, 3) + 1)
        # row 0: in-bounds partial write (5 valid rows at 4); row 1: base
        # 12 — an unmasked 8-wide write would clamp to 8 and shift; the
        # masked write must land the 3 valid rows exactly at 12..14
        out = np.asarray(cache_update(
            cache, upd, jnp.asarray([4, 12], jnp.int32),
            update_lens=jnp.asarray([5, 3], jnp.int32)))
        np.testing.assert_array_equal(out[0, 4:9], np.asarray(upd[0, :5]))
        assert (out[0, :4] == 0).all() and (out[0, 9:] == 0).all()
        np.testing.assert_array_equal(out[1, 12:15], np.asarray(upd[1, :3]))
        assert (out[1, :12] == 0).all() and (out[1, 15:] == 0).all()
        # zero-length rows leave the cache untouched (parked lane rows)
        out = np.asarray(cache_update(
            cache, upd, jnp.asarray([4, 12], jnp.int32),
            update_lens=jnp.asarray([0, 0], jnp.int32)))
        assert (out == 0).all()

    def test_conv_history_carries_last_valid_inputs(self):
        from repro.models.ssm import conv_history

        B, K1, S, C = 2, 3, 8, 4
        hist = jnp.asarray(np.arange(B * K1 * C, dtype=np.float32)
                           .reshape(B, K1, C))
        x = jnp.asarray(100 + np.arange(B * S * C, dtype=np.float32)
                        .reshape(B, S, C))
        # full rows: last K-1 inputs; len-0 rows: history unchanged
        out = conv_history(hist, x, jnp.asarray([S, 0], jnp.int32))
        np.testing.assert_array_equal(np.asarray(out[0]),
                                      np.asarray(x[0, -K1:]))
        np.testing.assert_array_equal(np.asarray(out[1]),
                                      np.asarray(hist[1]))
        # partial row: the K-1 inputs ending at position len-1
        out = conv_history(hist, x, jnp.asarray([5, 2], jnp.int32))
        np.testing.assert_array_equal(np.asarray(out[0]),
                                      np.asarray(x[0, 2:5]))
        xp = jnp.concatenate([hist, x], axis=1)
        np.testing.assert_array_equal(np.asarray(out[1]),
                                      np.asarray(xp[1, 2:2 + K1]))


# ---------------------------------------------------------------------------
# bucket math (memoized + bisect)
# ---------------------------------------------------------------------------


class TestBuckets:
    def test_prefill_buckets_memoized(self):
        from repro.kernels import ops

        assert ops.prefill_buckets(128) is ops.prefill_buckets(128)
        assert ops.prefill_buckets(128) == (8, 16, 32, 64, 128)
        assert ops.prefill_buckets(96) == (8, 16, 32, 64, 96)
        assert ops.prefill_buckets(6) == (6,)

    def test_chunk_buckets_cap(self):
        from repro.kernels import ops

        assert ops.chunk_buckets(128, 32) == (8, 16, 32)
        assert ops.chunk_buckets(128, 128) == (8, 16, 32, 64, 128)
        assert ops.chunk_buckets(6, 64) == (6,)
        # cap below the smallest bucket falls back to the smallest
        assert ops.chunk_buckets(128, 4) == (8,)

    def test_engine_bucket_edges(self, served):
        """min / max / off-by-one bucket edges through the bisect path."""
        cfg, model, params = served["dense"]
        eng = ServingEngine(model, params, cfg, max_batch=2, max_len=64)
        assert eng._bucket(1) == 8
        assert eng._bucket(8) == 8          # exact edge
        assert eng._bucket(9) == 16         # one past the edge
        assert eng._bucket(63) == 64
        assert eng._bucket(64) == 64        # max_len edge
        # attention-free prompts may exceed max_len: ladder keeps doubling
        assert eng._bucket(65) == 128
        assert eng._bucket(300) == 512
        assert eng._chunk_bucket(1) == 8
        assert eng._chunk_bucket(9) == 16
        assert eng._chunk_bucket(1000) == 64  # capped at chunk_tokens

    def test_chunk_tokens_validation(self, served):
        cfg, model, params = served["dense"]
        with pytest.raises(ValueError):
            ServingEngine(model, params, cfg, max_len=64, chunk_tokens=12)
        # >= max_len escapes the SSM-grain constraint (single chunk)
        ServingEngine(model, params, cfg, max_len=64, chunk_tokens=64)
        with pytest.raises(ValueError):
            ServingEngine(model, params, cfg, admission="bogus")


# ---------------------------------------------------------------------------
# fused-step energy (decode rows + chunk rows)
# ---------------------------------------------------------------------------


class TestFusedEnergy:
    def test_combine_shape_counts_sums(self):
        from repro.core.energy import combine_shape_counts

        a = {(8, 64, 64): 2.0, (8, 128, 64): 1.0}
        b = {(8, 64, 64): 3.0, (16, 64, 64): 1.0}
        got = combine_shape_counts(a, b)
        assert got == {(8, 64, 64): 5.0, (8, 128, 64): 1.0,
                       (16, 64, 64): 1.0}

    def test_fused_step_prices_union_fleet(self, served):
        from repro.core.energy import (combine_shape_counts,
                                       fused_step_energy, gemm_fleet_energy)

        cfg, _, _ = served["dense"]
        decode = gemm_shape_counts(cfg, 4, kv_rows=4 * 64)
        chunk = gemm_shape_counts(cfg, 2 * 8, head_tokens=2, kv_rows=2 * 64)
        fused = fused_step_energy(decode, chunk, chip="tpu_v5e",
                                  dtype="float32")
        ref = gemm_fleet_energy(combine_shape_counts(decode, chunk),
                                chip="tpu_v5e", dtype="float32",
                                name="fused_step")
        assert fused.energy_j == ref.energy_j
        d = gemm_fleet_energy(decode, chip="tpu_v5e", dtype="float32")
        c = gemm_fleet_energy(chunk, chip="tpu_v5e", dtype="float32")
        assert fused.step_s == pytest.approx(d.step_s + c.step_s)
        assert fused.energy_j >= max(d.energy_j, c.energy_j)

    def test_engine_fused_estimate_and_chunk_attribution(self, served):
        eng, res = serve(served, "dense", workload())
        est = eng.fused_step_estimate(2, 8)
        assert est.energy_j > 0
        rep = eng.report()
        assert rep["chunk_steps"] > 0
        # every request carries chunk-call prefill energy
        assert all(r.energy_j > 0 for r in res.values())
        assert rep["attributed_energy_j"] == pytest.approx(
            sum(r.energy_j for r in res.values()))

    def test_serving_fleet_covers_chunk_grid(self, served):
        from repro.kernels import ops

        cfg, _, _ = served["dense"]
        fleet = set(ops.serving_gemm_fleet(cfg, max_batch=4, max_len=64,
                                           chunk_tokens=16))
        for w in (1, 2, 4):
            for c in (8, 16):
                assert set(gemm_shape_counts(
                    cfg, w * c, head_tokens=w, kv_rows=w * 64)) <= fleet
