"""Property-based parity suite for the compiled estimator layer.

Random *fitted states* — valid tree topologies, grid-quantized thresholds
and leaf values, random affine coefficients — are generated directly (not
via `fit`), rebuilt through the `to_state`/`from_state` contract, and
served through the compiled scorer (`JaxEstimator`), asserting for every
estimator family:

  * x64 jit-scorer output is **bit-exact** vs the numpy `predict`;
  * f32 jit-scorer output is within 1e-6 relative (inputs/thresholds sit
    on grids far coarser than one fp32 ulp, so branch decisions agree and
    only accumulation rounding remains);
  * `to_state` -> `from_state` -> `to_state` is idempotent, and the
    compiled scorer built from a round-tripped state matches the original
    bit-for-bit.

Runs under hypothesis when available (drawing generator seeds/shape knobs);
falls back to a deterministic seed sweep otherwise, so the suite guards CI
with or without the optional dependency. The ×-both-chips predictor-level
parity (real fitted models over real chip feature tables) lives at the
bottom.
"""

import numpy as np
import pytest

from repro.core.mlperf import (
    compilable_families,
    estimator_from_state,
    registered_estimator_names,
)
from repro.core.mlperf.forest import RandomForestRegressor
from repro.core.mlperf.gbdt import GradientBoostedTreesRegressor
from repro.core.mlperf.jaxpredict import JaxEstimator
from repro.core.mlperf.linreg import LinearRegression, Ridge
from repro.core.mlperf.stacking import StackingRegressor
from repro.core.mlperf.tree import DecisionTreeRegressor, _FlatTree

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False

FAMILIES = ("tree", "forest", "gbdt", "linreg", "ridge", "stacking")

# Grids coarse enough that one fp32 ulp can't flip a comparison: feature
# values and split thresholds are multiples of 1/16 in [1/16, 4] (spacing
# 6.25e-2 >> 2**-22 ≈ 2.4e-7, the fp32 ulp at 4.0). Every generated
# quantity (features, thresholds, leaves, coefficients, intercepts) is
# *positive*, so fp32 accumulations never cancel and the elementwise
# relative-error bound stays a few ulps.
_GRID = 1.0 / 16.0


def _grid_vals(rng, size, lo=_GRID, hi=4.0):
    return rng.integers(round(lo / _GRID), round(hi / _GRID) + 1,
                        size=size).astype(np.float64) * _GRID


def _random_flat_tree(rng, depth: int, n_features: int,
                      n_targets: int) -> _FlatTree:
    """A random perfect binary tree of `depth` in the flat layout."""
    n_internal = 2 ** depth - 1
    n_nodes = 2 ** (depth + 1) - 1
    feature = np.full(n_nodes, -1, dtype=np.int32)
    feature[:n_internal] = rng.integers(0, n_features, size=n_internal)
    threshold = np.zeros(n_nodes)
    threshold[:n_internal] = _grid_vals(rng, n_internal)
    left = np.full(n_nodes, -1, dtype=np.int32)
    right = np.full(n_nodes, -1, dtype=np.int32)
    left[:n_internal] = 2 * np.arange(n_internal, dtype=np.int32) + 1
    right[:n_internal] = 2 * np.arange(n_internal, dtype=np.int32) + 2
    value = _grid_vals(rng, (n_nodes, n_targets), lo=0.5, hi=2.0)
    return _FlatTree(
        feature=feature, threshold=threshold,
        threshold_bin=np.zeros(n_nodes, dtype=np.int32),
        left=left, right=right, value=value,
        n_samples=np.ones(n_nodes, dtype=np.int32),
        gain=np.zeros(n_nodes),
    )


def _random_estimator(family: str, rng, *, n_features: int, n_targets: int,
                      depth: int, n_trees: int):
    """A predict-ready random fitted estimator of `family`."""
    trees = [_random_flat_tree(rng, depth, n_features, n_targets)
             for _ in range(n_trees)]

    def wrap(tree):
        est = DecisionTreeRegressor(max_depth=depth)
        est.tree_ = tree
        est.n_features_ = n_features
        est.n_targets_ = n_targets
        return est

    if family == "tree":
        return wrap(trees[0])
    if family == "forest":
        f = RandomForestRegressor(n_estimators=n_trees, max_depth=depth)
        f.estimators_ = [wrap(t) for t in trees]
        f.n_targets_ = n_targets
        return f
    if family == "gbdt":
        g = GradientBoostedTreesRegressor(n_estimators=n_trees,
                                          learning_rate=0.125,
                                          max_depth=depth)
        g.estimators_ = [wrap(t) for t in trees]
        g.base_ = _grid_vals(rng, n_targets, lo=0.5, hi=2.0)
        g.n_targets_ = n_targets
        return g
    if family in ("linreg", "ridge"):
        est = LinearRegression() if family == "linreg" else Ridge(alpha=0.5)
        est.coef_ = _grid_vals(rng, (n_features, n_targets), hi=2.0)
        est.intercept_ = _grid_vals(rng, n_targets, hi=2.0)
        return est
    if family == "stacking":
        s = StackingRegressor([], n_folds=2,
                              passthrough=bool(rng.integers(0, 2)))
        s.fitted_bases_ = [
            _random_estimator("forest", rng, n_features=n_features,
                              n_targets=n_targets, depth=depth,
                              n_trees=max(2, n_trees // 2)),
            _random_estimator("linreg", rng, n_features=n_features,
                              n_targets=n_targets, depth=depth, n_trees=1),
        ]
        s.n_targets_ = n_targets
        z_dim = (len(s.fitted_bases_) * n_targets
                 + (n_features if s.passthrough else 0))
        s.meta_ = []
        for _ in range(n_targets):
            m = Ridge(alpha=1e-3)
            m.coef_ = _grid_vals(rng, z_dim, hi=1.0)
            m.intercept_ = float(_grid_vals(rng, (), hi=1.0)[()])
            s.meta_.append(m)
        return s
    raise ValueError(family)


def _check_family(family: str, seed: int, n_features: int, n_targets: int,
                  depth: int, n_trees: int, n_rows: int) -> None:
    rng = np.random.default_rng(seed)
    est = _random_estimator(family, rng, n_features=n_features,
                            n_targets=n_targets, depth=depth,
                            n_trees=n_trees)
    X = _grid_vals(rng, (n_rows, n_features))
    want = np.asarray(est.predict(X)).reshape(n_rows, -1)

    # x64: bit-exact vs numpy predict
    got64 = JaxEstimator(est, x64=True).predict(X)
    np.testing.assert_array_equal(got64, want, err_msg=f"{family} x64")

    # f32: <= 1e-6 relative (grid-spaced data: no branch flips, positive
    # leaves: no cancellation)
    got32 = JaxEstimator(est).predict(X)
    rel = np.abs(got32 - want) / np.maximum(np.abs(want), 1e-12)
    assert rel.max() <= 1e-6, (family, rel.max())

    # state round-trip idempotence + compiled round-trip parity
    state = est.to_state()
    back = estimator_from_state(state)
    state2 = back.to_state()
    assert sorted(state) == sorted(state2), family
    for key in state:
        np.testing.assert_array_equal(state[key], state2[key],
                                      err_msg=f"{family}/{key}")
    np.testing.assert_array_equal(
        JaxEstimator(back, x64=True).predict(X), got64,
        err_msg=f"{family} compiled round-trip")


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(
        family=st.sampled_from(FAMILIES),
        seed=st.integers(0, 2**32 - 1),
        n_features=st.integers(2, 8),
        n_targets=st.integers(1, 4),
        depth=st.integers(1, 4),
        n_trees=st.integers(1, 8),
        n_rows=st.integers(1, 64),
    )
    def test_compiled_parity_hypothesis(family, seed, n_features, n_targets,
                                        depth, n_trees, n_rows):
        _check_family(family, seed, n_features, n_targets, depth, n_trees,
                      n_rows)


@pytest.mark.parametrize("family", FAMILIES)
@pytest.mark.parametrize("seed", range(4))
def test_compiled_parity_seeded(family, seed):
    """Deterministic fallback sweep (always runs, hypothesis or not)."""
    rng = np.random.default_rng(seed * 1000 + 7)
    _check_family(
        family, seed=seed * 31 + 1,
        n_features=int(rng.integers(2, 9)),
        n_targets=int(rng.integers(1, 5)),
        depth=int(rng.integers(1, 5)),
        n_trees=int(rng.integers(1, 9)),
        n_rows=int(rng.integers(1, 65)),
    )


def test_every_serializable_family_compiles():
    """The lowering registry covers the whole serialization registry:
    anything an artifact can hold can serve through the jit scorer."""
    assert set(registered_estimator_names()) <= set(compilable_families())


# ---------------------------------------------------------------------------
# predictor-level parity: real fitted models, both chips
# ---------------------------------------------------------------------------

CHIPS = ("tpu_v5e", "rtx4070")
MODELS = ("rf", "gbdt", "linreg", "stacking")


@pytest.fixture(scope="module")
def chip_tables():
    from repro.core.profiler import collect_dataset

    return {chip: collect_dataset(n_configs=300, seed=0, chip=chip)
            for chip in CHIPS}


def _small_zoo_model(name: str):
    """Shrunken Table VI models: parity doesn't need paper-scale
    ensembles, and 8 fits (4 families x 2 chips) must stay fast."""
    if name == "rf":
        return RandomForestRegressor(n_estimators=6, max_depth=5,
                                     random_state=0)
    if name == "gbdt":
        return GradientBoostedTreesRegressor(n_estimators=15, max_depth=3,
                                             random_state=0)
    if name == "linreg":
        return LinearRegression()
    if name == "stacking":
        return StackingRegressor(
            [RandomForestRegressor(n_estimators=4, max_depth=4,
                                   random_state=0),
             LinearRegression()],
            n_folds=2,
        )
    raise ValueError(name)


@pytest.mark.parametrize("chip", CHIPS)
@pytest.mark.parametrize("model", MODELS)
def test_x64_scorer_parity_all_models_both_chips(model, chip, chip_tables):
    """Every Table VI family serves through the compiled scorer on every
    chip's feature table; x64 estimator forward is bit-exact, so only the
    decode's exp/anchor ulps remain."""
    from repro.core.predictor import PerfPredictor

    table = chip_tables[chip]
    pred = PerfPredictor(model=model, residual=True, fast=True, chip=chip)
    pred.model = _small_zoo_model(model)
    pred.fit(table)
    assert pred.supports_jax()
    X = np.stack([table[k] for k in pred.feature_names], axis=1)[:128]
    sub = {k: v[:128] for k, v in table.items()}
    got = np.asarray(pred.jax_predictor(x64=True)(X))
    want = pred.predict_matrix(sub)
    np.testing.assert_allclose(got, want, rtol=1e-9)

    # the raw estimator forward (scaled features -> scaled targets) is
    # bit-exact — decode is the only remaining rounding source
    Xs = pred.scaler.transform(X)
    est_want = np.asarray(pred.model.predict(Xs)).reshape(len(Xs), -1)
    est_got = JaxEstimator(pred.model, x64=True).predict(Xs)
    np.testing.assert_array_equal(est_got, est_want)
